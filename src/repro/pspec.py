"""Activation-sharding annotation registry.

Model code is mesh-agnostic; the launcher registers the active mesh and the
logical->physical axis mapping, and layers annotate activations with
*logical* axes:

    with pspec.activation_mesh(mesh):
        ...jit/lower...          # model calls pspec.shard(x, "batch", None, "tp")

Outside a registered mesh every annotation is a no-op, so unit tests and
CPU examples run unchanged.  Specs are divisibility-guarded (an axis that
does not divide the dim is dropped) so one rule set serves full-size and
smoke configs.

Why explicit constraints: XLA SPMD propagates shardings forward from
operands, but a gather from a vocab-sharded embedding produces a replicated
result — without re-annotation the whole residual stream (and everything
after it) runs unpartitioned.  The batch axis constraint after the
embedding is what pins the activation layout for the entire network.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import numpy as np
import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["activation_mesh", "shard", "axis_size", "current_mesh"]

_tls = threading.local()

# logical name -> physical mesh axes
_LOGICAL = {
    "batch": ("pod", "data"),   # data parallel (pods x FSDP groups)
    "fsdp": ("data",),
    "tp": ("model",),           # tensor / expert parallel
    "sp": ("model",),           # Megatron-style sequence parallelism: the
    #                             residual stream between layers shards its
    #                             sequence dim over the TP axis, so scanned
    #                             layer carries cost (B·S·d)/(data·model)
    "seq": ("data", "model"),   # sequence parallelism (long-context decode)
    "tp_pad": ("model",),       # TP with uneven (padded) sharding allowed:
    #                             for head counts that don't divide the TP
    #                             axis (e.g. MLA's 40 heads on 16-way TP) —
    #                             XLA pads to 48; 20% waste beats full
    #                             replication of every attention tensor
}

_ALLOW_UNEVEN = {"tp_pad"}


def current_mesh():
    return getattr(_tls, "mesh", None)


@contextlib.contextmanager
def activation_mesh(mesh):
    prev = getattr(_tls, "mesh", None)
    _tls.mesh = mesh
    try:
        yield
    finally:
        _tls.mesh = prev


def _axes_size(mesh, axes: Tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes]))


def axis_size(name: str) -> int:
    mesh = current_mesh()
    if mesh is None:
        return 1
    axes = [a for a in _LOGICAL.get(name, ()) if a in mesh.axis_names]
    return _axes_size(mesh, tuple(axes)) if axes else 1


def shard(x, *logical: Optional[str]):
    """Annotate ``x`` with logical axes (None = unsharded dim).  No-op when
    no mesh is registered or under incompatible dims."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = []
    for dim, name in zip(x.shape, logical):
        if name is None:
            spec.append(None)
            continue
        phys = [a for a in _LOGICAL.get(name, (name,)) if a in mesh.axis_names]
        uneven_ok = name in _ALLOW_UNEVEN
        kept, size = [], 1
        for a in phys:
            s = mesh.shape[a]
            if dim % (size * s) == 0 or (uneven_ok and dim >= size * s):
                kept.append(a)
                size *= s
        spec.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    spec += [None] * (x.ndim - len(spec))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
