"""Unified model-config schema covering all 10 assigned architectures.

One dataclass drives model construction, sharding rules, input specs, the
ACADL workload extraction and the dry-run.  Per-family extras live in
optional sub-configs (attention / MoE / SSM / enc-dec / modality stubs).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import List, Literal, Optional, Tuple

__all__ = ["AttentionConfig", "MoEConfig", "SSMConfig", "EncDecConfig",
           "ModelConfig", "LayerKind", "SHAPES", "ShapeConfig"]

LayerKind = Literal["attn", "mamba"]


@dataclass(frozen=True)
class AttentionConfig:
    kind: Literal["gqa", "mla", "none"] = "gqa"
    n_heads: int = 16
    n_kv_heads: int = 16
    head_dim: int = 128
    window: int = 0                      # >0: sliding-window attention (SWA)
    rope_theta: float = 10_000.0
    # --- MLA (multi-head latent attention, MiniCPM3 / DeepSeek-V2 style) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    @property
    def qk_head_dim(self) -> int:
        if self.kind == "mla":
            return self.qk_nope_head_dim + self.qk_rope_head_dim
        return self.head_dim


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0            # DeepSeekMoE shared experts
    d_expert: int = 0                    # per-expert FFN width
    capacity_factor: float = 1.25
    every: int = 1                       # MoE layer period (jamba: 2)
    router_jitter: float = 0.0
    aux_loss_coef: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                     # 0 -> ceil(d_model / 16)
    chunk: int = 256                     # scan chunk (memory/remat knob)

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def dt_rank_of(self, d_model: int) -> int:
        return self.dt_rank or -(-d_model // 16)


@dataclass(frozen=True)
class EncDecConfig:
    n_encoder_layers: int = 12
    encoder_len: int = 1500              # whisper: 30 s of 10 ms frames / 2


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: Literal["dense", "moe", "hybrid", "ssm", "audio", "vlm"]
    n_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attention: AttentionConfig = AttentionConfig()
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    enc_dec: Optional[EncDecConfig] = None
    norm: Literal["rmsnorm", "layernorm", "nonparametric_ln"] = "rmsnorm"
    activation: Literal["silu", "gelu"] = "silu"
    tie_embeddings: bool = False
    # hybrid (jamba): attention every `attn_period` layers, offset `attn_offset`
    attn_period: int = 1
    attn_offset: int = 0
    # modality stubs
    n_patches: int = 0                   # vlm: precomputed patch embeddings
    # implementation selection
    attention_impl: str = "chunked"   # chunked | dense | flash_pallas[_interpret]
    ssm_impl: str = "chunked_scan"    # chunked_scan | pallas[_interpret]
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # hierarchical remat: save the residual stream every `remat_group`
    # pattern-period repeats; backward recomputes the group (memory knob for
    # deep/wide stacks — mistral-large's 88 x (B,S,d) carries)
    remat_group: int = 1
    # gradient-accumulation microbatches in train_step (memory knob: all
    # activation-linked buffers scale with B/microbatches)
    train_microbatches: int = 1
    # max positions for caches
    max_seq_len: int = 1 << 20
    # notes for DESIGN/EXPERIMENTS bookkeeping
    source: str = ""

    # ---- derived ---------------------------------------------------------------
    def layer_kinds(self) -> List[LayerKind]:
        """Per-layer block kind (jamba's 1:7 attention:mamba interleave)."""
        if self.family == "ssm":
            return ["mamba"] * self.n_layers
        if self.family == "hybrid":
            return ["attn" if (i % self.attn_period) == self.attn_offset
                    else "mamba" for i in range(self.n_layers)]
        return ["attn"] * self.n_layers

    def moe_layers(self) -> List[bool]:
        if self.moe is None:
            return [False] * self.n_layers
        return [(i % self.moe.every) == (self.moe.every - 1) or self.moe.every == 1
                for i in range(self.n_layers)]

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks), for 6·N·D."""
        a = self.attention
        d = self.d_model
        n = 0
        n += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        kinds = self.layer_kinds()
        moe_flags = self.moe_layers()
        for kind, is_moe in zip(kinds, moe_flags):
            if kind == "attn":
                if a.kind == "mla":
                    qk = a.qk_nope_head_dim + a.qk_rope_head_dim
                    n += d * a.q_lora_rank + a.q_lora_rank * a.n_heads * qk
                    n += d * (a.kv_lora_rank + a.qk_rope_head_dim)
                    n += a.kv_lora_rank * a.n_heads * (a.qk_nope_head_dim + a.v_head_dim)
                    n += a.n_heads * a.v_head_dim * d
                else:
                    n += d * a.n_heads * a.head_dim            # q
                    n += 2 * d * a.n_kv_heads * a.head_dim     # k, v
                    n += a.n_heads * a.head_dim * d            # o
            else:  # mamba
                s = self.ssm
                di = s.d_inner(d)
                n += d * 2 * di                                 # in_proj
                n += di * s.d_conv                              # conv
                n += di * (s.dt_rank_of(d) + 2 * s.d_state)     # x_proj
                n += s.dt_rank_of(d) * di + di                  # dt_proj
                n += di * s.d_state + di                        # A_log, D
                n += di * d                                     # out_proj
            if is_moe and self.moe is not None:
                m = self.moe
                n += d * m.n_experts                            # router
                n += m.n_experts * 3 * d * m.d_expert           # routed
                n += m.n_shared_experts * 3 * d * m.d_expert    # shared
            else:
                # gated (SwiGLU): gate/up/down; non-gated (gelu): up/down
                n += (3 if self.activation == "silu" else 2) * d * self.d_ff
        if self.enc_dec is not None:
            e = self.enc_dec
            # decoder blocks counted above; add encoder stack + cross-attn
            mlp_mats = 3 if self.activation == "silu" else 2
            per_enc = 4 * d * a.n_heads * a.head_dim + mlp_mats * d * self.d_ff
            n += e.n_encoder_layers * per_enc
            n += self.n_layers * 4 * d * a.n_heads * a.head_dim  # cross-attn
        return n

    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.moe is None:
            return self.n_params()
        m = self.moe
        total = self.n_params()
        routed_all = sum(m.n_experts * 3 * self.d_model * m.d_expert
                         for f in self.moe_layers() if f)
        routed_active = sum(m.top_k * 3 * self.d_model * m.d_expert
                            for f in self.moe_layers() if f)
        return total - routed_all + routed_active


# ---------------------------------------------------------------------------
# assigned input shapes (the 4 cells per architecture)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: Literal["train", "prefill", "decode"]


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
