"""Unified model API — family dispatch + input specs for every (arch ×
shape) cell.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of the given shape cell (weak-type-correct, shardable, no
device allocation) — the dry-run contract.  ``[audio]``/``[vlm]`` stubs:
frames/patches arrive as precomputed embeddings.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import encdec, lm
from .config import ModelConfig, ShapeConfig

__all__ = ["Model", "get_model", "input_specs", "cell_is_runnable"]


class Model:
    """Thin dispatcher: decoder-only LMs via ``lm``, whisper via ``encdec``."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.is_encdec = cfg.enc_dec is not None

    # -- params ---------------------------------------------------------------
    def init_params(self, key):
        if self.is_encdec:
            return encdec.init_params_encdec(self.cfg, key)
        return lm.init_params(self.cfg, key)

    def abstract_params(self):
        if self.is_encdec:
            return encdec.abstract_params_encdec(self.cfg)
        return lm.abstract_params(self.cfg)

    # -- forward --------------------------------------------------------------
    def logits(self, params, batch: Dict[str, Any], remat: bool = True):
        cfg = self.cfg
        if self.is_encdec:
            return encdec.forward_encdec(params, cfg, batch["tokens"],
                                         batch["frames"])
        return lm.forward(params, cfg, batch["tokens"],
                          patches=batch.get("patches"), remat=remat)

    def logits_and_aux(self, params, batch: Dict[str, Any], remat: bool = True):
        cfg = self.cfg
        if self.is_encdec:
            lg = encdec.forward_encdec(params, cfg, batch["tokens"],
                                       batch["frames"])
            return lg, jnp.zeros((), jnp.float32)
        return lm.forward_with_aux(params, cfg, batch["tokens"],
                                   patches=batch.get("patches"), remat=remat)

    # -- serving ----------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int):
        if self.is_encdec:
            return encdec.init_cache_encdec(self.cfg, batch, max_len)
        return lm.init_cache(self.cfg, batch, max_len)

    def prefill(self, params, batch: Dict[str, Any], cache):
        if self.is_encdec:
            return encdec.prefill_encdec(params, self.cfg, batch["tokens"],
                                         batch["frames"], cache)
        return lm.prefill(params, self.cfg, batch["tokens"], cache,
                          patches=batch.get("patches"))

    def decode_step(self, params, token, cache):
        if self.is_encdec:
            return encdec.decode_step_encdec(params, self.cfg, token, cache)
        return lm.decode_step(params, self.cfg, token, cache)


def get_model(cfg: ModelConfig) -> Model:
    return Model(cfg)


def cell_is_runnable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Shape-cell applicability (DESIGN.md §Shape-cell skips).

    long_500k needs sub-quadratic attention: runs for ssm / hybrid / SWA,
    skipped for pure full-attention archs.
    """
    if shape.name == "long_500k":
        subquadratic = (cfg.family in ("ssm", "hybrid")
                        or cfg.attention.window > 0)
        if not subquadratic:
            return False, ("pure full-attention arch: 500k dense KV decode "
                           "is excluded by the assignment's skip rule")
    if cfg.enc_dec is not None and shape.seq_len > cfg.max_seq_len:
        return False, f"decoder positions capped at {cfg.max_seq_len}"
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the step function's data inputs."""
    i32 = jnp.int32
    bf16 = jnp.bfloat16
    B, S = shape.global_batch, shape.seq_len

    if shape.mode in ("train", "prefill"):
        n_text = S - cfg.n_patches if cfg.n_patches else S
        specs: Dict[str, Any] = {
            "tokens": jax.ShapeDtypeStruct((B, n_text), i32),
        }
        if shape.mode == "train":
            specs["labels"] = jax.ShapeDtypeStruct((B, n_text), i32)
        if cfg.n_patches:
            specs["patches"] = jax.ShapeDtypeStruct(
                (B, cfg.n_patches, cfg.d_model), bf16)
        if cfg.enc_dec is not None:
            specs["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_dec.encoder_len, cfg.d_model), bf16)
        return specs

    # decode: one new token against a seq_len-deep cache
    return {"token": jax.ShapeDtypeStruct((B, 1), i32)}
