"""Shared model layers: norms, RoPE, attention family, MLPs.

All functions are pure (params-in, activations-out) and shape-polymorphic
over batch/sequence.  Attention is computed with a *chunked online-softmax*
(`flash-style`) ``lax.scan`` over KV blocks so prefill_32k never
materializes an S×S score matrix — the same math as the
``repro.kernels.flash_attention`` Pallas kernel, which replaces it on real
TPU backends (``impl="flash_pallas"``).
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import AttentionConfig
from .. import pspec

__all__ = [
    "rmsnorm", "layernorm", "nonparametric_ln", "norm",
    "rope_frequencies", "apply_rope",
    "chunked_attention", "dense_attention",
    "attention_block", "mla_block", "mlp_block",
    "init_attention", "init_mla", "init_mlp",
]

NEG = -1e18


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


# Norms compute their *statistics* in float32 (reductions with f32
# accumulation) but never materialize a float32 copy of x: a per-layer
# ``convert(x)`` gets rewritten by XLA into a single convert of the whole
# scan-saved carry stack (an (L, B, S, d) f32 buffer — observed 16.5 GiB/dev
# on mistral-large), and on real hardware costs a full extra read/write of
# the residual stream.  Applying the normalizer in bf16 keeps the math
# within bf16 rounding of the f32-everything reference.


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    ss = jnp.einsum("...d,...d->...", x, x,
                    preferred_element_type=jnp.float32) / x.shape[-1]
    inv = jax.lax.rsqrt(ss + eps)[..., None].astype(x.dtype)
    return x * inv * scale.astype(x.dtype)


def layernorm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
              eps: float = 1e-5) -> jnp.ndarray:
    mu = jnp.mean(x, axis=-1, keepdims=True, dtype=jnp.float32)
    xc = x - mu.astype(x.dtype)
    var = jnp.einsum("...d,...d->...", xc, xc,
                     preferred_element_type=jnp.float32) / x.shape[-1]
    inv = jax.lax.rsqrt(var + eps)[..., None].astype(x.dtype)
    return xc * inv * scale.astype(x.dtype) + bias.astype(x.dtype)


def nonparametric_ln(x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """OLMo's non-parametric LayerNorm: no scale, no bias (arXiv:2402.00838)."""
    mu = jnp.mean(x, axis=-1, keepdims=True, dtype=jnp.float32)
    xc = x - mu.astype(x.dtype)
    var = jnp.einsum("...d,...d->...", xc, xc,
                     preferred_element_type=jnp.float32) / x.shape[-1]
    inv = jax.lax.rsqrt(var + eps)[..., None].astype(x.dtype)
    return xc * inv


def norm(kind: str, x: jnp.ndarray, params: Optional[Dict] = None) -> jnp.ndarray:
    if kind == "rmsnorm":
        return rmsnorm(x, params["scale"])
    if kind == "layernorm":
        return layernorm(x, params["scale"], params["bias"])
    if kind == "nonparametric_ln":
        return nonparametric_ln(x)
    raise ValueError(kind)


def init_norm(kind: str, d: int, dtype) -> Optional[Dict]:
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    return {}  # non-parametric


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]                         # (..., S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention cores
# ---------------------------------------------------------------------------


def _expand_kv(k: jnp.ndarray, h: int) -> jnp.ndarray:
    """(B, T, KV, D) -> (B, T, H, D) by group expansion.

    Sharding note: score tensors keep an explicit full-head axis so TP
    shards them cleanly even when KV < mesh model size (KV=8 on a 16-way
    TP axis would otherwise force replication of every (KV, G, S, T)
    intermediate)."""
    kv = k.shape[2]
    if kv == h:
        return k
    return jnp.repeat(k, h // kv, axis=2)


def dense_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool, window: int = 0,
                    q_offset: int = 0) -> jnp.ndarray:
    """Reference attention.  q: (B, S, H, Dq), k/v: (B, T, KV, Dq/Dv);
    ``q_offset`` is the absolute position of q[0] (decode: T - 1)."""
    b, s, h, dq = q.shape
    t = k.shape[1]
    kf = _expand_kv(k, h).astype(jnp.float32)
    vf = _expand_kv(v, h).astype(jnp.float32)
    scores = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32), kf) / np.sqrt(dq)
    qpos = q_offset + jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), dtype=bool)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= kpos > qpos - window
    scores = jnp.where(mask, scores, NEG)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", p, vf)
    return out.astype(q.dtype)


def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      causal: bool, window: int = 0, chunk: int = 1024,
                      q_offset: int = 0) -> jnp.ndarray:
    """Online-softmax attention scanning KV in chunks (flash-style, pure
    jnp).  Never materializes (S, T); peak score memory is (B,KV,G,S,chunk).
    """
    b, s, h, dq = q.shape
    t = k.shape[1]
    if t <= chunk:
        return dense_attention(q, k, v, causal=causal, window=window,
                               q_offset=q_offset)
    assert t % chunk == 0, (t, chunk)
    dv = v.shape[-1]
    # pin head sharding (padded when H doesn't divide TP, e.g. MLA's 40
    # heads): without this, indivisible head counts replicate every score
    # and KV-chunk tensor across the whole TP axis (§Perf iteration 2)
    kf = pspec.shard(_expand_kv(k, h), "batch", None, "tp_pad", None)
    vf = pspec.shard(_expand_kv(v, h), "batch", None, "tp_pad", None)
    qf = pspec.shard(q / np.sqrt(dq).astype(q.dtype),
                     "batch", None, "tp_pad", None)
    kc = kf.reshape(b, t // chunk, chunk, h, dq)
    vc = vf.reshape(b, t // chunk, chunk, h, dv)
    qpos = q_offset + jnp.arange(s)

    @jax.checkpoint
    def step(carry, inp):
        # rematerialized in backward: the (.., S, chunk) score block is
        # recomputed per chunk, never stored — flash-attention's memory
        # discipline, expressed at the JAX level
        m, l, acc = carry
        ci, kb, vb = inp                       # kb: (B, C, H, Dq)
        scores = jnp.einsum("bshd,bchd->bhsc", qf, kb,
                            preferred_element_type=jnp.float32)
        kpos = ci * chunk + jnp.arange(chunk)
        mask = jnp.ones((s, chunk), dtype=bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window > 0:
            mask &= kpos[None, :] > qpos[:, None] - window
        scores = jnp.where(mask, scores, NEG)
        m_new = jnp.maximum(m, scores.max(axis=-1))
        p = jnp.exp(scores - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhsc,bchd->bhsd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), ()

    m0 = jnp.full((b, h, s), NEG, jnp.float32)
    l0 = jnp.zeros((b, h, s), jnp.float32)
    a0 = jnp.zeros((b, h, s, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (jnp.arange(t // chunk), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)


def _attend(q, k, v, *, causal, window, impl, chunk, q_offset=0):
    if impl == "dense":
        return dense_attention(q, k, v, causal=causal, window=window,
                               q_offset=q_offset)
    if impl in ("flash_pallas", "flash_pallas_interpret"):
        # the Pallas kernel path (TPU production; interpret=True on CPU).
        # Layout: (B, S, H, D) -> (B*H, S, D); kv expanded to full heads.
        from ..kernels import ops as kops

        b, s, h, dq = q.shape
        kf = _expand_kv(k, h)
        vf = _expand_kv(v, h)
        t = kf.shape[1]
        qh = jnp.moveaxis(q, 2, 1).reshape(b * h, s, dq)
        kh = jnp.moveaxis(kf, 2, 1).reshape(b * h, t, dq)
        vh = jnp.moveaxis(vf, 2, 1).reshape(b * h, t, vf.shape[-1])
        out = kops.flash_attention(
            qh, kh, vh, causal=causal, window=window,
            interpret=(impl == "flash_pallas_interpret"))
        return jnp.moveaxis(out.reshape(b, h, s, -1), 1, 2)
    return chunked_attention(q, k, v, causal=causal, window=window,
                             chunk=chunk, q_offset=q_offset)


# ---------------------------------------------------------------------------
# GQA / SWA attention block
# ---------------------------------------------------------------------------


def init_attention(key, cfg: AttentionConfig, d_model: int, dtype) -> Dict:
    a = cfg
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d_model ** -0.5
    return {
        "wq": jax.random.normal(k1, (d_model, a.n_heads * a.head_dim), dtype) * s,
        "wk": jax.random.normal(k2, (d_model, a.n_kv_heads * a.head_dim), dtype) * s,
        "wv": jax.random.normal(k3, (d_model, a.n_kv_heads * a.head_dim), dtype) * s,
        "wo": jax.random.normal(k4, (a.n_heads * a.head_dim, d_model), dtype) * s,
    }


def attention_block(params: Dict, x: jnp.ndarray, cfg: AttentionConfig, *,
                    positions: jnp.ndarray, causal: bool = True,
                    cache: Optional[Dict] = None,
                    impl: str = "chunked", chunk: int = 1024,
                    ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """GQA (optionally sliding-window) attention.

    ``cache``: {"k": (B, T, KV, D), "v": ..., "pos": int32 scalar} for
    decode; x is then (B, 1, d).  Returns (out, new_cache).
    """
    a = cfg
    b, s, _ = x.shape
    q = (x @ params["wq"]).reshape(b, s, a.n_heads, a.head_dim)
    k = (x @ params["wk"]).reshape(b, s, a.n_kv_heads, a.head_dim)
    v = (x @ params["wv"]).reshape(b, s, a.n_kv_heads, a.head_dim)
    q = apply_rope(q, positions, a.rope_theta)
    k = apply_rope(k, positions, a.rope_theta)

    new_cache = None
    if cache is not None:
        t = cache["k"].shape[1]
        pos = cache["pos"]
        kd, vd = cache["k"].dtype, cache["v"].dtype
        ring = a.window > 0 and t < 1 << 30  # SWA caches are ring buffers
        if s == 1:
            idx = jnp.mod(pos, t) if ring else pos
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(kd), idx, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(vd), idx, axis=1)
            kpos = jax.lax.dynamic_update_slice_in_dim(
                cache["kpos"], pos[None], idx, axis=0)
        elif s >= t:
            # prefill longer than the ring: keep the last t positions at
            # their ring slots (slot of position p is p % t)
            shift = s % t
            ck = jnp.roll(k[:, -t:].astype(kd), shift, axis=1)
            cv = jnp.roll(v[:, -t:].astype(vd), shift, axis=1)
            kpos = jnp.roll(jnp.arange(s - t, s, dtype=jnp.int32), shift)
        else:
            ck = cache["k"].at[:, :s].set(k.astype(kd))
            cv = cache["v"].at[:, :s].set(v.astype(vd))
            kpos = cache["kpos"].at[:s].set(jnp.arange(s, dtype=jnp.int32))
        new_cache = {"k": ck, "v": cv, "kpos": kpos, "pos": pos + s}
        if s == 1:
            out = _decode_attention(q, ck, cv, kpos, pos, window=a.window)
        else:  # prefill: attention over the fresh keys directly
            out = _attend(q, k, v, causal=causal, window=a.window, impl=impl,
                          chunk=chunk)
    else:
        out = _attend(q, k, v, causal=causal, window=a.window, impl=impl,
                      chunk=chunk)
    out = out.reshape(b, s, a.n_heads * a.head_dim) @ params["wo"]
    return out, new_cache


def _decode_attention(q, ck, cv, kpos, cur_pos, window: int = 0):
    """Single-step decode over a (B, T, KV, D) cache whose slot j holds
    absolute position kpos[j] (-1 = never written).  Masks invalid and
    out-of-window slots.  KV heads stay compressed (the cache is the
    memory-bound operand in decode); scores carry the KV axis and the
    group expansion happens on the tiny q side."""
    b, s, h, d = q.shape
    t, kv = ck.shape[1], ck.shape[2]
    g = h // kv
    qg = (q / np.sqrt(d).astype(q.dtype)).reshape(b, s, kv, g, d)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, ck,
                        preferred_element_type=jnp.float32)
    mask = (kpos >= 0) & (kpos <= cur_pos)
    if window > 0:
        mask &= kpos > cur_pos - window
    scores = jnp.where(mask[None, None, None, None, :], scores, NEG)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p.astype(cv.dtype), cv,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, s, h, cv.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, MiniCPM3 / DeepSeek-V2)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: AttentionConfig, d_model: int, dtype) -> Dict:
    a = cfg
    ks = jax.random.split(key, 7)
    s = d_model ** -0.5
    qk = a.qk_nope_head_dim + a.qk_rope_head_dim
    return {
        "wdq": jax.random.normal(ks[0], (d_model, a.q_lora_rank), dtype) * s,
        "q_norm": {"scale": jnp.ones((a.q_lora_rank,), dtype)},
        "wuq": jax.random.normal(ks[1], (a.q_lora_rank, a.n_heads * qk), dtype) * s,
        "wdkv": jax.random.normal(ks[2], (d_model, a.kv_lora_rank), dtype) * s,
        "kv_norm": {"scale": jnp.ones((a.kv_lora_rank,), dtype)},
        "wkr": jax.random.normal(ks[3], (d_model, a.qk_rope_head_dim), dtype) * s,
        "wuk": jax.random.normal(
            ks[4], (a.n_heads, a.kv_lora_rank, a.qk_nope_head_dim), dtype) * s,
        "wuv": jax.random.normal(
            ks[5], (a.n_heads, a.kv_lora_rank, a.v_head_dim), dtype) * s,
        "wo": jax.random.normal(
            ks[6], (a.n_heads * a.v_head_dim, d_model), dtype) * s,
    }


def mla_block(params: Dict, x: jnp.ndarray, cfg: AttentionConfig, *,
              positions: jnp.ndarray, causal: bool = True,
              cache: Optional[Dict] = None, impl: str = "chunked",
              chunk: int = 1024) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Multi-head latent attention.

    Prefill/train: expand the compressed KV into per-head k/v and run the
    chunked attention.  Decode: the **absorbed** form — the cache stores only
    (c_kv, k_rope); W_uk folds into the query and W_uv into the output, so a
    step costs O(T · (kv_lora + rope)) per head instead of re-expanding KV
    (this is MLA's stated decode advantage; cache bytes per token =
    kv_lora_rank + qk_rope_head_dim, independent of head count).
    """
    a = cfg
    b, s, _ = x.shape
    nh = a.n_heads
    dn, dr, dv = a.qk_nope_head_dim, a.qk_rope_head_dim, a.v_head_dim

    cq = rmsnorm(x @ params["wdq"], params["q_norm"]["scale"])
    q = (cq @ params["wuq"]).reshape(b, s, nh, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, a.rope_theta)

    c_kv = rmsnorm(x @ params["wdkv"], params["kv_norm"]["scale"])   # (B,S,R)
    k_rope = apply_rope((x @ params["wkr"])[:, :, None, :], positions,
                        a.rope_theta)                                 # (B,S,1,dr)

    new_cache = None
    if cache is not None:
        pos = cache["pos"]
        cc = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), pos, axis=1) \
            if s == 1 else cache["c_kv"].at[:, :s].set(c_kv.astype(cache["c_kv"].dtype))
        cr = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope[:, :, 0].astype(cache["k_rope"].dtype), pos, axis=1) \
            if s == 1 else cache["k_rope"].at[:, :s].set(k_rope[:, :, 0].astype(cache["k_rope"].dtype))
        new_cache = {"c_kv": cc, "k_rope": cr, "pos": pos + s}
    if cache is not None and s == 1:
        # absorbed single-step decode over the compressed cache
        q_abs = jnp.einsum("bshd,hrd->bshr", q_nope.astype(jnp.float32),
                           params["wuk"].astype(jnp.float32))         # (B,S,H,R)
        scale = 1.0 / np.sqrt(dn + dr)
        s_lat = jnp.einsum("bshr,btr->bhst", q_abs, cc.astype(jnp.float32))
        s_rope = jnp.einsum("bshd,btd->bhst", q_rope.astype(jnp.float32),
                            cr.astype(jnp.float32))
        scores = (s_lat + s_rope) * scale
        t = cc.shape[1]
        valid = jnp.arange(t)[None, :] < (pos + s)
        scores = jnp.where(valid[None, None], scores, NEG)
        p = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhst,btr->bshr", p, cc.astype(jnp.float32))  # (B,S,H,R)
        out = jnp.einsum("bshr,hrd->bshd", ctx,
                         params["wuv"].astype(jnp.float32)).astype(x.dtype)
    else:
        # train / prefill: expand compressed KV, causal chunked attention
        k_nope = jnp.einsum("bsr,hrd->bshd", c_kv, params["wuk"])
        v = jnp.einsum("bsr,hrd->bshd", c_kv, params["wuv"])
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, nh, dr))],
                            axis=-1)
        qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = _attend(qfull, k, v, causal=causal, window=0, impl=impl,
                      chunk=chunk)
    out = out.reshape(b, s, nh * dv) @ params["wo"]
    return out, new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, dtype, gated: bool = True) -> Dict:
    ks = jax.random.split(key, 3)
    s = d_model ** -0.5
    p = {
        "w_up": jax.random.normal(ks[0], (d_model, d_ff), dtype) * s,
        "w_down": jax.random.normal(ks[1], (d_ff, d_model), dtype) * (d_ff ** -0.5),
    }
    if gated:
        p["w_gate"] = jax.random.normal(ks[2], (d_model, d_ff), dtype) * s
    return p


def mlp_block(params: Dict, x: jnp.ndarray, activation: str = "silu") -> jnp.ndarray:
    act = jax.nn.silu if activation == "silu" else jax.nn.gelu
    up = x @ params["w_up"]
    if "w_gate" in params:
        up = act(x @ params["w_gate"]) * up
    else:
        up = act(up)
    return up @ params["w_down"]
