"""Mamba-1 selective-SSM block (falcon-mamba, jamba mamba layers).

Selective scan  h_t = exp(Δ_t A) h_{t-1} + Δ_t B_t x_t,  y_t = C_t h_t + D x_t
with diagonal A (d_inner, d_state).

Memory discipline: the per-token hidden state is d_inner × d_state floats —
materializing it for every position is impossible at 4k×B sequences.  The
CUDA kernel the paper's ecosystem uses never stores it; the TPU-idiomatic
equivalent here is a **two-level chunked scan**: an outer ``lax.scan`` over
sequence chunks carries (h, conv tail), the inner chunk is computed with a
time-step ``lax.scan`` whose body is rematerialized (``jax.checkpoint``), so
backward memory is O(S/chunk · state + chunk inputs), not O(S · state).

Decode: single-step recurrence over cached (conv tail, h) — O(1) per token,
which is why the ssm/hybrid architectures run the long_500k cell.
"""

from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import SSMConfig
from .. import pspec

__all__ = ["init_mamba", "mamba_block", "init_mamba_cache"]


def init_mamba(key, cfg: SSMConfig, d_model: int, dtype) -> Dict:
    di = cfg.d_inner(d_model)
    dtr = cfg.dt_rank_of(d_model)
    ks = jax.random.split(key, 6)
    s = d_model ** -0.5
    return {
        "in_proj": jax.random.normal(ks[0], (d_model, 2 * di), dtype) * s,
        "conv_w": jax.random.normal(ks[1], (cfg.d_conv, di), dtype) * 0.5,
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": jax.random.normal(ks[2], (di, dtr + 2 * cfg.d_state), dtype) * (di ** -0.5),
        "dt_proj": jax.random.normal(ks[3], (dtr, di), dtype) * (dtr ** -0.5),
        "dt_bias": jnp.full((di,), -4.6, dtype),   # softplus^-1(0.01)
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, cfg.d_state + 1, dtype=jnp.float32), (di, cfg.d_state)).copy()),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": jax.random.normal(ks[5], (di, d_model), dtype) * (di ** -0.5),
    }


def init_mamba_cache(cfg: SSMConfig, d_model: int, batch: int, dtype) -> Dict:
    di = cfg.d_inner(d_model)
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, di), dtype),
        "h": jnp.zeros((batch, di, cfg.d_state), jnp.float32),
    }


def _ssm_params(params: Dict, cfg: SSMConfig, xb: jnp.ndarray):
    """xb: (..., di) post-conv activations -> (dt, B, C) selective params."""
    dtr = cfg.dt_rank_of(params["in_proj"].shape[0])
    proj = xb @ params["x_proj"]
    dt, Bmat, Cmat = jnp.split(proj, [dtr, dtr + cfg.d_state], axis=-1)
    dt = jax.nn.softplus(dt @ params["dt_proj"] +
                         params["dt_bias"].astype(jnp.float32))  # (..., di)
    return dt, Bmat.astype(jnp.float32), Cmat.astype(jnp.float32)


def _scan_chunk(params: Dict, cfg: SSMConfig, h0: jnp.ndarray,
                xb: jnp.ndarray, z: jnp.ndarray,
                mask: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential selective scan over one chunk.

    xb/z: (B, C, di); h0: (B, di, N); mask: (C,) validity (padded positions
    leave the state untouched) -> (y (B,C,di), hC)."""
    A = -jnp.exp(params["A_log"])                     # (di, N)
    dt, Bm, Cm = _ssm_params(params, cfg, xb.astype(jnp.float32))
    if mask is not None:
        dt = dt * mask[None, :, None]                 # dt=0 -> identity step
    # pin shardings so every time step of the scan is collective-free:
    # state and dt are d_inner-sharded over TP, B/C replicated per shard
    # (without this, jamba's multi-pod scan emitted one small all-reduce
    # PER TIME STEP — 1.86M all-reduces per train step)
    h0 = pspec.shard(h0, "batch", "tp", None)
    dt = pspec.shard(dt, "batch", None, "tp")
    Bm = pspec.shard(Bm, "batch", None, None)
    Cm = pspec.shard(Cm, "batch", None, None)
    xb = pspec.shard(xb, "batch", None, "tp")

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp                     # (B,di),(B,di),(B,N),(B,N)
        dA = jnp.exp(dt_t[..., None] * A[None])       # (B, di, N)
        dBx = (dt_t * x_t)[..., None] * b_t[:, None, :]
        # pin per-step layouts: h is d_inner-sharded, N replicated — XLA
        # otherwise shards the tiny d_state axis and psums h EVERY step
        h = pspec.shard(dA * h + dBx, "batch", "tp", None)
        y = pspec.shard(jnp.einsum("bdn,bn->bd", h, c_t), "batch", "tp")
        return h, y

    xs = (jnp.moveaxis(xb.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bm, 1, 0),
          jnp.moveaxis(Cm, 1, 0))
    h, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)                        # (B, C, di)
    y = y + params["D"].astype(jnp.float32) * xb.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return y, h


def mamba_block(params: Dict, x: jnp.ndarray, cfg: SSMConfig, *,
                cache: Optional[Dict] = None, impl: str = "chunked_scan",
                ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """x: (B, S, d).  Train/prefill: chunked scan.  Decode (S == 1): O(1)
    cached recurrence."""
    b, s, d = x.shape
    di = cfg.d_inner(d)
    xz = pspec.shard(x @ params["in_proj"], "batch", None, "tp")
    xr, z = jnp.split(xz, 2, axis=-1)                 # (B, S, di) each

    if cache is not None and s == 1:
        # --- decode step ---
        conv_tail = cache["conv"]                     # (B, d_conv-1, di)
        win = jnp.concatenate([conv_tail, xr.astype(conv_tail.dtype)], axis=1)
        xb = jnp.einsum("bcd,cd->bd", win.astype(jnp.float32),
                        params["conv_w"].astype(jnp.float32)) + \
            params["conv_b"].astype(jnp.float32)
        xb = jax.nn.silu(xb)
        A = -jnp.exp(params["A_log"])
        dt, Bm, Cm = _ssm_params(params, cfg, xb)
        dA = jnp.exp(dt[..., None] * A[None])
        h = dA * cache["h"] + (dt * xb)[..., None] * Bm[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, Cm)
        y = y + params["D"].astype(jnp.float32) * xb
        y = y * jax.nn.silu(z[:, 0].astype(jnp.float32))
        out = (y.astype(x.dtype) @ params["out_proj"])[:, None]
        new_cache = {"conv": win[:, 1:].astype(conv_tail.dtype), "h": h}
        return out, new_cache

    # --- train / prefill: depthwise causal conv then chunked scan ---
    pad = jnp.zeros((b, cfg.d_conv - 1, di), xr.dtype)
    xpad = jnp.concatenate([pad, xr], axis=1)         # (B, S+dc-1, di)
    xb = sum(xpad[:, i:i + s] * params["conv_w"][i] for i in range(cfg.d_conv))
    xb = jax.nn.silu(xb + params["conv_b"])

    if impl in ("pallas", "pallas_interpret") and cache is None:
        # Pallas selective-scan kernel path (TPU production; interpret on CPU)
        from ..kernels import ops as kops

        A = params["A_log"]
        dt, Bm, Cm = _ssm_params(params, cfg, xb.astype(jnp.float32))
        y = kops.selective_scan(
            xb.astype(jnp.float32), dt, Bm, Cm, -jnp.exp(A),
            params["D"].astype(jnp.float32),
            interpret=(impl == "pallas_interpret"))
        y = y * jax.nn.silu(z.astype(jnp.float32))
        return y.astype(x.dtype) @ params["out_proj"], None

    chunk = min(cfg.chunk, s)
    s_pad = -(-s // chunk) * chunk                    # ragged: pad tail zeros
    if s_pad != s:
        zpad = jnp.zeros((b, s_pad - s, di))
        xb = jnp.concatenate([xb, zpad.astype(xb.dtype)], axis=1)
        z = jnp.concatenate([z, zpad.astype(z.dtype)], axis=1)
    nc = s_pad // chunk
    xb_c = xb.reshape(b, nc, chunk, di)
    z_c = z.reshape(b, nc, chunk, di)
    valid = (jnp.arange(s_pad) < s).astype(jnp.float32).reshape(nc, chunk)

    inner = jax.checkpoint(partial(_scan_chunk, params, cfg))

    def outer(h, inp):
        xc, zc, mk = inp
        y, h = inner(h, xc, zc, mk)
        return h, y

    h0 = (cache["h"] if cache is not None
          else jnp.zeros((b, di, cfg.d_state), jnp.float32))
    h_final, ys = jax.lax.scan(outer, h0,
                               (jnp.moveaxis(xb_c, 1, 0),
                                jnp.moveaxis(z_c, 1, 0), valid))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s_pad, di)[:, :s]
    out = y.astype(x.dtype) @ params["out_proj"]
    new_cache = None
    if cache is not None:  # prefill: final SSM state + conv tail
        tail = xpad[:, s: s + cfg.d_conv - 1]  # last d_conv-1 real inputs
        new_cache = {"conv": tail.astype(cache["conv"].dtype), "h": h_final}
    return out, new_cache
