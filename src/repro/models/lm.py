"""Decoder-only LM generic over the 10-arch config schema.

Layers are grouped by the architecture's *pattern period* P
(lcm of the hybrid attention period and the MoE period; P=1 for
homogeneous stacks) and scanned over ``n_layers / P`` repeats with the P
positions unrolled inside the scan body — so HLO stays compact (one body
per distinct layer structure) for every architecture including jamba's
1-attention-per-8 interleave.

Entry points:
  init_params(cfg, key)        real parameters (smoke tests, examples)
  abstract_params(cfg)         ShapeDtypeStructs (dry-run, no allocation)
  forward / forward_with_aux   logits (train / prefill-style full pass)
  init_cache / prefill / decode_step   serving path with KV/SSM caches
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .. import pspec
from . import layers as L
from .layers import init_norm, norm
from .mamba import init_mamba, init_mamba_cache, mamba_block
from .moe import init_moe, moe_block

__all__ = ["pattern_period", "init_params", "abstract_params", "forward",
           "forward_with_aux", "init_cache", "prefill", "decode_step"]

# parameters kept in float32 regardless of compute dtype (numerics-critical)
_F32_LEAVES = ("A_log", "D", "dt_bias", "router")


@jax.custom_vjp
def _grad_to_compute_dtype(x):
    """Identity whose backward casts the cotangent to the primal dtype.

    f32-accumulating einsums (norm statistics, attention scores) make their
    VJPs produce float32 cotangents; without a cast at the layer boundary
    the entire backward residual chain — and every backward dot and its
    FSDP gathers — runs in f32, doubling collective and HBM traffic
    (§Perf iteration 4).  Megatron keeps inter-layer grads in bf16 for the
    same reason; dW still accumulates in f32 inside the optimizer.
    """
    return x


def _gtc_fwd(x):
    return x, jnp.zeros((0,), x.dtype)  # residual carries only the dtype


def _gtc_bwd(res, g):
    return (g.astype(res.dtype),)


_grad_to_compute_dtype.defvjp(_gtc_fwd, _gtc_bwd)


@jax.custom_vjp
def _barrier(x):
    """``optimization_barrier`` with a defined VJP (identity-with-barrier on
    both passes).  The primitive itself has no differentiation rule, so the
    bare ``jax.lax.optimization_barrier`` call aborts any ``grad`` through
    the layer scan; semantically the barrier IS the identity, and the
    backward barrier keeps XLA from hoisting the cotangent upcast out of
    the backward scan for the same reason as the forward one."""
    return jax.lax.optimization_barrier(x)


def _barrier_fwd(x):
    return _barrier(x), None


def _barrier_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


_barrier.defvjp(_barrier_fwd, _barrier_bwd)


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


def pattern_period(cfg: ModelConfig) -> int:
    p = cfg.attn_period
    if cfg.moe is not None:
        p = math.lcm(p, cfg.moe.every)
    assert cfg.n_layers % p == 0, (cfg.n_layers, p)
    return p


def cast_tree(tree, dtype):
    """Cast weight leaves to the compute dtype, keeping numerics-critical
    leaves (SSM decay, router) in float32."""

    def cast(path, a):
        name = str(path[-1]) if path else ""
        if any(k in name for k in _F32_LEAVES):
            return a
        if a.dtype in (jnp.float32, jnp.bfloat16):
            return a.astype(dtype)
        return a

    return jax.tree_util.tree_map_with_path(
        lambda p, a: cast([getattr(k, "key", getattr(k, "idx", "")) for k in p], a),
        tree)


# ---------------------------------------------------------------------------
# parameter construction
# ---------------------------------------------------------------------------


def _init_layer(key, cfg: ModelConfig, kind: str, is_moe: bool, dtype) -> Dict:
    k1, k2 = jax.random.split(key)
    p: Dict[str, Any] = {"ln1": init_norm(cfg.norm, cfg.d_model, dtype),
                         "ln2": init_norm(cfg.norm, cfg.d_model, dtype)}
    if kind == "attn":
        if cfg.attention.kind == "mla":
            p["mix"] = L.init_mla(k1, cfg.attention, cfg.d_model, dtype)
        else:
            p["mix"] = L.init_attention(k1, cfg.attention, cfg.d_model, dtype)
    else:
        p["mix"] = init_mamba(k1, cfg.ssm, cfg.d_model, dtype)
    if is_moe:
        p["ffn"] = init_moe(k2, cfg.moe, cfg.d_model, dtype)
    elif cfg.d_ff > 0:
        gated = cfg.activation == "silu"
        p["ffn"] = L.init_mlp(k2, cfg.d_model, cfg.d_ff, dtype, gated=gated)
    else:
        del p["ln2"]  # pure-mamba layer (falcon-mamba): mixer only
    return p


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict:
    dtype = _dtype(cfg.param_dtype)
    P = pattern_period(cfg)
    R = cfg.n_layers // P
    kinds = cfg.layer_kinds()
    moes = cfg.moe_layers()
    k_emb, k_unemb, k_blocks, k_extra = jax.random.split(key, 4)

    blocks = []
    for pos in range(P):
        keys = jax.random.split(jax.random.fold_in(k_blocks, pos), R)
        stacked = [_init_layer(keys[r], cfg, kinds[pos], moes[pos], dtype)
                   for r in range(R)]
        blocks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *stacked))

    params: Dict[str, Any] = {
        "embed": jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model), dtype)
        * (cfg.d_model ** -0.5),
        "blocks": tuple(blocks),
        "final_norm": init_norm(cfg.norm, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = jax.random.normal(
            k_unemb, (cfg.d_model, cfg.vocab_size), dtype) * (cfg.d_model ** -0.5)
    if cfg.n_patches > 0:  # VLM stub: projection of precomputed patch embeds
        params["patch_proj"] = jax.random.normal(
            k_extra, (cfg.d_model, cfg.d_model), dtype) * (cfg.d_model ** -0.5)
    return params


def abstract_params(cfg: ModelConfig) -> Dict:
    """ShapeDtypeStruct pytree — the dry-run path, no allocation."""
    return jax.eval_shape(partial(init_params, cfg), jax.random.key(0))


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _layer_apply(cfg: ModelConfig, kind: str, is_moe: bool, lp: Dict,
                 x: jnp.ndarray, positions: jnp.ndarray,
                 cache: Optional[Dict], impl: str, chunk: int,
                 ) -> Tuple[jnp.ndarray, Optional[Dict], jnp.ndarray]:
    aux = jnp.zeros((), jnp.float32)
    # barrier: stops XLA hoisting the per-layer bf16->f32 norm upcast out of
    # the scan loop (which would materialize an f32 copy of the entire
    # (L, B, S, d) carry stack — observed on XLA:CPU)
    x = _barrier(x)
    # Megatron-SP discipline (training): the residual is sequence-sharded
    # between layers; gather the *activations* (tokens x d, small at
    # microbatched train shapes) at layer entry so the TP matmuls never
    # force XLA to all-gather full weight matrices (d x d_ff) instead.
    # Serving keeps h sequence-sharded: at 32k prefill the activation is
    # the big operand, and weights gather once per layer anyway
    # (§Perf iterations 3 and p1).
    h_spec = (None if cache is None else "sp")
    h = pspec.shard(norm(cfg.norm, x, lp["ln1"]), "batch", h_spec, None)
    if kind == "attn":
        fn = L.mla_block if cfg.attention.kind == "mla" else L.attention_block
        mixed, new_cache = fn(lp["mix"], h, cfg.attention, positions=positions,
                              causal=True, cache=cache, impl=impl, chunk=chunk)
    else:
        mixed, new_cache = mamba_block(lp["mix"], h, cfg.ssm, cache=cache,
                                       impl=cfg.ssm_impl)
    x = _grad_to_compute_dtype(pspec.shard(x + mixed, "batch", "sp", None))
    if "ffn" not in lp:          # pure-mamba layer (falcon-mamba)
        return x, new_cache, aux
    h = pspec.shard(norm(cfg.norm, x, lp["ln2"]), "batch", h_spec, None)
    if is_moe:
        ff, aux = moe_block(lp["ffn"], h, cfg.moe, activation=cfg.activation)
    else:
        ff = L.mlp_block(lp["ffn"], h, cfg.activation)
    return (_grad_to_compute_dtype(pspec.shard(x + ff, "batch", "sp", None)),
            new_cache, aux)


def _embed(params: Dict, cfg: ModelConfig, tokens: jnp.ndarray,
           patches: Optional[jnp.ndarray], dtype) -> jnp.ndarray:
    x = params["embed"][tokens].astype(dtype)
    if cfg.n_patches > 0 and patches is not None:
        px = (patches.astype(dtype) @ params["patch_proj"].astype(dtype))
        x = jnp.concatenate([px, x], axis=1)
    # pin the residual-stream layout: the vocab-sharded gather would
    # otherwise leave x replicated (see repro.pspec docstring)
    return pspec.shard(x, "batch", "sp", None)


def _unembed(params: Dict, x: jnp.ndarray, dtype) -> jnp.ndarray:
    unemb = params.get("unembed")
    if unemb is None:
        logits = x @ params["embed"].T.astype(dtype)
    else:
        logits = x @ unemb.astype(dtype)
    return pspec.shard(logits, "batch", None, "tp")


def forward_with_aux(params: Dict, cfg: ModelConfig, tokens: jnp.ndarray,
                     patches: Optional[jnp.ndarray] = None,
                     impl: Optional[str] = None, chunk: int = 1024,
                     remat: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens: (B, S_text); VLM: patches (B, n_patches, d) prepended.
    Returns (logits (B, S_total, V), moe aux loss)."""
    impl = impl or cfg.attention_impl
    dtype = _dtype(cfg.compute_dtype)
    x = _embed(params, cfg, tokens, patches, dtype)
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    P = pattern_period(cfg)
    kinds = cfg.layer_kinds()[:P]
    moes = cfg.moe_layers()[:P]

    G = max(1, cfg.remat_group)
    R = cfg.n_layers // P
    assert R % G == 0, (R, G)

    def body(carry, rep_params):
        x, aux = carry
        for g in range(G):
            gp = jax.tree.map(lambda a: a[g], rep_params) if G > 1 else rep_params
            for pos in range(P):
                x, _, a = _layer_apply(cfg, kinds[pos], moes[pos],
                                       gp[pos], x, positions, None,
                                       impl, chunk)
                aux = aux + a
        return (x, aux), ()

    if remat:
        # hierarchical rematerialization: the scan saves the residual every
        # remat_group repeats; backward recomputes the whole group from it.
        # nothing_saveable (vs dots_saveable) keeps chunked-attention score
        # blocks out of memory — the flash-style memory plan.
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    # cast the stacked params ONCE, outside the scan: FSDP all-gathers then
    # move bf16, not f32 master weights (2x less gather traffic and no
    # full-f32 weight materialization inside the layer body)
    blocks_c = cast_tree(params["blocks"], dtype)
    if G > 1:  # group the leading repeat dim: (R, ...) -> (R/G, G, ...)
        blocks_c = jax.tree.map(
            lambda a: a.reshape((R // G, G) + a.shape[1:]), blocks_c)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               blocks_c)
    x = norm(cfg.norm, x, params["final_norm"])
    return _unembed(params, x, dtype), aux


def forward(params, cfg, tokens, patches=None, impl=None,
            chunk: int = 1024, remat: bool = True) -> jnp.ndarray:
    return forward_with_aux(params, cfg, tokens, patches, impl, chunk,
                            remat)[0]


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode
# ---------------------------------------------------------------------------


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _layer_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int, dtype):
    a = cfg.attention
    if kind == "attn":
        if a.kind == "mla":
            return {"c_kv": jnp.zeros((batch, max_len, a.kv_lora_rank), dtype),
                    "k_rope": jnp.zeros((batch, max_len, a.qk_rope_head_dim), dtype),
                    "pos": jnp.zeros((), jnp.int32)}
        t = max_len if a.window == 0 else min(max_len, _round_up(a.window, 128))
        return {"k": jnp.zeros((batch, t, a.n_kv_heads, a.head_dim), dtype),
                "v": jnp.zeros((batch, t, a.n_kv_heads, a.head_dim), dtype),
                "kpos": jnp.full((t,), -1, jnp.int32),
                "pos": jnp.zeros((), jnp.int32)}
    return init_mamba_cache(cfg.ssm, cfg.d_model, batch, dtype)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Tuple:
    """Stacked caches mirroring the block structure: tuple over pattern
    positions, each a pytree with leading repeat dim R."""
    dtype = _dtype(cfg.compute_dtype)
    P = pattern_period(cfg)
    R = cfg.n_layers // P
    kinds = cfg.layer_kinds()[:P]
    caches = []
    for pos in range(P):
        c = _layer_cache(cfg, kinds[pos], batch, max_len, dtype)
        caches.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (R,) + x.shape).copy(), c))
    return tuple(caches)


def prefill(params: Dict, cfg: ModelConfig, tokens: jnp.ndarray,
            cache: Tuple, patches: Optional[jnp.ndarray] = None,
            impl: str = "chunked", chunk: int = 1024
            ) -> Tuple[jnp.ndarray, Tuple]:
    """Run the prompt through the model, filling caches.  Returns
    (last-position logits (B, 1, V), cache)."""
    dtype = _dtype(cfg.compute_dtype)
    x = _embed(params, cfg, tokens, patches, dtype)
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    P = pattern_period(cfg)
    kinds = cfg.layer_kinds()[:P]
    moes = cfg.moe_layers()[:P]

    def body(x, inp):
        rep_params, rep_cache = inp
        new_caches = []
        for pos in range(P):
            x, nc, _ = _layer_apply(cfg, kinds[pos], moes[pos],
                                    rep_params[pos], x, positions,
                                    rep_cache[pos], impl, chunk)
            new_caches.append(nc if nc is not None else rep_cache[pos])
        return x, tuple(new_caches)

    blocks_c = cast_tree(params["blocks"], dtype)
    x, new_cache = jax.lax.scan(body, x, (blocks_c, cache))
    x = norm(cfg.norm, x[:, -1:], params["final_norm"])
    return _unembed(params, x, dtype), new_cache


def decode_step(params: Dict, cfg: ModelConfig, token: jnp.ndarray,
                cache: Tuple) -> Tuple[jnp.ndarray, Tuple]:
    """One decode step.  token: (B, 1) -> logits (B, 1, V), updated cache."""
    dtype = _dtype(cfg.compute_dtype)
    x = pspec.shard(params["embed"][token].astype(dtype), "batch", None, None)
    P = pattern_period(cfg)
    kinds = cfg.layer_kinds()[:P]
    moes = cfg.moe_layers()[:P]
    pos0 = _find_pos(cache)
    positions = pos0 + jnp.zeros((1, 1), jnp.int32)

    def body(x, inp):
        rep_params, rep_cache = inp
        new_caches = []
        for pos in range(P):
            x, nc, _ = _layer_apply(cfg, kinds[pos], moes[pos],
                                    rep_params[pos], x, positions,
                                    rep_cache[pos], "dense", 1024)
            new_caches.append(nc if nc is not None else rep_cache[pos])
        return x, tuple(new_caches)

    blocks_c = cast_tree(params["blocks"], dtype)
    x, new_cache = jax.lax.scan(body, x, (blocks_c, cache))
    x = norm(cfg.norm, x, params["final_norm"])
    return _unembed(params, x, dtype), new_cache


def _find_pos(cache: Tuple):
    for c in cache:
        if isinstance(c, dict) and "pos" in c:
            return c["pos"][0]
    return jnp.zeros((), jnp.int32)
