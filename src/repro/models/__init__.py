"""Model zoo: the 10 assigned architectures under one config schema."""

from .api import Model, cell_is_runnable, get_model, input_specs
from .config import (AttentionConfig, EncDecConfig, ModelConfig, MoEConfig,
                     SHAPES, ShapeConfig, SSMConfig)

__all__ = [
    "Model", "get_model", "input_specs", "cell_is_runnable",
    "ModelConfig", "AttentionConfig", "MoEConfig", "SSMConfig",
    "EncDecConfig", "SHAPES", "ShapeConfig",
]
