"""Whisper-style encoder-decoder (whisper-small backbone).

The conv frontend is a STUB per the assignment: ``input_specs()`` feeds
precomputed frame embeddings (B, encoder_len, d_model) — the two stride-2
convs of real Whisper produce exactly this (1500 frames for 30 s audio).

Encoder: bidirectional attention over frames, sinusoidal positions.
Decoder: causal self-attention + cross-attention over encoder output,
learned positional embeddings, non-gated GELU MLPs (Whisper's geometry).
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .. import pspec
from . import layers as L
from .layers import init_norm, norm
from .lm import cast_tree, _dtype

__all__ = ["init_params_encdec", "abstract_params_encdec", "encode",
           "forward_encdec", "init_cache_encdec", "prefill_encdec",
           "decode_step_encdec"]


def _sinusoidal(length: int, d: int) -> np.ndarray:
    pos = np.arange(length)[:, None]
    dim = np.arange(0, d, 2)[None, :]
    ang = pos / np.power(10_000.0, dim / d)
    out = np.zeros((length, d), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return out


def _init_xattn(key, cfg, d, dtype):
    a = cfg.attention
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    return {
        "wq": jax.random.normal(ks[0], (d, a.n_heads * a.head_dim), dtype) * s,
        "wk": jax.random.normal(ks[1], (d, a.n_heads * a.head_dim), dtype) * s,
        "wv": jax.random.normal(ks[2], (d, a.n_heads * a.head_dim), dtype) * s,
        "wo": jax.random.normal(ks[3], (a.n_heads * a.head_dim, d), dtype) * s,
    }


def init_params_encdec(cfg: ModelConfig, key: jax.Array) -> Dict:
    dtype = _dtype(cfg.param_dtype)
    d = cfg.d_model
    e = cfg.enc_dec
    keys = jax.random.split(key, 8)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": init_norm("layernorm", d, dtype),
                "attn": L.init_attention(k1, cfg.attention, d, dtype),
                "ln2": init_norm("layernorm", d, dtype),
                "mlp": L.init_mlp(k2, d, cfg.d_ff, dtype, gated=False)}

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"ln1": init_norm("layernorm", d, dtype),
                "self": L.init_attention(k1, cfg.attention, d, dtype),
                "lnx": init_norm("layernorm", d, dtype),
                "cross": _init_xattn(k2, cfg, d, dtype),
                "ln2": init_norm("layernorm", d, dtype),
                "mlp": L.init_mlp(k3, d, cfg.d_ff, dtype, gated=False)}

    enc_ks = jax.random.split(keys[0], e.n_encoder_layers)
    dec_ks = jax.random.split(keys[1], cfg.n_layers)
    return {
        "enc_pos": jnp.asarray(_sinusoidal(e.encoder_len, d), dtype),
        "enc_blocks": jax.tree.map(lambda *xs: jnp.stack(xs),
                                   *[enc_layer(k) for k in enc_ks]),
        "enc_norm": init_norm("layernorm", d, dtype),
        "embed": jax.random.normal(keys[2], (cfg.vocab_size, d), dtype) * d ** -0.5,
        "dec_pos": jax.random.normal(
            keys[3], (min(cfg.max_seq_len, 32768), d), dtype) * 0.02,
        "dec_blocks": jax.tree.map(lambda *xs: jnp.stack(xs),
                                   *[dec_layer(k) for k in dec_ks]),
        "final_norm": init_norm("layernorm", d, dtype),
    }


def abstract_params_encdec(cfg: ModelConfig) -> Dict:
    return jax.eval_shape(partial(init_params_encdec, cfg), jax.random.key(0))


def _self_attn(lp, x, cfg, positions, cache=None):
    # whisper uses no RoPE; positions only index caches.  Reuse the GQA block
    # with theta-> identity by passing positions of zeros (rope(0) = id).
    zero_pos = jnp.zeros_like(positions)
    return L.attention_block(lp, x, cfg.attention, positions=zero_pos,
                             causal=cache is not None or True,
                             cache=cache, impl="chunked", chunk=1024)


def _cross_attn(lp, x, enc_k, enc_v, cfg):
    a = cfg.attention
    b, s, _ = x.shape
    q = (x @ lp["wq"]).reshape(b, s, a.n_heads, a.head_dim)
    out = L.dense_attention(q, enc_k, enc_v, causal=False)
    return out.reshape(b, s, a.n_heads * a.head_dim) @ lp["wo"]


def encode(params: Dict, cfg: ModelConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: (B, encoder_len, d) precomputed conv-frontend output (stub)."""
    dtype = _dtype(cfg.compute_dtype)
    x = frames.astype(dtype) + params["enc_pos"].astype(dtype)[None]
    positions = jnp.arange(x.shape[1])[None, :]

    x = pspec.shard(x, "batch", "sp", None)

    def body(x, lp):
        lp = cast_tree(lp, dtype)
        h = norm("layernorm", x, lp["ln1"])
        mixed, _ = L.attention_block(lp["attn"], h, cfg.attention,
                                     positions=jnp.zeros_like(positions),
                                     causal=False, impl="dense")
        x = x + mixed
        h = norm("layernorm", x, lp["ln2"])
        return pspec.shard(x + L.mlp_block(lp["mlp"], h, "gelu"),
                           "batch", "sp", None), ()

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return norm("layernorm", x, params["enc_norm"])


def _enc_kv(lp_cross, enc_out, cfg):
    a = cfg.attention
    b, t, _ = enc_out.shape
    k = (enc_out @ lp_cross["wk"]).reshape(b, t, a.n_heads, a.head_dim)
    v = (enc_out @ lp_cross["wv"]).reshape(b, t, a.n_heads, a.head_dim)
    return k, v


def forward_encdec(params: Dict, cfg: ModelConfig, tokens: jnp.ndarray,
                   frames: jnp.ndarray) -> jnp.ndarray:
    """Teacher-forcing decode over the full token sequence (training)."""
    dtype = _dtype(cfg.compute_dtype)
    enc_out = encode(params, cfg, frames)
    b, s = tokens.shape
    x = params["embed"][tokens].astype(dtype) + \
        params["dec_pos"][:s].astype(dtype)[None]
    x = pspec.shard(x, "batch", "sp", None)
    positions = jnp.arange(s)[None, :]

    def body(x, lp):
        lp = cast_tree(lp, dtype)
        h = norm("layernorm", x, lp["ln1"])
        mixed, _ = L.attention_block(lp["self"], h, cfg.attention,
                                     positions=jnp.zeros_like(positions),
                                     causal=True, impl="chunked", chunk=1024)
        x = x + mixed
        h = norm("layernorm", x, lp["lnx"])
        x = x + _cross_attn(lp["cross"], h, *_enc_kv(lp["cross"], enc_out, cfg), cfg)
        h = norm("layernorm", x, lp["ln2"])
        return pspec.shard(x + L.mlp_block(lp["mlp"], h, "gelu"),
                           "batch", "sp", None), ()

    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = norm("layernorm", x, params["final_norm"])
    return x @ params["embed"].T.astype(dtype)


def init_cache_encdec(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    dtype = _dtype(cfg.compute_dtype)
    a = cfg.attention
    Ld = cfg.n_layers
    e = cfg.enc_dec
    return {
        "self": {
            "k": jnp.zeros((Ld, batch, max_len, a.n_kv_heads, a.head_dim), dtype),
            "v": jnp.zeros((Ld, batch, max_len, a.n_kv_heads, a.head_dim), dtype),
            "kpos": jnp.full((Ld, max_len), -1, jnp.int32),
            "pos": jnp.zeros((Ld,), jnp.int32),
        },
        "cross_k": jnp.zeros((Ld, batch, e.encoder_len, a.n_heads, a.head_dim), dtype),
        "cross_v": jnp.zeros((Ld, batch, e.encoder_len, a.n_heads, a.head_dim), dtype),
    }


def prefill_encdec(params: Dict, cfg: ModelConfig, tokens: jnp.ndarray,
                   frames: jnp.ndarray, cache: Dict) -> Tuple[jnp.ndarray, Dict]:
    """Encode audio + run the prompt tokens, filling self- and cross-caches."""
    dtype = _dtype(cfg.compute_dtype)
    enc_out = encode(params, cfg, frames)
    b, s = tokens.shape
    x = params["embed"][tokens].astype(dtype) + \
        params["dec_pos"][:s].astype(dtype)[None]
    x = pspec.shard(x, "batch", "sp", None)
    positions = jnp.arange(s)[None, :]

    def body(x, inp):
        lp, sc = inp
        lp = cast_tree(lp, dtype)
        h = norm("layernorm", x, lp["ln1"])
        mixed, nc = L.attention_block(lp["self"], h, cfg.attention,
                                      positions=jnp.zeros_like(positions),
                                      causal=True, cache=sc,
                                      impl="chunked", chunk=1024)
        x = x + mixed
        ck, cv = _enc_kv(lp["cross"], enc_out, cfg)
        h = norm("layernorm", x, lp["lnx"])
        x = x + _cross_attn(lp["cross"], h, ck, cv, cfg)
        h = norm("layernorm", x, lp["ln2"])
        x = x + L.mlp_block(lp["mlp"], h, "gelu")
        return x, (nc, ck.astype(dtype), cv.astype(dtype))

    x, (self_c, cross_k, cross_v) = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["self"]))
    x = norm("layernorm", x[:, -1:], params["final_norm"])
    logits = x @ params["embed"].T.astype(dtype)
    return logits, {"self": self_c, "cross_k": cross_k, "cross_v": cross_v}


def decode_step_encdec(params: Dict, cfg: ModelConfig, token: jnp.ndarray,
                       cache: Dict) -> Tuple[jnp.ndarray, Dict]:
    dtype = _dtype(cfg.compute_dtype)
    b = token.shape[0]
    pos0 = cache["self"]["pos"][0]
    x = params["embed"][token].astype(dtype) + \
        jax.lax.dynamic_slice_in_dim(params["dec_pos"], pos0, 1, axis=0
                                     ).astype(dtype)[None, 0:1]
    positions = pos0 + jnp.zeros((1, 1), jnp.int32)

    def body(x, inp):
        lp, sc, ck, cv = inp
        lp = cast_tree(lp, dtype)
        h = norm("layernorm", x, lp["ln1"])
        mixed, nc = L.attention_block(lp["self"], h, cfg.attention,
                                      positions=jnp.zeros_like(positions),
                                      causal=True, cache=sc, impl="dense")
        x = x + mixed
        h = norm("layernorm", x, lp["lnx"])
        x = x + _cross_attn(lp["cross"], h, ck, cv, cfg)
        h = norm("layernorm", x, lp["ln2"])
        x = x + L.mlp_block(lp["mlp"], h, "gelu")
        return x, nc

    x, self_c = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["self"],
                  cache["cross_k"], cache["cross_v"]))
    x = norm("layernorm", x, params["final_norm"])
    logits = x @ params["embed"].T.astype(dtype)
    return logits, {"self": self_c, "cross_k": cache["cross_k"],
                    "cross_v": cache["cross_v"]}
