"""Mixture-of-Experts block: top-k routing with capacity-based dispatch.

Gather/scatter-free formulation chosen for SPMD friendliness and bounded
memory (DESIGN.md §6):

1. router logits (G, Tg, E) -> top_k expert ids + normalized gates;
2. per-slot positions inside each expert's capacity via K sequential
   one-hot cumsums (transient (G, Tg, E) each — never (T, E, C));
3. dispatch by *gather*: token index table (G, E, C) -> expert inputs
   (G, E, C, d) via take_along_axis;
4. expert FFN einsums with weights (E, d, f) — E shards over the ``model``
   mesh axis (expert parallelism); XLA inserts the all-to-alls;
5. combine by the transpose gather (G, Tg, K, d) weighted by gates.

Supports DeepSeekMoE fine-grained experts + shared experts (always-active
experts computed as a dense gated FFN of width n_shared * d_expert).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import MoEConfig
from .. import pspec
from .layers import init_mlp, mlp_block

__all__ = ["init_moe", "moe_block"]


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def init_moe(key, cfg: MoEConfig, d_model: int, dtype) -> Dict:
    m = cfg
    ks = jax.random.split(key, 5)
    s = d_model ** -0.5
    p = {
        "router": jax.random.normal(ks[0], (d_model, m.n_experts), jnp.float32) * s,
        "w_gate": jax.random.normal(ks[1], (m.n_experts, d_model, m.d_expert), dtype) * s,
        "w_up": jax.random.normal(ks[2], (m.n_experts, d_model, m.d_expert), dtype) * s,
        "w_down": jax.random.normal(ks[3], (m.n_experts, m.d_expert, d_model), dtype) * (m.d_expert ** -0.5),
    }
    if m.n_shared_experts > 0:
        p["shared"] = init_mlp(ks[4], d_model, m.n_shared_experts * m.d_expert,
                               dtype, gated=True)
    return p


def moe_block(params: Dict, x: jnp.ndarray, cfg: MoEConfig, *,
              activation: str = "silu", group: int = 1024,
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out, aux_loss)."""
    m = cfg
    b, s, d = x.shape
    T = b * s
    tg = min(group, T)
    assert T % tg == 0, (T, tg)
    g = T // tg
    xg = x.reshape(g, tg, d)

    logits = jnp.einsum("gtd,de->gte", xg, params["router"].astype(xg.dtype),
                        preferred_element_type=jnp.float32)       # (G,Tg,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, m.top_k)         # (G,Tg,K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9)

    # aux load-balancing loss (Switch-style)
    me = probs.mean(axis=(0, 1))                                   # (E,)
    ce = jax.nn.one_hot(expert_ids[..., 0], m.n_experts).mean(axis=(0, 1))
    aux = m.n_experts * jnp.sum(me * ce) * m.aux_loss_coef

    cap = _round_up(max(1, int(tg * m.top_k / m.n_experts * m.capacity_factor)), 8)

    # --- per-slot positions within expert capacity (K sequential cumsums) ---
    token_idx = jnp.zeros((g, m.n_experts, cap), jnp.int32)        # (G,E,C)
    token_valid = jnp.zeros((g, m.n_experts, cap), dtype=bool)
    pos_k = []
    counts = jnp.zeros((g, 1, m.n_experts), jnp.float32)
    for slot in range(m.top_k):
        onehot = jax.nn.one_hot(expert_ids[..., slot], m.n_experts)   # (G,Tg,E)
        pos = jnp.cumsum(onehot, axis=1) - 1.0 + counts               # (G,Tg,E)
        counts = counts + onehot.sum(axis=1, keepdims=True)
        p_tok = jnp.sum(pos * onehot, axis=-1).astype(jnp.int32)      # (G,Tg)
        ok = p_tok < cap
        pos_k.append((p_tok, ok))
        # scatter token index into (G, E, C) table
        e_ids = expert_ids[..., slot]                                 # (G,Tg)
        flat_ec = jnp.where(ok, e_ids * cap + jnp.minimum(p_tok, cap - 1), 0)
        upd_idx = jnp.where(ok, jnp.arange(tg)[None, :], 0)
        tbl = token_idx.reshape(g, m.n_experts * cap)
        vld = token_valid.reshape(g, m.n_experts * cap)
        tbl = jax.vmap(lambda t_, f_, u_, o_: t_.at[f_].set(
            jnp.where(o_, u_, t_[f_])))(tbl, flat_ec, upd_idx, ok)
        vld = jax.vmap(lambda v_, f_, o_: v_.at[f_].max(o_))(vld, flat_ec, ok)
        token_idx = tbl.reshape(g, m.n_experts, cap)
        token_valid = vld.reshape(g, m.n_experts, cap)

    # --- dispatch gather: (G, E, C, d) ---
    gathered = jnp.take_along_axis(
        xg[:, None, :, :],                                            # (G,1,Tg,d)
        token_idx[..., None].astype(jnp.int32).reshape(g, m.n_experts, cap, 1)
        .clip(0, tg - 1),
        axis=2)                                                       # broadcast E
    gathered = jnp.where(token_valid[..., None], gathered, 0.0)
    # expert parallelism: groups follow the batch shards, experts follow TP
    gathered = pspec.shard(gathered, "batch", "tp", None, None)

    # --- expert FFN (E sharded over `model`) ---
    act = jax.nn.silu if activation == "silu" else jax.nn.gelu
    h = act(jnp.einsum("gecd,edf->gecf", gathered, params["w_gate"])) * \
        jnp.einsum("gecd,edf->gecf", gathered, params["w_up"])
    expert_out = jnp.einsum("gecf,efd->gecd", h, params["w_down"])    # (G,E,C,d)
    expert_out = pspec.shard(expert_out, "batch", "tp", None, None)

    # --- combine: transpose gather per slot ---
    out = jnp.zeros((g, tg, d), expert_out.dtype)
    eo_flat = expert_out.reshape(g, m.n_experts * cap, d)
    for slot in range(m.top_k):
        p_tok, ok = pos_k[slot]
        e_ids = expert_ids[..., slot]
        flat = (e_ids * cap + jnp.minimum(p_tok, cap - 1)).clip(0, m.n_experts * cap - 1)
        piece = jnp.take_along_axis(eo_flat, flat[..., None], axis=1)  # (G,Tg,d)
        w = (gate_vals[..., slot] * ok.astype(jnp.float32))[..., None]
        out = out + piece * w.astype(piece.dtype)

    out = out.reshape(b, s, d).astype(x.dtype)
    if "shared" in params:
        out = out + mlp_block(params["shared"], x, activation)
    return out, aux
