"""Production mesh construction.

Single pod: (16, 16) = 256 chips, axes ("data", "model") — FSDP over
``data`` (params/optimizer sharded, all-gather on use), TP/EP over
``model`` (heads, d_ff, experts, decode-cache sequence).

Multi-pod: (2, 16, 16) = 512 chips, leading ``pod`` axis = pure data
parallelism across pods (gradient all-reduce over DCN, optionally
compressed — repro.optim.compress).

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "mesh_axis_sizes"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over the locally available devices (tests/examples)."""
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
