"""Roofline report: read the dry-run JSONs and emit the §Roofline table.

    PYTHONPATH=src python -m repro.launch.roofline_report \
        --dryrun experiments/dryrun --mesh single --markdown
"""

from __future__ import annotations

import argparse
import json
import warnings
from pathlib import Path
from typing import Dict, List, Optional

from ..models import SHAPES
from .roofline import HW_V5E, RooflineCell, roofline_terms


def load_cells(dryrun_dir: Path, mesh: str = "single") -> List[Dict]:
    out = []
    for p in sorted(dryrun_dir.glob(f"*__{mesh}.json")):
        r = json.loads(p.read_text())
        if "memory" in r:
            out.append(r)
    return out


def analyze(rec: Dict) -> Optional[RooflineCell]:
    """Roofline terms for one dry-run record.

    FLOPs: the trip-aware HLO dot walk (``dot_flops_per_device``) — XLA:CPU
    cost_analysis counts while bodies once, so its raw "flops" undercounts
    scanned layers.  Bytes: cost_analysis bytes scaled by the same trip
    correction (flops_walk / flops_ca), since both live in the same loop
    bodies.  Collectives: the trip-aware HLO parser (per-device bytes).
    """
    ca_flops = rec.get("flops_per_device") or 0.0
    dot_flops = rec.get("dot_flops_per_device") or 0.0
    flops = dot_flops or ca_flops
    trip_corr = flops / max(ca_flops, 1.0)
    if dot_flops > 0.0 and ca_flops > 0.0 and trip_corr < 1.0 - 1e-6:
        # the HLO walk can only add trip multiplication on top of what
        # cost_analysis already counts; undercounting means the parser
        # missed dots (format drift) — surface it instead of silently
        # deflating the compute/memory terms.
        warnings.warn(
            f"roofline: dot-FLOPs walk ({dot_flops:.3g}) < cost_analysis "
            f"({ca_flops:.3g}) for {rec.get('arch')}/{rec.get('shape')} — "
            "HLO parser drift?", RuntimeWarning, stacklevel=2)
    trip_corr = max(1.0, trip_corr)
    hbm = (rec.get("bytes_per_device") or 0.0) * trip_corr
    coll = rec.get("collective_bytes_total") or 0.0
    shape = SHAPES[rec["shape"]]
    tokens = (shape.global_batch if shape.mode == "decode"
              else shape.global_batch * shape.seq_len)
    mult = 3 if shape.mode == "train" else 1
    n_chips = 512 if rec["mesh"] in ("multi", "2x16x16") else 256
    model_flops = 2.0 * mult * rec["n_active_params"] * tokens / n_chips
    t = roofline_terms(flops, hbm, coll)
    # decode: mandatory traffic = params + cache streamed once per token
    mandatory_s = (rec.get("memory", {}).get("argument_bytes", 0)
                   / 819e9)
    cell = RooflineCell(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        compute_s=t["compute_s"], memory_s=t["memory_s"],
        collective_s=t["collective_s"],
        model_flops=model_flops, hlo_flops=flops,
        useful_ratio=model_flops / max(flops, 1e-30))
    cell.mandatory_memory_s = mandatory_s  # type: ignore[attr-defined]
    return cell


def table(cells: List[RooflineCell], markdown: bool = True) -> str:
    hdr = ["arch", "shape", "compute_s", "memory_s", "collective_s",
           "bound", "roofline_frac", "useful_flops_ratio"]
    rows = []
    for c in cells:
        rows.append([c.arch, c.shape, f"{c.compute_s:.4g}",
                     f"{c.memory_s:.4g}", f"{c.collective_s:.4g}",
                     c.dominant, f"{c.roofline_fraction:.3f}",
                     f"{c.useful_ratio:.3f}"])
    if markdown:
        lines = ["| " + " | ".join(hdr) + " |",
                 "|" + "|".join(["---"] * len(hdr)) + "|"]
        lines += ["| " + " | ".join(r) + " |" for r in rows]
        return "\n".join(lines)
    lines = [",".join(hdr)] + [",".join(r) for r in rows]
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    recs = load_cells(Path(args.dryrun), args.mesh)
    cells = [analyze(r) for r in recs]
    print(table([c for c in cells if c], markdown=args.markdown))


if __name__ == "__main__":
    main()
