"""Roofline analysis from compiled dry-run artifacts (EXPERIMENTS.md
§Roofline).

Three terms per (arch × shape × mesh) cell:

    compute    = HLO_FLOPs      / (peak_FLOP/s per chip)
    memory     = HLO_bytes      / (HBM bytes/s per chip)
    collective = coll_bytes/dev / (ICI bytes/s per link)

``compiled.cost_analysis()`` reports per-device FLOPs/bytes with while trip
counts applied.  Collective bytes are NOT in cost_analysis: we parse the
post-optimization HLO — every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute's shape, multiplied by the ring-algorithm
traffic factor and by the ``known_trip_count`` of every enclosing while
loop (scan bodies).

Caveat (recorded in DESIGN.md §8): XLA:CPU's SPMD partitioner may choose
different collective algorithms than TPU's, so the collective term is a
*structural estimate* (bytes over link bandwidth), not a measurement.

HLO text format assumptions (post-optimization HLO, verified against
jax 0.4.x / XLA:CPU):

- Instruction lines: ``[ROOT] %name = type{layout} op(...), attrs`` — the
  result type precedes the op name; operands may appear either bare
  (``dot(%a, %b)``) or with inlined operand types
  (``dot(f32[2,32,64]{2,1,0} %a, f32[64,64]{1,0} %b)``).  Both forms are
  accepted; layout suffixes may contain tiling annotations
  (``{1,0:T(8,128)}``).
- Computation headers start at column 0 and contain ``{`` plus either
  ``->`` or a leading ``ENTRY``.
- While loops carry ``body=%name`` / ``condition=%name`` and, when XLA
  could infer it, ``backend_config={"known_trip_count":{"n":"N"}}``.
- Nested calls are reachable via ``calls=``, ``to_apply=``, ``body=`` or
  ``branch_computations=`` attributes.
"""

from __future__ import annotations

import json
import re
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional, Tuple

__all__ = ["HW_V5E", "parse_collective_bytes", "parse_dot_flops",
           "roofline_terms", "RooflineCell"]

# TPU v5e constants (assignment-specified)
HW_V5E = {
    "peak_bf16_flops": 197e12,     # FLOP/s per chip
    "hbm_bytes_per_s": 819e9,      # per chip
    "ici_bytes_per_s": 50e9,       # per link
}

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# ring-algorithm traffic factors (bytes moved per device / payload bytes)
_FACTOR = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
           "all-to-all": 1.0, "collective-permute": 1.0}

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|"
                       r"pred|c64|c128|f8e4m3fn|f8e5m2)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    """Max element-shape bytes appearing in a type string (tuples -> max)."""
    best = 0
    for m in _SHAPE_RE.finditer(text):
        b = _DTYPE_BYTES[m.group(1)]
        dims = m.group(2)
        if dims:
            for d in dims.split(","):
                b *= int(d)
        best = max(best, b)
    return best


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    """computation name -> its instruction lines."""
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        if not line.startswith(" ") and ("{" in line) and ("->" in line or
                                                           line.startswith("ENTRY")):
            m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)", line.strip())
            cur = m.group(1) if m else None
            if line.strip().startswith("ENTRY"):
                comps["__entry__"] = comps.setdefault(m.group(1), [])
                comps["__entry_name__"] = m.group(1)  # type: ignore
            if cur is not None:
                comps.setdefault(cur, [])
        elif cur is not None and line.startswith("}"):
            cur = None
        elif cur is not None:
            comps[cur].append(line)
    return comps


def parse_collective_bytes(hlo: str) -> Dict[str, Dict[str, float]]:
    """Per-device collective traffic by kind, while-trip aware.

    Returns {kind: {"count": n_instructions, "bytes": traffic_bytes}} where
    traffic includes the ring factor and all enclosing loop trip counts.
    """
    comps = _split_computations(hlo)
    entry_name = comps.get("__entry_name__")
    if not isinstance(entry_name, str):
        # fall back: pick computation containing " ROOT %tuple" with most lines
        entry_name = max((k for k in comps if isinstance(comps[k], list)),
                         key=lambda k: len(comps[k]))

    # computation -> [(callee, trips)]
    calls: Dict[str, List[Tuple[str, float]]] = {}
    # computation -> [(kind, bytes)]
    colls: Dict[str, List[Tuple[str, float]]] = {}

    while_re = re.compile(r"=\s*\(.*?\)\s*while\(|while\(")
    body_re = re.compile(r"body=%?([\w\.\-]+)")
    trip_re = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
    callee_re = re.compile(r"(?:calls|to_apply|body|branch_computations)="
                           r"\{?%?([\w\.\-]+)")
    coll_re = re.compile(r"=\s*([^=]*?)\b(all-gather|all-reduce|"
                         r"reduce-scatter|all-to-all|collective-permute)"
                         r"(?:-start)?\(")

    for name, lines in comps.items():
        if not isinstance(lines, list):
            continue
        for line in lines:
            mc = coll_re.search(line)
            if mc and "-done" not in line:
                kind = mc.group(2)
                nbytes = _shape_bytes(mc.group(1))
                colls.setdefault(name, []).append((kind, float(nbytes)))
            if "while(" in line:
                mb = body_re.search(line)
                mt = trip_re.search(line)
                trips = float(mt.group(1)) if mt else 1.0
                if mb:
                    calls.setdefault(name, []).append((mb.group(1), trips))
            elif "calls=" in line or "to_apply=" in line:
                mk = callee_re.search(line)
                if mk:
                    calls.setdefault(name, []).append((mk.group(1), 1.0))

    # DFS with multipliers (the call graph is a DAG)
    out: Dict[str, Dict[str, float]] = {k: {"count": 0, "bytes": 0.0}
                                        for k in _COLL_KINDS}
    seen_stack = set()

    def walk(comp: str, mult: float) -> None:
        if comp in seen_stack:  # defensive: no recursion in HLO
            return
        seen_stack.add(comp)
        for kind, nbytes in colls.get(comp, ()):
            out[kind]["count"] += mult
            out[kind]["bytes"] += mult * nbytes * _FACTOR[kind]
        for callee, trips in calls.get(comp, ()):
            walk(callee, mult * trips)
        seen_stack.discard(comp)

    walk(entry_name, 1.0)
    return out


def parse_dot_flops(hlo: str) -> float:
    """Total dot/convolution FLOPs per device, while-trip aware.

    XLA:CPU's ``cost_analysis()`` counts each while body ONCE (no trip
    multiplication — verified against scanned-layer models), so the
    compute roofline term must be derived by walking the HLO: for every
    ``dot`` instruction, FLOPs = 2 * prod(output shape) * contracted size,
    multiplied by the ``known_trip_count`` of every enclosing while loop.
    """
    comps = _split_computations(hlo)
    entry_name = comps.get("__entry_name__")
    if not isinstance(entry_name, str):
        entry_name = max((k for k in comps if isinstance(comps[k], list)),
                         key=lambda k: len(comps[k]))

    inst_re = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
    body_re = re.compile(r"body=%?([\w\.\-]+)")
    trip_re = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
    callee_re = re.compile(r"(?:calls|to_apply|body|branch_computations)="
                           r"\{?%?([\w\.\-]+)")
    # operands may carry an inlined ``dtype[dims]{layout}`` prefix
    # (post-optimization HLO in current XLA) or appear bare (older text)
    _op = r"(?:\w+\[[0-9,]*\](?:\{[^}]*\})?\s+)?%([\w\.\-]+)"
    dot_re = re.compile(r"\bdot\(" + _op + r",\s*" + _op + r"\)")
    contract_re = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

    calls: Dict[str, List[Tuple[str, float]]] = {}
    flops: Dict[str, float] = {}

    for name, lines in comps.items():
        if not isinstance(lines, list):
            continue
        shapes: Dict[str, List[int]] = {}
        # first pass: symbol table of output shapes
        pend: List[Tuple[str, str]] = []
        for line in lines:
            m = inst_re.match(line)
            if not m:
                continue
            iname, rhs = m.group(1), m.group(2)
            sm = _SHAPE_RE.search(rhs)
            if sm:
                dims = [int(x) for x in sm.group(2).split(",")] if sm.group(2) else []
                shapes[iname] = dims
            pend.append((iname, rhs))
        total = 0.0
        for iname, rhs in pend:
            dm = dot_re.search(rhs)
            if dm:
                out_dims = shapes.get(iname, [])
                lhs_dims = shapes.get(dm.group(1), [])
                cm = contract_re.search(rhs)
                k = 1
                if cm and cm.group(1):
                    for ci in cm.group(1).split(","):
                        ci = int(ci)
                        if ci < len(lhs_dims):
                            k *= lhs_dims[ci]
                out = 1
                for dd in out_dims:
                    out *= dd
                total += 2.0 * out * k
            if "while(" in rhs:
                mb = body_re.search(rhs)
                mt = trip_re.search(rhs)
                if mb:
                    calls.setdefault(name, []).append(
                        (mb.group(1), float(mt.group(1)) if mt else 1.0))
            elif "calls=" in rhs or "to_apply=" in rhs:
                mk = callee_re.search(rhs)
                if mk:
                    calls.setdefault(name, []).append((mk.group(1), 1.0))
        flops[name] = total

    seen = set()

    def walk(comp: str, mult: float) -> float:
        if comp in seen:
            return 0.0
        seen.add(comp)
        t = flops.get(comp, 0.0) * mult
        for callee, trips in calls.get(comp, ()):
            t += walk(callee, mult * trips)
        seen.discard(comp)
        return t

    return walk(entry_name, 1.0)


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   hw: Dict[str, float] = HW_V5E) -> Dict[str, float]:
    return {
        "compute_s": flops / hw["peak_bf16_flops"],
        "memory_s": hbm_bytes / hw["hbm_bytes_per_s"],
        "collective_s": coll_bytes / hw["ici_bytes_per_s"],
    }


@dataclass
class RooflineCell:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops: float
    useful_ratio: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """compute_term / max-term: 1.0 = compute-bound at peak."""
        return self.compute_s / max(self.bound_s, 1e-30)
