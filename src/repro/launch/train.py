"""End-to-end training driver.

CPU-runnable with the reduced (smoke) configs — the quickstart trains a
~100M-class model for a few hundred steps — and mesh/shard-aware for real
deployments (same code path, bigger mesh).

Features wired in: deterministic resumable data pipeline, AdamW + warmup/
cosine schedule, atomic checkpoints + auto-resume (fault tolerance),
straggler monitor, failure injection (tests), SIGTERM checkpoint.

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/run1
"""

from __future__ import annotations

import argparse
import signal
import sys
import time
from dataclasses import replace
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import pspec
from ..ckpt import CheckpointManager
from ..configs import get_config, get_smoke_config
from ..data import DataConfig, TokenPipeline, synthetic_source
from ..models import get_model
from ..optim import AdamWConfig, linear_warmup_cosine
from ..runtime import FailureInjector, Metrics, StragglerMonitor
from .mesh import make_local_mesh
from .sharding import input_specs_sharding, param_specs
from .steps import init_train_state, make_train_step


def train_loop(cfg, *, steps: int, batch: int, seq: int,
               ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
               lr: float = 3e-4, seed: int = 0, mesh=None,
               fail_at_step: int = -1, log_every: int = 10,
               print_fn=print):
    """Returns (params, metrics).  Restartable: rerun with the same
    ckpt_dir to resume from the newest committed checkpoint."""
    model = get_model(cfg)
    opt_cfg = AdamWConfig(lr=lr, schedule=linear_warmup_cosine(
        max(1, steps // 20), steps))
    step_fn = make_train_step(cfg, opt_cfg)

    dcfg = DataConfig(seq_len=seq, global_batch=batch,
                      vocab_size=cfg.vocab_size, seed=seed)
    params, opt_state = init_train_state(cfg, jax.random.key(seed))

    start_step = 0
    mgr = CheckpointManager(ckpt_dir, ckpt_every) if ckpt_dir else None
    if mgr is not None:
        restored, extra = mgr.restore_or_none({"params": params,
                                               "opt": opt_state})
        if restored is not None:
            params, opt_state = restored["params"], restored["opt"]
            start_step = int(extra["data_step"])
            print_fn(f"[resume] restored step {start_step} from {mgr.directory}")

    pipe = TokenPipeline(dcfg, synthetic_source(dcfg), start_step=start_step)
    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
    monitor = StragglerMonitor()
    injector = FailureInjector(fail_at_step)
    metrics = Metrics()

    # SIGTERM -> checkpoint + clean exit (preemption handling)
    stop = {"now": False}

    def _sigterm(signum, frame):  # pragma: no cover - signal path
        stop["now"] = True

    old = signal.signal(signal.SIGTERM, _sigterm)

    def make_batch(np_batch):
        extra = {}
        if cfg.n_patches:
            extra["patches"] = jnp.zeros((batch, cfg.n_patches, cfg.d_model),
                                         jnp.float32)
        if cfg.enc_dec is not None:
            extra["frames"] = jnp.zeros(
                (batch, cfg.enc_dec.encoder_len, cfg.d_model), jnp.float32)
        return {"tokens": jnp.asarray(np_batch["tokens"]),
                "labels": jnp.asarray(np_batch["labels"]), **extra}

    try:
        for step in range(start_step, steps):
            injector.check(step)
            np_batch = next(pipe)
            monitor.start()
            params, opt_state, m = jit_step(params, opt_state,
                                            make_batch(np_batch))
            loss = float(m["loss"])
            straggler = monitor.stop()
            metrics.log(step, loss=loss, grad_norm=float(m["grad_norm"]),
                        lr=float(m["lr"]))
            if straggler:
                print_fn(f"[straggler] step {step} slow "
                         f"(median {np.median(monitor.times):.3f}s)")
            if step % log_every == 0:
                print_fn(f"step {step:5d} loss {loss:.4f} "
                         f"gnorm {float(m['grad_norm']):.3f}")
            if mgr is not None and (mgr.should_save(step + 1) or stop["now"]):
                mgr.save({"params": params, "opt": opt_state}, step + 1,
                         extra={"data_step": pipe.state()["step"],
                                "arch": cfg.arch_id})
            if stop["now"]:
                print_fn(f"[sigterm] checkpointed at step {step + 1}, exiting")
                break
    finally:
        pipe.close()
        signal.signal(signal.SIGTERM, old)
    if mgr is not None:
        mgr.save({"params": params, "opt": opt_state}, steps,
                 extra={"data_step": pipe.state()["step"],
                        "arch": cfg.arch_id})
    return params, metrics


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fail-at-step", type=int, default=-1)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = replace(cfg, train_microbatches=1)
    _, metrics = train_loop(
        cfg, steps=args.steps, batch=args.batch, seq=args.seq,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every, lr=args.lr,
        seed=args.seed, fail_at_step=args.fail_at_step)
    losses = [r["loss"] for r in metrics.rows]
    if losses:
        print(f"first loss {losses[0]:.4f} -> last loss {losses[-1]:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
