"""Sharding rules: parameter / input / cache PartitionSpecs per (arch,
shape, mesh).

Strategy (DESIGN.md §6):
* ``pod``   — pure DP: params replicated across pods, batch sharded.
* ``data``  — FSDP: the non-TP dimension of every weight matrix is sharded
  over ``data``; optimizer state inherits the weight's spec (ZeRO).
* ``model`` — TP: attention heads / d_ff / experts / mamba d_inner; for
  decode shapes additionally the KV-cache sequence dimension (sequence-
  parallel cache — scores reduce over a sharded axis, XLA inserts the
  softmax partial-reduction collectives).

Every rule is divisibility-guarded: an axis that does not divide the
dimension is dropped (never pad-shard), so the same rules serve full-size
and smoke configs.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig, ShapeConfig

__all__ = ["param_specs", "input_specs_sharding", "cache_specs",
           "batch_axes", "named", "guard_spec"]


def _axis_size(mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        return int(np.prod([_axis_size(mesh, n) for n in name]))
    return mesh.shape[name]


def guard_spec(mesh, spec: P, shape: Tuple[int, ...]) -> P:
    """Drop spec axes that don't divide the corresponding dim."""
    out = []
    for dim, names in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if names is None:
            out.append(None)
            continue
        cand = names if isinstance(names, tuple) else (names,)
        kept = []
        size = 1
        for n in cand:
            s = _axis_size(mesh, n)
            if dim % (size * s) == 0:
                kept.append(n)
                size *= s
        out.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    return P(*out)


def named(mesh, spec: P, shape: Tuple[int, ...]) -> NamedSharding:
    return NamedSharding(mesh, guard_spec(mesh, spec, shape))


def batch_axes(mesh) -> Tuple[str, ...]:
    return tuple(n for n in ("pod", "data") if n in mesh.axis_names)


# ---------------------------------------------------------------------------
# parameter rules (keyed by leaf name, stacked leading layer dim ignored)
# ---------------------------------------------------------------------------

# name -> spec for the *trailing* dims (leading stacked dims -> None)
_RULES: Dict[str, Tuple[Optional[Any], ...]] = {
    # embeddings
    "embed": ("model", "data"),
    "unembed": ("data", "model"),
    "patch_proj": ("data", "model"),
    "dec_pos": (None, "data"),
    "enc_pos": (None, None),
    # attention (col-parallel in, row-parallel out)
    "wq": ("data", "model"),
    "wk": ("data", "model"),
    "wv": ("data", "model"),
    "wo": ("model", "data"),
    # MLA
    "wdq": ("data", "model"),
    "wuq": ("model", None),       # (q_lora, H*qk): H over model would be 2nd
    "wdkv": ("data", None),
    "wkr": ("data", None),
    "wuk": ("model", None, None),  # (H, rank, hd)
    "wuv": ("model", None, None),
    # MLP
    "w_gate": ("data", "model"),
    "w_up": ("data", "model"),
    "w_down": ("model", "data"),
    # MoE (leading E dim)
    "router": ("data", None),
    # mamba
    "in_proj": ("data", "model"),
    "conv_w": (None, "model"),
    "conv_b": ("model",),
    "x_proj": ("model", None),
    "dt_proj": (None, "model"),
    "dt_bias": ("model",),
    "A_log": ("model", None),
    "D": ("model",),
    "out_proj": ("model", "data"),
    # norms
    "scale": (None,),
    "bias": (None,),
}

# MoE expert tensors carry a leading E dim that shards over `model`
_MOE_EXPERT_RULES: Dict[str, Tuple[Optional[Any], ...]] = {
    "w_gate": ("model", "data", None),
    "w_up": ("model", "data", None),
    "w_down": ("model", None, "data"),
}


def _leaf_spec(path, leaf) -> Tuple[Optional[Any], ...]:
    names = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
    name = str(names[-1])
    shape = leaf.shape
    in_moe = any(str(n) == "ffn" for n in names) and name in _MOE_EXPERT_RULES \
        and len(shape) >= 3
    # distinguish MoE expert weights (R, E, d, f) from MLP (R, d, f) by rank
    if in_moe and len(shape) == 4:
        trail = _MOE_EXPERT_RULES[name]
    elif name in _RULES:
        trail = _RULES[name]
    else:
        trail = ()
    lead = len(shape) - len(trail)
    if lead < 0:  # unstacked variant (e.g. whisper top-level embed)
        trail = trail[-len(shape):] if len(shape) else ()
        lead = len(shape) - len(trail)
    return (None,) * lead + tuple(trail)


def param_specs(mesh, abstract_params) -> Any:
    """Pytree of NamedShardings matching the abstract params."""

    def f(path, leaf):
        spec = P(*_leaf_spec(path, leaf))
        return named(mesh, spec, leaf.shape)

    return jax.tree_util.tree_map_with_path(f, abstract_params)


# ---------------------------------------------------------------------------
# inputs and caches
# ---------------------------------------------------------------------------


def input_specs_sharding(mesh, specs: Dict[str, Any]) -> Dict[str, Any]:
    """Batch-shard every input over (pod, data)."""
    ba = batch_axes(mesh)
    out = {}
    for k, v in specs.items():
        spec = P(ba) if v.shape[0] > 1 else P()
        out[k] = named(mesh, spec, v.shape)
    return out


def cache_specs(mesh, cfg: ModelConfig, abstract_cache, shape: ShapeConfig):
    """Decode caches: batch over (pod, data) when divisible; the cache
    sequence dim over ``model`` (sequence-parallel KV).  For B == 1
    (long_500k) the sequence dim takes (data, model)."""
    ba = batch_axes(mesh)
    B = shape.global_batch
    seq_axes = ("model",) if B > 1 else ("data", "model")

    def f(path, leaf):
        names = [str(getattr(k, "key", getattr(k, "idx", ""))) for k in path]
        name = names[-1] if names else ""
        shp = leaf.shape
        if name in ("k", "v"):          # (R, B, T, KV, hd)
            return named(mesh, P(None, ba, seq_axes, None, None), shp)
        if name in ("c_kv", "k_rope"):  # (R, B, T, rank)
            return named(mesh, P(None, ba, seq_axes, None), shp)
        if name in ("cross_k", "cross_v"):  # (L, B, T_enc, H, hd)
            return named(mesh, P(None, ba, None, "model", None), shp)
        if name == "conv":              # (R, B, dc-1, di)
            return named(mesh, P(None, ba, None, "model"), shp)
        if name == "h":                 # (R, B, di, N)
            return named(mesh, P(None, ba, "model", None), shp)
        if name == "kpos":              # (R, T)
            return named(mesh, P(None, seq_axes), shp)
        return named(mesh, P(), shp)

    return jax.tree_util.tree_map_with_path(f, abstract_cache)
