import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    # XLA:CPU's while-loop-invariant code motion hoists size-inflating
    # converts (bf16 saved-activation stacks -> f32) out of scan loops;
    # the TPU pipeline does not take such hoists.  Disable for parity so
    # the dry-run's memory analysis reflects the TPU memory plan.
    "--xla_disable_hlo_passes=while-loop-invariant-code-motion"
)

"""Multi-pod dry-run: lower + compile every (architecture × input shape ×
mesh) cell against the production mesh and record memory / cost /
collective analyses (EXPERIMENTS.md §Dry-run).

The two lines above MUST stay the first statements of this module: jax
locks the device count on first initialization.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh both --out experiments/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --arch jamba-v0.1-52b \
        --shape decode_32k --mesh single
"""

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from .. import pspec
from ..configs import ALIASES, all_arch_ids, get_config
from ..models import SHAPES, cell_is_runnable, get_model, input_specs
from ..models.config import ModelConfig, ShapeConfig
from .mesh import make_production_mesh
from .sharding import (batch_axes, cache_specs, input_specs_sharding,
                       named, param_specs)
from .steps import abstract_train_state, make_decode_step, make_prefill_step, \
    make_train_step
from jax.sharding import NamedSharding, PartitionSpec as P

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def count_collectives(hlo: str):
    out = {}
    for m in re.finditer(r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
                         r"collective-permute)(?:-start|-done)?\b", hlo):
        out[m.group(1)] = out.get(m.group(1), 0) + 1
    return out


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, donate: bool = True):
    """Build + lower + compile one cell; returns the analysis record."""
    with pspec.activation_mesh(mesh):
        return _lower_cell_inner(cfg, shape, mesh, donate)


def _lower_cell_inner(cfg: ModelConfig, shape: ShapeConfig, mesh,
                      donate: bool = True):
    model = get_model(cfg)
    specs = input_specs(cfg, shape)
    in_sh = input_specs_sharding(mesh, specs)
    t0 = time.time()

    if shape.mode == "train":
        params, opt_state = abstract_train_state(cfg)
        p_sh = param_specs(mesh, params)
        o_sh = jax.tree.map(lambda _: None, opt_state)
        # m/v inherit the weight spec; step scalar replicated
        o_sh = {"step": NamedSharding(mesh, P()),
                "m": jax.tree.map(lambda s: s, p_sh),
                "v": jax.tree.map(lambda s: s, p_sh)}
        step = make_train_step(cfg)
        jitted = jax.jit(step,
                         in_shardings=(p_sh, o_sh, in_sh),
                         donate_argnums=(0, 1) if donate else ())
        lowered = jitted.lower(params, opt_state, specs)
    elif shape.mode == "prefill":
        params = model.abstract_params()
        p_sh = param_specs(mesh, params)
        cache = jax.eval_shape(lambda: model.init_cache(shape.global_batch,
                                                        shape.seq_len))
        c_sh = cache_specs(mesh, cfg, cache, shape)
        step = make_prefill_step(cfg)
        jitted = jax.jit(step, in_shardings=(p_sh, c_sh, in_sh),
                         donate_argnums=(1,) if donate else ())
        lowered = jitted.lower(params, cache, specs)
    else:  # decode
        params = model.abstract_params()
        p_sh = param_specs(mesh, params)
        cache = jax.eval_shape(lambda: model.init_cache(shape.global_batch,
                                                        shape.seq_len))
        c_sh = cache_specs(mesh, cfg, cache, shape)
        step = make_decode_step(cfg)
        jitted = jax.jit(step, in_shardings=(p_sh, c_sh, in_sh["token"]),
                         donate_argnums=(1,) if donate else ())
        lowered = jitted.lower(params, cache, specs["token"])

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0] if ca else {}
    hlo = compiled.as_text()
    from .roofline import parse_collective_bytes, parse_dot_flops
    coll = parse_collective_bytes(hlo)
    dot_flops = parse_dot_flops(hlo)
    rec = {
        "arch": cfg.arch_id,
        "shape": shape.name,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "mode": shape.mode,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
        },
        "flops_per_device": ca.get("flops"),
        "dot_flops_per_device": dot_flops,
        "bytes_per_device": ca.get("bytes accessed"),
        "transcendentals": ca.get("transcendentals"),
        "collective_counts": count_collectives(hlo),
        "collective_bytes": coll,
        "collective_bytes_total": sum(v["bytes"] for v in coll.values()),
        "n_params": cfg.n_params(),
        "n_active_params": cfg.n_active_params(),
        "hlo_bytes": len(hlo),
    }
    return rec, compiled, lowered


def run_cells(arch_ids, shape_names, meshes, out_dir: Path, force: bool = False):
    out_dir.mkdir(parents=True, exist_ok=True)
    results = []
    for aid in arch_ids:
        cfg = get_config(aid)
        for sname in shape_names:
            shape = SHAPES[sname]
            ok, why = cell_is_runnable(cfg, shape)
            for mesh_name in meshes:
                tag = f"{ALIASES.get(aid, aid)}__{sname}__{mesh_name}"
                path = out_dir / f"{tag}.json"
                if path.exists() and not force:
                    results.append(json.loads(path.read_text()))
                    print(f"[cached] {tag}")
                    continue
                if not ok:
                    rec = {"arch": cfg.arch_id, "shape": sname,
                           "mesh": mesh_name, "skipped": why}
                    path.write_text(json.dumps(rec, indent=1))
                    print(f"[skip]   {tag}: {why}")
                    results.append(rec)
                    continue
                mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
                t0 = time.time()
                try:
                    rec, compiled, lowered = lower_cell(cfg, shape, mesh)
                    print(f"[ok]     {tag}: compile {rec['compile_s']}s "
                          f"temp {rec['memory']['temp_bytes']/2**30:.2f} GiB/dev")
                except Exception as e:  # noqa: BLE001 - record and continue
                    rec = {"arch": cfg.arch_id, "shape": sname,
                           "mesh": mesh_name, "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:],
                           "elapsed_s": round(time.time() - t0, 1)}
                    print(f"[FAIL]   {tag}: {type(e).__name__}: {e}")
                path.write_text(json.dumps(rec, indent=1))
                results.append(rec)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = all_arch_ids() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]
    results = run_cells(archs, shapes, meshes, Path(args.out),
                        force=args.force)
    n_ok = sum("memory" in r for r in results)
    n_skip = sum("skipped" in r for r in results)
    n_fail = sum("error" in r for r in results)
    print(f"\n=== dry-run: {n_ok} ok, {n_skip} skipped (by rule), {n_fail} FAILED ===")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
