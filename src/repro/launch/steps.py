"""Step builders: train_step / prefill_step / decode_step per config.

These close over the ModelConfig (static) and take only arrays, so a
single ``jax.jit`` per (arch × shape × mesh) cell covers the whole step —
the unit the dry-run lowers and the roofline analyses.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import get_model
from ..models.config import ModelConfig
from ..optim import AdamWConfig, adamw_init, adamw_update

__all__ = ["cross_entropy", "make_loss_fn", "make_train_step",
           "make_prefill_step", "make_decode_step", "init_train_state"]


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
    return nll.mean()


def make_loss_fn(cfg: ModelConfig, remat: bool = True) -> Callable:
    model = get_model(cfg)

    def loss_fn(params, batch: Dict[str, Any]):
        logits, aux = model.logits_and_aux(params, batch, remat=remat)
        if cfg.n_patches:  # VLM: patch prefix carries no LM loss
            logits = logits[:, cfg.n_patches:]
        loss = cross_entropy(logits, batch["labels"])
        return loss + aux, {"loss": loss, "aux": aux}

    return loss_fn


def make_train_step(cfg: ModelConfig, opt: Optional[AdamWConfig] = None,
                    remat: bool = True) -> Callable:
    opt = opt or AdamWConfig()
    loss_fn = make_loss_fn(cfg, remat=remat)
    n_micro = max(1, cfg.train_microbatches)

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            (loss, metrics), grads = grads_of(params, batch)
        else:
            # gradient accumulation: scan over microbatches; every
            # activation-linked buffer scales with B / n_micro
            micro = jax.tree.map(
                lambda a: a.reshape((n_micro, a.shape[0] // n_micro)
                                    + a.shape[1:]), batch)

            def acc_step(carry, mb):
                g_acc, l_acc = carry
                (l, m), g = grads_of(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), m

            g0 = jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32),
                              params)
            (grads, loss), ms = jax.lax.scan(acc_step, (g0, 0.0), micro)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = loss / n_micro
            metrics = jax.tree.map(lambda a: a[-1], ms)
        params, opt_state, opt_metrics = adamw_update(opt, params, grads,
                                                      opt_state)
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return params, opt_state, metrics

    return train_step


def init_train_state(cfg: ModelConfig, key) -> Tuple[Any, Any]:
    model = get_model(cfg)
    params = model.init_params(key)
    return params, adamw_init(params)


def abstract_train_state(cfg: ModelConfig) -> Tuple[Any, Any]:
    model = get_model(cfg)
    params = model.abstract_params()
    opt_state = jax.eval_shape(adamw_init, params)
    return params, opt_state


def make_prefill_step(cfg: ModelConfig) -> Callable:
    model = get_model(cfg)

    def prefill_step(params, cache, batch):
        return model.prefill(params, batch, cache)

    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    model = get_model(cfg)

    def decode_step(params, cache, token):
        return model.decode_step(params, token, cache)

    return decode_step
