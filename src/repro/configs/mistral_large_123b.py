"""mistral-large-123b — dense 88L d=12288, 96H GQA(kv=8), d_ff 28672,
vocab 32768.  The FSDP stress architecture of the pool.
[hf:mistralai/Mistral-Large-Instruct-2407; unverified]
"""

from dataclasses import replace

from ..models.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="mistral-large-123b",
    family="dense",
    n_layers=88,
    d_model=12288,
    d_ff=28672,
    vocab_size=32768,
    attention=AttentionConfig(
        kind="gqa", n_heads=96, n_kv_heads=8, head_dim=128,
        rope_theta=1_000_000.0,
    ),
    norm="rmsnorm",
    activation="silu",
    train_microbatches=8,   # grad-accumulation: 256 -> 8 x 32 (memory knob)
    param_dtype="bfloat16", # bf16 master + f32 adam moments (§Perf iter 4)
    source="hf:mistralai/Mistral-Large-Instruct-2407",
)

SMOKE_CONFIG = replace(
    CONFIG,
    n_layers=2, d_model=64, d_ff=160, vocab_size=256,
    attention=replace(CONFIG.attention, n_heads=8, n_kv_heads=2, head_dim=8),
)
