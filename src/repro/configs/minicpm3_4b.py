"""minicpm3-4b — dense 62L d=2560, 40H MLA, d_ff 6400, vocab 73448.

MLA geometry per hf:openbmb/MiniCPM3-4B: q_lora_rank 768, kv_lora_rank 256,
qk_nope_head_dim 64, qk_rope_head_dim 32, v_head_dim 64.
[hf:openbmb/MiniCPM3-4B; hf]
"""

from dataclasses import replace

from ..models.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    d_ff=6400,
    vocab_size=73448,
    attention=AttentionConfig(
        kind="mla", n_heads=40, n_kv_heads=40, head_dim=64,
        q_lora_rank=768, kv_lora_rank=256,
        qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64,
        rope_theta=10_000.0,
    ),
    norm="rmsnorm",
    activation="silu",
    tie_embeddings=True,
    train_microbatches=8,   # memory: 58 GiB/dev -> fits (EXPERIMENTS §Perf)
    source="hf:openbmb/MiniCPM3-4B",
)

SMOKE_CONFIG = replace(
    CONFIG,
    n_layers=2, d_model=64, d_ff=128, vocab_size=256,
    attention=replace(CONFIG.attention, n_heads=4, n_kv_heads=4, head_dim=16,
                      q_lora_rank=32, kv_lora_rank=16,
                      qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16),
)
