"""olmoe-1b-7b — MoE 16L d=2048, 16H MHA, vocab 50304;
64 experts (d_expert 1024) top-8, no shared experts.
[arXiv:2409.02060; hf]
"""

from dataclasses import replace

from ..models.config import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    d_ff=1024,
    vocab_size=50304,
    attention=AttentionConfig(
        kind="gqa", n_heads=16, n_kv_heads=16, head_dim=128,
        rope_theta=10_000.0,
    ),
    moe=MoEConfig(n_experts=64, top_k=8, n_shared_experts=0, d_expert=1024,
                  capacity_factor=1.25, every=1),
    norm="rmsnorm",
    activation="silu",
    source="arXiv:2409.02060",
)

SMOKE_CONFIG = replace(
    CONFIG,
    n_layers=2, d_model=64, d_ff=32, vocab_size=256,
    attention=replace(CONFIG.attention, n_heads=4, n_kv_heads=4, head_dim=16),
    moe=replace(CONFIG.moe, n_experts=8, top_k=2, d_expert=32),
)
