"""jamba-v0.1-52b — hybrid 32L d=4096: Mamba:attention 7:1 interleave
(1 attention layer per 8, offset 3 as in the release), 32H GQA(kv=8)
d_ff 14336, MoE 16 experts top-2 on every other layer, vocab 65536.
[arXiv:2403.19887; hf]
"""

from dataclasses import replace

from ..models.config import (AttentionConfig, ModelConfig, MoEConfig,
                             SSMConfig)

CONFIG = ModelConfig(
    arch_id="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab_size=65536,
    attention=AttentionConfig(
        kind="gqa", n_heads=32, n_kv_heads=8, head_dim=128,
        rope_theta=10_000.0,
    ),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, chunk=256),
    moe=MoEConfig(n_experts=16, top_k=2, n_shared_experts=0, d_expert=14336,
                  capacity_factor=1.25, every=2),
    attn_period=8,
    attn_offset=3,
    train_microbatches=8,   # memory: 66 GiB/dev -> fits (EXPERIMENTS §Perf)
    norm="rmsnorm",
    activation="silu",
    source="arXiv:2403.19887",
)

SMOKE_CONFIG = replace(
    CONFIG,
    n_layers=8, d_model=64, d_ff=96, vocab_size=256,
    attention=replace(CONFIG.attention, n_heads=4, n_kv_heads=2, head_dim=16),
    ssm=replace(CONFIG.ssm, d_state=4, chunk=8),
    moe=replace(CONFIG.moe, n_experts=4, top_k=2, d_expert=96),
)
