"""h2o-danube-3-4b — dense 24L d=3840, 32H GQA(kv=8), d_ff 10240,
vocab 32000; llama+mistral mix with sliding-window attention (window 4096).
[arXiv:2401.16818; unverified]
"""

from dataclasses import replace

from ..models.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    d_ff=10240,
    vocab_size=32000,
    attention=AttentionConfig(
        kind="gqa", n_heads=32, n_kv_heads=8, head_dim=120,
        window=4096, rope_theta=10_000.0,
    ),
    norm="rmsnorm",
    activation="silu",
    source="arXiv:2401.16818",
)

SMOKE_CONFIG = replace(
    CONFIG,
    n_layers=2, d_model=64, d_ff=128, vocab_size=256,
    attention=replace(CONFIG.attention, n_heads=4, n_kv_heads=2, head_dim=16,
                      window=16),
)
