"""Architecture configs: one module per assigned architecture.

``get_config(arch_id)`` -> full-size ModelConfig (dry-run only).
``get_smoke_config(arch_id)`` -> reduced same-family config (CPU tests).
"""

from importlib import import_module
from typing import List

from ..models.config import ModelConfig

ARCH_IDS = [
    "minicpm3_4b",
    "h2o_danube3_4b",
    "mistral_large_123b",
    "olmo_1b",
    "phi3_vision_4b",
    "deepseek_moe_16b",
    "olmoe_1b_7b",
    "jamba_v01_52b",
    "falcon_mamba_7b",
    "whisper_small",
]

# canonical assignment spelling -> module name
ALIASES = {
    "minicpm3-4b": "minicpm3_4b",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "mistral-large-123b": "mistral_large_123b",
    "olmo-1b": "olmo_1b",
    "phi-3-vision-4.2b": "phi3_vision_4b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "whisper-small": "whisper_small",
}


def _module(arch_id: str):
    name = ALIASES.get(arch_id, arch_id.replace("-", "_").replace(".", "_"))
    return import_module(f"repro.configs.{name}")


def get_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).SMOKE_CONFIG


def all_arch_ids() -> List[str]:
    return list(ARCH_IDS)
