"""whisper-small — enc-dec 12L+12L d=768, 12H MHA, d_ff 3072, vocab 51865;
conv frontend STUB (input_specs feeds 1500 precomputed frame embeddings).
[arXiv:2212.04356; unverified]
"""

from dataclasses import replace

from ..models.config import (AttentionConfig, EncDecConfig, ModelConfig)

CONFIG = ModelConfig(
    arch_id="whisper-small",
    family="audio",
    n_layers=12,                  # decoder layers
    d_model=768,
    d_ff=3072,
    vocab_size=51865,
    attention=AttentionConfig(
        kind="gqa", n_heads=12, n_kv_heads=12, head_dim=64,
    ),
    enc_dec=EncDecConfig(n_encoder_layers=12, encoder_len=1500),
    norm="layernorm",
    activation="gelu",
    tie_embeddings=True,
    max_seq_len=32768,
    train_microbatches=4,   # memory: 28 GiB/dev -> fits (EXPERIMENTS §Perf)
    source="arXiv:2212.04356",
)

SMOKE_CONFIG = replace(
    CONFIG,
    n_layers=2, d_model=64, d_ff=128, vocab_size=256, max_seq_len=64,
    attention=replace(CONFIG.attention, n_heads=4, n_kv_heads=4, head_dim=16),
    enc_dec=EncDecConfig(n_encoder_layers=2, encoder_len=16),
)
