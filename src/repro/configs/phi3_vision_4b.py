"""phi-3-vision-4.2b — VLM: phi3-mini backbone 32L d=3072, 32H MHA,
d_ff 8192, vocab 32064 + CLIP frontend (STUB: input_specs feeds precomputed
patch embeddings; n_patches positions are prepended to the text sequence).
[hf:microsoft/Phi-3-vision-128k-instruct; hf]
"""

from dataclasses import replace

from ..models.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    d_ff=8192,
    vocab_size=32064,
    attention=AttentionConfig(
        kind="gqa", n_heads=32, n_kv_heads=32, head_dim=96,
        rope_theta=10_000.0,
    ),
    norm="rmsnorm",
    activation="silu",
    n_patches=256,          # precomputed patch embeddings (stub frontend)
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)

SMOKE_CONFIG = replace(
    CONFIG,
    n_layers=2, d_model=64, d_ff=128, vocab_size=256, n_patches=8,
    attention=replace(CONFIG.attention, n_heads=4, n_kv_heads=4, head_dim=16),
)
