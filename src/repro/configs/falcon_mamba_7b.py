"""falcon-mamba-7b — pure SSM (mamba-1) 64L d=4096, attention-free,
ssm_state 16, vocab 65024.  Runs the long_500k cell (O(1)/token state).
[arXiv:2410.05355; unverified]
"""

from dataclasses import replace

from ..models.config import AttentionConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    d_ff=0,                      # attention-free, no FFN sublayer width
    vocab_size=65024,
    attention=AttentionConfig(kind="none", n_heads=0, n_kv_heads=0, head_dim=0),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, chunk=256),
    norm="rmsnorm",
    activation="silu",
    source="arXiv:2410.05355",
)

SMOKE_CONFIG = replace(
    CONFIG,
    n_layers=2, d_model=64, vocab_size=256,
    ssm=replace(CONFIG.ssm, d_state=4, chunk=8),
)
