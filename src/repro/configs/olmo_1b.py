"""olmo-1b — dense 16L d=2048, 16H MHA, d_ff 8192, vocab 50304;
non-parametric LayerNorm (no scale/bias, arXiv:2402.00838).
[arXiv:2402.00838; hf]
"""

from dataclasses import replace

from ..models.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    d_ff=8192,
    vocab_size=50304,
    attention=AttentionConfig(
        kind="gqa", n_heads=16, n_kv_heads=16, head_dim=128,
        rope_theta=10_000.0,
    ),
    norm="nonparametric_ln",
    activation="silu",
    tie_embeddings=True,
    source="arXiv:2402.00838",
)

SMOKE_CONFIG = replace(
    CONFIG,
    n_layers=2, d_model=64, d_ff=128, vocab_size=256,
    attention=replace(CONFIG.attention, n_heads=4, n_kv_heads=4, head_dim=16),
)
