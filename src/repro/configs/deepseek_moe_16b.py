"""deepseek-moe-16b — MoE 28L d=2048, 16H MHA, vocab 102400;
fine-grained 64 routed experts (d_expert 1408) top-6 + 2 shared experts.
[arXiv:2401.06066; hf]
"""

from dataclasses import replace

from ..models.config import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    d_ff=1408,                 # == d_expert (fine-grained experts)
    vocab_size=102400,
    attention=AttentionConfig(
        kind="gqa", n_heads=16, n_kv_heads=16, head_dim=128,
        rope_theta=10_000.0,
    ),
    moe=MoEConfig(n_experts=64, top_k=6, n_shared_experts=2, d_expert=1408,
                  capacity_factor=1.25, every=1),
    norm="rmsnorm",
    activation="silu",
    source="arXiv:2401.06066",
)

SMOKE_CONFIG = replace(
    CONFIG,
    n_layers=2, d_model=64, d_ff=48, vocab_size=256,
    attention=replace(CONFIG.attention, n_heads=4, n_kv_heads=4, head_dim=16),
    moe=replace(CONFIG.moe, n_experts=8, top_k=2, n_shared_experts=1,
                d_expert=48),
)
