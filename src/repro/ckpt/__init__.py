"""Fault-tolerant checkpointing: atomic manifests, auto-resume, elastic
re-sharding on restore."""

from .checkpoint import (CheckpointManager, latest_checkpoint, load_pytree,
                         save_pytree)

__all__ = ["CheckpointManager", "save_pytree", "load_pytree",
           "latest_checkpoint"]
