"""Atomic, resumable, elastic checkpoints.

Layout of one checkpoint:

    <dir>/step_000123/
        manifest.json        # leaf index, shapes/dtypes, data-iter state,
                             # mesh shape at save time, framework version
        arr_00000.npy ...    # one .npy per pytree leaf (host-local values)
        COMMIT               # written LAST -> crash-safe atomicity marker

Fault-tolerance contract (DESIGN.md §6):

* **atomic** — a checkpoint without COMMIT is ignored by the loader, so a
  preemption mid-save can never corrupt the restore path;
* **auto-resume** — ``latest_checkpoint`` finds the newest committed step;
* **elastic** — arrays are saved as full logical values (gathered per
  host); ``load_pytree`` re-shards onto whatever mesh/sharding the
  restoring job provides, so a 512-chip job can restore a 256-chip save
  (tested CPU-side in tests/test_ckpt.py with different device counts);
* **bounded retention** — keep the newest ``keep`` checkpoints.
"""

from __future__ import annotations

import json
import shutil
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["save_pytree", "load_pytree", "latest_checkpoint",
           "CheckpointManager"]

COMMIT = "COMMIT"
MANIFEST = "manifest.json"


def _leaf_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out.append((key, leaf))
    return out


def save_pytree(tree, directory: str | Path, step: int,
                extra: Optional[Dict[str, Any]] = None) -> Path:
    """Write one atomic checkpoint; returns its path."""
    directory = Path(directory)
    final = directory / f"step_{step:09d}"
    tmp = directory / f".tmp_step_{step:09d}_{int(time.time()*1e6)}"
    tmp.mkdir(parents=True, exist_ok=True)

    leaves = _leaf_paths(tree)
    index = []
    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"arr_{i:05d}.npy"
        np.save(tmp / fname, arr)
        index.append({"key": key, "file": fname, "shape": list(arr.shape),
                      "dtype": str(arr.dtype)})
    manifest = {"step": step, "index": index, "extra": extra or {},
                "time": time.time(), "version": 1}
    (tmp / MANIFEST).write_text(json.dumps(manifest, indent=1))
    (tmp / COMMIT).write_text("ok")          # commit marker LAST
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                        # atomic on POSIX
    return final


def latest_checkpoint(directory: str | Path) -> Optional[Path]:
    directory = Path(directory)
    if not directory.exists():
        return None
    cands = sorted(p for p in directory.iterdir()
                   if p.name.startswith("step_") and (p / COMMIT).exists())
    return cands[-1] if cands else None


def load_pytree(path: str | Path, like, shardings=None):
    """Restore into the structure of ``like``; if ``shardings`` is given
    (pytree of NamedSharding), device_put each leaf onto it — this is the
    elastic-reshard path (the saved mesh shape is irrelevant)."""
    path = Path(path)
    manifest = json.loads((path / MANIFEST).read_text())
    by_key = {e["key"]: e for e in manifest["index"]}
    leaves = _leaf_paths(like)
    sh_leaves = (_leaf_paths(shardings) if shardings is not None
                 else [(k, None) for k, _ in leaves])
    out = []
    for (key, leaf), (_, sh) in zip(leaves, sh_leaves):
        e = by_key.get(key)
        if e is None:
            raise KeyError(f"checkpoint {path} missing leaf {key!r}")
        arr = np.load(path / e["file"])
        want_dtype = getattr(leaf, "dtype", arr.dtype)
        arr = arr.astype(want_dtype) if str(want_dtype) != e["dtype"] else arr
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    treedef = jax.tree_util.tree_structure(like)
    return treedef.unflatten(out)


def manifest_extra(path: str | Path) -> Dict[str, Any]:
    return json.loads((Path(path) / MANIFEST).read_text())["extra"]


class CheckpointManager:
    """Periodic + on-signal checkpointing with retention and auto-resume."""

    def __init__(self, directory: str | Path, every_steps: int = 100,
                 keep: int = 3):
        self.directory = Path(directory)
        self.every_steps = every_steps
        self.keep = keep

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.every_steps == 0

    def save(self, tree, step: int, extra: Optional[Dict[str, Any]] = None):
        path = save_pytree(tree, self.directory, step, extra)
        self._gc()
        return path

    def restore_or_none(self, like, shardings=None):
        path = latest_checkpoint(self.directory)
        if path is None:
            return None, None
        tree = load_pytree(path, like, shardings)
        return tree, manifest_extra(path)

    def _gc(self) -> None:
        cands = sorted(p for p in self.directory.iterdir()
                       if p.name.startswith("step_") and (p / COMMIT).exists())
        for p in cands[:-self.keep]:
            shutil.rmtree(p, ignore_errors=True)
