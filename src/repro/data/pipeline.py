"""Token data pipeline.

Production posture (DESIGN.md §6):

* **deterministic** — batch ``i`` is a pure function of (seed, step), so a
  restarted job consumes exactly the tokens it would have seen;
* **resumable** — the iterator state is one integer (``step``), stored in
  every checkpoint manifest;
* **per-host sharded** — each host materializes only its slice of the
  global batch (``host_id``/``n_hosts``); the dry-run never allocates
  global arrays;
* **double-buffered** — a background thread prefetches the next batch while
  the step runs (CPU-side overlap).

Two sources: ``synthetic_source`` (zipf-ish token stream, used by tests and
the quickstart) and ``memmap_source`` (flat uint16/uint32 token file, the
deploy path — no tokenization at train time).
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, Dict, Iterator, Optional

import numpy as np

__all__ = ["DataConfig", "TokenPipeline", "synthetic_source", "memmap_source"]


@dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1
    prefetch: int = 2

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


def synthetic_source(cfg: DataConfig) -> Callable[[int], Dict[str, np.ndarray]]:
    """Deterministic synthetic LM batches: tokens[i+1] predicts tokens[i]."""

    def batch_at(step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.host_id]))
        # zipf-flavored marginal over the vocab (heavier head, long tail)
        z = rng.zipf(1.3, size=(cfg.host_batch, cfg.seq_len + 1))
        toks = (z % cfg.vocab_size).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    return batch_at


def memmap_source(cfg: DataConfig, path: str | Path,
                  dtype=np.uint16) -> Callable[[int], Dict[str, np.ndarray]]:
    """Flat token-file source; step/host determine the window (epoch wraps)."""
    data = np.memmap(path, dtype=dtype, mode="r")
    tokens_per_batch = cfg.host_batch * (cfg.seq_len + 1)
    n_windows = max(1, (len(data) - 1) // tokens_per_batch)

    def batch_at(step: int) -> Dict[str, np.ndarray]:
        w = (step * cfg.n_hosts + cfg.host_id) % n_windows
        flat = np.asarray(data[w * tokens_per_batch:(w + 1) * tokens_per_batch])
        toks = flat.reshape(cfg.host_batch, cfg.seq_len + 1).astype(np.int32)
        toks %= cfg.vocab_size
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    return batch_at


class TokenPipeline:
    """Resumable prefetching iterator over a deterministic batch function."""

    def __init__(self, cfg: DataConfig,
                 source: Optional[Callable[[int], Dict[str, np.ndarray]]] = None,
                 start_step: int = 0):
        self.cfg = cfg
        self.source = source or synthetic_source(cfg)
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=max(1, cfg.prefetch))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        s = self.step
        while not self._stop.is_set():
            try:
                self._q.put((s, self.source(s)), timeout=0.2)
                s += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        s, batch = self._q.get()
        self.step = s + 1  # checkpointable state: next step to consume
        return batch

    def state(self) -> Dict[str, int]:
        return {"step": self.step}

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=1.0)
