"""Data pipeline: deterministic, resumable, per-host sharded token streams."""

from .pipeline import (DataConfig, TokenPipeline, memmap_source,
                       synthetic_source)

__all__ = ["DataConfig", "TokenPipeline", "synthetic_source", "memmap_source"]
