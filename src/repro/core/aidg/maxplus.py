"""Max-plus evaluation of the AIDG in JAX (the TPU-native adaptation).

Two evaluators of the same recurrence  t_i = w_i + max(base_i, max_j (t_j + d_ji)):

* ``longest_path_scan`` — exact forward pass as a ``jax.lax.scan`` over
  nodes with padded predecessor gathers.  Differentiable in the latency
  parameters and ``vmap``-able over parameter batches (the DSE fast path).
* ``longest_path_blocked`` — the AIDG adjacency banded into dense blocks;
  each block solved by the max-plus Kleene closure  t_b = M*_b ⊗ h_b  with
  M* computed by repeated max-plus squaring — the matmul-shaped formulation
  the ``repro.kernels.maxplus`` Pallas kernel accelerates on the MXU-aligned
  layout (max/add on the VPU instead of mul/add on the MXU).

The storage request-slot queueing (arrival-ordered service, Figs. 12/13) is
``slot_queue_scan``: per storage, accesses sorted by arrival relax against a
sorted slot vector via ``lax.scan`` — also vmappable over parameters.
"""

from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .builder import AIDG

__all__ = [
    "longest_path_scan",
    "longest_path_blocked",
    "slot_queue_scan",
    "fixed_point_jax",
    "fixed_point_batch",
]

NEG = -1e18


@partial(jax.jit, static_argnames=("n",))
def _scan_impl(n: int, work: jnp.ndarray, base: jnp.ndarray,
               preds: jnp.ndarray, pred_extra: jnp.ndarray) -> jnp.ndarray:
    """t_i = w_i + max(base_i, max_k t[preds_ik] + extra_ik), forward order."""

    def step(t, i):
        js = preds[i]
        vals = jnp.where(js >= 0, t[jnp.maximum(js, 0)] + pred_extra[i], NEG)
        m = jnp.maximum(base[i], vals.max())
        t = t.at[i].set(m + work[i])
        return t, ()

    t0 = jnp.zeros((n,), dtype=jnp.float32)
    t, _ = jax.lax.scan(step, t0, jnp.arange(n))
    return t


def longest_path_scan(aidg: AIDG, work: Optional[jnp.ndarray] = None,
                      base: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    w = jnp.asarray(aidg.work if work is None else work, jnp.float32)
    b = jnp.asarray(aidg.base if base is None else base, jnp.float32)
    return _scan_impl(aidg.n, w, b, jnp.asarray(aidg.preds),
                      jnp.asarray(aidg.pred_extra))


# ---------------------------------------------------------------------------
# blocked max-plus closure evaluation
# ---------------------------------------------------------------------------


def _block_matrices(aidg: AIDG, block: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Dense per-block edge matrices.

    Returns (M_diag, M_sub, far_mask) where for each block b:
    ``M_diag[b][i, j]`` is the weight of edge (local j -> local i) inside the
    block (-inf if absent) *with w_i absorbed* (m_ij = d_ij + w_i), and
    ``M_sub[b][i, j]`` the edges from the previous block.  Edges reaching
    further back are returned as an explicit gather list folded into h.
    """
    n = aidg.n
    nb = (n + block - 1) // block
    Md = np.full((nb, block, block), NEG, dtype=np.float32)
    Ms = np.full((nb, block, block), NEG, dtype=np.float32)
    far: Dict[Tuple[int, int], float] = {}
    for i in range(n):
        bi, li = divmod(i, block)
        for k in range(aidg.preds.shape[1]):
            j = int(aidg.preds[i, k])
            if j < 0:
                break
            wgt = float(aidg.pred_extra[i, k]) + float(aidg.work[i])
            bj, lj = divmod(j, block)
            if bj == bi:
                Md[bi, li, lj] = max(Md[bi, li, lj], wgt)
            elif bj == bi - 1:
                Ms[bi, li, lj] = max(Ms[bi, li, lj], wgt)
            else:
                far[(i, j)] = max(far.get((i, j), NEG), wgt)
    far_arr = np.asarray([(i, j, w) for (i, j), w in far.items()],
                         dtype=np.float64).reshape(-1, 3)
    return Md, Ms, far_arr


def maxplus_matmul_jnp(A: jnp.ndarray, B: jnp.ndarray) -> jnp.ndarray:
    """(A ⊗ B)_ij = max_k A_ik + B_kj (pure-jnp reference path)."""
    return jnp.max(A[..., :, :, None] + B[..., None, :, :], axis=-2)


def maxplus_closure(M: jnp.ndarray, steps: int,
                    matmul=maxplus_matmul_jnp) -> jnp.ndarray:
    """Kleene star M* = (I ⊕ M)^(2^steps) by repeated max-plus squaring."""
    n = M.shape[-1]
    eye = jnp.where(jnp.eye(n, dtype=bool), 0.0, NEG)
    P = jnp.maximum(M, eye)
    for _ in range(steps):
        P = jnp.maximum(P, matmul(P, P))
    return P


def longest_path_blocked(aidg: AIDG, block: int = 128,
                         matmul=maxplus_matmul_jnp) -> np.ndarray:
    """Block-sequential evaluation: for each block b,
    h_b = max(base+w, far-edge gathers, M_sub ⊗ t_{b-1}), t_b = M*_bb ⊗ h_b."""
    n = aidg.n
    nb = (n + block - 1) // block
    Md, Ms, far = _block_matrices(aidg, block)
    steps = int(np.ceil(np.log2(max(2, block))))
    closures = jax.vmap(lambda M: maxplus_closure(M, steps, matmul))(
        jnp.asarray(Md))
    Ms_j = jnp.asarray(Ms)

    pad = nb * block - n
    base = np.pad(aidg.base.astype(np.float32), (0, pad), constant_values=NEG)
    work = np.pad(aidg.work.astype(np.float32), (0, pad), constant_values=0.0)
    h0 = (base + work).reshape(nb, block)

    t = np.full(nb * block, NEG, dtype=np.float32)
    mv = jax.jit(lambda M, v: jnp.max(M + v[None, :], axis=1))
    for b in range(nb):
        h = np.asarray(h0[b])
        if b > 0:
            prev = jnp.asarray(t[(b - 1) * block: b * block])
            h = np.maximum(h, np.asarray(mv(Ms_j[b], prev)))
        # far edges into this block (targets i in b, sources already final)
        for i, j, wgt in far:
            i = int(i)
            if i // block == b:
                li = i % block
                h[li] = max(h[li], t[int(j)] + wgt)
        tb = np.asarray(mv(closures[b], jnp.asarray(h)))
        # closure includes the identity, so h itself is included
        t[b * block: (b + 1) * block] = tb
    return t[:n].astype(np.float64)


# ---------------------------------------------------------------------------
# storage request-slot queueing in jnp (vmappable)
# ---------------------------------------------------------------------------


def slot_queue_scan(arrival: jnp.ndarray, lat: jnp.ndarray, slots: int
                    ) -> jnp.ndarray:
    """Service completion per access, arrival-ordered FIFO over ``slots``
    request slots.  ``arrival``/``lat`` are in *arrival order*."""

    def step(slot_free, inp):
        arr, l = inp
        begin = jnp.maximum(arr, slot_free[0])
        done = begin + l
        slot_free = jnp.sort(slot_free.at[0].set(done))
        return slot_free, done

    init = jnp.zeros((slots,), dtype=jnp.float32)
    _, done = jax.lax.scan(step, init, (arrival, lat))
    return done


def fixed_point_jax(aidg: AIDG, n_iters: int = 3,
                    work: Optional[jnp.ndarray] = None,
                    base: Optional[jnp.ndarray] = None,
                    storage_lat: Optional[Dict[str, jnp.ndarray]] = None,
                    ) -> jnp.ndarray:
    """JAX version of ``builder.longest_path_fixed_point`` — jit/vmap-able
    over (work, base, storage latencies) for design-space exploration."""
    w = jnp.asarray(aidg.work if work is None else work, jnp.float32)
    b0 = jnp.asarray(aidg.base if base is None else base, jnp.float32)
    preds = jnp.asarray(aidg.preds)
    extra = jnp.asarray(aidg.pred_extra)
    fu_lat = jnp.asarray(aidg.fu_lat, jnp.float32)
    n = aidg.n

    t = _scan_impl(n, w, b0, preds, extra)
    if not aidg.storage_nodes:
        return t
    for _ in range(n_iters):
        b = b0
        for st_name, nodes in aidg.storage_nodes.items():
            lats = jnp.asarray(
                aidg.storage_lat[st_name] if storage_lat is None
                else storage_lat[st_name], jnp.float32)
            nd = jnp.asarray(nodes)
            slots = aidg.storage_slots[st_name]
            arrival = t[nd] - w[nd]
            order = jnp.argsort(arrival)
            done = slot_queue_scan(arrival[order], lats[order], slots)
            need = done + fu_lat[nd[order]] - w[nd[order]]
            b = b.at[nd[order]].max(need)
        t = _scan_impl(n, w, b, preds, extra)
    return t


def fixed_point_batch(aidg: AIDG, works: Optional[jnp.ndarray] = None,
                      bases: Optional[jnp.ndarray] = None,
                      storage_lats: Optional[Dict[str, jnp.ndarray]] = None,
                      n_iters: int = 3) -> jnp.ndarray:
    """Batched ``fixed_point_jax``: any of ``works`` (B, n), ``bases``
    (B, n), ``storage_lats`` {name: (B, k)} may carry a leading batch axis;
    omitted inputs broadcast from the AIDG baseline.  Returns (B, n)
    completion times in one vmapped device launch — the raw-latency-space
    counterpart of ``dse.sweep`` (which batches multiplicative θ factors).
    """
    batched = [x for x in (works, bases) if x is not None]
    if storage_lats is not None:
        unknown = set(storage_lats) - set(aidg.storage_lat)
        if unknown:
            raise KeyError(f"unknown storage(s) {sorted(unknown)}; "
                           f"AIDG has {sorted(aidg.storage_lat)}")
        batched.extend(storage_lats.values())
    if not batched:
        raise ValueError("fixed_point_batch needs at least one batched input")
    shapes = [np.shape(x) for x in batched]
    if any(len(s) != 2 for s in shapes) or len({s[0] for s in shapes}) != 1:
        raise ValueError(f"batched inputs must be 2-D with one shared "
                         f"leading batch dim, got shapes {shapes}")
    B = batched[0].shape[0]
    w = (jnp.broadcast_to(jnp.asarray(aidg.work, jnp.float32), (B, aidg.n))
         if works is None else jnp.asarray(works, jnp.float32))
    b = (jnp.broadcast_to(jnp.asarray(aidg.base, jnp.float32), (B, aidg.n))
         if bases is None else jnp.asarray(bases, jnp.float32))
    sl = {name: (jnp.broadcast_to(jnp.asarray(lat, jnp.float32),
                                  (B, len(lat)))
                 if storage_lats is None or name not in storage_lats
                 else jnp.asarray(storage_lats[name], jnp.float32))
          for name, lat in aidg.storage_lat.items()}

    def one(w_, b_, sl_):
        return fixed_point_jax(aidg, n_iters=n_iters, work=w_, base=b_,
                               storage_lat=sl_)

    return jax.vmap(one)(w, b, sl)
