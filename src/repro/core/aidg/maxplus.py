"""Max-plus evaluation of the AIDG in JAX (the TPU-native adaptation).

Three engines for the same recurrence  t_i = w_i + max(base_i, max_j (t_j + d_ji)),
all consuming the build-time ``CompiledAIDG`` artifact
(trace → AIDG → LevelSchedule → CompiledAIDG, see ``builder.compile_aidg``):

* ``longest_path_wavefront`` — the default: a ``jax.lax.scan`` over
  topological *levels* with vectorized predecessor gathers and a max over
  the predecessor axis inside each level.  Sequential depth is the DAG's
  critical depth (``LevelSchedule.n_levels``), typically far smaller than
  the node count — the compiled-estimator payoff of Lübeck et al. 2024.
* ``longest_path_scan`` — exact forward pass as a ``lax.scan`` over nodes
  (one sequential step per instruction); kept as the reference device path.
* ``longest_path_blocked`` — the AIDG adjacency banded into dense blocks;
  each block solved by the max-plus Kleene closure  t_b = M*_b ⊗ h_b  with
  M* computed by repeated max-plus squaring, the whole block recurrence a
  single device-resident ``lax.scan``.  ``matmul=maxplus_matmul_pallas``
  routes every ⊗ through the ``repro.kernels.maxplus`` Pallas kernel
  (max/add on the VPU in the MXU-aligned layout).

All three are differentiable in the latency parameters and ``vmap``-able
over parameter batches; ``fixed_point_jax(engine=...)`` selects the
relaxation used between storage-queueing folds, and ``fixed_point_batch``
vmaps the whole fixed point.

The storage request-slot queueing (arrival-ordered service, Figs. 12/13) is
``slot_queue_scan``: per storage, accesses sorted by arrival relax against a
sorted slot vector via ``lax.scan`` — also vmappable over parameters.

**The smooth relaxation family** (gradient-based co-design, §1/§7): every
hard ``max`` above is piecewise-linear in the latency parameters, so
``jax.grad`` returns a subgradient that is blind across kinks and dead on
plateaus.  ``longest_path_soft`` / ``slot_queue_soft`` / ``fixed_point_soft``
replace each ``max`` with the temperature-τ log-sum-exp

    softmax_τ(x₁, …, x_K) = τ · log Σ_k exp(x_k / τ)
                          ∈ [max_k x_k,  max_k x_k + τ·log K]

which is smooth everywhere, monotone in every argument, and recovers the
exact wavefront result as τ → 0 (the overestimate is at most τ·log K per
reduction, K = in-degree + 1).  τ is a *traced* scalar, so annealing it
inside an optimization loop never re-traces the compiled evaluator —
``repro.core.aidg.gradient`` builds projected Adam on top of this.
"""

from __future__ import annotations

from functools import partial
from typing import Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from .builder import AIDG, CompiledAIDG, CondensedAIDG, NEG, compile_aidg, \
    condense_aidg

__all__ = [
    "ENGINES",
    "DEFAULT_ENGINE",
    "longest_path_wavefront",
    "longest_path_scan",
    "longest_path_blocked",
    "longest_path_condensed",
    "condensed_prefix",
    "condensed_scan",
    "slot_queue_scan",
    "fixed_point_jax",
    "fixed_point_batch",
    "maxplus_matmul_jnp",
    "maxplus_closure",
    "softmaximum",
    "softmax_reduce",
    "longest_path_soft",
    "slot_queue_soft",
    "fixed_point_soft",
]

# NEG (the max-plus -inf sentinel) is defined once in builder and
# re-exported here — condense_aidg writes it into coupling tables that the
# evaluators compare against, so there must be exactly one definition

ENGINES = ("wavefront", "scan", "blocked", "condensed")
DEFAULT_ENGINE = "wavefront"

AIDGLike = Union[AIDG, CompiledAIDG]


def _as_compiled(aidg: AIDGLike) -> CompiledAIDG:
    return aidg if isinstance(aidg, CompiledAIDG) else compile_aidg(aidg)


# ---------------------------------------------------------------------------
# per-node scan evaluation (reference device path)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("n",))
def _scan_impl(n: int, work: jnp.ndarray, base: jnp.ndarray,
               preds: jnp.ndarray, pred_extra: jnp.ndarray) -> jnp.ndarray:
    """t_i = w_i + max(base_i, max_k t[preds_ik] + extra_ik), forward order."""

    def step(t, i):
        js = preds[i]
        vals = jnp.where(js >= 0, t[jnp.maximum(js, 0)] + pred_extra[i], NEG)
        m = jnp.maximum(base[i], vals.max())
        t = t.at[i].set(m + work[i])
        return t, ()

    t0 = jnp.zeros((n,), dtype=jnp.float32)
    t, _ = jax.lax.scan(step, t0, jnp.arange(n))
    return t


def longest_path_scan(aidg: AIDGLike, work: Optional[jnp.ndarray] = None,
                      base: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Exact forward relaxation as a ``lax.scan`` over nodes (one
    sequential step per instruction) — the reference device path the
    wavefront and blocked engines are checked against."""
    ca = _as_compiled(aidg)
    a = ca.aidg
    w = jnp.asarray(a.work if work is None else work, jnp.float32)
    b = jnp.asarray(a.base if base is None else base, jnp.float32)
    return _scan_impl(a.n, w, b, jnp.asarray(a.preds),
                      jnp.asarray(a.pred_extra))


# ---------------------------------------------------------------------------
# level-scheduled wavefront evaluation (the default engine)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("n", "width"))
def _wavefront_impl(n: int, width: int, work: jnp.ndarray, base: jnp.ndarray,
                    preds_lv: jnp.ndarray, extra_lv: jnp.ndarray,
                    starts: jnp.ndarray, order: jnp.ndarray,
                    rank: jnp.ndarray) -> jnp.ndarray:
    """One ``lax.scan`` step per *level* over the level-major renumbering:
    each step slices a contiguous ``width`` window of (preds, extra, work,
    base), gathers the (strictly shallower, already-final) predecessor
    times, reduces over the predecessor axis, and writes the window back
    with one dynamic-update-slice — no scatters.  Window lanes that spill
    past the level's true extent compute garbage from not-yet-final inputs
    and are deterministically overwritten when their own level runs."""
    work_lv = jnp.concatenate(
        [work.astype(jnp.float32)[order], jnp.zeros((width,), jnp.float32)])
    base_lv = jnp.concatenate(
        [base.astype(jnp.float32)[order], jnp.full((width,), NEG, jnp.float32)])
    p = preds_lv.shape[1]

    def step(t, start):
        js = jax.lax.dynamic_slice(preds_lv, (start, 0), (width, p))
        ex = jax.lax.dynamic_slice(extra_lv, (start, 0), (width, p))
        wv = jax.lax.dynamic_slice(work_lv, (start,), (width,))
        bv = jax.lax.dynamic_slice(base_lv, (start,), (width,))
        vals = jnp.where(js >= 0, t[jnp.maximum(js, 0)] + ex, NEG)
        m = jnp.maximum(bv, vals.max(axis=1))
        t = jax.lax.dynamic_update_slice(t, m + wv, (start,))
        return t, ()

    t0 = jnp.zeros((n + width,), dtype=jnp.float32)
    t, _ = jax.lax.scan(step, t0, starts)
    return t[rank]


def longest_path_wavefront(aidg: AIDGLike,
                           work: Optional[jnp.ndarray] = None,
                           base: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Exact longest path in ``n_levels`` sequential device steps (vs ``n``
    for ``longest_path_scan``) — identical results, the wavefront order is
    just a parallel schedule of the same relaxation."""
    ca = _as_compiled(aidg)
    a = ca.aidg
    s = ca.schedule
    w = jnp.asarray(a.work if work is None else work, jnp.float32)
    b = jnp.asarray(a.base if base is None else base, jnp.float32)
    return _wavefront_impl(a.n, s.width, w, b, jnp.asarray(ca.preds_lv),
                           jnp.asarray(ca.extra_lv), jnp.asarray(s.starts),
                           jnp.asarray(s.order), jnp.asarray(s.rank))


# ---------------------------------------------------------------------------
# condensed wavefront evaluation (chain super-edges, sequential depth =
# the CONDENSED critical depth)
# ---------------------------------------------------------------------------


def condensed_prefix(cond: CondensedAIDG, w: jnp.ndarray) -> jnp.ndarray:
    """(n_ab,) inclusive prefix weights of every absorbed node: the exact
    θ-reweighted super-edge dot product ``Σ_prefix (edge extra + w_i)``,
    one ``cumsum`` + two gathers (segment boundaries are static)."""
    aw = w[jnp.asarray(cond.absorbed)] + jnp.asarray(cond.ab_const)
    tot0 = jnp.concatenate([jnp.zeros((1,), aw.dtype), jnp.cumsum(aw)])
    pos = jnp.arange(cond.n_absorbed)
    return tot0[pos + 1] - tot0[jnp.asarray(cond.ab_segstart)]


def condensed_scan(w_perm: jnp.ndarray, b_perm: jnp.ndarray,
                   extra_lv: jnp.ndarray, v_lv: jnp.ndarray,
                   preds_lv: jnp.ndarray, starts: jnp.ndarray,
                   tau=None, has_chains: bool = True) -> jnp.ndarray:
    """The condensed wavefront: one ``lax.scan`` step per UNIT level.  Each
    step gathers the (already-final) cross-unit predecessor times, reduces
    with the window's base, and then resolves every affine chain inside
    the window closed-form with one ``associative_scan`` of the max-plus
    affine composition

        (v₁, h₁) ∘ (v₂, h₂) = (v₁ + v₂, max(h₁ + v₂, h₂))

    (the τ-soft family composes under the SAME operator with
    ``softmaximum`` — smooth chains stay one associative scan).  ``v_lv``
    is the per-permuted-slot coupling weight (NEG = chain break), already
    including the target's own work; everything is in the level-major
    permuted layout of ``builder.condense_aidg``.  ``has_chains=False``
    (a trace-time constant) skips the affine scan entirely for graphs
    with no coupled nodes — the step then reduces to the plain wavefront."""
    NK = w_perm.shape[0]
    W = preds_lv.shape[0] - NK
    P = preds_lv.shape[1]
    work_pad = jnp.concatenate([w_perm, jnp.zeros((W,), jnp.float32)])
    base_pad = jnp.concatenate([b_perm, jnp.full((W,), NEG, jnp.float32)])

    def op(a, c):
        va, ha = a
        vb, hb = c
        if tau is None:
            h = jnp.maximum(ha + vb, hb)
        else:
            h = softmaximum(ha + vb, hb, tau)
        return jnp.maximum(va + vb, NEG), h

    def step(t, start):
        js = jax.lax.dynamic_slice(preds_lv, (start, 0), (W, P))
        ex = jax.lax.dynamic_slice(extra_lv, (start, 0), (W, P))
        wv = jax.lax.dynamic_slice(work_pad, (start,), (W,))
        bv = jax.lax.dynamic_slice(base_pad, (start,), (W,))
        vv = jax.lax.dynamic_slice(v_lv, (start,), (W,))
        vals = jnp.where(js >= 0, t[jnp.maximum(js, 0)] + ex, NEG)
        # compose the reductions instead of concatenating (LSE composes
        # exactly: lse(b, v₁..v_k) = lse(b, lse(v)) — and the fused
        # gather→where→reduce chain avoids materializing a (W, P+1) buffer)
        if tau is None:
            r = jnp.maximum(bv, vals.max(axis=1))
        else:
            r = softmaximum(bv, softmax_reduce(vals, tau, axis=1), tau)
        if has_chains:
            _, tw = jax.lax.associative_scan(op, (vv, r + wv))
        else:
            tw = r + wv
        return jax.lax.dynamic_update_slice(t, tw, (start,)), ()

    t0 = jnp.zeros((NK + W,), dtype=jnp.float32)
    t, _ = jax.lax.scan(step, t0, starts)
    return t[:NK]


def _condensed_relax(cond: CondensedAIDG, w: jnp.ndarray, b: jnp.ndarray,
                     tau=None) -> jnp.ndarray:
    """Condensed relaxation returning the FULL (n,) completion-time vector:
    kept nodes via the unit-level (soft) wavefront with in-window affine
    chains, absorbed nodes reconstructed as anchor + exact prefix sum.
    ``tau`` None = hard max; a traced scalar = the smooth LSE family
    (absorbed steps and chain couplings keep their exact sums — a tighter
    relaxation than softening every per-node max)."""
    kept_perm = jnp.asarray(cond.kept_perm)
    wk = w[kept_perm].astype(jnp.float32)
    bk = b[kept_perm].astype(jnp.float32)
    W = cond.schedule.width
    vc = jnp.asarray(cond.v_const_lv)
    coupled = vc > NEG / 2
    w_pad = jnp.concatenate([wk, jnp.zeros((W,), jnp.float32)])
    if cond.n_absorbed:
        prefix = condensed_prefix(cond, w.astype(jnp.float32))
        pidx = jnp.asarray(cond.pidx_lv)
        extra = (jnp.asarray(cond.const_lv)
                 + jnp.where(pidx >= 0, prefix[jnp.maximum(pidx, 0)], 0.0))
        vp = jnp.asarray(cond.v_pidx_lv)
        vpre = jnp.where(vp >= 0, prefix[jnp.maximum(vp, 0)], 0.0)
    else:
        extra = jnp.asarray(cond.const_lv)
        vpre = 0.0
    v_lv = jnp.where(coupled, vc + vpre + w_pad, NEG)
    tk = condensed_scan(wk, bk, extra, v_lv, jnp.asarray(cond.preds_lv),
                        jnp.asarray(cond.schedule.starts), tau=tau,
                        has_chains=cond.stats["n_coupled"] > 0)
    t = jnp.zeros((cond.n,), jnp.float32).at[kept_perm].set(tk)
    if cond.n_absorbed:
        t = t.at[jnp.asarray(cond.absorbed)].set(
            tk[jnp.asarray(cond.ab_anchor_perm)] + prefix)
    return t


def longest_path_condensed(aidg: AIDGLike,
                           work: Optional[jnp.ndarray] = None,
                           base: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Exact longest path in ``levels_condensed`` sequential device steps:
    chain interiors are folded into θ-parametric super-edges
    (``builder.condense_aidg``), so chain-dominated graphs lose most of
    their sequential scan length.  Identical to ``longest_path_wavefront``
    for any work vector with the ≥ 1-cycle floor (all shipped evaluators)."""
    ca = _as_compiled(aidg)
    a = ca.aidg
    cond = condense_aidg(a)
    w = jnp.asarray(a.work if work is None else work, jnp.float32)
    b = jnp.asarray(a.base if base is None else base, jnp.float32)
    return _condensed_relax(cond, w, b)


# ---------------------------------------------------------------------------
# blocked max-plus closure evaluation (device-resident)
# ---------------------------------------------------------------------------


def maxplus_matmul_jnp(A: jnp.ndarray, B: jnp.ndarray) -> jnp.ndarray:
    """(A ⊗ B)_ij = max_k A_ik + B_kj (pure-jnp reference path)."""
    return jnp.max(A[..., :, :, None] + B[..., None, :, :], axis=-2)


def maxplus_closure(M: jnp.ndarray, steps: int,
                    matmul=maxplus_matmul_jnp) -> jnp.ndarray:
    """Kleene star M* = (I ⊕ M)^(2^steps) by repeated max-plus squaring."""
    n = M.shape[-1]
    eye = jnp.where(jnp.eye(n, dtype=bool), 0.0, NEG)
    P = jnp.maximum(M, eye)
    for _ in range(steps):
        P = jnp.maximum(P, matmul(P, P))
    return P


def _blocked_structure(ca: CompiledAIDG, block: int) -> Tuple[np.ndarray, ...]:
    """Banded structure-only edge matrices, cached per block size on the
    CompiledAIDG.

    Returns (D_diag, D_sub, far_src, far_dst, far_w): per block b,
    ``D_diag[b][i, j]`` is the extra delay of edge (local j -> local i)
    inside the block (NEG if absent) *without* w_i (runtime work is folded
    at eval so the blocked engine stays θ-reweightable), ``D_sub`` the same
    for edges from the previous block, and the ``far_*`` arrays a padded
    per-block gather list for edges reaching further back (pad: weight NEG,
    dst ``block`` — a scratch slot)."""
    hit = ca._block_cache.get(block)
    if hit is not None:
        return hit
    a = ca.aidg
    n = a.n
    nb = max(1, (n + block - 1) // block)
    Dd = np.full((nb, block, block), NEG, dtype=np.float32)
    Ds = np.full((nb, block, block), NEG, dtype=np.float32)
    far: Dict[int, list] = {b: [] for b in range(nb)}
    for i in range(n):
        bi, li = divmod(i, block)
        for k in range(a.preds.shape[1]):
            j = int(a.preds[i, k])
            if j < 0:
                break
            d = float(a.pred_extra[i, k])
            bj, lj = divmod(j, block)
            if bj == bi:
                Dd[bi, li, lj] = max(Dd[bi, li, lj], d)
            elif bj == bi - 1:
                Ds[bi, li, lj] = max(Ds[bi, li, lj], d)
            else:
                far[bi].append((j, li, d))
    F = max(1, max(len(v) for v in far.values()))
    far_src = np.zeros((nb, F), dtype=np.int32)
    far_dst = np.full((nb, F), block, dtype=np.int32)
    far_w = np.full((nb, F), NEG, dtype=np.float32)
    for b, lst in far.items():
        for k, (j, li, d) in enumerate(lst):
            far_src[b, k] = j
            far_dst[b, k] = li
            far_w[b, k] = d
    out = (Dd, Ds, far_src, far_dst, far_w)
    ca._block_cache[block] = out
    return out


@partial(jax.jit, static_argnames=("n", "block", "matmul"))
def _blocked_core(n: int, block: int, Dd: jnp.ndarray, Ds: jnp.ndarray,
                  far_src: jnp.ndarray, far_dst: jnp.ndarray,
                  far_w: jnp.ndarray, work: jnp.ndarray, base: jnp.ndarray,
                  matmul: Callable = maxplus_matmul_jnp) -> jnp.ndarray:
    """Device-resident block recurrence: for each block b,
    h_b = max(base+w, far-edge gathers, M_sub ⊗ t_{b-1}), t_b = M*_bb ⊗ h_b,
    the whole loop one ``lax.scan`` (carry: the global t vector)."""
    nb = Dd.shape[0]
    pad = nb * block - n
    w_p = jnp.concatenate(
        [work.astype(jnp.float32), jnp.zeros((pad,), jnp.float32)])
    b_p = jnp.concatenate(
        [base.astype(jnp.float32), jnp.full((pad,), NEG, jnp.float32)])
    wb = w_p.reshape(nb, block)
    h0 = (b_p + w_p).reshape(nb, block)
    steps = int(np.ceil(np.log2(max(2, block))))
    # absorb runtime work into edge weights: m_ij = d_ij + w_i (target row)
    Md = Dd + wb[:, :, None]
    Ms = Ds + wb[:, :, None]
    closures = jax.vmap(lambda M: maxplus_closure(M, steps, matmul))(Md)

    def step(t, inp):
        bi, clo, Ms_b, w_b, fs, fd, fwgt, h_b = inp
        start = jnp.maximum(bi - 1, 0) * block
        prev = jax.lax.dynamic_slice(t, (start,), (block,))
        # block 0 has an all-NEG Ms_b, so the (garbage) prev is masked out
        h = jnp.maximum(h_b, matmul(Ms_b, prev[:, None])[:, 0])
        w_pad = jnp.concatenate([w_b, jnp.zeros((1,), jnp.float32)])
        contrib = t[fs] + fwgt + w_pad[fd]        # pad rows: + NEG, inert
        h = jnp.concatenate([h, jnp.full((1,), NEG, jnp.float32)])
        h = h.at[fd].max(contrib)[:block]
        tb = matmul(clo, h[:, None])[:, 0]        # closure includes identity
        t = jax.lax.dynamic_update_slice(t, tb, (bi * block,))
        return t, ()

    t0 = jnp.full((nb * block,), NEG, dtype=jnp.float32)
    t, _ = jax.lax.scan(
        step, t0, (jnp.arange(nb), closures, Ms, wb, far_src, far_dst, far_w,
                   h0))
    return t[:n]


def longest_path_blocked(aidg: AIDGLike, block: int = 128,
                         matmul: Callable = maxplus_matmul_jnp,
                         work: Optional[jnp.ndarray] = None,
                         base: Optional[jnp.ndarray] = None) -> np.ndarray:
    """Fully device-resident blocked evaluation (one ``lax.scan`` over
    blocks).  Pass ``matmul=repro.kernels.maxplus.maxplus_matmul_pallas`` to
    run every max-plus ⊗ through the Pallas kernel."""
    ca = _as_compiled(aidg)
    a = ca.aidg
    Dd, Ds, fs, fd, fw = _blocked_structure(ca, block)
    w = jnp.asarray(a.work if work is None else work, jnp.float32)
    b = jnp.asarray(a.base if base is None else base, jnp.float32)
    t = _blocked_core(a.n, block, jnp.asarray(Dd), jnp.asarray(Ds),
                      jnp.asarray(fs), jnp.asarray(fd), jnp.asarray(fw),
                      w, b, matmul=matmul)
    return np.asarray(t, dtype=np.float64)


# ---------------------------------------------------------------------------
# engine dispatch
# ---------------------------------------------------------------------------


def _relaxer(ca: CompiledAIDG, engine: str, block: int = 128
             ) -> Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]:
    """(work, base) -> t closure for the chosen engine, structure arrays
    bound once (they are jit-constant across a sweep)."""
    a = ca.aidg
    if engine == "wavefront":
        s = ca.schedule
        pl, el = jnp.asarray(ca.preds_lv), jnp.asarray(ca.extra_lv)
        st = jnp.asarray(s.starts)
        od, rk = jnp.asarray(s.order), jnp.asarray(s.rank)
        return lambda w, b: _wavefront_impl(a.n, s.width, w, b, pl, el, st,
                                            od, rk)
    if engine == "scan":
        preds = jnp.asarray(a.preds)
        extra = jnp.asarray(a.pred_extra)
        return lambda w, b: _scan_impl(a.n, w, b, preds, extra)
    if engine == "blocked":
        Dd, Ds, fs, fd, fw = _blocked_structure(ca, block)
        Dd, Ds = jnp.asarray(Dd), jnp.asarray(Ds)
        fs, fd, fw = jnp.asarray(fs), jnp.asarray(fd), jnp.asarray(fw)
        return lambda w, b: _blocked_core(a.n, block, Dd, Ds, fs, fd, fw,
                                          w, b)
    if engine == "condensed":
        cond = condense_aidg(a)
        return lambda w, b: _condensed_relax(cond, w, b)
    raise ValueError(f"unknown engine {engine!r}; choose from {ENGINES}")


# ---------------------------------------------------------------------------
# storage request-slot queueing in jnp (vmappable)
# ---------------------------------------------------------------------------


def slot_queue_scan(arrival: jnp.ndarray, lat: jnp.ndarray, slots: int
                    ) -> jnp.ndarray:
    """Service completion per access, arrival-ordered FIFO over ``slots``
    request slots.  ``arrival``/``lat`` are in *arrival order*.

    A single-slot queue is max-plus *linear*:
    ``done_k = max(arrival_k, done_{k-1}) + lat_k`` unrolls to
    ``done_k = S_k + max_{j<=k} (arrival_j - S_{j-1})`` with S the latency
    prefix sum — one ``cumsum`` + one ``cummax`` instead of k sequential
    scan steps.  Multi-slot queues keep the sorted-slot-vector scan (the
    min over slot frees breaks max-plus linearity)."""
    if slots == 1:
        S = jnp.cumsum(lat)
        return S + jax.lax.cummax(arrival - S + lat)

    def step(slot_free, inp):
        arr, l = inp
        begin = jnp.maximum(arr, slot_free[0])
        done = begin + l
        slot_free = jnp.sort(slot_free.at[0].set(done))
        return slot_free, done

    init = jnp.zeros((slots,), dtype=jnp.float32)
    _, done = jax.lax.scan(step, init, (arrival, lat))
    return done


# ---------------------------------------------------------------------------
# smooth max-plus relaxation (temperature-τ log-sum-exp family)
# ---------------------------------------------------------------------------


def softmaximum(a: jnp.ndarray, b: jnp.ndarray, tau) -> jnp.ndarray:
    """Smooth two-argument max: τ·logaddexp(a/τ, b/τ) ≥ max(a, b), exact as
    τ → 0.  Shift-stable (logaddexp subtracts the pairwise max internally),
    monotone in both arguments, and smooth everywhere — the gradient splits
    between a and b by their softmax weights instead of picking a winner."""
    return tau * jnp.logaddexp(a / tau, b / tau)


def softmax_reduce(x: jnp.ndarray, tau, axis: int = -1) -> jnp.ndarray:
    """Smooth max-reduction: τ·logsumexp(x/τ) over ``axis``.  Entries at the
    ``NEG`` sentinel contribute softmax weight exp(NEG/τ - max/τ) = 0, so
    padded predecessor slots stay inert exactly as under the hard max."""
    return tau * jax.nn.logsumexp(x / tau, axis=axis)


@partial(jax.jit, static_argnames=("n", "width"))
def _wavefront_soft_impl(n: int, width: int, tau: jnp.ndarray,
                         work: jnp.ndarray, base: jnp.ndarray,
                         preds_lv: jnp.ndarray, extra_lv: jnp.ndarray,
                         starts: jnp.ndarray, order: jnp.ndarray,
                         rank: jnp.ndarray) -> jnp.ndarray:
    """``_wavefront_impl`` with the per-node hard max over (base, preds)
    replaced by ``softmax_reduce``.  τ is traced, not static: annealing it
    re-uses the compiled kernel."""
    work_lv = jnp.concatenate(
        [work.astype(jnp.float32)[order], jnp.zeros((width,), jnp.float32)])
    base_lv = jnp.concatenate(
        [base.astype(jnp.float32)[order], jnp.full((width,), NEG, jnp.float32)])
    p = preds_lv.shape[1]

    def step(t, start):
        js = jax.lax.dynamic_slice(preds_lv, (start, 0), (width, p))
        ex = jax.lax.dynamic_slice(extra_lv, (start, 0), (width, p))
        wv = jax.lax.dynamic_slice(work_lv, (start,), (width,))
        bv = jax.lax.dynamic_slice(base_lv, (start,), (width,))
        vals = jnp.where(js >= 0, t[jnp.maximum(js, 0)] + ex, NEG)
        m = softmax_reduce(jnp.concatenate([bv[:, None], vals], axis=1), tau,
                           axis=1)
        t = jax.lax.dynamic_update_slice(t, m + wv, (start,))
        return t, ()

    t0 = jnp.zeros((n + width,), dtype=jnp.float32)
    t, _ = jax.lax.scan(step, t0, starts)
    return t[rank]


def longest_path_soft(aidg: AIDGLike, tau: float = 0.05,
                      work: Optional[jnp.ndarray] = None,
                      base: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Smooth wavefront relaxation: upper-bounds ``longest_path_wavefront``
    node-wise, with per-node slack at most depth·τ·log(in-degree + 1), so
    the τ → 0 limit is the exact longest path.  Differentiable in (work,
    base) everywhere, including across critical-path switches."""
    ca = _as_compiled(aidg)
    a = ca.aidg
    s = ca.schedule
    w = jnp.asarray(a.work if work is None else work, jnp.float32)
    b = jnp.asarray(a.base if base is None else base, jnp.float32)
    return _wavefront_soft_impl(a.n, s.width, jnp.asarray(tau, jnp.float32),
                                w, b, jnp.asarray(ca.preds_lv),
                                jnp.asarray(ca.extra_lv),
                                jnp.asarray(s.starts), jnp.asarray(s.order),
                                jnp.asarray(s.rank))


def slot_queue_soft(arrival: jnp.ndarray, lat: jnp.ndarray, slots: int,
                    tau) -> jnp.ndarray:
    """``slot_queue_scan`` with every hard max softened.

    The single-slot closed form stays closed-form: the unrolled recurrence
    ``done_k = S_k + max_{j<=k}(arrival_j - S_{j-1})`` becomes
    ``S_k + τ·cumlogsumexp((arrival - S + lat)/τ)`` — the running soft-max
    via one associative scan (pairwise shift-stable), matching the hard
    cumsum + cummax path as τ → 0.  Multi-slot queues keep the sorted
    slot-vector scan with a ``softmaximum`` service-begin; the sort itself
    is piecewise-constant in the parameters and needs no smoothing."""
    if slots == 1:
        S = jnp.cumsum(lat)
        return S + tau * jax.lax.cumlogsumexp((arrival - S + lat) / tau)

    def step(slot_free, inp):
        arr, l = inp
        begin = softmaximum(arr, slot_free[0], tau)
        done = begin + l
        slot_free = jnp.sort(slot_free.at[0].set(done))
        return slot_free, done

    init = jnp.zeros((slots,), dtype=jnp.float32)
    _, done = jax.lax.scan(step, init, (arrival, lat))
    return done


def fixed_point_soft(aidg: AIDGLike, tau: float = 0.05, n_iters: int = 3,
                     work: Optional[jnp.ndarray] = None,
                     base: Optional[jnp.ndarray] = None,
                     storage_lat: Optional[Dict[str, jnp.ndarray]] = None,
                     engine: str = DEFAULT_ENGINE) -> jnp.ndarray:
    """``fixed_point_jax`` over the smooth family: soft wavefront
    relaxations between queueing folds, ``slot_queue_soft`` inside them, and
    a ``softmaximum`` base fold-back.  The arrival-order ``argsort`` is
    piecewise-constant in θ (its subgradient contribution is zero almost
    everywhere), so treating it as a constant gather keeps the whole fixed
    point ``jax.grad``-safe.  ``engine``: ``"wavefront"`` (default) or
    ``"condensed"`` (chain super-edges keep their exact sums — a tighter
    soft relaxation on a shorter sequential scan)."""
    ca = _as_compiled(aidg)
    a = ca.aidg
    tau = jnp.asarray(tau, jnp.float32)
    w = jnp.asarray(a.work if work is None else work, jnp.float32)
    b0 = jnp.asarray(a.base if base is None else base, jnp.float32)
    if engine == "condensed":
        cond = condense_aidg(a)
        relax = lambda w_, b_: _condensed_relax(cond, w_, b_, tau=tau)
    elif engine == "wavefront":
        s = ca.schedule
        pl, el = jnp.asarray(ca.preds_lv), jnp.asarray(ca.extra_lv)
        st_, od, rk = (jnp.asarray(s.starts), jnp.asarray(s.order),
                       jnp.asarray(s.rank))
        relax = lambda w_, b_: _wavefront_soft_impl(a.n, s.width, tau, w_,
                                                    b_, pl, el, st_, od, rk)
    else:
        raise ValueError(f"fixed_point_soft supports engines 'wavefront' "
                         f"and 'condensed', got {engine!r}")
    queue = lambda arr, lat, slots: slot_queue_soft(arr, lat, slots, tau)

    def fold(b, nd, need):
        # scatter the access needs into node space (duplicates keep the
        # hard max — a zero-measure kink), then soft-fold into the base:
        # softmaximum(b, NEG) == b exactly, so untouched nodes are inert
        need_full = jnp.full_like(b, NEG).at[nd].max(need)
        return softmaximum(b, need_full, tau)

    return _fixed_point_core(ca, relax, queue, fold, w, b0, storage_lat,
                             n_iters)


def _fixed_point_core(ca: CompiledAIDG, relax: Callable, queue: Callable,
                      fold: Callable, w: jnp.ndarray, b0: jnp.ndarray,
                      storage_lat: Optional[Dict[str, jnp.ndarray]],
                      n_iters: int) -> jnp.ndarray:
    """The one queueing fixed point shared by the hard and soft evaluators
    (so the gradient always descends the same objective the hard path
    scores): relax the DAG, replay each storage's accesses in estimated-
    arrival order through ``queue``, ``fold`` the service needs back into
    the bases, iterate.  Node-space gathers use the *constant* scatter
    indices; only the (θ-dependent) sort into service order and back needs
    batched-index gathers."""
    a = ca.aidg
    fu_lat = jnp.asarray(a.fu_lat, jnp.float32)
    t = relax(w, b0)
    if not a.storage_nodes:
        return t
    for _ in range(n_iters):
        b = b0
        for st_name in ca.storage_order:
            lats = jnp.asarray(
                a.storage_lat[st_name] if storage_lat is None
                else storage_lat[st_name], jnp.float32)
            nd = jnp.asarray(ca.storage_scatter[st_name])
            slots = a.storage_slots[st_name]
            w_nd = w[nd]
            arrival = t[nd] - w_nd
            order = jnp.argsort(arrival)
            done_sorted = queue(arrival[order], lats[order], slots)
            done = done_sorted[jnp.argsort(order)]    # back to access order
            need = done + fu_lat[nd] - w_nd
            b = fold(b, nd, need)
        t = relax(w, b)
    return t


def fixed_point_jax(aidg: AIDGLike, n_iters: int = 3,
                    work: Optional[jnp.ndarray] = None,
                    base: Optional[jnp.ndarray] = None,
                    storage_lat: Optional[Dict[str, jnp.ndarray]] = None,
                    engine: str = DEFAULT_ENGINE) -> jnp.ndarray:
    """JAX version of ``builder.longest_path_fixed_point`` — jit/vmap-able
    over (work, base, storage latencies) for design-space exploration.
    ``engine`` selects the DAG relaxation between queueing folds."""
    ca = _as_compiled(aidg)
    a = ca.aidg
    w = jnp.asarray(a.work if work is None else work, jnp.float32)
    b0 = jnp.asarray(a.base if base is None else base, jnp.float32)
    return _fixed_point_core(
        ca, _relaxer(ca, engine), slot_queue_scan,
        lambda b, nd, need: b.at[nd].max(need), w, b0, storage_lat, n_iters)


def fixed_point_batch(aidg: AIDGLike, works: Optional[jnp.ndarray] = None,
                      bases: Optional[jnp.ndarray] = None,
                      storage_lats: Optional[Dict[str, jnp.ndarray]] = None,
                      n_iters: int = 3,
                      engine: str = DEFAULT_ENGINE) -> jnp.ndarray:
    """Batched ``fixed_point_jax``: any of ``works`` (B, n), ``bases``
    (B, n), ``storage_lats`` {name: (B, k)} may carry a leading batch axis;
    omitted inputs broadcast from the AIDG baseline.  Returns (B, n)
    completion times in one vmapped device launch — the raw-latency-space
    counterpart of ``dse.sweep`` (which batches multiplicative θ factors).
    """
    ca = _as_compiled(aidg)
    a = ca.aidg
    batched = [x for x in (works, bases) if x is not None]
    if storage_lats is not None:
        unknown = set(storage_lats) - set(a.storage_lat)
        if unknown:
            raise KeyError(f"unknown storage(s) {sorted(unknown)}; "
                           f"AIDG has {sorted(a.storage_lat)}")
        batched.extend(storage_lats.values())
    if not batched:
        raise ValueError("fixed_point_batch needs at least one batched input")
    shapes = [np.shape(x) for x in batched]
    if any(len(s) != 2 for s in shapes) or len({s[0] for s in shapes}) != 1:
        raise ValueError(f"batched inputs must be 2-D with one shared "
                         f"leading batch dim, got shapes {shapes}")
    B = batched[0].shape[0]
    w = (jnp.broadcast_to(jnp.asarray(a.work, jnp.float32), (B, a.n))
         if works is None else jnp.asarray(works, jnp.float32))
    b = (jnp.broadcast_to(jnp.asarray(a.base, jnp.float32), (B, a.n))
         if bases is None else jnp.asarray(bases, jnp.float32))
    sl = {name: (jnp.broadcast_to(jnp.asarray(lat, jnp.float32),
                                  (B, len(lat)))
                 if storage_lats is None or name not in storage_lats
                 else jnp.asarray(storage_lats[name], jnp.float32))
          for name, lat in a.storage_lat.items()}

    def one(w_, b_, sl_):
        return fixed_point_jax(ca, n_iters=n_iters, work=w_, base=b_,
                               storage_lat=sl_, engine=engine)

    return jax.vmap(one)(w, b, sl)
