"""Design-space exploration over ACADL accelerator parameters (paper §1/§7:
"the timing simulation can be used in the optimization loop of
hardware-aware NAS and DNN/HW Co-Design").

The AIDG separates *structure* (the dependency DAG, built once per
workload) from *weights* (per-instruction latencies).  Latencies are
re-parameterized as multiplicative factors over the baseline:

    fu_lat_i(θ)  = θ_op[op_class_i]    · fu_lat_i
    mem_lat_i(θ) = θ_st[storage(i)]    · mem_lat_i

so θ = 1 reproduces the modeled accelerator exactly, θ_op[gemm@mxu#] = 0.5
models a 2× faster matrix unit, θ_st[hbm#] = 2 a half-bandwidth memory, etc.
``sweep`` evaluates thousands of candidate accelerators in one batched JAX
call via ``vmap`` over θ — the trace and graph are never rebuilt.

Because the whole evaluator is JAX end-to-end, the makespan is also
*differentiable in θ*: ``evaluate_theta_soft`` swaps the hard max-plus
engine for the temperature-τ smooth family (``maxplus.fixed_point_soft``)
and ``grad_sweep`` returns a cached ``jit(vmap(value_and_grad))`` that maps
a batch of *shared knob vectors* straight to (soft cycles, d cycles / d
knob) — the chain through ``DesignSpace.projection`` is part of the traced
function, so gradients land on the few shared knobs rather than the
per-scenario θ columns.  ``repro.core.aidg.gradient`` turns this into a
projected-Adam design-space optimizer.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .builder import AIDG, CompiledAIDG, compile_aidg, longest_path_fixed_point
from .maxplus import (DEFAULT_ENGINE, fixed_point_jax, fixed_point_soft,
                      softmax_reduce, softmaximum)

__all__ = ["DSEProblem", "make_problem", "evaluate_theta", "compiled_sweep",
           "sweep", "evaluate_theta_soft", "grad_sweep"]


@dataclass
class DSEProblem:
    aidg: AIDG
    op_names: List[str]          # op-class index -> name
    storage_names: List[str]     # storage-class index -> name
    # per-node gather indices
    node_op: np.ndarray          # (n,) int32
    node_storage: Dict[str, int] = field(default_factory=dict)  # name -> id
    # build-time compilation artifact (level schedule + padded gathers),
    # shared by every sweep over this problem
    caidg: Optional[CompiledAIDG] = None
    # (n_iters, engine) -> jitted vmapped evaluator, and
    # ("grad", n_iters, projection bytes) -> jitted vmapped value_and_grad
    # (jax.jit caches by function identity, so re-creating the lambda per
    # sweep() would re-trace)
    _compiled: Dict[Tuple, Callable] = field(default_factory=dict, repr=False)

    @property
    def n_op(self) -> int:
        return len(self.op_names)

    @property
    def n_st(self) -> int:
        return len(self.storage_names)

    @property
    def compiled_aidg(self) -> CompiledAIDG:
        if self.caidg is None:  # hand-built problems compile lazily
            self.caidg = compile_aidg(self.aidg)
        return self.caidg


def make_problem(aidg: AIDG) -> DSEProblem:
    op_names = [None] * len(aidg.classes)
    for name, idx in aidg.classes.items():
        op_names[idx] = name
    st_names = sorted(aidg.storage_nodes.keys())
    return DSEProblem(aidg=aidg, op_names=op_names, storage_names=st_names,
                      node_op=aidg.op_class,
                      node_storage={s: i for i, s in enumerate(st_names)},
                      caidg=compile_aidg(aidg))


def _reweight(prob: DSEProblem, theta_op: jnp.ndarray, theta_st: jnp.ndarray,
              floor: Callable = jnp.maximum
              ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray], jnp.ndarray]:
    """θ -> (per-node work, scaled storage latencies, scaled fu latencies).
    ``floor`` applies the 1-cycle occupancy minimum — ``jnp.maximum`` on
    the hard path, a τ-``softmaximum`` on the smooth one (one shared
    re-weighting, so hard and soft evaluators can't drift apart)."""
    aidg = prob.aidg
    fu = jnp.asarray(aidg.fu_lat) * theta_op[prob.node_op]
    mem_scale = jnp.ones(aidg.n, dtype=jnp.float32)
    st_lat: Dict[str, jnp.ndarray] = {}
    for st, cid in prob.node_storage.items():
        nodes = aidg.storage_nodes[st]
        st_lat[st] = jnp.asarray(aidg.storage_lat[st]) * theta_st[cid]
        mem_scale = mem_scale.at[jnp.asarray(nodes)].set(theta_st[cid])
    mem = jnp.asarray(aidg.mem_lat) * mem_scale
    work = floor(jnp.float32(1.0), fu + mem)
    return work, st_lat, fu


def evaluate_theta(prob: DSEProblem, theta_op: jnp.ndarray,
                   theta_st: jnp.ndarray, n_iters: int = 2,
                   engine: str = DEFAULT_ENGINE) -> jnp.ndarray:
    """Estimated cycles for one parameter point (jit/vmap-able)."""
    work, st_lat, fu = _reweight(prob, theta_op, theta_st)
    # fixed_point_jax reads fu_lat for the queueing fold-back; the scaled fu
    # enters through `work`, so pass base/work/storage latencies explicitly.
    # The CompiledAIDG carries the level schedule, built once per scenario.
    t = fixed_point_jax(prob.compiled_aidg, n_iters=n_iters, work=work,
                        storage_lat=st_lat, engine=engine)
    return t.max()


def compiled_sweep(prob: DSEProblem, n_iters: int = 2,
                   engine: str = DEFAULT_ENGINE) -> Callable:
    """Cached jit(vmap) evaluator for ``prob``: (B, n_op), (B, n_st) ->
    (B,) cycles.  The first call per (problem, n_iters, engine) traces;
    every later sweep over the same AIDG re-uses the compiled kernel — the
    property the multi-scenario explorer relies on for its configs/sec
    throughput."""
    fn = prob._compiled.get((n_iters, engine))
    if fn is None:
        f = lambda to, ts: evaluate_theta(prob, to, ts, n_iters=n_iters,
                                          engine=engine)
        fn = jax.jit(jax.vmap(f))
        prob._compiled[(n_iters, engine)] = fn
    return fn


def sweep(prob: DSEProblem, thetas_op: np.ndarray, thetas_st: np.ndarray,
          n_iters: int = 2, batched: bool = True,
          chunk: Optional[int] = None,
          engine: str = DEFAULT_ENGINE) -> np.ndarray:
    """Evaluate a batch of candidate accelerators.

    ``thetas_op``: (B, n_op), ``thetas_st``: (B, n_st) -> (B,) cycles.
    One ``vmap`` + ``jit`` over the whole batch: the DSE loop the paper
    motivates, shaped for a single device launch.

    ``chunk``: split very large batches into fixed-size device launches to
    bound peak memory (the tail chunk is padded to ``chunk`` rows so the
    compiled kernel is reused rather than re-traced per remainder shape).

    ``engine``: the DAG relaxation used inside the fixed point —
    ``"wavefront"`` (default, level-scheduled), ``"scan"`` (per-node), or
    ``"blocked"`` (max-plus closure blocks).
    """
    if chunk is not None and chunk <= 0:
        raise ValueError(f"chunk must be positive, got {chunk}")
    if not batched:
        f = lambda to, ts: evaluate_theta(prob, to, ts, n_iters=n_iters,
                                          engine=engine)
        return np.asarray([f(jnp.asarray(a), jnp.asarray(b))
                           for a, b in zip(thetas_op, thetas_st)])
    fn = compiled_sweep(prob, n_iters, engine)
    to = jnp.asarray(thetas_op, jnp.float32)
    ts = jnp.asarray(thetas_st, jnp.float32)
    B = to.shape[0]
    if chunk is None or B <= chunk:
        return np.asarray(fn(to, ts))
    out = np.empty(B, dtype=np.float32)
    for s in range(0, B, chunk):
        e = min(s + chunk, B)
        if e - s < chunk:  # pad the tail to the compiled batch shape
            pad = chunk - (e - s)
            co = jnp.concatenate([to[s:e], jnp.ones((pad, to.shape[1]),
                                                    jnp.float32)])
            cs = jnp.concatenate([ts[s:e], jnp.ones((pad, ts.shape[1]),
                                                    jnp.float32)])
            out[s:e] = np.asarray(fn(co, cs))[: e - s]
        else:
            out[s:e] = np.asarray(fn(to[s:e], ts[s:e]))
    return out


# ---------------------------------------------------------------------------
# smooth evaluation + knob-space gradients (the co-design inner loop)
# ---------------------------------------------------------------------------


def evaluate_theta_soft(prob: DSEProblem, theta_op: jnp.ndarray,
                        theta_st: jnp.ndarray, tau, n_iters: int = 2
                        ) -> jnp.ndarray:
    """Smooth estimated cycles for one parameter point: the τ-tempered
    counterpart of ``evaluate_theta`` (soft occupancy floor, soft wavefront
    fixed point, soft makespan reduction).  Upper-bounds the hard estimate
    and converges to it as τ → 0; smooth in (θ_op, θ_st) everywhere — the
    hard ``max(1, fu + mem)`` floor would have zero gradient wherever θ has
    pushed a node under it, killing descent directions exactly where fast
    hardware stops paying, so the floor is softened too."""
    work, st_lat, _ = _reweight(prob, theta_op, theta_st,
                                floor=lambda a, b: softmaximum(a, b, tau))
    t = fixed_point_soft(prob.compiled_aidg, tau=tau, n_iters=n_iters,
                         work=work, storage_lat=st_lat)
    return softmax_reduce(t, tau)


def grad_sweep(prob: DSEProblem, op_idx: np.ndarray, st_idx: np.ndarray,
               n_iters: int = 2) -> Callable:
    """Cached ``jit(vmap(value_and_grad))`` from *shared knob space*:
    ``fn(knobs (B, K), tau) -> (soft cycles (B,), d cycles/d knob (B, K))``.

    ``op_idx`` / ``st_idx`` are ``DesignSpace.projection(prob)`` gather maps
    (op-class/storage -> knob, with K = identity column); baking them into
    the traced function chains the projection inside autodiff, so the
    returned gradient is already in the K shared knobs — no per-scenario θ
    chain rule on the host.  τ is traced: annealing re-uses the kernel."""
    op_idx = np.asarray(op_idx, np.int64)
    st_idx = np.asarray(st_idx, np.int64)
    key = ("grad", n_iters, op_idx.tobytes(), st_idx.tobytes())
    fn = prob._compiled.get(key)
    if fn is None:
        oi, si = jnp.asarray(op_idx), jnp.asarray(st_idx)

        def f(knobs, tau):
            padded = jnp.concatenate(
                [knobs, jnp.ones((1,), knobs.dtype)])   # identity column
            return evaluate_theta_soft(prob, padded[oi], padded[si], tau,
                                       n_iters=n_iters)

        fn = jax.jit(jax.vmap(jax.value_and_grad(f), in_axes=(0, None)))
        prob._compiled[key] = fn
    return fn
