"""Design-space exploration over ACADL accelerator parameters (paper §1/§7:
"the timing simulation can be used in the optimization loop of
hardware-aware NAS and DNN/HW Co-Design").

The AIDG separates *structure* (the dependency DAG, built once per
workload) from *weights* (per-instruction latencies).  Latencies are
re-parameterized as multiplicative factors over the baseline:

    fu_lat_i(θ)  = θ_op[op_class_i]    · fu_lat_i
    mem_lat_i(θ) = θ_st[storage(i)]    · mem_lat_i

so θ = 1 reproduces the modeled accelerator exactly, θ_op[gemm@mxu#] = 0.5
models a 2× faster matrix unit, θ_st[hbm#] = 2 a half-bandwidth memory, etc.
``sweep`` evaluates thousands of candidate accelerators in one batched JAX
call via ``vmap`` over θ — the trace and graph are never rebuilt.

Because the whole evaluator is JAX end-to-end, the makespan is also
*differentiable in θ*: ``evaluate_theta_soft`` swaps the hard max-plus
engine for the temperature-τ smooth family (``maxplus.fixed_point_soft``)
and ``grad_sweep`` returns a cached ``jit(vmap(value_and_grad))`` that maps
a batch of *shared knob vectors* straight to (soft cycles, d cycles / d
knob) — the chain through ``DesignSpace.projection`` is part of the traced
function, so gradients land on the few shared knobs rather than the
per-scenario θ columns.  ``repro.core.aidg.gradient`` turns this into a
projected-Adam design-space optimizer.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .builder import (AIDG, CompiledAIDG, CondensedAIDG, compile_aidg,
                      condense_aidg, longest_path_fixed_point)
from .maxplus import (DEFAULT_ENGINE, NEG, condensed_scan, fixed_point_jax,
                      fixed_point_soft, softmax_reduce, softmaximum)

__all__ = ["DSEProblem", "make_problem", "evaluate_theta", "compiled_sweep",
           "sweep", "evaluate_theta_soft", "grad_sweep", "LayerStack",
           "NETWORK_MODES", "compiled_network_sweep", "grad_network_sweep",
           "PackSpec", "PackedMatrix"]


@dataclass
class DSEProblem:
    """One workload's parameterized timing model: the immutable AIDG plus
    the gather maps that turn a θ vector (one factor per op class / storage
    class) into per-node latency scalings, and the per-problem cache of
    compiled evaluators.  Built once per (architecture, workload) cell by
    ``make_problem``; every sweep re-weights this structure."""

    aidg: AIDG
    op_names: List[str]          # op-class index -> name
    storage_names: List[str]     # storage-class index -> name
    # per-node gather indices
    node_op: np.ndarray          # (n,) int32
    node_storage: Dict[str, int] = field(default_factory=dict)  # name -> id
    # build-time compilation artifact (level schedule + padded gathers),
    # shared by every sweep over this problem
    caidg: Optional[CompiledAIDG] = None
    # (n_iters, engine) -> jitted vmapped evaluator, and
    # ("grad", n_iters, projection bytes) -> jitted vmapped value_and_grad
    # (jax.jit caches by function identity, so re-creating the lambda per
    # sweep() would re-trace)
    _compiled: Dict[Tuple, Callable] = field(default_factory=dict, repr=False)

    @property
    def n_op(self) -> int:
        """Number of op classes = columns of a θ_op candidate row."""
        return len(self.op_names)

    @property
    def n_st(self) -> int:
        """Number of storage classes = columns of a θ_st candidate row."""
        return len(self.storage_names)

    @property
    def compiled_aidg(self) -> CompiledAIDG:
        """The build-time compile artifact (level schedule + gathers)."""
        if self.caidg is None:  # hand-built problems compile lazily
            self.caidg = compile_aidg(self.aidg)
        return self.caidg


def make_problem(aidg: AIDG) -> DSEProblem:
    """AIDG -> DSEProblem: name the op/storage classes, build the per-node
    gather indices, and run the build-time compile pipeline
    (``compile_aidg``) so every sweep shares one level schedule."""
    op_names = [None] * len(aidg.classes)
    for name, idx in aidg.classes.items():
        op_names[idx] = name
    st_names = sorted(aidg.storage_nodes.keys())
    return DSEProblem(aidg=aidg, op_names=op_names, storage_names=st_names,
                      node_op=aidg.op_class,
                      node_storage={s: i for i, s in enumerate(st_names)},
                      caidg=compile_aidg(aidg))


def _reweight(prob: DSEProblem, theta_op: jnp.ndarray, theta_st: jnp.ndarray,
              floor: Callable = jnp.maximum
              ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray], jnp.ndarray]:
    """θ -> (per-node work, scaled storage latencies, scaled fu latencies).
    ``floor`` applies the 1-cycle occupancy minimum — ``jnp.maximum`` on
    the hard path, a τ-``softmaximum`` on the smooth one (one shared
    re-weighting, so hard and soft evaluators can't drift apart)."""
    aidg = prob.aidg
    fu = jnp.asarray(aidg.fu_lat) * theta_op[prob.node_op]
    mem_scale = jnp.ones(aidg.n, dtype=jnp.float32)
    st_lat: Dict[str, jnp.ndarray] = {}
    for st, cid in prob.node_storage.items():
        nodes = aidg.storage_nodes[st]
        st_lat[st] = jnp.asarray(aidg.storage_lat[st]) * theta_st[cid]
        mem_scale = mem_scale.at[jnp.asarray(nodes)].set(theta_st[cid])
    mem = jnp.asarray(aidg.mem_lat) * mem_scale
    work = floor(jnp.float32(1.0), fu + mem)
    return work, st_lat, fu


def evaluate_theta(prob: DSEProblem, theta_op: jnp.ndarray,
                   theta_st: jnp.ndarray, n_iters: int = 2,
                   engine: str = DEFAULT_ENGINE) -> jnp.ndarray:
    """Estimated cycles for one parameter point (jit/vmap-able)."""
    work, st_lat, fu = _reweight(prob, theta_op, theta_st)
    # fixed_point_jax reads fu_lat for the queueing fold-back; the scaled fu
    # enters through `work`, so pass base/work/storage latencies explicitly.
    # The CompiledAIDG carries the level schedule, built once per scenario.
    t = fixed_point_jax(prob.compiled_aidg, n_iters=n_iters, work=work,
                        storage_lat=st_lat, engine=engine)
    return t.max()


def compiled_sweep(prob: DSEProblem, n_iters: int = 2,
                   engine: str = DEFAULT_ENGINE) -> Callable:
    """Cached jit(vmap) evaluator for ``prob``: (B, n_op), (B, n_st) ->
    (B,) cycles.  The first call per (problem, n_iters, engine) traces;
    every later sweep over the same AIDG re-uses the compiled kernel — the
    property the multi-scenario explorer relies on for its configs/sec
    throughput."""
    fn = prob._compiled.get((n_iters, engine))
    if fn is None:
        f = lambda to, ts: evaluate_theta(prob, to, ts, n_iters=n_iters,
                                          engine=engine)
        fn = jax.jit(jax.vmap(f))
        prob._compiled[(n_iters, engine)] = fn
    return fn


def sweep(prob: DSEProblem, thetas_op: np.ndarray, thetas_st: np.ndarray,
          n_iters: int = 2, batched: bool = True,
          chunk: Optional[int] = None,
          engine: str = DEFAULT_ENGINE) -> np.ndarray:
    """Evaluate a batch of candidate accelerators.

    ``thetas_op``: (B, n_op), ``thetas_st``: (B, n_st) -> (B,) cycles.
    One ``vmap`` + ``jit`` over the whole batch: the DSE loop the paper
    motivates, shaped for a single device launch.

    ``chunk``: split very large batches into fixed-size device launches to
    bound peak memory (the tail chunk is padded to ``chunk`` rows so the
    compiled kernel is reused rather than re-traced per remainder shape).

    ``engine``: the DAG relaxation used inside the fixed point —
    ``"wavefront"`` (default, level-scheduled), ``"condensed"``
    (chain-condensed wavefront, see ``builder.condense_aidg``), ``"scan"``
    (per-node), or ``"blocked"`` (max-plus closure blocks).
    """
    if chunk is not None and chunk <= 0:
        raise ValueError(f"chunk must be positive, got {chunk}")
    if not batched:
        f = lambda to, ts: evaluate_theta(prob, to, ts, n_iters=n_iters,
                                          engine=engine)
        return np.asarray([f(jnp.asarray(a), jnp.asarray(b))
                           for a, b in zip(thetas_op, thetas_st)])
    fn = compiled_sweep(prob, n_iters, engine)
    to = jnp.asarray(thetas_op, jnp.float32)
    ts = jnp.asarray(thetas_st, jnp.float32)
    B = to.shape[0]
    if chunk is None or B <= chunk:
        return np.asarray(fn(to, ts))
    out = np.empty(B, dtype=np.float32)
    for s in range(0, B, chunk):
        e = min(s + chunk, B)
        if e - s < chunk:  # pad the tail to the compiled batch shape
            pad = chunk - (e - s)
            co = jnp.concatenate([to[s:e], jnp.ones((pad, to.shape[1]),
                                                    jnp.float32)])
            cs = jnp.concatenate([ts[s:e], jnp.ones((pad, ts.shape[1]),
                                                    jnp.float32)])
            out[s:e] = np.asarray(fn(co, cs))[: e - s]
        else:
            out[s:e] = np.asarray(fn(to[s:e], ts[s:e]))
    return out


# ---------------------------------------------------------------------------
# smooth evaluation + knob-space gradients (the co-design inner loop)
# ---------------------------------------------------------------------------


def evaluate_theta_soft(prob: DSEProblem, theta_op: jnp.ndarray,
                        theta_st: jnp.ndarray, tau, n_iters: int = 2,
                        engine: str = DEFAULT_ENGINE) -> jnp.ndarray:
    """Smooth estimated cycles for one parameter point: the τ-tempered
    counterpart of ``evaluate_theta`` (soft occupancy floor, soft wavefront
    fixed point, soft makespan reduction).  Upper-bounds the hard estimate
    and converges to it as τ → 0; smooth in (θ_op, θ_st) everywhere — the
    hard ``max(1, fu + mem)`` floor would have zero gradient wherever θ has
    pushed a node under it, killing descent directions exactly where fast
    hardware stops paying, so the floor is softened too.  ``engine``:
    ``"wavefront"`` (default) or ``"condensed"`` (exact chain sums on a
    shorter sequential scan — a tighter soft relaxation)."""
    work, st_lat, _ = _reweight(prob, theta_op, theta_st,
                                floor=lambda a, b: softmaximum(a, b, tau))
    t = fixed_point_soft(prob.compiled_aidg, tau=tau, n_iters=n_iters,
                         work=work, storage_lat=st_lat, engine=engine)
    return softmax_reduce(t, tau)


def grad_sweep(prob: DSEProblem, op_idx: np.ndarray, st_idx: np.ndarray,
               n_iters: int = 2) -> Callable:
    """Cached ``jit(vmap(value_and_grad))`` from *shared knob space*:
    ``fn(knobs (B, K), tau) -> (soft cycles (B,), d cycles/d knob (B, K))``.

    ``op_idx`` / ``st_idx`` are ``DesignSpace.projection(prob)`` gather maps
    (op-class/storage -> knob, with K = identity column); baking them into
    the traced function chains the projection inside autodiff, so the
    returned gradient is already in the K shared knobs — no per-scenario θ
    chain rule on the host.  τ is traced: annealing re-uses the kernel."""
    op_idx = np.asarray(op_idx, np.int64)
    st_idx = np.asarray(st_idx, np.int64)
    key = ("grad", n_iters, op_idx.tobytes(), st_idx.tobytes())
    fn = prob._compiled.get(key)
    if fn is None:
        oi, si = jnp.asarray(op_idx), jnp.asarray(st_idx)

        def f(knobs, tau):
            padded = jnp.concatenate(
                [knobs, jnp.ones((1,), knobs.dtype)])   # identity column
            return evaluate_theta_soft(prob, padded[oi], padded[si], tau,
                                       n_iters=n_iters)

        fn = jax.jit(jax.vmap(jax.value_and_grad(f), in_axes=(0, None)))
        prob._compiled[key] = fn
    return fn


# ---------------------------------------------------------------------------
# stacked per-layer programs: whole-network end-to-end latency
# ---------------------------------------------------------------------------

NETWORK_MODES = ("sequential", "pipelined")


@dataclass
class LayerStack:
    """A whole network as a *stack* of per-layer DSE problems plus the
    max-plus composition structure (built by ``repro.core.network``).

    ``problems[u]`` is the AIDG of one **unique** layer program; the
    network's execution order is a sequence of *runs* — maximal stretches
    of ``run_reps[r]`` consecutive instances of unique layer
    ``run_layer[r]`` (a transformer's 16 identical blocks are one run of
    16, a tiled operator's ``tiles`` repeats fold in multiplicatively).

    ``prologue_len[u]`` is the static length of the layer's load-only
    instruction prefix (no compute op has executed yet): its completion
    time is the part of the layer a *double-buffered* pipeline can overlap
    with the previous layer's tail.  ``fits_within[r]`` / ``fits_between[r]``
    are 0/1 capacity gates — overlap is only credited when the two layers'
    stationary working sets fit the architecture's on-chip buffer together.

    Composition (per candidate, all in the traced function):

    * ``sequential``: Σ_r reps_r · m_{l(r)} — every instance back-to-back,
      the mode whose θ = 1 value matches the per-layer event-sim oracle
      composition exactly.
    * ``pipelined``: the sequential total minus the credited overlaps
      min(p_next, m_prev) — never below any single layer, never above the
      sequential total.
    """

    problems: List[DSEProblem]
    prologue_len: np.ndarray        # (L,) int   — load-only prefix length
    run_layer: np.ndarray           # (R,) int   — unique-layer id per run
    run_reps: np.ndarray            # (R,) float — instances per run
    fits_within: np.ndarray         # (R,) float — 0/1 double-buffer gate
    fits_between: np.ndarray        # (R-1,) float — 0/1 gate to next run
    _compiled: Dict[Tuple, Callable] = field(default_factory=dict, repr=False)

    @property
    def n_layers(self) -> int:
        """Unique per-layer programs in the stack (the compile unit)."""
        return len(self.problems)

    @property
    def instances(self) -> float:
        """Total layer instances composed end-to-end (Σ run reps)."""
        return float(np.asarray(self.run_reps, np.float64).sum())


def _layer_times(prob: DSEProblem, theta_op: jnp.ndarray,
                 theta_st: jnp.ndarray, n_iters: int, engine: str,
                 k_prologue: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One layer's (makespan, prologue completion) at θ — the prologue is
    the hard max over the first ``k_prologue`` (load-only) instructions."""
    work, st_lat, _ = _reweight(prob, theta_op, theta_st)
    t = fixed_point_jax(prob.compiled_aidg, n_iters=n_iters, work=work,
                        storage_lat=st_lat, engine=engine)
    p = t[:k_prologue].max() if k_prologue > 0 else jnp.float32(0.0)
    return t.max(), p


def _compose(stack: LayerStack, m: jnp.ndarray, p: jnp.ndarray, mode: str,
             minimum: Callable = jnp.minimum) -> jnp.ndarray:
    """(L,) per-unique-layer makespans/prologues -> end-to-end cycles.
    ``minimum`` is the overlap clip — ``jnp.minimum`` on the hard path, a
    τ-softmin on the smooth one (overlap can't exceed the previous layer's
    makespan or the next layer's prologue)."""
    rl = jnp.asarray(stack.run_layer)
    reps = jnp.asarray(stack.run_reps, jnp.float32)
    mr, pr = m[rl], p[rl]
    total = (reps * mr).sum()
    if mode == "sequential":
        return total
    fw = jnp.asarray(stack.fits_within, jnp.float32)
    within = ((reps - 1.0) * minimum(pr, mr) * fw).sum()
    if stack.run_layer.shape[0] > 1:
        fb = jnp.asarray(stack.fits_between, jnp.float32)
        between = (minimum(pr[1:], mr[:-1]) * fb).sum()
    else:
        between = jnp.float32(0.0)
    return total - within - between


def compiled_network_sweep(stack: LayerStack, n_iters: int = 2,
                           engine: str = DEFAULT_ENGINE,
                           mode: str = "sequential") -> Callable:
    """Cached jit(vmap) end-to-end evaluator for a layer stack:
    ``fn(tuple of (B, n_op_l), tuple of (B, n_st_l)) -> (B,) cycles``.

    The per-layer wavefronts and the max-plus composition live in ONE
    traced function, so a candidate batch costs one device launch per
    network cell regardless of depth — and repeated layers are evaluated
    once per unique program, not once per instance."""
    if mode not in NETWORK_MODES:
        raise ValueError(f"mode must be one of {NETWORK_MODES}, got {mode!r}")
    key = (n_iters, engine, mode)
    fn = stack._compiled.get(key)
    if fn is None:
        ks = [int(k) for k in stack.prologue_len]

        def f(tos, tss):
            times = [_layer_times(prob, to, ts, n_iters, engine, k)
                     for prob, k, to, ts
                     in zip(stack.problems, ks, tos, tss)]
            m = jnp.stack([t[0] for t in times])
            p = jnp.stack([t[1] for t in times])
            return _compose(stack, m, p, mode)

        fn = jax.jit(jax.vmap(f))
        stack._compiled[key] = fn
    return fn


def _layer_times_soft(prob: DSEProblem, theta_op: jnp.ndarray,
                      theta_st: jnp.ndarray, tau, n_iters: int,
                      k_prologue: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Smooth counterpart of ``_layer_times`` (soft floor, soft fixed
    point, soft reductions) — differentiable in θ everywhere."""
    work, st_lat, _ = _reweight(prob, theta_op, theta_st,
                                floor=lambda a, b: softmaximum(a, b, tau))
    t = fixed_point_soft(prob.compiled_aidg, tau=tau, n_iters=n_iters,
                         work=work, storage_lat=st_lat)
    p = (softmax_reduce(t[:k_prologue], tau) if k_prologue > 0
         else jnp.float32(0.0))
    return softmax_reduce(t, tau), p


def grad_network_sweep(stack: LayerStack, projections: Sequence[Tuple],
                       n_iters: int = 2, mode: str = "sequential"
                       ) -> Callable:
    """Cached ``jit(vmap(value_and_grad))`` of *end-to-end* network latency
    from shared knob space: ``fn(knobs (B, K), tau) -> (soft cycles (B,),
    d cycles/d knob (B, K))``.

    ``projections[u]`` is ``DesignSpace.projection(problems[u])``; baking
    every per-layer gather into one traced function chains projection →
    per-layer soft wavefront → max-plus composition inside autodiff, so
    the K shared knobs receive the full network's gradient in one call.
    In ``sequential`` mode the soft value upper-bounds the hard one (every
    softened reduction does); ``pipelined`` additionally softens the
    overlap clip with a softmin, which approximates rather than bounds."""
    if mode not in NETWORK_MODES:
        raise ValueError(f"mode must be one of {NETWORK_MODES}, got {mode!r}")
    projections = [(np.asarray(oi, np.int64), np.asarray(si, np.int64))
                   for oi, si in projections]
    key = (("grad", n_iters, mode)
           + tuple(oi.tobytes() + si.tobytes() for oi, si in projections))
    fn = stack._compiled.get(key)
    if fn is None:
        ks = [int(k) for k in stack.prologue_len]
        gathers = [(jnp.asarray(oi), jnp.asarray(si))
                   for oi, si in projections]

        def f(knobs, tau):
            padded = jnp.concatenate(
                [knobs, jnp.ones((1,), knobs.dtype)])   # identity column
            times = [_layer_times_soft(prob, padded[oi], padded[si], tau,
                                       n_iters, k)
                     for prob, k, (oi, si)
                     in zip(stack.problems, ks, gathers)]
            m = jnp.stack([t[0] for t in times])
            p = jnp.stack([t[1] for t in times])
            softmin = lambda a, b: -softmaximum(-a, -b, tau)
            return _compose(stack, m, p, mode, minimum=softmin)

        fn = jax.jit(jax.vmap(jax.value_and_grad(f), in_axes=(0, None)))
        stack._compiled[key] = fn
    return fn


# ---------------------------------------------------------------------------
# matrix packing: ALL cells x ALL candidates in one traced dispatch
# ---------------------------------------------------------------------------

_BIG = 1e18


@dataclass(frozen=True)
class PackSpec:
    """One cell's contribution to a :class:`PackedMatrix`: its (unique)
    per-layer problems + projections and the max-plus composition arrays.
    An operator cell is the trivial spec — one problem, one run of one
    repetition, no overlap gates; a network cell mirrors its
    :class:`LayerStack` (``fits_*`` all-zero encodes sequential mode, so
    one composition formula serves both modes)."""

    problems: Tuple[DSEProblem, ...]
    projections: Tuple[Tuple[np.ndarray, np.ndarray], ...]
    prologue_len: np.ndarray     # (L,) int — per-problem load-only prefix
    run_layer: np.ndarray        # (R,) int — local problem index per run
    run_reps: np.ndarray         # (R,) float
    fits_within: np.ndarray      # (R,) float 0/1 (0 = no overlap credited)
    fits_between: np.ndarray     # (R-1,) float 0/1
    # energy objective (optional — zero when absent): per-problem folded
    # dynamic pJ per knob (repro.core.aidg.energy.fold_dyn_energy, each
    # (n_knobs + 1,)) and the cell's static leakage pJ per cycle
    edyn: Tuple[np.ndarray, ...] = ()
    static_pj: float = 0.0

    @staticmethod
    def operator(problem: DSEProblem, projection, edyn=None,
                 static_pj: float = 0.0) -> "PackSpec":
        """The single-problem spec of an operator cell."""
        return PackSpec((problem,), (tuple(projection),),
                        np.zeros(1, np.int64), np.zeros(1, np.int64),
                        np.ones(1, np.float32), np.zeros(1, np.float32),
                        np.zeros(0, np.float32),
                        () if edyn is None else (np.asarray(edyn),),
                        float(static_pj))


@dataclass
class _PackedRow:
    """Per-unique-problem numpy staging arrays (permuted kept space)."""

    problem: DSEProblem
    cond: CondensedAIDG
    fu: np.ndarray               # (nk,) raw FU latency, permuted kept order
    mem: np.ndarray              # (nk,) raw memory latency
    base: np.ndarray             # (nk,) static base
    opk: np.ndarray              # (nk,) knob id scaling fu (K = identity)
    stk: np.ndarray              # (nk,) knob id scaling mem
    prol: np.ndarray             # (nk,) bool — original id < prologue_len
    ab_fu: np.ndarray            # (n_ab,) absorbed-node raw FU latency
    ab_opk: np.ndarray           # (n_ab,) knob id scaling it
    # storages as (perm positions, lats, knob, slots, ordered) — slots == 1
    # solves closed-form, > 1 runs the slot-vector scan; ``ordered`` means
    # the arrival order is PROVABLY static (each access an ancestor of the
    # next), so the per-candidate argsort is the identity and is skipped
    queues: List[Tuple[np.ndarray, np.ndarray, int, int, bool]]


def _stage_row(prob: DSEProblem, proj, k_prologue: int) -> _PackedRow:
    """Condense one problem (prologue boundary force-kept) and gather its
    θ-independent arrays into the permuted kept layout."""
    a = prob.aidg
    cond = condense_aidg(a, boundary=int(k_prologue) if k_prologue else None)
    op_idx, st_idx = (np.asarray(proj[0], np.int64),
                      np.asarray(proj[1], np.int64))
    kop = cond.kept_perm                          # original ids, permuted
    stk_full = np.full(a.n, -1, dtype=np.int64)   # -1 -> identity (patched)
    for st, cid in prob.node_storage.items():
        stk_full[a.storage_nodes[st]] = st_idx[cid]
    queues: List[Tuple[np.ndarray, np.ndarray, int, int, bool]] = []
    ca = prob.compiled_aidg
    for name in ca.storage_order:
        perm_pos = cond.schedule.rank[
            cond.kept_rank[a.storage_nodes[name]]].astype(np.int64)
        lat = np.asarray(a.storage_lat[name], np.float32)
        knob = int(st_idx[prob.node_storage[name]])
        slots = int(a.storage_slots[name])
        queues.append((perm_pos, lat, knob, slots,
                       cond.storage_static_order(name)))
    return _PackedRow(
        problem=prob, cond=cond,
        fu=a.fu_lat[kop].astype(np.float32),
        mem=a.mem_lat[kop].astype(np.float32),
        base=a.base[kop].astype(np.float32),
        opk=op_idx[a.op_class[kop]],
        stk=stk_full[kop],
        prol=(kop < k_prologue),
        ab_fu=a.fu_lat[cond.absorbed].astype(np.float32),
        ab_opk=op_idx[a.op_class[cond.absorbed]],
        queues=queues)


class PackedMatrix:
    """The whole scenario/network matrix as ONE traced evaluator.

    Every unique (condensed) per-layer problem across all cells becomes one
    *row*: its level windows, predecessor slots, absorbed-prefix tables,
    and storage queues are padded to shared shapes and evaluated by a
    ``vmap`` over rows inside a ``vmap`` over candidates — all cells x all
    candidates in a single jitted dispatch, with masking keeping padded
    rows/slots/accesses inert.  Rows are grouped into *shape buckets*
    (``_bucketize``) so a width-1 chain cell never pays a wide systolic
    cell's window; every bucket's vmapped scan lives in the same trace, so
    it is still one dispatch per batch.  Cells then compose their rows'
    makespans
    (and prologue times, for pipelined network cells) with the same
    run-length max-plus formula as :class:`LayerStack` — a tile program
    shared by several networks is evaluated once per candidate, not once
    per cell.

    Built by :meth:`build` from cell :class:`PackSpec`s;
    ``repro.core.aidg.explorer.Explorer`` (``engine="packed"``, the
    default) routes ``evaluate`` / coordinate descent / the gradient
    engine through it.
    """

    def __init__(self, rows: List[_PackedRow], specs: List[PackSpec],
                 row_of: List[List[int]], n_knobs: int, n_iters: int):
        self.rows = rows
        self.specs = specs
        self.row_of = row_of          # per cell: global row id per problem
        self.n_knobs = n_knobs
        self.n_iters = n_iters
        self._arrays = None           # lazily-built jnp constant pytree
        self._buckets: Optional[List[List[int]]] = None
        self._compiled: Dict[Tuple, Callable] = {}

    # -- construction -------------------------------------------------------

    @staticmethod
    def build(specs: Sequence[PackSpec], n_knobs: int,
              n_iters: int = 2) -> "PackedMatrix":
        """Dedup problems across cells (by object identity — the scenario
        cache already shares repeated tile programs), condense each exactly
        once with its prologue boundary, and stage the packed arrays."""
        by_id: Dict[int, int] = {}
        staged: List[Tuple[DSEProblem, Tuple, int]] = []
        row_of: List[List[int]] = []
        for spec in specs:
            ids = []
            for prob, proj, k in zip(spec.problems, spec.projections,
                                     spec.prologue_len):
                rid = by_id.get(id(prob))
                if rid is None:
                    rid = len(staged)
                    by_id[id(prob)] = rid
                    staged.append([prob, proj, int(k)])
                else:
                    staged[rid][2] = max(staged[rid][2], int(k))
                ids.append(rid)
            row_of.append(ids)
        rows = [_stage_row(prob, proj, k) for prob, proj, k in staged]
        return PackedMatrix(rows, list(specs), row_of, n_knobs, n_iters)

    @property
    def n_rows(self) -> int:
        """Unique packed problems (the vmap-over-cells extent)."""
        return len(self.rows)

    @property
    def n_cells(self) -> int:
        """Matrix cells composed from the packed rows."""
        return len(self.specs)

    def stats(self) -> Dict[str, float]:
        """Aggregate packing/condensation statistics (for benchmarks and
        docs): total vs kept nodes, original vs condensed level totals,
        shape-bucket count, and the padded sequential scan total (one scan
        per bucket, all in one dispatch)."""
        conds = [r.cond for r in self.rows]
        lv0 = sum(c.stats["levels"] for c in conds)
        lv1 = sum(c.stats["levels_condensed"] for c in conds)
        buckets = self._bucketize()
        scan = sum(max(conds[i].schedule.n_levels for i in b)
                   for b in buckets)
        return {"rows": self.n_rows, "cells": self.n_cells,
                "nodes": sum(c.n for c in conds),
                "kept": sum(c.n_kept for c in conds),
                "levels": lv0, "levels_condensed": lv1,
                "level_reduction": lv0 / max(1, lv1),
                "buckets": len(buckets), "scan_len": scan}

    # -- packed constant arrays --------------------------------------------

    def _bucketize(self) -> List[List[int]]:
        """Group rows into shape buckets so padding waste stays bounded:
        the vmapped wavefront pads every bucket member to the bucket's
        (levels, width, preds) maxima, so a single global bucket would make
        every small cell pay the largest cell's scan — measured 20x+ WORSE
        than the per-cell loop on the default matrix.  Greedy assignment in
        descending per-row cost, joining a bucket only when the added
        padded work stays within 1.5x the row's own work.  All buckets
        still evaluate inside ONE jitted function (one dispatch).
        Memoized — ``stats`` and ``_build_arrays`` share one assignment."""
        if self._buckets is not None:
            return self._buckets
        rows = self.rows

        def qlen(i):   # sequential multi-slot queue steps (per iteration)
            return max((len(nd) for nd, _, _, sl, _ in rows[i].queues
                        if sl > 1), default=0)

        def rcost(i):
            c = rows[i].cond
            return (max(1, c.schedule.n_levels) * max(1, c.schedule.width)
                    * max(1, c.preds_lv.shape[1])
                    + self.n_iters * qlen(i) * 8)

        def bcost(members):
            lv = max(rows[i].cond.schedule.n_levels for i in members)
            w = max(rows[i].cond.schedule.width for i in members)
            p = max(rows[i].cond.preds_lv.shape[1] for i in members)
            q = self.n_iters * max(qlen(i) for i in members)
            return (len(members)
                    * (max(1, lv) * max(1, w) * max(1, p) + q * 8))

        order = sorted(range(len(rows)), key=lambda i: (-rcost(i), i))
        buckets: List[List[int]] = []
        # rows with affine chains never share a bucket with chain-free rows
        # (the in-window associative scan is a trace-time constant per
        # bucket, and it costs real per-step kernels)
        chainy = [rows[i].cond.stats["n_coupled"] > 0
                  for i in range(len(rows))]
        for i in order:
            best, best_delta = None, None
            for b in buckets:
                if chainy[b[0]] != chainy[i]:
                    continue
                delta = bcost(b + [i]) - bcost(b)
                if best_delta is None or delta < best_delta:
                    best, best_delta = b, delta
            if best is not None and best_delta <= 1.5 * rcost(i):
                best.append(i)
            else:
                buckets.append([i])
        self._buckets = buckets
        return buckets

    def _bucket_arrays(self, members: List[int]):
        """Stage one bucket's stacked jnp constants (dims = bucket maxima)."""
        rows = [self.rows[i] for i in members]
        K = self.n_knobs
        NK = max(r.cond.n_kept for r in rows)
        W = max(r.cond.schedule.width for r in rows)
        P = max(r.cond.preds_lv.shape[1] for r in rows)
        LV = max(r.cond.schedule.n_levels for r in rows)
        AB = max(1, max(r.cond.n_absorbed for r in rows))
        R = len(rows)

        fu = np.zeros((R, NK), np.float32)
        mem = np.zeros((R, NK), np.float32)
        base = np.full((R, NK), NEG, np.float32)
        opk = np.full((R, NK), K, np.int64)
        stk = np.full((R, NK), K, np.int64)
        nmask = np.zeros((R, NK), bool)
        prol = np.zeros((R, NK), bool)
        has_prol = np.zeros((R,), np.float32)
        preds = np.full((R, NK + W, P), -1, np.int32)
        const = np.zeros((R, NK + W, P), np.float32)
        pidx = np.full((R, NK + W, P), -1, np.int32)
        vc = np.full((R, NK + W), NEG, np.float32)
        vp = np.full((R, NK + W), -1, np.int32)
        starts = np.full((R, LV), NK, np.int32)
        ab_fu = np.zeros((R, AB), np.float32)
        ab_opk = np.full((R, AB), K, np.int64)
        ab_const = np.zeros((R, AB), np.float32)
        ab_seg = np.tile(np.arange(AB, dtype=np.int64), (R, 1))

        for i, r in enumerate(rows):
            c = r.cond
            nk, w, p = c.n_kept, c.schedule.width, c.preds_lv.shape[1]
            fu[i, :nk] = r.fu
            mem[i, :nk] = r.mem
            base[i, :nk] = r.base
            opk[i, :nk] = r.opk
            stk[i, :nk] = np.where(r.stk >= 0, r.stk, K)
            nmask[i, :nk] = True
            prol[i, :nk] = r.prol
            has_prol[i] = float(r.prol.any())
            preds[i, : nk + w, :p] = c.preds_lv
            const[i, : nk + w, :p] = c.const_lv
            pidx[i, : nk + w, :p] = c.pidx_lv
            vc[i, : nk + w] = c.v_const_lv
            vp[i, : nk + w] = c.v_pidx_lv
            starts[i, : c.schedule.n_levels] = c.schedule.starts
            na = c.n_absorbed
            if na:
                ab_fu[i, :na] = r.ab_fu
                ab_opk[i, :na] = r.ab_opk
                ab_const[i, :na] = c.ab_const
                ab_seg[i, :na] = c.ab_segstart

        # storage queues in four families — (single-slot | multi-slot) x
        # (statically-ordered | dynamic) — padded over (row, storage,
        # access); ordered families skip the per-candidate argsort
        def select(r, single, ordered):
            return [(nd, lat, kn, sl) for nd, lat, kn, sl, o in r.queues
                    if (sl == 1) == single and o == ordered]

        J = jnp.asarray
        groups = {}
        for key, single, ordered in (("s1o", True, True),
                                     ("s1d", True, False),
                                     ("smo", False, True),
                                     ("smd", False, False)):
            sel = [select(r, single, ordered) for r in rows]
            NS = max(1, max(len(s) for s in sel))
            SA = max(1, max((len(nd) for s in sel for nd, _, _, _ in s),
                            default=1))
            SL = max(1, max((sl for s in sel for _, _, _, sl in s),
                            default=1))
            g_nd = np.full((R, NS, SA), -1, np.int64)
            g_lat = np.zeros((R, NS, SA), np.float32)
            g_kn = np.full((R, NS), K, np.int64)
            g_sl = np.ones((R, NS), np.int32)
            present = False
            for i, s in enumerate(sel):
                for si, (nd, lat, kn, sl) in enumerate(s):
                    g_nd[i, si, : len(nd)] = nd
                    g_lat[i, si, : len(nd)] = lat
                    g_kn[i, si] = kn
                    g_sl[i, si] = sl
                    present = True
            groups[key] = dict(nd=J(g_nd), lat=J(g_lat), kn=J(g_kn),
                               sl=J(g_sl), SL=SL, present=present)

        return dict(
            NK=NK, W=W, P=P, LV=LV, AB=AB,
            has_chains=any(r.cond.stats["n_coupled"] > 0 for r in rows),
            fu=J(fu), mem=J(mem), base=J(base), opk=J(opk), stk=J(stk),
            nmask=J(nmask), prol=J(prol), has_prol=J(has_prol),
            preds=J(preds), const=J(const), pidx=J(pidx), vc=J(vc), vp=J(vp),
            starts=J(starts),
            ab_fu=J(ab_fu), ab_opk=J(ab_opk), ab_const=J(ab_const),
            ab_seg=J(ab_seg), queues=groups)

    def _build_arrays(self):
        if self._arrays is not None:
            return self._arrays
        buckets = self._bucketize()
        bucket_arrays = [self._bucket_arrays(b) for b in buckets]
        # inverse permutation: concatenated bucket outputs -> global row ids
        flat = [i for b in buckets for i in b]
        inv = np.empty(len(flat), np.int64)
        inv[flat] = np.arange(len(flat))

        # composition arrays over cells (global row ids)
        CL = len(self.specs)
        RU = max(1, max(len(s.run_layer) for s in self.specs))
        runs = np.zeros((CL, RU), np.int64)
        reps = np.zeros((CL, RU), np.float32)
        fw = np.zeros((CL, RU), np.float32)
        fb = np.zeros((CL, max(1, RU - 1)), np.float32)
        # per-cell dynamic-energy knob vectors: Σ_runs reps · edyn[layer]
        # (energy is work — overlap shortens the makespan, not the joules)
        edyn_c = np.zeros((CL, self.n_knobs + 1), np.float64)
        pstat = np.zeros((CL,), np.float64)
        for ci, spec in enumerate(self.specs):
            nr = len(spec.run_layer)
            runs[ci, :nr] = np.asarray(self.row_of[ci])[spec.run_layer]
            reps[ci, :nr] = spec.run_reps
            fw[ci, :nr] = spec.fits_within
            if nr > 1:
                fb[ci, : nr - 1] = spec.fits_between
            if spec.edyn:
                for li, r in zip(spec.run_layer, spec.run_reps):
                    edyn_c[ci] += float(r) * np.asarray(spec.edyn[int(li)],
                                                        np.float64)
            pstat[ci] = spec.static_pj

        J = jnp.asarray
        self._arrays = dict(
            buckets=bucket_arrays, inv=J(inv), RU=RU,
            runs=J(runs), reps=J(reps), fw=J(fw), fb=J(fb),
            edyn=J(edyn_c.astype(np.float32)),
            pstat=J(pstat.astype(np.float32)))
        return self._arrays

    # -- the traced evaluator ----------------------------------------------

    _ROW_KEYS = ("fu", "mem", "base", "opk", "stk", "nmask", "prol",
                 "has_prol", "preds", "const", "pidx", "vc", "vp", "starts",
                 "ab_fu", "ab_opk", "ab_const", "ab_seg")

    def _row_fn(self, A, soft: bool):
        """One packed row's fixed point: (row-array dict, kn, tau) ->
        (makespan, prologue completion).  Python-level ``soft`` selects the
        hard max family or the τ-tempered LSE family at trace time; the
        queue families' static attributes (slot width, ordered-ness,
        presence) specialize the trace per bucket."""
        NK, W = A["NK"], A["W"]
        n_iters = self.n_iters
        qstatic = [(key, g["SL"], key.startswith("s1"), key.endswith("o"))
                   for key, g in A["queues"].items() if g["present"]]

        def fn(args, kn, tau):
            (fu, mem, base0, opk, stk, nmask, prol, has_prol, preds, const,
             pidx, vc, vp, starts, ab_fu, ab_opk, ab_const, ab_seg) = (
                args[k] for k in self._ROW_KEYS)
            if soft:
                floor = lambda x: softmaximum(jnp.float32(1.0), x, tau)
                reduce2 = lambda a, b: softmaximum(a, b, tau)
            else:
                floor = lambda x: jnp.maximum(jnp.float32(1.0), x)
                reduce2 = jnp.maximum
            w = floor(fu * kn[opk] + mem * kn[stk])
            aw = floor(ab_fu * kn[ab_opk]) + ab_const
            tot0 = jnp.concatenate([jnp.zeros((1,), jnp.float32),
                                    jnp.cumsum(aw)])
            prefix = tot0[1:] - tot0[ab_seg]
            extra = const + jnp.where(pidx >= 0,
                                      prefix[jnp.maximum(pidx, 0)], 0.0)
            w_pad = jnp.concatenate([w, jnp.zeros((W,), jnp.float32)])
            v_lv = jnp.where(
                vc > NEG / 2,
                vc + jnp.where(vp >= 0, prefix[jnp.maximum(vp, 0)], 0.0)
                + w_pad, NEG)

            def relax(b):
                return condensed_scan(w, b, extra, v_lv, preds, starts,
                                      tau=tau if soft else None,
                                      has_chains=A["has_chains"])

            def q_single(ordered):
                def q(nd0, lat0, knob, t):
                    msk = nd0 >= 0
                    nd = jnp.maximum(nd0, 0)
                    lat = lat0 * kn[knob]
                    arr = jnp.where(msk, t[nd] - w[nd], _BIG)
                    if ordered:   # provably static order: argsort = id
                        arr_s, lat_s = arr, lat
                    else:
                        o = jnp.argsort(arr)
                        arr_s, lat_s = arr[o], lat[o]
                    S = jnp.cumsum(lat_s)
                    z = arr_s - S + lat_s
                    if soft:
                        done_s = S + tau * jax.lax.cumlogsumexp(z / tau)
                    else:
                        done_s = S + jax.lax.cummax(z)
                    if ordered:
                        done = done_s
                    else:   # inverse permutation by scatter, not a 2nd sort
                        inv = (jnp.zeros_like(o).at[o]
                               .set(jnp.arange(o.shape[0])))
                        done = done_s[inv]
                    need = jnp.where(msk, done + fu[nd] - w[nd], NEG)
                    return jnp.where(msk, nd, NK), need
                return q

            def q_multi(ordered, SL):
                def q(nd0, lat0, knob, slots, t):
                    msk = nd0 >= 0
                    nd = jnp.maximum(nd0, 0)
                    lat = lat0 * kn[knob]
                    arr = jnp.where(msk, t[nd] - w[nd], _BIG)
                    if ordered:
                        arr_s, lat_s = arr, lat
                    else:
                        o = jnp.argsort(arr)
                        arr_s, lat_s = arr[o], lat[o]

                    def step(free, inp):
                        a, l = inp
                        k = jnp.argmin(free)   # earliest-free slot
                        done = reduce2(a, free[k]) + l
                        return free.at[k].set(done), done

                    free0 = jnp.where(jnp.arange(SL) < slots, 0.0, _BIG)
                    _, done_s = jax.lax.scan(step, free0, (arr_s, lat_s))
                    if ordered:
                        done = done_s
                    else:
                        inv = (jnp.zeros_like(o).at[o]
                               .set(jnp.arange(o.shape[0])))
                        done = done_s[inv]
                    need = jnp.where(msk, done + fu[nd] - w[nd], NEG)
                    return jnp.where(msk, nd, NK), need
                return q

            t = relax(base0)
            for _ in range(n_iters):
                need_full = jnp.full((NK + 1,), NEG, jnp.float32)
                for key, SL, single, ordered in qstatic:
                    qa = args["queues"][key]
                    if single:
                        nd_g, need_g = jax.vmap(
                            q_single(ordered), in_axes=(0, 0, 0, None))(
                            qa["nd"], qa["lat"], qa["kn"], t)
                    else:
                        nd_g, need_g = jax.vmap(
                            q_multi(ordered, SL),
                            in_axes=(0, 0, 0, 0, None))(
                            qa["nd"], qa["lat"], qa["kn"], qa["sl"], t)
                    need_full = need_full.at[nd_g.reshape(-1)].max(
                        need_g.reshape(-1))
                if soft:
                    b = softmaximum(base0, need_full[:NK], tau)
                else:
                    b = jnp.maximum(base0, need_full[:NK])
                t = relax(b)

            tm = jnp.where(nmask, t, NEG)
            tp = jnp.where(prol, t, NEG)
            if soft:
                m = softmax_reduce(tm, tau)
                p = softmax_reduce(tp, tau)
            else:
                m = tm.max()
                p = tp.max()
            return m, jnp.where(has_prol > 0, p, 0.0)

        return fn

    def _matrix_fn(self, soft: bool):
        """knobs (K,) [, tau] -> per-cell ``(cycles (S,), energy (S,))``,
        fully traced: one vmapped wavefront fixed point per shape bucket
        (all inside the one trace), bucket outputs re-ordered to global
        rows, then the run-length composition per cell.  The energy
        objective rides the SAME trace — one pre-folded matvec
        ``edyn @ (1/θ)`` plus the static term ``P_static · cycles`` — so a
        3-objective evaluation is still a single dispatch with no second
        pass."""
        A = self._build_arrays()

        def bucket_args(BA):
            d = {k: BA[k] for k in self._ROW_KEYS}
            d["queues"] = {key: {f: g[f] for f in ("nd", "lat", "kn", "sl")}
                           for key, g in BA["queues"].items()
                           if g["present"]}
            return d

        per_bucket = [(self._row_fn(BA, soft), bucket_args(BA))
                      for BA in A["buckets"]]
        inv = A["inv"]
        runs, reps, fw, fb = A["runs"], A["reps"], A["fw"], A["fb"]
        edyn, pstat = A["edyn"], A["pstat"]
        RU = A["RU"]

        def fn(knobs, tau):
            kn = jnp.concatenate([knobs.astype(jnp.float32),
                                  jnp.ones((1,), jnp.float32)])
            ms, ps = [], []
            for row_fn, row_args in per_bucket:
                m_b, p_b = jax.vmap(row_fn, in_axes=(0, None, None))(
                    row_args, kn, tau)
                ms.append(m_b)
                ps.append(p_b)
            m = jnp.concatenate(ms)[inv]
            p = jnp.concatenate(ps)[inv]
            mr, pr = m[runs], p[runs]
            clip = ((lambda a, b: -softmaximum(-a, -b, tau)) if soft
                    else jnp.minimum)
            total = (reps * mr).sum(axis=-1)
            within = ((reps - 1.0) * clip(pr, mr) * fw).sum(axis=-1)
            if RU > 1:
                between = (clip(pr[:, 1:], mr[:, :-1]) * fb).sum(axis=-1)
            else:
                between = 0.0
            cycles = total - within - between
            # DVFS-style dynamic term (faster units burn more pJ per op)
            # plus leakage over the makespan — analytic in θ, and the
            # static part differentiates through the soft makespan
            energy = edyn @ (1.0 / kn) + pstat * cycles
            return cycles, energy

        return fn

    # -- public evaluation surface -----------------------------------------

    def _full_fn(self) -> Callable:
        """Cached ``jit(vmap)`` hard evaluator of the FULL objective tuple:
        ``fn(knobs (B, K)) -> ((B, S) cycles, (B, S) energy pJ)`` — the
        whole matrix in one dispatch, energy in the same trace."""
        fn = self._compiled.get("hard")
        if fn is None:
            f = self._matrix_fn(soft=False)
            fn = jax.jit(jax.vmap(lambda k: f(k, jnp.float32(1.0))))
            self._compiled["hard"] = fn
        return fn

    def evaluate_fn(self) -> Callable:
        """The cycles-only view of :meth:`_full_fn`:
        ``fn(knobs (B, K)) -> (B, S) cycles`` (same compiled dispatch)."""
        full = self._full_fn()
        return lambda kt: full(kt)[0]

    def n_shards(self, n_devices: Optional[int] = None) -> int:
        """Devices the sharded evaluator spreads the candidate axis over:
        ``n_devices`` capped by what the backend exposes (force more host
        CPU devices with ``XLA_FLAGS=--xla_force_host_platform_device_count
        =8``), all local devices when ``None``."""
        avail = jax.local_device_count()
        if n_devices is None:
            return avail
        if not (1 <= n_devices <= avail):
            raise ValueError(f"n_devices must be in [1, {avail}], "
                             f"got {n_devices}")
        return int(n_devices)

    def sharded_fn(self, n_devices: Optional[int] = None) -> Callable:
        """Cached device-sharded hard evaluator: ``fn(knobs (B, K)) ->
        ((B, S) cycles, (B, S) energy)`` with the CANDIDATE axis split
        across ``n_shards`` devices via ``shard_map`` (``pmap`` fallback
        on JAX builds without it) — each device runs the same vmapped
        packed evaluator over its B/D slice, so results are bitwise
        identical to the single-device path (per-candidate rows are
        independent; asserted by ``tests/test_serve.py``).  B must be a
        multiple of the device count — ``evaluate(sharded=True)`` pads
        for you."""
        D = self.n_shards(n_devices)
        key = ("sharded", D)
        fn = self._compiled.get(key)
        if fn is None:
            f = self._matrix_fn(soft=False)
            batched = jax.vmap(lambda k: f(k, jnp.float32(1.0)))
            devices = jax.local_devices()[:D]
            try:
                from jax.experimental.shard_map import shard_map
                from jax.sharding import Mesh, PartitionSpec as P
                mesh = Mesh(np.asarray(devices), ("cand",))
                fn = jax.jit(shard_map(batched, mesh=mesh,
                                       in_specs=P("cand"),
                                       out_specs=(P("cand"), P("cand"))))
            except ImportError:       # pre-shard_map JAX: explicit pmap
                pfn = jax.pmap(batched, devices=devices)

                def fn(kt, _pfn=pfn, _D=D):
                    B = kt.shape[0]
                    c, en = _pfn(kt.reshape(_D, B // _D, kt.shape[1]))
                    return c.reshape(B, -1), en.reshape(B, -1)
            self._compiled[key] = fn
        return fn

    def evaluate(self, knob_thetas: np.ndarray,
                 chunk: Optional[int] = None, sharded: bool = False,
                 n_devices: Optional[int] = None) -> np.ndarray:
        """(B, n_knobs) candidates -> (B, S) estimated cycles.

        ``chunk`` bounds peak memory; every partial chunk is padded to the
        compiled batch shape (no per-remainder re-trace).  ``sharded``
        splits the candidate axis across ``n_devices`` local devices
        (``sharded_fn``) for near-linear multi-device throughput with
        bitwise-identical results; the batch is padded with θ = 1 rows up
        to a device multiple and sliced back."""
        return self.evaluate_full(knob_thetas, chunk=chunk, sharded=sharded,
                                  n_devices=n_devices)[0]

    def evaluate_full(self, knob_thetas: np.ndarray,
                      chunk: Optional[int] = None, sharded: bool = False,
                      n_devices: Optional[int] = None
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """(B, n_knobs) candidates -> ``((B, S) cycles, (B, S) energy
        pJ)``, both objectives from the SAME compiled dispatch (energy is
        one folded matvec plus the static term inside the latency trace —
        see :meth:`_matrix_fn`); cells built without energy coefficients
        report 0.  Options as :meth:`evaluate`."""
        if sharded:
            mult = self.n_shards(n_devices)
            fn = self.sharded_fn(mult)
        else:
            mult = 1
            fn = self._full_fn()
        kt = jnp.asarray(np.atleast_2d(np.asarray(knob_thetas, np.float32)))
        B = kt.shape[0]

        def run(block, rows):
            """Evaluate ``block`` padded with θ = 1 rows up to ``rows``."""
            n = block.shape[0]
            if n < rows:
                block = jnp.concatenate(
                    [block, jnp.ones((rows - n, kt.shape[1]), jnp.float32)])
            c, en = fn(block)
            return np.asarray(c)[:n], np.asarray(en)[:n]

        up = lambda n: -(-n // mult) * mult   # round up to device multiple
        if chunk is None or B <= chunk:
            return run(kt, up(B))
        step = up(chunk)
        out_c = np.empty((B, self.n_cells), dtype=np.float32)
        out_e = np.empty((B, self.n_cells), dtype=np.float32)
        for s in range(0, B, step):
            e = min(s + step, B)
            out_c[s:e], out_e[s:e] = run(kt[s:e], step)
        return out_c, out_e

    def export_training_table(self, knob_thetas: np.ndarray,
                              chunk: Optional[int] = None
                              ) -> Dict[str, np.ndarray]:
        """Sweep-output export for surrogate training
        (``repro.surrogate``): evaluate ``(N, n_knobs)`` candidates plus
        the θ = 1 reference in ONE chunked pass and return the
        self-describing table ``{"theta" (N, K), "cycles" (N, S),
        "energy" (N, S), "cycles_base" (S,), "energy_base" (S,)}`` —
        baselines from the same dispatch, so ratios are exactly the
        quantities the packed engine normalizes by."""
        kt = np.atleast_2d(np.asarray(knob_thetas, np.float32))
        stacked = np.concatenate(
            [np.ones((1, kt.shape[1]), np.float32), kt], axis=0)
        cycles, energy = self.evaluate_full(stacked, chunk=chunk)
        return {"theta": kt,
                "cycles": cycles[1:], "energy": energy[1:],
                "cycles_base": np.asarray(cycles[0], np.float64),
                "energy_base": np.asarray(energy[0], np.float64)}

    def grad_fn(self, baselines: np.ndarray) -> Callable:
        """Cached ``jit(vmap(value_and_grad))`` over the soft family:
        ``fn(knobs (B, K), tau) -> (mean normalized latency (B,),
        d latency / d knob (B, K))`` — the whole matrix's end-to-end
        gradient in one dispatch (τ traced, annealing never re-traces)."""
        key = ("grad", np.asarray(baselines, np.float64).tobytes())
        fn = self._compiled.get(key)
        if fn is None:
            f = self._matrix_fn(soft=True)
            bl = jnp.asarray(baselines, jnp.float32)

            def val(knobs, tau):
                return (f(knobs, tau)[0] / bl).mean()

            fn = jax.jit(jax.vmap(jax.value_and_grad(val),
                                  in_axes=(0, None)))
            self._compiled[key] = fn
        return fn

    def grad3_fn(self, baselines: np.ndarray,
                 energy_baselines: np.ndarray) -> Callable:
        """Cached multi-objective gradient dispatch over the soft family:
        ``fn(knobs (B, K), tau) -> (values (B, 2), jacobian (B, 2, K))``
        where row 0 is mean normalized latency and row 1 mean normalized
        energy — one ``jacrev`` through the shared soft trace, so the
        energy gradient (analytic ``-edyn_k/θ_k²`` plus the static term
        through the soft makespan) costs no extra dispatch."""
        key = ("grad3", np.asarray(baselines, np.float64).tobytes(),
               np.asarray(energy_baselines, np.float64).tobytes())
        fn = self._compiled.get(key)
        if fn is None:
            f = self._matrix_fn(soft=True)
            bl = jnp.asarray(baselines, jnp.float32)
            ebl = jnp.asarray(np.maximum(
                np.asarray(energy_baselines, np.float64), 1e-30), jnp.float32)

            def vals(knobs, tau):
                c, en = f(knobs, tau)
                return jnp.stack([(c / bl).mean(), (en / ebl).mean()])

            def vg(knobs, tau):
                return vals(knobs, tau), jax.jacrev(vals)(knobs, tau)

            fn = jax.jit(jax.vmap(vg, in_axes=(0, None)))
            self._compiled[key] = fn
        return fn
