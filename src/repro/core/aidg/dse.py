"""Design-space exploration over ACADL accelerator parameters (paper §1/§7:
"the timing simulation can be used in the optimization loop of
hardware-aware NAS and DNN/HW Co-Design").

The AIDG separates *structure* (the dependency DAG, built once per
workload) from *weights* (per-instruction latencies).  Latencies are
re-parameterized as multiplicative factors over the baseline:

    fu_lat_i(θ)  = θ_op[op_class_i]    · fu_lat_i
    mem_lat_i(θ) = θ_st[storage(i)]    · mem_lat_i

so θ = 1 reproduces the modeled accelerator exactly, θ_op[gemm@mxu#] = 0.5
models a 2× faster matrix unit, θ_st[hbm#] = 2 a half-bandwidth memory, etc.
``sweep`` evaluates thousands of candidate accelerators in one batched JAX
call via ``vmap`` over θ — the trace and graph are never rebuilt.

Because the whole evaluator is JAX end-to-end, the makespan is also
*differentiable in θ*: ``evaluate_theta_soft`` swaps the hard max-plus
engine for the temperature-τ smooth family (``maxplus.fixed_point_soft``)
and ``grad_sweep`` returns a cached ``jit(vmap(value_and_grad))`` that maps
a batch of *shared knob vectors* straight to (soft cycles, d cycles / d
knob) — the chain through ``DesignSpace.projection`` is part of the traced
function, so gradients land on the few shared knobs rather than the
per-scenario θ columns.  ``repro.core.aidg.gradient`` turns this into a
projected-Adam design-space optimizer.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .builder import AIDG, CompiledAIDG, compile_aidg, longest_path_fixed_point
from .maxplus import (DEFAULT_ENGINE, fixed_point_jax, fixed_point_soft,
                      softmax_reduce, softmaximum)

__all__ = ["DSEProblem", "make_problem", "evaluate_theta", "compiled_sweep",
           "sweep", "evaluate_theta_soft", "grad_sweep", "LayerStack",
           "NETWORK_MODES", "compiled_network_sweep", "grad_network_sweep"]


@dataclass
class DSEProblem:
    """One workload's parameterized timing model: the immutable AIDG plus
    the gather maps that turn a θ vector (one factor per op class / storage
    class) into per-node latency scalings, and the per-problem cache of
    compiled evaluators.  Built once per (architecture, workload) cell by
    ``make_problem``; every sweep re-weights this structure."""

    aidg: AIDG
    op_names: List[str]          # op-class index -> name
    storage_names: List[str]     # storage-class index -> name
    # per-node gather indices
    node_op: np.ndarray          # (n,) int32
    node_storage: Dict[str, int] = field(default_factory=dict)  # name -> id
    # build-time compilation artifact (level schedule + padded gathers),
    # shared by every sweep over this problem
    caidg: Optional[CompiledAIDG] = None
    # (n_iters, engine) -> jitted vmapped evaluator, and
    # ("grad", n_iters, projection bytes) -> jitted vmapped value_and_grad
    # (jax.jit caches by function identity, so re-creating the lambda per
    # sweep() would re-trace)
    _compiled: Dict[Tuple, Callable] = field(default_factory=dict, repr=False)

    @property
    def n_op(self) -> int:
        """Number of op classes = columns of a θ_op candidate row."""
        return len(self.op_names)

    @property
    def n_st(self) -> int:
        """Number of storage classes = columns of a θ_st candidate row."""
        return len(self.storage_names)

    @property
    def compiled_aidg(self) -> CompiledAIDG:
        """The build-time compile artifact (level schedule + gathers)."""
        if self.caidg is None:  # hand-built problems compile lazily
            self.caidg = compile_aidg(self.aidg)
        return self.caidg


def make_problem(aidg: AIDG) -> DSEProblem:
    """AIDG -> DSEProblem: name the op/storage classes, build the per-node
    gather indices, and run the build-time compile pipeline
    (``compile_aidg``) so every sweep shares one level schedule."""
    op_names = [None] * len(aidg.classes)
    for name, idx in aidg.classes.items():
        op_names[idx] = name
    st_names = sorted(aidg.storage_nodes.keys())
    return DSEProblem(aidg=aidg, op_names=op_names, storage_names=st_names,
                      node_op=aidg.op_class,
                      node_storage={s: i for i, s in enumerate(st_names)},
                      caidg=compile_aidg(aidg))


def _reweight(prob: DSEProblem, theta_op: jnp.ndarray, theta_st: jnp.ndarray,
              floor: Callable = jnp.maximum
              ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray], jnp.ndarray]:
    """θ -> (per-node work, scaled storage latencies, scaled fu latencies).
    ``floor`` applies the 1-cycle occupancy minimum — ``jnp.maximum`` on
    the hard path, a τ-``softmaximum`` on the smooth one (one shared
    re-weighting, so hard and soft evaluators can't drift apart)."""
    aidg = prob.aidg
    fu = jnp.asarray(aidg.fu_lat) * theta_op[prob.node_op]
    mem_scale = jnp.ones(aidg.n, dtype=jnp.float32)
    st_lat: Dict[str, jnp.ndarray] = {}
    for st, cid in prob.node_storage.items():
        nodes = aidg.storage_nodes[st]
        st_lat[st] = jnp.asarray(aidg.storage_lat[st]) * theta_st[cid]
        mem_scale = mem_scale.at[jnp.asarray(nodes)].set(theta_st[cid])
    mem = jnp.asarray(aidg.mem_lat) * mem_scale
    work = floor(jnp.float32(1.0), fu + mem)
    return work, st_lat, fu


def evaluate_theta(prob: DSEProblem, theta_op: jnp.ndarray,
                   theta_st: jnp.ndarray, n_iters: int = 2,
                   engine: str = DEFAULT_ENGINE) -> jnp.ndarray:
    """Estimated cycles for one parameter point (jit/vmap-able)."""
    work, st_lat, fu = _reweight(prob, theta_op, theta_st)
    # fixed_point_jax reads fu_lat for the queueing fold-back; the scaled fu
    # enters through `work`, so pass base/work/storage latencies explicitly.
    # The CompiledAIDG carries the level schedule, built once per scenario.
    t = fixed_point_jax(prob.compiled_aidg, n_iters=n_iters, work=work,
                        storage_lat=st_lat, engine=engine)
    return t.max()


def compiled_sweep(prob: DSEProblem, n_iters: int = 2,
                   engine: str = DEFAULT_ENGINE) -> Callable:
    """Cached jit(vmap) evaluator for ``prob``: (B, n_op), (B, n_st) ->
    (B,) cycles.  The first call per (problem, n_iters, engine) traces;
    every later sweep over the same AIDG re-uses the compiled kernel — the
    property the multi-scenario explorer relies on for its configs/sec
    throughput."""
    fn = prob._compiled.get((n_iters, engine))
    if fn is None:
        f = lambda to, ts: evaluate_theta(prob, to, ts, n_iters=n_iters,
                                          engine=engine)
        fn = jax.jit(jax.vmap(f))
        prob._compiled[(n_iters, engine)] = fn
    return fn


def sweep(prob: DSEProblem, thetas_op: np.ndarray, thetas_st: np.ndarray,
          n_iters: int = 2, batched: bool = True,
          chunk: Optional[int] = None,
          engine: str = DEFAULT_ENGINE) -> np.ndarray:
    """Evaluate a batch of candidate accelerators.

    ``thetas_op``: (B, n_op), ``thetas_st``: (B, n_st) -> (B,) cycles.
    One ``vmap`` + ``jit`` over the whole batch: the DSE loop the paper
    motivates, shaped for a single device launch.

    ``chunk``: split very large batches into fixed-size device launches to
    bound peak memory (the tail chunk is padded to ``chunk`` rows so the
    compiled kernel is reused rather than re-traced per remainder shape).

    ``engine``: the DAG relaxation used inside the fixed point —
    ``"wavefront"`` (default, level-scheduled), ``"scan"`` (per-node), or
    ``"blocked"`` (max-plus closure blocks).
    """
    if chunk is not None and chunk <= 0:
        raise ValueError(f"chunk must be positive, got {chunk}")
    if not batched:
        f = lambda to, ts: evaluate_theta(prob, to, ts, n_iters=n_iters,
                                          engine=engine)
        return np.asarray([f(jnp.asarray(a), jnp.asarray(b))
                           for a, b in zip(thetas_op, thetas_st)])
    fn = compiled_sweep(prob, n_iters, engine)
    to = jnp.asarray(thetas_op, jnp.float32)
    ts = jnp.asarray(thetas_st, jnp.float32)
    B = to.shape[0]
    if chunk is None or B <= chunk:
        return np.asarray(fn(to, ts))
    out = np.empty(B, dtype=np.float32)
    for s in range(0, B, chunk):
        e = min(s + chunk, B)
        if e - s < chunk:  # pad the tail to the compiled batch shape
            pad = chunk - (e - s)
            co = jnp.concatenate([to[s:e], jnp.ones((pad, to.shape[1]),
                                                    jnp.float32)])
            cs = jnp.concatenate([ts[s:e], jnp.ones((pad, ts.shape[1]),
                                                    jnp.float32)])
            out[s:e] = np.asarray(fn(co, cs))[: e - s]
        else:
            out[s:e] = np.asarray(fn(to[s:e], ts[s:e]))
    return out


# ---------------------------------------------------------------------------
# smooth evaluation + knob-space gradients (the co-design inner loop)
# ---------------------------------------------------------------------------


def evaluate_theta_soft(prob: DSEProblem, theta_op: jnp.ndarray,
                        theta_st: jnp.ndarray, tau, n_iters: int = 2
                        ) -> jnp.ndarray:
    """Smooth estimated cycles for one parameter point: the τ-tempered
    counterpart of ``evaluate_theta`` (soft occupancy floor, soft wavefront
    fixed point, soft makespan reduction).  Upper-bounds the hard estimate
    and converges to it as τ → 0; smooth in (θ_op, θ_st) everywhere — the
    hard ``max(1, fu + mem)`` floor would have zero gradient wherever θ has
    pushed a node under it, killing descent directions exactly where fast
    hardware stops paying, so the floor is softened too."""
    work, st_lat, _ = _reweight(prob, theta_op, theta_st,
                                floor=lambda a, b: softmaximum(a, b, tau))
    t = fixed_point_soft(prob.compiled_aidg, tau=tau, n_iters=n_iters,
                         work=work, storage_lat=st_lat)
    return softmax_reduce(t, tau)


def grad_sweep(prob: DSEProblem, op_idx: np.ndarray, st_idx: np.ndarray,
               n_iters: int = 2) -> Callable:
    """Cached ``jit(vmap(value_and_grad))`` from *shared knob space*:
    ``fn(knobs (B, K), tau) -> (soft cycles (B,), d cycles/d knob (B, K))``.

    ``op_idx`` / ``st_idx`` are ``DesignSpace.projection(prob)`` gather maps
    (op-class/storage -> knob, with K = identity column); baking them into
    the traced function chains the projection inside autodiff, so the
    returned gradient is already in the K shared knobs — no per-scenario θ
    chain rule on the host.  τ is traced: annealing re-uses the kernel."""
    op_idx = np.asarray(op_idx, np.int64)
    st_idx = np.asarray(st_idx, np.int64)
    key = ("grad", n_iters, op_idx.tobytes(), st_idx.tobytes())
    fn = prob._compiled.get(key)
    if fn is None:
        oi, si = jnp.asarray(op_idx), jnp.asarray(st_idx)

        def f(knobs, tau):
            padded = jnp.concatenate(
                [knobs, jnp.ones((1,), knobs.dtype)])   # identity column
            return evaluate_theta_soft(prob, padded[oi], padded[si], tau,
                                       n_iters=n_iters)

        fn = jax.jit(jax.vmap(jax.value_and_grad(f), in_axes=(0, None)))
        prob._compiled[key] = fn
    return fn


# ---------------------------------------------------------------------------
# stacked per-layer programs: whole-network end-to-end latency
# ---------------------------------------------------------------------------

NETWORK_MODES = ("sequential", "pipelined")


@dataclass
class LayerStack:
    """A whole network as a *stack* of per-layer DSE problems plus the
    max-plus composition structure (built by ``repro.core.network``).

    ``problems[u]`` is the AIDG of one **unique** layer program; the
    network's execution order is a sequence of *runs* — maximal stretches
    of ``run_reps[r]`` consecutive instances of unique layer
    ``run_layer[r]`` (a transformer's 16 identical blocks are one run of
    16, a tiled operator's ``tiles`` repeats fold in multiplicatively).

    ``prologue_len[u]`` is the static length of the layer's load-only
    instruction prefix (no compute op has executed yet): its completion
    time is the part of the layer a *double-buffered* pipeline can overlap
    with the previous layer's tail.  ``fits_within[r]`` / ``fits_between[r]``
    are 0/1 capacity gates — overlap is only credited when the two layers'
    stationary working sets fit the architecture's on-chip buffer together.

    Composition (per candidate, all in the traced function):

    * ``sequential``: Σ_r reps_r · m_{l(r)} — every instance back-to-back,
      the mode whose θ = 1 value matches the per-layer event-sim oracle
      composition exactly.
    * ``pipelined``: the sequential total minus the credited overlaps
      min(p_next, m_prev) — never below any single layer, never above the
      sequential total.
    """

    problems: List[DSEProblem]
    prologue_len: np.ndarray        # (L,) int   — load-only prefix length
    run_layer: np.ndarray           # (R,) int   — unique-layer id per run
    run_reps: np.ndarray            # (R,) float — instances per run
    fits_within: np.ndarray         # (R,) float — 0/1 double-buffer gate
    fits_between: np.ndarray        # (R-1,) float — 0/1 gate to next run
    _compiled: Dict[Tuple, Callable] = field(default_factory=dict, repr=False)

    @property
    def n_layers(self) -> int:
        """Unique per-layer programs in the stack (the compile unit)."""
        return len(self.problems)

    @property
    def instances(self) -> float:
        """Total layer instances composed end-to-end (Σ run reps)."""
        return float(np.asarray(self.run_reps, np.float64).sum())


def _layer_times(prob: DSEProblem, theta_op: jnp.ndarray,
                 theta_st: jnp.ndarray, n_iters: int, engine: str,
                 k_prologue: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One layer's (makespan, prologue completion) at θ — the prologue is
    the hard max over the first ``k_prologue`` (load-only) instructions."""
    work, st_lat, _ = _reweight(prob, theta_op, theta_st)
    t = fixed_point_jax(prob.compiled_aidg, n_iters=n_iters, work=work,
                        storage_lat=st_lat, engine=engine)
    p = t[:k_prologue].max() if k_prologue > 0 else jnp.float32(0.0)
    return t.max(), p


def _compose(stack: LayerStack, m: jnp.ndarray, p: jnp.ndarray, mode: str,
             minimum: Callable = jnp.minimum) -> jnp.ndarray:
    """(L,) per-unique-layer makespans/prologues -> end-to-end cycles.
    ``minimum`` is the overlap clip — ``jnp.minimum`` on the hard path, a
    τ-softmin on the smooth one (overlap can't exceed the previous layer's
    makespan or the next layer's prologue)."""
    rl = jnp.asarray(stack.run_layer)
    reps = jnp.asarray(stack.run_reps, jnp.float32)
    mr, pr = m[rl], p[rl]
    total = (reps * mr).sum()
    if mode == "sequential":
        return total
    fw = jnp.asarray(stack.fits_within, jnp.float32)
    within = ((reps - 1.0) * minimum(pr, mr) * fw).sum()
    if stack.run_layer.shape[0] > 1:
        fb = jnp.asarray(stack.fits_between, jnp.float32)
        between = (minimum(pr[1:], mr[:-1]) * fb).sum()
    else:
        between = jnp.float32(0.0)
    return total - within - between


def compiled_network_sweep(stack: LayerStack, n_iters: int = 2,
                           engine: str = DEFAULT_ENGINE,
                           mode: str = "sequential") -> Callable:
    """Cached jit(vmap) end-to-end evaluator for a layer stack:
    ``fn(tuple of (B, n_op_l), tuple of (B, n_st_l)) -> (B,) cycles``.

    The per-layer wavefronts and the max-plus composition live in ONE
    traced function, so a candidate batch costs one device launch per
    network cell regardless of depth — and repeated layers are evaluated
    once per unique program, not once per instance."""
    if mode not in NETWORK_MODES:
        raise ValueError(f"mode must be one of {NETWORK_MODES}, got {mode!r}")
    key = (n_iters, engine, mode)
    fn = stack._compiled.get(key)
    if fn is None:
        ks = [int(k) for k in stack.prologue_len]

        def f(tos, tss):
            times = [_layer_times(prob, to, ts, n_iters, engine, k)
                     for prob, k, to, ts
                     in zip(stack.problems, ks, tos, tss)]
            m = jnp.stack([t[0] for t in times])
            p = jnp.stack([t[1] for t in times])
            return _compose(stack, m, p, mode)

        fn = jax.jit(jax.vmap(f))
        stack._compiled[key] = fn
    return fn


def _layer_times_soft(prob: DSEProblem, theta_op: jnp.ndarray,
                      theta_st: jnp.ndarray, tau, n_iters: int,
                      k_prologue: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Smooth counterpart of ``_layer_times`` (soft floor, soft fixed
    point, soft reductions) — differentiable in θ everywhere."""
    work, st_lat, _ = _reweight(prob, theta_op, theta_st,
                                floor=lambda a, b: softmaximum(a, b, tau))
    t = fixed_point_soft(prob.compiled_aidg, tau=tau, n_iters=n_iters,
                         work=work, storage_lat=st_lat)
    p = (softmax_reduce(t[:k_prologue], tau) if k_prologue > 0
         else jnp.float32(0.0))
    return softmax_reduce(t, tau), p


def grad_network_sweep(stack: LayerStack, projections: Sequence[Tuple],
                       n_iters: int = 2, mode: str = "sequential"
                       ) -> Callable:
    """Cached ``jit(vmap(value_and_grad))`` of *end-to-end* network latency
    from shared knob space: ``fn(knobs (B, K), tau) -> (soft cycles (B,),
    d cycles/d knob (B, K))``.

    ``projections[u]`` is ``DesignSpace.projection(problems[u])``; baking
    every per-layer gather into one traced function chains projection →
    per-layer soft wavefront → max-plus composition inside autodiff, so
    the K shared knobs receive the full network's gradient in one call.
    In ``sequential`` mode the soft value upper-bounds the hard one (every
    softened reduction does); ``pipelined`` additionally softens the
    overlap clip with a softmin, which approximates rather than bounds."""
    if mode not in NETWORK_MODES:
        raise ValueError(f"mode must be one of {NETWORK_MODES}, got {mode!r}")
    projections = [(np.asarray(oi, np.int64), np.asarray(si, np.int64))
                   for oi, si in projections]
    key = (("grad", n_iters, mode)
           + tuple(oi.tobytes() + si.tobytes() for oi, si in projections))
    fn = stack._compiled.get(key)
    if fn is None:
        ks = [int(k) for k in stack.prologue_len]
        gathers = [(jnp.asarray(oi), jnp.asarray(si))
                   for oi, si in projections]

        def f(knobs, tau):
            padded = jnp.concatenate(
                [knobs, jnp.ones((1,), knobs.dtype)])   # identity column
            times = [_layer_times_soft(prob, padded[oi], padded[si], tau,
                                       n_iters, k)
                     for prob, k, (oi, si)
                     in zip(stack.problems, ks, gathers)]
            m = jnp.stack([t[0] for t in times])
            p = jnp.stack([t[1] for t in times])
            softmin = lambda a, b: -softmaximum(-a, -b, tau)
            return _compose(stack, m, p, mode, minimum=softmin)

        fn = jax.jit(jax.vmap(jax.value_and_grad(f), in_axes=(0, None)))
        stack._compiled[key] = fn
    return fn
