"""AIDG — Architectural Instruction Dependency Graph (paper §6, [16]).

The event-driven simulator (``repro.core.acadl.sim``) is the cycle-accurate
oracle; the AIDG is the paper's fast path: instruction completion times
satisfy the max-plus recurrence

    t_i = w_i + max(base_i, max_{j -> i} (t_j + d_ji))

over a DAG whose forward edges encode

* **data dependencies** — RAW/WAW from the program-order last-writer map
  (paper Fig. 11),
* **structural hazards** — serialization of instructions through the same
  FunctionalUnit / ExecuteStage (Fig. 10),
* **branch bubbles** — the fetch group after a pc-writer waits for the
  branch to resolve plus a fetch + route refill (Fig. 9),
* **issue-buffer backpressure** — instruction i cannot be in flight before
  instruction i - issue_buffer_size left the buffer,

with ``base_i`` the static fetch-visibility time of i's fetch group.

**DataStorage request slots** (Figs. 12/13) are *not* program-order
serializable: the hardware services requests in arrival order across all
MemoryAccessUnits.  They are handled by the queueing fixed point of
``longest_path_fixed_point``: relax the DAG, replay each storage's accesses
in estimated-arrival order against its request slots, fold the resulting
delays back into the node bases, and iterate — the paper's "fixed point
analysis of consecutive loop iterations" ([16]) in max-plus form.

All DAG edges point forward in trace order, so each relaxation is one O(E)
pass — ``numpy`` here; ``repro.core.aidg.maxplus`` evaluates the same
relaxation as blocked max-plus linear algebra (JAX / Pallas), and
``repro.core.aidg.dse`` vmaps it over accelerator latency parameters for
design-space exploration (the paper's NAS/co-design loop).
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..acadl.graph import ArchitectureGraph
from ..acadl.sim import TraceEntry, build_trace
from ..acadl.units import FunctionalUnit

__all__ = ["AIDG", "LevelSchedule", "CompiledAIDG", "CondensedAIDG",
           "build_aidg", "compile_aidg", "compute_level_schedule",
           "condense_aidg", "longest_path", "longest_path_fixed_point",
           "estimate_cycles"]

MAX_PREDS = 12  # minimum padded predecessor slots per node (jnp/Pallas path);
#                 build_aidg widens the padding when a node has more — edges
#                 are never dropped

NEG = -1e18     # max-plus -inf sentinel — THE definition; maxplus/dse
#                 re-import it (condensation writes it into coupling
#                 tables the evaluators compare against)


@dataclass
class AIDG:
    """Padded-CSR forward DAG with per-node work and base offsets."""

    n: int
    work: np.ndarray          # (n,) float32 — w_i = max(1, fu_lat + mem_lat)
    fu_lat: np.ndarray        # (n,) float32 — functional-unit latency
    mem_lat: np.ndarray       # (n,) float32 — total storage latency
    base: np.ndarray          # (n,) float32 — fetch visibility + route latency
    preds: np.ndarray         # (n, MAX_PREDS) int32 — predecessor ids, -1 pad
    pred_extra: np.ndarray    # (n, MAX_PREDS) float32 — extra edge delay
    #                           (t_i >= t_j + pred_extra + w_i)
    # --- storage request-slot queueing (arrival-ordered fixed point) ---
    storage_nodes: Dict[str, np.ndarray] = field(default_factory=dict)
    storage_lat: Dict[str, np.ndarray] = field(default_factory=dict)
    storage_slots: Dict[str, int] = field(default_factory=dict)
    # --- metadata for parameterized re-weighting (DSE) ---
    op_class: np.ndarray = field(                 # (n,) int32
        default_factory=lambda: np.zeros(0, dtype=np.int32))
    op_scale: np.ndarray = field(                 # (n,) float32 — macs/words
        default_factory=lambda: np.zeros(0, dtype=np.float32))
    mem_words: np.ndarray = field(                # (n,) float32
        default_factory=lambda: np.zeros(0, dtype=np.float32))
    classes: Dict[str, int] = field(default_factory=dict)
    stats: Dict[str, Any] = field(default_factory=dict)
    # lazily-built compilation artifact (level schedule + padded gathers),
    # memoized here because the DAG structure is immutable per scenario
    _compiled: Optional["CompiledAIDG"] = field(default=None, repr=False)
    # boundary -> CondensedAIDG, memoized per chain-condensation boundary
    _condensed: Dict[Optional[int], "CondensedAIDG"] = field(
        default_factory=dict, repr=False)

    @property
    def edges(self) -> int:
        """Number of real (non-padding) dependency edges in the DAG."""
        return int((self.preds >= 0).sum())


def _fetch_schedule(ag: ArchitectureGraph, trace: Sequence[TraceEntry]
                    ) -> Tuple[np.ndarray, List[List[int]], int]:
    """Static visibility time of each instruction's fetch group (Fig. 9),
    ignoring dynamic stalls (branch bubbles become AIDG edges)."""
    fetch = ag.fetch_stages[0]
    imau = fetch.imau
    imem = imau.instruction_memory
    port_width = max(1, imem.port_width)
    imem_read_lat = imem.access_latency("read", 0)
    fetch_cost = max(1, imem_read_lat + imau.latency.resolve())

    groups: List[List[int]] = []
    cur: List[int] = []
    for e in trace:
        cur.append(e.idx)
        if len(cur) >= port_width or e.is_pc_writer:
            groups.append(cur)
            cur = []
    if cur:
        groups.append(cur)

    visible = np.zeros(len(trace), dtype=np.float32)
    t = 0
    for g in groups:
        t += fetch_cost
        for idx in g:
            visible[idx] = t
    return visible, groups, fetch_cost


def build_aidg(ag: ArchitectureGraph, trace: Sequence[TraceEntry],
               include_buffer_edges: bool = True) -> AIDG:
    """Trace -> AIDG: derive per-node work/base and the forward dependency
    edges (data, structural, branch-bubble, issue-buffer — see the module
    docstring), pad predecessors to CSR form, record the storage queueing
    and DSE metadata, and run the build-time compile pipeline."""
    n = len(trace)
    work = np.ones(n, dtype=np.float32)
    fu_lat_arr = np.zeros(n, dtype=np.float32)
    mem_lat_arr = np.zeros(n, dtype=np.float32)
    base = np.zeros(n, dtype=np.float32)
    route_lat_arr = np.zeros(n, dtype=np.float32)
    preds: List[List[Tuple[int, float]]] = [[] for _ in range(n)]

    op_class = np.zeros(n, dtype=np.int32)
    op_scale = np.ones(n, dtype=np.float32)
    mem_words = np.zeros(n, dtype=np.float32)
    classes: Dict[str, int] = {}

    visible, groups, fetch_cost = _fetch_schedule(ag, trace)
    fetch = ag.fetch_stages[0]
    ibs = max(1, fetch.issue_buffer_size)

    last_on_unit: Dict[str, int] = {}
    last_on_stage: Dict[str, int] = {}
    storage_nodes: Dict[str, List[int]] = {}
    storage_lat: Dict[str, List[float]] = {}
    storage_slots: Dict[str, int] = {}

    for e in trace:
        i = e.idx
        instr = e.instr

        # ---- work = fu latency + memory latency (>= 1 cycle occupancy) ----
        fl = 0.0
        if e.fu_name is not None:
            fu: FunctionalUnit = ag.by_name[e.fu_name]
            tags = instr.tags
            fl = float(fu.latency.resolve(
                operation=instr.operation,
                words=int(tags.get("words", 1)),
                macs=int(tags.get("macs", tags.get("words", 1)))))
        ml = float(e.mem_latency)
        fu_lat_arr[i] = fl
        mem_lat_arr[i] = ml
        work[i] = max(1.0, fl + ml)

        # ---- base = fetch visibility + route buffer latencies ----
        route_lat = 0.0
        for sname in e.route[:-1]:
            stage = ag.by_name[sname]
            route_lat += float(stage.latency.resolve())
        route_lat_arr[i] = route_lat
        base[i] = visible[i] + route_lat

        # ---- data dependencies ----
        for j in e.deps:
            preds[i].append((j, 0.0))

        # ---- structural: same FunctionalUnit / terminal stage serialize ----
        if e.fu_name is not None:
            j = last_on_unit.get(e.fu_name)
            if j is not None:
                preds[i].append((j, 0.0))
            last_on_unit[e.fu_name] = i
        if e.route:
            stage_name = e.route[-1]
            j = last_on_stage.get(stage_name)
            if j is not None and all(p != j for p, _ in preds[i]):
                preds[i].append((j, 0.0))
            last_on_stage[stage_name] = i

        # ---- storage request-slot queueing records ----
        for st_name, lat in e.mem_parts:
            st = ag.by_name[st_name]
            storage_nodes.setdefault(st_name, []).append(i)
            storage_lat.setdefault(st_name, []).append(float(lat))
            storage_slots[st_name] = max(1, st.max_concurrent_requests)
            mem_words[i] = float(instr.tags.get("words", 1))

        # ---- issue-buffer backpressure (approximation) ----
        if include_buffer_edges and i - ibs >= 0:
            preds[i].append((i - ibs, 0.0))

        # ---- DSE metadata ----
        key = (instr.operation if e.fu_name is None
               else f"{instr.operation}@{_unit_class(e.fu_name)}")
        op_class[i] = classes.setdefault(key, len(classes))
        tags = instr.tags
        op_scale[i] = float(tags.get("macs", tags.get("words", 1)))

    # branch bubbles: every instruction of group g+1 waits for the pc-writer
    # closing group g to resolve, then a fetch + route refill
    for gi in range(len(groups) - 1):
        tail = groups[gi][-1]
        if trace[tail].is_pc_writer:
            for idx in groups[gi + 1]:
                preds[idx].append((tail, fetch_cost + route_lat_arr[idx]))

    # pad to (n, width).  width is normally MAX_PREDS but grows to the true
    # maximum in-degree when a node has more predecessors — truncation here
    # would silently under-estimate the critical path (an edge is a timing
    # constraint; dropping one can only make t_i smaller).
    dedups: List[Dict[int, float]] = []
    overflow = 0
    width = MAX_PREDS
    for ps in preds:
        dedup: Dict[int, float] = {}
        for j, d in ps:
            dedup[j] = max(dedup.get(j, -1.0), d)
        if len(dedup) > MAX_PREDS:
            overflow += 1
            width = max(width, len(dedup))
        dedups.append(dedup)
    if overflow:
        warnings.warn(
            f"build_aidg: {overflow} node(s) exceed MAX_PREDS={MAX_PREDS} "
            f"predecessors; widening padded slots to {width} (no edges "
            f"dropped, but evaluator gathers get proportionally wider)",
            RuntimeWarning, stacklevel=2)
    pred_arr = np.full((n, width), -1, dtype=np.int32)
    pred_extra = np.zeros((n, width), dtype=np.float32)
    for i, dedup in enumerate(dedups):
        # latest predecessors first (they bind tightest; order is cosmetic
        # now that every edge is kept)
        for k, (j, d) in enumerate(sorted(dedup.items(), key=lambda kv: -kv[0])):
            pred_arr[i, k] = j
            pred_extra[i, k] = d

    aidg = AIDG(n=n, work=work, fu_lat=fu_lat_arr, mem_lat=mem_lat_arr,
                base=base, preds=pred_arr, pred_extra=pred_extra,
                storage_nodes={k: np.asarray(v, dtype=np.int64)
                               for k, v in storage_nodes.items()},
                storage_lat={k: np.asarray(v, dtype=np.float32)
                             for k, v in storage_lat.items()},
                storage_slots=storage_slots,
                op_class=op_class, op_scale=op_scale, mem_words=mem_words,
                classes=classes,
                stats={"groups": len(groups), "pred_overflow": overflow,
                       "pred_width": width, "fetch_cost": fetch_cost})
    compile_aidg(aidg)  # level schedule is build-time, structure is static
    return aidg


def _unit_class(fu_name: str) -> str:
    """Collapse template-replicated units (fu[0][1], lsu3) to a class name
    so DSE parameters are shared across identical units."""
    import re

    return re.sub(r"\d+", "#", fu_name)


# ---------------------------------------------------------------------------
# build-time compilation: trace -> AIDG -> LevelSchedule -> CompiledAIDG
# ---------------------------------------------------------------------------


@dataclass
class LevelSchedule:
    """Topological wavefront schedule of the AIDG, in level-major layout.

    ``depth[i]`` is node i's longest-path depth (0 for source nodes, else
    1 + max over predecessors), so every predecessor of a node sits at a
    strictly smaller depth.  Nodes are renumbered level-major (``order``:
    permuted position -> original id; ``rank``: original id -> permuted
    position) so each level occupies the contiguous permuted slots
    ``[starts[d], starts[d] + counts[d])``.  The wavefront evaluator scans
    over ``starts`` with a fixed window of ``width`` slots per step —
    contiguous dynamic slices in, one dynamic-update-slice out — for
    O(n_levels) sequential device steps instead of O(n).  A window wider
    than its level spills into the next level's slots; those lanes compute
    garbage from not-yet-final inputs and are deterministically overwritten
    when their own level runs (windows never reach *earlier* slots).

    ``level_nodes[d]`` lists the original ids at depth d (pad ``n``) — the
    gather-form view kept for inspection and stats.
    """

    n: int
    depth: np.ndarray          # (n,) int32
    level_nodes: np.ndarray    # (n_levels, width) int32, pad = n
    order: np.ndarray          # (n,) int32 — permuted position -> original id
    rank: np.ndarray           # (n,) int32 — original id -> permuted position
    starts: np.ndarray         # (n_levels,) int32 — level start, permuted

    @property
    def n_levels(self) -> int:
        """Critical depth of the DAG = sequential wavefront steps."""
        return int(self.level_nodes.shape[0])

    @property
    def width(self) -> int:
        """Widest level = the wavefront evaluator's window size."""
        return int(self.level_nodes.shape[1])

    @property
    def parallelism(self) -> float:
        """Mean nodes per level = the sequential-depth compression the
        wavefront evaluator gets over the per-node scan."""
        return self.n / max(1, self.n_levels)


def compute_level_schedule(preds: np.ndarray, n: int) -> LevelSchedule:
    """Longest-path depths + level-major renumbering for a padded-CSR
    forward DAG (all predecessor ids < node id)."""
    depth = np.zeros(n, dtype=np.int32)
    for i in range(n):
        row = preds[i]
        js = row[row >= 0]
        if js.size:
            depth[i] = int(depth[js].max()) + 1
    if n == 0:
        z = np.zeros(0, dtype=np.int32)
        return LevelSchedule(0, depth, np.zeros((0, 0), dtype=np.int32),
                             z, z, z)
    n_levels = int(depth.max()) + 1
    counts = np.bincount(depth, minlength=n_levels)
    order = np.argsort(depth, kind="stable")   # trace order within a level
    rank = np.empty(n, dtype=np.int32)
    rank[order] = np.arange(n, dtype=np.int32)
    starts = np.zeros(n_levels, dtype=np.int64)
    np.cumsum(counts[:-1], out=starts[1:])
    level_nodes = np.full((n_levels, int(counts.max())), n, dtype=np.int32)
    cols = np.arange(n) - starts[depth[order]]
    level_nodes[depth[order], cols] = order
    return LevelSchedule(n, depth, level_nodes, order.astype(np.int32), rank,
                         starts.astype(np.int32))


@dataclass
class CompiledAIDG:
    """Build-time compilation artifact: the AIDG plus everything the device
    evaluators need that depends only on *structure* (never on θ): the
    level schedule, the predecessor gather arrays rewritten into the
    schedule's level-major numbering (so each wavefront step reads a
    contiguous window), and per-storage scatter indices in a deterministic
    order.  Built once per scenario by ``compile_aidg`` and shared by every
    sweep over the same graph."""

    aidg: AIDG
    schedule: LevelSchedule
    # (n + width, p_used): predecessor *permuted positions* / extra edge
    # delays, rows in level-major order, -1 pad; the slot axis is trimmed
    # from the AIDG's fixed MAX_PREDS padding to the true maximum in-degree
    # (typically 2-4x narrower — pad slots are pure wasted compute on the
    # device), and the trailing ``width`` rows absorb the last wavefront
    # window's spill
    preds_lv: np.ndarray
    extra_lv: np.ndarray
    storage_order: Tuple[str, ...]
    storage_scatter: Dict[str, np.ndarray]   # name -> (k,) int32 node ids
    # per-block-size banded edge matrices for the blocked engine, built on
    # first use (structure only — runtime work/base are folded at eval)
    _block_cache: Dict[int, Tuple] = field(default_factory=dict, repr=False)

    @property
    def n(self) -> int:
        """Node (instruction) count of the underlying AIDG."""
        return self.aidg.n


def compile_aidg(aidg: AIDG) -> CompiledAIDG:
    """AIDG -> CompiledAIDG, memoized on the AIDG instance (the DAG is
    immutable per scenario; only work/base/storage latencies vary)."""
    if aidg._compiled is not None:
        return aidg._compiled
    sched = compute_level_schedule(aidg.preds, aidg.n)
    # slots are packed left by build_aidg, so trimming to the true maximum
    # in-degree drops only pad columns
    deg = (aidg.preds >= 0).sum(axis=1)
    p = max(1, int(deg.max())) if aidg.n else 1
    w = sched.width
    perm_preds = aidg.preds[sched.order][:, :p]   # (n, p_used), original ids
    mapped = np.where(perm_preds >= 0,
                      sched.rank[np.maximum(perm_preds, 0)], -1)
    preds_lv = np.concatenate(
        [mapped, np.full((w, p), -1, dtype=np.int32)], axis=0)
    extra_lv = np.concatenate(
        [aidg.pred_extra[sched.order][:, :p],
         np.zeros((w, p), dtype=np.float32)], axis=0)
    order = tuple(sorted(aidg.storage_nodes))
    scatter = {s: np.asarray(aidg.storage_nodes[s], dtype=np.int32)
               for s in order}
    ca = CompiledAIDG(aidg=aidg, schedule=sched,
                      preds_lv=preds_lv.astype(np.int32), extra_lv=extra_lv,
                      storage_order=order, storage_scatter=scatter)
    aidg.stats["n_levels"] = sched.n_levels
    aidg.stats["max_level_width"] = sched.width
    aidg._compiled = ca
    return ca


# ---------------------------------------------------------------------------
# θ-parametric chain condensation: CompiledAIDG -> CondensedAIDG
# ---------------------------------------------------------------------------


@dataclass
class CondensedAIDG:
    """Chain-condensed evaluation artifact (structure only, exact for every
    θ with per-node work ≥ 1 — the floor every shipped evaluator enforces).

    A maximal run of consecutive *single-node levels* is a chain: each
    member's only timing-relevant input is the member one level up.  A
    member is **absorbed** when (a) it touches no storage request slots
    (the queueing fixed point needs materialized arrival times and base
    fold-backs), (b) every non-direct predecessor edge is dominated by the
    direct chain edge for all θ (``extra ≤ direct_extra + gap``, each chain
    step contributing work ≥ 1), (c) its static ``base`` is dominated the
    same way, and (d) it has at least one successor (so the makespan
    survives on kept nodes).  An absorbed member's completion time is then
    *exactly* ``t_anchor + Σ (edge extra + w_i(θ))`` over the absorbed
    prefix — a dot product between the segment's 0/1 prefix-membership
    vector and the θ-reweighted per-node work vector, evaluated inside the
    trace as one ``cumsum`` (``op_class_counts`` exposes the aggregated
    per-op-class count form of the same super-edges).  Everything a kept
    node reads from an absorbed one is rewritten as a super-edge from the
    segment anchor carrying (constant extra, prefix index).

    Kept nodes keep the exact wavefront recurrence; the level schedule is
    recomputed over the condensed DAG, so the sequential scan length drops
    from the original critical depth to the condensed one (≥ 3x on
    chain-dominated cells — see ``stats``).

    ``boundary`` (optional): the last chain member with original id <
    ``boundary`` is force-kept, so a max over kept nodes with id < boundary
    equals the max over *all* nodes with id < boundary (the network
    frontend's prologue reduction needs this).
    """

    aidg: AIDG
    boundary: Optional[int]
    n_kept: int
    kept: np.ndarray           # (n_kept,) original ids, ascending
    kept_rank: np.ndarray      # (n,) original id -> kept index, -1 = absorbed
    absorbed: np.ndarray       # (n_ab,) original ids, segment-major order
    ab_anchor: np.ndarray      # (n_ab,) kept index of the segment anchor
    ab_const: np.ndarray       # (n_ab,) f32 — direct-step edge extra into it
    ab_segstart: np.ndarray    # (n_ab,) int32 — segment's first position
    # UNIT-level wavefront schedule: a unit is either one kept node or a
    # maximal *affine chain* of kept nodes (single-node condensed levels
    # whose only live input is the previous chain member — storage
    # accessors included, their base still binds).  One scan step per unit
    # level; each chain inside a window evaluates closed-form by the
    # associative max-plus affine scan, so sequential depth is the number
    # of unit levels, not chain length.
    schedule: LevelSchedule    # over kept indices, unit-major renumbering
    # level-major condensed predecessor slots (rows: permuted kept position
    # + trailing width spill, like CompiledAIDG.preds_lv): source permuted
    # position, constant extra, and the absorbed-prefix index (-1 = the
    # source is kept, edge weight is just the constant).  Chain-coupled
    # nodes carry NO slots — their single live input is the in-window
    # affine coupling (v_const_lv / v_pidx_lv; the coupling weight at θ is
    # const + prefix + own work).
    preds_lv: np.ndarray       # (n_kept + W, P) int32
    const_lv: np.ndarray       # (n_kept + W, P) f32
    pidx_lv: np.ndarray        # (n_kept + W, P) int32
    v_const_lv: np.ndarray     # (n_kept + W,) f32 — NEG = not coupled
    v_pidx_lv: np.ndarray      # (n_kept + W,) int32 — -1 = no prefix
    kept_perm: np.ndarray      # (n_kept,) original ids in permuted order
    ab_anchor_perm: np.ndarray  # (n_ab,) permuted position of the anchor
    stats: Dict[str, Any] = field(default_factory=dict)

    @property
    def n(self) -> int:
        """Original node count (the condensed evaluator still consumes and
        reconstructs full-length work/base/t vectors)."""
        return self.aidg.n

    @property
    def n_absorbed(self) -> int:
        """Nodes folded into super-edges (``n - n_kept``)."""
        return int(self.absorbed.shape[0])

    def storage_scatter_kept(self, name: str) -> np.ndarray:
        """Kept-index positions of one storage's access nodes (storage
        accessors are never absorbed, so this is total)."""
        return self.kept_rank[self.aidg.storage_nodes[name]].astype(np.int32)

    def storage_static_order(self, name: str) -> bool:
        """True when this storage's accesses are PROVABLY served in access
        order for every θ: each access is a DAG ancestor of the next, so
        ``arrival_{k+1} = t_{k+1} - w_{k+1} ≥ t_k + w_{k+1} - w_{k+1} =
        arrival_k`` (work ≥ 1, extras ≥ 0 — holds on the hard and soft
        paths alike).  A stable argsort of a statically-sorted key vector
        is the identity, so the evaluator skips the per-candidate sort —
        bit-identical results, no sort kernels."""
        return bool(self.stats.get("static_order", {}).get(name, False))

    def op_class_counts(self) -> np.ndarray:
        """(n_segments, n_op_classes) per-op-class count vectors of the
        condensed super-edges: row s counts, per op class, the absorbed
        nodes of segment s — the ``counts ⋅ work(θ)`` view of the prefix
        weights (the evaluator uses the per-node prefix cumsum, which is
        the same dot product at per-node granularity)."""
        if not self.absorbed.size:
            return np.zeros((0, max(1, len(self.aidg.classes))), np.int64)
        seg_id = np.cumsum(np.arange(len(self.absorbed))
                           == self.ab_segstart)  # 1-based per segment
        n_seg = int(seg_id[-1])
        n_cls = max(1, len(self.aidg.classes))
        out = np.zeros((n_seg, n_cls), np.int64)
        np.add.at(out, (seg_id - 1, self.aidg.op_class[self.absorbed]), 1)
        return out


def _chain_absorb_flags(aidg: AIDG, sched: LevelSchedule,
                        boundary: Optional[int]
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-node absorb decision plus the direct chain step (prev, extra).

    Returns (absorb bool (n,), chain_prev int (n,), chain_extra f32 (n,)):
    ``chain_prev[i]``/``chain_extra[i]`` are the single dominating direct
    edge of an absorbed node (undefined elsewhere)."""
    n = aidg.n
    absorb = np.zeros(n, dtype=bool)
    chain_prev = np.full(n, -1, dtype=np.int64)
    chain_extra = np.zeros(n, dtype=np.float32)
    if n == 0:
        return absorb, chain_prev, chain_extra
    depth = sched.depth
    n_levels = sched.n_levels
    counts = np.bincount(depth, minlength=n_levels)
    first_at_level = sched.order[sched.starts]          # (n_levels,)
    single = counts == 1
    outdeg = np.zeros(n, dtype=np.int64)
    real = aidg.preds >= 0
    np.add.at(outdeg, aidg.preds[real], 1)
    storage = np.zeros(n, dtype=bool)
    for nodes in aidg.storage_nodes.values():
        storage[nodes] = True
    preds, extra = aidg.preds, aidg.pred_extra

    d = 0
    while d < n_levels:
        if not single[d]:
            d += 1
            continue
        d1 = d
        while d1 + 1 < n_levels and single[d1 + 1]:
            d1 += 1
        # chain run over levels [d, d1]; the entry stays kept
        for lv in range(d + 1, d1 + 1):
            i = int(first_at_level[lv])
            prev = int(first_at_level[lv - 1])
            if storage[i] or outdeg[i] == 0:
                continue
            e_direct = None
            ok = True
            row, ex = preds[i], extra[i]
            for k in range(row.shape[0]):
                j = int(row[k])
                if j < 0:
                    break
                if j == prev:
                    e_direct = float(ex[k])
            if e_direct is None:        # defensive: depth says it exists
                continue
            for k in range(row.shape[0]):
                j = int(row[k])
                if j < 0:
                    break
                if j == prev:
                    continue
                dj = int(depth[j])
                # a side edge is dominated by the direct chain edge when its
                # source is a shallower member of the SAME run and its extra
                # cannot outrun the ≥ 1-cycle-per-step chain (work floor)
                if not (d <= dj <= lv - 2) or int(first_at_level[dj]) != j:
                    ok = False
                    break
                gap = (lv - 1) - dj
                if float(ex[k]) > e_direct + gap + 1e-6:
                    ok = False
                    break
            if ok and float(aidg.base[i]) > (float(aidg.base[prev]) + 1.0
                                             + e_direct + 1e-6):
                ok = False              # the static base could bind
            if ok:
                absorb[i] = True
                chain_prev[i] = prev
                chain_extra[i] = e_direct
        # boundary: keep the deepest run member with original id < boundary
        # so a prefix max over kept ids < boundary stays exact (prologue)
        if boundary is not None:
            q = -1
            for lv in range(d, d1 + 1):
                m = int(first_at_level[lv])
                if m < boundary:
                    q = m
            if q >= 0:
                absorb[q] = False
        d = d1 + 1
    return absorb, chain_prev, chain_extra


def _storage_static_orders(aidg: AIDG) -> Dict[str, bool]:
    """Per storage: is the arrival order provably static (each access a DAG
    ancestor of the next)?  Ancestor sets via one bitset DP over the
    forward CSR; cached on the AIDG (boundary-independent)."""
    hit = aidg.stats.get("storage_static_order")
    if hit is not None:
        return hit
    out: Dict[str, bool] = {}
    if aidg.storage_nodes:
        n = aidg.n
        words = (n + 63) // 64
        anc = np.zeros((n, words), np.uint64)
        preds = aidg.preds
        for i in range(n):
            acc = anc[i]
            for k in range(preds.shape[1]):
                j = int(preds[i, k])
                if j < 0:
                    break
                np.bitwise_or(acc, anc[j], out=acc)
                acc[j >> 6] |= np.uint64(1 << (j & 63))
        for st, nodes in aidg.storage_nodes.items():
            ok = True
            for k in range(len(nodes) - 1):
                a, b = int(nodes[k]), int(nodes[k + 1])
                if not (int(anc[b, a >> 6]) >> (a & 63)) & 1:
                    ok = False
                    break
            out[st] = ok
    aidg.stats["storage_static_order"] = out
    return out


def condense_aidg(aidg: AIDG, boundary: Optional[int] = None
                  ) -> CondensedAIDG:
    """AIDG -> CondensedAIDG (memoized per ``boundary`` on the AIDG):
    collapse provably-linear chain interiors into θ-parametric super-edges
    and recompute the level schedule over the kept nodes.  Exact on the
    hard max-plus path for every θ (work floor ≥ 1); on the smooth τ path
    absorbed steps use their exact sums, giving a *tighter* upper bound of
    the hard result than the uncondensed soft wavefront."""
    hit = aidg._condensed.get(boundary)
    if hit is not None:
        return hit
    ca = compile_aidg(aidg)
    sched0 = ca.schedule
    n = aidg.n
    absorb, chain_prev, chain_extra = _chain_absorb_flags(aidg, sched0,
                                                          boundary)

    kept = np.nonzero(~absorb)[0].astype(np.int64)
    kept_rank = np.full(n, -1, dtype=np.int64)
    kept_rank[kept] = np.arange(len(kept))

    # absorbed nodes in segment-major order (each segment = a maximal
    # absorbed stretch hanging off one kept anchor), with prefix bookkeeping
    ab_list: List[int] = []
    ab_anchor: List[int] = []
    ab_const: List[float] = []
    ab_segstart: List[int] = []
    ab_pos = np.full(n, -1, dtype=np.int64)
    order_by_depth = sched0.order  # absorbed nodes sit on single-node levels
    for i in order_by_depth:
        i = int(i)
        if not absorb[i]:
            continue
        p = int(chain_prev[i])
        pos = len(ab_list)
        if absorb[p]:
            anchor = ab_anchor[ab_pos[p]]
            seg = ab_segstart[ab_pos[p]]
        else:
            anchor = int(kept_rank[p])
            seg = pos
        ab_list.append(i)
        ab_anchor.append(anchor)
        ab_const.append(float(chain_extra[i]))
        ab_segstart.append(seg)
        ab_pos[i] = pos

    # condensed predecessor slots over kept nodes: edges from absorbed
    # sources are rewritten to their segment anchor + prefix index
    nk = len(kept)
    deg = (aidg.preds[kept] >= 0).sum(axis=1) if nk else np.zeros(0, int)
    p_used = max(1, int(deg.max())) if nk else 1
    cpreds = np.full((nk, p_used), -1, dtype=np.int64)
    cconst = np.zeros((nk, p_used), dtype=np.float32)
    cpidx = np.full((nk, p_used), -1, dtype=np.int64)
    for ki, i in enumerate(kept):
        row, ex = aidg.preds[i], aidg.pred_extra[i]
        slot = 0
        for k in range(row.shape[0]):
            j = int(row[k])
            if j < 0:
                break
            if absorb[j]:
                cpreds[ki, slot] = ab_anchor[ab_pos[j]]
                cpidx[ki, slot] = ab_pos[j]
            else:
                cpreds[ki, slot] = kept_rank[j]
            cconst[ki, slot] = float(ex[k])
            slot += 1

    ab_seg_arr = np.asarray(ab_segstart, dtype=np.int64)

    # --- affine-chain coupling over the condensed DAG --------------------
    # A kept node is *coupled* to one predecessor p when every one of its
    # other live edges is provably dominated by the (i, p) edge for all θ:
    # ``extra_k ≤ lb(direct) + D(src_k → p)`` with D the longest path in
    # edges (each edge gains ≥ 1 cycle — work floor), or the side edge is
    # a sub-prefix of the direct super-edge's own segment.  Unlike
    # absorption, the node stays materialized (its base — and any storage
    # fold-back into it — still binds), so storage accessors couple too;
    # each maximal chain then evaluates closed-form by the associative
    # affine scan — this is what collapses lane-parallel graphs (one chain
    # per PE/unit), not just scalar in-order ones.
    coupled = np.zeros(nk, dtype=bool)
    v_const = np.full(nk, NEG, dtype=np.float32)
    v_pidx = np.full(nk, -1, dtype=np.int64)
    chain_prev_k = np.full(nk, -1, dtype=np.int64)
    if nk:
        # all-pairs longest path in edges over the condensed DAG (int16,
        # -1 = unreachable); row i indexed by source
        D = np.full((nk, nk), -1, dtype=np.int16)
        for ki in range(nk):
            acc = D[ki]
            row = cpreds[ki]
            for s in range(p_used):
                j = int(row[s])
                if j < 0:
                    break
                dj = D[j]
                np.maximum(acc, dj + 1, out=acc, where=dj >= 0)
                if acc[j] < 1:
                    acc[j] = 1

        def _seg_count(p):
            return int(p - ab_seg_arr[p] + 1)

        taken = np.zeros(nk, dtype=bool)   # p already continues a chain
        for ki in range(nk):
            slots = [(int(cpreds[ki, s]), float(cconst[ki, s]),
                      int(cpidx[ki, s]))
                     for s in range(p_used) if cpreds[ki, s] >= 0]
            if not slots:
                continue
            # try direct candidates by descending static lower bound
            cands = sorted(
                ((cst + (_seg_count(px) if px >= 0 else 0), src, cst, px)
                 for src, cst, px in slots if not taken[src]),
                key=lambda c: -c[0])
            for lb_d, p, const_d, p_d in cands:
                ok = True
                used_direct = False
                for src, cst, px in slots:
                    if (not used_direct and (src, cst, px)
                            == (p, const_d, p_d)):
                        used_direct = True
                        continue
                    if px < 0:
                        gap = 0 if src == p else int(D[p][src])
                        if (src != p and gap < 0) or cst > lb_d + gap + 1e-6:
                            ok = False
                            break
                    elif (src == p and p_d >= 0
                          and ab_seg_arr[px] == ab_seg_arr[p_d]
                          and px <= p_d):
                        # same-segment sub-prefix: the direct super-edge
                        # walks through every step the side edge counts
                        if cst > const_d + (p_d - px) + 1e-6:
                            ok = False
                            break
                    else:
                        ok = False
                        break
                if ok:
                    coupled[ki] = True
                    v_const[ki] = const_d
                    v_pidx[ki] = p_d
                    chain_prev_k[ki] = p
                    taken[p] = True
                    break
        del D

    # keep the chains only where they pay: the affine associative scan
    # adds per-step kernels, so marginal level reductions (a systolic
    # array's 87 -> 83) cost more than they save, while chain-dominated
    # graphs (2683 -> 1) win enormously.  Rough per-step cost model with a
    # fixed overhead term, measured on the CPU backend.
    if nk and coupled.any():
        unit_of_t = np.full(nk, -1, dtype=np.int64)
        n_units_t = 0
        for ki in range(nk):
            if coupled[ki]:
                unit_of_t[ki] = unit_of_t[chain_prev_k[ki]]
            else:
                unit_of_t[ki] = n_units_t
                n_units_t += 1
        udepth_t = np.zeros(n_units_t, dtype=np.int64)
        for ki in range(nk):
            if coupled[ki]:
                continue
            dmax = -1
            for s in range(p_used):
                j = int(cpreds[ki, s])
                if j >= 0:
                    dmax = max(dmax, int(udepth_t[unit_of_t[j]]))
            udepth_t[unit_of_t[ki]] = dmax + 1
        node_lv = udepth_t[unit_of_t]
        wc = int(np.bincount(node_lv).max())
        n_ulv_c = int(udepth_t.max()) + 1
        deg_live = ((cpreds >= 0) & ~coupled[:, None]).sum(axis=1)
        p_live = max(1, int(deg_live.max()))
        pre = compute_level_schedule(cpreds.astype(np.int32), nk)
        cost_chain = n_ulv_c * (512.0 + wc * (p_live + 3
                                              + 2 * np.log2(max(2, wc))))
        cost_plain = pre.n_levels * (256.0 + pre.width * (p_used + 3))
        if cost_chain >= cost_plain:
            coupled[:] = False
            chain_prev_k[:] = -1
            v_const[:] = NEG
            v_pidx[:] = -1

    # coupled nodes keep no slots — their one live input is the coupling
    live = ~coupled[:, None] & (cpreds >= 0)
    cpreds = np.where(live, cpreds, -1)
    cconst = np.where(live, cconst, 0.0).astype(np.float32)
    cpidx = np.where(live, cpidx, -1)
    # repack slots left so trimming stays tight
    if nk:
        key = np.where(cpreds >= 0, 0, 1)
        slot_order = np.argsort(key, axis=1, kind="stable")
        rows_idx = np.arange(nk)[:, None]
        cpreds = cpreds[rows_idx, slot_order]
        cconst = cconst[rows_idx, slot_order]
        cpidx = cpidx[rows_idx, slot_order]
        deg_live = (cpreds >= 0).sum(axis=1)
        p_used = max(1, int(deg_live.max()))
        cpreds, cconst, cpidx = (cpreds[:, :p_used], cconst[:, :p_used],
                                 cpidx[:, :p_used])

    # --- unit DAG: chains as super-nodes, one scan step per unit level ---
    # kept-index order is topological AND walks every chain head-to-tail
    # (links ascend), so members land in chain order within their unit
    unit_of = np.full(nk, -1, dtype=np.int64)
    unit_members: List[List[int]] = []
    for ki in range(nk):
        if coupled[ki]:
            unit_of[ki] = unit_of[chain_prev_k[ki]]
            unit_members[unit_of[ki]].append(ki)
        else:
            unit_of[ki] = len(unit_members)
            unit_members.append([ki])
    udepth = np.zeros(len(unit_members), dtype=np.int64)
    for u, members in enumerate(unit_members):   # entry pre-depth order
        dmax = -1
        for ki in members:
            for s in range(p_used):
                j = int(cpreds[ki, s])
                if j >= 0:
                    dmax = max(dmax, int(udepth[unit_of[j]]))
        udepth[u] = dmax + 1

    # level-major node ordering: units by (level, entry), members in chain
    # order; windows therefore cover whole chains and the in-window affine
    # coupling never crosses a window boundary
    n_ulv = int(udepth.max()) + 1 if nk else 0
    uorder = sorted(range(len(unit_members)),
                    key=lambda u: (int(udepth[u]), unit_members[u][0]))
    order = np.asarray([ki for u in uorder for ki in unit_members[u]],
                       dtype=np.int64)
    depth_nodes = np.asarray([int(udepth[unit_of[ki]]) for ki in order],
                             dtype=np.int32)
    rank = np.empty(nk, dtype=np.int32)
    rank[order] = np.arange(nk, dtype=np.int32)
    lv_counts = np.bincount(depth_nodes, minlength=max(1, n_ulv))
    starts = np.zeros(max(1, n_ulv), dtype=np.int64)
    np.cumsum(lv_counts[:-1], out=starts[1:])
    width = int(lv_counts.max()) if nk else 0
    level_nodes = np.full((n_ulv, max(1, width)), nk, dtype=np.int32)
    if nk:
        cols = np.arange(nk) - starts[depth_nodes]
        level_nodes[depth_nodes, cols] = order
    depth_full = np.zeros(nk, dtype=np.int32)
    depth_full[order] = depth_nodes
    csched = LevelSchedule(nk, depth_full, level_nodes,
                           order.astype(np.int32), rank,
                           starts[:n_ulv].astype(np.int32))

    w = csched.width
    perm_preds = cpreds[order] if nk else cpreds
    mapped = np.where(perm_preds >= 0,
                      rank[np.maximum(perm_preds, 0)], -1)
    preds_lv = np.concatenate(
        [mapped, np.full((w, p_used), -1, dtype=np.int64)],
        axis=0).astype(np.int32)
    const_lv = np.concatenate(
        [cconst[order] if nk else cconst,
         np.zeros((w, p_used), dtype=np.float32)], axis=0)
    pidx_lv = np.concatenate(
        [cpidx[order] if nk else cpidx,
         np.full((w, p_used), -1, dtype=np.int64)],
        axis=0).astype(np.int32)
    v_const_lv = np.concatenate(
        [v_const[order] if nk else v_const,
         np.full((w,), NEG, dtype=np.float32)])
    v_pidx_lv = np.concatenate(
        [v_pidx[order] if nk else v_pidx,
         np.full((w,), -1, dtype=np.int64)]).astype(np.int32)

    ab_anchor_arr = np.asarray(ab_anchor, dtype=np.int64)
    cond = CondensedAIDG(
        aidg=aidg, boundary=boundary, n_kept=nk, kept=kept,
        kept_rank=kept_rank,
        absorbed=np.asarray(ab_list, dtype=np.int64),
        ab_anchor=ab_anchor_arr,
        ab_const=np.asarray(ab_const, dtype=np.float32),
        ab_segstart=ab_seg_arr,
        schedule=csched, preds_lv=preds_lv, const_lv=const_lv,
        pidx_lv=pidx_lv, v_const_lv=v_const_lv, v_pidx_lv=v_pidx_lv,
        kept_perm=kept[order] if nk else kept,
        ab_anchor_perm=(rank[ab_anchor_arr].astype(np.int64)
                        if len(ab_list) else ab_anchor_arr),
        stats={"n": n, "n_kept": nk, "n_absorbed": len(ab_list),
               "n_coupled": int(coupled.sum()),
               "units": len(unit_members),
               "levels": sched0.n_levels, "levels_condensed": csched.n_levels,
               "level_reduction": sched0.n_levels / max(1, csched.n_levels),
               "static_order": _storage_static_orders(aidg)})
    aidg._condensed[boundary] = cond
    return cond


def longest_path(aidg: AIDG, work: Optional[np.ndarray] = None,
                 base: Optional[np.ndarray] = None) -> np.ndarray:
    """Exact O(E) forward relaxation over the forward DAG (no storage
    queueing): t_i = w_i + max(base_i, max_j (t_j + d_ji))."""
    w = aidg.work if work is None else work
    b = aidg.base if base is None else base
    t = np.zeros(aidg.n, dtype=np.float64)
    preds = aidg.preds
    extra = aidg.pred_extra
    for i in range(aidg.n):
        m = b[i]
        row = preds[i]
        for k in range(row.shape[0]):
            j = row[k]
            if j < 0:
                break
            v = t[j] + extra[i, k]
            if v > m:
                m = v
        t[i] = m + w[i]
    return t


def longest_path_fixed_point(aidg: AIDG, n_iters: int = 3,
                             work: Optional[np.ndarray] = None,
                             base: Optional[np.ndarray] = None,
                             storage_lat: Optional[Dict[str, np.ndarray]] = None,
                             ) -> np.ndarray:
    """Forward relaxation + arrival-ordered request-slot queueing, iterated
    to a fixed point (paper [16]).

    Each outer iteration: (1) exact longest path over the forward DAG with
    the current per-node base offsets; (2) replay every storage's accesses in
    estimated-arrival order against its ``max_concurrent_requests`` slots;
    (3) fold each access's service-completion (+ its unit latency) back into
    the node's base.  Stops early when the makespan is stable.
    """
    import heapq

    w = aidg.work if work is None else work
    b0 = aidg.base if base is None else base
    slat = aidg.storage_lat if storage_lat is None else storage_lat
    b = b0.astype(np.float64).copy()
    t = longest_path(aidg, work=w, base=b)
    if not aidg.storage_nodes:
        return t
    prev_makespan = t.max() if aidg.n else 0.0
    for _ in range(n_iters):
        b = b0.astype(np.float64).copy()
        for st_name, nodes in aidg.storage_nodes.items():
            lats = slat[st_name]
            slots = aidg.storage_slots[st_name]
            # arrival = when the unit would issue the transaction
            arrival = t[nodes] - w[nodes]
            order = np.argsort(arrival, kind="stable")
            heap = [0.0] * slots
            heapq.heapify(heap)
            for k in order:
                i = int(nodes[k])
                begin = max(float(arrival[k]), heapq.heappop(heap))
                done = begin + float(lats[k])
                heapq.heappush(heap, done)
                # t_i >= done + fu_lat_i  ->  base_i >= done + fu - w
                need = done + aidg.fu_lat[i] - w[i]
                if need > b[i]:
                    b[i] = need
        t = longest_path(aidg, work=w, base=b)
        makespan = t.max()
        if abs(makespan - prev_makespan) < 0.5:
            break
        prev_makespan = makespan
    return t


def estimate_cycles(ag: ArchitectureGraph, program: Sequence[Any],
                    entry: int = 0, n_iters: int = 3) -> Tuple[float, AIDG]:
    """Trace + AIDG + fixed-point longest path -> estimated cycles (the
    paper's fast performance estimation)."""
    trace = build_trace(ag, program, entry)
    aidg = build_aidg(ag, trace)
    t = longest_path_fixed_point(aidg, n_iters=n_iters)
    return (float(t.max()) if aidg.n else 0.0), aidg
