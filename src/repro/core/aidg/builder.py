"""AIDG — Architectural Instruction Dependency Graph (paper §6, [16]).

The event-driven simulator (``repro.core.acadl.sim``) is the cycle-accurate
oracle; the AIDG is the paper's fast path: instruction completion times
satisfy the max-plus recurrence

    t_i = w_i + max(base_i, max_{j -> i} (t_j + d_ji))

over a DAG whose forward edges encode

* **data dependencies** — RAW/WAW from the program-order last-writer map
  (paper Fig. 11),
* **structural hazards** — serialization of instructions through the same
  FunctionalUnit / ExecuteStage (Fig. 10),
* **branch bubbles** — the fetch group after a pc-writer waits for the
  branch to resolve plus a fetch + route refill (Fig. 9),
* **issue-buffer backpressure** — instruction i cannot be in flight before
  instruction i - issue_buffer_size left the buffer,

with ``base_i`` the static fetch-visibility time of i's fetch group.

**DataStorage request slots** (Figs. 12/13) are *not* program-order
serializable: the hardware services requests in arrival order across all
MemoryAccessUnits.  They are handled by the queueing fixed point of
``longest_path_fixed_point``: relax the DAG, replay each storage's accesses
in estimated-arrival order against its request slots, fold the resulting
delays back into the node bases, and iterate — the paper's "fixed point
analysis of consecutive loop iterations" ([16]) in max-plus form.

All DAG edges point forward in trace order, so each relaxation is one O(E)
pass — ``numpy`` here; ``repro.core.aidg.maxplus`` evaluates the same
relaxation as blocked max-plus linear algebra (JAX / Pallas), and
``repro.core.aidg.dse`` vmaps it over accelerator latency parameters for
design-space exploration (the paper's NAS/co-design loop).
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..acadl.graph import ArchitectureGraph
from ..acadl.sim import TraceEntry, build_trace
from ..acadl.units import FunctionalUnit

__all__ = ["AIDG", "LevelSchedule", "CompiledAIDG", "build_aidg",
           "compile_aidg", "compute_level_schedule", "longest_path",
           "longest_path_fixed_point", "estimate_cycles"]

MAX_PREDS = 12  # minimum padded predecessor slots per node (jnp/Pallas path);
#                 build_aidg widens the padding when a node has more — edges
#                 are never dropped


@dataclass
class AIDG:
    """Padded-CSR forward DAG with per-node work and base offsets."""

    n: int
    work: np.ndarray          # (n,) float32 — w_i = max(1, fu_lat + mem_lat)
    fu_lat: np.ndarray        # (n,) float32 — functional-unit latency
    mem_lat: np.ndarray       # (n,) float32 — total storage latency
    base: np.ndarray          # (n,) float32 — fetch visibility + route latency
    preds: np.ndarray         # (n, MAX_PREDS) int32 — predecessor ids, -1 pad
    pred_extra: np.ndarray    # (n, MAX_PREDS) float32 — extra edge delay
    #                           (t_i >= t_j + pred_extra + w_i)
    # --- storage request-slot queueing (arrival-ordered fixed point) ---
    storage_nodes: Dict[str, np.ndarray] = field(default_factory=dict)
    storage_lat: Dict[str, np.ndarray] = field(default_factory=dict)
    storage_slots: Dict[str, int] = field(default_factory=dict)
    # --- metadata for parameterized re-weighting (DSE) ---
    op_class: np.ndarray = field(                 # (n,) int32
        default_factory=lambda: np.zeros(0, dtype=np.int32))
    op_scale: np.ndarray = field(                 # (n,) float32 — macs/words
        default_factory=lambda: np.zeros(0, dtype=np.float32))
    mem_words: np.ndarray = field(                # (n,) float32
        default_factory=lambda: np.zeros(0, dtype=np.float32))
    classes: Dict[str, int] = field(default_factory=dict)
    stats: Dict[str, Any] = field(default_factory=dict)
    # lazily-built compilation artifact (level schedule + padded gathers),
    # memoized here because the DAG structure is immutable per scenario
    _compiled: Optional["CompiledAIDG"] = field(default=None, repr=False)

    @property
    def edges(self) -> int:
        """Number of real (non-padding) dependency edges in the DAG."""
        return int((self.preds >= 0).sum())


def _fetch_schedule(ag: ArchitectureGraph, trace: Sequence[TraceEntry]
                    ) -> Tuple[np.ndarray, List[List[int]], int]:
    """Static visibility time of each instruction's fetch group (Fig. 9),
    ignoring dynamic stalls (branch bubbles become AIDG edges)."""
    fetch = ag.fetch_stages[0]
    imau = fetch.imau
    imem = imau.instruction_memory
    port_width = max(1, imem.port_width)
    imem_read_lat = imem.access_latency("read", 0)
    fetch_cost = max(1, imem_read_lat + imau.latency.resolve())

    groups: List[List[int]] = []
    cur: List[int] = []
    for e in trace:
        cur.append(e.idx)
        if len(cur) >= port_width or e.is_pc_writer:
            groups.append(cur)
            cur = []
    if cur:
        groups.append(cur)

    visible = np.zeros(len(trace), dtype=np.float32)
    t = 0
    for g in groups:
        t += fetch_cost
        for idx in g:
            visible[idx] = t
    return visible, groups, fetch_cost


def build_aidg(ag: ArchitectureGraph, trace: Sequence[TraceEntry],
               include_buffer_edges: bool = True) -> AIDG:
    """Trace -> AIDG: derive per-node work/base and the forward dependency
    edges (data, structural, branch-bubble, issue-buffer — see the module
    docstring), pad predecessors to CSR form, record the storage queueing
    and DSE metadata, and run the build-time compile pipeline."""
    n = len(trace)
    work = np.ones(n, dtype=np.float32)
    fu_lat_arr = np.zeros(n, dtype=np.float32)
    mem_lat_arr = np.zeros(n, dtype=np.float32)
    base = np.zeros(n, dtype=np.float32)
    route_lat_arr = np.zeros(n, dtype=np.float32)
    preds: List[List[Tuple[int, float]]] = [[] for _ in range(n)]

    op_class = np.zeros(n, dtype=np.int32)
    op_scale = np.ones(n, dtype=np.float32)
    mem_words = np.zeros(n, dtype=np.float32)
    classes: Dict[str, int] = {}

    visible, groups, fetch_cost = _fetch_schedule(ag, trace)
    fetch = ag.fetch_stages[0]
    ibs = max(1, fetch.issue_buffer_size)

    last_on_unit: Dict[str, int] = {}
    last_on_stage: Dict[str, int] = {}
    storage_nodes: Dict[str, List[int]] = {}
    storage_lat: Dict[str, List[float]] = {}
    storage_slots: Dict[str, int] = {}

    for e in trace:
        i = e.idx
        instr = e.instr

        # ---- work = fu latency + memory latency (>= 1 cycle occupancy) ----
        fl = 0.0
        if e.fu_name is not None:
            fu: FunctionalUnit = ag.by_name[e.fu_name]
            tags = instr.tags
            fl = float(fu.latency.resolve(
                operation=instr.operation,
                words=int(tags.get("words", 1)),
                macs=int(tags.get("macs", tags.get("words", 1)))))
        ml = float(e.mem_latency)
        fu_lat_arr[i] = fl
        mem_lat_arr[i] = ml
        work[i] = max(1.0, fl + ml)

        # ---- base = fetch visibility + route buffer latencies ----
        route_lat = 0.0
        for sname in e.route[:-1]:
            stage = ag.by_name[sname]
            route_lat += float(stage.latency.resolve())
        route_lat_arr[i] = route_lat
        base[i] = visible[i] + route_lat

        # ---- data dependencies ----
        for j in e.deps:
            preds[i].append((j, 0.0))

        # ---- structural: same FunctionalUnit / terminal stage serialize ----
        if e.fu_name is not None:
            j = last_on_unit.get(e.fu_name)
            if j is not None:
                preds[i].append((j, 0.0))
            last_on_unit[e.fu_name] = i
        if e.route:
            stage_name = e.route[-1]
            j = last_on_stage.get(stage_name)
            if j is not None and all(p != j for p, _ in preds[i]):
                preds[i].append((j, 0.0))
            last_on_stage[stage_name] = i

        # ---- storage request-slot queueing records ----
        for st_name, lat in e.mem_parts:
            st = ag.by_name[st_name]
            storage_nodes.setdefault(st_name, []).append(i)
            storage_lat.setdefault(st_name, []).append(float(lat))
            storage_slots[st_name] = max(1, st.max_concurrent_requests)
            mem_words[i] = float(instr.tags.get("words", 1))

        # ---- issue-buffer backpressure (approximation) ----
        if include_buffer_edges and i - ibs >= 0:
            preds[i].append((i - ibs, 0.0))

        # ---- DSE metadata ----
        key = (instr.operation if e.fu_name is None
               else f"{instr.operation}@{_unit_class(e.fu_name)}")
        op_class[i] = classes.setdefault(key, len(classes))
        tags = instr.tags
        op_scale[i] = float(tags.get("macs", tags.get("words", 1)))

    # branch bubbles: every instruction of group g+1 waits for the pc-writer
    # closing group g to resolve, then a fetch + route refill
    for gi in range(len(groups) - 1):
        tail = groups[gi][-1]
        if trace[tail].is_pc_writer:
            for idx in groups[gi + 1]:
                preds[idx].append((tail, fetch_cost + route_lat_arr[idx]))

    # pad to (n, width).  width is normally MAX_PREDS but grows to the true
    # maximum in-degree when a node has more predecessors — truncation here
    # would silently under-estimate the critical path (an edge is a timing
    # constraint; dropping one can only make t_i smaller).
    dedups: List[Dict[int, float]] = []
    overflow = 0
    width = MAX_PREDS
    for ps in preds:
        dedup: Dict[int, float] = {}
        for j, d in ps:
            dedup[j] = max(dedup.get(j, -1.0), d)
        if len(dedup) > MAX_PREDS:
            overflow += 1
            width = max(width, len(dedup))
        dedups.append(dedup)
    if overflow:
        warnings.warn(
            f"build_aidg: {overflow} node(s) exceed MAX_PREDS={MAX_PREDS} "
            f"predecessors; widening padded slots to {width} (no edges "
            f"dropped, but evaluator gathers get proportionally wider)",
            RuntimeWarning, stacklevel=2)
    pred_arr = np.full((n, width), -1, dtype=np.int32)
    pred_extra = np.zeros((n, width), dtype=np.float32)
    for i, dedup in enumerate(dedups):
        # latest predecessors first (they bind tightest; order is cosmetic
        # now that every edge is kept)
        for k, (j, d) in enumerate(sorted(dedup.items(), key=lambda kv: -kv[0])):
            pred_arr[i, k] = j
            pred_extra[i, k] = d

    aidg = AIDG(n=n, work=work, fu_lat=fu_lat_arr, mem_lat=mem_lat_arr,
                base=base, preds=pred_arr, pred_extra=pred_extra,
                storage_nodes={k: np.asarray(v, dtype=np.int64)
                               for k, v in storage_nodes.items()},
                storage_lat={k: np.asarray(v, dtype=np.float32)
                             for k, v in storage_lat.items()},
                storage_slots=storage_slots,
                op_class=op_class, op_scale=op_scale, mem_words=mem_words,
                classes=classes,
                stats={"groups": len(groups), "pred_overflow": overflow,
                       "pred_width": width, "fetch_cost": fetch_cost})
    compile_aidg(aidg)  # level schedule is build-time, structure is static
    return aidg


def _unit_class(fu_name: str) -> str:
    """Collapse template-replicated units (fu[0][1], lsu3) to a class name
    so DSE parameters are shared across identical units."""
    import re

    return re.sub(r"\d+", "#", fu_name)


# ---------------------------------------------------------------------------
# build-time compilation: trace -> AIDG -> LevelSchedule -> CompiledAIDG
# ---------------------------------------------------------------------------


@dataclass
class LevelSchedule:
    """Topological wavefront schedule of the AIDG, in level-major layout.

    ``depth[i]`` is node i's longest-path depth (0 for source nodes, else
    1 + max over predecessors), so every predecessor of a node sits at a
    strictly smaller depth.  Nodes are renumbered level-major (``order``:
    permuted position -> original id; ``rank``: original id -> permuted
    position) so each level occupies the contiguous permuted slots
    ``[starts[d], starts[d] + counts[d])``.  The wavefront evaluator scans
    over ``starts`` with a fixed window of ``width`` slots per step —
    contiguous dynamic slices in, one dynamic-update-slice out — for
    O(n_levels) sequential device steps instead of O(n).  A window wider
    than its level spills into the next level's slots; those lanes compute
    garbage from not-yet-final inputs and are deterministically overwritten
    when their own level runs (windows never reach *earlier* slots).

    ``level_nodes[d]`` lists the original ids at depth d (pad ``n``) — the
    gather-form view kept for inspection and stats.
    """

    n: int
    depth: np.ndarray          # (n,) int32
    level_nodes: np.ndarray    # (n_levels, width) int32, pad = n
    order: np.ndarray          # (n,) int32 — permuted position -> original id
    rank: np.ndarray           # (n,) int32 — original id -> permuted position
    starts: np.ndarray         # (n_levels,) int32 — level start, permuted

    @property
    def n_levels(self) -> int:
        """Critical depth of the DAG = sequential wavefront steps."""
        return int(self.level_nodes.shape[0])

    @property
    def width(self) -> int:
        """Widest level = the wavefront evaluator's window size."""
        return int(self.level_nodes.shape[1])

    @property
    def parallelism(self) -> float:
        """Mean nodes per level = the sequential-depth compression the
        wavefront evaluator gets over the per-node scan."""
        return self.n / max(1, self.n_levels)


def compute_level_schedule(preds: np.ndarray, n: int) -> LevelSchedule:
    """Longest-path depths + level-major renumbering for a padded-CSR
    forward DAG (all predecessor ids < node id)."""
    depth = np.zeros(n, dtype=np.int32)
    for i in range(n):
        row = preds[i]
        js = row[row >= 0]
        if js.size:
            depth[i] = int(depth[js].max()) + 1
    if n == 0:
        z = np.zeros(0, dtype=np.int32)
        return LevelSchedule(0, depth, np.zeros((0, 0), dtype=np.int32),
                             z, z, z)
    n_levels = int(depth.max()) + 1
    counts = np.bincount(depth, minlength=n_levels)
    order = np.argsort(depth, kind="stable")   # trace order within a level
    rank = np.empty(n, dtype=np.int32)
    rank[order] = np.arange(n, dtype=np.int32)
    starts = np.zeros(n_levels, dtype=np.int64)
    np.cumsum(counts[:-1], out=starts[1:])
    level_nodes = np.full((n_levels, int(counts.max())), n, dtype=np.int32)
    cols = np.arange(n) - starts[depth[order]]
    level_nodes[depth[order], cols] = order
    return LevelSchedule(n, depth, level_nodes, order.astype(np.int32), rank,
                         starts.astype(np.int32))


@dataclass
class CompiledAIDG:
    """Build-time compilation artifact: the AIDG plus everything the device
    evaluators need that depends only on *structure* (never on θ): the
    level schedule, the predecessor gather arrays rewritten into the
    schedule's level-major numbering (so each wavefront step reads a
    contiguous window), and per-storage scatter indices in a deterministic
    order.  Built once per scenario by ``compile_aidg`` and shared by every
    sweep over the same graph."""

    aidg: AIDG
    schedule: LevelSchedule
    # (n + width, p_used): predecessor *permuted positions* / extra edge
    # delays, rows in level-major order, -1 pad; the slot axis is trimmed
    # from the AIDG's fixed MAX_PREDS padding to the true maximum in-degree
    # (typically 2-4x narrower — pad slots are pure wasted compute on the
    # device), and the trailing ``width`` rows absorb the last wavefront
    # window's spill
    preds_lv: np.ndarray
    extra_lv: np.ndarray
    storage_order: Tuple[str, ...]
    storage_scatter: Dict[str, np.ndarray]   # name -> (k,) int32 node ids
    # per-block-size banded edge matrices for the blocked engine, built on
    # first use (structure only — runtime work/base are folded at eval)
    _block_cache: Dict[int, Tuple] = field(default_factory=dict, repr=False)

    @property
    def n(self) -> int:
        """Node (instruction) count of the underlying AIDG."""
        return self.aidg.n


def compile_aidg(aidg: AIDG) -> CompiledAIDG:
    """AIDG -> CompiledAIDG, memoized on the AIDG instance (the DAG is
    immutable per scenario; only work/base/storage latencies vary)."""
    if aidg._compiled is not None:
        return aidg._compiled
    sched = compute_level_schedule(aidg.preds, aidg.n)
    # slots are packed left by build_aidg, so trimming to the true maximum
    # in-degree drops only pad columns
    deg = (aidg.preds >= 0).sum(axis=1)
    p = max(1, int(deg.max())) if aidg.n else 1
    w = sched.width
    perm_preds = aidg.preds[sched.order][:, :p]   # (n, p_used), original ids
    mapped = np.where(perm_preds >= 0,
                      sched.rank[np.maximum(perm_preds, 0)], -1)
    preds_lv = np.concatenate(
        [mapped, np.full((w, p), -1, dtype=np.int32)], axis=0)
    extra_lv = np.concatenate(
        [aidg.pred_extra[sched.order][:, :p],
         np.zeros((w, p), dtype=np.float32)], axis=0)
    order = tuple(sorted(aidg.storage_nodes))
    scatter = {s: np.asarray(aidg.storage_nodes[s], dtype=np.int32)
               for s in order}
    ca = CompiledAIDG(aidg=aidg, schedule=sched,
                      preds_lv=preds_lv.astype(np.int32), extra_lv=extra_lv,
                      storage_order=order, storage_scatter=scatter)
    aidg.stats["n_levels"] = sched.n_levels
    aidg.stats["max_level_width"] = sched.width
    aidg._compiled = ca
    return ca


def longest_path(aidg: AIDG, work: Optional[np.ndarray] = None,
                 base: Optional[np.ndarray] = None) -> np.ndarray:
    """Exact O(E) forward relaxation over the forward DAG (no storage
    queueing): t_i = w_i + max(base_i, max_j (t_j + d_ji))."""
    w = aidg.work if work is None else work
    b = aidg.base if base is None else base
    t = np.zeros(aidg.n, dtype=np.float64)
    preds = aidg.preds
    extra = aidg.pred_extra
    for i in range(aidg.n):
        m = b[i]
        row = preds[i]
        for k in range(row.shape[0]):
            j = row[k]
            if j < 0:
                break
            v = t[j] + extra[i, k]
            if v > m:
                m = v
        t[i] = m + w[i]
    return t


def longest_path_fixed_point(aidg: AIDG, n_iters: int = 3,
                             work: Optional[np.ndarray] = None,
                             base: Optional[np.ndarray] = None,
                             storage_lat: Optional[Dict[str, np.ndarray]] = None,
                             ) -> np.ndarray:
    """Forward relaxation + arrival-ordered request-slot queueing, iterated
    to a fixed point (paper [16]).

    Each outer iteration: (1) exact longest path over the forward DAG with
    the current per-node base offsets; (2) replay every storage's accesses in
    estimated-arrival order against its ``max_concurrent_requests`` slots;
    (3) fold each access's service-completion (+ its unit latency) back into
    the node's base.  Stops early when the makespan is stable.
    """
    import heapq

    w = aidg.work if work is None else work
    b0 = aidg.base if base is None else base
    slat = aidg.storage_lat if storage_lat is None else storage_lat
    b = b0.astype(np.float64).copy()
    t = longest_path(aidg, work=w, base=b)
    if not aidg.storage_nodes:
        return t
    prev_makespan = t.max() if aidg.n else 0.0
    for _ in range(n_iters):
        b = b0.astype(np.float64).copy()
        for st_name, nodes in aidg.storage_nodes.items():
            lats = slat[st_name]
            slots = aidg.storage_slots[st_name]
            # arrival = when the unit would issue the transaction
            arrival = t[nodes] - w[nodes]
            order = np.argsort(arrival, kind="stable")
            heap = [0.0] * slots
            heapq.heapify(heap)
            for k in order:
                i = int(nodes[k])
                begin = max(float(arrival[k]), heapq.heappop(heap))
                done = begin + float(lats[k])
                heapq.heappush(heap, done)
                # t_i >= done + fu_lat_i  ->  base_i >= done + fu - w
                need = done + aidg.fu_lat[i] - w[i]
                if need > b[i]:
                    b[i] = need
        t = longest_path(aidg, work=w, base=b)
        makespan = t.max()
        if abs(makespan - prev_makespan) < 0.5:
            break
        prev_makespan = makespan
    return t


def estimate_cycles(ag: ArchitectureGraph, program: Sequence[Any],
                    entry: int = 0, n_iters: int = 3) -> Tuple[float, AIDG]:
    """Trace + AIDG + fixed-point longest path -> estimated cycles (the
    paper's fast performance estimation)."""
    trace = build_trace(ag, program, entry)
    aidg = build_aidg(ag, trace)
    t = longest_path_fixed_point(aidg, n_iters=n_iters)
    return (float(t.max()) if aidg.n else 0.0), aidg
