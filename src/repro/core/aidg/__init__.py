"""AIDG: Architectural Instruction Dependency Graph fast estimation
(paper §6, [16]) — numpy exact path, JAX max-plus paths, DSE sweeps."""

from .builder import (
    AIDG,
    build_aidg,
    estimate_cycles,
    longest_path,
    longest_path_fixed_point,
)
from .maxplus import (
    fixed_point_batch,
    fixed_point_jax,
    longest_path_blocked,
    longest_path_scan,
    maxplus_closure,
    maxplus_matmul_jnp,
    slot_queue_scan,
)
from .dse import (DSEProblem, compiled_sweep, evaluate_theta, make_problem,
                  sweep)
from .explorer import (
    DEFAULT_SPACE,
    CompiledScenario,
    DesignSpace,
    ExplorationResult,
    Explorer,
    Knob,
    Scenario,
    clear_scenario_cache,
    compile_scenario,
    default_scenarios,
    grid_candidates,
    pareto_front,
    random_candidates,
)

__all__ = [
    "AIDG", "build_aidg", "estimate_cycles", "longest_path",
    "longest_path_fixed_point",
    "longest_path_scan", "longest_path_blocked", "fixed_point_jax",
    "fixed_point_batch",
    "maxplus_closure", "maxplus_matmul_jnp", "slot_queue_scan",
    "DSEProblem", "make_problem", "evaluate_theta", "compiled_sweep", "sweep",
    "Scenario", "CompiledScenario", "default_scenarios", "compile_scenario",
    "clear_scenario_cache", "Knob", "DesignSpace", "DEFAULT_SPACE",
    "grid_candidates", "random_candidates", "pareto_front",
    "Explorer", "ExplorationResult",
]
