"""AIDG: Architectural Instruction Dependency Graph fast estimation
(paper §6, [16]) — numpy exact path, JAX max-plus paths, DSE sweeps."""

from .builder import (
    AIDG,
    build_aidg,
    estimate_cycles,
    longest_path,
    longest_path_fixed_point,
)
from .maxplus import (
    fixed_point_jax,
    longest_path_blocked,
    longest_path_scan,
    maxplus_closure,
    maxplus_matmul_jnp,
    slot_queue_scan,
)
from .dse import DSEProblem, evaluate_theta, make_problem, sweep

__all__ = [
    "AIDG", "build_aidg", "estimate_cycles", "longest_path",
    "longest_path_fixed_point",
    "longest_path_scan", "longest_path_blocked", "fixed_point_jax",
    "maxplus_closure", "maxplus_matmul_jnp", "slot_queue_scan",
    "DSEProblem", "make_problem", "evaluate_theta", "sweep",
]
