"""AIDG: Architectural Instruction Dependency Graph fast estimation
(paper §6, [16]) — numpy exact path, compiled JAX max-plus engines
(trace → AIDG → LevelSchedule → CompiledAIDG), DSE sweeps."""

from .builder import (
    AIDG,
    CompiledAIDG,
    CondensedAIDG,
    LevelSchedule,
    build_aidg,
    compile_aidg,
    compute_level_schedule,
    condense_aidg,
    estimate_cycles,
    longest_path,
    longest_path_fixed_point,
)
from .maxplus import (
    DEFAULT_ENGINE,
    ENGINES,
    fixed_point_batch,
    fixed_point_jax,
    fixed_point_soft,
    longest_path_blocked,
    longest_path_condensed,
    longest_path_scan,
    longest_path_soft,
    longest_path_wavefront,
    maxplus_closure,
    maxplus_matmul_jnp,
    slot_queue_scan,
    slot_queue_soft,
    softmax_reduce,
    softmaximum,
)
from .dse import (DSEProblem, PackedMatrix, compiled_sweep, evaluate_theta,
                  evaluate_theta_soft, grad_sweep, make_problem, sweep)
from .gradient import GradientExplorer, GradientResult
from .explorer import (
    DEFAULT_SPACE,
    CompiledScenario,
    DesignSpace,
    ExplorationResult,
    Explorer,
    Knob,
    Scenario,
    clear_scenario_cache,
    compile_scenario,
    default_scenarios,
    grid_candidates,
    pareto_front,
    random_candidates,
)

__all__ = [
    "AIDG", "CompiledAIDG", "CondensedAIDG", "LevelSchedule", "build_aidg",
    "compile_aidg", "compute_level_schedule", "condense_aidg",
    "estimate_cycles", "longest_path", "longest_path_fixed_point",
    "ENGINES", "DEFAULT_ENGINE",
    "longest_path_wavefront", "longest_path_scan", "longest_path_blocked",
    "longest_path_condensed", "longest_path_soft", "fixed_point_jax",
    "fixed_point_batch", "fixed_point_soft", "maxplus_closure",
    "maxplus_matmul_jnp",
    "slot_queue_scan", "slot_queue_soft", "softmaximum", "softmax_reduce",
    "DSEProblem", "PackedMatrix", "make_problem", "evaluate_theta",
    "evaluate_theta_soft", "grad_sweep", "compiled_sweep", "sweep",
    "GradientExplorer", "GradientResult",
    "Scenario", "CompiledScenario", "default_scenarios", "compile_scenario",
    "clear_scenario_cache", "Knob", "DesignSpace", "DEFAULT_SPACE",
    "grid_candidates", "random_candidates", "pareto_front",
    "Explorer", "ExplorationResult",
]
