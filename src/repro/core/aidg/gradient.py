"""Gradient-based design-space optimization over the smooth max-plus
relaxation (paper §1/§7: the timing model *inside* the co-design loop).

``Explorer.refine`` moves 5 shared knobs by derivative-free coordinate
descent — ``points x knobs x rounds`` full-matrix sweeps.  But the sweep is
pure JAX end-to-end, so the makespan is differentiable in θ; this module
makes the gradient first-class:

* the objective is evaluated through ``dse.grad_sweep`` — one cached
  ``jit(vmap(value_and_grad))`` per scenario, gradients landing directly on
  the shared knobs (the ``DesignSpace.projection`` chain is traced), on the
  temperature-τ smooth family of ``maxplus.fixed_point_soft`` — or, when
  the explorer runs the matrix-packed engine (the default), through ONE
  ``dse.PackedMatrix.grad_fn`` dispatch differentiating every cell at
  once;
* the area proxy  cost(θ) = Σ_k w_k / θ_k  is differentiated analytically
  alongside (``d cost/d θ_k = -w_k / θ_k²``);
* ``GradientExplorer.refine`` runs **batched multi-start projected Adam**
  (every start is one vmap lane of the same compiled kernel) in the
  **log-domain** u = log θ — multiplicative knobs get scale-free steps and
  the box [lo, hi] becomes a simple clip of u — with **τ annealing** from a
  heavily smoothed landscape down to a near-exact one (τ is traced, so the
  schedule never re-traces);
* the finishing step re-scores every start with the *hard* evaluator, so
  the returned design is judged by the same objective as every other
  candidate generator.

A budget of ``starts x (steps + 1)`` candidate evaluations replaces the
coordinate-descent sweep's ``(points + 1) x knobs x rounds`` — measured in
``benchmarks/bench_dse.py`` (``dse/gradient``) and asserted end-to-end by
``tests/test_gradient_dse.py``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ...optim.adamw import AdamWConfig, adamw_init, adamw_update
from .explorer import Explorer

__all__ = ["GradientResult", "GradientExplorer"]

OBJECTIVES = ("product", "latency", "energy", "edp")


@dataclass
class GradientResult:
    """One multi-start run: the incumbent plus enough trail to audit it."""

    theta: np.ndarray           # (K,) best knob vector, judged by hard score
    score: float                # hard objective of ``theta``
    start_thetas: np.ndarray    # (M, K) where each start began
    final_thetas: np.ndarray    # (M, K) where each start converged
    final_scores: np.ndarray    # (M,) hard objective per start
    evaluations: int            # candidate evaluations consumed (grad + hard)
    history: List[Dict[str, float]] = field(default_factory=list)

    @property
    def best_start(self) -> int:
        """Index of the start whose hard final score won."""
        return int(np.argmin(self.final_scores))


class GradientExplorer:
    """Batched multi-start projected Adam over an ``Explorer``'s matrix.

    Shares the explorer's compiled scenarios, projections, baselines, and
    knob weights; adds one cached gradient kernel per scenario.  The
    descent objective is the *log* of the hard score —
    ``log latency + log cost`` for ``objective="product"`` (or just
    ``log latency``) — because the product's two factors move on different
    scales and the log makes Adam's per-knob steps comparable.  The energy
    objectives (``"energy"``, ``"edp"`` = energy-delay product) ride the
    packed 3-objective dispatch (``PackedMatrix.grad3_fn``): the dynamic
    term's gradient is analytic (``-edyn_k/θ_k²``) and the static term
    differentiates through the soft makespan, all in the same trace.
    """

    def __init__(self, explorer: Explorer, objective: str = "product"):
        if objective not in OBJECTIVES:
            raise ValueError(f"objective must be one of {OBJECTIVES}, "
                             f"got {objective!r}")
        self.explorer = explorer
        self.objective = objective
        self.space = explorer.space
        self._baselines = np.asarray(explorer.baselines, np.float64)
        self._packed3_fn = None
        if objective in ("energy", "edp") and explorer.engine != "packed":
            raise ValueError(
                f"objective {objective!r} needs the packed engine's "
                f"3-objective dispatch (this explorer uses "
                f"{explorer.engine!r})")
        if explorer.engine == "packed":
            # ONE cached jit(vmap(value_and_grad)) for the whole matrix:
            # the packed soft evaluator differentiates every cell (operator
            # and end-to-end network compositions alike) in one dispatch
            self._packed_fn = explorer.packed_matrix().grad_fn(
                self._baselines)
            if objective in ("energy", "edp"):
                self._packed3_fn = explorer.packed_matrix().grad3_fn(
                    self._baselines, explorer.energy_baselines)
            self._fns = None
        else:
            # one cached jit(vmap(value_and_grad)) per cell, built through
            # the cell protocol so operator cells and whole-network cells
            # both contribute their d(cycles)/d(knob)
            self._packed_fn = None
            self._fns = [cs.grad_fn(proj, n_iters=explorer.n_iters)
                         for cs, proj
                         in zip(explorer.compiled, explorer._projections)]
        self._weights = explorer.knob_weights().astype(np.float64)
        self._log_lo = np.log([k.lo for k in self.space.knobs])
        self._log_hi = np.log([k.hi for k in self.space.knobs])

    # -- the smooth objective ----------------------------------------------

    def value_and_grad(self, knob_thetas: np.ndarray, tau: float
                       ) -> Tuple[np.ndarray, np.ndarray]:
        """(M, K) candidates -> (objective (M,), d objective/d θ (M, K)) at
        temperature τ.  Latency and its gradient come from the per-scenario
        compiled kernels; the cost factor enters analytically."""
        kt = jnp.asarray(np.atleast_2d(knob_thetas), jnp.float32)
        if self._packed3_fn is not None:
            v, j = self._packed3_fn(kt, jnp.float32(tau))
            v = np.asarray(v, np.float64)
            j = np.asarray(j, np.float64)
            lat, en = v[:, 0], v[:, 1]
            dlat, den = j[:, 0, :], j[:, 1, :]
            if self.objective == "energy":
                return np.log(en), den / en[:, None]
            return (np.log(lat) + np.log(en),                 # "edp"
                    dlat / lat[:, None] + den / en[:, None])
        if self._packed_fn is not None:
            v, g = self._packed_fn(kt, jnp.float32(tau))
            lat = np.asarray(v, np.float64)
            dlat = np.asarray(g, np.float64)
        else:
            M = kt.shape[0]
            lat = np.zeros(M, np.float64)
            dlat = np.zeros((M, self.space.n), np.float64)
            for fn, b in zip(self._fns, self._baselines):
                v, g = fn(kt, jnp.float32(tau))
                lat += np.asarray(v, np.float64) / b
                dlat += np.asarray(g, np.float64) / b
            S = len(self._fns)
            lat /= S
            dlat /= S
        obj = np.log(lat)
        grad = dlat / lat[:, None]
        if self.objective == "product":
            th = np.asarray(np.atleast_2d(knob_thetas), np.float64)
            cost = (self._weights[None, :] / th).sum(axis=1)
            dcost = -self._weights[None, :] / th ** 2
            obj = obj + np.log(cost)
            grad = grad + dcost / cost[:, None]
        return obj, grad

    def hard_score(self, knob_thetas: np.ndarray) -> np.ndarray:
        """The non-smooth objective every other generator is judged by."""
        res = self.explorer.explore(np.atleast_2d(knob_thetas))
        return {"product": res.latency * res.cost,
                "latency": res.latency,
                "energy": res.energy,
                "edp": res.latency * res.energy}[self.objective]

    # -- batched multi-start projected Adam --------------------------------

    def make_starts(self, start: Optional[np.ndarray], starts: int,
                    seed: int) -> np.ndarray:
        """(M, K) start matrix: row 0 is ``start`` (default θ = 1, the
        reference machine), the rest log-uniform in the knob box."""
        K = self.space.n
        first = (np.ones(K, np.float32) if start is None
                 else self.space.clip(start).reshape(K))
        rng = np.random.default_rng(seed)
        rows = [first]
        for _ in range(max(0, starts - 1)):
            rows.append(np.exp(rng.uniform(self._log_lo, self._log_hi))
                        .astype(np.float32))
        return np.stack(rows)

    def refine(self, start: Optional[np.ndarray] = None, starts: int = 2,
               steps: int = 22, lr: float = 0.25, tau0: float = 0.5,
               tau_min: float = 0.01, seed: int = 0) -> GradientResult:
        """Run ``steps`` Adam updates on u = log θ for ``starts`` parallel
        starts, annealing τ geometrically tau0 -> tau_min, then re-score
        the finals with the hard evaluator and return the incumbent.

        Candidate-evaluation budget: ``starts * steps`` gradient evals plus
        ``starts`` hard finals — with the defaults, 46 evaluations against
        the 100 of ``Explorer.refine``'s default coordinate descent (and a
        matching-or-better latency·cost incumbent, asserted end-to-end by
        ``tests/test_gradient_dse.py`` and measured by the ``dse/gradient``
        benchmark row)."""
        start_thetas = self.make_starts(start, starts, seed)
        u = jnp.asarray(np.log(start_thetas), jnp.float32)
        lo = jnp.asarray(self._log_lo, jnp.float32)
        hi = jnp.asarray(self._log_hi, jnp.float32)
        # Adam reused from the training stack (state is a generic pytree —
        # here a single (M, K) leaf).  No weight decay: u = 0 is θ = 1, and
        # decaying toward the reference machine would bias the search; no
        # global-norm clip: it would couple unrelated starts.
        cfg = AdamWConfig(lr=lr, b1=0.9, b2=0.95, weight_decay=0.0,
                          clip_norm=0.0)
        state = adamw_init(u)
        history: List[Dict[str, float]] = []
        taus = (np.geomspace(tau0, max(tau_min, 1e-4), steps)
                if steps > 1 else np.asarray([tau0]))
        for t, tau in enumerate(taus[:steps]):
            theta = np.exp(np.asarray(u, np.float64))
            obj, dtheta = self.value_and_grad(theta, float(tau))
            du = jnp.asarray(dtheta * theta, jnp.float32)   # d/du = θ·d/dθ
            u, state, _ = adamw_update(cfg, u, du, state)
            u = jnp.clip(u, lo, hi)                          # projection
            history.append({"step": t, "tau": float(tau),
                            "obj_mean": float(obj.mean()),
                            "obj_min": float(obj.min())})
        final_thetas = np.exp(np.asarray(u, np.float64)).astype(np.float32)
        final_scores = np.asarray(self.hard_score(final_thetas), np.float64)
        best = int(np.argmin(final_scores))
        evals = start_thetas.shape[0] * len(taus[:steps]) \
            + start_thetas.shape[0]
        return GradientResult(theta=final_thetas[best],
                              score=float(final_scores[best]),
                              start_thetas=start_thetas,
                              final_thetas=final_thetas,
                              final_scores=final_scores,
                              evaluations=evals, history=history)
