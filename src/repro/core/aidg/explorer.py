"""Batched multi-architecture design-space exploration (DSE) engine.

The paper's payoff (§1/§7) is that the AIDG timing model is fast enough to
sit *inside* an optimization loop.  ``repro.core.aidg.dse`` delivers that
for one (architecture, workload) pair; this module scales it to the full
**scenario matrix**:

    every architecture in ``repro.core.archs.ARCH_REGISTRY``
        (oma, systolic, gamma, eyeriss, plasticine, tpu_v5e)
  x every workload mapped onto it
        (gemm, conv, attention, selective-scan, map-reduce)
  x thousands of candidate accelerator parameterizations θ

evaluated in batched JAX calls.  The moving parts:

* **Scenario** — a named (arch, workload) cell with a builder that returns
  a fresh ``(ArchitectureGraph, program)``.  ``default_scenarios()`` yields
  the built-in matrix; cells that don't map (e.g. conv on OMA) are simply
  absent.
* **AIDG cache** — ``compile_scenario`` traces the program, builds the
  AIDG, and derives the ``DSEProblem`` ONCE per scenario; every subsequent
  sweep re-uses the cached graph (cold build ≡ cached build, asserted by
  ``tests/test_dse_explorer.py``).
* **DesignSpace / Knob** — a small set of named multiplicative latency
  factors shared ACROSS architectures.  A knob matches op classes and/or
  storages by regex (e.g. the ``matrix`` knob scales ``gemm@matMulFu#`` on
  Γ̈ *and* ``gemm@mxu#`` on the TPU model), so one candidate vector
  parameterizes every scenario at once; unmatched classes stay at θ = 1.
* **Candidate generators** — ``grid_candidates``, ``random_candidates``,
  and ``Explorer.refine`` (coordinate descent around the incumbent).
* **Multi-objective scoring + Pareto frontier** — latency (mean
  baseline-relative cycles across the matrix) vs. energy (per-op-class
  dynamic + static coefficients from ``repro.core.archs.energy``, folded
  into the same dispatch) vs. a cost/area proxy (silicon spent speeding a
  knob up is ∝ the parameter volume the knob governs, divided by θ).
  ``pareto_front`` extracts the deterministic non-dominated set over any
  number of objectives.

Worked example (numbers in ``docs/dse.md``, measured by
``benchmarks/bench_dse.py``)::

    from repro.core.aidg.explorer import Explorer, random_candidates
    ex = Explorer()                        # full matrix, cached AIDGs
    cand = random_candidates(ex.space, 1024)
    res = ex.explore(cand)                 # one batched sweep per scenario
    for row in res.frontier():             # Pareto-optimal designs
        print(row)
"""

from __future__ import annotations

import re
import warnings
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..acadl.sim import build_trace, simulate
from ..archs.energy import energy_model
from .builder import (AIDG, CompiledAIDG, LevelSchedule, build_aidg,
                      condense_aidg, longest_path_fixed_point)
from .dse import DSEProblem, PackSpec, PackedMatrix, make_problem, sweep
from .energy import fold_dyn_energy
from .maxplus import DEFAULT_ENGINE, ENGINES

# the Explorer's engine knob: every per-cell max-plus relaxation, plus the
# matrix-packed single-dispatch evaluator (the default)
EXPLORER_ENGINES = ENGINES + ("packed",)
DEFAULT_EXPLORER_ENGINE = "packed"

__all__ = [
    "Scenario", "CompiledScenario", "default_scenarios", "compile_scenario",
    "clear_scenario_cache", "scenario_cache_stats", "Knob", "DesignSpace",
    "DEFAULT_SPACE", "EXPLORER_ENGINES", "DEFAULT_EXPLORER_ENGINE",
    "grid_candidates", "random_candidates", "pareto_front", "resolve_cells",
    "Explorer", "ExplorationResult",
]


# ---------------------------------------------------------------------------
# scenarios: the (architecture, workload) matrix
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Scenario:
    """One cell of the matrix: how to build (AG, program) from scratch.

    ``params`` is the hashable identity of the cell (sizes, unit counts);
    together with (arch, workload) it keys the AIDG cache.  ``sim_tol`` is
    the expected relative AIDG-vs-event-simulator error (0.0 = exact)."""

    arch: str
    workload: str
    build: Callable[[], Tuple[object, list]]
    params: Tuple[Tuple[str, object], ...] = ()
    sim_tol: float = 0.0

    @property
    def name(self) -> str:
        """Display name, ``arch/workload``."""
        return f"{self.arch}/{self.workload}"

    @property
    def key(self) -> Tuple:
        """AIDG-cache key: (arch, workload, params, builder identity) — the
        builder participates so two scenarios sharing sizes but built by
        different functions don't silently alias in the cache."""
        return (self.arch, self.workload, self.params,
                getattr(self.build, "__module__", ""),
                getattr(self.build, "__qualname__", ""))


def _gamma_units(nu: int) -> Tuple[Tuple[str, str, str], ...]:
    return tuple((f"lsu{k}", f"matMulFu{k}", f"vrf{k}") for k in range(nu))


def _attn_units(nu: int) -> Tuple[Tuple[str, str, str], ...]:
    return tuple((f"lsu{k}", f"matAddFu{k}", f"vrf{k}") for k in range(nu))


def _build_oma_gemm(n: int):
    from ..archs import ARCH_REGISTRY
    from ..mapping.gemm import init_gemm_memory, oma_gemm_looped
    ag, _ = ARCH_REGISTRY["oma"]()
    A = np.ones((n, n))
    init_gemm_memory(ag, A, A)
    return ag, oma_gemm_looped(n, n, n)


def _build_systolic_gemm(m: int, k: int, l: int, rows: int, cols: int):
    from ..archs import ARCH_REGISTRY
    from ..mapping.systolic import init_systolic_memory, systolic_gemm_program
    ag, _ = ARCH_REGISTRY["systolic"](rows, cols)
    init_systolic_memory(ag, np.ones((m, k)), np.ones((k, l)))
    return ag, systolic_gemm_program(m, k, l, rows, cols)


def _build_gamma_gemm(n: int, nu: int):
    from ..archs import ARCH_REGISTRY
    from ..mapping.gemm import gamma_gemm, init_gemm_memory
    ag, _ = ARCH_REGISTRY["gamma"](n_units=nu)
    A = np.ones((n, n), np.float32)
    init_gemm_memory(ag, A, A, memory="dram0", tile=8)
    return ag, gamma_gemm(n, n, n, tile=8, units=_gamma_units(nu))


def _build_gamma_attention(seq: int, ctx: int, hd: int, nu: int):
    from ..archs import ARCH_REGISTRY
    from ..mapping.fused import gamma_attention
    ag, _ = ARCH_REGISTRY["gamma"](n_units=nu)
    return ag, gamma_attention(seq, ctx, hd, units=_attn_units(nu))


def _build_gamma_scan(tokens: int, d_state: int, nu: int):
    from ..archs import ARCH_REGISTRY
    from ..mapping.fused import gamma_scan
    ag, _ = ARCH_REGISTRY["gamma"](n_units=nu)
    return ag, gamma_scan(tokens, d_state, units=_attn_units(nu))


def _build_eyeriss_conv(ifm_h: int, ifm_w: int, flt: int, rows: int, cols: int):
    from ..archs import ARCH_REGISTRY
    from ..mapping.conv import eyeriss_conv2d, init_conv_memory
    ag, _ = ARCH_REGISTRY["eyeriss"](rows=rows, columns=cols)
    init_conv_memory(ag, np.ones((ifm_h, ifm_w)), np.ones((flt, flt)))
    return ag, eyeriss_conv2d(ifm_h, ifm_w, flt, flt, rows, cols)


def _build_plasticine_reduce(n: int, npcu: int):
    from ..archs import ARCH_REGISTRY
    from ..mapping.patterns import init_vector_memory, plasticine_map_reduce
    ag, _ = ARCH_REGISTRY["plasticine"](n_pcu=npcu, n_pmu=npcu)
    init_vector_memory(ag, np.ones(n), npcu)
    return ag, plasticine_map_reduce(n, npcu, npcu)


def _build_tpu(op: str, m: int, k: int, n: int, count: int):
    from ..archs import ARCH_REGISTRY
    from ..mapping.workload import OperatorCall, UMA_REGISTRY
    ag, _ = ARCH_REGISTRY["tpu_v5e"]()
    fn = UMA_REGISTRY[("tpu_v5e", op)]
    return ag, fn(OperatorCall(op, m, k, n, count, "dse"))


def default_scenarios() -> List[Scenario]:
    """The built-in matrix: 6 architectures x 5 workload kinds, 10 mapped
    cells.  Sizes are chosen so every trace builds in well under a second
    while still exercising multi-unit overlap and storage queueing."""

    def S(arch, wl, fn, *args, tol=0.0, **kw):
        # the wrapped builder's identity goes into params: every lambda
        # minted here shares one __qualname__, so Scenario.key's builder
        # guard alone cannot tell two S(...) cells apart
        params = ((("__builder__", f"{fn.__module__}.{fn.__qualname__}"),)
                  + tuple(enumerate(args)) + tuple(sorted(kw.items())))
        return Scenario(arch, wl, lambda: fn(*args, **kw), params, tol)

    return [
        S("oma", "gemm", _build_oma_gemm, 6),
        S("systolic", "gemm", _build_systolic_gemm, 8, 12, 8, 4, 4, tol=0.04),
        S("gamma", "gemm", _build_gamma_gemm, 32, 2, tol=0.02),
        S("gamma", "attention", _build_gamma_attention, 32, 64, 8, 2),
        S("gamma", "scan", _build_gamma_scan, 256, 16, 2),
        S("eyeriss", "conv", _build_eyeriss_conv, 10, 12, 3, 4, 4, tol=0.08),
        S("plasticine", "reduce", _build_plasticine_reduce, 1024, 4, tol=0.02),
        S("tpu_v5e", "gemm", _build_tpu, "gemm", 256, 256, 256, 8, tol=0.02),
        S("tpu_v5e", "attention", _build_tpu, "attention", 128, 256, 256, 8,
          tol=0.02),
        S("tpu_v5e", "scan", _build_tpu, "scan", 128, 512, 2, 8, tol=0.02),
    ]


# ---------------------------------------------------------------------------
# per-scenario compilation + AIDG cache
# ---------------------------------------------------------------------------


@dataclass
class CompiledScenario:
    """Trace + AIDG + DSEProblem for one cell, built once and re-used by
    every sweep (the graph is *structure*; θ only re-weights it).

    Implements the **cell protocol** the :class:`Explorer` evaluates
    against — ``projection`` / ``evaluate`` / ``accumulate_weights`` /
    ``grad_fn`` / ``simulate`` / ``stats_row`` — so operator cells and
    whole-network cells (``repro.core.network.CompiledNetwork``) are
    interchangeable rows of the scenario matrix."""

    scenario: Scenario
    aidg: AIDG
    problem: DSEProblem
    baseline: float            # fixed-point makespan at θ = 1

    @property
    def name(self) -> str:
        """Display name inherited from the scenario (``arch/workload``)."""
        return self.scenario.name

    @property
    def arch(self) -> str:
        """The cell's architecture (query-resolution protocol)."""
        return self.scenario.arch

    @property
    def workload(self) -> str:
        """The cell's workload kind (query-resolution protocol): an
        operator name here; network cells report their network name."""
        return self.scenario.workload

    @property
    def compiled_aidg(self) -> CompiledAIDG:
        """The build-time compilation artifact shared by every sweep."""
        return self.problem.compiled_aidg

    @property
    def schedule(self) -> LevelSchedule:
        """The build-time level schedule (trace → AIDG → LevelSchedule →
        CompiledAIDG): n_levels sequential wavefront steps instead of n."""
        return self.compiled_aidg.schedule

    # -- the cell protocol (shared with repro.core.network) -----------------

    def projection(self, space: "DesignSpace"):
        """Cell-opaque projection data for ``space`` (cached per cell by
        the Explorer): here the (op -> knob, storage -> knob) gather maps."""
        return space.projection(self.problem)

    def evaluate(self, space: "DesignSpace", knob_thetas: np.ndarray,
                 proj=None, n_iters: int = 2, chunk: Optional[int] = None,
                 engine: str = DEFAULT_ENGINE) -> np.ndarray:
        """(B, n_knobs) shared candidates -> (B,) estimated cycles via the
        cached compiled sweep for this cell's problem."""
        to, ts = space.theta_for(self.problem, knob_thetas, proj)
        return sweep(self.problem, to, ts, n_iters=n_iters, chunk=chunk,
                     engine=engine)

    def accumulate_weights(self, space: "DesignSpace", proj,
                           w: np.ndarray) -> None:
        """Add this cell's parameter volume per knob into ``w`` (in place):
        summed instruction op_scale for op knobs, summed mem_words for
        storage knobs."""
        op_idx, st_idx = proj
        aidg = self.aidg
        node_knob = op_idx[aidg.op_class]
        for ki in range(space.n):
            w[ki] += float(aidg.op_scale[node_knob == ki].sum())
        for st_name, cid in self.problem.node_storage.items():
            ki = st_idx[cid]
            if ki < space.n:
                nodes = aidg.storage_nodes[st_name]
                w[ki] += float(aidg.mem_words[nodes].sum())

    def grad_fn(self, proj, n_iters: int = 2) -> Callable:
        """Cached ``jit(vmap(value_and_grad))`` from shared knob space:
        ``fn(knobs (B, K), tau) -> (soft cycles (B,), gradient (B, K))``."""
        from .dse import grad_sweep
        op_idx, st_idx = proj
        return grad_sweep(self.problem, op_idx, st_idx, n_iters=n_iters)

    def energy_coeffs(self, space: "DesignSpace", proj
                      ) -> Tuple[np.ndarray, float]:
        """This cell's folded energy coefficients: ``((n_knobs + 1,)``
        dynamic pJ per knob at θ = 1, static leakage pJ per cycle) — the
        same fold the packed trace consumes, usable analytically by the
        per-cell engines (energy given cycles is closed-form)."""
        model = energy_model(self.arch)
        return (fold_dyn_energy(self.problem, proj, space.n, model),
                model.static_pj)

    def pack_spec(self, proj, n_knobs: Optional[int] = None) -> PackSpec:
        """This cell's :class:`repro.core.aidg.dse.PackSpec` — a single
        problem, one run of one repetition, no overlap gates.  With
        ``n_knobs`` the spec carries the folded energy coefficients (the
        packed evaluator's 3-objective dispatch); without, energy is
        omitted (reported as 0)."""
        if n_knobs is None:
            return PackSpec.operator(self.problem, proj)
        model = energy_model(self.arch)
        return PackSpec.operator(
            self.problem, proj,
            edyn=fold_dyn_energy(self.problem, proj, n_knobs, model),
            static_pj=model.static_pj)

    def simulate(self) -> int:
        """Cycle-accurate oracle: rebuild the AG from scratch (the builder's
        functional pre-execution mutates memory) and run the event
        simulator.  Slow — test/benchmark use only."""
        ag, prog = self.scenario.build()
        return simulate(ag, prog).cycles

    def stats_row(self) -> Dict[str, float]:
        """Level-schedule statistics: node count vs critical depth, plus
        the chain-condensed depth (``condense_aidg``) the packed engine
        scans instead."""
        s = self.schedule
        c = condense_aidg(self.aidg).stats
        return {"name": self.name, "n": s.n, "levels": s.n_levels,
                "max_width": s.width,
                "parallelism": round(s.parallelism, 2),
                "kept": c["n_kept"],
                "levels_condensed": c["levels_condensed"]}


_AIDG_CACHE: Dict[Tuple, CompiledScenario] = {}
_CACHE_STATS = {"hits": 0, "misses": 0}


def compile_scenario(sc: Scenario, use_cache: bool = True) -> CompiledScenario:
    """(arch, workload) -> CompiledScenario, cached on ``Scenario.key``.

    The cache is process-wide and counts hits/misses
    (``scenario_cache_stats``) — the network frontend leans on it so a
    layer shape repeated across a model (or across models) compiles once.
    """
    if use_cache and sc.key in _AIDG_CACHE:
        _CACHE_STATS["hits"] += 1
        return _AIDG_CACHE[sc.key]
    if use_cache:
        _CACHE_STATS["misses"] += 1
    ag, prog = sc.build()
    trace = build_trace(ag, prog)
    aidg = build_aidg(ag, trace)
    prob = make_problem(aidg)
    baseline = float(longest_path_fixed_point(aidg).max())
    cs = CompiledScenario(sc, aidg, prob, baseline)
    if use_cache:
        _AIDG_CACHE[sc.key] = cs
    return cs


def scenario_cache_stats() -> Dict[str, int]:
    """Process-wide AIDG-cache counters: ``{"hits": ..., "misses": ...}``
    (uncached ``compile_scenario(use_cache=False)`` builds count neither)."""
    return dict(_CACHE_STATS)


def clear_scenario_cache() -> None:
    """Drop every cached CompiledScenario and zero the hit/miss counters."""
    _AIDG_CACHE.clear()
    _CACHE_STATS["hits"] = 0
    _CACHE_STATS["misses"] = 0


# ---------------------------------------------------------------------------
# shared design space: named knobs -> per-scenario θ columns
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Knob:
    """One shared multiplicative latency factor.

    ``ops`` / ``storages`` are regexes matched (``re.search``) against the
    DSEProblem's op-class names (e.g. ``gemm@matMulFu#``) and storage names
    (e.g. ``dram0``).  θ < 1 = faster/more expensive hardware."""

    name: str
    lo: float = 0.25
    hi: float = 4.0
    ops: str = ""
    storages: str = ""


@dataclass(frozen=True)
class DesignSpace:
    knobs: Tuple[Knob, ...]

    @property
    def n(self) -> int:
        """Number of shared knobs = columns of a candidate row."""
        return len(self.knobs)

    @property
    def names(self) -> List[str]:
        """Knob names, in candidate-column order."""
        return [k.name for k in self.knobs]

    def _match(self, patterns: List[str], name: str) -> int:
        """Index of the first knob whose pattern matches, else ``self.n``
        (the identity column — that class is not under DSE control)."""
        for ki, pat in enumerate(patterns):
            if pat and re.search(pat, name):
                return ki
        return self.n

    def projection(self, prob: DSEProblem) -> Tuple[np.ndarray, np.ndarray]:
        """Per-problem gather maps (op_class -> knob, storage -> knob)."""
        op_pats = [k.ops for k in self.knobs]
        st_pats = [k.storages for k in self.knobs]
        op_idx = np.asarray([self._match(op_pats, nm) for nm in prob.op_names],
                            dtype=np.int64)
        st_idx = np.asarray([self._match(st_pats, nm)
                             for nm in prob.storage_names], dtype=np.int64)
        return op_idx, st_idx

    def theta_for(self, prob: DSEProblem, knob_thetas: np.ndarray,
                  projection: Optional[Tuple[np.ndarray, np.ndarray]] = None,
                  ) -> Tuple[np.ndarray, np.ndarray]:
        """(B, n_knobs) shared candidates -> (B, n_op), (B, n_st) θ for one
        scenario's problem; unmatched classes get the identity 1.0."""
        kt = np.asarray(knob_thetas, np.float32)
        if kt.ndim == 1:
            kt = kt[None, :]
        if kt.shape[1] != self.n:
            raise ValueError(f"candidates have {kt.shape[1]} knobs, "
                             f"space has {self.n}")
        op_idx, st_idx = projection or self.projection(prob)
        padded = np.concatenate(
            [kt, np.ones((kt.shape[0], 1), np.float32)], axis=1)
        return padded[:, op_idx], padded[:, st_idx]

    def clip(self, knob_thetas: np.ndarray) -> np.ndarray:
        """Project candidates into the per-knob [lo, hi] box."""
        lo = np.asarray([k.lo for k in self.knobs], np.float32)
        hi = np.asarray([k.hi for k in self.knobs], np.float32)
        return np.clip(np.asarray(knob_thetas, np.float32), lo, hi)


DEFAULT_SPACE = DesignSpace((
    # compute: matrix-shaped units (MXU / MAC array / conv PE) vs.
    # vector/elementwise units (VPU, matAddFu, map/reduce pipelines)
    Knob("matrix", ops=r"gemm@|^mac|row_conv@"),
    Knob("vector", ops=r"attn@|scan@|matadd@|map@|reduce@|psum_add"),
    Knob("loadstore", ops=r"t_load@|t_store@|^load@|^store@|drain@"),
    # memory hierarchy: on-chip SRAM-class storage vs. external DRAM/HBM
    Knob("onchip", storages=r"spm|glb|pmu|vmem|sram|imem|cache"),
    Knob("dram", storages=r"dram|hbm"),
))


# ---------------------------------------------------------------------------
# candidate generators
# ---------------------------------------------------------------------------


def random_candidates(space: DesignSpace, n: int, seed: int = 0,
                      include_baseline: bool = True) -> np.ndarray:
    """(n, n_knobs) log-uniform samples of the knob box (row 0 = θ = 1 when
    ``include_baseline``, so every batch carries the reference machine)."""
    rng = np.random.default_rng(seed)
    cols = [np.exp(rng.uniform(np.log(k.lo), np.log(k.hi), n))
            for k in space.knobs]
    out = np.stack(cols, axis=1).astype(np.float32)
    if include_baseline and n > 0:
        out[0] = 1.0
    return out


def grid_candidates(space: DesignSpace, points: int = 4) -> np.ndarray:
    """Full factorial grid, ``points`` log-spaced levels per knob ->
    (points ** n_knobs, n_knobs) candidates in deterministic C order."""
    axes = [np.geomspace(k.lo, k.hi, points) for k in space.knobs]
    mesh = np.meshgrid(*axes, indexing="ij")
    return np.stack([m.reshape(-1) for m in mesh], axis=1).astype(np.float32)


# ---------------------------------------------------------------------------
# multi-objective scoring + Pareto frontier
# ---------------------------------------------------------------------------


def pareto_front(objectives: np.ndarray) -> np.ndarray:
    """Indices of the non-dominated rows of a (B, M >= 2) minimization
    problem, sorted by the first objective.  Deterministic: ties broken by
    original row order (stable lexsort); exact duplicates keep the first
    row only.

    Rows with NaN/inf objectives are ignored with a warning: NaN breaks the
    lexsort's ordering contract and an inf-latency row could otherwise be
    "non-dominated" purely by having the smallest cost — a diverged sweep
    (θ outside the evaluator's stable range) must not corrupt the frontier.

    The sweep visits rows in lexicographic order (first objective primary),
    keeping a row unless some earlier sorted row weakly dominates it (<= in
    every objective) — equivalent to checking kept rows only (<= is
    transitive: whatever dominates a dominated row also dominates its
    victims), which turns the scan into one vectorized (B, B) dominance
    mask instead of a Python pairwise loop (the serving tier ranks every
    answer through here, so this is a hot path); on 2-objective input it
    reduces to the classic best-so-far scan bit-for-bit.
    """
    objs = np.asarray(objectives, np.float64)
    assert objs.ndim == 2 and objs.shape[1] >= 2
    finite = np.isfinite(objs).all(axis=1)
    if not finite.all():
        warnings.warn(
            f"pareto_front: ignoring {int((~finite).sum())} candidate(s) "
            f"with non-finite objectives", RuntimeWarning, stacklevel=2)
        if not finite.any():
            return np.zeros(0, dtype=np.int64)
    rows = np.nonzero(finite)[0]
    sub = objs[rows]
    m = sub.shape[1]
    order = np.lexsort(tuple(sub[:, j] for j in range(m - 1, -1, -1)))
    ss = sub[order]
    # dom[i, j] = sorted row j weakly dominates sorted row i; only j < i
    # can apply (lexsorted), so mask the upper triangle + diagonal
    dom = (ss[None, :, :] <= ss[:, None, :]).all(axis=2)
    dom &= np.tri(len(ss), k=-1, dtype=bool)
    return np.asarray(rows[order[~dom.any(axis=1)]], dtype=np.int64)


def resolve_cells(compiled: Sequence, workload: Optional[str] = None,
                  archs: Optional[Sequence[str]] = None) -> List[int]:
    """Query resolution over the cell protocol: matrix column indices of
    the cells matching a (workload, architecture-subset) question.

    ``workload`` matches each cell's ``workload`` property exactly — an
    operator kind (``"gemm"``) for operator cells, a network name
    (``"whisper_small"``) for network cells; ``None`` matches every
    workload.  ``archs`` restricts to those architectures (``None`` = no
    restriction).  Raises ``KeyError`` listing what IS served when
    nothing matches — a typo'd query must fail loudly, not answer over an
    empty subset."""
    if isinstance(archs, str):
        archs = (archs,)
    wanted = None if archs is None else set(archs)
    idx = [i for i, cs in enumerate(compiled)
           if (workload is None or cs.workload == workload)
           and (wanted is None or cs.arch in wanted)]
    if not idx:
        served = sorted({cs.workload for cs in compiled})
        on = sorted({cs.arch for cs in compiled})
        raise KeyError(
            f"no cell matches workload={workload!r} archs={archs!r}; "
            f"served workloads: {served} on architectures: {on}")
    return idx


@dataclass
class ExplorationResult:
    """One batched sweep over the matrix: per-candidate cycles per scenario
    plus the three scalar objectives (latency, energy, area cost) and
    their Pareto-optimal subset."""

    space: DesignSpace
    scenario_names: List[str]
    candidates: np.ndarray      # (B, n_knobs)
    cycles: np.ndarray          # (B, S)
    latency: np.ndarray         # (B,)  mean baseline-relative cycles
    energy: np.ndarray          # (B,)  mean baseline-relative energy
    cost: np.ndarray            # (B,)  area proxy
    pareto: np.ndarray          # indices into candidates, sorted by latency

    def frontier(self) -> List[Dict[str, float]]:
        """The Pareto-optimal designs as dict rows (index, objectives, and
        per-knob θ), sorted by latency."""
        rows = []
        for i in self.pareto:
            row = {"index": int(i), "latency": float(self.latency[i]),
                   "energy": float(self.energy[i]),
                   "cost": float(self.cost[i])}
            row.update({f"theta[{n}]": float(self.candidates[i, j])
                        for j, n in enumerate(self.space.names)})
            rows.append(row)
        return rows

    @property
    def best(self) -> int:
        """Candidate minimizing latency * cost (a scalar compromise)."""
        return int(np.argmin(self.latency * self.cost))


class Explorer:
    """The batched multi-architecture DSE engine.

    Compiles every scenario once (AIDG cache + level schedule), projects
    shared knob vectors to per-scenario θ, and evaluates candidate batches
    in batched jitted sweeps — thousands of (arch, workload, θ) cells per
    call, no graph rebuilds, no retracing.

    ``engine`` selects the evaluator.  ``"packed"`` (the default) runs the
    whole matrix through one :class:`repro.core.aidg.dse.PackedMatrix`
    dispatch: every cell chain-condensed (``builder.condense_aidg``),
    padded to shared shapes, and evaluated cells x candidates in a single
    traced ``vmap`` x ``vmap`` — no per-cell Python loop, no per-cell
    dispatch.  The per-cell engines remain available for reference and
    benchmarking: ``"wavefront"`` (a ``lax.scan`` over topological levels
    per cell), ``"condensed"`` (per-cell wavefront over the condensed
    schedule), ``"scan"`` (one step per node), and ``"blocked"`` (max-plus
    Kleene-closure blocks).

    ``networks=True`` appends the whole-network matrix
    (``repro.core.network.default_network_scenarios``); a sequence of
    model names appends just those networks.  Each added cell is
    a full DNN lowered layer-by-layer onto one architecture and scored by
    *end-to-end* latency.  Any object implementing the cell protocol
    (``compile`` on the scenario; ``projection`` / ``evaluate`` /
    ``accumulate_weights`` / ``grad_fn`` / ``simulate`` / ``stats_row`` on
    the compiled cell) can sit in the matrix next to operator cells.
    """

    def __init__(self, scenarios: Optional[Sequence[Scenario]] = None,
                 space: DesignSpace = DEFAULT_SPACE, n_iters: int = 2,
                 use_cache: bool = True,
                 engine: str = DEFAULT_EXPLORER_ENGINE,
                 networks=False):
        if engine not in EXPLORER_ENGINES:
            raise ValueError(f"unknown engine {engine!r}; "
                             f"choose from {EXPLORER_ENGINES}")
        self.space = space
        self.n_iters = n_iters
        self.engine = engine
        self._packed: Optional[PackedMatrix] = None
        cells = list(default_scenarios() if scenarios is None else scenarios)
        if networks:
            from ..network import default_network_scenarios
            # True -> the full default network matrix; a sequence of model
            # names -> just those networks (still every mapping arch); a
            # bare string would iterate its characters, so wrap it
            if isinstance(networks, str):
                networks = [networks]
            cells += default_network_scenarios(
                networks=None if networks is True else networks)
        self.compiled: List[CompiledScenario] = [
            s.compile(use_cache) if hasattr(s, "compile")
            else compile_scenario(s, use_cache) for s in cells]
        self._projections = [cs.projection(space) for cs in self.compiled]
        self._weights: Optional[np.ndarray] = None
        self._energy_arrays_cache = None
        # normalization denominators from the SAME evaluator the sweeps use
        # (compiled_sweep at θ = 1), so the baseline candidate's latency
        # and energy are exactly 1.0 per scenario — CompiledScenario
        # .baseline comes from the numpy fixed-point pass, whose iteration
        # count/early-stop can differ by a fraction of a cycle
        bl, ebl = self.evaluate_full(np.ones((1, space.n), np.float32))
        self._baselines = bl[0]
        self._energy_baselines = np.maximum(ebl[0], 1e-30)

    @property
    def scenario_names(self) -> List[str]:
        """Cell names, in matrix-column order."""
        return [cs.name for cs in self.compiled]

    @property
    def baselines(self) -> np.ndarray:
        """(S,) per-cell cycles at θ = 1 from the same compiled evaluator
        the sweeps use — the latency-normalization denominators."""
        return self._baselines

    @property
    def energy_baselines(self) -> np.ndarray:
        """(S,) per-cell energy (pJ) at θ = 1 from the same evaluator —
        the energy-normalization denominators."""
        return self._energy_baselines

    def level_stats(self) -> List[Dict[str, float]]:
        """Per-scenario level-schedule statistics: node count vs critical
        depth — the sequential-step compression the wavefront engine gets
        over the per-node scan.  Network cells report their unique-layer
        aggregate."""
        return [cs.stats_row() for cs in self.compiled]

    # -- cost/area proxy ----------------------------------------------------

    def knob_weights(self) -> np.ndarray:
        """Area weight per knob ∝ the parameter volume it governs: summed
        instruction op_scale (macs/words) for op knobs and summed mem_words
        for storage knobs, across the whole matrix, normalized to mean 1."""
        if self._weights is not None:
            return self._weights
        w = np.zeros(self.space.n, dtype=np.float64)
        for cs, proj in zip(self.compiled, self._projections):
            cs.accumulate_weights(self.space, proj, w)
        total = w.sum()
        if total <= 0:
            w[:] = 1.0
        else:
            w = w / total * self.space.n
        self._weights = w
        return w

    def cost_proxy(self, knob_thetas: np.ndarray) -> np.ndarray:
        """Silicon-area proxy: speeding a knob up (θ < 1) costs area in
        proportion to the parameter volume it governs — Σ_k w_k / θ_k."""
        kt = np.asarray(knob_thetas, np.float64)
        if kt.ndim == 1:
            kt = kt[None, :]
        return (self.knob_weights()[None, :] / kt).sum(axis=1)

    # -- batched evaluation -------------------------------------------------

    def packed_matrix(self) -> PackedMatrix:
        """The matrix-packed single-dispatch evaluator over all cells
        (built lazily from every cell's ``pack_spec``, energy coefficients
        folded in; cached)."""
        if self._packed is None:
            specs = [cs.pack_spec(proj, n_knobs=self.space.n) for cs, proj
                     in zip(self.compiled, self._projections)]
            self._packed = PackedMatrix.build(specs, self.space.n,
                                              n_iters=self.n_iters)
        return self._packed

    def _energy_arrays(self) -> Tuple[np.ndarray, np.ndarray]:
        """Per-cell folded energy coefficients ``((S, n_knobs + 1) dynamic
        pJ per knob, (S,) static pJ per cycle)`` — the analytic
        energy-given-cycles closure the per-cell engines use (the packed
        engine carries the same fold inside its trace)."""
        if self._energy_arrays_cache is None:
            coeffs = [cs.energy_coeffs(self.space, proj) for cs, proj
                      in zip(self.compiled, self._projections)]
            self._energy_arrays_cache = (
                np.stack([c[0] for c in coeffs]).astype(np.float64),
                np.asarray([c[1] for c in coeffs], np.float64))
        return self._energy_arrays_cache

    def evaluate(self, knob_thetas: np.ndarray,
                 chunk: Optional[int] = None, sharded: bool = False,
                 n_devices: Optional[int] = None) -> np.ndarray:
        """(B, n_knobs) candidates -> (B, S) estimated cycles.  With the
        default ``engine="packed"``, the WHOLE matrix x batch is one
        jitted dispatch — optionally ``sharded`` over the candidate axis
        across ``n_devices`` local devices (bitwise-identical results,
        see ``PackedMatrix.sharded_fn``); per-cell engines fall back to
        one batched sweep per scenario over cached compiled kernels."""
        kt = np.asarray(knob_thetas, np.float32)
        if kt.ndim == 1:
            kt = kt[None, :]
        if self.engine == "packed":
            return self.packed_matrix().evaluate(kt, chunk=chunk,
                                                 sharded=sharded,
                                                 n_devices=n_devices)
        if sharded:
            raise ValueError("sharded evaluation requires engine='packed' "
                             f"(this explorer uses {self.engine!r})")
        cols = [cs.evaluate(self.space, kt, proj, n_iters=self.n_iters,
                            chunk=chunk, engine=self.engine)
                for cs, proj in zip(self.compiled, self._projections)]
        return np.stack(cols, axis=1)

    def evaluate_full(self, knob_thetas: np.ndarray,
                      chunk: Optional[int] = None, sharded: bool = False,
                      n_devices: Optional[int] = None
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """(B, n_knobs) candidates -> ``((B, S) cycles, (B, S) energy
        pJ)``.  With the packed engine both objectives come out of the
        SAME jitted dispatch (``PackedMatrix.evaluate_full`` — no second
        pass); the per-cell engines apply the identical closed-form
        ``edyn @ (1/θ) + P_static · cycles`` to their cycles (energy given
        cycles is analytic, so no extra evaluation there either)."""
        kt = np.asarray(knob_thetas, np.float32)
        if kt.ndim == 1:
            kt = kt[None, :]
        if self.engine == "packed":
            return self.packed_matrix().evaluate_full(
                kt, chunk=chunk, sharded=sharded, n_devices=n_devices)
        cycles = self.evaluate(kt, chunk=chunk, sharded=sharded,
                               n_devices=n_devices)
        edyn, pstat = self._energy_arrays()
        inv = 1.0 / np.concatenate(
            [kt.astype(np.float64), np.ones((kt.shape[0], 1))], axis=1)
        energy = inv @ edyn.T + pstat[None, :] * cycles.astype(np.float64)
        return cycles, energy.astype(np.float32)

    def explore(self, knob_thetas: np.ndarray,
                chunk: Optional[int] = None) -> ExplorationResult:
        """Evaluate + score + Pareto-extract one candidate batch (three
        objectives: latency, energy, area cost)."""
        kt = np.asarray(knob_thetas, np.float32)
        if kt.ndim == 1:
            kt = kt[None, :]
        cycles, energy_pj = self.evaluate_full(kt, chunk=chunk)
        latency = (cycles / self.baselines[None, :]).mean(axis=1)
        energy = (energy_pj / self.energy_baselines[None, :]).mean(axis=1)
        cost = self.cost_proxy(kt)
        front = pareto_front(np.stack([latency, energy, cost], axis=1))
        return ExplorationResult(self.space, self.scenario_names, kt, cycles,
                                 latency, energy, cost, front)

    # -- refinement: coordinate descent or gradient descent -----------------

    def refine(self, start: Optional[np.ndarray] = None,
               rounds: Optional[int] = None, points: Optional[int] = None,
               objective: str = "product", method: str = "coord",
               **grad_kwargs) -> np.ndarray:
        """Refine the incumbent design.

        ``method="coord"`` (default): deterministic coordinate descent —
        sweep one knob at a time over ``points`` (default 9) log-spaced
        levels (others fixed), keep the argmin, cycle ``rounds`` (default
        2) times; evaluates ``(points + 1) x n_knobs x rounds`` candidates.

        ``method="grad"``: batched multi-start projected Adam over the
        smooth max-plus relaxation (``repro.core.aidg.gradient``) —
        a handful of gradient steps per start instead of per-knob sweeps;
        ``grad_kwargs`` (``starts``, ``steps``, ``lr``, ``tau0``,
        ``tau_min``, ``seed``) pass through to ``GradientExplorer.refine``.

        Arguments that belong to the *other* method are rejected, not
        silently ignored (``rounds``/``points`` are coordinate-descent
        knobs; the gradient budget is ``starts``/``steps``).

        ``objective``: 'product' minimizes latency * cost; 'latency'
        ignores cost (pure speed); 'energy' minimizes normalized energy;
        'edp' minimizes the energy-delay product (latency * energy)."""
        if objective not in ("product", "latency", "energy", "edp"):
            raise ValueError(
                f"objective must be one of 'product', 'latency', 'energy' "
                f"or 'edp', got {objective!r}")
        if method == "grad":
            if rounds is not None or points is not None:
                raise TypeError(
                    "rounds/points configure coordinate descent; for "
                    "method='grad' size the search with starts/steps")
            from .gradient import GradientExplorer
            ge = GradientExplorer(self, objective=objective)
            return ge.refine(start=start, **grad_kwargs).theta
        if method != "coord":
            raise ValueError(f"method must be 'coord' or 'grad', "
                             f"got {method!r}")
        if grad_kwargs:
            raise TypeError(f"unexpected arguments for method='coord': "
                            f"{sorted(grad_kwargs)}")
        rounds = 2 if rounds is None else rounds
        points = 9 if points is None else points
        cur = (np.ones(self.space.n, np.float32) if start is None
               else self.space.clip(start).copy())
        for _ in range(rounds):
            for ki, knob in enumerate(self.space.knobs):
                # the incumbent value is always a candidate level, so a
                # coordinate step can never regress from an off-grid start
                levels = np.append(np.geomspace(knob.lo, knob.hi, points),
                                   cur[ki]).astype(np.float32)
                cand = np.repeat(cur[None, :], len(levels), axis=0)
                cand[:, ki] = levels
                res = self.explore(cand)
                score = {"latency": res.latency,
                         "energy": res.energy,
                         "edp": res.latency * res.energy,
                         "product": res.latency * res.cost}[objective]
                cur = cand[int(np.argmin(score))]
        return cur
