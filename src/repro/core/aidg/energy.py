"""Dynamic-energy folding: per-op-class coefficients -> per-knob vectors.

The energy objective is evaluated *inside* the packed latency trace (see
``dse.PackedMatrix``), which never materializes per-node arrays — so the
coefficients must be pre-folded to the same granularity the trace works
at: one dynamic-energy scalar per design-space knob.  That fold is exact
because instruction counts are θ-independent:

    E_dyn(θ) = Σ_k edyn[k] / θ_k        (DVFS-style: faster units burn
                                         more energy per issued op)
    E(θ)     = E_dyn(θ) + P_static · T(θ)

``fold_dyn_energy`` computes ``edyn`` (a ``(n_knobs + 1,)`` vector, last
column the identity knob) for one per-layer problem by

* counting instructions per op class — through
  ``CondensedAIDG.op_class_counts`` (absorbed nodes) plus a bincount over
  the kept nodes when a condensation is supplied, or a plain bincount
  over the raw AIDG otherwise; absorbed ∪ kept = all nodes, so both
  routes produce identical integer counts — pinned by
  ``tests/test_energy.py``;
* crediting per-storage word traffic (``AIDG.mem_words``) to the knob
  scaling that storage, mirroring ``CompiledScenario.accumulate_weights``
  (storage accessors are never absorbed, so this is condensation-
  invariant).

``energy_bottleneck_report`` is the ZigZag-style read of the same data:
storage-node traffic x per-level access energy, grouped by storage class
— where the joules go, before any θ search.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..archs.energy import EnergyModel, energy_model

__all__ = ["fold_dyn_energy", "energy_bottleneck_report"]


def fold_dyn_energy(prob, proj, n_knobs: int, model: EnergyModel,
                    cond=None) -> np.ndarray:
    """(n_knobs + 1,) dynamic pJ per knob for one problem at θ = 1.

    ``proj`` is the design-space projection ``(op_idx, st_idx)`` mapping
    op-class / storage-class ids to knob columns (value ``n_knobs`` = the
    identity column).  With ``cond`` (a ``CondensedAIDG``) the op counts
    are reassembled from the condensed representation — super-edge count
    vectors plus the kept nodes — instead of the raw node array.
    """
    a = prob.aidg
    op_idx = np.asarray(proj[0], np.int64)
    st_idx = np.asarray(proj[1], np.int64)
    n_cls = max(1, len(a.classes))
    if cond is not None:
        counts = np.zeros(n_cls, np.int64)
        occ = cond.op_class_counts()
        if occ.size:
            counts += occ.sum(axis=0)
        counts += np.bincount(a.op_class[cond.kept], minlength=n_cls)
    else:
        counts = np.bincount(a.op_class, minlength=n_cls)

    edyn = np.zeros(n_knobs + 1, np.float64)
    for name, cid in a.classes.items():
        edyn[int(op_idx[cid])] += float(counts[cid]) * model.op_pj(name)
    for st_name, cid in prob.node_storage.items():
        words = float(a.mem_words[a.storage_nodes[st_name]].sum())
        edyn[int(st_idx[cid])] += words * model.word_pj(st_name)
    return edyn


def _cell_problems(cell) -> Tuple[Sequence, np.ndarray]:
    """(problems, per-problem composition weight) of any matrix cell."""
    if hasattr(cell, "stack"):          # CompiledNetwork
        return cell.stack.problems, np.asarray(cell.reps_per_layer,
                                               np.float64)
    return (cell.problem,), np.ones(1, np.float64)


def energy_bottleneck_report(cell, model: Optional[EnergyModel] = None
                             ) -> List[Dict[str, object]]:
    """Per-memory-level energy bottlenecks of one matrix cell (à la
    ZigZag): storage-node word traffic x per-level access energy, grouped
    by storage class, sorted by energy descending.

    Works on any cell implementing the Explorer protocol — operator cells
    (one problem) and network cells (unique tile problems weighted by
    their composed instance counts).  Rows carry ``storage_class``,
    the member ``storages``, total ``words`` moved, ``pj_per_word``, the
    class ``energy_pj`` and its ``share`` of the cell's total access
    energy.
    """
    model = model or energy_model(cell.arch)
    probs, reps = _cell_problems(cell)
    words_by_cls: Dict[str, float] = {}
    names_by_cls: Dict[str, set] = {}
    for prob, r in zip(probs, reps):
        a = prob.aidg
        for st_name in prob.node_storage:
            cls = model.storage_class(st_name)
            w = float(a.mem_words[a.storage_nodes[st_name]].sum()) * float(r)
            words_by_cls[cls] = words_by_cls.get(cls, 0.0) + w
            names_by_cls.setdefault(cls, set()).add(st_name)
    rows = []
    for cls, words in words_by_cls.items():
        pj = float(model.word_table[cls])
        rows.append({"storage_class": cls,
                     "storages": tuple(sorted(names_by_cls[cls])),
                     "words": words, "pj_per_word": pj,
                     "energy_pj": words * pj})
    total = sum(r["energy_pj"] for r in rows) or 1.0
    for r in rows:
        r["share"] = r["energy_pj"] / total
    rows.sort(key=lambda r: -r["energy_pj"])
    return rows
