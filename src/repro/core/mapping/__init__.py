"""Operator mapping: DNN operators -> ACADL instruction streams (paper §5)."""

from .gemm import (gamma_gemm, init_gemm_memory, oma_gemm_looped,
                   oma_gemm_unrolled, read_gemm_result)
from .systolic import (init_systolic_memory, read_systolic_result,
                       systolic_gemm_program)
from .workload import (OperatorCall, UMA_REGISTRY, extract_operators,
                       map_to_gamma, map_to_tpu, register_operator)
from .conv import eyeriss_conv2d, init_conv_memory, read_conv_result
from .patterns import (init_vector_memory, plasticine_map_reduce,
                       read_scalar)
from .fused import gamma_attention, gamma_scan

__all__ = [
    "oma_gemm_looped", "oma_gemm_unrolled", "gamma_gemm",
    "init_gemm_memory", "read_gemm_result",
    "systolic_gemm_program", "init_systolic_memory", "read_systolic_result",
    "OperatorCall", "extract_operators", "map_to_tpu", "map_to_gamma",
    "UMA_REGISTRY", "register_operator",
    "eyeriss_conv2d", "init_conv_memory", "read_conv_result",
    "plasticine_map_reduce", "init_vector_memory", "read_scalar",
    "gamma_attention", "gamma_scan",
]
