"""Output-stationary GeMM mapping for the parameterizable systolic array
(paper §4.2, Fig. 4).

Dataflow: activations (A) stream right through the ``a`` channel, weights
(B) stream down through the ``b`` channel, each PE accumulates its output
element in ``acc``.  After the K reduction, results drain right through the
``a`` channel into the per-row store units.

The instruction stream is emitted in program order; the skewed wavefront
emerges from the register dependencies (PE (r,c)'s mac at step k reads the
``a`` forwarded by PE (r,c-1) at step k and the ``b`` forwarded by PE
(r-1,c)), which the out-of-order issue of the timing simulation resolves —
exactly the paper's "multiple instructions can be forwarded out-of-order at
the same time" semantics.

Matrices larger than the array are tiled over (rows × columns) output tiles;
the K dimension streams fully through each tile residency.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from ..acadl import Instruction
from ..acadl.base import ExecutionEnv
from ..acadl.graph import ArchitectureGraph

__all__ = [
    "systolic_gemm_program",
    "init_systolic_memory",
    "read_systolic_result",
]


# -- architecture-specific instruction builders --------------------------------


def _sa_load(dst: str, addr: int, unit: str) -> Instruction:
    def fn(env: ExecutionEnv, ins: Instruction) -> None:
        env.write_reg(dst, env.read_mem(addr))
    return Instruction("load", (), (dst,), read_addresses=(addr,), function=fn,
                       unit_hint=unit)


def _sa_store(src: str, addr: int, unit: str) -> Instruction:
    def fn(env: ExecutionEnv, ins: Instruction) -> None:
        env.write_mem(addr, env.read_reg(src))
    return Instruction("store", (src,), (), write_addresses=(addr,), function=fn,
                       unit_hint=unit)


def _sa_mac_fwd(r: int, c: int, rows: int, cols: int, unit: str,
                a_fwd: Optional[str], b_fwd: Optional[str]) -> Instruction:
    """acc[r][c] += a*b; forward a right and b down (when neighbours exist)."""
    a_reg, b_reg, acc_reg = f"a[{r}][{c}]", f"b[{r}][{c}]", f"acc[{r}][{c}]"
    writes = (acc_reg,) + ((a_fwd,) if a_fwd else ()) + ((b_fwd,) if b_fwd else ())

    def fn(env: ExecutionEnv, ins: Instruction) -> None:
        a, b = env.read_reg(a_reg), env.read_reg(b_reg)
        env.write_reg(acc_reg, env.read_reg(acc_reg) + a * b)
        if a_fwd:
            env.write_reg(a_fwd, a)
        if b_fwd:
            env.write_reg(b_fwd, b)
    return Instruction("mac_fwd", (a_reg, b_reg, acc_reg), writes, function=fn,
                       unit_hint=unit)


def _sa_init_acc(r: int, c: int, unit: str) -> Instruction:
    acc_reg = f"acc[{r}][{c}]"

    def fn(env: ExecutionEnv, ins: Instruction) -> None:
        env.write_reg(acc_reg, 0)
    return Instruction("drain", (), (acc_reg,), function=fn, unit_hint=unit)


def _sa_drain(src: str, dst: str, unit: str) -> Instruction:
    """Move a value one hop right along the a/drain channel."""
    def fn(env: ExecutionEnv, ins: Instruction) -> None:
        env.write_reg(dst, env.read_reg(src))
    return Instruction("drain", (src,), (dst,), function=fn, unit_hint=unit)


# -- data placement --------------------------------------------------------------


def init_systolic_memory(ag: ArchitectureGraph, a: np.ndarray, b: np.ndarray,
                         a_base: int = 0x1000, b_base: int = 0x40000,
                         memory: str = "dram0") -> None:
    mem = ag.by_name[memory]
    m, k = a.shape
    k2, l = b.shape
    assert k == k2
    for i in range(m):
        for kk in range(k):
            mem.write(a_base + i * k + kk, float(a[i, kk]))
    for kk in range(k):
        for j in range(l):
            mem.write(b_base + kk * l + j, float(b[kk, j]))


def read_systolic_result(ag: ArchitectureGraph, m: int, l: int,
                         c_base: int = 0x80000, memory: str = "dram0") -> np.ndarray:
    mem = ag.by_name[memory]
    out = np.zeros((m, l))
    for i in range(m):
        for j in range(l):
            out[i, j] = mem.read(c_base + i * l + j)
    return out


# -- program generation ------------------------------------------------------------


def systolic_gemm_program(m: int, k: int, l: int, rows: int, columns: int,
                          a_base: int = 0x1000, b_base: int = 0x40000,
                          c_base: int = 0x80000) -> List[Instruction]:
    """Emit the full instruction stream for C(m×l) = A(m×k) B(k×l) on a
    rows×columns output-stationary array.  m and l are tiled by the array
    shape; ragged edges fall back to partially-used PEs."""
    prog: List[Instruction] = []
    for ti in range(0, m, rows):
        tr = min(rows, m - ti)          # active rows in this tile
        for tj in range(0, l, columns):
            tc = min(columns, l - tj)   # active columns
            prog.extend(_tile_program(ti, tj, tr, tc, k, l, rows, columns,
                                      a_base + ti * k, b_base + tj,
                                      c_base + ti * l + tj))
    return prog


def _tile_program(ti: int, tj: int, tr: int, tc: int, k: int, l: int,
                  rows: int, columns: int, a_tile_base: int, b_tile_base: int,
                  c_tile_base: int) -> List[Instruction]:
    prog: List[Instruction] = []
    # 1. reset accumulators of active PEs
    for r in range(tr):
        for c in range(tc):
            prog.append(_sa_init_acc(r, c, f"fu[{r}][{c}]"))

    # 2. K reduction: stream A right / B down, mac everywhere
    for kk in range(k):
        for r in range(tr):  # A[r, kk] enters column 0 of row r
            prog.append(_sa_load(f"a[{r}][0]", a_tile_base + r * k + kk,
                                 f"mau_lu_row{r}"))
        for c in range(tc):  # B[kk, c] enters row 0 of column c
            prog.append(_sa_load(f"b[0][{c}]", b_tile_base + kk * l + c,
                                 f"mau_lu_col{c}"))
        for r in range(tr):
            for c in range(tc):
                a_fwd = f"a[{r}][{c + 1}]" if c + 1 < tc else None
                b_fwd = f"b[{r + 1}][{c}]" if r + 1 < tr else None
                prog.append(_sa_mac_fwd(r, c, rows, columns, f"fu[{r}][{c}]",
                                        a_fwd, b_fwd))

    # 3. drain: shift accumulators right through the a-channel into the
    # per-row store unit register, rightmost column first; partial tiles
    # keep hopping through the inactive PEs to the physical last column
    for r in range(tr):
        for s in range(tc):
            src_col = tc - 1 - s
            cur = f"acc[{r}][{src_col}]"
            for cc in range(src_col, columns):
                dst = (f"out_su_row{r}" if cc == columns - 1
                       else f"a[{r}][{cc + 1}]")
                prog.append(_sa_drain(cur, dst, f"fu[{r}][{cc}]"))
                cur = dst
            prog.append(_sa_store(f"out_su_row{r}",
                                  c_tile_base + r * l + src_col,
                                  f"mau_su_row{r}"))
    return prog
