"""Workload extraction: model configs -> ACADL operator streams (paper §5).

Every architecture config doubles as an ACADL *workload*: one train or
serve step decomposes into a stream of fused-tensor operators
(GEMM / attention / scan tiles) that maps onto any modeled accelerator via
the UMA-style interface functions below.  This is the paper's §5 pipeline
(TVM/UMA -> accelerator instructions) with the DNN coming from our own
config system instead of a TVM Relay graph.

The fused-tensor abstraction level keeps streams small (one instruction per
operator tile at ``tile`` granularity — or one per whole operator at
``coarse=True``), so the AIDG estimator answers "how many cycles does one
step of arch X cost on accelerator Y" in milliseconds — the accelerator-
selection / NAS / co-design loop of §1 and §7.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ...models.config import ModelConfig, ShapeConfig
from ..acadl import Instruction, isa

__all__ = ["OperatorCall", "extract_operators", "map_to_gamma",
           "map_to_tpu", "UMA_REGISTRY", "register_operator"]


@dataclass(frozen=True)
class OperatorCall:
    """One fused DNN operator instance (the UMA interface-function unit)."""

    op: str                 # "gemm" | "attention" | "scan" | "elementwise"
    m: int = 1              # rows (tokens)
    k: int = 1              # contraction
    n: int = 1              # cols
    count: int = 1          # identical repeats (layers folded in)
    tag: str = ""

    @property
    def macs(self) -> int:
        return self.m * self.k * self.n * self.count

    @property
    def words(self) -> int:
        return (self.m * self.k + self.k * self.n + self.m * self.n) * self.count


def extract_operators(cfg: ModelConfig, shape: ShapeConfig) -> List[OperatorCall]:
    """Per-step operator stream for a (config, shape) cell.

    Decode counts one token; train counts fwd+bwd (3x fwd MACs)."""
    a = cfg.attention
    d = cfg.d_model
    if shape.mode == "decode":
        tokens = shape.global_batch            # one new token per sequence
        ctx = shape.seq_len
    else:
        tokens = shape.global_batch * shape.seq_len
        ctx = shape.seq_len
    mult = 3 if shape.mode == "train" else 1   # bwd ~= 2x fwd MACs

    ops: List[OperatorCall] = []
    kinds = cfg.layer_kinds()
    moes = cfg.moe_layers()
    n_attn = sum(1 for k in kinds if k == "attn")
    n_mamba = len(kinds) - n_attn
    n_moe = sum(moes)
    n_dense = len(kinds) - n_moe if cfg.d_ff > 0 else 0

    if n_attn:
        if a.kind == "mla":
            qk = a.qk_nope_head_dim + a.qk_rope_head_dim
            ops += [
                OperatorCall("gemm", tokens, d, a.q_lora_rank, n_attn * mult, "q_down"),
                OperatorCall("gemm", tokens, a.q_lora_rank, a.n_heads * qk, n_attn * mult, "q_up"),
                OperatorCall("gemm", tokens, d, a.kv_lora_rank + a.qk_rope_head_dim, n_attn * mult, "kv_down"),
                OperatorCall("gemm", tokens, a.kv_lora_rank, a.n_heads * (a.qk_nope_head_dim + a.v_head_dim), n_attn * mult, "kv_up"),
                OperatorCall("gemm", tokens, a.n_heads * a.v_head_dim, d, n_attn * mult, "o"),
            ]
            attn_dim = a.v_head_dim
        else:
            hq = a.n_heads * a.head_dim
            hkv = a.n_kv_heads * a.head_dim
            ops += [
                OperatorCall("gemm", tokens, d, hq, n_attn * mult, "q"),
                OperatorCall("gemm", tokens, d, 2 * hkv, n_attn * mult, "kv"),
                OperatorCall("gemm", tokens, hq, d, n_attn * mult, "o"),
            ]
            attn_dim = a.head_dim
        eff_ctx = min(ctx, a.window) if a.window > 0 else ctx
        if shape.mode != "decode":
            eff_ctx = eff_ctx // 2  # causal average
        ops.append(OperatorCall(
            "attention", tokens * a.n_heads, eff_ctx, 2 * attn_dim,
            n_attn * mult, "attn_core"))

    if n_mamba and cfg.ssm is not None:
        s = cfg.ssm
        di = s.d_inner(d)
        ops += [
            OperatorCall("gemm", tokens, d, 2 * di, n_mamba * mult, "ssm_in"),
            OperatorCall("gemm", tokens, di, s.dt_rank_of(d) + 2 * s.d_state, n_mamba * mult, "ssm_proj"),
            OperatorCall("scan", tokens, di * s.d_state, 2, n_mamba * mult, "ssm_scan"),
            OperatorCall("gemm", tokens, di, d, n_mamba * mult, "ssm_out"),
        ]

    if n_dense:
        ops.append(OperatorCall("gemm", tokens, d, 3 * cfg.d_ff, n_dense * mult, "mlp"))
    if n_moe and cfg.moe is not None:
        m = cfg.moe
        active = m.top_k + m.n_shared_experts
        ops.append(OperatorCall("gemm", tokens * active, d, 3 * m.d_expert,
                                n_moe * mult, "moe"))
        ops.append(OperatorCall("gemm", tokens, d, m.n_experts, n_moe * mult, "router"))

    # embedding / unembedding
    ops.append(OperatorCall("gemm", tokens, d, cfg.vocab_size, mult, "unembed"))
    if cfg.enc_dec is not None:
        e = cfg.enc_dec
        enc_tokens = shape.global_batch * e.encoder_len * (mult if shape.mode == "train" else 1)
        hq = a.n_heads * a.head_dim
        ops += [
            OperatorCall("gemm", enc_tokens, d, 4 * hq, e.n_encoder_layers, "enc_attn_proj"),
            OperatorCall("attention", enc_tokens * a.n_heads, e.encoder_len, 2 * a.head_dim, e.n_encoder_layers, "enc_attn"),
            OperatorCall("gemm", enc_tokens, d, 2 * cfg.d_ff, e.n_encoder_layers, "enc_mlp"),
            OperatorCall("gemm", tokens, d, 2 * hq, cfg.n_layers * mult, "xattn_q"),
            OperatorCall("attention", tokens * a.n_heads, e.encoder_len, 2 * a.head_dim, cfg.n_layers * mult, "xattn"),
        ]
    return ops


# ---------------------------------------------------------------------------
# UMA-style operator-interface registry (paper §5)
# ---------------------------------------------------------------------------

UMA_REGISTRY: Dict[Tuple[str, str], object] = {}


def register_operator(accelerator: str, op: str):
    """Register an interface function mapping an OperatorCall to ACADL
    instructions on ``accelerator`` (cf. ``oma_tiled_gemm`` in §5)."""

    def deco(fn):
        UMA_REGISTRY[(accelerator, op)] = fn
        return fn
    return deco


def _tiles(x: int, t: int) -> int:
    return max(1, -(-x // t))


@register_operator("tpu_v5e", "gemm")
def _tpu_gemm(call: OperatorCall, unit_prefix: str = "", tile: int = 128,
              coarse: bool = True) -> List[Instruction]:
    """GEMM -> MXU gemm instructions.  ``coarse``: one instruction per
    repeat with the whole op's macs (fused-tensor abstraction level)."""
    out: List[Instruction] = []
    VW = 1 << 24
    if coarse:
        m, k, n = call.m, call.k, call.n
        for r in range(call.count):
            addr = (hash((call.tag, r)) % (1 << 14)) * 4
            st = f"dstage.{r % 8}"
            # HBM -> VMEM via the async copy engine, then VMEM -> vregs
            out.append(isa.t_load(st, VW + addr, (k, n), unit="dma0"))
            out.append(isa.t_store(st, addr + 1, shape=(k, n), unit="dma0"))
            out.append(isa.t_load("v.a", addr, (m, k), unit="lsu0"))
            out.append(isa.t_load("v.b", addr + 1, (k, n), unit="lsu0"))
            out.append(isa.t_gemm("v.acc", "v.a", "v.b", unit="mxu0",
                                  tile=(m, k, n)))
            out.append(isa.t_store("v.acc", addr + 2, shape=(m, n), unit="lsu0"))
        return out
    mt, kt, nt = (_tiles(call.m, tile), _tiles(call.k, tile),
                  _tiles(call.n, tile))
    for r in range(call.count * mt * nt):
        out.append(isa.t_load("v.a", 0, (tile, tile * kt), unit="lsu0"))
        out.append(isa.t_load("v.b", 1, (tile * kt, tile), unit="lsu0"))
        out.append(isa.t_gemm("v.acc", "v.a", "v.b", unit="mxu0",
                              tile=(tile, tile * kt, tile)))
        out.append(isa.t_store("v.acc", 2, shape=(tile, tile), unit="lsu0"))
    return out


@register_operator("tpu_v5e", "attention")
def _tpu_attention(call: OperatorCall, coarse: bool = True) -> List[Instruction]:
    out = [isa.t_load("v.q", 0, (call.m, call.n // 2), unit="lsu0"),
           isa.t_load("v.k", 1, (call.k, call.n // 2), unit="lsu0"),
           isa.t_load("v.vv", 2, (call.k, call.n // 2), unit="lsu0")]
    for r in range(call.count):
        out.append(isa.t_attn("v.s", "v.q", "v.k", "v.vv", unit="vpu0",
                              tile=(call.m, call.k, call.n // 2)))
    out.append(isa.t_store("v.s", 3, shape=(call.m, call.n // 2), unit="lsu0"))
    return out


@register_operator("tpu_v5e", "scan")
def _tpu_scan(call: OperatorCall, coarse: bool = True) -> List[Instruction]:
    out = [isa.t_load("v.a", 0, (call.m, call.k), unit="lsu0")]
    for r in range(call.count):
        out.append(isa.t_scan("v.s", "v.s", "v.a", "v.b", unit="vpu0",
                              words=call.m * call.k))
    out.append(isa.t_store("v.s", 1, shape=(call.m, call.k), unit="lsu0"))
    return out


@register_operator("gamma", "gemm")
def _gamma_gemm_op(call: OperatorCall, units=(("lsu0", "matMulFu0", "vrf0"),),
                   tile: int = 8) -> List[Instruction]:
    from .gemm import gamma_gemm
    # map the logical gemm onto 8x8 gamma tiles, folding count into m
    m = min(call.m * call.count, 512)  # cap the emitted stream
    k = min(call.k, 64)
    n = min(call.n, 64)
    m, k, n = (max(tile, (x // tile) * tile) for x in (m, k, n))
    return gamma_gemm(m, k, n, tile=tile, units=units)


@register_operator("gamma", "attention")
def _gamma_attention_op(call: OperatorCall,
                        units=(("lsu0", "matAddFu0", "vrf0"),),
                        tile: int = 8) -> List[Instruction]:
    """Attention -> Γ̈ ``t_attn`` tile stream (``mapping.fused``), the
    q/kv extents capped so the emitted stream stays simulator-sized."""
    from .fused import gamma_attention
    seq = max(tile, min(call.m * call.count, 256) // tile * tile)
    ctx = max(tile, min(call.k, 128) // tile * tile)
    hd = max(1, min(call.n // 2, 64))
    return gamma_attention(seq, ctx, hd, tile=tile, units=units)


@register_operator("gamma", "scan")
def _gamma_scan_op(call: OperatorCall,
                   units=(("lsu0", "matAddFu0", "vrf0"),),
                   tile: int = 8) -> List[Instruction]:
    """Selective scan -> Γ̈ chunked-scan stream; tokens capped, state
    columns striped across the provided units."""
    from .fused import gamma_scan
    tokens = max(tile, min(call.m * call.count, 1024) // tile * tile)
    d_state = max(len(units), min(call.k, 64))
    d_state -= d_state % len(units)
    return gamma_scan(tokens, d_state, tile=tile, units=units)


def map_to_tpu(cfg: ModelConfig, shape: ShapeConfig,
               per_device: int = 512) -> List[Instruction]:
    """Full-step operator stream mapped onto the TPU-v5e ACADL model.

    ``per_device``: divide every operator's token dimension by the chip
    count (the ACADL model is one core; the mesh scales tokens)."""
    prog: List[Instruction] = []
    for call in extract_operators(cfg, shape):
        m = max(1, call.m // per_device)
        scaled = OperatorCall(call.op, m, call.k, call.n, call.count, call.tag)
        fn = UMA_REGISTRY.get(("tpu_v5e", call.op))
        if fn is None:
            continue
        prog.extend(fn(scaled))
    return prog


def map_to_gamma(cfg: ModelConfig, shape: ShapeConfig,
                 units=(("lsu0", "matMulFu0", "vrf0"),)) -> List[Instruction]:
    """Full-step operator stream mapped onto the Γ̈ ACADL model: GEMMs via
    the matMul units, attention/scan via the matAdd units (their register
    triples derived by name from ``units``); unmapped kinds are skipped."""
    attn_units = tuple((lsu, fu.replace("matMulFu", "matAddFu"), vrf)
                       for lsu, fu, vrf in units)
    prog: List[Instruction] = []
    for call in extract_operators(cfg, shape):
        fn = UMA_REGISTRY.get(("gamma", call.op))
        if fn is None:
            continue
        kw = {"units": units if call.op == "gemm" else attn_units}
        prog.extend(fn(call, **kw))
        if len(prog) > 4000:   # bounded stream for the event simulator
            break
    return prog
