"""Tiled GeMM operator mappings onto ACADL models (paper §5).

Three abstraction levels, matching the paper's examples:

* ``oma_gemm_looped``   — scalar level with control flow (Listing 5 style):
  three nested register-counted loops around the built-in ``mac``.
* ``oma_gemm_unrolled`` — scalar level, branch-free, *tiled* execution order
  (the divide-and-conquer order of eq. (1)-(5)); tiling changes the cache hit
  pattern, which the timing simulation rewards — this is the knob the paper's
  ``oma_tiled_gemm(...)`` interface function exposes to TVM/UMA.
* ``gamma_gemm``        — fused-tensor level for Γ̈ (Listing 4 style):
  ``t_load``/``t_gemm``(+activation)/``t_add``/``t_store`` tile streams,
  round-robin across compute units.

Address map convention (row-major): A (m×n) at ``a_base + i*n + k``, B (n×l)
at ``b_base + k*l + j``, C (m×l) at ``c_base + i*l + j``.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..acadl import Instruction
from ..acadl import isa
from ..acadl.asm import ProgramBuilder
from ..acadl.graph import ArchitectureGraph

__all__ = [
    "init_gemm_memory",
    "read_gemm_result",
    "oma_gemm_looped",
    "oma_gemm_unrolled",
    "gamma_gemm",
]


# ---------------------------------------------------------------------------
# data placement helpers
# ---------------------------------------------------------------------------


def init_gemm_memory(ag: ArchitectureGraph, a: np.ndarray, b: np.ndarray,
                     a_base: int = 0x1000, b_base: int = 0x2000,
                     c_base: int = 0x3000, memory: str = "dmem0",
                     tile: Optional[int] = None) -> Dict[str, int]:
    """Write A and B into the data memory word-by-word (scalar level) or
    tile-by-tile (fused-tensor level, ``tile`` = tile edge)."""
    mem = ag.by_name[memory]
    m, n = a.shape
    n2, l = b.shape
    assert n == n2
    if tile is None:
        for i in range(m):
            for k in range(n):
                mem.write(a_base + i * n + k, float(a[i, k]))
        for k in range(n):
            for j in range(l):
                mem.write(b_base + k * l + j, float(b[k, j]))
    else:
        # tile-granular addressing: one address per tile
        for ti in range(m // tile):
            for tk in range(n // tile):
                mem.write(a_base + ti * (n // tile) + tk,
                          a[ti * tile:(ti + 1) * tile, tk * tile:(tk + 1) * tile].copy())
        for tk in range(n // tile):
            for tj in range(l // tile):
                mem.write(b_base + tk * (l // tile) + tj,
                          b[tk * tile:(tk + 1) * tile, tj * tile:(tj + 1) * tile].copy())
    return {"a_base": a_base, "b_base": b_base, "c_base": c_base}


def read_gemm_result(ag: ArchitectureGraph, m: int, l: int, c_base: int = 0x3000,
                     memory: str = "dmem0", tile: Optional[int] = None) -> np.ndarray:
    mem = ag.by_name[memory]
    if tile is None:
        out = np.zeros((m, l))
        for i in range(m):
            for j in range(l):
                out[i, j] = mem.read(c_base + i * l + j)
        return out
    out = np.zeros((m, l))
    for ti in range(m // tile):
        for tj in range(l // tile):
            out[ti * tile:(ti + 1) * tile, tj * tile:(tj + 1) * tile] = \
                mem.read(c_base + ti * (l // tile) + tj)
    return out


# ---------------------------------------------------------------------------
# OMA scalar-level mappings
# ---------------------------------------------------------------------------


def oma_gemm_looped(m: int, n: int, l: int, a_base: int = 0x1000,
                    b_base: int = 0x2000, c_base: int = 0x3000) -> List[Instruction]:
    """Listing-5-style looped GeMM: registers count i/j/k, the built-in
    ``mac`` accumulates, branches close the loops."""
    pb = ProgramBuilder()
    pb.emit(isa.movi("r1", 0))                 # i = 0
    pb.label("Li")
    pb.emit(isa.movi("r2", 0))                 # j = 0
    pb.label("Lj")
    pb.emit(isa.movi("r8", 0))                 # acc = 0
    pb.emit(isa.movi("r3", 0))                 # k = 0
    pb.label("Lk")
    pb.emit(isa.muli("r4", "r1", n))           # r4 = i*n
    pb.emit(isa.add("r4", "r4", "r3"))         # r4 += k
    pb.emit(isa.addi("r4", "r4", a_base))      # r4 += a_base
    pb.emit(isa.load("r6", ("reg", "r4")))     # r6 = A[i,k]
    pb.emit(isa.muli("r5", "r3", l))           # r5 = k*l
    pb.emit(isa.add("r5", "r5", "r2"))         # r5 += j
    pb.emit(isa.addi("r5", "r5", b_base))      # r5 += b_base
    pb.emit(isa.load("r7", ("reg", "r5")))     # r7 = B[k,j]
    pb.emit(isa.mac("r8", "r6", "r7"))         # acc += A*B
    pb.emit(isa.addi("r3", "r3", 1))           # k += 1
    pb.branch_ne("r3", n, "Lk")
    pb.emit(isa.muli("r9", "r1", l))           # r9 = i*l
    pb.emit(isa.add("r9", "r9", "r2"))         # r9 += j
    pb.emit(isa.addi("r9", "r9", c_base))      # r9 += c_base
    pb.emit(isa.store("r8", ("reg", "r9")))    # C[i,j] = acc
    pb.emit(isa.addi("r2", "r2", 1))           # j += 1
    pb.branch_ne("r2", l, "Lj")
    pb.emit(isa.addi("r1", "r1", 1))           # i += 1
    pb.branch_ne("r1", m, "Li")
    return pb.build()


def oma_gemm_unrolled(m: int, n: int, l: int, tile_m: int = 0, tile_n: int = 0,
                      tile_l: int = 0, a_base: int = 0x1000, b_base: int = 0x2000,
                      c_base: int = 0x3000) -> List[Instruction]:
    """Branch-free scalar GeMM in *tiled* execution order.

    ``tile_* = 0`` means untiled (row-major ijk order).  With tiling, the
    (i,j,k) space is visited tile-by-tile per eq. (1)-(5): output tiles reuse
    A tiles across the j loop, which the data cache rewards.
    """
    tm = tile_m or m
    tn = tile_n or n
    tl = tile_l or l
    out: List[Instruction] = []
    for ti in range(0, m, tm):
        for tj in range(0, l, tl):
            # acc-per-output-element lives in r8 between k-tiles via C rewrite
            for i in range(ti, min(ti + tm, m)):
                for j in range(tj, min(tj + tl, l)):
                    out.append(isa.movi("r8", 0))
                    for tk in range(0, n, tn):
                        for k in range(tk, min(tk + tn, n)):
                            out.append(isa.load("r6", a_base + i * n + k))
                            out.append(isa.load("r7", b_base + k * l + j))
                            out.append(isa.mac("r8", "r6", "r7"))
                    out.append(isa.store("r8", c_base + i * l + j))
    return out


# ---------------------------------------------------------------------------
# Γ̈ fused-tensor-level mapping
# ---------------------------------------------------------------------------


def gamma_gemm(m: int, n: int, l: int, tile: int = 8,
               units: Sequence[Tuple[str, str, str]] = (("lsu0", "matMulFu0", "vrf0"),),
               a_base: int = 0x1000, b_base: int = 0x2000, c_base: int = 0x100000,
               activation: int = 0) -> List[Instruction]:
    """Fused-tensor tiled GeMM for Γ̈ (paper Listing 4).

    ``units`` is a sequence of (load/store MAU name, compute FU name, vector
    register prefix) triples; output tiles round-robin across them so
    instructions for different hardware components issue in parallel and
    execute out-of-order (paper §4.3).  The optional ``activation`` (1=ReLU)
    is applied by the *final* k-tile gemm of each output tile.

    ``c_base`` defaults into the DRAM range (reachable from every load/store
    unit).  Passing a scratchpad-range base (e.g. ``0x3000``) reproduces
    Listing 4's store-to-scratchpad — valid when every emitting unit is
    adjacent to that scratchpad (n_units <= 2 on the ring topology).
    """
    assert m % tile == 0 and n % tile == 0 and l % tile == 0
    mt, nt, lt = m // tile, n // tile, l // tile
    prog: List[Instruction] = []
    u = 0
    for ti in range(mt):
        for tj in range(lt):
            lsu, cfu, vrf = units[u % len(units)]
            u += 1
            acc_reg = f"{vrf}.acc"
            for tk in range(nt):
                a_addr = a_base + ti * nt + tk
                b_addr = b_base + tk * lt + tj
                ra, rb = f"{vrf}.a", f"{vrf}.b"
                prog.append(isa.t_load(ra, a_addr, (tile, tile), unit=lsu))
                prog.append(isa.t_load(rb, b_addr, (tile, tile), unit=lsu))
                last = tk == nt - 1
                act = activation if last else 0
                if tk == 0:
                    prog.append(isa.t_gemm(acc_reg, ra, rb, activation=act, unit=cfu,
                                           tile=(tile, tile, tile)))
                else:
                    prog.append(isa.t_gemm(acc_reg, ra, rb, activation=act,
                                           acc=acc_reg, unit=cfu,
                                           tile=(tile, tile, tile)))
            prog.append(isa.t_store(acc_reg, c_base + ti * lt + tj,
                                    shape=(tile, tile), unit=lsu))
    return prog
