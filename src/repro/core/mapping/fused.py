"""Fused-tensor attention / selective-scan mappings for Γ̈ (beyond-paper
workloads on the paper's §4.3 accelerator).

The Γ̈ ``matAddFu`` processes the beyond-paper ``attn`` and ``scan``
fused-tensor operations (see ``repro.core.archs.gamma``), so the modern
attention and SSM workloads of the operator-extraction layer can be mapped
onto the paper's accelerator — these builders emit the tile-level
instruction streams the DSE scenario matrix evaluates.

Both builders are timing-oriented: tiles are loaded from DRAM addresses that
need not be initialised (``t_load`` of an unwritten address yields an
abstract tile and the trace stays timing-accurate), the same convention the
TPU-v5e operator mappings use.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

from ..acadl import Instruction, isa

__all__ = ["gamma_attention", "gamma_scan"]

Q_BASE = 0x400
KV_BASE = 0x800
OUT_BASE = 0x1800
X_BASE = 0x400
D_BASE = 0xC00
S_BASE = 0x1800


def gamma_attention(seq: int, ctx: int, head_dim: int, tile: int = 8,
                    units: Sequence[Tuple[str, str, str]] = (
                        ("lsu0", "matAddFu0", "vrf0"),),
                    ) -> List[Instruction]:
    """Tiled attention ``softmax(q k^T) v`` on Γ̈: one ``t_attn`` per
    (q-tile, kv-tile) pair, issued flash-attention-style (all kv tiles
    stream through the FU per q-tile, serialized on the accumulator
    register's unit).  Timing-oriented like every builder in this module:
    the accumulator is overwritten, not functionally accumulated — the
    instruction stream models the schedule, not the arithmetic.

    ``units``: (load/store MAU, attn-capable FU, vreg prefix) triples;
    q-tiles round-robin across them like ``gamma_gemm`` output tiles.
    """
    assert seq % tile == 0 and ctx % tile == 0
    qt, kt = seq // tile, ctx // tile
    # the fixed DRAM regions must not alias, or build_trace manufactures
    # false store-to-load dependencies that corrupt the timing estimate
    assert qt <= KV_BASE - Q_BASE and 2 * qt * kt <= OUT_BASE - KV_BASE, \
        "tile counts overflow the fixed address regions"
    prog: List[Instruction] = []
    for ti in range(qt):
        lsu, fu, vrf = units[ti % len(units)]
        rq, rk, rv, ro = (f"{vrf}.0", f"{vrf}.1", f"{vrf}.2", f"{vrf}.acc")
        prog.append(isa.t_load(rq, Q_BASE + ti, (tile, head_dim), unit=lsu))
        for tj in range(kt):
            prog.append(isa.t_load(rk, KV_BASE + 2 * (ti * kt + tj),
                                   (tile, head_dim), unit=lsu))
            prog.append(isa.t_load(rv, KV_BASE + 2 * (ti * kt + tj) + 1,
                                   (tile, head_dim), unit=lsu))
            prog.append(isa.t_attn(ro, rq, rk, rv, unit=fu,
                                   tile=(tile, tile, head_dim)))
        prog.append(isa.t_store(ro, OUT_BASE + ti, shape=(tile, head_dim),
                                unit=lsu))
    return prog


def gamma_scan(tokens: int, d_state: int, tile: int = 8,
               units: Sequence[Tuple[str, str, str]] = (
                   ("lsu0", "matAddFu0", "vrf0"),),
               ) -> List[Instruction]:
    """Chunked selective-scan ``state = decay * state + x`` on Γ̈.

    The token axis is a true recurrence, so it is NEVER split across
    units: the *state* dimension is striped instead (each unit owns
    ``d_state / len(units)`` state columns and scans every token chunk
    sequentially through its own state register).  Each stripe's state
    register therefore carries the full-depth RAW chain the SSM workload
    imposes, while stripes proceed in parallel — the same decomposition a
    real multi-unit selective scan uses.  Emission interleaves stripes per
    chunk so instructions for different units issue back-to-back.
    """
    assert tokens % tile == 0
    chunks = tokens // tile
    nu = len(units)
    assert d_state % nu == 0, "state columns must stripe evenly across units"
    assert chunks * nu <= D_BASE - X_BASE, \
        "chunk count overflows the fixed address regions"
    cols = max(1, d_state // nu)
    prog: List[Instruction] = []
    for c in range(chunks):
        for k, (lsu, fu, vrf) in enumerate(units):
            rx, rd, rs = f"{vrf}.0", f"{vrf}.1", f"{vrf}.2"
            prog.append(isa.t_load(rx, X_BASE + c * nu + k, (tile, cols),
                                   unit=lsu))
            prog.append(isa.t_load(rd, D_BASE + c * nu + k, (tile, cols),
                                   unit=lsu))
            prog.append(isa.t_scan(rs, rs, rx, rd, unit=fu,
                                   words=tile * cols))
            if (c + 1) % 8 == 0 or c == chunks - 1:
                prog.append(isa.t_store(rs, S_BASE + c * nu + k,
                                        shape=(tile, cols), unit=lsu))
    return prog
