"""Parallel-pattern mapping for the Plasticine-derived model (paper §6
references [27]): map / reduce pipelines over PMU-resident vectors.

``plasticine_map_reduce`` computes ``reduce(+, map(f, x))`` for a vector
striped across the PMUs: each PCU loads its stripe, applies the map in its
SIMD pipeline, reduces locally, and PCU 0 combines the partials — the
canonical Plasticine execution of a parallel pattern.
"""

from __future__ import annotations

from typing import Callable, List

import numpy as np

from ..acadl import Instruction
from ..acadl.base import ExecutionEnv
from ..acadl.graph import ArchitectureGraph
from .workload import _tiles  # noqa: F401  (shared helper)

__all__ = ["init_vector_memory", "plasticine_map_reduce", "read_scalar"]

PMU_WINDOW = 0x10000


def init_vector_memory(ag: ArchitectureGraph, x: np.ndarray, n_pmu: int) -> None:
    stripes = np.array_split(x.astype(np.float64), n_pmu)
    for j, s in enumerate(stripes):
        ag.by_name[f"pmu{j}"].write(j * PMU_WINDOW, s.copy())


def read_scalar(ag: ArchitectureGraph, n_pmu: int) -> float:
    out = ag.by_name["pmu0"].read(0 * PMU_WINDOW + 1)
    return float(np.asarray(out).sum())


def _map_op(dst: str, src: str, fn_name: str, unit: str, words: int) -> Instruction:
    f = {"square": lambda v: v * v, "relu": lambda v: np.maximum(v, 0),
         "exp": np.exp}[fn_name]

    def fn(env: ExecutionEnv, ins: Instruction) -> None:
        env.write_reg(dst, f(np.asarray(env.read_reg(src))))
    return Instruction("map", (src,), (dst,), function=fn, unit_hint=unit,
                       tags={"words": words})


def _reduce_op(dst: str, src: str, unit: str, words: int) -> Instruction:
    def fn(env: ExecutionEnv, ins: Instruction) -> None:
        env.write_reg(dst, np.asarray(env.read_reg(src)).sum(keepdims=True))
    return Instruction("reduce", (src,), (dst,), function=fn, unit_hint=unit,
                       tags={"words": words})


def _combine(dst: str, a: str, b: str, unit: str) -> Instruction:
    def fn(env: ExecutionEnv, ins: Instruction) -> None:
        env.write_reg(dst, np.asarray(env.read_reg(a)) +
                      np.asarray(env.read_reg(b)))
    return Instruction("matadd", (a, b), (dst,), function=fn, unit_hint=unit,
                       tags={"words": 1})


def _ld(dst: str, addr: int, unit: str, words: int) -> Instruction:
    def fn(env: ExecutionEnv, ins: Instruction) -> None:
        env.write_reg(dst, env.read_mem(addr))
    return Instruction("t_load", (), (dst,), read_addresses=(addr,),
                       function=fn, unit_hint=unit, tags={"words": words})


def _st(src: str, addr: int, unit: str, words: int = 1) -> Instruction:
    def fn(env: ExecutionEnv, ins: Instruction) -> None:
        env.write_mem(addr, env.read_reg(src))
    return Instruction("t_store", (src,), (), write_addresses=(addr,),
                       function=fn, unit_hint=unit, tags={"words": words})


def plasticine_map_reduce(n: int, n_pcu: int, n_pmu: int,
                          map_fn: str = "square") -> List[Instruction]:
    """sum(map_fn(x)) with x striped over the PMUs, one PCU per stripe."""
    prog: List[Instruction] = []
    stripe = -(-n // n_pmu)
    active = min(n_pcu, n_pmu)
    # each PCU: load stripe -> map -> local reduce
    for i in range(active):
        prog.append(_ld(f"v{i}.0", i * PMU_WINDOW, f"pcu_mau{i}", stripe))
        prog.append(_map_op(f"v{i}.1", f"v{i}.0", map_fn, f"pcu_fu{i}", stripe))
        prog.append(_reduce_op(f"v{i}.2", f"v{i}.1", f"pcu_fu{i}", stripe))
        prog.append(_st(f"v{i}.2", i * PMU_WINDOW + 2, f"pcu_mau{i}"))
    # PCU 0 combines the partials (reads every PMU)
    prog.append(_ld("v0.3", 0 * PMU_WINDOW + 2, "pcu_mau0", 1))
    for i in range(1, active):
        prog.append(_ld("v0.4", i * PMU_WINDOW + 2, "pcu_mau0", 1))
        prog.append(_combine("v0.3", "v0.3", "v0.4", "pcu_fu0"))
    prog.append(_st("v0.3", 0 * PMU_WINDOW + 1, "pcu_mau0"))
    return prog
