"""Convolution mappings (paper §5 mentions conv via input transformations).

* ``eyeriss_conv2d`` — row-stationary dataflow on the Eyeriss-derived model
  (paper §6 references [26]): filter rows stay in a PE, ifmap rows slide
  diagonally, psums accumulate vertically.  One ``row_conv`` instruction =
  one 1-D convolution of an ifmap row with a filter row; ``psum_add``
  merges partials down each column.
* ``oma_conv2d_im2col`` — scalar fallback: im2col + the OMA tiled GeMM
  (the §5 "input data transformations" path).
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from ..acadl import Instruction
from ..acadl.base import ExecutionEnv
from ..acadl.graph import ArchitectureGraph

__all__ = ["init_conv_memory", "eyeriss_conv2d", "read_conv_result"]

IFM_BASE = 0x0          # GLB rows: one address per ifmap/filter/psum row
FLT_BASE = 0x40000
PSUM_BASE = 0x80000


def init_conv_memory(ag: ArchitectureGraph, ifmap: np.ndarray,
                     filt: np.ndarray, glb: str = "glb0") -> None:
    """ifmap (H, W), filt (R, S) — row-granular placement in the GLB."""
    mem = ag.by_name[glb]
    for r in range(ifmap.shape[0]):
        mem.write(IFM_BASE + r, ifmap[r].astype(np.float64).copy())
    for r in range(filt.shape[0]):
        mem.write(FLT_BASE + r, filt[r].astype(np.float64).copy())


def read_conv_result(ag: ArchitectureGraph, out_h: int,
                     glb: str = "glb0") -> np.ndarray:
    mem = ag.by_name[glb]
    rows = [np.asarray(mem.read(PSUM_BASE + r)) for r in range(out_h)]
    return np.stack(rows)


def _t_load_row(dst: str, addr: int, words: int, unit: str) -> Instruction:
    def fn(env: ExecutionEnv, ins: Instruction) -> None:
        env.write_reg(dst, env.read_mem(addr))
    return Instruction("t_load", (), (dst,), read_addresses=(addr,),
                       function=fn, unit_hint=unit, tags={"words": words})


def _t_store_row(src: str, addr: int, words: int, unit: str) -> Instruction:
    def fn(env: ExecutionEnv, ins: Instruction) -> None:
        env.write_mem(addr, env.read_reg(src))
    return Instruction("t_store", (src,), (), write_addresses=(addr,),
                       function=fn, unit_hint=unit, tags={"words": words})


def _row_conv(r: int, c: int, out_w: int, flt_w: int, unit: str) -> Instruction:
    """ps[r][c] = conv1d(ifm[r][c], w[r][c]) — valid mode."""
    w_reg, i_reg, p_reg = f"w[{r}][{c}]", f"ifm[{r}][{c}]", f"ps[{r}][{c}]"

    def fn(env: ExecutionEnv, ins: Instruction) -> None:
        w = np.asarray(env.read_reg(w_reg))
        x = np.asarray(env.read_reg(i_reg))
        out = np.asarray([np.dot(x[j:j + len(w)], w)
                          for j in range(len(x) - len(w) + 1)])
        env.write_reg(p_reg, out)
    return Instruction("row_conv", (w_reg, i_reg), (p_reg,), function=fn,
                       unit_hint=unit,
                       tags={"words": out_w, "macs": out_w * flt_w})


def _psum_add(r_src: int, r_dst: int, c: int, out_w: int, unit: str) -> Instruction:
    """ps[r_dst][c] += ps[r_src][c] (vertical accumulation)."""
    src, dst = f"ps[{r_src}][{c}]", f"ps[{r_dst}][{c}]"

    def fn(env: ExecutionEnv, ins: Instruction) -> None:
        env.write_reg(dst, np.asarray(env.read_reg(dst)) +
                      np.asarray(env.read_reg(src)))
    return Instruction("psum_add", (src, dst), (dst,), function=fn,
                       unit_hint=unit, tags={"words": out_w, "macs": out_w})


def eyeriss_conv2d(ifm_h: int, ifm_w: int, flt_h: int, flt_w: int,
                   rows: int, columns: int) -> List[Instruction]:
    """Row-stationary single-channel conv2d (valid).

    PE (r, c) holds filter row r and processes output rows assigned to
    logical column c; psums accumulate up the column (PE r adds into
    PE r-1, row 0 stores).  Output rows are striped over `columns`.
    """
    out_h = ifm_h - flt_h + 1
    out_w = ifm_w - flt_w + 1
    assert flt_h <= rows, (flt_h, rows)
    prog: List[Instruction] = []

    # load filter rows (stationary) into every active column
    for c in range(min(columns, out_h)):
        for r in range(flt_h):
            prog.append(_t_load_row(f"w[{r}][{c}]", FLT_BASE + r, flt_w,
                                    f"elu{r}"))

    for o in range(out_h):
        c = o % columns
        # ifmap rows o..o+flt_h-1 slide into the column's PEs
        for r in range(flt_h):
            prog.append(_t_load_row(f"ifm[{r}][{c}]", IFM_BASE + o + r,
                                    ifm_w, f"elu{r}"))
            prog.append(_row_conv(r, c, out_w, flt_w, f"efu[{r}][{c}]"))
        # vertical psum accumulation into row 0
        for r in range(flt_h - 1, 0, -1):
            prog.append(_psum_add(r, r - 1, c, out_w, f"efu[{r-1}][{c}]"))
        prog.append(_t_store_row(f"ps[0][{c}]", PSUM_BASE + o, out_w,
                                 f"esu{0}"))
    return prog
