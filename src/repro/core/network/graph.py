"""Layer-graph frontend: model config -> ordered per-layer operator graph.

The paper's central demonstration is mapping *entire DNNs* onto
ACADL-modeled accelerators and inferring end-to-end timing (§1, §5, §7;
Lübeck et al. 2024 make the layer-graph level the unit of automatic
performance-model generation).  ``repro.core.mapping.workload`` already
extracts a model's per-step operator *totals* (one ``OperatorCall`` per
operator kind, layer counts folded into ``count``); this module recovers
the **execution order**: the sequence of per-layer operator instances one
forward step actually runs, e.g. for a 16-block decoder-only LM

    [q, kv, attn_core, o, mlp] x 16, unembed

Each instance carries a ``count=1`` ``OperatorCall`` (its exact shape) and
the graph records which instances share a shape — the unit of AIDG
compile-caching downstream (16 identical blocks lower to ONE compiled
per-layer program repeated 16 times).

The expansion is validated against ``extract_operators``: every extracted
call's folded ``count`` must equal its number of occurrences in the
expanded sequence (times the train-mode multiplier), so the layer graph
can never silently drift from the operator-extraction shapes.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

from ...models.config import ModelConfig, ShapeConfig
from ..mapping.workload import OperatorCall, extract_operators

__all__ = ["LayerInstance", "LayerGraph", "extract_layer_graph",
           "NETWORK_SHAPE"]

# the reference whole-network shape: single-token decode at a small batch.
# Sizes keep every per-layer program event-simulatable in tests while the
# coarse (fused-tensor) latency models still see the real layer shapes.
NETWORK_SHAPE = ShapeConfig("net_decode", seq_len=2048, global_batch=8,
                            mode="decode")


@dataclass(frozen=True)
class LayerInstance:
    """One per-layer operator instance in execution order."""

    tag: str                 # operator-extraction tag ("q", "mlp", ...)
    call: OperatorCall       # exact shape, count = 1
    unique: int              # index into LayerGraph.unique


@dataclass
class LayerGraph:
    """The expanded execution sequence of a model's forward step.

    ``unique`` holds one ``OperatorCall`` per distinct (op, m, k, n) shape;
    ``instances`` the full ordered sequence referencing it; ``runs`` the
    run-length encoding of ``instances`` by unique id — the structure the
    max-plus composition consumes."""

    arch_id: str
    shape: ShapeConfig
    instances: List[LayerInstance]
    unique: List[OperatorCall]

    @property
    def runs(self) -> List[Tuple[int, int]]:
        """Run-length encoding [(unique_id, consecutive instances), ...]."""
        out: List[Tuple[int, int]] = []
        for inst in self.instances:
            if out and out[-1][0] == inst.unique:
                out[-1] = (inst.unique, out[-1][1] + 1)
            else:
                out.append((inst.unique, 1))
        return out

    @property
    def ops(self) -> Tuple[str, ...]:
        """The distinct operator kinds the network needs an arch to map."""
        return tuple(sorted({c.op for c in self.unique}))

    def counts(self) -> Dict[int, int]:
        """unique id -> total instances across the sequence."""
        out: Dict[int, int] = {}
        for inst in self.instances:
            out[inst.unique] = out.get(inst.unique, 0) + 1
        return out


def _block_tags(cfg: ModelConfig, kind: str, is_moe: bool) -> List[str]:
    """Execution-order operator tags of one decoder block."""
    tags: List[str] = []
    if kind == "attn":
        if cfg.attention.kind == "mla":
            tags += ["q_down", "q_up", "kv_down", "kv_up", "attn_core", "o"]
        else:
            tags += ["q", "kv", "attn_core", "o"]
        if cfg.enc_dec is not None:
            tags += ["xattn_q", "xattn"]
    else:
        tags += ["ssm_in", "ssm_proj", "ssm_scan", "ssm_out"]
    if is_moe and cfg.moe is not None:
        tags += ["router", "moe"]
    elif cfg.d_ff > 0:
        tags += ["mlp"]
    return tags


def extract_layer_graph(cfg: ModelConfig, shape: ShapeConfig = NETWORK_SHAPE
                        ) -> LayerGraph:
    """Expand (config, shape) into the ordered per-layer operator sequence.

    Raises ``ValueError`` if the expansion disagrees with
    ``extract_operators`` about any operator's total count — the two views
    must describe the same network."""
    calls = extract_operators(cfg, shape)
    per_tag: Dict[str, OperatorCall] = {}
    folded: Dict[str, int] = {}
    for c in calls:
        if c.tag in per_tag:
            raise ValueError(f"duplicate operator tag {c.tag!r} in "
                             f"{cfg.arch_id}")
        per_tag[c.tag] = OperatorCall(c.op, c.m, c.k, c.n, 1, c.tag)
        folded[c.tag] = c.count

    tags: List[str] = []
    if cfg.enc_dec is not None:
        for _ in range(cfg.enc_dec.n_encoder_layers):
            tags += ["enc_attn_proj", "enc_attn", "enc_mlp"]
    for kind, is_moe in zip(cfg.layer_kinds(), cfg.moe_layers()):
        tags += _block_tags(cfg, kind, is_moe)
    tags.append("unembed")

    # consistency: occurrences x train multiplier == extracted fold count
    mult = 3 if shape.mode == "train" else 1
    occur: Dict[str, int] = {}
    for t in tags:
        occur[t] = occur.get(t, 0) + 1
    enc_tags = {"enc_attn_proj", "enc_attn", "enc_mlp"}
    for tag, n in folded.items():
        # encoder ops run forward-only even in train mode upstream
        expect = occur.get(tag, 0) * (1 if tag in enc_tags else mult)
        if expect != n:
            raise ValueError(
                f"{cfg.arch_id}: layer-graph expansion has {expect} "
                f"x {tag!r} but extract_operators folded count {n}")
    missing = [t for t in tags if t not in per_tag]
    if missing:
        raise ValueError(f"{cfg.arch_id}: no extracted operator for tags "
                         f"{sorted(set(missing))}")

    unique: List[OperatorCall] = []
    by_shape: Dict[Tuple, int] = {}
    instances: List[LayerInstance] = []
    for t in tags:
        call = per_tag[t]
        key = (call.op, call.m, call.k, call.n)
        if key not in by_shape:
            by_shape[key] = len(unique)
            unique.append(call)
        instances.append(LayerInstance(t, call, by_shape[key]))
    return LayerGraph(cfg.arch_id, shape, instances, unique)
