"""Network-level mapping: lower whole DNNs onto ACADL accelerators.

The layer-graph frontend (``graph``) expands a model config into its
ordered per-layer operator sequence, the lowering table (``lowering``)
maps each operator onto every modeled architecture via the existing
``repro.core.mapping`` builders, and the model layer (``model``) composes
the per-layer AIDG makespans in max-plus — sequentially or with
capacity-bounded double-buffered pipelining — and plugs the result into
the DSE stack as first-class Explorer cells (``Explorer(networks=True)``).

See ``docs/networks.md`` for the pipeline walkthrough and measured
numbers.
"""

from .graph import (LayerGraph, LayerInstance, NETWORK_SHAPE,
                    extract_layer_graph)
from .lowering import (ARCH_CAPACITY_WORDS, ARCH_TILE_TOL, LoweredLayer,
                       lower_call, lowerable_ops)
from .model import (CompiledNetwork, NETWORKS, NETWORK_ARCHS,
                    NetworkScenario, default_network_scenarios)

__all__ = [
    "LayerGraph", "LayerInstance", "NETWORK_SHAPE", "extract_layer_graph",
    "ARCH_CAPACITY_WORDS", "ARCH_TILE_TOL", "LoweredLayer", "lower_call",
    "lowerable_ops", "CompiledNetwork", "NETWORKS", "NETWORK_ARCHS",
    "NetworkScenario", "default_network_scenarios",
]
