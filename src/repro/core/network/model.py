"""Whole-network cells: lower a DNN onto an architecture, compose in
max-plus, and plug into the DSE stack.

``NetworkScenario`` is the network-level counterpart of
``explorer.Scenario``: one (architecture, network) cell.  ``compile``
drives the full pipeline

    config -> layer graph -> per-layer lowering -> per-layer CompiledAIDG
           -> LayerStack (max-plus composition structure)

with every per-layer program compiled through the process-wide scenario
cache (``explorer.compile_scenario``), so a layer shape repeated inside a
network — or shared between networks — builds its AIDG exactly once.

``CompiledNetwork`` implements the Explorer's cell protocol
(``projection`` / ``evaluate`` / ``accumulate_weights`` / ``grad_fn`` /
``simulate`` / ``stats_row``): a network cell sits in the scenario matrix
next to single-operator cells, is swept by the same shared knob vectors,
and reports *end-to-end* latency — `Explorer(networks=True)` is the
paper's DNN-to-accelerator performance model in the co-design loop.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ...configs import get_config
from ..aidg.dse import (LayerStack, NETWORK_MODES, PackSpec,
                        compiled_network_sweep, grad_network_sweep)
from ..aidg.explorer import (CompiledScenario, DesignSpace,
                             compile_scenario)
from ..aidg.maxplus import DEFAULT_ENGINE
from ...models.config import ShapeConfig
from .graph import NETWORK_SHAPE, LayerGraph, extract_layer_graph
from .lowering import (ARCH_CAPACITY_WORDS, ARCH_TILE_TOL, lower_call,
                       lowerable_ops)

__all__ = ["NetworkScenario", "CompiledNetwork", "default_network_scenarios",
           "NETWORKS", "NETWORK_ARCHS"]

# the default whole-network matrix: the four assigned models the ROADMAP
# names, across every architecture that lowers all of their operators
NETWORKS = ("whisper_small", "olmo_1b", "olmoe_1b_7b", "falcon_mamba_7b")
NETWORK_ARCHS = ("oma", "systolic", "gamma", "eyeriss", "plasticine",
                 "tpu_v5e")

# operation classes counted as pure data movement for the prologue prefix
_MEM_OPS = frozenset({"t_load", "t_store", "load", "store"})


@dataclass(frozen=True)
class NetworkScenario:
    """One (architecture, whole network) cell of the scenario matrix.

    ``mode`` selects the max-plus composition: ``"sequential"`` (layers
    back-to-back — the oracle-matching default) or ``"pipelined"``
    (double-buffered inter-layer overlap bounded by on-chip capacity).
    ``sim_tol`` is the cell's expected AIDG-vs-oracle relative error,
    inherited from its architecture's tile accuracy."""

    arch: str
    network: str
    shape: ShapeConfig = NETWORK_SHAPE
    mode: str = "sequential"

    def __post_init__(self):
        if self.mode not in NETWORK_MODES:
            raise ValueError(f"mode must be one of {NETWORK_MODES}, "
                             f"got {self.mode!r}")

    @property
    def name(self) -> str:
        """Display name, ``arch/network`` (one matrix cell)."""
        return f"{self.arch}/{self.network}"

    @property
    def sim_tol(self) -> float:
        """Expected AIDG-vs-oracle relative error, from the architecture's
        measured tile accuracy (0.0 = cycle-exact tiles)."""
        return ARCH_TILE_TOL[self.arch]

    def layer_graph(self) -> LayerGraph:
        """The network's expanded per-layer operator sequence."""
        return extract_layer_graph(get_config(self.network), self.shape)

    def compile(self, use_cache: bool = True) -> "CompiledNetwork":
        """Lower every layer, compile unique tile programs (shared AIDG
        cache), and assemble the composition stack."""
        lg = self.layer_graph()
        lowered = []
        for call in lg.unique:
            low = lower_call(self.arch, call)
            if low is None:
                raise ValueError(
                    f"{self.name}: operator {call.op!r} has no lowering on "
                    f"{self.arch} (lowerable: {lowerable_ops(self.arch)})")
            lowered.append(low)

        # unique TILE programs (several layers usually share one)
        cells: List[CompiledScenario] = []
        tile_of_unique: List[int] = []
        by_key: Dict[Tuple, int] = {}
        for low in lowered:
            key = low.scenario.key
            if key not in by_key:
                by_key[key] = len(cells)
                cells.append(compile_scenario(low.scenario, use_cache))
            tile_of_unique.append(by_key[key])

        # run-length composition over tile programs; per-run reps fold the
        # per-instance tile extrapolation
        run_layer: List[int] = []
        run_reps: List[float] = []
        run_words: List[float] = []
        for uid, n_inst in lg.runs:
            t = tile_of_unique[uid]
            reps = n_inst * lowered[uid].tiles
            if run_layer and run_layer[-1] == t:
                run_reps[-1] += reps
            else:
                run_layer.append(t)
                run_reps.append(reps)
                run_words.append(lowered[uid].weight_words)

        cap = float(ARCH_CAPACITY_WORDS[self.arch])
        ww = np.asarray(run_words, np.float64)
        fits_within = (2.0 * ww <= cap).astype(np.float32)
        fits_between = ((ww[:-1] + ww[1:]) <= cap).astype(np.float32)

        stack = LayerStack(
            problems=[c.problem for c in cells],
            prologue_len=np.asarray([_prologue_len(c) for c in cells],
                                    np.int64),
            run_layer=np.asarray(run_layer, np.int64),
            run_reps=np.asarray(run_reps, np.float32),
            fits_within=fits_within,
            fits_between=fits_between,
        )
        return CompiledNetwork(self, lg, cells, stack)


def _prologue_len(cs: CompiledScenario) -> int:
    """Length of the load-only instruction prefix of the tile program: the
    part of a layer a double-buffered pipeline can overlap with the
    previous layer's tail (no compute op has consumed its inputs yet)."""
    op_is_mem = np.asarray(
        [nm.split("@")[0] in _MEM_OPS for nm in cs.problem.op_names])
    mem_node = op_is_mem[cs.aidg.op_class]
    k = 0
    while k < cs.aidg.n and mem_node[k]:
        k += 1
    return k


@dataclass
class CompiledNetwork:
    """A compiled whole-network cell: unique tile cells + LayerStack.

    Implements the Explorer cell protocol; every evaluation is one jitted
    device call computing per-unique-layer wavefronts and the max-plus
    composition together."""

    scenario: NetworkScenario
    layer_graph: LayerGraph
    cells: List[CompiledScenario]       # unique tile programs
    stack: LayerStack
    _sim_cache: Optional[float] = field(default=None, repr=False)

    @property
    def name(self) -> str:
        """Display name inherited from the scenario (``arch/network``)."""
        return self.scenario.name

    @property
    def arch(self) -> str:
        """The cell's architecture (query-resolution protocol)."""
        return self.scenario.arch

    @property
    def workload(self) -> str:
        """The cell's workload kind (query-resolution protocol): the
        network name, so a served query for e.g. ``"whisper_small"``
        resolves to this cell on every mapped architecture."""
        return self.scenario.network

    @property
    def n_layers(self) -> int:
        """Unique per-layer programs (the compile unit)."""
        return len(self.cells)

    @property
    def reps_per_layer(self) -> np.ndarray:
        """(L,) total composed instances per unique tile program."""
        out = np.zeros(len(self.cells), np.float64)
        for t, r in zip(self.stack.run_layer, self.stack.run_reps):
            out[int(t)] += float(r)
        return out

    # -- the cell protocol --------------------------------------------------

    def projection(self, space: DesignSpace) -> List[Tuple]:
        """Per-unique-layer (op -> knob, storage -> knob) gather maps."""
        return [space.projection(p) for p in self.stack.problems]

    def _thetas(self, space: DesignSpace, kt: np.ndarray, proj):
        proj = proj or self.projection(space)
        tos, tss = [], []
        for prob, pr in zip(self.stack.problems, proj):
            to, ts = space.theta_for(prob, kt, pr)
            tos.append(to)
            tss.append(ts)
        return tuple(tos), tuple(tss)

    def evaluate(self, space: DesignSpace, knob_thetas: np.ndarray,
                 proj=None, n_iters: int = 2, chunk: Optional[int] = None,
                 engine: str = DEFAULT_ENGINE) -> np.ndarray:
        """(B, n_knobs) shared candidates -> (B,) end-to-end network cycles
        through the cached stacked sweep (one device launch per batch)."""
        kt = np.asarray(knob_thetas, np.float32)
        if kt.ndim == 1:
            kt = kt[None, :]
        fn = compiled_network_sweep(self.stack, n_iters=n_iters,
                                    engine=engine, mode=self.scenario.mode)
        tos, tss = self._thetas(space, kt, proj)
        B = kt.shape[0]
        if chunk is None or B <= chunk:
            return np.asarray(fn(tos, tss))
        out = np.empty(B, dtype=np.float32)
        for s in range(0, B, chunk):
            e = min(s + chunk, B)
            pad = chunk - (e - s)
            sl = lambda xs: tuple(
                np.concatenate([x[s:e],
                                np.ones((pad,) + x.shape[1:], x.dtype)])
                if pad else x[s:e] for x in xs)
            out[s:e] = np.asarray(fn(sl(tos), sl(tss)))[: e - s]
        return out

    def accumulate_weights(self, space: DesignSpace, proj,
                           w: np.ndarray) -> None:
        """Parameter-volume weights, per unique layer scaled by its total
        composed instances (a block repeated 16x governs 16x the area)."""
        proj = proj or self.projection(space)
        reps = self.reps_per_layer
        for cs, pr, r in zip(self.cells, proj, reps):
            wc = np.zeros_like(w)
            cs.accumulate_weights(space, pr, wc)
            w += wc * r

    def grad_fn(self, proj, n_iters: int = 2):
        """Cached jit(vmap(value_and_grad)) of end-to-end soft latency."""
        return grad_network_sweep(self.stack, proj, n_iters=n_iters,
                                  mode=self.scenario.mode)

    def energy_coeffs(self, space: DesignSpace, proj
                      ) -> Tuple[np.ndarray, float]:
        """Folded energy coefficients of the whole network: per-unique-
        layer dynamic pJ per knob scaled by composed instance counts
        (energy is work — pipelined overlap shortens the makespan, not
        the joules), plus the architecture's static pJ per cycle."""
        from ..archs.energy import energy_model
        from ..aidg.energy import fold_dyn_energy
        model = energy_model(self.arch)
        proj = proj or self.projection(space)
        edyn = np.zeros(space.n + 1, np.float64)
        for prob, pr, r in zip(self.stack.problems, proj,
                               self.reps_per_layer):
            edyn += float(r) * fold_dyn_energy(prob, pr, space.n, model)
        return edyn, model.static_pj

    def pack_spec(self, proj, n_knobs: Optional[int] = None) -> PackSpec:
        """This cell's :class:`repro.core.aidg.dse.PackSpec`: the stack's
        unique tile problems plus its run-length composition arrays.
        Sequential cells zero the overlap gates (one composition formula
        serves both modes); pipelined cells keep them, and the prologue
        boundary is passed through so condensation force-keeps the last
        chain node of every load-only prefix.  With ``n_knobs`` the spec
        carries per-unique-layer folded energy coefficients (the packed
        3-objective dispatch scales them by the run repetitions)."""
        seq = self.scenario.mode == "sequential"
        st = self.stack
        nr = len(st.run_layer)
        edyn: Tuple[np.ndarray, ...] = ()
        static_pj = 0.0
        if n_knobs is not None:
            from ..archs.energy import energy_model
            from ..aidg.energy import fold_dyn_energy
            model = energy_model(self.arch)
            edyn = tuple(fold_dyn_energy(prob, pr, n_knobs, model)
                         for prob, pr in zip(st.problems, proj))
            static_pj = model.static_pj
        return PackSpec(
            problems=tuple(st.problems),
            projections=tuple(tuple(p) for p in proj),
            prologue_len=np.asarray(st.prologue_len, np.int64),
            run_layer=np.asarray(st.run_layer, np.int64),
            run_reps=np.asarray(st.run_reps, np.float32),
            fits_within=(np.zeros(nr, np.float32) if seq
                         else np.asarray(st.fits_within, np.float32)),
            fits_between=(np.zeros(max(0, nr - 1), np.float32) if seq
                          else np.asarray(st.fits_between, np.float32)),
            edyn=edyn, static_pj=static_pj)

    def simulate(self) -> float:
        """Event-simulator oracle, composed the same way the estimate is:
        simulate each unique tile program once, then apply the sequential
        composition Σ reps·sim (memoized — the tiles are immutable)."""
        if self._sim_cache is None:
            sims = np.asarray([c.simulate() for c in self.cells], np.float64)
            self._sim_cache = float((self.reps_per_layer * sims).sum())
        return self._sim_cache

    def stats_row(self) -> Dict[str, float]:
        """Aggregate level-schedule statistics over unique tile programs
        (including the chain-condensed depths the packed engine scans)."""
        n = sum(c.schedule.n for c in self.cells)
        levels = sum(c.schedule.n_levels for c in self.cells)
        rows = [c.stats_row() for c in self.cells]
        return {"name": self.name, "n": n, "levels": levels,
                "max_width": max(c.schedule.width for c in self.cells),
                "parallelism": round(n / max(1, levels), 2),
                "kept": sum(r["kept"] for r in rows),
                "levels_condensed": sum(r["levels_condensed"]
                                        for r in rows)}


def default_network_scenarios(networks: Optional[Sequence[str]] = None,
                              archs: Optional[Sequence[str]] = None,
                              shape: ShapeConfig = NETWORK_SHAPE,
                              mode: str = "sequential"
                              ) -> List[NetworkScenario]:
    """The whole-network matrix: every requested network on every
    architecture that lowers all of its operators (cells that don't map
    are absent, like the operator matrix)."""
    out: List[NetworkScenario] = []
    for net in (NETWORKS if networks is None else networks):
        lg = extract_layer_graph(get_config(net), shape)
        for arch in (NETWORK_ARCHS if archs is None else archs):
            if all(op in lowerable_ops(arch) for op in lg.ops):
                out.append(NetworkScenario(arch, net, shape, mode))
    return out
