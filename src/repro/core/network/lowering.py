"""Per-architecture lowering of layer-graph operators to AIDG programs.

Every architecture in ``repro.core.archs.ARCH_REGISTRY`` gets a lowering
from ``OperatorCall`` (one per-layer operator instance) to a concrete
ACADL instruction stream, reusing the existing ``repro.core.mapping``
builders.  Two regimes:

* **Full-shape lowering** (``tpu_v5e``): the fused-tensor abstraction
  level folds the whole operator's MACs/words into per-instruction latency
  arguments, so one per-layer program models the *exact* layer shape —
  ``tiles = 1``.
* **Representative-tile lowering** (every tiled/scalar machine): the
  per-layer program is one fixed, measured-accurate tile of the operator
  on that machine (e.g. a 32³ Γ̈ GEMM tile, an 8×16×8 systolic residency,
  a 64×64 Eyeriss row-stationary pass) and the layer's cycles are
  ``tile makespan × tiles`` with ``tiles = ceil(layer MACs / tile MACs)``
  — the standard tile-extrapolation performance model.  Because every
  layer of an operator kind shares ONE tile program, a whole network
  compiles a handful of AIDGs per architecture (asserted via the
  scenario-cache hit counters).

Operators an architecture has no natural unit for are lowered through a
documented **proxy** at matched MAC count (attention → GEMM tiles on the
systolic array and OMA, GEMM/attention → row-stationary conv passes on
Eyeriss via the im2col correspondence, everything → map/reduce pipelines
on Plasticine); ``lower_call`` returns ``None`` where no lowering is
defensible (e.g. selective scan on the systolic array), and that network
cell is simply absent from the matrix — same convention as the operator
matrix.

Tile sizes are chosen from measured AIDG-vs-event-simulator error (see
``docs/networks.md``): every tile used here is exact or within 1% of the
oracle, so composed network estimates stay within 1% end-to-end.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from ..aidg.explorer import Scenario
from ..archs import ARCH_CAPACITY_WORDS
from ..mapping.workload import OperatorCall

__all__ = ["LoweredLayer", "lower_call", "lowerable_ops",
           "ARCH_CAPACITY_WORDS", "ARCH_TILE_TOL"]

# Measured AIDG-vs-event-sim relative error bound of the tile programs
# below (0.0 = cycle-exact; see docs/networks.md for the measurements).
ARCH_TILE_TOL: Dict[str, float] = {
    "oma": 0.0,
    "systolic": 0.008,
    "gamma": 0.0,
    "eyeriss": 0.01,
    "plasticine": 0.0,
    "tpu_v5e": 0.0,
}


@dataclass(frozen=True)
class LoweredLayer:
    """One layer instance lowered onto one architecture.

    ``scenario`` is the (cacheable) tile-program cell; ``tiles`` the
    analytic repeat count extrapolating the tile to the full layer;
    ``weight_words`` the stationary working set one buffered instance
    occupies (the double-buffer capacity gate compares two of these
    against ``ARCH_CAPACITY_WORDS``)."""

    scenario: Scenario
    tiles: float
    weight_words: float


def _scenario(arch: str, op: str, fn: Callable, *args) -> Scenario:
    """Tile-program cell keyed like ``default_scenarios``' S() helper (the
    builder identity participates, so network tiles never alias operator
    cells built from different functions)."""
    params = ((("__builder__", f"{fn.__module__}.{fn.__qualname__}"),)
              + tuple(enumerate(args)))
    return Scenario(arch, op, lambda: fn(*args), params,
                    ARCH_TILE_TOL[arch])


def _stationary_words(call: OperatorCall) -> float:
    """The operand a buffered schedule keeps resident: the weight matrix
    for GEMM, the KV working set for attention, the state for a scan."""
    if call.op == "scan":
        return float(call.k)
    return float(call.k * call.n)


# ---------------------------------------------------------------------------
# tile builders (module-level so their identity keys the AIDG cache)
# ---------------------------------------------------------------------------


def _tile_tpu(op: str, m: int, k: int, n: int):
    from ..archs import ARCH_REGISTRY
    from ..mapping.workload import UMA_REGISTRY
    ag, _ = ARCH_REGISTRY["tpu_v5e"]()
    return ag, UMA_REGISTRY[("tpu_v5e", op)](OperatorCall(op, m, k, n, 1,
                                                          "net"))


def _tile_gamma_gemm(n: int, nu: int):
    from ..aidg.explorer import _gamma_units
    from ..archs import ARCH_REGISTRY
    from ..mapping.gemm import gamma_gemm, init_gemm_memory
    ag, _ = ARCH_REGISTRY["gamma"](n_units=nu)
    A = np.ones((n, n), np.float32)
    init_gemm_memory(ag, A, A, memory="dram0", tile=8)
    return ag, gamma_gemm(n, n, n, tile=8, units=_gamma_units(nu))


def _tile_gamma_attention(seq: int, ctx: int, hd: int, nu: int):
    from ..aidg.explorer import _attn_units
    from ..archs import ARCH_REGISTRY
    from ..mapping.fused import gamma_attention
    ag, _ = ARCH_REGISTRY["gamma"](n_units=nu)
    return ag, gamma_attention(seq, ctx, hd, units=_attn_units(nu))


def _tile_gamma_scan(tokens: int, d_state: int, nu: int):
    from ..aidg.explorer import _attn_units
    from ..archs import ARCH_REGISTRY
    from ..mapping.fused import gamma_scan
    ag, _ = ARCH_REGISTRY["gamma"](n_units=nu)
    return ag, gamma_scan(tokens, d_state, units=_attn_units(nu))


def _tile_systolic_gemm(m: int, k: int, n: int, rows: int, cols: int):
    from ..archs import ARCH_REGISTRY
    from ..mapping.systolic import init_systolic_memory, systolic_gemm_program
    ag, _ = ARCH_REGISTRY["systolic"](rows, cols)
    init_systolic_memory(ag, np.ones((m, k)), np.ones((k, n)))
    return ag, systolic_gemm_program(m, k, n, rows, cols)


def _tile_eyeriss_conv(h: int, w: int, f: int, rows: int, cols: int):
    from ..archs import ARCH_REGISTRY
    from ..mapping.conv import eyeriss_conv2d, init_conv_memory
    ag, _ = ARCH_REGISTRY["eyeriss"](rows=rows, columns=cols)
    init_conv_memory(ag, np.ones((h, w)), np.ones((f, f)))
    return ag, eyeriss_conv2d(h, w, f, f, rows, cols)


def _tile_plasticine_reduce(n: int, npcu: int):
    from ..archs import ARCH_REGISTRY
    from ..mapping.patterns import init_vector_memory, plasticine_map_reduce
    ag, _ = ARCH_REGISTRY["plasticine"](n_pcu=npcu, n_pmu=npcu)
    init_vector_memory(ag, np.ones(n), npcu)
    return ag, plasticine_map_reduce(n, npcu, npcu)


def _tile_oma_gemm(n: int, t: int):
    from ..archs import ARCH_REGISTRY
    from ..mapping.gemm import init_gemm_memory, oma_gemm_unrolled
    ag, _ = ARCH_REGISTRY["oma"]()
    A = np.ones((n, n))
    init_gemm_memory(ag, A, A)
    return ag, oma_gemm_unrolled(n, n, n, t, t, t)


# ---------------------------------------------------------------------------
# the lowering table: (arch, op) -> (tile scenario, tile MACs, tile words)
# ---------------------------------------------------------------------------

# (scenario factory, tile MAC capacity, buffered tile words).  Proxy
# lowerings reuse another op's tile at matched MAC count.
_GAMMA_GEMM = (lambda: _scenario("gamma", "gemm", _tile_gamma_gemm, 32, 2),
               32 * 32 * 32, 32 * 32)
_GAMMA_ATTN = (lambda: _scenario("gamma", "attention",
                                 _tile_gamma_attention, 32, 64, 8, 2),
               32 * 64 * 2 * 8, 64 * 16)
_GAMMA_SCAN = (lambda: _scenario("gamma", "scan", _tile_gamma_scan,
                                 256, 16, 2),
               256 * 16 * 2, 16)
_SYSTOLIC_GEMM = (lambda: _scenario("systolic", "gemm", _tile_systolic_gemm,
                                    8, 16, 8, 4, 4),
                  8 * 16 * 8, 16 * 8)
_EYERISS_CONV = (lambda: _scenario("eyeriss", "conv", _tile_eyeriss_conv,
                                   64, 64, 3, 3, 3),
                 62 * 62 * 3 * 3, 64 * 3)
_PLASTICINE_MR = (lambda: _scenario("plasticine", "reduce",
                                    _tile_plasticine_reduce, 2048, 4),
                  2048, 2048)
_OMA_GEMM = (lambda: _scenario("oma", "gemm", _tile_oma_gemm, 4, 2),
             4 * 4 * 4, 4 * 4)

_TILES: Dict[Tuple[str, str], Tuple[Callable, int, int]] = {
    ("gamma", "gemm"): _GAMMA_GEMM,
    ("gamma", "attention"): _GAMMA_ATTN,
    ("gamma", "scan"): _GAMMA_SCAN,
    ("systolic", "gemm"): _SYSTOLIC_GEMM,
    ("systolic", "attention"): _SYSTOLIC_GEMM,   # QKᵀ/PV as GEMM tiles
    ("eyeriss", "gemm"): _EYERISS_CONV,          # im2col correspondence
    ("eyeriss", "attention"): _EYERISS_CONV,
    ("plasticine", "gemm"): _PLASTICINE_MR,      # dot-product map/reduce
    ("plasticine", "attention"): _PLASTICINE_MR,
    ("plasticine", "scan"): _PLASTICINE_MR,      # scans ARE its pattern
    ("oma", "gemm"): _OMA_GEMM,
    ("oma", "attention"): _OMA_GEMM,             # scalar QKᵀ/PV proxy
}

_TPU_OPS = ("gemm", "attention", "scan")


def lowerable_ops(arch: str) -> Tuple[str, ...]:
    """The operator kinds ``lower_call`` can map onto ``arch``."""
    if arch == "tpu_v5e":
        return _TPU_OPS
    return tuple(sorted(op for (a, op) in _TILES if a == arch))


def lower_call(arch: str, call: OperatorCall) -> Optional[LoweredLayer]:
    """One per-layer operator instance -> its program on ``arch``.

    Returns ``None`` when the architecture has no (even proxy) lowering
    for the operator kind — the caller drops the whole network cell."""
    if arch == "tpu_v5e":
        if call.op not in _TPU_OPS:
            return None
        sc = _scenario("tpu_v5e", call.op, _tile_tpu, call.op, call.m,
                       call.k, call.n)
        return LoweredLayer(sc, 1.0, _stationary_words(call))
    hit = _TILES.get((arch, call.op))
    if hit is None:
        return None
    factory, tile_macs, tile_words = hit
    tiles = float(max(1, math.ceil(call.macs / tile_macs)))
    return LoweredLayer(factory(), tiles, float(tile_words))
