"""Plasticine-derived reconfigurable parallel-patterns accelerator
(paper §6 references [27]).

Modeled at the tensor level: Pattern Compute Units (PCUs) are ExecuteStages
holding a SIMD ``map``/``reduce`` FunctionalUnit over vector registers;
Pattern Memory Units (PMUs) are banked SRAM scratchpads with address-stream
MAUs; a shared DRAM feeds the PMUs through DMA MAUs.  The checkerboard
interconnect of the real chip is abstracted to PCU<->PMU register/storage
edges (ACADL models dependencies, not wires — paper §3).
"""

from __future__ import annotations

from typing import Dict, List, Optional

from ..acadl import (
    ACADLEdge,
    CONTAINS,
    Data,
    DRAM,
    ExecuteStage,
    FORWARD,
    FunctionalUnit,
    InstructionFetchStage,
    InstructionMemoryAccessUnit,
    MemoryAccessUnit,
    READ_DATA,
    RegisterFile,
    SRAM,
    WRITE_DATA,
    create_ag,
    generate,
    latency_t,
)

__all__ = ["generate_plasticine", "make_plasticine_ag"]

PMU_WINDOW = 0x10000  # address window per PMU


@generate
def generate_plasticine(n_pcu: int = 4, n_pmu: int = 4, *, simd_lanes: int = 16,
                        pipeline_depth: int = 6, port_width: int = 8,
                        issue_buffer_size: int = 64,
                        dram_kw: Optional[dict] = None) -> Dict[str, object]:
    imem0 = SRAM(name="imem0", read_latency=1, write_latency=1,
                 address_ranges=((0, 1 << 22),), port_width=port_width)
    pcrf0 = RegisterFile(name="pcrf0", data_width=32,
                         registers={"pc": Data(32, 0)})
    ifs0 = InstructionFetchStage(name="ifs0", latency=latency_t(1),
                                 issue_buffer_size=issue_buffer_size)
    imau0 = InstructionMemoryAccessUnit(name="imau0", latency=latency_t(0))
    ACADLEdge(imem0, imau0, READ_DATA)
    ACADLEdge(pcrf0, imau0, READ_DATA)
    ACADLEdge(imau0, pcrf0, WRITE_DATA)
    ACADLEdge(ifs0, imau0, CONTAINS)

    dram0 = DRAM(name="dram0", read_latency=24, write_latency=24,
                 address_ranges=((n_pmu * PMU_WINDOW, 1 << 26),), port_width=16,
                 max_concurrent_requests=4, read_write_ports=n_pmu + 1,
                 **(dram_kw or {}))

    lanes = simd_lanes

    pmus, pmu_maus = [], []
    for j in range(n_pmu):
        pmu = SRAM(name=f"pmu{j}", read_latency=1, write_latency=1,
                   address_ranges=((j * PMU_WINDOW, (j + 1) * PMU_WINDOW),),
                   port_width=lanes, max_concurrent_requests=2,
                   read_write_ports=n_pcu + 2)
        # DMA engine DRAM <-> PMU
        dex = ExecuteStage(name=f"pdma_ex{j}", latency=latency_t(1))
        dma = MemoryAccessUnit(name=f"pdma{j}", to_process={"t_load", "t_store"},
                               latency=latency_t(1))
        drf = RegisterFile(name=f"pdma_rf{j}", data_width=32 * lanes,
                           registers={f"dstage{j}.{i}": Data(32 * lanes, None)
                                      for i in range(4)})
        ACADLEdge(dex, dma, CONTAINS)
        ACADLEdge(dram0, dma, READ_DATA)
        ACADLEdge(dma, dram0, WRITE_DATA)
        ACADLEdge(pmu, dma, READ_DATA)
        ACADLEdge(dma, pmu, WRITE_DATA)
        ACADLEdge(drf, dma, READ_DATA)
        ACADLEdge(dma, drf, WRITE_DATA)
        ACADLEdge(ifs0, dex, FORWARD)
        pmus.append(pmu)
        pmu_maus.append(dma)

    pcus = []
    for i in range(n_pcu):
        ex = ExecuteStage(name=f"pcu_ex{i}", latency=latency_t(1))
        # SIMD pipeline: `words` elements at `lanes`/cycle after fill
        fu = FunctionalUnit(
            name=f"pcu_fu{i}",
            to_process={"map", "reduce", "matadd", "scan"},
            latency=latency_t(lambda operation="", words=lanes, **_:
                              pipeline_depth + max(1, words // lanes)),
        )
        rf = RegisterFile(name=f"pcu_rf{i}", data_width=32 * lanes,
                          registers={f"v{i}.{r}": Data(32 * lanes, None)
                                     for r in range(16)})
        # per-PCU scratchpad access unit (reads/writes any PMU)
        mex = ExecuteStage(name=f"pcu_mex{i}", latency=latency_t(1))
        mau = MemoryAccessUnit(name=f"pcu_mau{i}", to_process={"t_load", "t_store"},
                               latency=latency_t(1))
        ACADLEdge(ex, fu, CONTAINS)
        ACADLEdge(rf, fu, READ_DATA)
        ACADLEdge(fu, rf, WRITE_DATA)
        ACADLEdge(mex, mau, CONTAINS)
        ACADLEdge(rf, mau, READ_DATA)
        ACADLEdge(mau, rf, WRITE_DATA)
        for pmu in pmus:
            ACADLEdge(pmu, mau, READ_DATA)
            ACADLEdge(mau, pmu, WRITE_DATA)
        ACADLEdge(ifs0, ex, FORWARD)
        ACADLEdge(ifs0, mex, FORWARD)
        pcus.append({"ex": ex, "fu": fu, "rf": rf, "mau": mau})

    return {"pcus": pcus, "pmus": pmus, "pmu_maus": pmu_maus, "dram0": dram0,
            "simd_lanes": lanes, "n_pcu": n_pcu, "n_pmu": n_pmu}


def make_plasticine_ag(n_pcu: int = 4, n_pmu: int = 4, **params):
    handles = generate_plasticine(n_pcu, n_pmu, **params)
    ag = create_ag()
    return ag, handles
