"""Γ̈ [gœna] — General Operationally Extendable Neural Network Accelerator
(paper §4.3, Fig. 6/7, Listing 4).

Fused-tensor-operations-level model.  The architecture is composed of
``n_units`` templates, each containing a load/store unit (``lsu<k>``), a
compute unit (``cu<k>`` holding ``matMulFu<k>`` and ``matAddFu<k>``), a
vector register file (``vrf<k>``) and a scratchpad SRAM (``spm<k>``); a
shared DRAM data memory feeds all load/store units.  Scratchpads are shared
with the *adjacent* compute unit's load/store unit (ring topology), matching
"the scratchpad is an SRAM used to store partial results that can be shared
with adjacent compute units".

Instructions for different hardware components issue in parallel and execute
out-of-order — this emerges from the timing semantics (§6): the fetch stage
forwards multiple instructions per cycle and units serialize only on data
dependencies and structural hazards.

Beyond-paper extension (recorded in DESIGN.md): ``matAddFu`` additionally
processes ``scan`` (chunked SSM recurrence) and ``attn`` (fused attention
tile) so modern attention-free/hybrid workloads can be mapped; the paper
explicitly allows instructions that "carry out complex operations".
"""

from __future__ import annotations

from typing import Dict, List

from ..acadl import (
    ACADLEdge,
    CONTAINS,
    Data,
    DRAM,
    ExecuteStage,
    FORWARD,
    FunctionalUnit,
    InstructionFetchStage,
    InstructionMemoryAccessUnit,
    MemoryAccessUnit,
    READ_DATA,
    RegisterFile,
    SRAM,
    WRITE_DATA,
    connect_dangling_edge,
    create_ag,
    generate,
    latency_t,
)

__all__ = ["GammaComputeTemplate", "generate_gamma", "make_gamma_ag"]


class GammaComputeTemplate:
    """One dashed-line template of Fig. 6: load/store unit + compute unit +
    scratchpad, with the vector register file binding them."""

    def __init__(self, k: int, *, tile: int = 8, n_vregs: int = 32,
                 vreg_bits: int = 128, gemm_latency=None, lsu_latency: int = 1,
                 spm_kw: Dict | None = None):
        t = tile
        # MAC-array timing: an 8x8 fused gemm streams `tile` ranks through an
        # 8x8 MAC grid — macs / (tile*tile) cycles (+1 fill).
        if gemm_latency is None:
            gemm_latency = latency_t(
                lambda operation="", macs=t * t * t, **_: max(1, macs // (t * t) + 1))

        self.ex_lsu = ExecuteStage(name=f"ex_lsu{k}", latency=latency_t(1))
        self.lsu = MemoryAccessUnit(name=f"lsu{k}",
                                    to_process={"t_load", "t_store"},
                                    latency=latency_t(lsu_latency))
        ACADLEdge(self.ex_lsu, self.lsu, CONTAINS)

        self.cu = ExecuteStage(name=f"cu{k}", latency=latency_t(1))
        self.matMulFu = FunctionalUnit(name=f"matMulFu{k}",
                                       to_process={"gemm"},
                                       latency=gemm_latency)
        # VPU-style unit: elementwise + beyond-paper scan/attn fused ops
        self.matAddFu = FunctionalUnit(
            name=f"matAddFu{k}",
            to_process={"matadd", "scan", "attn"},
            latency=latency_t(lambda operation="", words=t * t, macs=0, **_:
                              max(1, words // t)),
        )
        ACADLEdge(self.cu, self.matMulFu, CONTAINS)
        ACADLEdge(self.cu, self.matAddFu, CONTAINS)

        regs = {f"vrf{k}.{i}": Data(vreg_bits, None) for i in range(n_vregs)}
        for special in ("a", "b", "acc"):
            regs[f"vrf{k}.{special}"] = Data(vreg_bits, None)
        self.vrf = RegisterFile(name=f"vrf{k}", data_width=vreg_bits,
                                registers=regs)

        ACADLEdge(self.vrf, self.matMulFu, READ_DATA)
        ACADLEdge(self.matMulFu, self.vrf, WRITE_DATA)
        ACADLEdge(self.vrf, self.matAddFu, READ_DATA)
        ACADLEdge(self.matAddFu, self.vrf, WRITE_DATA)
        # the load/store unit moves tiles between memories and vector registers
        ACADLEdge(self.vrf, self.lsu, READ_DATA)
        ACADLEdge(self.lsu, self.vrf, WRITE_DATA)

        # scratchpad: tile-granular addressing, one tile moves in
        # tile*tile/port words per beat
        self.spm = SRAM(name=f"spm{k}", read_latency=1, write_latency=1,
                        address_ranges=((0x3000 + k * 0x1000, 0x4000 + k * 0x1000),),
                        port_width=t * t, read_write_ports=4,
                        **(spm_kw or {}))
        ACADLEdge(self.spm, self.lsu, READ_DATA)
        ACADLEdge(self.lsu, self.spm, WRITE_DATA)


@generate
def generate_gamma(n_units: int = 2, *, tile: int = 8, n_vregs: int = 32,
                   port_width: int = 8, issue_buffer_size: int = 32,
                   dram_read_latency: int = 20, dram_write_latency: int = 20,
                   dram_port_width: int = 16) -> Dict[str, object]:
    """Instantiate the Γ̈ AG with ``n_units`` compute/scratchpad templates."""
    # fetch front-end (same structure as OMA)
    imem0 = SRAM(name="imem0", read_latency=1, write_latency=1,
                 address_ranges=((0, 1 << 22),), port_width=port_width)
    pcrf0 = RegisterFile(name="pcrf0", data_width=32,
                         registers={"pc": Data(32, 0)})
    ifs0 = InstructionFetchStage(name="ifs0", latency=latency_t(1),
                                 issue_buffer_size=issue_buffer_size)
    imau0 = InstructionMemoryAccessUnit(name="imau0", latency=latency_t(0))
    ACADLEdge(imem0, imau0, READ_DATA)
    ACADLEdge(pcrf0, imau0, READ_DATA)
    ACADLEdge(imau0, pcrf0, WRITE_DATA)
    ACADLEdge(ifs0, imau0, CONTAINS)

    dram0 = DRAM(name="dram0", read_latency=dram_read_latency,
                 write_latency=dram_write_latency,
                 address_ranges=((0, 0x3000), (0x3000 + n_units * 0x1000, 1 << 22)),
                 port_width=dram_port_width,
                 max_concurrent_requests=2,
                 read_write_ports=2 * max(1, n_units))

    units: List[GammaComputeTemplate] = []
    for k in range(n_units):
        u = GammaComputeTemplate(k, tile=tile, n_vregs=n_vregs)
        # DRAM data path
        ACADLEdge(dram0, u.lsu, READ_DATA)
        ACADLEdge(u.lsu, dram0, WRITE_DATA)
        # instruction routing
        ACADLEdge(ifs0, u.ex_lsu, FORWARD)
        ACADLEdge(ifs0, u.cu, FORWARD)
        units.append(u)

    # adjacent scratchpad sharing (ring): lsu k can also access spm (k+1)%n
    if n_units > 1:
        for k, u in enumerate(units):
            nbr = units[(k + 1) % n_units]
            ACADLEdge(nbr.spm, u.lsu, READ_DATA)
            ACADLEdge(u.lsu, nbr.spm, WRITE_DATA)

    return {"imem0": imem0, "ifs0": ifs0, "dram0": dram0, "units": units,
            "tile": tile}


def make_gamma_ag(n_units: int = 2, **params):
    handles = generate_gamma(n_units, **params)
    ag = create_ag()
    return ag, handles
