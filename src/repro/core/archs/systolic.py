"""Parameterizable systolic array — paper §4.2, Fig. 4/5, Listings 2/3.

A rows×columns grid of processing elements (PEs).  Data is passed only
vertically down and horizontally right; load units feed the first row and
column, store units drain results.  Templates (Python classes instantiating
ACADL objects + dangling edges) build the AG exactly as the paper describes:
``ProcessingElement`` mirrors Listing 2, the array generator mirrors
Listing 3, load/store/fetch unit templates complete the architecture.

Dataflow implemented by the operator mapping (`repro.core.mapping.systolic`):
output-stationary GeMM — activations stream right, weights stream down,
accumulators stay in the PE, then results drain right through the ``a``
channel to the store units on the last column.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from ..acadl import (
    ACADLEdge,
    CONTAINS,
    DanglingEdge,
    Data,
    DRAM,
    ExecuteStage,
    FORWARD,
    FunctionalUnit,
    InstructionFetchStage,
    InstructionMemoryAccessUnit,
    MemoryAccessUnit,
    READ_DATA,
    RegisterFile,
    SRAM,
    WRITE_DATA,
    connect_dangling_edge,
    create_ag,
    generate,
    latency_t,
)

__all__ = ["ProcessingElement", "LoadUnit", "StoreUnit", "FetchUnit",
           "generate_systolic", "make_systolic_ag"]


class ProcessingElement:
    """PE template (paper Listing 2): ExecuteStage + FunctionalUnit +
    RegisterFile plus dangling edges as the template interface."""

    def __init__(self, regs: int, row: int, col: int, mac_latency: int = 1):
        # acadl objects
        self.ex = ExecuteStage(name=f"ex[{row}][{col}]", latency=latency_t(1))
        self.fu = FunctionalUnit(
            name=f"fu[{row}][{col}]",
            to_process={"mac_fwd", "drain"},
            latency=latency_t(mac_latency),
        )
        regdict = {f"a[{row}][{col}]": Data(32, 0),
                   f"b[{row}][{col}]": Data(32, 0),
                   f"acc[{row}][{col}]": Data(32, 0)}
        for i in range(max(0, regs - 3)):
            regdict[f"r{i}[{row}][{col}]"] = Data(32, 0)
        self.rf = RegisterFile(name=f"rf[{row}][{col}]", data_width=32,
                               registers=regdict)

        # edges
        ACADLEdge(self.ex, self.fu, CONTAINS)
        ACADLEdge(self.rf, self.fu, READ_DATA)
        ACADLEdge(self.fu, self.rf, WRITE_DATA)

        # dangling edges (template interface, paper Listing 2)
        self.ex_ingoing_forward = DanglingEdge(edge_type=FORWARD, target=self.ex)
        self.rf_ingoing_write = DanglingEdge(edge_type=WRITE_DATA, target=self.rf)
        self.rf_outgoing_read = DanglingEdge(edge_type=READ_DATA, source=self.rf)
        self.fu_outgoing_write = DanglingEdge(edge_type=WRITE_DATA, source=self.fu)


class LoadUnit:
    """Load unit template: ExecuteStage + MemoryAccessUnit supporting
    ``load``; writes into the first-row/column PE register files."""

    def __init__(self, name: str, latency: int = 1):
        self.ex = ExecuteStage(name=f"ex_{name}", latency=latency_t(1))
        self.mau = MemoryAccessUnit(name=f"mau_{name}", to_process={"load"},
                                    latency=latency_t(latency))
        ACADLEdge(self.ex, self.mau, CONTAINS)
        self.mem_read = DanglingEdge(edge_type=READ_DATA, target=self.mau)
        self.rf_write = DanglingEdge(edge_type=WRITE_DATA, source=self.mau)
        self.ingoing_forward = DanglingEdge(edge_type=FORWARD, target=self.ex)


class StoreUnit:
    """Store unit template: ExecuteStage + MemoryAccessUnit supporting
    ``store``; reads the last-column PE register files + its own out reg."""

    def __init__(self, name: str, latency: int = 1):
        self.ex = ExecuteStage(name=f"ex_{name}", latency=latency_t(1))
        self.mau = MemoryAccessUnit(name=f"mau_{name}", to_process={"store"},
                                    latency=latency_t(latency))
        self.rf = RegisterFile(name=f"rf_{name}", data_width=32,
                               registers={f"out_{name}": Data(32, 0)})
        ACADLEdge(self.ex, self.mau, CONTAINS)
        ACADLEdge(self.rf, self.mau, READ_DATA)
        self.rf_ingoing_write = DanglingEdge(edge_type=WRITE_DATA, target=self.rf)
        self.mem_write = DanglingEdge(edge_type=WRITE_DATA, source=self.mau)
        self.ingoing_forward = DanglingEdge(edge_type=FORWARD, target=self.ex)


class FetchUnit:
    """Fetch unit template: same objects/edges as the OMA front-end."""

    def __init__(self, port_width: int, issue_buffer_size: int):
        self.imem = SRAM(name="imem0", read_latency=1, write_latency=1,
                         address_ranges=((0, 1 << 22),), port_width=port_width)
        self.pcrf = RegisterFile(name="pcrf0", data_width=32,
                                 registers={"pc": Data(32, 0)})
        self.ifs = InstructionFetchStage(name="ifs0", latency=latency_t(1),
                                         issue_buffer_size=issue_buffer_size)
        self.imau = InstructionMemoryAccessUnit(name="imau0", latency=latency_t(0))
        ACADLEdge(self.imem, self.imau, READ_DATA)
        ACADLEdge(self.pcrf, self.imau, READ_DATA)
        ACADLEdge(self.imau, self.pcrf, WRITE_DATA)
        ACADLEdge(self.ifs, self.imau, CONTAINS)


@generate
def generate_systolic(rows: int, columns: int, *, mac_latency: int = 1,
                      load_latency: int = 1, store_latency: int = 1,
                      dram_read_latency: int = 4, dram_write_latency: int = 4,
                      port_width: Optional[int] = None,
                      issue_buffer_size: Optional[int] = None,
                      dram_kw: Optional[dict] = None) -> Dict[str, object]:
    """Instantiate the parameterizable systolic array (paper Listing 3)."""
    pw = port_width if port_width is not None else max(4, rows * columns)
    ibs = issue_buffer_size if issue_buffer_size is not None else 4 * pw

    fetch = FetchUnit(pw, ibs)
    # one port per connected MemoryAccessUnit: row loaders + column loaders
    # + row store units all touch the DRAM (paper Fig. 4)
    dram = DRAM(name="dram0", read_latency=dram_read_latency,
                write_latency=dram_write_latency,
                address_ranges=((0, 1 << 22),),
                max_concurrent_requests=max(1, (rows + columns) // 2),
                read_write_ports=2 * rows + columns,
                **(dram_kw or {}))

    # instantiate array that holds all PEs (paper Listing 3)
    pes: List[List[Optional[ProcessingElement]]] = [
        [None] * columns for _ in range(rows)
    ]
    for row in range(rows):
        for col in range(columns):
            pes[row][col] = ProcessingElement(regs=4, row=row, col=col,
                                              mac_latency=mac_latency)
            # vertical: top neighbour's fu writes this PE's rf (b flows down)
            if row > 0:
                connect_dangling_edge(
                    pes[row - 1][col].fu_outgoing_write,
                    pes[row][col].rf_ingoing_write,
                )
            # horizontal: left neighbour's fu writes this PE's rf (a flows right)
            if col > 0:
                connect_dangling_edge(
                    pes[row][col - 1].fu_outgoing_write,
                    pes[row][col].rf_ingoing_write,
                )
            # every PE stage is reachable from the fetch stage
            connect_dangling_edge(fetch.ifs, pes[row][col].ex_ingoing_forward)

    # load units: one per row (A stream) and one per column (B stream)
    row_loaders, col_loaders = [], []
    for row in range(rows):
        lu = LoadUnit(f"lu_row{row}", load_latency)
        connect_dangling_edge(lu.mem_read, dram)
        connect_dangling_edge(lu.rf_write, pes[row][0].rf)
        connect_dangling_edge(fetch.ifs, lu.ingoing_forward)
        row_loaders.append(lu)
    for col in range(columns):
        lu = LoadUnit(f"lu_col{col}", load_latency)
        connect_dangling_edge(lu.mem_read, dram)
        connect_dangling_edge(lu.rf_write, pes[0][col].rf)
        connect_dangling_edge(fetch.ifs, lu.ingoing_forward)
        col_loaders.append(lu)

    # store units: one per row, fed by the last column's PE through the
    # a-channel (drain dataflow); the PE fu writes the store unit's rf
    store_units = []
    for row in range(rows):
        su = StoreUnit(f"su_row{row}", store_latency)
        connect_dangling_edge(pes[row][columns - 1].fu_outgoing_write,
                              su.rf_ingoing_write)
        connect_dangling_edge(su.mem_write, dram)
        connect_dangling_edge(fetch.ifs, su.ingoing_forward)
        store_units.append(su)

    return {"pes": pes, "fetch": fetch, "dram": dram,
            "row_loaders": row_loaders, "col_loaders": col_loaders,
            "store_units": store_units, "rows": rows, "columns": columns}


def make_systolic_ag(rows: int, columns: int, **params):
    handles = generate_systolic(rows, columns, **params)
    ag = create_ag()
    return ag, handles
