"""One MAC Accelerator (OMA) — paper §4.1, Fig. 2/3, Listing 1.

Scalar-operations-level model: one data memory behind a data cache, one
register file, an execution stage holding the ALU (``fu0``) and the memory
access unit (``mau0``), and a fetch front-end (``ifs0`` containing ``imau0``
reading ``imem0`` and the pc register file ``pcrf0``).
"""

from __future__ import annotations

from typing import Dict

from ..acadl import (
    ACADLEdge,
    CONTAINS,
    Data,
    ExecuteStage,
    FORWARD,
    FunctionalUnit,
    InstructionFetchStage,
    InstructionMemoryAccessUnit,
    MemoryAccessUnit,
    PipelineStage,
    READ_DATA,
    RegisterFile,
    SetAssociativeCache,
    SRAM,
    WRITE_DATA,
    create_ag,
    generate,
    latency_t,
)

__all__ = ["generate_oma", "make_oma_ag", "OMA_SCALAR_OPS"]

OMA_SCALAR_OPS = {
    "mov", "addi", "add", "sub", "muli", "mac", "beqi", "bnei", "jumpi", "halt",
}


@generate
def generate_oma(*, n_registers: int = 16, data_width: int = 32,
                 imem_port_width: int = 1, issue_buffer_size: int = 4,
                 fu_latency: int = 1, mac_latency: int = 1,
                 mau_latency: int = 1, dmem_read_latency: int = 10,
                 dmem_write_latency: int = 10, cache_sets: int = 64,
                 cache_ways: int = 4, cache_hit_latency: int = 1,
                 cache_miss_latency: int = 12, cache_line_size: int = 8,
                 dmem_size: int = 1 << 20) -> Dict[str, object]:
    """Instantiate the OMA architecture graph (paper Listing 1)."""

    # instruction fetch front-end
    imem0 = SRAM(name="imem0", read_latency=1, write_latency=1,
                 address_ranges=((0, 1 << 20),), port_width=imem_port_width)
    pcrf0 = RegisterFile(name="pcrf0", data_width=32,
                         registers={"pc": Data(32, 0)})
    ifs0 = InstructionFetchStage(name="ifs0", latency=latency_t(1),
                                 issue_buffer_size=issue_buffer_size)
    imau0 = InstructionMemoryAccessUnit(name="imau0", latency=latency_t(0))

    # instruction processing
    ds0 = PipelineStage(name="ds0", latency=latency_t(1))
    ex0 = ExecuteStage(name="ex0", latency=latency_t(1))
    fu0 = FunctionalUnit(
        name="fu0",
        to_process=OMA_SCALAR_OPS - {"mac"},
        latency=latency_t(fu_latency),
    )
    # the built-in MAC gets its own latency knob via a dedicated unit entry;
    # paper models a single ALU — we keep one unit but allow a distinct MAC
    # latency through a latency function
    fu0.to_process.add("mac")
    if mac_latency != fu_latency:
        base, mac_l = fu_latency, mac_latency
        fu0.latency = latency_t(lambda operation="", **_: mac_l if operation == "mac" else base)

    mau0 = MemoryAccessUnit(name="mau0", to_process={"load", "store"},
                            latency=latency_t(mau_latency))
    regs = {f"r{i}": Data(data_width, 0) for i in range(n_registers)}
    regs["z0"] = Data(data_width, 0)      # zero register (paper Listing 5)
    regs["acc"] = Data(data_width, 0)
    rf0 = RegisterFile(name="rf0", data_width=data_width, registers=regs)
    dmem0 = SRAM(name="dmem0", read_latency=dmem_read_latency,
                 write_latency=dmem_write_latency,
                 address_ranges=((0, dmem_size),))
    dcache0 = SetAssociativeCache(
        name="dcache0", sets=cache_sets, ways=cache_ways,
        hit_latency=cache_hit_latency, miss_latency=cache_miss_latency,
        cache_line_size=cache_line_size,
    )

    # edges (paper Listing 1, lines 35-51)
    ACADLEdge(imem0, imau0, READ_DATA)
    ACADLEdge(pcrf0, imau0, READ_DATA)
    ACADLEdge(imau0, pcrf0, WRITE_DATA)
    ACADLEdge(ifs0, imau0, CONTAINS)
    ACADLEdge(ifs0, ds0, FORWARD)
    ACADLEdge(ds0, ex0, FORWARD)
    ACADLEdge(ex0, fu0, CONTAINS)
    ACADLEdge(fu0, rf0, WRITE_DATA)
    ACADLEdge(rf0, fu0, READ_DATA)
    ACADLEdge(ex0, mau0, CONTAINS)
    ACADLEdge(mau0, rf0, WRITE_DATA)
    ACADLEdge(rf0, mau0, READ_DATA)
    ACADLEdge(mau0, dcache0, WRITE_DATA)
    ACADLEdge(dcache0, mau0, READ_DATA)
    ACADLEdge(dcache0, dmem0, WRITE_DATA)
    ACADLEdge(dmem0, dcache0, READ_DATA)

    return {"imem0": imem0, "pcrf0": pcrf0, "ifs0": ifs0, "imau0": imau0,
            "ds0": ds0, "ex0": ex0, "fu0": fu0, "mau0": mau0, "rf0": rf0,
            "dmem0": dmem0, "dcache0": dcache0}


def make_oma_ag(**params):
    """Generate + create the OMA AG in one call."""
    handles = generate_oma(**params)
    ag = create_ag()
    return ag, handles
