"""TPU-v5e-like accelerator model (fused-tensor abstraction level).

This is the ACADL model of the framework's *target hardware* — the same
constants used by the roofline analysis (197 TFLOP/s bf16, 819 GB/s HBM):

* ``mxu0``    — systolic matrix unit: ``gemm`` tiles, ``macs_per_cycle`` =
  n_mxu * 128 * 128 MACs/cycle (197e12 / 2 / 1.5e9 ≈ 65k MACs/cycle ->
  4 MXUs at 1.5 GHz).
* ``vpu0``    — vector unit: elementwise/``matadd``/``scan``/``attn``
  softmax-side work at 8*128 lanes/cycle.
* ``vmem0``   — on-chip vector memory (SRAM scratchpad), tile-granular
  addresses, very wide port.
* ``hbm0``    — HBM (DRAM timing): 819 GB/s at 1.5 GHz = 546 B/cycle =
  273 bf16 words/cycle -> port_width 256.
* ``dma0``    — async copy engine HBM <-> VMEM (the Pallas ``pltpu.emit``
  analogue); ``lsu0`` moves VMEM tiles into vector registers.

One AG = one TPU core.  Multi-chip parallelism is the JAX layer's job
(pjit/shard_map over the production mesh); ACADL models the per-chip timing
that the roofline terms summarize.  ``repro.core.mapping.workload`` maps a
model config's per-layer operator stream onto this AG at one-instruction-
per-fused-op granularity, and the AIDG estimator returns cycles -> seconds
via ``clock_ghz``.
"""

from __future__ import annotations

from typing import Dict, Optional

from ..acadl import (
    ACADLEdge,
    CONTAINS,
    Data,
    DRAM,
    ExecuteStage,
    FORWARD,
    FunctionalUnit,
    InstructionFetchStage,
    InstructionMemoryAccessUnit,
    MemoryAccessUnit,
    READ_DATA,
    RegisterFile,
    SRAM,
    WRITE_DATA,
    create_ag,
    generate,
    latency_t,
)

__all__ = ["generate_tpu_v5e", "make_tpu_v5e_ag", "TPU_V5E"]

# hardware constants shared with repro.launch.roofline
TPU_V5E = {
    "clock_ghz": 1.5,
    "peak_bf16_flops": 197e12,
    "hbm_bytes_per_s": 819e9,
    "ici_bytes_per_s_per_link": 50e9,
    "n_mxu": 4,
    "mxu_dim": 128,
    "vpu_lanes": 8 * 128,
    "vmem_bytes": 128 * 1024 * 1024,
    "hbm_bytes": 16 * 1024 * 1024 * 1024,
}

VMEM_WINDOW = 1 << 24   # tile-granular VMEM addresses below, HBM above


@generate
def generate_tpu_v5e(*, n_mxu: int = 4, mxu_dim: int = 128,
                     vpu_lanes: int = 1024, hbm_port_words: int = 256,
                     vmem_port_words: int = 4096,
                     issue_buffer_size: int = 128,
                     port_width: int = 16,
                     dma_concurrency: int = 8,
                     n_vregs: int = 64) -> Dict[str, object]:
    imem0 = SRAM(name="imem0", read_latency=1, write_latency=1,
                 address_ranges=((0, 1 << 22),), port_width=port_width)
    pcrf0 = RegisterFile(name="pcrf0", data_width=32,
                         registers={"pc": Data(32, 0)})
    ifs0 = InstructionFetchStage(name="ifs0", latency=latency_t(1),
                                 issue_buffer_size=issue_buffer_size)
    imau0 = InstructionMemoryAccessUnit(name="imau0", latency=latency_t(0))
    ACADLEdge(imem0, imau0, READ_DATA)
    ACADLEdge(pcrf0, imau0, READ_DATA)
    ACADLEdge(imau0, pcrf0, WRITE_DATA)
    ACADLEdge(ifs0, imau0, CONTAINS)

    # memories: bf16 words (data_width 16)
    hbm0 = DRAM(name="hbm0", read_latency=100, write_latency=100,
                data_width=16, port_width=hbm_port_words,
                address_ranges=((VMEM_WINDOW, 1 << 40),),
                t_RCD=20, t_RP=20, row_size=1 << 14,
                max_concurrent_requests=dma_concurrency,
                read_write_ports=2)
    vmem0 = SRAM(name="vmem0", read_latency=2, write_latency=2,
                 data_width=16, port_width=vmem_port_words,
                 address_ranges=((0, VMEM_WINDOW),),
                 max_concurrent_requests=4, read_write_ports=4)

    # async copy engine HBM <-> VMEM
    dma_ex = ExecuteStage(name="dma_ex0", latency=latency_t(1))
    dma0 = MemoryAccessUnit(name="dma0", to_process={"t_load", "t_store"},
                            latency=latency_t(1))
    dma_rf = RegisterFile(name="dma_rf0", data_width=16 * 4096,
                          registers={f"dstage.{i}": Data(16 * 4096, None)
                                     for i in range(dma_concurrency)})
    ACADLEdge(dma_ex, dma0, CONTAINS)
    ACADLEdge(hbm0, dma0, READ_DATA)
    ACADLEdge(dma0, hbm0, WRITE_DATA)
    ACADLEdge(vmem0, dma0, READ_DATA)
    ACADLEdge(dma0, vmem0, WRITE_DATA)
    ACADLEdge(dma_rf, dma0, READ_DATA)
    ACADLEdge(dma0, dma_rf, WRITE_DATA)
    ACADLEdge(ifs0, dma_ex, FORWARD)

    # vector registers + VMEM load/store unit
    vregs = {f"v.{i}": Data(16 * 8 * 128, None) for i in range(n_vregs)}
    for sp in ("a", "b", "acc", "q", "k", "vv", "s"):
        vregs[f"v.{sp}"] = Data(16 * 8 * 128, None)
    vrf0 = RegisterFile(name="vrf0", data_width=16 * 8 * 128, registers=vregs)
    lsu_ex = ExecuteStage(name="lsu_ex0", latency=latency_t(1))
    lsu0 = MemoryAccessUnit(name="lsu0", to_process={"t_load", "t_store"},
                            latency=latency_t(1))
    ACADLEdge(lsu_ex, lsu0, CONTAINS)
    ACADLEdge(vmem0, lsu0, READ_DATA)
    ACADLEdge(lsu0, vmem0, WRITE_DATA)
    ACADLEdge(vrf0, lsu0, READ_DATA)
    ACADLEdge(lsu0, vrf0, WRITE_DATA)
    ACADLEdge(ifs0, lsu_ex, FORWARD)

    # MXU: gemm tiles at macs_per_cycle throughput (+ pipeline fill)
    macs_per_cycle = n_mxu * mxu_dim * mxu_dim
    mxu_ex = ExecuteStage(name="mxu_ex0", latency=latency_t(1))
    mxu0 = FunctionalUnit(
        name="mxu0", to_process={"gemm"},
        latency=latency_t(lambda operation="", macs=macs_per_cycle, **_:
                          mxu_dim + max(1, macs // macs_per_cycle)),
    )
    ACADLEdge(mxu_ex, mxu0, CONTAINS)
    ACADLEdge(vrf0, mxu0, READ_DATA)
    ACADLEdge(mxu0, vrf0, WRITE_DATA)
    ACADLEdge(ifs0, mxu_ex, FORWARD)

    # VPU: elementwise / softmax-side / scan at vpu_lanes words/cycle
    vpu_ex = ExecuteStage(name="vpu_ex0", latency=latency_t(1))
    vpu0 = FunctionalUnit(
        name="vpu0", to_process={"matadd", "scan", "attn"},
        latency=latency_t(lambda operation="", words=vpu_lanes, macs=0, **_:
                          8 + max(1, words // vpu_lanes)),
    )
    ACADLEdge(vpu_ex, vpu0, CONTAINS)
    ACADLEdge(vrf0, vpu0, READ_DATA)
    ACADLEdge(vpu0, vrf0, WRITE_DATA)
    ACADLEdge(ifs0, vpu_ex, FORWARD)

    return {"imem0": imem0, "ifs0": ifs0, "hbm0": hbm0, "vmem0": vmem0,
            "dma0": dma0, "lsu0": lsu0, "mxu0": mxu0, "vpu0": vpu0,
            "vrf0": vrf0, "macs_per_cycle": macs_per_cycle}


def make_tpu_v5e_ag(**params):
    handles = generate_tpu_v5e(**params)
    ag = create_ag()
    return ag, handles
