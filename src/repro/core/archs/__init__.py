"""Accelerator zoo: the paper's worked examples (OMA §4.1, systolic array
§4.2, Γ̈ §4.3) plus the Eyeriss- and Plasticine-derived models referenced in
§6 and the TPU-v5e-like model of this framework's target hardware."""

from .oma import generate_oma, make_oma_ag, OMA_SCALAR_OPS
from .systolic import (
    FetchUnit,
    LoadUnit,
    ProcessingElement,
    StoreUnit,
    generate_systolic,
    make_systolic_ag,
)
from .gamma import GammaComputeTemplate, generate_gamma, make_gamma_ag
from .eyeriss import EyerissPE, generate_eyeriss, make_eyeriss_ag
from .plasticine import generate_plasticine, make_plasticine_ag
from .tpu_v5e import TPU_V5E, generate_tpu_v5e, make_tpu_v5e_ag

# name -> AG factory, the uniform handle the DSE scenario matrix
# (repro.core.aidg.explorer) iterates over.  Factories take their
# arch-specific sizing kwargs and return (ArchitectureGraph, handles).
ARCH_REGISTRY = {
    "oma": make_oma_ag,
    "systolic": make_systolic_ag,
    "gamma": make_gamma_ag,
    "eyeriss": make_eyeriss_ag,
    "plasticine": make_plasticine_ag,
    "tpu_v5e": make_tpu_v5e_ag,
}

__all__ = [
    "generate_oma", "make_oma_ag", "OMA_SCALAR_OPS",
    "ProcessingElement", "LoadUnit", "StoreUnit", "FetchUnit",
    "generate_systolic", "make_systolic_ag",
    "GammaComputeTemplate", "generate_gamma", "make_gamma_ag",
    "EyerissPE", "generate_eyeriss", "make_eyeriss_ag",
    "generate_plasticine", "make_plasticine_ag",
    "TPU_V5E", "generate_tpu_v5e", "make_tpu_v5e_ag",
    "ARCH_REGISTRY",
]
