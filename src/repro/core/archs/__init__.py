"""Accelerator zoo: the paper's worked examples (OMA §4.1, systolic array
§4.2, Γ̈ §4.3) plus the Eyeriss- and Plasticine-derived models referenced in
§6 and the TPU-v5e-like model of this framework's target hardware."""

from .oma import generate_oma, make_oma_ag, OMA_SCALAR_OPS
from .systolic import (
    FetchUnit,
    LoadUnit,
    ProcessingElement,
    StoreUnit,
    generate_systolic,
    make_systolic_ag,
)
from .gamma import GammaComputeTemplate, generate_gamma, make_gamma_ag
from .eyeriss import EyerissPE, generate_eyeriss, make_eyeriss_ag
from .plasticine import generate_plasticine, make_plasticine_ag
from .tpu_v5e import TPU_V5E, generate_tpu_v5e, make_tpu_v5e_ag
from .energy import (ARCH_TECH_NM, ENERGY_REGISTRY, EnergyModel,
                     TECH_TABLES, energy_model)

# name -> AG factory, the uniform handle the DSE scenario matrix
# (repro.core.aidg.explorer) iterates over.  Factories take their
# arch-specific sizing kwargs and return (ArchitectureGraph, handles).
ARCH_REGISTRY = {
    "oma": make_oma_ag,
    "systolic": make_systolic_ag,
    "gamma": make_gamma_ag,
    "eyeriss": make_eyeriss_ag,
    "plasticine": make_plasticine_ag,
    "tpu_v5e": make_tpu_v5e_ag,
}

# On-chip double-buffer capacity per architecture, in data words: the
# storage a pipelined network schedule (repro.core.network) can stage the
# NEXT layer's stationary operand into while the current layer computes.
# Derived from each model: OMA's scalar data cache, one systolic-array
# worth of PE registers plus stream buffers, the Γ̈ scratchpad, the
# Eyeriss GLB (108 KB class), the aggregate Plasticine PMU capacity, and
# the TPU-v5e VMEM (128 MiB of bf16 words).  Coarse by construction — the
# capacity gate only decides whether inter-layer overlap is credited.
ARCH_CAPACITY_WORDS = {
    "oma": 4 * 1024,
    "systolic": 16 * 1024,
    "gamma": 64 * 1024,
    "eyeriss": 54 * 1024,
    "plasticine": 256 * 1024,
    "tpu_v5e": TPU_V5E["vmem_bytes"] // 2,
}

__all__ = [
    "generate_oma", "make_oma_ag", "OMA_SCALAR_OPS",
    "ProcessingElement", "LoadUnit", "StoreUnit", "FetchUnit",
    "generate_systolic", "make_systolic_ag",
    "GammaComputeTemplate", "generate_gamma", "make_gamma_ag",
    "EyerissPE", "generate_eyeriss", "make_eyeriss_ag",
    "generate_plasticine", "make_plasticine_ag",
    "TPU_V5E", "generate_tpu_v5e", "make_tpu_v5e_ag",
    "ARCH_REGISTRY", "ARCH_CAPACITY_WORDS",
    "EnergyModel", "ENERGY_REGISTRY", "ARCH_TECH_NM", "TECH_TABLES",
    "energy_model",
]
