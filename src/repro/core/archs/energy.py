"""Per-op-class energy and power coefficients for every architecture in
:data:`ARCH_REGISTRY`.

Lumos-style defaults: a small per-tech-node table of dynamic energy per
operation (by coarse op category) and per word moved (by storage class),
plus a static leakage term per cycle.  The absolute numbers are
literature ballparks (Horowitz ISSCC'14 for the 45 nm anchors, scaled by
node following the usual capacitance trend) — the point is *relative*
fidelity across op classes and memory levels, which is what the DSE
objective and the ZigZag-style bottleneck report consume.

Two classifiers map the repo's own names onto table categories:

- op classes (``AIDG.classes`` entries like ``gemm@pe`` / ``t_load@mem``)
  -> ``mac`` / ``vector`` / ``mem`` / ``ctrl``;
- storage-node names (``spm`` / ``dram_port`` / ``glb`` ...)
  -> ``reg`` / ``onchip`` / ``dram``.

Both reuse the same name conventions as ``explorer.DEFAULT_SPACE``, so a
unit that the DSE scales with the ``matrix`` knob draws ``mac`` energy.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Mapping, Tuple

__all__ = [
    "TECH_TABLES", "ARCH_TECH_NM", "EnergyModel", "ENERGY_REGISTRY",
    "energy_model", "OP_CATEGORIES", "STORAGE_CLASSES",
]

OP_CATEGORIES: Tuple[str, ...] = ("mac", "vector", "mem", "ctrl")
STORAGE_CLASSES: Tuple[str, ...] = ("reg", "onchip", "dram")

# tech node (nm) -> {"op": pJ per issued operation by category,
#                    "word": pJ per word moved by storage class,
#                    "static": pJ leaked per cycle}
TECH_TABLES: Dict[int, Dict[str, object]] = {
    65: {"op": {"mac": 6.0, "vector": 2.4, "mem": 1.2, "ctrl": 0.6},
         "word": {"reg": 0.12, "onchip": 12.0, "dram": 900.0},
         "static": 40.0},
    45: {"op": {"mac": 4.0, "vector": 1.6, "mem": 0.8, "ctrl": 0.4},
         "word": {"reg": 0.08, "onchip": 8.0, "dram": 650.0},
         "static": 25.0},
    28: {"op": {"mac": 2.2, "vector": 0.9, "mem": 0.45, "ctrl": 0.22},
         "word": {"reg": 0.05, "onchip": 4.5, "dram": 420.0},
         "static": 14.0},
    22: {"op": {"mac": 1.7, "vector": 0.7, "mem": 0.35, "ctrl": 0.17},
         "word": {"reg": 0.04, "onchip": 3.4, "dram": 350.0},
         "static": 10.0},
    7: {"op": {"mac": 0.45, "vector": 0.18, "mem": 0.09, "ctrl": 0.05},
        "word": {"reg": 0.01, "onchip": 1.0, "dram": 120.0},
        "static": 3.0},
}

# Assumed implementation node per zoo architecture (publication-era
# silicon: Eyeriss 65 nm chip, OMA-class MCU 45 nm, Plasticine 28 nm,
# systolic-array exemplar 28 nm, Γ̈ 22 nm study, TPU v5e ~7 nm).
ARCH_TECH_NM: Dict[str, int] = {
    "oma": 45,
    "systolic": 28,
    "gamma": 22,
    "eyeriss": 65,
    "plasticine": 28,
    "tpu_v5e": 7,
}

_DEFAULT_NM = 45

# op-class-name -> category (first match wins; default "ctrl").  The
# patterns mirror the FU-class conventions used across the zoo and in
# ``explorer.DEFAULT_SPACE``.
_OP_PATTERNS: Tuple[Tuple[str, "re.Pattern"], ...] = (
    ("mac", re.compile(r"gemm@|^mac|row_conv@")),
    ("vector", re.compile(r"attn@|scan@|matadd@|map@|reduce@|psum_add")),
    ("mem", re.compile(r"t_load@|t_store@|^load@|^store@|drain@")),
)

# storage-node-name -> class (first match wins; default "reg").
_STORAGE_PATTERNS: Tuple[Tuple[str, "re.Pattern"], ...] = (
    ("dram", re.compile(r"dram|hbm")),
    ("onchip", re.compile(r"spm|glb|pmu|vmem|sram|imem|cache")),
)


@dataclass(frozen=True)
class EnergyModel:
    """Energy/power coefficients of one architecture.

    ``op_table`` is pJ per issued operation by op category, ``word_table``
    pJ per word moved by storage class, ``static_pj`` leakage pJ per
    cycle.  ``op_pj`` / ``word_pj`` classify repo-native names (op-class
    strings, storage-node names) and look the category up.
    """

    name: str
    tech_nm: int
    op_table: Mapping[str, float] = field(repr=False)
    word_table: Mapping[str, float] = field(repr=False)
    static_pj: float = 0.0

    @staticmethod
    def op_category(op_class_name: str) -> str:
        for cat, pat in _OP_PATTERNS:
            if pat.search(op_class_name):
                return cat
        return "ctrl"

    @staticmethod
    def storage_class(storage_name: str) -> str:
        for cls, pat in _STORAGE_PATTERNS:
            if pat.search(storage_name):
                return cls
        return "reg"

    def op_pj(self, op_class_name: str) -> float:
        """Dynamic pJ per issued instruction of this op class (classified
        by name via :meth:`op_category`)."""
        return float(self.op_table[self.op_category(op_class_name)])

    def word_pj(self, storage_name: str) -> float:
        """Access pJ per word moved through this storage node (classified
        into reg/onchip/dram via :meth:`storage_class`)."""
        return float(self.word_table[self.storage_class(storage_name)])


def _model(name: str, nm: int) -> EnergyModel:
    t = TECH_TABLES[nm]
    return EnergyModel(name=name, tech_nm=nm,
                       op_table=dict(t["op"]), word_table=dict(t["word"]),
                       static_pj=float(t["static"]))


ENERGY_REGISTRY: Dict[str, EnergyModel] = {
    arch: _model(arch, nm) for arch, nm in ARCH_TECH_NM.items()
}


def energy_model(arch: str) -> EnergyModel:
    """The :class:`EnergyModel` of ``arch`` (default node for unknowns)."""
    got = ENERGY_REGISTRY.get(arch)
    if got is None:
        got = _model(arch, _DEFAULT_NM)
    return got
