"""Eyeriss-v1-derived accelerator model (paper §6 references [26]).

Row-stationary CNN accelerator modeled at the *tensor* abstraction level:
each PE processes 1-D convolution rows (``row_conv``) and partial-sum
accumulation (``psum_add``); a global buffer (GLB) SRAM sits between the DRAM
and the PE array; per-row load units multicast filter/ifmap rows into PE
register files, per-row store units drain psums back to the GLB.

The grid is ``rows × columns`` (Eyeriss v1: 12 × 14).  Row-stationary
dataflow: filter rows stay in a PE, ifmap rows slide diagonally, psums move
vertically — here the *dependency structure* of the emitted instruction
stream encodes the dataflow; the timing simulation extracts the parallelism.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from ..acadl import (
    ACADLEdge,
    CONTAINS,
    Data,
    DRAM,
    ExecuteStage,
    FORWARD,
    FunctionalUnit,
    InstructionFetchStage,
    InstructionMemoryAccessUnit,
    MemoryAccessUnit,
    READ_DATA,
    RegisterFile,
    SRAM,
    WRITE_DATA,
    create_ag,
    generate,
    latency_t,
)

__all__ = ["EyerissPE", "generate_eyeriss", "make_eyeriss_ag"]


class EyerissPE:
    """PE template: spad register file + MAC pipeline processing whole rows.

    ``row_conv`` latency = output-row taps (macs tag); matches Eyeriss's
    one-MAC-per-cycle PE with operand spads.
    """

    def __init__(self, row: int, col: int):
        self.ex = ExecuteStage(name=f"eex[{row}][{col}]", latency=latency_t(1))
        self.fu = FunctionalUnit(
            name=f"efu[{row}][{col}]",
            to_process={"row_conv", "psum_add"},
            latency=latency_t(lambda operation="", macs=1, words=1, **_: max(1, macs)),
        )
        regs = {f"w[{row}][{col}]": Data(512, None),     # filter row (stationary)
                f"ifm[{row}][{col}]": Data(512, None),   # ifmap row (sliding)
                f"ps[{row}][{col}]": Data(512, None)}    # psum row
        self.rf = RegisterFile(name=f"erf[{row}][{col}]", data_width=512,
                               registers=regs)
        ACADLEdge(self.ex, self.fu, CONTAINS)
        ACADLEdge(self.rf, self.fu, READ_DATA)
        ACADLEdge(self.fu, self.rf, WRITE_DATA)


@generate
def generate_eyeriss(rows: int = 12, columns: int = 14, *,
                     glb_kw: Optional[dict] = None,
                     port_width: int = 16,
                     issue_buffer_size: int = 64) -> Dict[str, object]:
    imem0 = SRAM(name="imem0", read_latency=1, write_latency=1,
                 address_ranges=((0, 1 << 22),), port_width=port_width)
    pcrf0 = RegisterFile(name="pcrf0", data_width=32,
                         registers={"pc": Data(32, 0)})
    ifs0 = InstructionFetchStage(name="ifs0", latency=latency_t(1),
                                 issue_buffer_size=issue_buffer_size)
    imau0 = InstructionMemoryAccessUnit(name="imau0", latency=latency_t(0))
    ACADLEdge(imem0, imau0, READ_DATA)
    ACADLEdge(pcrf0, imau0, READ_DATA)
    ACADLEdge(imau0, pcrf0, WRITE_DATA)
    ACADLEdge(ifs0, imau0, CONTAINS)

    dram0 = DRAM(name="dram0", read_latency=20, write_latency=20,
                 address_ranges=((1 << 20, 1 << 22),), port_width=8,
                 max_concurrent_requests=2, read_write_ports=1)
    # 108 KB global buffer; row-granular addressing below 1<<20
    glb0 = SRAM(name="glb0", read_latency=2, write_latency=2,
                address_ranges=((0, 1 << 20),), port_width=32,
                max_concurrent_requests=4,
                read_write_ports=2 * rows + 2,
                **(glb_kw or {}))

    # DMA between DRAM and GLB
    dma_ex = ExecuteStage(name="edma_ex", latency=latency_t(1))
    dma = MemoryAccessUnit(name="edma", to_process={"t_load", "t_store"},
                           latency=latency_t(1))
    ACADLEdge(dma_ex, dma, CONTAINS)
    ACADLEdge(dram0, dma, READ_DATA)
    ACADLEdge(dma, dram0, WRITE_DATA)
    ACADLEdge(glb0, dma, READ_DATA)
    ACADLEdge(dma, glb0, WRITE_DATA)
    ACADLEdge(ifs0, dma_ex, FORWARD)
    # DMA needs a staging register file
    dma_rf = RegisterFile(name="edma_rf", data_width=512,
                          registers={f"stage{i}": Data(512, None) for i in range(8)})
    ACADLEdge(dma_rf, dma, READ_DATA)
    ACADLEdge(dma, dma_rf, WRITE_DATA)

    pes: List[List[EyerissPE]] = []
    for r in range(rows):
        pes.append([EyerissPE(r, c) for c in range(columns)])

    # per-row load unit (GLB -> PE rfs of that row) and store unit
    loaders, stores = [], []
    for r in range(rows):
        lex = ExecuteStage(name=f"elu_ex{r}", latency=latency_t(1))
        lmau = MemoryAccessUnit(name=f"elu{r}", to_process={"t_load"},
                                latency=latency_t(1))
        ACADLEdge(lex, lmau, CONTAINS)
        ACADLEdge(glb0, lmau, READ_DATA)
        for c in range(columns):
            ACADLEdge(lmau, pes[r][c].rf, WRITE_DATA)
        ACADLEdge(ifs0, lex, FORWARD)
        loaders.append(lmau)

        sex = ExecuteStage(name=f"esu_ex{r}", latency=latency_t(1))
        smau = MemoryAccessUnit(name=f"esu{r}", to_process={"t_store"},
                                latency=latency_t(1))
        ACADLEdge(sex, smau, CONTAINS)
        for c in range(columns):
            ACADLEdge(pes[r][c].rf, smau, READ_DATA)
        ACADLEdge(smau, glb0, WRITE_DATA)
        ACADLEdge(ifs0, sex, FORWARD)
        stores.append(smau)

    # vertical psum accumulation: PE (r,c) writes psum into (r-1,c)
    for r in range(1, rows):
        for c in range(columns):
            ACADLEdge(pes[r][c].fu, pes[r - 1][c].rf, WRITE_DATA)

    for r in range(rows):
        for c in range(columns):
            ACADLEdge(ifs0, pes[r][c].ex, FORWARD)

    return {"pes": pes, "glb0": glb0, "dram0": dram0, "loaders": loaders,
            "stores": stores, "dma": dma, "rows": rows, "columns": columns}


def make_eyeriss_ag(rows: int = 12, columns: int = 14, **params):
    handles = generate_eyeriss(rows, columns, **params)
    ag = create_ag()
    return ag, handles
