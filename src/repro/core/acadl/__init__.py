"""ACADL — Abstract Computer Architecture Description Language (Müller et al. 2024).

Public surface mirrors the paper's Python front-end:

    from repro.core.acadl import *

    @generate
    def my_arch():
        ...ACADLObject subclasses + ACADLEdge(...)...

    my_arch()
    ag = create_ag()
    result = simulate(ag, program)
"""

from .base import ACADLObject, Data, Instruction, latency_t
from .edges import (
    ACADLDanglingEdge,
    ACADLEdge,
    CONTAINS,
    DanglingEdge,
    EdgeType,
    EdgeValidityError,
    FORWARD,
    READ_DATA,
    WRITE_DATA,
    connect_dangling_edge,
    create_ag,
    generate,
)
from .graph import AGValidityError, ArchitectureGraph
from .pipeline import ExecuteStage, InstructionFetchStage, PipelineStage
from .storage import (
    CacheInterface,
    DataStorage,
    DRAM,
    MemoryInterface,
    RegisterFile,
    SetAssociativeCache,
    SRAM,
)
from .units import FunctionalUnit, InstructionMemoryAccessUnit, MemoryAccessUnit
from .sim import EventSimulator, SimResult, TraceEntry, build_trace, simulate
from . import isa

__all__ = [
    "ACADLObject", "Data", "Instruction", "latency_t",
    "ACADLEdge", "ACADLDanglingEdge", "DanglingEdge", "EdgeType",
    "READ_DATA", "WRITE_DATA", "CONTAINS", "FORWARD",
    "connect_dangling_edge", "generate", "create_ag",
    "EdgeValidityError", "AGValidityError", "ArchitectureGraph",
    "PipelineStage", "ExecuteStage", "InstructionFetchStage",
    "RegisterFile", "DataStorage", "MemoryInterface", "SRAM", "DRAM",
    "CacheInterface", "SetAssociativeCache",
    "FunctionalUnit", "MemoryAccessUnit", "InstructionMemoryAccessUnit",
    "EventSimulator", "SimResult", "TraceEntry", "build_trace", "simulate",
    "isa",
]
