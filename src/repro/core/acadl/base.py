"""ACADL base classes: ACADLObject, Data, latency_t, Instruction.

Faithful to Müller et al. 2024 §3 (Fig. 1 class diagram):

* ``ACADLObject`` is the virtual base class; its only attribute is ``name``,
  the unique identifier of each object.
* ``Data`` represents any data stored in memories, registers and immediates.
  ``size`` is the data size in bits, ``payload`` the value used by the
  functional simulation.
* ``latency_t`` describes a time delta in clock cycles — either a constant
  integer or a function evaluated during performance estimation (the paper
  allows a string containing a function; we accept callables and strings).
* ``Instruction`` carries read/write register sets, read/write memory address
  sets, immediates, a mnemonic (``operation``) and a ``function`` implementing
  the data manipulation for the functional simulation.  Instructions are not
  limited to fine-grained operations: a single instruction may perform a
  matrix-matrix multiplication (fused-tensor abstraction level).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple, Union

__all__ = [
    "ACADLObject",
    "Data",
    "latency_t",
    "LatencyLike",
    "Instruction",
]


class latency_t:
    """A time delta in clock cycles.

    Either a non-negative integer constant, or a callable/str expression
    evaluated at simulation time with a context dict (e.g. the accessed
    address, current cycle, stateful memory model).  ``latency_t(1)`` mirrors
    the paper's Python front-end notation.
    """

    __slots__ = ("value", "fn", "expr")

    def __init__(self, value: Union[int, str, Callable[..., int]]):
        self.fn: Optional[Callable[..., int]] = None
        self.expr: Optional[str] = None
        if isinstance(value, latency_t):
            self.value = value.value
            self.fn = value.fn
            self.expr = value.expr
        elif isinstance(value, int):
            if value < 0:
                raise ValueError(f"latency must be >= 0, got {value}")
            self.value = value
        elif callable(value):
            self.value = None
            self.fn = value
        elif isinstance(value, str):
            # The paper allows "a string containing a function that is
            # evaluated during the performance estimation".
            self.value = None
            self.expr = value
        else:
            raise TypeError(f"latency_t expects int, str or callable, got {type(value)}")

    def is_static(self) -> bool:
        return self.value is not None

    def resolve(self, **ctx: Any) -> int:
        if self.value is not None:
            return self.value
        if self.fn is not None:
            return int(self.fn(**ctx))
        assert self.expr is not None
        return int(eval(self.expr, {"__builtins__": {}}, dict(ctx)))  # noqa: S307 - paper-specified semantics

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        if self.value is not None:
            return f"latency_t({self.value})"
        return f"latency_t(<dynamic {self.expr or self.fn}>)"

    def __eq__(self, other: object) -> bool:
        if isinstance(other, int):
            return self.value == other
        if isinstance(other, latency_t):
            return (self.value, self.expr) == (other.value, other.expr) and self.fn is other.fn
        return NotImplemented

    def __hash__(self) -> int:
        return hash((self.value, self.expr, id(self.fn)))


LatencyLike = Union[int, str, Callable[..., int], latency_t]


def _as_latency(value: LatencyLike) -> latency_t:
    return value if isinstance(value, latency_t) else latency_t(value)


class ACADLObject:
    """Virtual base class for every computer-architecture module in ACADL."""

    _registry_counter = itertools.count()

    def __init__(self, name: str):
        if not isinstance(name, str) or not name:
            raise ValueError("ACADLObject requires a non-empty string name")
        self.name = name
        # creation order — used for deterministic AG iteration
        self._uid = next(ACADLObject._registry_counter)
        from .edges import _current_builder  # local import to avoid a cycle

        builder = _current_builder()
        if builder is not None:
            builder.register_object(self)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"{type(self).__name__}(name={self.name!r})"


@dataclass
class Data:
    """Any data stored in memories, registers and immediates.

    ``size`` is the size in bits; ``payload`` is the actual value used by the
    functional simulation (int, float, numpy array for tensor-level data, ...).
    """

    size: int
    payload: Any = None

    def copy(self) -> "Data":
        return Data(self.size, self.payload)


@dataclass
class Instruction:
    """A unit of architectural state change (paper §3).

    ``operation`` is the mnemonic; ``function`` manipulates data when the
    instruction is processed by a FunctionalUnit (functional simulation).
    ``read_registers``/``write_registers`` name registers, while
    ``read_addresses``/``write_addresses`` are memory addresses.  Addresses may
    be given indirectly as ``("reg", name)`` tuples resolved against a register
    file at execution time (register-indirect addressing, cf. Listing 5's
    ``load [r9] => r6``).

    ``unit_hint`` optionally pins the instruction to a named
    FunctionalUnit/ExecuteStage — used by the operator-mapping layer to emit
    deterministic schedules that the AIDG estimator and the event-driven
    simulator agree on.
    """

    operation: str
    read_registers: Tuple[str, ...] = ()
    write_registers: Tuple[str, ...] = ()
    read_addresses: Tuple[Any, ...] = ()
    write_addresses: Tuple[Any, ...] = ()
    immediates: Tuple[Any, ...] = ()
    function: Optional[Callable[..., Any]] = None
    size: int = 32
    unit_hint: Optional[str] = None
    # free-form metadata (e.g. tensor tile coordinates); never inspected by
    # the simulator, useful for debugging and benchmarks.
    tags: Dict[str, Any] = field(default_factory=dict)

    def execute(self, env: "ExecutionEnv") -> None:
        """Run ``function`` against an execution environment.

        Called by FunctionalUnit.process() during the functional simulation.
        """
        if self.function is not None:
            self.function(env, self)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        rr = ",".join(map(str, self.read_registers))
        wr = ",".join(map(str, self.write_registers))
        return f"Instruction({self.operation} r[{rr}] -> w[{wr}])"


class ExecutionEnv:
    """Register/memory access facade handed to Instruction.function.

    Bridges the functional simulation to RegisterFiles and DataStorages that
    the executing FunctionalUnit is connected to.
    """

    def __init__(self, read_reg: Callable[[str], Any], write_reg: Callable[[str, Any], None],
                 read_mem: Callable[[int], Any], write_mem: Callable[[int, Any], None]):
        self.read_reg = read_reg
        self.write_reg = write_reg
        self.read_mem = read_mem
        self.write_mem = write_mem
