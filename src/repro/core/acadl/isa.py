"""Instruction builders for the scalar and fused-tensor abstraction levels.

The scalar ISA follows the OMA example (paper Listing 5): ``mov``, ``addi``,
``add``, ``mac``, ``load``, ``store``, ``beqi``, ``jumpi``.  Branch offsets
are given in *instruction counts* relative to the next instruction (the
paper's listing uses byte offsets of 4-byte words; we normalize to
instruction indices to keep programs self-contained).

The fused-tensor ISA follows the Γ̈ example (paper Listing 4): ``t_load``,
``t_store``, ``t_gemm`` (with optional activation), ``t_add``, plus the
beyond-paper ``t_scan`` (chunked SSM recurrence) and ``t_attn`` (fused
attention tile) used by the operator-mapping layer for modern workloads.
Tensor instructions read/write *vector registers* (named ``r[<u>].<i>`` in
the paper) holding numpy arrays as payloads.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import numpy as np

from .base import ExecutionEnv, Instruction

__all__ = [
    "mov", "movi", "addi", "add", "sub", "muli", "mac", "load", "store",
    "beqi", "bnei", "jumpi", "halt",
    "t_load", "t_store", "t_gemm", "t_add", "t_scan", "t_attn",
]


# ---------------------------------------------------------------------------
# scalar level (OMA)
# ---------------------------------------------------------------------------


def movi(dst: str, imm: Any) -> Instruction:
    def fn(env: ExecutionEnv, ins: Instruction) -> None:
        env.write_reg(dst, ins.immediates[0])
    return Instruction("mov", (), (dst,), immediates=(imm,), function=fn)


def mov(dst: str, src: str) -> Instruction:
    def fn(env: ExecutionEnv, ins: Instruction) -> None:
        env.write_reg(dst, env.read_reg(src))
    return Instruction("mov", (src,), (dst,), function=fn)


def addi(dst: str, src: str, imm: int) -> Instruction:
    def fn(env: ExecutionEnv, ins: Instruction) -> None:
        env.write_reg(dst, env.read_reg(src) + ins.immediates[0])
    return Instruction("addi", (src,), (dst,), immediates=(imm,), function=fn)


def add(dst: str, a: str, b: str) -> Instruction:
    def fn(env: ExecutionEnv, ins: Instruction) -> None:
        env.write_reg(dst, env.read_reg(a) + env.read_reg(b))
    return Instruction("add", (a, b), (dst,), function=fn)


def sub(dst: str, a: str, b: str) -> Instruction:
    def fn(env: ExecutionEnv, ins: Instruction) -> None:
        env.write_reg(dst, env.read_reg(a) - env.read_reg(b))
    return Instruction("sub", (a, b), (dst,), function=fn)


def muli(dst: str, src: str, imm: Any) -> Instruction:
    def fn(env: ExecutionEnv, ins: Instruction) -> None:
        env.write_reg(dst, env.read_reg(src) * ins.immediates[0])
    return Instruction("muli", (src,), (dst,), immediates=(imm,), function=fn)


def mac(acc: str, a: str, b: str) -> Instruction:
    """Multiply-accumulate: acc += a * b (the OMA's built-in MAC)."""
    def fn(env: ExecutionEnv, ins: Instruction) -> None:
        env.write_reg(acc, env.read_reg(acc) + env.read_reg(a) * env.read_reg(b))
    return Instruction("mac", (a, b, acc), (acc,), function=fn)


def load(dst: str, addr: Any) -> Instruction:
    """``load [addr] => dst``; ``addr`` is an int or ``("reg", name)``."""
    reads = (addr[1],) if isinstance(addr, tuple) else ()

    def fn(env: ExecutionEnv, ins: Instruction) -> None:
        a = env.read_reg(addr[1]) if isinstance(addr, tuple) else addr
        env.write_reg(dst, env.read_mem(int(a)))
    return Instruction("load", reads, (dst,), read_addresses=(addr,), function=fn)


def store(src: str, addr: Any) -> Instruction:
    """``store src => [addr]``."""
    reads = (src,) + ((addr[1],) if isinstance(addr, tuple) else ())

    def fn(env: ExecutionEnv, ins: Instruction) -> None:
        a = env.read_reg(addr[1]) if isinstance(addr, tuple) else addr
        env.write_mem(int(a), env.read_reg(src))
    return Instruction("store", reads, (), write_addresses=(addr,), function=fn)


def beqi(src: str, imm: Any, offset: int) -> Instruction:
    """Branch if ``src == imm``: pc += offset (in instructions, relative to
    the *next* instruction).  Writes the ``pc`` register."""
    def fn(env: ExecutionEnv, ins: Instruction) -> None:
        if env.read_reg(src) == ins.immediates[0]:
            env.write_reg("pc", env.read_reg("__pc_next__") + ins.immediates[1])
    return Instruction("beqi", (src,), ("pc",), immediates=(imm, offset), function=_pc_rel(fn))


def bnei(src: str, imm: Any, offset: int) -> Instruction:
    def fn(env: ExecutionEnv, ins: Instruction) -> None:
        if env.read_reg(src) != ins.immediates[0]:
            env.write_reg("pc", env.read_reg("__pc_next__") + ins.immediates[1])
    return Instruction("bnei", (src,), ("pc",), immediates=(imm, offset), function=_pc_rel(fn))


def jumpi(offset: int) -> Instruction:
    def fn(env: ExecutionEnv, ins: Instruction) -> None:
        env.write_reg("pc", env.read_reg("__pc_next__") + ins.immediates[0])
    return Instruction("jumpi", (), ("pc",), immediates=(offset,), function=_pc_rel(fn))


def halt() -> Instruction:
    def fn(env: ExecutionEnv, ins: Instruction) -> None:
        env.write_reg("pc", -2)  # jump out of the program
    return Instruction("halt", (), ("pc",), function=fn)


def _pc_rel(fn):
    """Wrap a branch function so it can read the fall-through pc.

    ``build_trace`` executes instructions knowing the next pc; we expose it
    through a pseudo-register resolved by the wrapper closure at trace time.
    The wrapper intercepts reads of ``__pc_next__``.
    """
    def wrapped(env: ExecutionEnv, ins: Instruction) -> None:
        next_holder = {}

        def read_reg(name: str):
            if name == "__pc_next__":
                return next_holder["v"]
            return env.read_reg(name)

        # the trace builder stores the fall-through index on the instruction
        next_holder["v"] = ins.tags.get("_pc_next", 0)
        inner_env = ExecutionEnv(read_reg, env.write_reg, env.read_mem, env.write_mem)
        fn(inner_env, ins)
    return wrapped


# ---------------------------------------------------------------------------
# fused-tensor level (Γ̈)
# ---------------------------------------------------------------------------


def t_load(dst: str, addr: int, shape: Tuple[int, ...], unit: Optional[str] = None) -> Instruction:
    """Load a tensor tile from ``addr`` into vector register ``dst``."""
    def fn(env: ExecutionEnv, ins: Instruction) -> None:
        v = env.read_mem(addr)
        if not isinstance(v, np.ndarray):
            v = None  # abstract tile: timing-only simulation (workloads)
        env.write_reg(dst, v)
    words = int(np.prod(shape))
    return Instruction("t_load", (), (dst,), read_addresses=(addr,), function=fn,
                       unit_hint=unit, tags={"words": words, "shape": shape})


def t_store(src: str, addr: int, shape: Tuple[int, ...] = (), unit: Optional[str] = None) -> Instruction:
    def fn(env: ExecutionEnv, ins: Instruction) -> None:
        env.write_mem(addr, env.read_reg(src))
    words = int(np.prod(shape)) if shape else 1
    return Instruction("t_store", (src,), (), write_addresses=(addr,), function=fn,
                       unit_hint=unit, tags={"words": words, "shape": shape})


def t_gemm(dst: str, a: str, b: str, activation: int = 0, acc: Optional[str] = None,
           unit: Optional[str] = None, tile: Tuple[int, int, int] = (8, 8, 8)) -> Instruction:
    """Fused GeMM tile: dst = act(a @ b [+ acc]); activation 1 = ReLU
    (paper Listing 4's trailing ``1: ReLU`` parameter).  ``tile`` = (m, k, n)
    tile extents; macs = m*k*n drives latency functions of compute units."""
    reads = (a, b) + ((acc,) if acc else ())

    def fn(env: ExecutionEnv, ins: Instruction) -> None:
        va, vb = env.read_reg(a), env.read_reg(b)
        if va is None or vb is None:
            env.write_reg(dst, None)  # abstract tile (timing-only)
            return
        out = np.asarray(va) @ np.asarray(vb)
        if acc:
            out = out + np.asarray(env.read_reg(acc))
        if activation == 1:
            out = np.maximum(out, 0)
        env.write_reg(dst, out)
    m, k, n = tile
    return Instruction("gemm", reads, (dst,), immediates=(activation,), function=fn,
                       unit_hint=unit,
                       tags={"words": m * n, "macs": m * k * n, "tile": tile})


def t_add(dst: str, a: str, b: str, unit: Optional[str] = None,
          words: int = 64) -> Instruction:
    def fn(env: ExecutionEnv, ins: Instruction) -> None:
        va, vb = env.read_reg(a), env.read_reg(b)
        if va is None or vb is None:
            env.write_reg(dst, None)
            return
        env.write_reg(dst, np.asarray(va) + np.asarray(vb))
    return Instruction("matadd", (a, b), (dst,), function=fn, unit_hint=unit,
                       tags={"words": words, "macs": words})


def t_scan(dst: str, state: str, x: str, decay: str, unit: Optional[str] = None,
           words: int = 64) -> Instruction:
    """Beyond-paper fused-tensor op: chunked linear recurrence
    ``state = decay * state + x`` (SSM/Mamba chunk), enabling ACADL modeling
    of attention-free architectures (DESIGN.md §Arch-applicability)."""
    def fn(env: ExecutionEnv, ins: Instruction) -> None:
        s = env.read_reg(state)
        d_ = env.read_reg(decay)
        xx = env.read_reg(x)
        if s is None or d_ is None or xx is None:
            env.write_reg(dst, None)
            return
        env.write_reg(dst, np.asarray(d_) * np.asarray(s) + np.asarray(xx))
    return Instruction("scan", (state, x, decay), (dst,), function=fn, unit_hint=unit,
                       tags={"words": words, "macs": 2 * words})


def t_attn(dst: str, q: str, k: str, v: str, unit: Optional[str] = None,
           tile: Tuple[int, int, int] = (8, 8, 8)) -> Instruction:
    """Beyond-paper fused attention tile: dst = softmax(q k^T) v.
    ``tile`` = (q_len, kv_len, head_dim)."""
    def fn(env: ExecutionEnv, ins: Instruction) -> None:
        vals = [env.read_reg(r) for r in (q, k, v)]
        if any(x is None for x in vals):
            env.write_reg(dst, None)
            return
        Q, K, V = (np.asarray(x) for x in vals)
        s = Q @ K.T / np.sqrt(Q.shape[-1])
        s = s - s.max(axis=-1, keepdims=True)
        p = np.exp(s)
        p /= p.sum(axis=-1, keepdims=True)
        env.write_reg(dst, p @ V)
    tq, tk, hd = tile
    return Instruction("attn", (q, k, v), (dst,), function=fn, unit_hint=unit,
                       tags={"words": tq * hd, "macs": 2 * tq * tk * hd, "tile": tile})
