"""Architecture graph (AG): the UML object diagram of a modeled architecture.

``ArchitectureGraph`` holds the instantiated ACADL objects and validated
edges, wires the convenience pointers the simulator uses (contained units,
readable/writable register files and storages, forward targets), and checks
global well-formedness beyond per-edge validity:

* object names are unique (checked at registration);
* every InstructionFetchStage contains an InstructionMemoryAccessUnit with a
  connected instruction memory;
* DataStorage ``read_write_ports`` bounds the number of connected
  MemoryAccessUnits;
* CONTAINS is exclusive — a FunctionalUnit belongs to exactly one stage.
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .base import ACADLObject
from .edges import ACADLEdge, EdgeType
from .pipeline import ExecuteStage, InstructionFetchStage, PipelineStage
from .storage import DataStorage, RegisterFile
from .units import FunctionalUnit, InstructionMemoryAccessUnit, MemoryAccessUnit

__all__ = ["ArchitectureGraph", "AGValidityError"]


class AGValidityError(ValueError):
    pass


class ArchitectureGraph:
    def __init__(self, objects: Sequence[ACADLObject], edges: Sequence[ACADLEdge]):
        self.objects: List[ACADLObject] = list(objects)
        self.edges: List[ACADLEdge] = list(edges)
        self.by_name: Dict[str, ACADLObject] = {o.name: o for o in self.objects}
        if len(self.by_name) != len(self.objects):
            raise AGValidityError("duplicate object names in AG")
        self._finalize()
        self._validate()

    # -- wiring ------------------------------------------------------------------
    def _finalize(self) -> None:
        # reset wiring (idempotent construction)
        for o in self.objects:
            if isinstance(o, PipelineStage):
                o.forward_targets = []
            if isinstance(o, ExecuteStage):
                o.functional_units = []
            if isinstance(o, FunctionalUnit):
                o.readable_rfs = []
                o.writable_rfs = []
            if isinstance(o, MemoryAccessUnit):
                o.readable_storages = []
                o.writable_storages = []
            if isinstance(o, DataStorage):
                o.backing = None

        for e in self.edges:
            s, t, k = e.source, e.target, e.edge_type
            if k is EdgeType.FORWARD:
                s.forward_targets.append(t)
            elif k is EdgeType.CONTAINS:
                s.functional_units.append(t)
            elif k is EdgeType.READ_DATA:
                if isinstance(s, RegisterFile):
                    t.readable_rfs.append(s)
                elif isinstance(s, DataStorage) and isinstance(t, (MemoryAccessUnit,)):
                    t.readable_storages.append(s)
                elif isinstance(s, DataStorage) and isinstance(t, DataStorage):
                    t.backing = s  # cache fill path: t reads (fills) from s
            elif k is EdgeType.WRITE_DATA:
                if isinstance(s, FunctionalUnit) and isinstance(t, RegisterFile):
                    s.writable_rfs.append(t)
                elif isinstance(s, MemoryAccessUnit) and isinstance(t, DataStorage):
                    s.writable_storages.append(t)

    # -- global validity -----------------------------------------------------------
    def _validate(self) -> None:
        # CONTAINS exclusivity
        owner: Dict[str, str] = {}
        for e in self.edges:
            if e.edge_type is EdgeType.CONTAINS:
                prev = owner.setdefault(e.target.name, e.source.name)
                if prev != e.source.name:
                    raise AGValidityError(
                        f"FunctionalUnit {e.target.name!r} contained by both "
                        f"{prev!r} and {e.source.name!r} (composition must be exclusive)"
                    )
        # fetch stages need an instruction path
        for o in self.objects:
            if isinstance(o, InstructionFetchStage):
                imau = o.imau
                if imau is None:
                    raise AGValidityError(
                        f"InstructionFetchStage {o.name!r} contains no InstructionMemoryAccessUnit"
                    )
                if imau.instruction_memory is None:
                    raise AGValidityError(
                        f"InstructionMemoryAccessUnit {imau.name!r} has no instruction memory "
                        f"(READ_DATA edge from a DataStorage)"
                    )
        # port bounds
        port_users: Dict[str, set] = {}
        for e in self.edges:
            if e.edge_type in (EdgeType.READ_DATA, EdgeType.WRITE_DATA):
                st, mau = None, None
                if isinstance(e.source, DataStorage) and isinstance(e.target, MemoryAccessUnit):
                    st, mau = e.source, e.target
                elif isinstance(e.source, MemoryAccessUnit) and isinstance(e.target, DataStorage):
                    st, mau = e.target, e.source
                if st is not None:
                    port_users.setdefault(st.name, set()).add(mau.name)
        for st_name, users in port_users.items():
            st = self.by_name[st_name]
            if len(users) > st.read_write_ports:
                raise AGValidityError(
                    f"DataStorage {st_name!r} has {len(users)} connected MemoryAccessUnits "
                    f"but only read_write_ports={st.read_write_ports}"
                )

    # -- queries ------------------------------------------------------------------
    def of_type(self, cls) -> List[ACADLObject]:
        return [o for o in self.objects if isinstance(o, cls)]

    @property
    def fetch_stages(self) -> List[InstructionFetchStage]:
        return self.of_type(InstructionFetchStage)

    @property
    def pipeline_stages(self) -> List[PipelineStage]:
        return self.of_type(PipelineStage)

    @property
    def functional_units(self) -> List[FunctionalUnit]:
        return self.of_type(FunctionalUnit)

    @property
    def storages(self) -> List[DataStorage]:
        return self.of_type(DataStorage)

    def timing_reset(self) -> None:
        for st in self.storages:
            st.timing_reset()

    def describe(self) -> str:
        """Human-readable AG summary (block-diagram-as-text)."""
        lines = [f"ArchitectureGraph: {len(self.objects)} objects, {len(self.edges)} edges"]
        for o in self.objects:
            lines.append(f"  {type(o).__name__:28s} {o.name}")
        for e in self.edges:
            lines.append(f"  {e.source.name} --{e.edge_type.value}--> {e.target.name}")
        return "\n".join(lines)
