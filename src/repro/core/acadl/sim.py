"""Cycle-accurate event-driven timing simulation (paper §6).

Semantics implemented:

* every latency-bearing object gets a counter ``t`` and a ``ready`` flag; the
  global simulation time ``T`` advances in whole clock cycles and all state
  transitions occur at cycle boundaries;
* the InstructionFetchStage fetches ``port_width`` instructions per
  transaction through its InstructionMemoryAccessUnit, stalls while the issue
  buffer lacks space, and forwards multiple instructions *out-of-order* (per
  target stage, FIFO within a target) in the same cycle (Fig. 9);
* an ExecuteStage hands a supported instruction to the contained
  FunctionalUnit and is busy until processing finishes (its own latency is
  not accumulated); otherwise it buffers the instruction ``latency`` cycles
  and forwards it to a ready connected stage — busy stages model structural
  hazards (Fig. 10);
* a FunctionalUnit/MemoryAccessUnit starts its ``latency`` countdown only
  after all previous in-order instructions modifying its accessed registers
  and addresses have finished — tracked through a global last-writer map
  built in program order (Fig. 11);
* DataStorages service up to ``max_concurrent_requests`` transactions, each
  request slot with its own counter; excess requests queue FIFO
  (Figs. 12/13).  DRAM row-buffer state and cache hit/miss state resolve
  latencies per access.

Functional simulation strategy: instructions are functionally executed *in
program order at fetch time* (trace construction), which resolves
register-indirect addresses, control flow and stateful memory latencies
deterministically; the timing simulation then replays the trace.  This is
exactly the AIDG trace discipline of the paper's fast path [16] and is
equivalent to execute-at-process for programs whose functional behaviour is
timing-independent (data races are excluded by the dependency semantics).
Branch handling: an in-flight pc-writing instruction blocks further fetch
(the fetch unit reads ``pc``), yielding a deterministic branch bubble; a
pc-writer also terminates its fetch group.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

from .base import ExecutionEnv, Instruction
from .graph import ArchitectureGraph
from .pipeline import ExecuteStage, InstructionFetchStage, PipelineStage
from .storage import DataStorage, RegisterFile
from .units import FunctionalUnit, MemoryAccessUnit

__all__ = ["TraceEntry", "build_trace", "EventSimulator", "SimResult", "simulate"]

PC = "pc"


# ---------------------------------------------------------------------------
# Trace construction (functional pre-execution in program order)
# ---------------------------------------------------------------------------


@dataclass
class TraceEntry:
    idx: int                      # dynamic program-order index
    instr: Instruction
    deps: Tuple[int, ...]         # indices of RAW/WAW predecessors
    mem_latency: int              # total storage cycles (sum of mem_parts)
    route: Tuple[str, ...]        # pipeline stages after the fetch stage
    fu_name: Optional[str]        # executing FunctionalUnit (None = pass-through)
    is_pc_writer: bool = False
    # per-access storage charges: (storage name, latency) — each access
    # occupies a request slot of *its own* storage (paper Figs. 12/13)
    mem_parts: Tuple[Tuple[str, int], ...] = ()


class _FunctionalMachine:
    """Sequential functional executor over an AG (program order)."""

    def __init__(self, ag: ArchitectureGraph):
        self.ag = ag
        self.rfs: List[RegisterFile] = ag.of_type(RegisterFile)

    def _rf_for(self, reg: str) -> RegisterFile:
        for rf in self.rfs:
            if rf.has(reg):
                return rf
        raise KeyError(f"no RegisterFile holds register {reg!r}")

    def read_reg(self, reg: str) -> Any:
        return self._rf_for(reg).read(reg)

    def write_reg(self, reg: str, value: Any) -> None:
        self._rf_for(reg).write(reg, value)


def _resolve_addresses(addrs: Sequence[Any], machine: _FunctionalMachine) -> Tuple[int, ...]:
    out = []
    for a in addrs:
        if isinstance(a, tuple) and len(a) == 2 and a[0] == "reg":
            out.append(int(machine.read_reg(a[1])))
        else:
            out.append(int(a))
    return tuple(out)


def _find_unit_and_route(ag: ArchitectureGraph, fetch: InstructionFetchStage,
                         instr: Instruction) -> Tuple[Tuple[str, ...], Optional[str]]:
    """BFS the FORWARD graph from the fetch stage to a stage whose contained
    FunctionalUnit supports the instruction.  Deterministic: AG order."""
    frontier: deque = deque((t, (t.name,)) for t in fetch.forward_targets)
    seen: Set[str] = set()
    fallback: Optional[Tuple[Tuple[str, ...], None]] = None
    while frontier:
        stage, path = frontier.popleft()
        if stage.name in seen:
            continue
        seen.add(stage.name)
        if isinstance(stage, ExecuteStage):
            fu = stage.unit_for(instr)
            if fu is not None:
                return path, fu.name
        if fallback is None and not stage.forward_targets:
            fallback = (path, None)
        for t in stage.forward_targets:
            frontier.append((t, path + (t.name,)))
    if fallback is not None:
        return fallback
    raise LookupError(
        f"no FunctionalUnit reachable from {fetch.name!r} supports {instr!r} "
        f"(operation {instr.operation!r}, unit_hint={instr.unit_hint!r})"
    )


def build_trace(ag: ArchitectureGraph, program: Sequence[Instruction],
                entry: int = 0, max_instructions: int = 1_000_000) -> List[TraceEntry]:
    """Functionally execute ``program`` and emit the dynamic trace.

    ``program`` is addressed by instruction index; control flow works through
    the ``pc`` register semantics: a pc-writing instruction's function sets
    the next instruction index via ``env.write_reg("pc", target_idx)``.
    """
    ag.timing_reset()
    machine = _FunctionalMachine(ag)
    fetch_stages = ag.fetch_stages
    if not fetch_stages:
        raise ValueError("AG has no InstructionFetchStage")
    fetch = fetch_stages[0]
    route_cache: Dict[Any, Tuple[Tuple[str, ...], Optional[str]]] = {}

    # last-writer map in program order: resource key -> trace idx
    last_writer: Dict[Any, int] = {}
    trace: List[TraceEntry] = []
    pc = entry
    steps = 0
    while 0 <= pc < len(program):
        steps += 1
        if steps > max_instructions:
            raise RuntimeError(f"trace exceeded {max_instructions} instructions — runaway loop?")
        instr = program[pc]
        idx = len(trace)

        raddrs = _resolve_addresses(instr.read_addresses, machine)
        waddrs = _resolve_addresses(instr.write_addresses, machine)

        # ---- dependencies: RAW on reads, WAW on writes (paper Fig. 11) ----
        deps: Set[int] = set()
        for reg in instr.read_registers:
            if ("r", reg) in last_writer:
                deps.add(last_writer[("r", reg)])
        for reg in instr.write_registers:
            if ("r", reg) in last_writer:
                deps.add(last_writer[("r", reg)])
        for a in raddrs:
            if ("m", a) in last_writer:
                deps.add(last_writer[("m", a)])
        for a in waddrs:
            if ("m", a) in last_writer:
                deps.add(last_writer[("m", a)])

        rkey = (instr.operation, instr.unit_hint,
                instr.read_registers, instr.write_registers)
        if rkey not in route_cache:
            route_cache[rkey] = _find_unit_and_route(ag, fetch, instr)
        route, fu_name = route_cache[rkey]

        # ---- memory latency (program-order stateful resolution) ----
        mem_parts: List[Tuple[str, int]] = []
        words = int(instr.tags.get("words", 1))
        if fu_name is not None:
            fu = ag.by_name[fu_name]
            if isinstance(fu, MemoryAccessUnit):
                for a in raddrs:
                    for st in fu.storage_chain("read", a):
                        mem_parts.append((st.name, st.access_latency("read", a, words)))
                for a in waddrs:
                    for st in fu.storage_chain("write", a):
                        mem_parts.append((st.name, st.access_latency("write", a, words)))
        mem_lat = sum(l for _, l in mem_parts)

        is_pc_writer = PC in instr.write_registers

        # ---- functional execution (sequential) ----
        next_pc = pc + 1
        instr.tags["_pc_next"] = next_pc  # fall-through index for branches
        if instr.function is not None:
            executed_pc: Dict[str, int] = {}

            def write_reg(reg: str, value: Any) -> None:
                if reg == PC:
                    executed_pc["pc"] = int(value)
                else:
                    machine.write_reg(reg, value)

            fu_obj = ag.by_name[fu_name] if fu_name else None
            if isinstance(fu_obj, MemoryAccessUnit):
                env = ExecutionEnv(machine.read_reg, write_reg,
                                   fu_obj._read_mem, fu_obj._write_mem)
            else:
                def no_mem(*a: Any) -> Any:
                    raise TypeError(f"{instr!r} accesses memory but runs on a non-memory unit")
                env = ExecutionEnv(machine.read_reg, write_reg, no_mem, no_mem)
            instr.execute(env)
            if "pc" in executed_pc:
                next_pc = executed_pc["pc"]

        # ---- update last-writer map ----
        for reg in instr.write_registers:
            if reg != PC:
                last_writer[("r", reg)] = idx
        for a in waddrs:
            last_writer[("m", a)] = idx

        trace.append(TraceEntry(idx, instr, tuple(sorted(deps)), mem_lat, route,
                                fu_name, is_pc_writer, tuple(mem_parts)))
        pc = next_pc
    return trace


# ---------------------------------------------------------------------------
# Event-driven timing simulation over the trace
# ---------------------------------------------------------------------------


@dataclass
class SimResult:
    cycles: int
    issue_time: List[int]      # cycle at which the instruction left the issue buffer
    start_time: List[int]      # cycle at which FU processing began
    complete_time: List[int]   # cycle at which the instruction finished
    stats: Dict[str, Any] = field(default_factory=dict)

    @property
    def n_instructions(self) -> int:
        return len(self.complete_time)


class _StorageTiming:
    """Request-slot + FIFO timing for a DataStorage (Figs. 12/13)."""

    def __init__(self, storage: DataStorage):
        self.storage = storage
        self.slots: List[int] = [0] * max(1, storage.max_concurrent_requests)

    def service(self, at: int, latency: int) -> int:
        """Earliest completion of a request arriving at cycle ``at``:
        earliest-free slot (FIFO overflow queue semantics)."""
        i = min(range(len(self.slots)), key=lambda k: self.slots[k])
        begin = max(at, self.slots[i])
        done = begin + latency
        self.slots[i] = done
        return done

    def next_free(self) -> int:
        return min(self.slots)


class EventSimulator:
    """Replays a trace against the AG with cycle-accurate stage timing."""

    def __init__(self, ag: ArchitectureGraph, trace: Sequence[TraceEntry]):
        self.ag = ag
        self.trace = list(trace)
        fetches = ag.fetch_stages
        if not fetches:
            raise ValueError("AG has no InstructionFetchStage")
        self.fetch = fetches[0]
        imau = self.fetch.imau
        assert imau is not None and imau.instruction_memory is not None
        self.imem = imau.instruction_memory
        self.imau_latency = imau.latency.resolve()

    def run(self, max_cycles: int = 10_000_000) -> SimResult:
        trace = self.trace
        n = len(trace)
        issue_t = [-1] * n
        start_t = [-1] * n
        complete_t = [-1] * n
        if n == 0:
            return SimResult(0, issue_t, start_t, complete_t)

        port_width = max(1, self.imem.port_width)
        ibs = max(1, self.fetch.issue_buffer_size)
        imem_read_lat = self.imem.access_latency("read", 0)
        fetch_cost = max(1, imem_read_lat + self.imau_latency)

        # --- fetch groups: consecutive trace entries; a pc-writer ends its group ---
        groups: List[List[int]] = []
        cur: List[int] = []
        for e in trace:
            cur.append(e.idx)
            if len(cur) >= port_width or e.is_pc_writer:
                groups.append(cur)
                cur = []
        if cur:
            groups.append(cur)

        # --- dynamic state ---
        issue_buffer: List[int] = []             # visible, fetched order
        pending: deque = deque()                 # (visible_at, [idxs]) in flight
        next_group = 0
        fetch_port_free = 0                      # cycle the fetch port frees up
        pending_branch: Optional[int] = None     # unresolved pc-writer idx

        # per-stage occupancy: stage name -> (trace idx, phase, time)
        # phases: "buffer" (waiting own latency), "wait_next" (trying to
        # forward), "fu_wait" (deps unresolved), "fu_busy" (until time)
        occupant: Dict[str, Optional[Tuple[int, str, int]]] = {
            s.name: None for s in self.ag.of_type(PipelineStage)
        }
        storage_timing: Dict[str, _StorageTiming] = {
            st.name: _StorageTiming(st) for st in self.ag.storages
        }
        done: List[bool] = [False] * n

        T = 0
        completed = 0
        while completed < n:
            if T > max_cycles:
                raise RuntimeError(f"simulation exceeded {max_cycles} cycles")
            changed = False

            # ---- 0. fetched instructions become visible ----
            while pending and pending[0][0] <= T:
                _, idxs = pending.popleft()
                issue_buffer.extend(idxs)
                changed = True

            # ---- 1. completions & buffer-phase expirations ----
            for name, occ in list(occupant.items()):
                if occ is None:
                    continue
                idx, phase, t_ready = occ
                if phase == "fu_busy" and t_ready <= T:
                    complete_t[idx] = t_ready
                    done[idx] = True
                    completed += 1
                    occupant[name] = None
                    changed = True
                    if pending_branch == idx:
                        pending_branch = None
                elif phase == "buffer" and t_ready <= T:
                    occupant[name] = (idx, "wait_next", T)
                    changed = True

            # ---- 2. forwards along routes (fixed point -> simultaneous shift) ----
            moved = True
            while moved:
                moved = False
                for name, occ in list(occupant.items()):
                    if occ is None:
                        continue
                    idx, phase, t_ready = occ
                    if phase != "wait_next":
                        continue
                    e = trace[idx]
                    route = e.route
                    pos = route.index(name)
                    if pos + 1 >= len(route):
                        # pass-through instruction completes at route end
                        complete_t[idx] = T
                        done[idx] = True
                        completed += 1
                        occupant[name] = None
                        moved = changed = True
                        if pending_branch == idx:
                            pending_branch = None
                        continue
                    nxt = route[pos + 1]
                    if occupant[nxt] is None:
                        occupant[name] = None
                        self._receive(nxt, idx, T, occupant, trace)
                        moved = changed = True

            # ---- 3. issue from buffer: out-of-order, FIFO per target stage ----
            tried_targets: Set[str] = set()
            for idx in list(issue_buffer):
                first = trace[idx].route[0]
                if first in tried_targets:
                    continue
                tried_targets.add(first)
                if occupant[first] is None:
                    issue_buffer.remove(idx)
                    issue_t[idx] = T
                    self._receive(first, idx, T, occupant, trace)
                    changed = True

            # ---- 4. FU starts: deps resolved -> begin processing (runs after
            # forwards/issue so an instruction received this cycle can start
            # this cycle -> 1 op/cycle steady-state pipelines) ----
            for name, occ in list(occupant.items()):
                if occ is None:
                    continue
                idx, phase, _ = occ
                if phase != "fu_wait":
                    continue
                e = trace[idx]
                if all(done[d] for d in e.deps):
                    fu: FunctionalUnit = self.ag.by_name[e.fu_name]
                    tags = e.instr.tags
                    fu_lat = fu.latency.resolve(
                        operation=e.instr.operation,
                        words=int(tags.get("words", 1)),
                        macs=int(tags.get("macs", tags.get("words", 1))),
                    )
                    start_t[idx] = T
                    finish = T + fu_lat
                    if e.mem_parts:
                        # each access occupies a request slot of its own
                        # storage; the instruction finishes when the slowest
                        # of its transactions completes (Figs. 12/13)
                        finish_mem = T
                        for st_name, lat in e.mem_parts:
                            svc_done = storage_timing[st_name].service(T, lat)
                            finish_mem = max(finish_mem, svc_done)
                        finish = finish_mem + fu_lat
                    elif e.mem_latency > 0:
                        finish = T + e.mem_latency + fu_lat
                    occupant[name] = (idx, "fu_busy", max(finish, T + 1))
                    changed = True

            # ---- 5. fetch (Fig. 9) ----
            in_flight = sum(len(g) for _, g in pending)
            if (next_group < len(groups)
                    and fetch_port_free <= T
                    and pending_branch is None
                    and len(issue_buffer) + in_flight + len(groups[next_group]) <= ibs):
                g = groups[next_group]
                next_group += 1
                fetch_port_free = T + fetch_cost
                pending.append((T + fetch_cost, g))
                for idx in g:
                    if trace[idx].is_pc_writer:
                        pending_branch = idx
                changed = True

            # ---- 6. advance time (event skip when idle) ----
            if changed:
                T += 1
            else:
                nxt_times = [t for _, t in [(0, fetch_port_free)] if t > T]
                nxt_times += [t for t, _ in pending if t > T]
                for occ in occupant.values():
                    if occ is not None and occ[2] > T:
                        nxt_times.append(occ[2])
                if not nxt_times:
                    raise RuntimeError(
                        f"deadlock at T={T}: {completed}/{n} complete; "
                        f"buffer={issue_buffer[:8]} occupants="
                        f"{ {k: v for k, v in occupant.items() if v} }"
                    )
                T = max(T + 1, min(nxt_times))

        return SimResult(cycles=max(complete_t) if complete_t else 0,
                         issue_time=issue_t, start_time=start_t,
                         complete_time=complete_t,
                         stats={"instructions": n, "fetch_groups": len(groups)})

    def _receive(self, stage_name: str, idx: int, T: int,
                 occupant: Dict[str, Optional[Tuple[int, str, int]]],
                 trace: Sequence[TraceEntry]) -> None:
        stage = self.ag.by_name[stage_name]
        e = trace[idx]
        if isinstance(stage, ExecuteStage) and e.fu_name is not None \
                and stage_name == e.route[-1]:
            occupant[stage_name] = (idx, "fu_wait", T)
        else:
            lat = stage.latency.resolve()
            occupant[stage_name] = (idx, "buffer", T + lat)


def simulate(ag: ArchitectureGraph, program: Sequence[Instruction],
             entry: int = 0, max_cycles: int = 10_000_000) -> SimResult:
    """Functional + timing simulation of ``program`` on ``ag``."""
    trace = build_trace(ag, program, entry)
    sim = EventSimulator(ag, trace)
    return sim.run(max_cycles)
