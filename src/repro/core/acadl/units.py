"""ACADL functional units (paper §3).

``FunctionalUnit`` executes Instructions passed to ``process()`` and changes
architectural state through the RegisterFiles it is connected to via
``READ_DATA``/``WRITE_DATA`` edges.  It can only process Instructions whose
``operation`` is in ``to_process`` *and* whose read/write register sets are
accessible through those edges.  Processing takes ``latency`` cycles once all
data dependencies from previous instructions are resolved.

``MemoryAccessUnit`` additionally accesses DataStorages;
``InstructionMemoryAccessUnit`` adds ``fetch()`` reading ``length``
instructions starting at ``address`` from the instruction memory.
"""

from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional, Sequence, Set, Tuple

from .base import ACADLObject, Data, Instruction, latency_t, LatencyLike, _as_latency
from .storage import DataStorage, RegisterFile

__all__ = [
    "FunctionalUnit",
    "MemoryAccessUnit",
    "InstructionMemoryAccessUnit",
]


class FunctionalUnit(ACADLObject):
    def __init__(self, name: str, to_process: Iterable[str] = (),
                 latency: LatencyLike = 1):
        super().__init__(name)
        self.to_process: Set[str] = set(to_process)
        self.latency = _as_latency(latency)
        # wired by ArchitectureGraph.finalize() from READ_DATA/WRITE_DATA edges
        self.readable_rfs: List[RegisterFile] = []
        self.writable_rfs: List[RegisterFile] = []

    # -- access checks ---------------------------------------------------------
    def _find_rf(self, rfs: Sequence[RegisterFile], reg: str) -> Optional[RegisterFile]:
        for rf in rfs:
            if rf.has(reg):
                return rf
        return None

    def can_access(self, instruction: Instruction) -> bool:
        """Register-set accessibility check (paper §3: FunctionalUnits can only
        process Instructions whose read/write registers are accessible)."""
        for reg in instruction.read_registers:
            if self._find_rf(self.readable_rfs, reg) is None:
                return False
        for reg in instruction.write_registers:
            if self._find_rf(self.writable_rfs, reg) is None:
                return False
        return True

    def supports(self, instruction: Instruction) -> bool:
        if instruction.operation not in self.to_process:
            return False
        if instruction.unit_hint is not None and instruction.unit_hint != self.name:
            return False
        return self.can_access(instruction)

    # -- functional simulation -------------------------------------------------
    def read(self, reg: str) -> Any:
        rf = self._find_rf(self.readable_rfs, reg)
        if rf is None:
            raise KeyError(f"{self.name}: no readable RegisterFile holds {reg!r}")
        return rf.read(reg)

    def write(self, reg: str, value: Any) -> None:
        rf = self._find_rf(self.writable_rfs, reg)
        if rf is None:
            raise KeyError(f"{self.name}: no writable RegisterFile holds {reg!r}")
        rf.write(reg, value)

    def process(self, instruction: Instruction) -> None:
        """Functional part of processing (timing is the simulator's job)."""
        from .base import ExecutionEnv

        env = ExecutionEnv(self.read, self.write, self._read_mem, self._write_mem)
        instruction.execute(env)

    # memory access is only available on MemoryAccessUnit
    def _read_mem(self, address: int) -> Any:
        raise TypeError(f"{type(self).__name__} {self.name!r} has no memory access")

    def _write_mem(self, address: int, value: Any) -> None:
        raise TypeError(f"{type(self).__name__} {self.name!r} has no memory access")


class MemoryAccessUnit(FunctionalUnit):
    """FunctionalUnit that additionally accesses DataStorages (paper §3)."""

    def __init__(self, name: str, to_process: Iterable[str] = ("load", "store"),
                 latency: LatencyLike = 1):
        super().__init__(name, to_process, latency)
        # wired by ArchitectureGraph.finalize()
        self.readable_storages: List[DataStorage] = []
        self.writable_storages: List[DataStorage] = []

    def _storage_for(self, storages: Sequence[DataStorage], address: int) -> Optional[DataStorage]:
        best = None
        for st in storages:
            cov = getattr(st, "covers", None)
            if cov is not None:
                if cov(address):
                    return st
            elif best is None:
                best = st
        return best

    def _read_mem(self, address: int) -> Any:
        st = self._storage_for(self.readable_storages, address)
        if st is None:
            raise KeyError(f"{self.name}: no readable DataStorage covers address {address:#x}")
        return st.read(address)

    def _write_mem(self, address: int, value: Any) -> None:
        st = self._storage_for(self.writable_storages, address)
        if st is None:
            raise KeyError(f"{self.name}: no writable DataStorage covers address {address:#x}")
        st.write(address, value)

    # -- timing helper: storage chain for an address ---------------------------
    def storage_chain(self, kind: str, address: int) -> List[DataStorage]:
        """The storages consulted for an access, nearest first.

        For a cache in front of a memory this is [cache, memory]; the
        simulator charges the cache's (hit|miss) latency, a miss already
        includes the backing-store trip (paper §6: after ``miss_latency``
        cycles the cache simulator is updated and the slot becomes ready).
        """
        storages = self.readable_storages if kind == "read" else self.writable_storages
        st = self._storage_for(storages, address)
        return [st] if st is not None else []


class InstructionMemoryAccessUnit(MemoryAccessUnit):
    """Adds ``fetch()``: read ``length`` instructions from instruction memory."""

    def __init__(self, name: str, latency: LatencyLike = 1):
        super().__init__(name, to_process=(), latency=latency)

    @property
    def instruction_memory(self) -> Optional[DataStorage]:
        return self.readable_storages[0] if self.readable_storages else None

    def fetch(self, address: int, length: int) -> List[Instruction]:
        imem = self.instruction_memory
        if imem is None:
            raise RuntimeError(f"{self.name}: no instruction memory connected")
        out: List[Instruction] = []
        for a in range(address, address + length):
            word = imem.read(a)
            if isinstance(word, Instruction):
                out.append(word)
        return out
