"""ACADL edges, dangling edges and the ``@generate``/``create_ag`` front-end.

The paper's Python front-end (§4) works as follows:

* architecture implementations are Python functions decorated with
  ``@generate``; calling the function registers every instantiated
  ``ACADLObject`` and ``ACADLEdge`` into an implicit builder and *implicitly
  checks the validity of all edges*;
* ``create_ag()`` then instantiates the architecture graph (AG);
* ``ACADLEdge(src, dst, edge_type)`` connects instantiated objects;
* ``ACADLDanglingEdge`` (aka ``DanglingEdge``) has only a source *or* a
  target and provides template interfaces; ``connect_dangling_edge()`` joins
  two dangling edges (or a dangling edge and an object) into a real edge,
  validity-checked against the class diagram.  Unconnected dangling edges
  simply never materialize.
"""

from __future__ import annotations

import enum
import functools
import threading
from typing import List, Optional, Union

__all__ = [
    "EdgeType",
    "READ_DATA",
    "WRITE_DATA",
    "CONTAINS",
    "FORWARD",
    "ACADLEdge",
    "ACADLDanglingEdge",
    "DanglingEdge",
    "connect_dangling_edge",
    "generate",
    "create_ag",
    "EdgeValidityError",
]


class EdgeType(enum.Enum):
    """Typed relations from the ACADL class diagram (Fig. 1)."""

    READ_DATA = "READ_DATA"      # association: caller reads data from callee (:read())
    WRITE_DATA = "WRITE_DATA"    # association: caller writes data to callee (:write())
    CONTAINS = "CONTAINS"        # composition: stage contains functional units
    FORWARD = "FORWARD"          # association: pipeline stage forwards instructions


READ_DATA = EdgeType.READ_DATA
WRITE_DATA = EdgeType.WRITE_DATA
CONTAINS = EdgeType.CONTAINS
FORWARD = EdgeType.FORWARD


class EdgeValidityError(TypeError):
    """Raised when an edge violates the ACADL class diagram."""


def _edge_is_valid(src, dst, edge_type: EdgeType) -> Optional[str]:
    """Return an error string when (src, dst, edge_type) violates Fig. 1.

    The admissible relations, per the class diagram and the modeling
    examples (§4):

    * FORWARD: PipelineStage -> PipelineStage (incl. ExecuteStage and
      InstructionFetchStage subclasses).
    * CONTAINS: ExecuteStage -> FunctionalUnit (incl. MemoryAccessUnit /
      InstructionMemoryAccessUnit subclasses).
    * READ_DATA: RegisterFile -> FunctionalUnit, DataStorage ->
      MemoryAccessUnit, DataStorage -> DataStorage (cache fill path, cf.
      ``ACADLEdge(dmem0, dcache0, READ_DATA)``), RegisterFile ->
      InstructionMemoryAccessUnit (pc read) and DataStorage ->
      InstructionMemoryAccessUnit (instruction memory read).
    * WRITE_DATA: FunctionalUnit -> RegisterFile, MemoryAccessUnit ->
      DataStorage, DataStorage -> DataStorage (write-back path),
      InstructionMemoryAccessUnit -> RegisterFile (pc increment) and
      FunctionalUnit -> FunctionalUnit register forwarding is *not* allowed —
      forwarding between template PEs goes through the neighbour's
      RegisterFile (cf. §4.2).
    """

    # Local imports: edges.py is imported by base.py at class-definition time.
    from .pipeline import PipelineStage, ExecuteStage
    from .units import FunctionalUnit, MemoryAccessUnit, InstructionMemoryAccessUnit
    from .storage import DataStorage, RegisterFile

    if edge_type is EdgeType.FORWARD:
        if isinstance(src, PipelineStage) and isinstance(dst, PipelineStage):
            return None
        return f"FORWARD requires PipelineStage -> PipelineStage, got {type(src).__name__} -> {type(dst).__name__}"

    if edge_type is EdgeType.CONTAINS:
        if isinstance(src, ExecuteStage) and isinstance(dst, FunctionalUnit):
            return None
        return f"CONTAINS requires ExecuteStage -> FunctionalUnit, got {type(src).__name__} -> {type(dst).__name__}"

    if edge_type is EdgeType.READ_DATA:
        if isinstance(src, RegisterFile) and isinstance(dst, FunctionalUnit):
            return None
        if isinstance(src, DataStorage) and isinstance(dst, (MemoryAccessUnit, InstructionMemoryAccessUnit)):
            return None
        if isinstance(src, DataStorage) and isinstance(dst, DataStorage):
            return None  # memory -> cache fill
        return (
            "READ_DATA requires RegisterFile->FunctionalUnit, DataStorage->MemoryAccessUnit "
            f"or DataStorage->DataStorage, got {type(src).__name__} -> {type(dst).__name__}"
        )

    if edge_type is EdgeType.WRITE_DATA:
        if isinstance(src, FunctionalUnit) and isinstance(dst, RegisterFile):
            return None
        if isinstance(src, MemoryAccessUnit) and isinstance(dst, DataStorage):
            return None
        if isinstance(src, DataStorage) and isinstance(dst, DataStorage):
            return None  # cache -> memory write-back
        return (
            "WRITE_DATA requires FunctionalUnit->RegisterFile, MemoryAccessUnit->DataStorage "
            f"or DataStorage->DataStorage, got {type(src).__name__} -> {type(dst).__name__}"
        )

    return f"unknown edge type {edge_type!r}"  # pragma: no cover


class ACADLEdge:
    """A validated, typed edge between two instantiated ACADL objects."""

    __slots__ = ("source", "target", "edge_type")

    def __init__(self, source, target, edge_type: EdgeType):
        err = _edge_is_valid(source, target, edge_type)
        if err is not None:
            raise EdgeValidityError(f"invalid edge {source!r} -> {target!r}: {err}")
        self.source = source
        self.target = target
        self.edge_type = edge_type
        builder = _current_builder()
        if builder is not None:
            builder.register_edge(self)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"ACADLEdge({self.source.name} -> {self.target.name}, {self.edge_type.value})"


class ACADLDanglingEdge:
    """An edge with only a source *or* a target (template interface).

    Unconnected dangling edges never instantiate an ``ACADLEdge``.
    """

    __slots__ = ("source", "target", "edge_type", "connected")

    def __init__(self, edge_type: EdgeType, source=None, target=None):
        if (source is None) == (target is None):
            raise ValueError("DanglingEdge needs exactly one of source/target")
        self.edge_type = edge_type
        self.source = source
        self.target = target
        self.connected = False

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        side = f"{self.source.name} ->" if self.source is not None else f"-> {self.target.name}"
        return f"DanglingEdge({side}, {self.edge_type.value})"


DanglingEdge = ACADLDanglingEdge  # paper uses both spellings


def connect_dangling_edge(a: Union[ACADLDanglingEdge, object], b: Union[ACADLDanglingEdge, object]) -> ACADLEdge:
    """Join two dangling edges — or a dangling edge and an ACADL object —
    into a validated ``ACADLEdge`` (paper §4.2).
    """

    from .base import ACADLObject

    def _is_dangling(x) -> bool:
        return isinstance(x, ACADLDanglingEdge)

    if _is_dangling(a) and _is_dangling(b):
        if a.edge_type is not b.edge_type:
            raise EdgeValidityError(
                f"cannot connect dangling edges of different types: {a.edge_type} vs {b.edge_type}"
            )
        src = a.source if a.source is not None else b.source
        dst = a.target if a.target is not None else b.target
        if src is None or dst is None:
            raise EdgeValidityError("connected dangling edges must supply one source and one target")
        edge = ACADLEdge(src, dst, a.edge_type)
        a.connected = b.connected = True
        return edge

    if _is_dangling(a) != _is_dangling(b):
        dangler, obj = (a, b) if _is_dangling(a) else (b, a)
        if not isinstance(obj, ACADLObject):
            raise EdgeValidityError(f"cannot connect dangling edge to non-ACADL object {obj!r}")
        if dangler.source is not None:
            edge = ACADLEdge(dangler.source, obj, dangler.edge_type)
        else:
            edge = ACADLEdge(obj, dangler.target, dangler.edge_type)
        dangler.connected = True
        return edge

    raise EdgeValidityError("connect_dangling_edge needs at least one dangling edge")


# ---------------------------------------------------------------------------
# Builder context: @generate + create_ag()
# ---------------------------------------------------------------------------


class _AGBuilder:
    def __init__(self) -> None:
        self.objects: List[object] = []
        self.edges: List[ACADLEdge] = []
        self._names = set()

    def register_object(self, obj) -> None:
        if obj.name in self._names:
            raise ValueError(f"duplicate ACADL object name {obj.name!r} — names are unique identifiers")
        self._names.add(obj.name)
        self.objects.append(obj)

    def register_edge(self, edge: ACADLEdge) -> None:
        self.edges.append(edge)


_tls = threading.local()


def _builder_stack() -> List[_AGBuilder]:
    stack = getattr(_tls, "stack", None)
    if stack is None:
        stack = _tls.stack = []
    return stack


def _current_builder() -> Optional[_AGBuilder]:
    stack = _builder_stack()
    return stack[-1] if stack else None


def generate(fn):
    """Decorator encapsulating an architecture implementation (paper §4.1).

    Calling the decorated function collects all objects/edges instantiated in
    its body (edge validity is checked at instantiation) and stores them for
    the next ``create_ag()`` call.  The decorated function's return value is
    passed through, so templates can hand back object handles.
    """

    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        builder = _AGBuilder()
        _builder_stack().append(builder)
        try:
            result = fn(*args, **kwargs)
        finally:
            _builder_stack().pop()
        _tls.last_builder = builder
        return result

    wrapper.__acadl_generate__ = True
    return wrapper


def create_ag():
    """Instantiate the AG of the most recently generated architecture."""

    from .graph import ArchitectureGraph

    builder = getattr(_tls, "last_builder", None)
    if builder is None:
        raise RuntimeError("create_ag() called before any @generate-decorated function ran")
    return ArchitectureGraph(builder.objects, builder.edges)
