"""Tiny label-resolving assembler for ACADL scalar programs.

Branch instructions take offsets relative to the next instruction; writing
loops by hand is error-prone, so ``ProgramBuilder`` provides labels:

    pb = ProgramBuilder()
    pb.emit(isa.movi("r1", 0))
    pb.label("loop")
    ...
    pb.branch_ne("r1", 8, "loop")
    program = pb.build()
"""

from __future__ import annotations

from typing import Callable, Dict, List, Tuple, Union

from . import isa
from .base import Instruction

__all__ = ["ProgramBuilder"]


class ProgramBuilder:
    def __init__(self) -> None:
        # entries: Instruction | ("branch", maker(offset)->Instruction, label)
        self._items: List[Union[Instruction, Tuple[str, Callable[[int], Instruction], str]]] = []
        self._labels: Dict[str, int] = {}

    def emit(self, instr: Instruction) -> "ProgramBuilder":
        self._items.append(instr)
        return self

    def label(self, name: str) -> "ProgramBuilder":
        if name in self._labels:
            raise ValueError(f"duplicate label {name!r}")
        self._labels[name] = len(self._items)
        return self

    def branch_eq(self, src: str, imm, label: str) -> "ProgramBuilder":
        self._items.append(("branch", lambda off: isa.beqi(src, imm, off), label))
        return self

    def branch_ne(self, src: str, imm, label: str) -> "ProgramBuilder":
        self._items.append(("branch", lambda off: isa.bnei(src, imm, off), label))
        return self

    def jump(self, label: str) -> "ProgramBuilder":
        self._items.append(("branch", lambda off: isa.jumpi(off), label))
        return self

    def build(self) -> List[Instruction]:
        program: List[Instruction] = []
        for i, item in enumerate(self._items):
            if isinstance(item, Instruction):
                program.append(item)
            else:
                _, maker, label = item
                if label not in self._labels:
                    raise ValueError(f"undefined label {label!r}")
                offset = self._labels[label] - (i + 1)
                program.append(maker(offset))
        return program

    def __len__(self) -> int:
        return len(self._items)
