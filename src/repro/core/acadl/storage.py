"""ACADL storage classes: RegisterFile, DataStorage hierarchy (paper §3).

``DataStorage`` is the virtual base for all data storages.  ``data_width`` is
the bit-length of one data word, ``max_concurrent_requests`` the number of
simultaneously serviced read/write requests (request *slots*, each with its
own latency counter in the timing simulation), ``read_write_ports`` how many
MemoryAccessUnits may connect, and ``port_width`` how many data words move in
a single transaction.  ``data`` maps addresses to words.

``MemoryInterface`` adds read/write latencies and address ranges; ``DRAM``
and ``SRAM`` override the latencies with stateful functions (DRAM: row-buffer
model driven by ``bank_address_ranges``/``t_RCD``/``t_RP``/``t_RAS``);
``CacheInterface``/``SetAssociativeCache`` add the usual cache attributes and
an internal set-associative cache simulator (the paper defers to pycachesim —
we implement an equivalent LRU/FIFO model in-tree to stay dependency-free).
"""

from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

from .base import ACADLObject, Data, latency_t, LatencyLike, _as_latency

__all__ = [
    "RegisterFile",
    "DataStorage",
    "MemoryInterface",
    "SRAM",
    "DRAM",
    "CacheInterface",
    "SetAssociativeCache",
]


class RegisterFile(ACADLObject):
    """Maps unique register names to values (paper §3)."""

    def __init__(self, name: str, data_width: int = 32,
                 registers: Optional[Dict[str, Data]] = None):
        super().__init__(name)
        self.data_width = data_width
        self.registers: Dict[str, Data] = dict(registers or {})

    def read(self, reg: str) -> Any:
        if reg not in self.registers:
            raise KeyError(f"register {reg!r} not in RegisterFile {self.name!r}")
        return self.registers[reg].payload

    def write(self, reg: str, value: Any) -> None:
        if reg not in self.registers:
            # registers are declared up-front; writing to an undeclared
            # register is a modeling error, except for auto-extensible files
            raise KeyError(f"register {reg!r} not in RegisterFile {self.name!r}")
        self.registers[reg].payload = value

    def has(self, reg: str) -> bool:
        return reg in self.registers


class DataStorage(ACADLObject):
    """Virtual base class for all data storages."""

    def __init__(self, name: str, data_width: int = 32,
                 max_concurrent_requests: int = 1,
                 read_write_ports: int = 1,
                 port_width: int = 1,
                 data: Optional[Dict[int, Any]] = None):
        if type(self) is DataStorage:
            raise TypeError("DataStorage is a virtual base class — instantiate a subclass")
        super().__init__(name)
        self.data_width = data_width
        self.max_concurrent_requests = max_concurrent_requests
        self.read_write_ports = read_write_ports
        self.port_width = port_width
        self.data: Dict[int, Any] = dict(data or {})

    # -- functional simulation -------------------------------------------------
    def read(self, address: int) -> Any:
        return self.data.get(address, 0)

    def write(self, address: int, value: Any) -> None:
        self.data[address] = value

    # -- timing model ------------------------------------------------------------
    def timing_reset(self) -> None:
        """Reset stateful latency models (row buffers, cache tags)."""

    def access_latency(self, kind: str, address: int, words: int = 1) -> int:
        """Latency in cycles of a ``read``/``write`` transaction of ``words``
        data words (tensor-level instructions move whole tiles; ``port_width``
        words transfer per cycle once the transaction is open).

        Stateful: calling order matters for DRAM row buffers and caches.
        """
        raise NotImplementedError

    def burst_cycles(self, words: int) -> int:
        """Extra cycles past the first transaction beat for a ``words``-word
        burst at ``port_width`` words/cycle."""
        if words <= self.port_width:
            return 0
        return (words + self.port_width - 1) // self.port_width - 1


class MemoryInterface(DataStorage):
    """Adds read/write latencies and address ranges to DataStorage."""

    def __init__(self, name: str,
                 read_latency: LatencyLike = 1,
                 write_latency: LatencyLike = 1,
                 address_ranges: Sequence[Tuple[int, int]] = ((0, 2 ** 32),),
                 **kw):
        super().__init__(name, **kw)
        self.read_latency = _as_latency(read_latency)
        self.write_latency = _as_latency(write_latency)
        self.address_ranges: Tuple[Tuple[int, int], ...] = tuple(tuple(r) for r in address_ranges)

    def covers(self, address: int) -> bool:
        return any(lo <= address < hi for lo, hi in self.address_ranges)

    def access_latency(self, kind: str, address: int, words: int = 1) -> int:
        lat = self.read_latency if kind == "read" else self.write_latency
        return lat.resolve(address=address) + self.burst_cycles(words)


class SRAM(MemoryInterface):
    """SRAM: constant-latency memory (scratchpads, instruction memories)."""


class DRAM(MemoryInterface):
    """DRAM with a stateful open-row latency model (paper §3).

    ``bank_address_ranges`` partitions the address space into banks; each
    bank has an open-row register.  A row holds ``row_size`` words.

    Latency of an access (simplified DDR timing, consistent with the paper's
    ``t_RCD``/``t_RP``/``t_RAS`` attributes):

    * row hit   : base latency (CAS, = read/write_latency)
    * row miss  : t_RP (precharge) + t_RCD (activate) + base
    * bank idle : t_RCD (activate) + base
    """

    def __init__(self, name: str,
                 bank_address_ranges: Sequence[Tuple[int, int]] = ((0, 2 ** 32),),
                 t_RCD: int = 8, t_RP: int = 8, t_RAS: int = 20,
                 row_size: int = 1024, **kw):
        kw.setdefault("read_latency", 10)
        kw.setdefault("write_latency", 10)
        super().__init__(name, **kw)
        self.bank_address_ranges = tuple(tuple(r) for r in bank_address_ranges)
        self.t_RCD = t_RCD
        self.t_RP = t_RP
        self.t_RAS = t_RAS
        self.row_size = row_size
        self._open_rows: Dict[int, Optional[int]] = {}

    def timing_reset(self) -> None:
        self._open_rows = {}

    def _bank_of(self, address: int) -> int:
        for i, (lo, hi) in enumerate(self.bank_address_ranges):
            if lo <= address < hi:
                return i
        return len(self.bank_address_ranges)  # out-of-range: synthetic bank

    def access_latency(self, kind: str, address: int, words: int = 1) -> int:
        base = (self.read_latency if kind == "read" else self.write_latency).resolve(address=address)
        bank = self._bank_of(address)
        row = address // self.row_size
        open_row = self._open_rows.get(bank)
        if open_row is None:
            lat = self.t_RCD + base
        elif open_row == row:
            lat = base
        else:
            lat = self.t_RP + self.t_RCD + base
        self._open_rows[bank] = row
        return lat + self.burst_cycles(words)


class CacheInterface(DataStorage):
    """Adds common cache attributes to DataStorage (paper §3)."""

    def __init__(self, name: str,
                 write_allocate: bool = True,
                 write_back: bool = True,
                 miss_latency: LatencyLike = 10,
                 hit_latency: LatencyLike = 1,
                 cache_line_size: int = 8,
                 replacement_policy: str = "LRU",
                 **kw):
        if type(self) is CacheInterface:
            raise TypeError("CacheInterface is abstract — use SetAssociativeCache")
        super().__init__(name, **kw)
        self.write_allocate = write_allocate
        self.write_back = write_back
        self.miss_latency = _as_latency(miss_latency)
        self.hit_latency = _as_latency(hit_latency)
        self.cache_line_size = cache_line_size
        self.replacement_policy = replacement_policy
        self.backing: Optional[DataStorage] = None  # wired from the AG fill edges

    # functional read-through / write-through against the backing store, so
    # caches are transparent to the functional simulation
    def read(self, address: int) -> Any:
        if address in self.data:
            return self.data[address]
        if self.backing is not None:
            return self.backing.read(address)
        return 0

    def write(self, address: int, value: Any) -> None:
        self.data[address] = value
        if self.backing is not None:
            self.backing.write(address, value)

    def covers(self, address: int) -> bool:
        if self.backing is None:
            return True
        cov = getattr(self.backing, "covers", None)
        return cov(address) if cov is not None else True


class SetAssociativeCache(CacheInterface):
    """Set-associative cache with an in-tree LRU/FIFO tag simulator.

    §6: on a miss, the request slot's latency counter is set to
    ``miss_latency``; after it elapses the tag state is updated and the slot
    is ready.  Hits take ``hit_latency``.
    """

    def __init__(self, name: str, sets: int = 64, ways: int = 4, **kw):
        super().__init__(name, **kw)
        self.sets = sets
        self.ways = ways
        # tag state: per set, ordered list of line tags (front = LRU victim)
        self._tags: List[List[int]] = [[] for _ in range(sets)]

    def timing_reset(self) -> None:
        self._tags = [[] for _ in range(self.sets)]

    def _locate(self, address: int) -> Tuple[int, int]:
        line = address // self.cache_line_size
        return line % self.sets, line // self.sets  # (set index, tag)

    def probe(self, address: int) -> bool:
        """True iff address currently hits (no state change)."""
        s, tag = self._locate(address)
        return tag in self._tags[s]

    def access_latency(self, kind: str, address: int, words: int = 1) -> int:
        s, tag = self._locate(address)
        ways = self._tags[s]
        hit = tag in ways
        if hit:
            if self.replacement_policy.upper() == "LRU":
                ways.remove(tag)
                ways.append(tag)  # most-recently-used at the back
            return self.hit_latency.resolve(address=address) + self.burst_cycles(words)
        # miss — allocate (reads always; writes only with write_allocate)
        if kind == "read" or self.write_allocate:
            if len(ways) >= self.ways:
                ways.pop(0)  # evict LRU/FIFO front
            ways.append(tag)
        return self.miss_latency.resolve(address=address) + self.burst_cycles(words)
