"""ACADL pipeline stages (paper §3).

``PipelineStage`` forwards instructions: ``receive()`` is called by another
stage's ``forward()``; an instruction can only be forwarded if the receiving
stage is ``ready()``; it resides ``latency`` cycles before being forwarded.

``ExecuteStage`` inherits from PipelineStage and contains FunctionalUnits.
On receive it checks whether a contained unit supports the instruction
(operation in ``to_process`` + register accessibility); if so the unit
processes it and the ExecuteStage's own latency is *not* accumulated.

``InstructionFetchStage`` inherits from ExecuteStage, owns an issue buffer of
``issue_buffer_size`` instructions, fetches through a contained
InstructionMemoryAccessUnit every cycle while space remains, and may forward
multiple instructions out-of-order in the same clock cycle.
"""

from __future__ import annotations

from typing import List, Optional

from .base import ACADLObject, Instruction, latency_t, LatencyLike, _as_latency
from .units import FunctionalUnit, InstructionMemoryAccessUnit

__all__ = ["PipelineStage", "ExecuteStage", "InstructionFetchStage"]


class PipelineStage(ACADLObject):
    def __init__(self, name: str, latency: LatencyLike = 1):
        super().__init__(name)
        self.latency = _as_latency(latency)
        # wired by ArchitectureGraph.finalize() from FORWARD edges
        self.forward_targets: List["PipelineStage"] = []

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"{type(self).__name__}(name={self.name!r}, latency={self.latency!r})"


class ExecuteStage(PipelineStage):
    def __init__(self, name: str, latency: LatencyLike = 1):
        super().__init__(name, latency)
        # wired by ArchitectureGraph.finalize() from CONTAINS edges
        self.functional_units: List[FunctionalUnit] = []

    def unit_for(self, instruction: Instruction) -> Optional[FunctionalUnit]:
        """First contained FunctionalUnit that supports the instruction."""
        for fu in self.functional_units:
            if fu.supports(instruction):
                return fu
        return None


class InstructionFetchStage(ExecuteStage):
    def __init__(self, name: str, latency: LatencyLike = 1, issue_buffer_size: int = 4):
        super().__init__(name, latency)
        self.issue_buffer_size = issue_buffer_size

    @property
    def imau(self) -> Optional[InstructionMemoryAccessUnit]:
        for fu in self.functional_units:
            if isinstance(fu, InstructionMemoryAccessUnit):
                return fu
        return None
