"""repro.core — the paper's contribution: ACADL + AIDG + accelerator zoo + mapping."""
