"""The surrogate tier of the staged oracle hierarchy (ROADMAP item 3).

``surrogate → packed → wavefront → event sim``: tiny monotone closed-form
models (:mod:`repro.surrogate.model`) distilled from the packed oracle's
sweep outputs (:mod:`repro.surrogate.train`), predicting per-cell latency
AND energy with calibrated per-cell confidence bounds.  ``repro.serve``
answers from this tier when every queried cell's bound clears the
service threshold and falls back to the packed dispatch otherwise; the
cross-engine agreement of the whole chain is asserted in one place by
``tests/test_oracle_chain.py``.
"""

from .model import (DEFAULT_GROUPS, DEFAULT_PATHS, init_cell_params,
                    init_stacked_params, predict_rel, predict_rel_cells)
from .train import (SurrogateBundle, SurrogateConfig, evaluate_surrogate,
                    train_surrogate)

__all__ = [
    "DEFAULT_GROUPS", "DEFAULT_PATHS", "init_cell_params",
    "init_stacked_params", "predict_rel", "predict_rel_cells",
    "SurrogateBundle", "SurrogateConfig", "evaluate_surrogate",
    "train_surrogate",
]
