"""Train and calibrate the surrogate tier on packed-oracle sweeps.

The pipeline (all driven by one fixed seed, so artifacts are exactly
reproducible):

1. **Sample** — log-uniform knob candidates over the design box
   (``random_candidates``, row 0 = θ = 1), evaluated by the packed
   oracle's sweep export (:meth:`PackedMatrix.export_training_table`):
   one dispatch for every cell × every sample, both objectives.
2. **Fit** — every cell's monotone closed form
   (:mod:`repro.surrogate.model`) trains *jointly* as one stacked pytree:
   ``jax.vmap`` over cells inside a jitted ``lax.scan`` of
   ``repro.optim.adamw`` steps, minimizing mean squared *relative* error
   of both heads against the baseline-normalized sweep outputs.
3. **Calibrate** — residual quantiles on a held-out split become each
   cell's stated confidence bound: ``err_bound = margin · q(residuals)``.
   The serving tier answers from the surrogate only where that bound
   clears its threshold, so calibration is what makes the fast tier
   honest.

The :class:`SurrogateBundle` is the deployable artifact: stacked
parameters, per-cell baselines, calibrated bounds, and denormalizing
predictors — savable to one ``.npz`` (``tools/train_surrogate.py``).
"""

from __future__ import annotations

import json
from dataclasses import asdict, dataclass
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..optim.adamw import AdamWConfig, adamw_init, adamw_update
from .model import (DEFAULT_GROUPS, DEFAULT_PATHS, _MIN_TAU,
                    init_stacked_params, predict_rel, predict_rel_cells)

__all__ = ["SurrogateConfig", "SurrogateBundle", "train_surrogate",
           "evaluate_surrogate"]


def _np_softplus(x: np.ndarray) -> np.ndarray:
    """Overflow-stable host-side softplus (float32 in, float32 out)."""
    return np.logaddexp(np.float32(0.0), x, dtype=np.float32)


@dataclass(frozen=True)
class SurrogateConfig:
    """Training/calibration hyperparameters (all defaults fixed-seed
    reproducible).  ``n_samples`` log-uniform sweep draws (row 0 = θ = 1)
    split ``holdout`` to the calibration set; ``steps`` AdamW steps at
    ``lr`` with cosine decay; ``quantile`` × ``bound_margin`` turn
    held-out residuals into each cell's stated confidence bound;
    ``chunk`` bounds the export's device batch (memory cap)."""

    groups: int = DEFAULT_GROUPS
    paths: int = DEFAULT_PATHS
    n_samples: int = 192
    holdout: float = 0.25
    steps: int = 1500
    lr: float = 0.03
    seed: int = 0
    quantile: float = 0.95
    bound_margin: float = 1.5
    chunk: Optional[int] = 64


class SurrogateBundle:
    """The trained surrogate tier for one served matrix: stacked per-cell
    parameters, θ = 1 baselines (denormalization), and the calibrated
    per-cell confidence bounds the staged router checks.

    ``predict_full`` mirrors ``PackedMatrix.evaluate_full`` — ``(B, K)``
    candidates → ``((B, S) cycles, (B, S) energy pJ)`` — but as a pure
    NumPy closed form on the host: a few tens of thousands of flops with
    NO device dispatch, which is the whole point of the tier (a jitted
    call would pay ~1 ms of dispatch overhead per query and eat the
    entire speedup over the packed engine)."""

    def __init__(self, cell_names: Sequence[str], knob_names: Sequence[str],
                 params: Dict[str, jnp.ndarray], cycles_base: np.ndarray,
                 energy_base: np.ndarray, err_latency: np.ndarray,
                 err_energy: np.ndarray, err_bound: np.ndarray,
                 meta: Optional[Dict] = None):
        self.cell_names = tuple(cell_names)
        self.knob_names = tuple(knob_names)
        self.params = jax.tree.map(jnp.asarray, params)
        self.cycles_base = np.asarray(cycles_base, np.float64)
        self.energy_base = np.asarray(energy_base, np.float64)
        self.err_latency = np.asarray(err_latency, np.float64)
        self.err_energy = np.asarray(err_energy, np.float64)
        self.err_bound = np.asarray(err_bound, np.float64)
        self.meta = dict(meta or {})
        # serving-path fast weights: softplus applied once, host numpy
        p = {k: np.asarray(v, np.float32) for k, v in self.params.items()}
        self._np_a = p["a"]                                   # (S, G, J)
        self._np_w = _np_softplus(p["w_raw"])                 # (S, G, J, K)
        self._np_tau = _np_softplus(p["tau_raw"]) + _MIN_TAU  # (S, G)
        self._np_alpha = _np_softplus(p["alpha_raw"])         # (S, K)
        self._np_beta = _np_softplus(p["beta_raw"])           # (S,)
        self._np_gamma = _np_softplus(p["gamma_raw"])         # (S,)

    # -- shape ---------------------------------------------------------------

    @property
    def n_cells(self) -> int:
        """Matrix cells this bundle predicts (leading params axis)."""
        return len(self.cell_names)

    @property
    def n_knobs(self) -> int:
        """Design-space knobs the surrogate was trained over."""
        return len(self.knob_names)

    # -- prediction ----------------------------------------------------------

    def predict_rel(self, knob_thetas: np.ndarray
                    ) -> Tuple[np.ndarray, np.ndarray]:
        """``(B, K)`` candidates → ``((B, S), (B, S))`` latency/energy
        ratios relative to the θ = 1 reference machine.  Pure host
        NumPy — same closed form as :func:`repro.surrogate.model
        .predict_rel`, float32 throughout."""
        kt = np.atleast_2d(np.asarray(knob_thetas, np.float32))
        # affine paths: (S, B, G, J) = a + kt . softplus(w)
        z = (self._np_a[:, None]
             + np.einsum("bk,sgjk->sbgj", kt, self._np_w))
        # stable logsumexp over the path axis, temperature per (S, G)
        zt = z / self._np_tau[:, None, :, None]
        m = zt.max(axis=3, keepdims=True)
        lse = np.squeeze(m, 3) + np.log(
            np.exp(zt - m).sum(axis=3, dtype=np.float32))
        lat = (self._np_tau[:, None, :] * lse).sum(axis=2)    # (S, B)
        en = ((1.0 / kt) @ self._np_alpha.T).T \
            + self._np_beta[:, None] * lat + self._np_gamma[:, None]
        return (lat.T.astype(np.float32, copy=False),
                en.T.astype(np.float32, copy=False))

    def predict_full(self, knob_thetas: np.ndarray
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """``(B, K)`` candidates → ``((B, S) cycles, (B, S) energy pJ)``
        — the surrogate's drop-in analogue of the packed oracle's
        ``evaluate_full``, denormalized by the recorded baselines."""
        lat, en = self.predict_rel(knob_thetas)
        return (np.asarray(lat * self.cycles_base[None, :], np.float32),
                np.asarray(en * self.energy_base[None, :], np.float32))

    # -- confidence ----------------------------------------------------------

    def confident(self, cols: Optional[Sequence[int]] = None,
                  max_err: float = 0.02) -> bool:
        """Whether EVERY cell in ``cols`` (default: all) carries a stated
        confidence bound at or under ``max_err`` — the staged router's
        per-cell threshold check."""
        b = self.err_bound if cols is None \
            else self.err_bound[np.asarray(cols, np.int64)]
        return bool(b.size) and bool(np.all(b <= max_err))

    # -- persistence ---------------------------------------------------------

    def save(self, path) -> None:
        """Serialize to one ``.npz`` (parameters, baselines, bounds, and
        a JSON metadata record) — ``load`` restores an identical bundle."""
        flat = {f"param.{k}": np.asarray(v) for k, v in self.params.items()}
        np.savez(
            path, **flat,
            cycles_base=self.cycles_base, energy_base=self.energy_base,
            err_latency=self.err_latency, err_energy=self.err_energy,
            err_bound=self.err_bound,
            cell_names=np.asarray(self.cell_names),
            knob_names=np.asarray(self.knob_names),
            meta=np.asarray(json.dumps(self.meta)))

    @classmethod
    def load(cls, path) -> "SurrogateBundle":
        """Restore a bundle saved by :meth:`save`."""
        with np.load(path, allow_pickle=False) as z:
            params = {k[len("param."):]: jnp.asarray(z[k])
                      for k in z.files if k.startswith("param.")}
            return cls(
                cell_names=[str(s) for s in z["cell_names"]],
                knob_names=[str(s) for s in z["knob_names"]],
                params=params, cycles_base=z["cycles_base"],
                energy_base=z["energy_base"],
                err_latency=z["err_latency"], err_energy=z["err_energy"],
                err_bound=z["err_bound"],
                meta=json.loads(str(z["meta"])))


def _fit(key: jax.Array, kt: np.ndarray, y_lat: np.ndarray,
         y_en: np.ndarray, cfg: SurrogateConfig) -> Dict[str, jnp.ndarray]:
    """Joint fit of all cells: one stacked pytree, one jitted scan of
    AdamW steps minimizing mean squared relative error of both heads."""
    S = y_lat.shape[1]
    params = init_stacked_params(key, S, kt.shape[1],
                                 cfg.groups, cfg.paths)
    ktj = jnp.asarray(kt, jnp.float32)
    ylj = jnp.asarray(y_lat.T, jnp.float32)      # (S, N)
    yej = jnp.asarray(y_en.T, jnp.float32)

    def loss(p):
        def cell(pc, yl, ye):
            pl, pe = predict_rel(pc, ktj)
            return (jnp.mean(jnp.square((pl - yl) / yl))
                    + jnp.mean(jnp.square((pe - ye) / ye)))
        return jnp.mean(jax.vmap(cell)(p, ylj, yej))

    total = max(1, cfg.steps)
    opt = AdamWConfig(
        lr=cfg.lr, weight_decay=0.0, clip_norm=1.0,
        schedule=lambda step: 0.5 * (1.0 + jnp.cos(
            jnp.pi * jnp.minimum(step.astype(jnp.float32) / total, 1.0))))
    state = adamw_init(params)

    def step(carry, _):
        p, st = carry
        l, g = jax.value_and_grad(loss)(p)
        p, st, _ = adamw_update(opt, p, g, st)
        return (p, st), l

    (params, _), losses = jax.lax.scan(step, (params, state), None,
                                       length=cfg.steps)
    return jax.tree.map(lambda a: jax.device_get(a), params), losses


def _rel_err(pred: np.ndarray, truth: np.ndarray) -> np.ndarray:
    """Elementwise |pred − truth| / truth (truth is strictly positive —
    cycle counts and pJ)."""
    return np.abs(pred - truth) / np.maximum(np.abs(truth), 1e-12)


def train_surrogate(explorer, config: Optional[SurrogateConfig] = None
                    ) -> SurrogateBundle:
    """Train + calibrate a :class:`SurrogateBundle` for ``explorer``'s
    packed matrix (the module-docstring pipeline).  Deterministic given
    ``config.seed``; the explorer must use the packed engine (it is the
    training oracle)."""
    from ..core.aidg.explorer import random_candidates

    cfg = config or SurrogateConfig()
    pm = explorer.packed_matrix()
    kt = random_candidates(explorer.space, cfg.n_samples, seed=cfg.seed)
    table = pm.export_training_table(kt, chunk=cfg.chunk)
    y_lat = table["cycles"] / table["cycles_base"][None, :]
    y_en = table["energy"] / table["energy_base"][None, :]

    # held-out split: seeded permutation of the non-reference rows (the
    # θ = 1 row always trains — the bundle must be anchored at 1.0)
    n = kt.shape[0]
    rng = np.random.default_rng(cfg.seed + 1)
    perm = 1 + rng.permutation(n - 1)
    n_hold = max(1, int(round(cfg.holdout * n)))
    hold, tr = perm[:n_hold], np.concatenate([[0], perm[n_hold:]])

    params, _ = _fit(jax.random.PRNGKey(cfg.seed), kt[tr], y_lat[tr],
                     y_en[tr], cfg)

    # calibration: held-out residual quantiles -> stated per-cell bounds
    pl, pe = predict_rel_cells(jax.tree.map(jnp.asarray, params),
                               jnp.asarray(kt[hold], jnp.float32))
    e_lat = _rel_err(np.asarray(pl).T, y_lat[hold])     # (H, S)
    e_en = _rel_err(np.asarray(pe).T, y_en[hold])
    q_lat = np.quantile(e_lat, cfg.quantile, axis=0)
    q_en = np.quantile(e_en, cfg.quantile, axis=0)
    bound = cfg.bound_margin * np.maximum(q_lat, q_en)

    names = [cs.name for cs in explorer.compiled]
    return SurrogateBundle(
        cell_names=names, knob_names=explorer.space.names, params=params,
        cycles_base=table["cycles_base"], energy_base=table["energy_base"],
        err_latency=q_lat, err_energy=q_en, err_bound=bound,
        meta={"config": asdict(cfg), "n_train": int(tr.size),
              "n_holdout": int(hold.size)})


def evaluate_surrogate(bundle: SurrogateBundle, explorer, n: int = 48,
                       seed: int = 1234) -> Dict[str, object]:
    """Fresh-sample evaluation report: ``n`` seeded draws the training
    never saw, scored against the packed oracle.  Returns per-cell
    relative-error arrays plus the matrix-wide medians and the per-cell
    within-stated-bound coverage — the numbers the oracle-chain tier,
    the surrogate-smoke CI job, and ``docs/surrogate.md`` quote."""
    from ..core.aidg.explorer import random_candidates

    kt = random_candidates(explorer.space, n, seed=seed,
                           include_baseline=False)
    cyc, en = explorer.evaluate_full(kt)
    p_cyc, p_en = bundle.predict_full(kt)
    e_lat = _rel_err(np.asarray(p_cyc, np.float64),
                     np.asarray(cyc, np.float64))       # (n, S)
    e_en = _rel_err(np.asarray(p_en, np.float64),
                    np.asarray(en, np.float64))
    cover = np.mean(e_lat <= bundle.err_bound[None, :], axis=0)
    return {
        "err_latency": e_lat, "err_energy": e_en,
        "median_latency_err": float(np.median(e_lat)),
        "median_energy_err": float(np.median(e_en)),
        "bound_coverage": cover,
        "cells": list(bundle.cell_names),
    }
