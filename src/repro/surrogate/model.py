"""Monotone closed-form surrogate models for the packed oracle.

The exact engines compute a cell's latency as a composition of ``max`` /
``sum`` / affine steps with nonnegative θ coefficients (wavefront levels,
queue folds, run-length network composition), so ``T(θ)`` is a convex
piecewise-linear, monotone-nondecreasing function of the knob vector on
the design box.  The surrogate mirrors that structure instead of using a
generic MLP: per cell, a *sum of softened maxima of affine functions*

``lat(θ) = Σ_g  τ_g · logsumexp_j[(a_gj + w_gj · θ) / τ_g]``,
``w = softplus(raw) ≥ 0``

(``G`` groups ≈ composed layer runs, ``J`` paths per group ≈ competing
critical paths).  Nonnegative weights make every prediction **provably
monotone nondecreasing in each θ knob** — the same direction the exact
engine provably has — which the Hypothesis property tests pin down.
Energy reuses the engine's own closed form ``E(θ) = edyn · (1/θ) + const
+ static · T(θ)`` with learned nonnegative coefficients:

``en(θ) = α · (1/θ) + β · lat(θ) + γ``,  ``α, β, γ ≥ 0``.

Both heads predict ratios relative to the θ = 1 reference machine; the
:class:`repro.surrogate.train.SurrogateBundle` denormalizes with the
recorded baselines.  Parameters per cell are tiny (G·J·(K+1) + K + 2
floats), so all cells train jointly as one stacked pytree via
``jax.vmap`` + ``repro.optim.adamw``.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["init_cell_params", "predict_rel", "predict_rel_cells",
           "init_stacked_params", "DEFAULT_GROUPS", "DEFAULT_PATHS"]

# Default surrogate shape: 4 composition groups x 8 affine paths covers
# the default matrix (single-operator cells use the spare groups as
# near-zero terms; deep network cells spread their run structure).
DEFAULT_GROUPS = 4
DEFAULT_PATHS = 8

_MIN_TAU = 1e-3       # LSE temperature floor (exact-max limit stays off)


def _inv_softplus(y: float) -> float:
    """The raw value whose softplus is ``y`` (for parameter init)."""
    return float(math.log(math.expm1(max(y, 1e-6))))


def init_cell_params(key: jax.Array, n_knobs: int,
                     groups: int = DEFAULT_GROUPS,
                     paths: int = DEFAULT_PATHS) -> Dict[str, jnp.ndarray]:
    """Fresh single-cell parameters (a dict pytree), initialized so the
    latency head predicts ≈ 1 at θ = 1 (each group contributes ≈ 1/G and
    path weights start near ``1 / (G · K)``) with small seeded jitter to
    break path symmetry."""
    kw, ka, ke = jax.random.split(key, 3)
    w0 = _inv_softplus(1.0 / (groups * n_knobs))
    return {
        "a": 0.02 * jax.random.normal(ka, (groups, paths), jnp.float32),
        "w_raw": w0 + 0.25 * jax.random.normal(
            kw, (groups, paths, n_knobs), jnp.float32),
        "tau_raw": jnp.full((groups,), _inv_softplus(0.05), jnp.float32),
        "alpha_raw": _inv_softplus(0.1 / n_knobs)
        + 0.1 * jax.random.normal(ke, (n_knobs,), jnp.float32),
        "beta_raw": jnp.asarray(_inv_softplus(0.5), jnp.float32),
        "gamma_raw": jnp.asarray(_inv_softplus(0.1), jnp.float32),
    }


def init_stacked_params(key: jax.Array, n_cells: int, n_knobs: int,
                        groups: int = DEFAULT_GROUPS,
                        paths: int = DEFAULT_PATHS) -> Dict[str, jnp.ndarray]:
    """Per-cell parameters stacked along a leading cell axis — the pytree
    the joint training loop (and :func:`predict_rel_cells`) consumes."""
    keys = jax.random.split(key, n_cells)
    return jax.vmap(lambda k: init_cell_params(k, n_knobs, groups, paths)
                    )(keys)


def predict_rel(params: Dict[str, jnp.ndarray], kt: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One cell's surrogate forward pass: ``(B, K)`` knob candidates →
    ``((B,) latency ratio, (B,) energy ratio)`` relative to θ = 1.

    Latency is the sum-of-softmax closed form from the module docstring;
    with ``softplus`` weights it is monotone nondecreasing in every knob
    for any parameter values.  Energy is the engine's analytic shape with
    learned nonnegative coefficients (its ``α/θ`` term falls, its
    ``β · lat`` term rises with θ — exactly like the exact objective)."""
    kt = jnp.asarray(kt, jnp.float32)
    w = jax.nn.softplus(params["w_raw"])            # (G, J, K) >= 0
    tau = jax.nn.softplus(params["tau_raw"]) + _MIN_TAU   # (G,)
    # affine paths: (B, G, J) = a + kt . w
    z = params["a"][None] + jnp.einsum("bk,gjk->bgj", kt, w)
    lat = jnp.sum(tau[None, :]
                  * jax.scipy.special.logsumexp(z / tau[None, :, None],
                                                axis=2), axis=1)
    alpha = jax.nn.softplus(params["alpha_raw"])    # (K,) >= 0
    beta = jax.nn.softplus(params["beta_raw"])
    gamma = jax.nn.softplus(params["gamma_raw"])
    en = (1.0 / kt) @ alpha + beta * lat + gamma
    return lat, en


# Stacked-cell forward pass: params carry a leading cell axis, the
# candidate batch is shared -> ((S, B) latency ratios, (S, B) energy
# ratios).  This is the serving-path entry point (one tiny dispatch for
# a whole candidate block across every queried cell).
predict_rel_cells = jax.vmap(predict_rel, in_axes=(0, None))
