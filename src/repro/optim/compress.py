"""Gradient compression for the cross-pod all-reduce (DESIGN.md §6).

The ``pod`` mesh axis crosses the slow inter-pod links (DCN); compressing
gradients before that all-reduce trades a little precision for 2-4x less
DCN traffic:

* ``compress_bf16`` — stochastic-rounded bf16 (2x).
* ``compress_int8`` / ``decompress_int8`` — per-tensor absmax int8 (4x)
  with ``error_feedback_update`` keeping a residual so quantization error
  accumulates into later steps instead of being lost (EF-SGD style).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["compress_bf16", "compress_int8", "decompress_int8",
           "error_feedback_update"]


def compress_bf16(tree, key: jax.Array):
    """Stochastic rounding f32 -> bf16 (unbiased under averaging)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))

    def sr(x, k):
        x = x.astype(jnp.float32)
        lo = x.astype(jnp.bfloat16)
        lo32 = lo.astype(jnp.float32)
        # next bf16 grid point toward x: one bf16 ULP via bit manipulation
        # (nextafter would step one *f32* ULP, which collapses back to lo)
        bits = jax.lax.bitcast_convert_type(lo, jnp.uint16).astype(jnp.int32)
        toward_up = x > lo32
        neg = lo32 < 0
        step = jnp.where(toward_up != neg, 1, -1)
        hi = jax.lax.bitcast_convert_type(
            (bits + step).astype(jnp.uint16), jnp.bfloat16)
        hi32 = hi.astype(jnp.float32)
        span = jnp.where(hi32 != lo32, jnp.abs(hi32 - lo32), 1.0)
        p_hi = jnp.clip(jnp.abs(x - lo32) / span, 0.0, 1.0)
        u = jax.random.uniform(k, x.shape)
        return jnp.where(u < p_hi, hi, lo)

    return treedef.unflatten([sr(x, k) for x, k in zip(leaves, keys)])


def compress_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tensor absmax int8 quantization -> (q, scale)."""
    x = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def error_feedback_update(grad: jnp.ndarray, residual: jnp.ndarray
                          ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """EF: compress (grad + residual); the new residual is what the
    quantizer dropped.  Returns (q, scale, new_residual)."""
    g = grad.astype(jnp.float32) + residual
    q, scale = compress_int8(g)
    new_residual = g - decompress_int8(q, scale)
    return q, scale, new_residual
