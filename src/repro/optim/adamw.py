"""AdamW with decoupled weight decay and global-norm clipping."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm"]


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    schedule: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda p: jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), p)
    return {"step": jnp.zeros((), jnp.int32), "m": zeros(params),
            "v": zeros(params)}


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(jax.tree_util.tree_reduce(
        lambda acc, g: acc + jnp.sum(jnp.square(g.astype(jnp.float32))),
        tree, jnp.zeros((), jnp.float32)))


def adamw_update(cfg: AdamWConfig, params, grads, state
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    step = state["step"] + 1
    lr = cfg.lr * (cfg.schedule(step) if cfg.schedule is not None else 1.0)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm > 0 else 1.0

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    # tree-generic: params may be any pytree — a full model, or a bare
    # array (the DSE gradient explorer optimizes a single (starts, knobs)
    # leaf).  tree.map also validates that grads/m/v mirror params, which
    # the old flatten_up_to dance did not.
    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    outer = jax.tree.structure(params)
    new_p, new_m, new_v = jax.tree.transpose(
        outer, jax.tree.structure((0, 0, 0)), out)
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_p, {"step": step, "m": new_m, "v": new_v}, metrics
