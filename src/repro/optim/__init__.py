"""Optimizer substrate: AdamW with schedules, global-norm clipping, and
gradient compression for the cross-pod all-reduce.

Self-contained (no optax dependency): state is a pytree
{"step", "m", "v"}; master weights stay in the params dtype (float32 by
default), ZeRO-sharding of m/v follows the parameter sharding rules
(repro.launch.sharding gives m/v the same PartitionSpec as the weight, so
FSDP shards the optimizer state for free).
"""

from .adamw import AdamWConfig, adamw_init, adamw_update
from .schedule import cosine_schedule, linear_warmup_cosine
from .compress import (compress_bf16, compress_int8, decompress_int8,
                       error_feedback_update)

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update",
    "cosine_schedule", "linear_warmup_cosine",
    "compress_bf16", "compress_int8", "decompress_int8",
    "error_feedback_update",
]
