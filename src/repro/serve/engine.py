"""DSE-as-a-service: a persistent, micro-batching, cache-backed query
engine over the matrix-packed evaluator.

The ROADMAP's millions-of-users story: many concurrent clients ask
"which accelerator + config for my model?" and share ONE compiled engine.
:class:`DSEService` wires three layers together:

* **one compiled matrix** — an :class:`repro.core.aidg.explorer.Explorer`
  (``engine="packed"`` by default) whose :class:`PackedMatrix` evaluates
  every cell x every candidate in a single jitted dispatch, optionally
  sharded over the candidate axis across devices
  (``PackedMatrix.evaluate(sharded=True)``);
* **a bounded micro-batch window** — concurrent queries coalesce into
  shared packed dispatches (:class:`repro.serve.batcher.MicroBatcher`):
  queries arriving within ``window_s`` of each other (up to ``max_batch``)
  ride one device launch, their candidate blocks stacked along the batch
  axis;
* **an answer cache** — canonical query keys (:attr:`Query.key`) memoize
  fully-ranked answers, with hit/miss counters mirroring the scenario
  cache's (``explorer.scenario_cache_stats``); repeated questions never
  touch the device again.

**Determinism.**  Every answer is a pure function of (candidate pool,
query): the pool is fixed at construction, per-candidate evaluation is
row-independent and bitwise deterministic, and ranking is the
deterministic ``pareto_front``.  So the served answer is byte-equal to a
direct Explorer sweep of the same candidates, identical regardless of
arrival order, batching, cache state, or sharding — asserted by
``tests/test_serve.py``.
"""

from __future__ import annotations

import threading
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from ..core.aidg.explorer import (Explorer, pareto_front, random_candidates,
                                  resolve_cells, scenario_cache_stats)
from .batcher import MicroBatcher, plan_batches
from .query import Answer, Design, Query

__all__ = ["DSEService"]


class DSEService:
    """The persistent query service (see module docstring).

    ``explorer``: a pre-built Explorer to serve; when ``None``, one is
    constructed from ``scenarios`` / ``networks`` (the Explorer defaults).
    ``pool`` / ``seed`` / ``candidates``: the shared candidate pool —
    either an explicit ``(B, n_knobs)`` array or ``pool`` log-uniform
    samples (row 0 = θ = 1, so the reference machine is always ranked).
    ``max_batch`` / ``window_s``: the micro-batch window (at most
    ``max_batch`` queries per dispatch, closed ``window_s`` seconds after
    the first arrival).
    ``sharded`` / ``n_devices``: shard every dispatch's candidate axis
    across devices (bitwise-identical results, see
    ``PackedMatrix.evaluate``).
    ``chunk``: bound per-dispatch device batch rows (memory cap).
    """

    def __init__(self, explorer: Optional[Explorer] = None, *,
                 scenarios=None, networks=False,
                 pool: int = 64, seed: int = 0,
                 candidates: Optional[np.ndarray] = None,
                 max_batch: int = 8, window_s: float = 0.002,
                 sharded: bool = False, n_devices: Optional[int] = None,
                 chunk: Optional[int] = None):
        if explorer is None:
            explorer = Explorer(scenarios=scenarios, networks=networks)
        self.explorer = explorer
        self.space = explorer.space
        if candidates is None:
            candidates = random_candidates(self.space, pool, seed=seed)
        self.pool = np.asarray(candidates, np.float32)
        if self.pool.ndim != 2 or self.pool.shape[1] != self.space.n:
            raise ValueError(f"candidate pool must be (B, {self.space.n}), "
                             f"got {self.pool.shape}")
        self.sharded = bool(sharded)
        self.n_devices = n_devices
        self.chunk = chunk
        self._lock = threading.Lock()
        self._cache: Dict[Tuple, Answer] = {}
        self.cache_stats = {"hits": 0, "misses": 0, "coalesced": 0}
        self._resolved: Dict[Tuple, Tuple[Tuple[str, ...], np.ndarray]] = {}
        self.dispatched_candidates = 0
        # every window that reached _dispatch (threaded OR replay), as
        # query keys; and the deduped keys each DEVICE dispatch evaluated
        self.window_log: List[List[Tuple]] = []
        self.evaluated_log: List[List[Tuple]] = []
        self.batcher = MicroBatcher(self._dispatch, max_batch=max_batch,
                                    window_s=window_s)

    # -- client surface -----------------------------------------------------

    def submit(self, query: Optional[Query] = None, **kwargs):
        """Enqueue one query into the current micro-batch window; returns
        a future resolving to its :class:`Answer`.  Accepts either a
        :class:`Query` or ``Query.make`` keyword arguments.  Resolution
        and override validation happen HERE, in the caller — a malformed
        query fails fast and can never poison its window's batchmates."""
        q = self._canonical(query, kwargs)
        self._resolve(q)               # validates workload/arch subset
        self._override_columns(q)      # validates knob names + bounds
        return self.batcher.submit(q)

    def query(self, query: Optional[Query] = None, timeout: float = 120.0,
              **kwargs) -> Answer:
        """Blocking ``submit``: one answer, through the shared window."""
        return self.submit(query, **kwargs).result(timeout=timeout)

    def query_many(self, queries: Sequence[Query]) -> List[Answer]:
        """Sequential replay oracle: the same queries through the same
        dispatch path, coalesced by the same FIFO plan the worker thread
        uses (``plan_batches``) but synchronously in the caller — the
        reference answers the concurrency/determinism tests compare the
        threaded path against."""
        queries = [self._canonical(q, {}) for q in queries]
        out: List[Answer] = []
        for s, e in plan_batches(len(queries), self.batcher.max_batch):
            out.extend(self._dispatch(queries[s:e]))
        return out

    def close(self) -> None:
        """Flush pending windows and stop the worker thread."""
        self.batcher.close()

    def __enter__(self) -> "DSEService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def stats(self) -> Dict[str, object]:
        """Service counters: answer-cache hits/misses/coalesced, dispatch
        count and mean batch size, total device-evaluated candidates, the
        ranking objectives and per-cell energy baselines (pJ at θ = 1),
        and the process-wide scenario-cache counters the answer cache
        mirrors."""
        with self._lock:
            cs = dict(self.cache_stats)
            cand = self.dispatched_candidates
            windows = len(self.window_log)
            n_queries = sum(len(b) for b in self.window_log)
            device = len(self.evaluated_log)
        return {
            "cache": cs,
            "hit_ratio": (cs["hits"] + cs["coalesced"])
            / max(1, cs["hits"] + cs["coalesced"] + cs["misses"]),
            "windows": windows,
            "device_dispatches": device,
            "dispatched_queries": n_queries,
            "mean_batch": n_queries / max(1, windows),
            "dispatched_candidates": cand,
            "pool": int(self.pool.shape[0]),
            "cells": len(self.explorer.compiled),
            "objectives": ("latency", "energy", "cost"),
            "energy_baseline_pj": {
                cs.name: float(b) for cs, b in zip(
                    self.explorer.compiled, self.explorer.energy_baselines)},
            "sharded": self.sharded,
            "scenario_cache": scenario_cache_stats(),
        }

    # -- resolution ---------------------------------------------------------

    def _canonical(self, query: Optional[Query], kwargs) -> Query:
        if query is None:
            return Query.make(**kwargs)
        if kwargs:
            raise TypeError("pass a Query OR Query.make kwargs, not both")
        # re-canonicalize hand-built dataclasses (sorts archs/overrides)
        return Query.make(query.workload, query.archs, query.override_map,
                          query.top_k)

    def _resolve(self, q: Query) -> Tuple[Tuple[str, ...], np.ndarray]:
        """Query -> (cell names, matrix column indices), memoized."""
        key = (q.workload, q.archs)
        hit = self._resolved.get(key)
        if hit is None:
            idx = resolve_cells(self.explorer.compiled, workload=q.workload,
                                archs=q.archs)
            names = tuple(self.explorer.compiled[i].name for i in idx)
            hit = (names, np.asarray(idx, np.int64))
            self._resolved[key] = hit
        return hit

    def _override_columns(self, q: Query) -> List[Tuple[int, float]]:
        """Validated (knob column, pinned θ) pairs for a query."""
        cols = []
        for name, val in q.overrides:
            if name not in self.space.names:
                raise KeyError(f"unknown knob {name!r}; space has "
                               f"{self.space.names}")
            ki = self.space.names.index(name)
            knob = self.space.knobs[ki]
            if not (knob.lo <= val <= knob.hi):
                raise ValueError(f"override {name}={val} outside "
                                 f"[{knob.lo}, {knob.hi}]")
            cols.append((ki, float(val)))
        return cols

    def _candidates_for(self, q: Query) -> np.ndarray:
        """The query's effective candidate block: the shared pool with the
        overridden knob columns pinned (a pure function of the query, so
        identical queries always evaluate identical candidates)."""
        cand = self.pool.copy()
        for ki, val in self._override_columns(q):
            cand[:, ki] = val
        return cand

    # -- the coalesced dispatch --------------------------------------------

    def _dispatch(self, queries: List[Query]) -> List[Answer]:
        """One micro-batch window -> one packed device dispatch.

        Cache hits answer immediately; the remaining queries are deduped
        by key (same-window duplicates coalesce onto one computation) and
        grouped by override signature (same overrides = same candidate
        block, evaluated once); the distinct blocks are stacked along the
        candidate axis and evaluated in ONE ``PackedMatrix`` dispatch
        (sharded over devices when configured).  Per-candidate rows are
        independent, so stacking order cannot change any query's answer.
        """
        with self._lock:
            answers: Dict[Tuple, Answer] = {}
            order: List[Tuple] = []
            fresh: Dict[Tuple, Query] = {}
            self.window_log.append([q.key for q in queries])
            for q in queries:
                order.append(q.key)
                if q.key in answers or q.key in fresh:
                    self.cache_stats["coalesced"] += 1
                elif q.key in self._cache:
                    self.cache_stats["hits"] += 1
                    cached = self._cache[q.key]
                    answers[q.key] = Answer(cached.query, cached.cells,
                                            cached.designs,
                                            cached.best_arch, cached=True)
                else:
                    self.cache_stats["misses"] += 1
                    fresh[q.key] = q

        if fresh:
            # one candidate block per distinct override signature
            blocks: Dict[Tuple, np.ndarray] = {}
            for q in fresh.values():
                if q.overrides not in blocks:
                    blocks[q.overrides] = self._candidates_for(q)
            sigs = list(blocks)
            stacked = np.concatenate([blocks[s] for s in sigs], axis=0)
            cycles, energy = self.explorer.evaluate_full(
                stacked, chunk=self.chunk, sharded=self.sharded,
                n_devices=self.n_devices)
            starts = dict(zip(sigs, np.cumsum(
                [0] + [blocks[s].shape[0] for s in sigs[:-1]])))
            with self._lock:
                self.dispatched_candidates += stacked.shape[0]
                self.evaluated_log.append(list(fresh))
                for key, q in fresh.items():
                    s = int(starts[q.overrides])
                    block = blocks[q.overrides]
                    ans = self._rank(q, block,
                                     cycles[s: s + block.shape[0]],
                                     energy[s: s + block.shape[0]])
                    answers[key] = ans
                    self._cache[key] = ans

        return [answers[k] for k in order]

    def _rank(self, q: Query, cand: np.ndarray, cycles: np.ndarray,
              energy_pj: np.ndarray) -> Answer:
        """Score one query's candidate block over its resolved cell subset
        and extract the Pareto-ranked top-k designs — the same latency /
        energy / cost / ``pareto_front`` pipeline as ``Explorer.explore``,
        with latency and energy averaged over the queried cells only."""
        names, cols = self._resolve(q)
        rel = cycles[:, cols] / self.explorer.baselines[None, cols]
        latency = rel.mean(axis=1)
        energy = (energy_pj[:, cols]
                  / self.explorer.energy_baselines[None, cols]).mean(axis=1)
        cost = self.explorer.cost_proxy(cand)
        front = pareto_front(np.stack([latency, energy, cost], axis=1))
        top = front[: q.top_k]
        designs = tuple(
            Design(theta=tuple(float(v) for v in cand[i]),
                   latency=float(latency[i]), energy=float(energy[i]),
                   cost=float(cost[i]),
                   cycles=tuple(float(c) for c in cycles[i, cols]))
            for i in top)
        # "which accelerator": the arch whose cell runs the top design at
        # the lowest baseline-relative latency
        lead = int(top[0]) if len(top) else int(np.argmin(latency))
        best_cell = int(np.argmin(rel[lead]))
        best_arch = self.explorer.compiled[int(cols[best_cell])].arch
        return Answer(query=q, cells=names, designs=designs,
                      best_arch=best_arch)
