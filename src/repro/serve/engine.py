"""DSE-as-a-service: a persistent, micro-batching, cache-backed query
engine over the matrix-packed evaluator.

The ROADMAP's millions-of-users story: many concurrent clients ask
"which accelerator + config for my model?" and share ONE compiled engine.
:class:`DSEService` wires three layers together:

* **one compiled matrix** — an :class:`repro.core.aidg.explorer.Explorer`
  (``engine="packed"`` by default) whose :class:`PackedMatrix` evaluates
  every cell x every candidate in a single jitted dispatch, optionally
  sharded over the candidate axis across devices
  (``PackedMatrix.evaluate(sharded=True)``);
* **a bounded micro-batch window** — concurrent queries coalesce into
  shared packed dispatches (:class:`repro.serve.batcher.MicroBatcher`):
  queries arriving within ``window_s`` of each other (up to ``max_batch``)
  ride one device launch, their candidate blocks stacked along the batch
  axis;
* **an answer cache** — canonical query keys (:attr:`Query.key`) memoize
  fully-ranked answers, with hit/miss counters mirroring the scenario
  cache's (``explorer.scenario_cache_stats``); repeated questions never
  touch the device again.

**Determinism.**  Every answer is a pure function of (candidate pool,
query): the pool is fixed at construction, per-candidate evaluation is
row-independent and bitwise deterministic, and ranking is the
deterministic ``pareto_front``.  So the served answer is byte-equal to a
direct Explorer sweep of the same candidates, identical regardless of
arrival order, batching, cache state, or sharding — asserted by
``tests/test_serve.py``.
"""

from __future__ import annotations

import os
import threading
import time
from concurrent.futures import TimeoutError as _FutureTimeout
from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

import numpy as np

from ..core.aidg.explorer import (Explorer, pareto_front, random_candidates,
                                  resolve_cells, scenario_cache_stats)
from .batcher import MicroBatcher, plan_batches
from .errors import (DeadlineExceeded, OracleUnavailable, PoisonedDispatch,
                     TransientDispatchError)
from .faults import ENV_FAULT_PLAN, FaultInjector, FaultPlan, WorkerKill
from .policy import CircuitBreaker, RetryPolicy
from .query import Answer, Design, Query

__all__ = ["DSEService", "DEGRADED_WIDEN"]

# degraded answers stamp their bound wider than the surrogate's calibrated
# one: while the breaker is open the service also serves cells whose
# bounds would normally fail the routing threshold, so the stated
# contract carries an explicit extra safety factor
DEGRADED_WIDEN = 2.0


@dataclass(frozen=True)
class _Submission:
    """One enqueued query plus its submit-time metadata.  The deadline is
    deliberately NOT part of the query: two clients asking the same
    question with different deadlines must still coalesce onto one
    computation and one cache entry."""

    query: Query
    deadline: Optional[float] = None     # absolute time.monotonic seconds


class DSEService:
    """The persistent query service (see module docstring).

    ``explorer``: a pre-built Explorer to serve; when ``None``, one is
    constructed from ``scenarios`` / ``networks`` (the Explorer defaults).
    ``pool`` / ``seed`` / ``candidates``: the shared candidate pool —
    either an explicit ``(B, n_knobs)`` array or ``pool`` log-uniform
    samples (row 0 = θ = 1, so the reference machine is always ranked).
    ``max_batch`` / ``window_s``: the micro-batch window (at most
    ``max_batch`` queries per dispatch, closed ``window_s`` seconds after
    the first arrival).
    ``sharded`` / ``n_devices``: shard every dispatch's candidate axis
    across devices (bitwise-identical results, see
    ``PackedMatrix.evaluate``).
    ``chunk``: bound per-dispatch device batch rows (memory cap).
    ``surrogate`` / ``surrogate_max_err``: arm the staged oracle
    hierarchy — a trained :class:`repro.surrogate.SurrogateBundle` (or
    ``True`` to train one here from the fixed default seed).  A fresh
    query is answered by the surrogate tier when EVERY resolved cell's
    calibrated confidence bound is at or under ``surrogate_max_err``,
    and falls back to the exact packed dispatch otherwise; per-tier
    answer counts, per-tier latency, and the fallback rate are reported
    by :meth:`stats`.
    ``retry`` / ``breaker``: the failure policy over the packed dispatch
    (:mod:`repro.serve.policy`) — transient dispatch failures retry with
    jittered exponential backoff, and ``open_after`` consecutive
    exhausted dispatches open the circuit breaker; while it is open,
    queries with calibrated surrogate coverage (every resolved cell's
    bound at or under ``degraded_max_err``) are answered
    ``tier="surrogate-degraded"`` with a :data:`DEGRADED_WIDEN`-widened
    bound stamped on the answer, and the rest fail fast with
    :class:`~repro.serve.errors.OracleUnavailable` instead of queuing
    behind a dead oracle.  Degraded and failed outcomes are never
    cached, so recovery restores exact ``tier="packed"`` answers.
    ``fault_plan``: a :class:`repro.serve.faults.FaultPlan` (or spec
    string) injecting deterministic dispatch faults for tests/chaos runs;
    defaults to the ``SERVE_FAULT_PLAN`` environment variable.
    """

    def __init__(self, explorer: Optional[Explorer] = None, *,
                 scenarios=None, networks=False,
                 pool: int = 64, seed: int = 0,
                 candidates: Optional[np.ndarray] = None,
                 max_batch: int = 8, window_s: float = 0.002,
                 sharded: bool = False, n_devices: Optional[int] = None,
                 chunk: Optional[int] = None,
                 surrogate=None, surrogate_max_err: float = 0.02,
                 retry: Optional[RetryPolicy] = None,
                 breaker: Optional[CircuitBreaker] = None,
                 fault_plan: Union[FaultPlan, str, None] = None,
                 degraded_max_err: float = float("inf")):
        if explorer is None:
            explorer = Explorer(scenarios=scenarios, networks=networks)
        self.explorer = explorer
        self.space = explorer.space
        if candidates is None:
            candidates = random_candidates(self.space, pool, seed=seed)
        self.pool = np.asarray(candidates, np.float32)
        if self.pool.ndim != 2 or self.pool.shape[1] != self.space.n:
            raise ValueError(f"candidate pool must be (B, {self.space.n}), "
                             f"got {self.pool.shape}")
        self.sharded = bool(sharded)
        self.n_devices = n_devices
        self.chunk = chunk
        self.surrogate = self._check_surrogate(surrogate)
        self.surrogate_max_err = float(surrogate_max_err)
        self.degraded_max_err = float(degraded_max_err)
        self.retry = retry if retry is not None else RetryPolicy(seed=seed)
        self.breaker = breaker if breaker is not None else CircuitBreaker()
        if fault_plan is None:
            fault_plan = os.environ.get(ENV_FAULT_PLAN) or None
        if isinstance(fault_plan, str):
            fault_plan = FaultPlan.parse(fault_plan)
        self.fault_plan = fault_plan
        self.faults = None if fault_plan is None else FaultInjector(fault_plan)
        self._lock = threading.Lock()
        self._cache: Dict[Tuple, Answer] = {}
        self.cache_stats = {"hits": 0, "misses": 0, "coalesced": 0}
        self._resolved: Dict[Tuple, Tuple[Tuple[str, ...], np.ndarray]] = {}
        self._sur_ok: Dict[Tuple, bool] = {}
        self.dispatched_candidates = 0
        self.tier_counts = {"surrogate": 0, "packed": 0,
                            "surrogate-degraded": 0, "failed": 0}
        self.tier_time_s = {"surrogate": 0.0, "packed": 0.0,
                            "surrogate-degraded": 0.0}
        self.timeouts = 0               # query() timeouts (leak-accounted)
        self.deadline_misses = 0        # submissions expired pre-evaluation
        self.retries = 0                # packed attempts beyond the first
        # every window that reached _dispatch (threaded OR replay), as
        # query keys; and the deduped keys each DEVICE dispatch evaluated
        self.window_log: List[List[Tuple]] = []
        self.evaluated_log: List[List[Tuple]] = []
        self.batcher = MicroBatcher(self._dispatch, max_batch=max_batch,
                                    window_s=window_s)

    def _check_surrogate(self, surrogate):
        """Resolve/validate the surrogate tier: ``True`` trains a bundle
        for this explorer from the fixed default seed; a provided bundle
        must have been trained on exactly this matrix and design space
        (cell-by-cell alignment — a mismatched bundle would silently
        predict the wrong cells)."""
        if surrogate is None:
            return None
        if surrogate is True:
            from ..surrogate import train_surrogate
            surrogate = train_surrogate(self.explorer)
        names = tuple(cs.name for cs in self.explorer.compiled)
        if tuple(surrogate.cell_names) != names:
            raise ValueError(
                f"surrogate bundle cells {surrogate.cell_names} do not "
                f"match the served matrix {names}")
        if tuple(surrogate.knob_names) != tuple(self.space.names):
            raise ValueError(
                f"surrogate bundle knobs {surrogate.knob_names} do not "
                f"match the design space {self.space.names}")
        return surrogate

    # -- client surface -----------------------------------------------------

    def submit(self, query: Optional[Query] = None,
               deadline_s: Optional[float] = None, **kwargs):
        """Enqueue one query into the current micro-batch window; returns
        a future resolving to its :class:`Answer`.  Accepts either a
        :class:`Query` or ``Query.make`` keyword arguments.  Resolution
        and override validation happen HERE, in the caller — a malformed
        query fails fast and can never poison its window's batchmates.

        ``deadline_s`` (relative seconds) propagates into the micro-batch
        window: the query's window closes no later than HALF its budget
        (closing at the deadline itself would leave the evaluation no
        time at all — shortening the window early only costs batching
        efficiency, never correctness), and a query still unanswered when
        its deadline passes fails with
        :class:`~repro.serve.errors.DeadlineExceeded` instead of being
        evaluated for nobody."""
        q = self._canonical(query, kwargs)
        self._resolve(q)               # validates workload/arch subset
        self._override_columns(q)      # validates knob names + bounds
        now = time.monotonic()
        deadline = None if deadline_s is None else now + float(deadline_s)
        window_close = (None if deadline_s is None
                        else now + float(deadline_s) / 2.0)
        return self.batcher.submit(_Submission(q, deadline),
                                   deadline=window_close)

    def query(self, query: Optional[Query] = None, timeout: float = 120.0,
              deadline_s: Optional[float] = None, **kwargs) -> Answer:
        """Blocking ``submit``: one answer, through the shared window.

        A timeout no longer leaks the enqueued future: the future is
        cancelled (the batcher drops cancelled items before dispatch) or,
        when already past cancellation, its eventual outcome is consumed
        so nothing dangles — either way the ``timeouts`` counter in
        :meth:`stats` accounts for it, and the raised error is the
        structured :class:`~repro.serve.errors.DeadlineExceeded` (a
        ``TimeoutError`` subclass, so existing callers keep working)."""
        if deadline_s is not None:
            timeout = min(timeout, float(deadline_s))
        fut = self.submit(query, deadline_s=deadline_s, **kwargs)
        try:
            return fut.result(timeout=timeout)
        except _FutureTimeout:
            with self._lock:
                self.timeouts += 1
            if not fut.cancel():
                # already running/done: consume the eventual outcome so
                # the dropped result is accounted, not silently leaked
                fut.add_done_callback(
                    lambda f: f.cancelled() or f.exception())
            raise DeadlineExceeded(
                f"no answer within {timeout:g}s", timeout_s=timeout) from None

    def query_many(self, queries: Sequence[Query],
                   return_exceptions: bool = False) -> List[Answer]:
        """Sequential replay oracle: the same queries through the same
        dispatch path, coalesced by the same FIFO plan the worker thread
        uses (``plan_batches``) but synchronously in the caller — the
        reference answers the concurrency/determinism tests compare the
        threaded path against.  With ``return_exceptions`` (the replay
        mode fault tests use), per-query structured errors come back in
        place of answers instead of raising on the first one."""
        subs = [_Submission(self._canonical(q, {})) for q in queries]
        out: List[Answer] = []
        for s, e in plan_batches(len(subs), self.batcher.max_batch):
            out.extend(self._dispatch(subs[s:e]))
        if not return_exceptions:
            for o in out:
                if isinstance(o, BaseException):
                    raise o
        return out

    def close(self) -> None:
        """Flush pending windows and stop the worker thread."""
        self.batcher.close()

    def __enter__(self) -> "DSEService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def stats(self) -> Dict[str, object]:
        """Service counters: answer-cache hits/misses/coalesced, dispatch
        count and mean batch size, total device-evaluated candidates, the
        ranking objectives and per-cell energy baselines (pJ at θ = 1),
        the process-wide scenario-cache counters the answer cache
        mirrors, and the staged-oracle tier accounting — per-tier answer
        counts (``tiers``, cache hits included), per-tier cumulative and
        per-query latency (``tier_time_s`` / ``tier_us_per_query``), and
        the ``fallback_rate`` (fraction of fresh queries the surrogate
        tier had to hand to the exact packed dispatch; 1.0 when no
        surrogate is armed) — plus the failure-semantics counters: the
        circuit ``breaker`` snapshot, ``retries``, ``timeouts``,
        ``deadline_misses``, and the batcher's ``cancelled`` /
        ``worker_restarts``."""
        with self._lock:
            cs = dict(self.cache_stats)
            cand = self.dispatched_candidates
            windows = len(self.window_log)
            n_queries = sum(len(b) for b in self.window_log)
            device = len(self.evaluated_log)
            tiers = dict(self.tier_counts)
            tier_time = dict(self.tier_time_s)
            timeouts = self.timeouts
            deadline_misses = self.deadline_misses
            retries = self.retries
        fresh = tiers["surrogate"] + tiers["packed"]
        return {
            "cache": cs,
            "hit_ratio": (cs["hits"] + cs["coalesced"])
            / max(1, cs["hits"] + cs["coalesced"] + cs["misses"]),
            "windows": windows,
            "device_dispatches": device,
            "dispatched_queries": n_queries,
            "mean_batch": n_queries / max(1, windows),
            "dispatched_candidates": cand,
            "pool": int(self.pool.shape[0]),
            "cells": len(self.explorer.compiled),
            "objectives": ("latency", "energy", "cost"),
            "energy_baseline_pj": {
                cs.name: float(b) for cs, b in zip(
                    self.explorer.compiled, self.explorer.energy_baselines)},
            "sharded": self.sharded,
            "scenario_cache": scenario_cache_stats(),
            "surrogate_armed": self.surrogate is not None,
            "surrogate_max_err": self.surrogate_max_err,
            "tiers": {"cache": cs["hits"], **tiers},
            "tier_time_s": tier_time,
            "tier_us_per_query": {
                t: tier_time[t] / tiers[t] * 1e6 if tiers.get(t) else 0.0
                for t in tier_time},
            "fallback_rate": tiers["packed"] / fresh if fresh else 0.0,
            "breaker": self.breaker.snapshot(),
            "retries": retries,
            "timeouts": timeouts,
            "deadline_misses": deadline_misses,
            "cancelled": self.batcher.cancelled,
            "worker_restarts": self.batcher.worker_restarts,
            "fault_plan": (self.fault_plan.to_spec()
                           if self.fault_plan is not None else None),
        }

    # -- resolution ---------------------------------------------------------

    def _canonical(self, query: Optional[Query], kwargs) -> Query:
        if query is None:
            return Query.make(**kwargs)
        if kwargs:
            raise TypeError("pass a Query OR Query.make kwargs, not both")
        # re-canonicalize hand-built dataclasses (sorts archs/overrides)
        return Query.make(query.workload, query.archs, query.override_map,
                          query.top_k)

    def _resolve(self, q: Query) -> Tuple[Tuple[str, ...], np.ndarray]:
        """Query -> (cell names, matrix column indices), memoized."""
        key = (q.workload, q.archs)
        hit = self._resolved.get(key)
        if hit is None:
            idx = resolve_cells(self.explorer.compiled, workload=q.workload,
                                archs=q.archs)
            names = tuple(self.explorer.compiled[i].name for i in idx)
            hit = (names, np.asarray(idx, np.int64))
            self._resolved[key] = hit
        return hit

    def _override_columns(self, q: Query) -> List[Tuple[int, float]]:
        """Validated (knob column, pinned θ) pairs for a query."""
        cols = []
        for name, val in q.overrides:
            if name not in self.space.names:
                raise KeyError(f"unknown knob {name!r}; space has "
                               f"{self.space.names}")
            ki = self.space.names.index(name)
            knob = self.space.knobs[ki]
            if not (knob.lo <= val <= knob.hi):
                raise ValueError(f"override {name}={val} outside "
                                 f"[{knob.lo}, {knob.hi}]")
            cols.append((ki, float(val)))
        return cols

    def _candidates_for(self, q: Query) -> np.ndarray:
        """The query's effective candidate block: the shared pool with the
        overridden knob columns pinned (a pure function of the query, so
        identical queries always evaluate identical candidates)."""
        cand = self.pool.copy()
        for ki, val in self._override_columns(q):
            cand[:, ki] = val
        return cand

    # -- the coalesced dispatch --------------------------------------------

    def _dispatch(self, submissions: List) -> List:
        """One micro-batch window through the staged oracle hierarchy.

        Submissions already past their deadline fail immediately with
        :class:`DeadlineExceeded` (counted ``deadline_misses``) — they
        never reach an oracle.  Cache hits answer next; the remaining
        queries are deduped by key (same-window duplicates coalesce onto
        one computation), routed to the surrogate tier when eligible
        (:meth:`_surrogate_answers`), and the rest grouped by override
        signature (same overrides = same candidate block, evaluated
        once) into ONE stacked ``PackedMatrix`` dispatch (sharded over
        devices when configured) behind the retry policy and circuit
        breaker.  Per-candidate rows are independent, so stacking order
        cannot change any query's answer.  The returned list holds one
        outcome per submission — an :class:`Answer` or a structured
        error (the batcher fails exactly that item's future with it).
        """
        subs = [s if isinstance(s, _Submission) else _Submission(s)
                for s in submissions]
        now = time.monotonic()
        with self._lock:
            outcomes: List[Optional[object]] = [None] * len(subs)
            answers: Dict[Tuple, object] = {}
            fresh: Dict[Tuple, Query] = {}
            self.window_log.append([s.query.key for s in subs])
            for i, sub in enumerate(subs):
                q = sub.query
                if sub.deadline is not None and now > sub.deadline:
                    self.deadline_misses += 1
                    outcomes[i] = DeadlineExceeded(
                        f"query expired {now - sub.deadline:.3f}s before "
                        f"evaluation", workload=q.workload)
                elif q.key in answers or q.key in fresh:
                    self.cache_stats["coalesced"] += 1
                elif q.key in self._cache:
                    self.cache_stats["hits"] += 1
                    cached = self._cache[q.key]
                    answers[q.key] = Answer(cached.query, cached.cells,
                                            cached.designs,
                                            cached.best_arch, cached=True,
                                            tier=cached.tier,
                                            err_bound=cached.err_bound)
                else:
                    self.cache_stats["misses"] += 1
                    fresh[q.key] = q

        if fresh:
            # staged oracle hierarchy: queries whose every resolved cell
            # clears the surrogate's calibrated bound answer from the fast
            # tier; the rest fall back to the exact packed dispatch
            sur = {k: q for k, q in fresh.items()
                   if self._surrogate_answers(q)}
            packed = {k: q for k, q in fresh.items() if k not in sur}
            if sur:
                self._answer_surrogate(sur, answers)
            if packed:
                self._answer_packed(packed, answers)

        return [o if o is not None else answers[s.query.key]
                for o, s in zip(outcomes, subs)]

    def _surrogate_answers(self, q: Query) -> bool:
        """True when the armed surrogate's calibrated per-cell bounds
        clear ``surrogate_max_err`` for EVERY cell the query resolves to
        (memoized per resolved subset)."""
        if self.surrogate is None:
            return False
        key = (q.workload, q.archs)
        ok = self._sur_ok.get(key)
        if ok is None:
            _, cols = self._resolve(q)
            ok = bool(np.all(self.surrogate.err_bound[cols]
                             <= self.surrogate_max_err))
            self._sur_ok[key] = ok
        return ok

    def _answer_surrogate(self, group: Dict[Tuple, Query],
                          answers: Dict[Tuple, object],
                          degraded: bool = False) -> None:
        """Fast tier: each distinct override signature's candidate block
        goes through the bundle's jitted predictor at the fixed (pool,
        n_knobs) shape — no stacking, so every call reuses one compiled
        shape; the device-dispatch counters (``dispatched_candidates``,
        ``evaluated_log``) are deliberately NOT touched, they count exact
        packed work only.  In ``degraded`` mode (circuit breaker open)
        answers are stamped ``tier="surrogate-degraded"`` with the
        :data:`DEGRADED_WIDEN`-widened bound and are NOT cached — once
        the breaker closes, the same question gets an exact answer."""
        tier = "surrogate-degraded" if degraded else "surrogate"
        t0 = time.perf_counter()
        blocks: Dict[Tuple, np.ndarray] = {}
        preds: Dict[Tuple, Tuple[np.ndarray, np.ndarray]] = {}
        for q in group.values():
            if q.overrides not in blocks:
                blocks[q.overrides] = self._candidates_for(q)
                preds[q.overrides] = self.surrogate.predict_full(
                    blocks[q.overrides])
        with self._lock:
            for key, q in group.items():
                cycles, energy = preds[q.overrides]
                ans = self._rank(q, blocks[q.overrides], cycles, energy,
                                 tier=tier)
                answers[key] = ans
                if not degraded:
                    self._cache[key] = ans
            self.tier_counts[tier] += len(group)
            self.tier_time_s[tier] += time.perf_counter() - t0

    def _answer_packed(self, group: Dict[Tuple, Query],
                       answers: Dict[Tuple, object]) -> None:
        """Exact tier: one candidate block per distinct override
        signature, stacked along the candidate axis and evaluated in ONE
        ``PackedMatrix`` dispatch (sharded over devices when configured)
        behind the retry policy and circuit breaker.  Per-candidate rows
        are independent, so stacking order cannot change any query's
        answer.  When the breaker is open — or a dispatch exhausts its
        retry budget — the whole group degrades
        (:meth:`_answer_degraded`) instead of queuing behind the dead
        oracle."""
        t0 = time.perf_counter()
        if not self.breaker.allow():
            self._answer_degraded(group, answers, "circuit breaker open")
            return
        blocks: Dict[Tuple, np.ndarray] = {}
        for q in group.values():
            if q.overrides not in blocks:
                blocks[q.overrides] = self._candidates_for(q)
        sigs = list(blocks)
        stacked = np.concatenate([blocks[s] for s in sigs], axis=0)
        try:
            cycles, energy = self._packed_evaluate(stacked)
        except TransientDispatchError as e:
            self.breaker.record_failure()
            self._answer_degraded(group, answers,
                                  f"packed dispatch failed: {e}")
            return
        except BaseException:
            # a non-transient dispatch death (WorkerKill, SystemExit)
            # must still resolve the breaker's admitted attempt — a
            # half-open probe that died silently would otherwise leave
            # the breaker shedding forever; the exception itself keeps
            # propagating (the batcher fails the window's futures)
            self.breaker.record_failure()
            raise
        self.breaker.record_success()
        starts = dict(zip(sigs, np.cumsum(
            [0] + [blocks[s].shape[0] for s in sigs[:-1]])))
        with self._lock:
            self.dispatched_candidates += stacked.shape[0]
            self.evaluated_log.append(list(group))
            for key, q in group.items():
                s = int(starts[q.overrides])
                block = blocks[q.overrides]
                ans = self._rank(q, block,
                                 cycles[s: s + block.shape[0]],
                                 energy[s: s + block.shape[0]])
                answers[key] = ans
                self._cache[key] = ans
            self.tier_counts["packed"] += len(group)
            self.tier_time_s["packed"] += time.perf_counter() - t0

    def _packed_evaluate(self, stacked: np.ndarray
                         ) -> Tuple[np.ndarray, np.ndarray]:
        """One guarded oracle call: fault injection (when a plan is
        armed), output validation (a "successful" dispatch returning
        non-finite numbers is a :class:`PoisonedDispatch`, not an
        answer), and retry-with-backoff around both.  Raises the last
        :class:`TransientDispatchError` once the budget is spent."""
        def attempt() -> Tuple[np.ndarray, np.ndarray]:
            poisoned = False
            if self.faults is not None:
                n, act = self.faults.next("packed")
                if act.latency_s:
                    time.sleep(act.latency_s)
                if act.kind == "error":
                    raise TransientDispatchError(
                        f"injected dispatch fault at attempt {n}", attempt=n)
                if act.kind == "kill":
                    raise WorkerKill(f"injected worker kill at attempt {n}")
                poisoned = act.kind == "poison"
            if poisoned:
                # the oracle "returns", but its payload is garbage
                shape = (stacked.shape[0], len(self.explorer.compiled))
                cycles = np.full(shape, np.nan, np.float32)
                energy = np.full(shape, np.nan, np.float32)
            else:
                cycles, energy = self.explorer.evaluate_full(
                    stacked, chunk=self.chunk, sharded=self.sharded,
                    n_devices=self.n_devices)
            if not (np.isfinite(cycles).all() and np.isfinite(energy).all()):
                raise PoisonedDispatch(
                    "packed dispatch returned non-finite cycles/energy")
            return cycles, energy

        def on_retry(_e: BaseException) -> None:
            with self._lock:
                self.retries += 1

        return self.retry.call(attempt, retry_on=(TransientDispatchError,),
                               on_retry=on_retry)

    def _answer_degraded(self, group: Dict[Tuple, Query],
                         answers: Dict[Tuple, object], reason: str) -> None:
        """Graceful degradation down the oracle hierarchy: with the
        packed oracle unreachable, queries whose every resolved cell has
        a calibrated surrogate bound at or under ``degraded_max_err``
        are still answered — ``tier="surrogate-degraded"``, widened
        bound stamped — and the rest fail fast with a structured
        :class:`OracleUnavailable` instead of queuing behind a dead
        dispatch.  Neither outcome is cached."""
        cover: Dict[Tuple, Query] = {}
        for key, q in group.items():
            _, cols = self._resolve(q)
            covered = (self.surrogate is not None and bool(
                np.all(np.isfinite(self.surrogate.err_bound[cols])
                       & (self.surrogate.err_bound[cols]
                          <= self.degraded_max_err))))
            if covered:
                cover[key] = q
            else:
                with self._lock:
                    self.tier_counts["failed"] += 1
                answers[key] = OracleUnavailable(
                    f"packed oracle unavailable ({reason}) and query has "
                    f"no calibrated surrogate coverage",
                    breaker=self.breaker.state, workload=q.workload)
        if cover:
            self._answer_surrogate(cover, answers, degraded=True)

    def _rank(self, q: Query, cand: np.ndarray, cycles: np.ndarray,
              energy_pj: np.ndarray, tier: str = "packed") -> Answer:
        """Score one query's candidate block over its resolved cell subset
        and extract the Pareto-ranked top-k designs — the same latency /
        energy / cost / ``pareto_front`` pipeline as ``Explorer.explore``,
        with latency and energy averaged over the queried cells only."""
        names, cols = self._resolve(q)
        rel = cycles[:, cols] / self.explorer.baselines[None, cols]
        latency = rel.mean(axis=1)
        energy = (energy_pj[:, cols]
                  / self.explorer.energy_baselines[None, cols]).mean(axis=1)
        cost = self.explorer.cost_proxy(cand)
        front = pareto_front(np.stack([latency, energy, cost], axis=1))
        top = front[: q.top_k]
        designs = tuple(
            Design(theta=tuple(float(v) for v in cand[i]),
                   latency=float(latency[i]), energy=float(energy[i]),
                   cost=float(cost[i]),
                   cycles=tuple(float(c) for c in cycles[i, cols]))
            for i in top)
        # "which accelerator": the arch whose cell runs the top design at
        # the lowest baseline-relative latency
        lead = int(top[0]) if len(top) else int(np.argmin(latency))
        best_cell = int(np.argmin(rel[lead]))
        best_arch = self.explorer.compiled[int(cols[best_cell])].arch
        if tier == "surrogate":
            err = float(self.surrogate.err_bound[cols].max())
        elif tier == "surrogate-degraded":
            err = DEGRADED_WIDEN * float(self.surrogate.err_bound[cols].max())
        else:
            err = 0.0
        return Answer(query=q, cells=names, designs=designs,
                      best_arch=best_arch, tier=tier, err_bound=err)
