"""DSE-as-a-service: a persistent, micro-batching, cache-backed query
engine over the matrix-packed evaluator.

The ROADMAP's millions-of-users story: many concurrent clients ask
"which accelerator + config for my model?" and share ONE compiled engine.
:class:`DSEService` wires three layers together:

* **one compiled matrix** — an :class:`repro.core.aidg.explorer.Explorer`
  (``engine="packed"`` by default) whose :class:`PackedMatrix` evaluates
  every cell x every candidate in a single jitted dispatch, optionally
  sharded over the candidate axis across devices
  (``PackedMatrix.evaluate(sharded=True)``);
* **a bounded micro-batch window** — concurrent queries coalesce into
  shared packed dispatches (:class:`repro.serve.batcher.MicroBatcher`):
  queries arriving within ``window_s`` of each other (up to ``max_batch``)
  ride one device launch, their candidate blocks stacked along the batch
  axis;
* **an answer cache** — canonical query keys (:attr:`Query.key`) memoize
  fully-ranked answers, with hit/miss counters mirroring the scenario
  cache's (``explorer.scenario_cache_stats``); repeated questions never
  touch the device again.

**Determinism.**  Every answer is a pure function of (candidate pool,
query): the pool is fixed at construction, per-candidate evaluation is
row-independent and bitwise deterministic, and ranking is the
deterministic ``pareto_front``.  So the served answer is byte-equal to a
direct Explorer sweep of the same candidates, identical regardless of
arrival order, batching, cache state, or sharding — asserted by
``tests/test_serve.py``.
"""

from __future__ import annotations

import threading
import time
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from ..core.aidg.explorer import (Explorer, pareto_front, random_candidates,
                                  resolve_cells, scenario_cache_stats)
from .batcher import MicroBatcher, plan_batches
from .query import Answer, Design, Query

__all__ = ["DSEService"]


class DSEService:
    """The persistent query service (see module docstring).

    ``explorer``: a pre-built Explorer to serve; when ``None``, one is
    constructed from ``scenarios`` / ``networks`` (the Explorer defaults).
    ``pool`` / ``seed`` / ``candidates``: the shared candidate pool —
    either an explicit ``(B, n_knobs)`` array or ``pool`` log-uniform
    samples (row 0 = θ = 1, so the reference machine is always ranked).
    ``max_batch`` / ``window_s``: the micro-batch window (at most
    ``max_batch`` queries per dispatch, closed ``window_s`` seconds after
    the first arrival).
    ``sharded`` / ``n_devices``: shard every dispatch's candidate axis
    across devices (bitwise-identical results, see
    ``PackedMatrix.evaluate``).
    ``chunk``: bound per-dispatch device batch rows (memory cap).
    ``surrogate`` / ``surrogate_max_err``: arm the staged oracle
    hierarchy — a trained :class:`repro.surrogate.SurrogateBundle` (or
    ``True`` to train one here from the fixed default seed).  A fresh
    query is answered by the surrogate tier when EVERY resolved cell's
    calibrated confidence bound is at or under ``surrogate_max_err``,
    and falls back to the exact packed dispatch otherwise; per-tier
    answer counts, per-tier latency, and the fallback rate are reported
    by :meth:`stats`.
    """

    def __init__(self, explorer: Optional[Explorer] = None, *,
                 scenarios=None, networks=False,
                 pool: int = 64, seed: int = 0,
                 candidates: Optional[np.ndarray] = None,
                 max_batch: int = 8, window_s: float = 0.002,
                 sharded: bool = False, n_devices: Optional[int] = None,
                 chunk: Optional[int] = None,
                 surrogate=None, surrogate_max_err: float = 0.02):
        if explorer is None:
            explorer = Explorer(scenarios=scenarios, networks=networks)
        self.explorer = explorer
        self.space = explorer.space
        if candidates is None:
            candidates = random_candidates(self.space, pool, seed=seed)
        self.pool = np.asarray(candidates, np.float32)
        if self.pool.ndim != 2 or self.pool.shape[1] != self.space.n:
            raise ValueError(f"candidate pool must be (B, {self.space.n}), "
                             f"got {self.pool.shape}")
        self.sharded = bool(sharded)
        self.n_devices = n_devices
        self.chunk = chunk
        self.surrogate = self._check_surrogate(surrogate)
        self.surrogate_max_err = float(surrogate_max_err)
        self._lock = threading.Lock()
        self._cache: Dict[Tuple, Answer] = {}
        self.cache_stats = {"hits": 0, "misses": 0, "coalesced": 0}
        self._resolved: Dict[Tuple, Tuple[Tuple[str, ...], np.ndarray]] = {}
        self._sur_ok: Dict[Tuple, bool] = {}
        self.dispatched_candidates = 0
        self.tier_counts = {"surrogate": 0, "packed": 0}
        self.tier_time_s = {"surrogate": 0.0, "packed": 0.0}
        # every window that reached _dispatch (threaded OR replay), as
        # query keys; and the deduped keys each DEVICE dispatch evaluated
        self.window_log: List[List[Tuple]] = []
        self.evaluated_log: List[List[Tuple]] = []
        self.batcher = MicroBatcher(self._dispatch, max_batch=max_batch,
                                    window_s=window_s)

    def _check_surrogate(self, surrogate):
        """Resolve/validate the surrogate tier: ``True`` trains a bundle
        for this explorer from the fixed default seed; a provided bundle
        must have been trained on exactly this matrix and design space
        (cell-by-cell alignment — a mismatched bundle would silently
        predict the wrong cells)."""
        if surrogate is None:
            return None
        if surrogate is True:
            from ..surrogate import train_surrogate
            surrogate = train_surrogate(self.explorer)
        names = tuple(cs.name for cs in self.explorer.compiled)
        if tuple(surrogate.cell_names) != names:
            raise ValueError(
                f"surrogate bundle cells {surrogate.cell_names} do not "
                f"match the served matrix {names}")
        if tuple(surrogate.knob_names) != tuple(self.space.names):
            raise ValueError(
                f"surrogate bundle knobs {surrogate.knob_names} do not "
                f"match the design space {self.space.names}")
        return surrogate

    # -- client surface -----------------------------------------------------

    def submit(self, query: Optional[Query] = None, **kwargs):
        """Enqueue one query into the current micro-batch window; returns
        a future resolving to its :class:`Answer`.  Accepts either a
        :class:`Query` or ``Query.make`` keyword arguments.  Resolution
        and override validation happen HERE, in the caller — a malformed
        query fails fast and can never poison its window's batchmates."""
        q = self._canonical(query, kwargs)
        self._resolve(q)               # validates workload/arch subset
        self._override_columns(q)      # validates knob names + bounds
        return self.batcher.submit(q)

    def query(self, query: Optional[Query] = None, timeout: float = 120.0,
              **kwargs) -> Answer:
        """Blocking ``submit``: one answer, through the shared window."""
        return self.submit(query, **kwargs).result(timeout=timeout)

    def query_many(self, queries: Sequence[Query]) -> List[Answer]:
        """Sequential replay oracle: the same queries through the same
        dispatch path, coalesced by the same FIFO plan the worker thread
        uses (``plan_batches``) but synchronously in the caller — the
        reference answers the concurrency/determinism tests compare the
        threaded path against."""
        queries = [self._canonical(q, {}) for q in queries]
        out: List[Answer] = []
        for s, e in plan_batches(len(queries), self.batcher.max_batch):
            out.extend(self._dispatch(queries[s:e]))
        return out

    def close(self) -> None:
        """Flush pending windows and stop the worker thread."""
        self.batcher.close()

    def __enter__(self) -> "DSEService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def stats(self) -> Dict[str, object]:
        """Service counters: answer-cache hits/misses/coalesced, dispatch
        count and mean batch size, total device-evaluated candidates, the
        ranking objectives and per-cell energy baselines (pJ at θ = 1),
        the process-wide scenario-cache counters the answer cache
        mirrors, and the staged-oracle tier accounting — per-tier answer
        counts (``tiers``, cache hits included), per-tier cumulative and
        per-query latency (``tier_time_s`` / ``tier_us_per_query``), and
        the ``fallback_rate`` (fraction of fresh queries the surrogate
        tier had to hand to the exact packed dispatch; 1.0 when no
        surrogate is armed)."""
        with self._lock:
            cs = dict(self.cache_stats)
            cand = self.dispatched_candidates
            windows = len(self.window_log)
            n_queries = sum(len(b) for b in self.window_log)
            device = len(self.evaluated_log)
            tiers = dict(self.tier_counts)
            tier_time = dict(self.tier_time_s)
        fresh = tiers["surrogate"] + tiers["packed"]
        return {
            "cache": cs,
            "hit_ratio": (cs["hits"] + cs["coalesced"])
            / max(1, cs["hits"] + cs["coalesced"] + cs["misses"]),
            "windows": windows,
            "device_dispatches": device,
            "dispatched_queries": n_queries,
            "mean_batch": n_queries / max(1, windows),
            "dispatched_candidates": cand,
            "pool": int(self.pool.shape[0]),
            "cells": len(self.explorer.compiled),
            "objectives": ("latency", "energy", "cost"),
            "energy_baseline_pj": {
                cs.name: float(b) for cs, b in zip(
                    self.explorer.compiled, self.explorer.energy_baselines)},
            "sharded": self.sharded,
            "scenario_cache": scenario_cache_stats(),
            "surrogate_armed": self.surrogate is not None,
            "surrogate_max_err": self.surrogate_max_err,
            "tiers": {"cache": cs["hits"], **tiers},
            "tier_time_s": tier_time,
            "tier_us_per_query": {
                t: tier_time[t] / tiers[t] * 1e6 if tiers[t] else 0.0
                for t in tiers},
            "fallback_rate": tiers["packed"] / fresh if fresh else 0.0,
        }

    # -- resolution ---------------------------------------------------------

    def _canonical(self, query: Optional[Query], kwargs) -> Query:
        if query is None:
            return Query.make(**kwargs)
        if kwargs:
            raise TypeError("pass a Query OR Query.make kwargs, not both")
        # re-canonicalize hand-built dataclasses (sorts archs/overrides)
        return Query.make(query.workload, query.archs, query.override_map,
                          query.top_k)

    def _resolve(self, q: Query) -> Tuple[Tuple[str, ...], np.ndarray]:
        """Query -> (cell names, matrix column indices), memoized."""
        key = (q.workload, q.archs)
        hit = self._resolved.get(key)
        if hit is None:
            idx = resolve_cells(self.explorer.compiled, workload=q.workload,
                                archs=q.archs)
            names = tuple(self.explorer.compiled[i].name for i in idx)
            hit = (names, np.asarray(idx, np.int64))
            self._resolved[key] = hit
        return hit

    def _override_columns(self, q: Query) -> List[Tuple[int, float]]:
        """Validated (knob column, pinned θ) pairs for a query."""
        cols = []
        for name, val in q.overrides:
            if name not in self.space.names:
                raise KeyError(f"unknown knob {name!r}; space has "
                               f"{self.space.names}")
            ki = self.space.names.index(name)
            knob = self.space.knobs[ki]
            if not (knob.lo <= val <= knob.hi):
                raise ValueError(f"override {name}={val} outside "
                                 f"[{knob.lo}, {knob.hi}]")
            cols.append((ki, float(val)))
        return cols

    def _candidates_for(self, q: Query) -> np.ndarray:
        """The query's effective candidate block: the shared pool with the
        overridden knob columns pinned (a pure function of the query, so
        identical queries always evaluate identical candidates)."""
        cand = self.pool.copy()
        for ki, val in self._override_columns(q):
            cand[:, ki] = val
        return cand

    # -- the coalesced dispatch --------------------------------------------

    def _dispatch(self, queries: List[Query]) -> List[Answer]:
        """One micro-batch window through the staged oracle hierarchy.

        Cache hits answer immediately; the remaining queries are deduped
        by key (same-window duplicates coalesce onto one computation),
        routed to the surrogate tier when eligible
        (:meth:`_surrogate_answers`), and the rest grouped by override
        signature (same overrides = same candidate block, evaluated
        once) into ONE stacked ``PackedMatrix`` dispatch (sharded over
        devices when configured).  Per-candidate rows are independent,
        so stacking order cannot change any query's answer.
        """
        with self._lock:
            answers: Dict[Tuple, Answer] = {}
            order: List[Tuple] = []
            fresh: Dict[Tuple, Query] = {}
            self.window_log.append([q.key for q in queries])
            for q in queries:
                order.append(q.key)
                if q.key in answers or q.key in fresh:
                    self.cache_stats["coalesced"] += 1
                elif q.key in self._cache:
                    self.cache_stats["hits"] += 1
                    cached = self._cache[q.key]
                    answers[q.key] = Answer(cached.query, cached.cells,
                                            cached.designs,
                                            cached.best_arch, cached=True,
                                            tier=cached.tier,
                                            err_bound=cached.err_bound)
                else:
                    self.cache_stats["misses"] += 1
                    fresh[q.key] = q

        if fresh:
            # staged oracle hierarchy: queries whose every resolved cell
            # clears the surrogate's calibrated bound answer from the fast
            # tier; the rest fall back to the exact packed dispatch
            sur = {k: q for k, q in fresh.items()
                   if self._surrogate_answers(q)}
            packed = {k: q for k, q in fresh.items() if k not in sur}
            if sur:
                self._answer_surrogate(sur, answers)
            if packed:
                self._answer_packed(packed, answers)

        return [answers[k] for k in order]

    def _surrogate_answers(self, q: Query) -> bool:
        """True when the armed surrogate's calibrated per-cell bounds
        clear ``surrogate_max_err`` for EVERY cell the query resolves to
        (memoized per resolved subset)."""
        if self.surrogate is None:
            return False
        key = (q.workload, q.archs)
        ok = self._sur_ok.get(key)
        if ok is None:
            _, cols = self._resolve(q)
            ok = bool(np.all(self.surrogate.err_bound[cols]
                             <= self.surrogate_max_err))
            self._sur_ok[key] = ok
        return ok

    def _answer_surrogate(self, group: Dict[Tuple, Query],
                          answers: Dict[Tuple, Answer]) -> None:
        """Fast tier: each distinct override signature's candidate block
        goes through the bundle's jitted predictor at the fixed (pool,
        n_knobs) shape — no stacking, so every call reuses one compiled
        shape; the device-dispatch counters (``dispatched_candidates``,
        ``evaluated_log``) are deliberately NOT touched, they count exact
        packed work only."""
        t0 = time.perf_counter()
        blocks: Dict[Tuple, np.ndarray] = {}
        preds: Dict[Tuple, Tuple[np.ndarray, np.ndarray]] = {}
        for q in group.values():
            if q.overrides not in blocks:
                blocks[q.overrides] = self._candidates_for(q)
                preds[q.overrides] = self.surrogate.predict_full(
                    blocks[q.overrides])
        with self._lock:
            for key, q in group.items():
                cycles, energy = preds[q.overrides]
                ans = self._rank(q, blocks[q.overrides], cycles, energy,
                                 tier="surrogate")
                answers[key] = ans
                self._cache[key] = ans
            self.tier_counts["surrogate"] += len(group)
            self.tier_time_s["surrogate"] += time.perf_counter() - t0

    def _answer_packed(self, group: Dict[Tuple, Query],
                       answers: Dict[Tuple, Answer]) -> None:
        """Exact tier: one candidate block per distinct override
        signature, stacked along the candidate axis and evaluated in ONE
        ``PackedMatrix`` dispatch (sharded over devices when configured).
        Per-candidate rows are independent, so stacking order cannot
        change any query's answer."""
        t0 = time.perf_counter()
        blocks: Dict[Tuple, np.ndarray] = {}
        for q in group.values():
            if q.overrides not in blocks:
                blocks[q.overrides] = self._candidates_for(q)
        sigs = list(blocks)
        stacked = np.concatenate([blocks[s] for s in sigs], axis=0)
        cycles, energy = self.explorer.evaluate_full(
            stacked, chunk=self.chunk, sharded=self.sharded,
            n_devices=self.n_devices)
        starts = dict(zip(sigs, np.cumsum(
            [0] + [blocks[s].shape[0] for s in sigs[:-1]])))
        with self._lock:
            self.dispatched_candidates += stacked.shape[0]
            self.evaluated_log.append(list(group))
            for key, q in group.items():
                s = int(starts[q.overrides])
                block = blocks[q.overrides]
                ans = self._rank(q, block,
                                 cycles[s: s + block.shape[0]],
                                 energy[s: s + block.shape[0]])
                answers[key] = ans
                self._cache[key] = ans
            self.tier_counts["packed"] += len(group)
            self.tier_time_s["packed"] += time.perf_counter() - t0

    def _rank(self, q: Query, cand: np.ndarray, cycles: np.ndarray,
              energy_pj: np.ndarray, tier: str = "packed") -> Answer:
        """Score one query's candidate block over its resolved cell subset
        and extract the Pareto-ranked top-k designs — the same latency /
        energy / cost / ``pareto_front`` pipeline as ``Explorer.explore``,
        with latency and energy averaged over the queried cells only."""
        names, cols = self._resolve(q)
        rel = cycles[:, cols] / self.explorer.baselines[None, cols]
        latency = rel.mean(axis=1)
        energy = (energy_pj[:, cols]
                  / self.explorer.energy_baselines[None, cols]).mean(axis=1)
        cost = self.explorer.cost_proxy(cand)
        front = pareto_front(np.stack([latency, energy, cost], axis=1))
        top = front[: q.top_k]
        designs = tuple(
            Design(theta=tuple(float(v) for v in cand[i]),
                   latency=float(latency[i]), energy=float(energy[i]),
                   cost=float(cost[i]),
                   cycles=tuple(float(c) for c in cycles[i, cols]))
            for i in top)
        # "which accelerator": the arch whose cell runs the top design at
        # the lowest baseline-relative latency
        lead = int(top[0]) if len(top) else int(np.argmin(latency))
        best_cell = int(np.argmin(rel[lead]))
        best_arch = self.explorer.compiled[int(cols[best_cell])].arch
        err = (float(self.surrogate.err_bound[cols].max())
               if tier == "surrogate" else 0.0)
        return Answer(query=q, cells=names, designs=designs,
                      best_arch=best_arch, tier=tier, err_bound=err)
