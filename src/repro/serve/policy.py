"""Failure policy for the packed-oracle dispatch: retry with jittered
exponential backoff, and a circuit breaker with graceful degradation
hooks.

Both pieces are deliberately deterministic so the serving determinism
contract (threaded == sequential replay, asserted under injected faults
in ``tests/test_serve_faults.py``) survives them:

* :class:`RetryPolicy` draws its jitter from a seeded RNG, so the delay
  SEQUENCE is a pure function of (seed, call order) — and delays only
  affect wall time, never which answer a query gets;
* :class:`CircuitBreaker` measures its open→half-open cooldown in
  *rejected dispatch opportunities* (``probe_after``), not wall-clock
  seconds, so a replay of the same dispatch sequence walks the same
  closed → open → half-open → closed path bit-identically.  An optional
  ``cooldown_s`` adds a wall-clock minimum on top for real deployments.

The state machine (see ``docs/serving.md`` for the diagram):

* **closed** — dispatches flow; ``open_after`` CONSECUTIVE failures trip
  the breaker (any success resets the streak);
* **open** — every dispatch is rejected without touching the oracle
  (callers degrade to the surrogate tier or fail fast); after
  ``probe_after`` rejections (and ``cooldown_s``, if set) the next
  ``allow()`` admits exactly one half-open probe;
* **half-open** — one probe in flight: success closes the breaker,
  failure re-opens it and the cooldown starts over.
"""

from __future__ import annotations

import threading
import time
from random import Random
from typing import Callable, Iterator, List, Optional, Tuple

__all__ = ["RetryPolicy", "CircuitBreaker"]


class RetryPolicy:
    """Jittered-exponential-backoff schedule for transient dispatch
    failures: attempt ``i`` (0-based) sleeps ``base_s * factor**(i-1) *
    (1 + jitter * u)`` first, with ``u ~ U[0, 1)`` from a seeded RNG and
    no sleep before the first attempt.  ``max_attempts`` bounds the total
    tries (1 = no retries)."""

    def __init__(self, max_attempts: int = 3, base_s: float = 0.005,
                 factor: float = 2.0, jitter: float = 0.5, seed: int = 0,
                 sleep: Callable[[float], None] = time.sleep):
        if max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got {max_attempts}")
        if base_s < 0 or factor < 1.0 or jitter < 0:
            raise ValueError("need base_s >= 0, factor >= 1, jitter >= 0")
        self.max_attempts = int(max_attempts)
        self.base_s = float(base_s)
        self.factor = float(factor)
        self.jitter = float(jitter)
        self._rng = Random(seed)
        self._sleep = sleep
        self._lock = threading.Lock()

    def delays(self) -> Iterator[float]:
        """One backoff schedule: yields ``max_attempts`` delays (the
        first is always 0.0); the caller sleeps each delay before the
        corresponding attempt."""
        for i in range(self.max_attempts):
            if i == 0:
                yield 0.0
                continue
            with self._lock:
                u = self._rng.random()
            yield self.base_s * self.factor ** (i - 1) * (1 + self.jitter * u)

    def call(self, fn: Callable[[], object],
             retry_on: Tuple[type, ...],
             on_retry: Optional[Callable[[BaseException], None]] = None):
        """Run ``fn`` under the schedule: exceptions in ``retry_on`` are
        retried (``on_retry`` observes each one) until the budget is
        spent, then the last one propagates; anything else propagates
        immediately."""
        last: Optional[BaseException] = None
        for i, delay in enumerate(self.delays()):
            if delay:
                self._sleep(delay)
            try:
                return fn()
            except retry_on as e:          # noqa: PERF203 — retry loop
                last = e
                if on_retry is not None and i + 1 < self.max_attempts:
                    on_retry(e)
        assert last is not None
        raise last


class CircuitBreaker:
    """Consecutive-failure circuit breaker over the packed dispatch (see
    the module docstring for the state machine).  Thread-safe; every
    transition is recorded in :attr:`transitions` as ``(from, to)`` pairs
    so tests can assert the exact path taken."""

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half-open"

    def __init__(self, open_after: int = 3, probe_after: int = 2,
                 cooldown_s: float = 0.0,
                 clock: Callable[[], float] = time.monotonic):
        if open_after < 1:
            raise ValueError(f"open_after must be >= 1, got {open_after}")
        if probe_after < 0:
            raise ValueError(f"probe_after must be >= 0, got {probe_after}")
        self.open_after = int(open_after)
        self.probe_after = int(probe_after)
        self.cooldown_s = float(cooldown_s)
        self._clock = clock
        self._lock = threading.Lock()
        self._state = self.CLOSED
        self._consecutive_failures = 0
        self._rejected_since_open = 0
        self._opened_at = 0.0
        self.opens = 0                      # total closed/half-open -> open
        self.shed = 0                       # dispatches rejected while open
        self.transitions: List[Tuple[str, str]] = []

    @property
    def state(self) -> str:
        """Current state: ``"closed"``, ``"open"``, or ``"half-open"``."""
        with self._lock:
            return self._state

    def _move(self, to: str) -> None:
        self.transitions.append((self._state, to))
        self._state = to

    def allow(self) -> bool:
        """May the next dispatch touch the oracle?  While open, each call
        is one rejected opportunity; after ``probe_after`` of them (and
        the wall cooldown, if any) the breaker goes half-open and THIS
        call is admitted as the probe."""
        with self._lock:
            if self._state == self.CLOSED:
                return True
            if self._state == self.HALF_OPEN:
                # one probe at a time: concurrent dispatches keep shedding
                # until the in-flight probe reports back
                self.shed += 1
                return False
            ready = self._rejected_since_open >= self.probe_after and \
                (self._clock() - self._opened_at) >= self.cooldown_s
            if ready:
                self._move(self.HALF_OPEN)
                return True
            self._rejected_since_open += 1
            self.shed += 1
            return False

    def record_success(self) -> None:
        """A dispatch completed: resets the failure streak; a successful
        half-open probe closes the breaker."""
        with self._lock:
            self._consecutive_failures = 0
            if self._state == self.HALF_OPEN:
                self._move(self.CLOSED)

    def record_failure(self) -> None:
        """A dispatch failed (retries exhausted): a failed probe
        re-opens; in closed state, ``open_after`` consecutive failures
        trip the breaker."""
        with self._lock:
            self._consecutive_failures += 1
            if self._state == self.HALF_OPEN or (
                    self._state == self.CLOSED
                    and self._consecutive_failures >= self.open_after):
                self._move(self.OPEN)
                self.opens += 1
                self._rejected_since_open = 0
                self._opened_at = self._clock()

    def snapshot(self) -> dict:
        """Counters for ``DSEService.stats()`` / the health probe."""
        with self._lock:
            return {"state": self._state, "opens": self.opens,
                    "shed": self.shed,
                    "consecutive_failures": self._consecutive_failures,
                    "transitions": list(self.transitions)}
