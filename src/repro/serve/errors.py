"""Structured error taxonomy for the serving stack.

Every way a query can fail — shed at admission, past its deadline, or
stranded behind a dead oracle — maps to one :class:`ServeError` subclass
carrying a stable machine-readable ``kind``, an HTTP-flavoured ``code``,
and a ``retryable`` hint, so clients (and the RPC front-end, which
serializes them as ``{"ok": false, "error": {...}}`` frames) can react
programmatically instead of parsing message strings.

The taxonomy is deliberately small and closed:

=====================  ====  =========  =======================================
class                  code  retryable  raised when
=====================  ====  =========  =======================================
``InvalidQuery``       400   no         the query itself is malformed (unknown
                                        workload/arch/knob, out-of-range pin)
``Overloaded``         429   yes        the front-end's admission queue is full
                                        (load shedding — try again later)
``OracleUnavailable``  503   yes        the circuit breaker is open and the
                                        query has no surrogate coverage to
                                        degrade onto
``DeadlineExceeded``   504   yes        the per-query deadline or the client
                                        timeout elapsed first
=====================  ====  =========  =======================================

``TransientDispatchError`` / ``PoisonedDispatch`` are internal: the
retry policy treats them as retryable dispatch outcomes and they never
reach a client un-translated (after the retry budget they surface as
``OracleUnavailable`` for the queries that could not degrade).
"""

from __future__ import annotations

from concurrent.futures import TimeoutError as _FutureTimeout
from typing import Dict, Optional, Type

__all__ = [
    "ServeError", "InvalidQuery", "Overloaded", "OracleUnavailable",
    "DeadlineExceeded", "TransientDispatchError", "PoisonedDispatch",
    "error_payload", "error_from_payload",
]


class ServeError(Exception):
    """Base class: a structured, client-visible serving failure."""

    kind: str = "serve-error"
    code: int = 500
    retryable: bool = False

    def __init__(self, message: str = "", **detail):
        super().__init__(message or self.kind)
        self.detail: Dict[str, object] = detail


class InvalidQuery(ServeError):
    """The query itself is malformed — retrying the same bytes cannot
    succeed (unknown workload/arch/knob, out-of-range override, bad
    frame)."""

    kind = "invalid-query"
    code = 400
    retryable = False


class Overloaded(ServeError):
    """Load-shed at admission: the front-end's bounded in-flight queue is
    full.  The 429 of the serving stack — the request was never enqueued,
    so retrying after a backoff is safe and expected."""

    kind = "overloaded"
    code = 429
    retryable = True


class OracleUnavailable(ServeError):
    """The packed oracle is unreachable (circuit breaker open / retries
    exhausted) and this query has no calibrated surrogate coverage to
    degrade onto — it fails fast instead of queuing behind a dead
    dispatch."""

    kind = "oracle-unavailable"
    code = 503
    retryable = True


class DeadlineExceeded(ServeError, _FutureTimeout):
    """The per-query deadline (or the blocking-call timeout) elapsed
    before an answer was produced.  Subclasses
    ``concurrent.futures.TimeoutError`` so callers of the pre-deadline
    API that caught ``TimeoutError`` keep working unchanged."""

    kind = "deadline-exceeded"
    code = 504
    retryable = True


class TransientDispatchError(ServeError):
    """Internal: one packed-dispatch attempt failed in a way worth
    retrying (injected fault, flaky backend).  Consumed by the retry
    policy / circuit breaker; clients never see it directly."""

    kind = "transient-dispatch"
    code = 503
    retryable = True


class PoisonedDispatch(TransientDispatchError):
    """Internal: the dispatch RETURNED, but its payload failed output
    validation (non-finite cycles/energy) — treated exactly like a
    failed attempt so a misbehaving oracle cannot leak garbage answers."""

    kind = "poisoned-dispatch"


_KINDS: Dict[str, Type[ServeError]] = {
    cls.kind: cls
    for cls in (ServeError, InvalidQuery, Overloaded, OracleUnavailable,
                DeadlineExceeded, TransientDispatchError, PoisonedDispatch)
}


def error_payload(err: BaseException) -> Dict[str, object]:
    """The wire form of an error (``{"kind", "code", "message",
    "retryable", "detail"}``) — non-:class:`ServeError` exceptions map to
    the base kind so the frame is always well-formed."""
    if isinstance(err, ServeError):
        return {"kind": err.kind, "code": err.code, "message": str(err),
                "retryable": err.retryable, "detail": dict(err.detail)}
    return {"kind": ServeError.kind, "code": ServeError.code,
            "message": f"{type(err).__name__}: {err}", "retryable": False,
            "detail": {}}


def error_from_payload(payload: Dict[str, object],
                       default: Optional[Type[ServeError]] = None
                       ) -> ServeError:
    """Reconstruct the matching :class:`ServeError` subclass from a wire
    payload (unknown kinds fall back to ``default`` or the base class) —
    the client half of the structured-error round trip."""
    cls = _KINDS.get(str(payload.get("kind")), default or ServeError)
    err = cls(str(payload.get("message", "")),
              **dict(payload.get("detail") or {}))
    return err
