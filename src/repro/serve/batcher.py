"""Bounded-window micro-batching for concurrent query streams.

Many clients submit items concurrently; one worker thread coalesces them
into *dispatches* — contiguous, arrival-ordered batches of at most
``max_batch`` items, closed early when the batch fills and at the latest
``window_s`` seconds after its first item arrived.  The dispatch callback
receives the whole batch and returns one result per item; results resolve
the per-item futures.

The batching CONTRACT the property tests pin down
(``tests/test_property.py``):

* every submitted item lands in exactly one dispatch (the dispatch log is
  a partition of the submission sequence — no drop, no dup);
* batches are contiguous in arrival order (the worker drains FIFO);
* per-item results never depend on batchmates (that part is the dispatch
  function's obligation — the service keeps per-query answers a pure
  function of the query, which is what makes micro-batching invisible).

``hold()`` freezes batch formation (submissions queue up but nothing
dispatches) so tests and benchmarks can stage exact window contents
instead of racing the wall clock.
"""

from __future__ import annotations

import atexit
import threading
import time
import weakref
from concurrent.futures import Future
from contextlib import contextmanager
from typing import Callable, List, Optional, Sequence, Tuple

__all__ = ["MicroBatcher", "plan_batches"]

# every live batcher, so interpreter shutdown can flush + join the worker
# threads of instances nobody explicitly closed (weak: a collected batcher
# needs no cleanup — its worker is a daemon and dies with the process)
_LIVE: "weakref.WeakSet[MicroBatcher]" = weakref.WeakSet()


def _close_all() -> None:
    """``atexit`` safety net: close every still-live batcher so no worker
    thread is left running user code while the interpreter tears down
    (unjoined workers racing module teardown raise spurious exceptions)."""
    for b in list(_LIVE):
        b.close(timeout=1.0)


atexit.register(_close_all)


def plan_batches(n: int, max_batch: int) -> List[Tuple[int, int]]:
    """Arrival-ordered batch boundaries for ``n`` pending items:
    ``[(start, end), ...]`` half-open index ranges, each at most
    ``max_batch`` long — the same greedy FIFO split the worker thread
    applies, exposed pure so the synchronous replay path
    (``DSEService.query_many``) provably coalesces identically."""
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    return [(s, min(s + max_batch, n)) for s in range(0, n, max_batch)]


class MicroBatcher:
    """One worker thread turning concurrent ``submit`` calls into bounded
    arrival-ordered dispatches (see the module docstring for the
    contract).  ``dispatch`` maps a list of items to a list of results of
    the same length; an exception from it fails every future in the
    batch.  ``dispatch_log`` records the sequence numbers of every batch,
    in dispatch order — the partition evidence tests assert on."""

    def __init__(self, dispatch: Callable[[List], List],
                 max_batch: int = 8, window_s: float = 0.002):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if window_s < 0:
            raise ValueError(f"window_s must be >= 0, got {window_s}")
        self._dispatch = dispatch
        self.max_batch = int(max_batch)
        self.window_s = float(window_s)
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._pending: List[Tuple[int, object, Future]] = []
        self._seq = 0
        self._held = 0
        self._in_flight = 0
        self._closed = False
        self.dispatch_log: List[List[int]] = []
        self._worker = threading.Thread(target=self._run, daemon=True,
                                        name="microbatcher")
        self._worker.start()
        _LIVE.add(self)

    # -- client side --------------------------------------------------------

    def submit(self, item) -> Future:
        """Enqueue one item; returns the future its result will resolve."""
        fut: Future = Future()
        with self._cond:
            if self._closed:
                raise RuntimeError("MicroBatcher is closed")
            self._pending.append((self._seq, item, fut))
            self._seq += 1
            self._cond.notify_all()
        return fut

    @contextmanager
    def hold(self):
        """Freeze batch formation while the context is open: submissions
        accumulate into one window deterministically (tests/benchmarks
        stage exact batch contents instead of racing ``window_s``)."""
        with self._cond:
            self._held += 1
        try:
            yield self
        finally:
            with self._cond:
                self._held -= 1
                self._cond.notify_all()

    def drain(self, timeout: float = 30.0) -> None:
        """Block until every already-submitted item has been dispatched
        AND its future resolved (the dispatch log is complete up to the
        last pre-drain submission when this returns)."""
        deadline = time.monotonic() + timeout
        with self._cond:
            while self._pending or self._in_flight:
                left = deadline - time.monotonic()
                if left <= 0:
                    raise TimeoutError("MicroBatcher.drain timed out")
                self._cond.wait(left)

    def close(self, timeout: Optional[float] = None) -> None:
        """Dispatch whatever is pending, then stop the worker thread.

        Idempotent — safe to call repeatedly, from ``atexit``, or while a
        ``hold()`` is open (closing overrides the hold so pending items
        still flush rather than deadlocking the worker).  ``timeout``
        bounds the join; ``None`` waits until the worker exits."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        if self._worker is not threading.current_thread():
            self._worker.join(timeout)
        _LIVE.discard(self)

    def __enter__(self) -> "MicroBatcher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- worker side --------------------------------------------------------

    def _take_batch(self) -> List[Tuple[int, object, Future]]:
        """Wait for a window to close, then pop the next FIFO batch: at
        most ``max_batch`` items, no earlier than ``window_s`` after the
        window's first item arrived (unless the batch is already full, or
        the batcher is closing)."""
        with self._cond:
            while True:
                # a close overrides any open hold(): pending items must
                # still flush or the worker (and its joiner) deadlocks
                if self._pending and (not self._held or self._closed):
                    deadline = self._window_open + self.window_s
                    if (len(self._pending) >= self.max_batch
                            or self._closed
                            or time.monotonic() >= deadline):
                        batch = self._pending[: self.max_batch]
                        del self._pending[: len(batch)]
                        self._in_flight += 1
                        return batch
                    self._cond.wait(max(0.0, deadline - time.monotonic()))
                    continue
                if self._closed and not self._pending:
                    return []
                if self._pending and self._held:
                    self._cond.wait()
                else:
                    # idle: note when the NEXT window opens
                    self._cond.wait()
                    self._window_open = time.monotonic()

    def _run(self) -> None:
        self._window_open = time.monotonic()
        while True:
            batch = self._take_batch()
            if not batch:
                return
            self._window_open = time.monotonic()
            items = [it for _, it, _ in batch]
            try:
                results = self._dispatch(items)
                if len(results) != len(items):
                    raise RuntimeError(
                        f"dispatch returned {len(results)} results for "
                        f"{len(items)} items")
            except Exception as e:     # noqa: BLE001 — forwarded to futures
                self.dispatch_log.append([seq for seq, _, _ in batch])
                for _, _, fut in batch:
                    fut.set_exception(e)
                self._settle()
                continue
            self.dispatch_log.append([seq for seq, _, _ in batch])
            for (_, _, fut), res in zip(batch, results):
                fut.set_result(res)
            self._settle()

    def _settle(self) -> None:
        with self._cond:
            self._in_flight -= 1
            if not self._pending and not self._in_flight:
                self._cond.notify_all()   # wake drain()
