"""Bounded-window micro-batching for concurrent query streams.

Many clients submit items concurrently; one worker thread coalesces them
into *dispatches* — contiguous, arrival-ordered batches of at most
``max_batch`` items, closed early when the batch fills and at the latest
``window_s`` seconds after its first item arrived.  The dispatch callback
receives the whole batch and returns one result per item; results resolve
the per-item futures.

The batching CONTRACT the property tests pin down
(``tests/test_property.py``):

* every submitted item lands in exactly one dispatch (the dispatch log is
  a partition of the submission sequence — no drop, no dup);
* batches are contiguous in arrival order (the worker drains FIFO);
* per-item results never depend on batchmates (that part is the dispatch
  function's obligation — the service keeps per-query answers a pure
  function of the query, which is what makes micro-batching invisible).

``hold()`` freezes batch formation (submissions queue up but nothing
dispatches) so tests and benchmarks can stage exact window contents
instead of racing the wall clock.
"""

from __future__ import annotations

import atexit
import threading
import time
import weakref
from concurrent.futures import Future
from contextlib import contextmanager
from typing import Callable, List, Optional, Sequence, Tuple

__all__ = ["MicroBatcher", "plan_batches"]

# every live batcher, so interpreter shutdown can flush + join the worker
# threads of instances nobody explicitly closed (weak: a collected batcher
# needs no cleanup — its worker is a daemon and dies with the process)
_LIVE: "weakref.WeakSet[MicroBatcher]" = weakref.WeakSet()


def _close_all() -> None:
    """``atexit`` safety net: close every still-live batcher so no worker
    thread is left running user code while the interpreter tears down
    (unjoined workers racing module teardown raise spurious exceptions)."""
    for b in list(_LIVE):
        b.close(timeout=1.0)


atexit.register(_close_all)


def plan_batches(n: int, max_batch: int) -> List[Tuple[int, int]]:
    """Arrival-ordered batch boundaries for ``n`` pending items:
    ``[(start, end), ...]`` half-open index ranges, each at most
    ``max_batch`` long — the same greedy FIFO split the worker thread
    applies, exposed pure so the synchronous replay path
    (``DSEService.query_many``) provably coalesces identically."""
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    return [(s, min(s + max_batch, n)) for s in range(0, n, max_batch)]


class MicroBatcher:
    """One worker thread turning concurrent ``submit`` calls into bounded
    arrival-ordered dispatches (see the module docstring for the
    contract).  ``dispatch`` maps a list of items to a list of results of
    the same length; a result element that is itself an exception fails
    ONLY that item's future (per-item structured errors), while an
    exception raised by ``dispatch`` fails every future in the batch —
    and a non-``Exception`` ``BaseException`` (``KeyboardInterrupt``,
    ``SystemExit``, injected ``WorkerKill``) additionally re-raises after
    failing the futures, so the worker dies instead of swallowing it; the
    forwarded exception carries the window's items as ``batch_items``.
    ``dispatch_log`` records the sequence numbers of every batch, in
    dispatch order — the partition evidence tests assert on."""

    def __init__(self, dispatch: Callable[[List], List],
                 max_batch: int = 8, window_s: float = 0.002):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if window_s < 0:
            raise ValueError(f"window_s must be >= 0, got {window_s}")
        self._dispatch = dispatch
        self.max_batch = int(max_batch)
        self.window_s = float(window_s)
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._pending: List[Tuple[int, object, Future,
                                  Optional[float]]] = []
        self._seq = 0
        self._held = 0
        self._in_flight = 0
        self._closed = False
        self.dispatch_log: List[List[int]] = []
        self.cancelled = 0              # futures cancelled before dispatch
        self.worker_restarts = 0        # respawns after a worker death
        self._dead = False              # worker announced its own death
        self._window_open = time.monotonic()
        self._worker = self._spawn_worker()
        _LIVE.add(self)

    def _spawn_worker(self) -> threading.Thread:
        worker = threading.Thread(target=self._run, daemon=True,
                                  name="microbatcher")
        worker.start()
        return worker

    def _ensure_worker(self) -> None:
        """Worker supervision (caller must hold the lock): a worker
        killed mid-dispatch by a ``BaseException`` (injected
        ``WorkerKill``, a stray ``SystemExit``) is respawned so the
        batcher keeps serving instead of stranding every later
        submission.  The worker flags ``_dead`` under the lock BEFORE it
        re-raises, so a submit racing its unwind (``is_alive()`` still
        true) respawns rather than enqueuing onto a corpse."""
        if not self._closed and (self._dead or not self._worker.is_alive()):
            self.worker_restarts += 1
            self._dead = False
            self._worker = self._spawn_worker()

    # -- client side --------------------------------------------------------

    def submit(self, item, deadline: Optional[float] = None) -> Future:
        """Enqueue one item; returns the future its result will resolve.
        ``deadline`` (absolute ``time.monotonic()`` seconds) closes the
        item's window no later than that instant — a tight per-query
        deadline shortens its window instead of waiting out
        ``window_s``."""
        fut: Future = Future()
        with self._cond:
            if self._closed:
                raise RuntimeError("MicroBatcher is closed")
            self._ensure_worker()
            self._pending.append((self._seq, item, fut, deadline))
            self._seq += 1
            self._cond.notify_all()
        return fut

    @contextmanager
    def hold(self):
        """Freeze batch formation while the context is open: submissions
        accumulate into one window deterministically (tests/benchmarks
        stage exact batch contents instead of racing ``window_s``)."""
        with self._cond:
            self._held += 1
        try:
            yield self
        finally:
            with self._cond:
                self._held -= 1
                self._cond.notify_all()

    def drain(self, timeout: float = 30.0) -> None:
        """Block until every already-submitted item has been dispatched
        AND its future resolved (the dispatch log is complete up to the
        last pre-drain submission when this returns).  Purely
        event-driven: the waiter sleeps on the condition until the worker
        settles the last batch (``Condition.wait_for`` — no deadline
        polling loop burning a core under load)."""
        with self._cond:
            self._ensure_worker()
            done = self._cond.wait_for(
                lambda: not self._pending and not self._in_flight, timeout)
            if not done:
                raise TimeoutError("MicroBatcher.drain timed out")

    def close(self, timeout: Optional[float] = None) -> None:
        """Dispatch whatever is pending, then stop the worker thread.

        Idempotent — safe to call repeatedly, from ``atexit``, or while a
        ``hold()`` is open (closing overrides the hold so pending items
        still flush rather than deadlocking the worker).  ``timeout``
        bounds the join; ``None`` waits until the worker exits."""
        with self._cond:
            # a dead worker (BaseException mid-dispatch) with items still
            # queued gets one last respawn so close() flushes rather than
            # stranding those futures
            if self._pending:
                self._ensure_worker()
            self._closed = True
            self._cond.notify_all()
        if self._worker is not threading.current_thread():
            self._worker.join(timeout)
        _LIVE.discard(self)

    def __enter__(self) -> "MicroBatcher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- worker side --------------------------------------------------------

    def _take_batch(self) -> List[Tuple[int, object, Future,
                                        Optional[float]]]:
        """Wait for a window to close, then pop the next FIFO batch: at
        most ``max_batch`` items, no earlier than ``window_s`` after the
        window's first item arrived — or the earliest per-item deadline
        in the forming batch, whichever comes first (unless the batch is
        already full, or the batcher is closing).  Items whose futures
        were cancelled while queued are dropped here, before dispatch."""
        with self._cond:
            while True:
                # reap cancel()ed futures: they must neither be dispatched
                # nor keep a window open waiting on them
                live = [p for p in self._pending if not p[2].cancelled()]
                if len(live) != len(self._pending):
                    self.cancelled += len(self._pending) - len(live)
                    self._pending[:] = live
                    if not live:
                        self._cond.notify_all()   # wake drain()
                # a close overrides any open hold(): pending items must
                # still flush or the worker (and its joiner) deadlocks
                if self._pending and (not self._held or self._closed):
                    deadline = self._window_open + self.window_s
                    for _, _, _, item_dl in self._pending[: self.max_batch]:
                        if item_dl is not None:
                            deadline = min(deadline, item_dl)
                    if (len(self._pending) >= self.max_batch
                            or self._closed
                            or time.monotonic() >= deadline):
                        batch = self._pending[: self.max_batch]
                        del self._pending[: len(batch)]
                        self._in_flight += 1
                        return batch
                    self._cond.wait(max(0.0, deadline - time.monotonic()))
                    continue
                if self._closed and not self._pending:
                    return []
                if self._pending and self._held:
                    self._cond.wait()
                else:
                    # idle: note when the NEXT window opens
                    self._cond.wait()
                    self._window_open = time.monotonic()

    @staticmethod
    def _resolve(fut: Future, res: object) -> None:
        """Settle one future defensively: a result that IS an exception
        fails the future (per-item structured errors from the dispatch
        function), and a future cancelled mid-dispatch is left alone
        (its submitter already walked away — the outcome is accounted,
        not crashed on)."""
        if fut.cancelled():
            return
        if isinstance(res, BaseException):
            fut.set_exception(res)
        else:
            fut.set_result(res)

    def _run(self) -> None:
        while True:
            batch = self._take_batch()
            if not batch:
                return
            with self._cond:
                self._window_open = time.monotonic()
            items = [it for _, it, _, _ in batch]
            try:
                results = self._dispatch(items)
                if len(results) != len(items):
                    raise RuntimeError(
                        f"dispatch returned {len(results)} results for "
                        f"{len(items)} items")
            except BaseException as e:  # noqa: BLE001 — forwarded, see below
                # diagnosability: the forwarded exception names exactly
                # which window died with it
                try:
                    e.batch_items = tuple(items)
                except Exception:       # __slots__ exceptions: best-effort
                    pass
                self.dispatch_log.append([seq for seq, *_ in batch])
                for _, _, fut, _ in batch:
                    self._resolve(fut, e)
                self._settle()
                if not isinstance(e, Exception):
                    # KeyboardInterrupt / SystemExit / injected WorkerKill:
                    # fail the batch's futures (no client may hang) but
                    # NEVER swallow a BaseException into them — re-raise
                    # so the worker dies loudly.  Items already queued
                    # behind the dead window would otherwise strand (no
                    # later submit to trigger supervision), so the dying
                    # worker spawns its own successor when work remains;
                    # an idle batcher stays dead until the next submit.
                    with self._cond:
                        self._dead = True
                        if self._pending and not self._closed:
                            self.worker_restarts += 1
                            self._dead = False
                            self._worker = self._spawn_worker()
                    raise
                continue
            self.dispatch_log.append([seq for seq, *_ in batch])
            for (_, _, fut, _), res in zip(batch, results):
                self._resolve(fut, res)
            self._settle()

    def _settle(self) -> None:
        with self._cond:
            self._in_flight -= 1
            if not self._pending and not self._in_flight:
                self._cond.notify_all()   # wake drain()
