"""DSE-as-a-service: the persistent, micro-batching, cache-backed query
engine over the matrix-packed evaluator (see ``docs/serving.md``).

    from repro.serve import DSEService, Query

    with DSEService(networks=True, sharded=True) as svc:
        ans = svc.query(workload="gemm", archs=("gamma", "tpu_v5e"))
        print(ans.best_arch, ans.best.knobs(svc.space.names))

Fault tolerance rides on top: :mod:`repro.serve.policy` (retry +
circuit breaker), :mod:`repro.serve.faults` (deterministic fault
injection), :mod:`repro.serve.errors` (the structured error taxonomy)
and :mod:`repro.serve.frontend` (the length-prefixed-JSON RPC
front-end with deadlines, admission control, and health probes).
"""

from .batcher import MicroBatcher, plan_batches
from .engine import DEGRADED_WIDEN, DSEService
from .errors import (DeadlineExceeded, InvalidQuery, OracleUnavailable,
                     Overloaded, PoisonedDispatch, ServeError,
                     TransientDispatchError, error_from_payload,
                     error_payload)
from .faults import (ENV_FAULT_PLAN, FaultAction, FaultInjector, FaultPlan,
                     WorkerKill)
from .frontend import ServeClient, ServeFrontend
from .policy import CircuitBreaker, RetryPolicy
from .query import Answer, Design, Query

__all__ = [
    "DSEService", "DEGRADED_WIDEN", "MicroBatcher", "plan_batches",
    "Query", "Design", "Answer",
    "ServeError", "InvalidQuery", "Overloaded", "OracleUnavailable",
    "DeadlineExceeded", "TransientDispatchError", "PoisonedDispatch",
    "error_payload", "error_from_payload",
    "RetryPolicy", "CircuitBreaker",
    "FaultPlan", "FaultAction", "FaultInjector", "WorkerKill",
    "ENV_FAULT_PLAN",
    "ServeFrontend", "ServeClient",
]
