"""DSE-as-a-service: the persistent, micro-batching, cache-backed query
engine over the matrix-packed evaluator (see ``docs/serving.md``).

    from repro.serve import DSEService, Query

    with DSEService(networks=True, sharded=True) as svc:
        ans = svc.query(workload="gemm", archs=("gamma", "tpu_v5e"))
        print(ans.best_arch, ans.best.knobs(svc.space.names))
"""

from .batcher import MicroBatcher, plan_batches
from .engine import DSEService
from .query import Answer, Design, Query

__all__ = ["DSEService", "MicroBatcher", "plan_batches",
           "Query", "Design", "Answer"]
