"""The network front-end: length-prefixed-JSON RPC over TCP for
:class:`~repro.serve.engine.DSEService` (ROADMAP item 1's "real network
front-end (sockets/RPC)").

Wire protocol — deliberately boring: each frame is a 4-byte big-endian
length followed by a UTF-8 JSON body, both directions, many requests per
connection.  Requests are ``{"op": "query" | "health" | "stats", ...}``;
query requests carry the :meth:`Query.to_payload` fields plus an
optional ``deadline_ms``.  Responses are ``{"ok": true, ...}`` or
``{"ok": false, "error": {kind, code, message, retryable, detail}}``
(:mod:`repro.serve.errors`) — a client never has to parse message
strings to decide whether to retry.

Failure semantics at this layer (``docs/serving.md`` §Failure
semantics):

* **bounded admission** — at most ``max_inflight`` queries are being
  served concurrently; one more is shed immediately with a 429-style
  ``overloaded`` error instead of queuing without bound (the client's
  cue to back off);
* **deadline propagation** — ``deadline_ms`` becomes the service-side
  ``deadline_s``: it shortens the query's micro-batch window, expires it
  before evaluation when the window was too slow, and bounds the
  blocking wait — one number, enforced at every layer;
* **health/readiness** — ``{"op": "health"}`` answers without touching
  an oracle: readiness, circuit-breaker state, per-tier answer counts
  and latency, fallback rate, and the shed/timeout counters — what a
  load balancer polls to take a degraded replica out of rotation.

:class:`ServeClient` is the matching client (used by the load harness
and the chaos tests); :func:`send_frame` / :func:`recv_frame` expose the
framing for anyone speaking the protocol raw.
"""

from __future__ import annotations

import json
import socket
import socketserver
import struct
import threading
from typing import Dict, Mapping, Optional, Tuple

from .engine import DSEService
from .errors import (InvalidQuery, Overloaded, ServeError, error_from_payload,
                     error_payload)
from .query import Answer, Query

__all__ = ["ServeFrontend", "ServeClient", "send_frame", "recv_frame"]

_LEN = struct.Struct(">I")
MAX_FRAME = 16 * 1024 * 1024


def _jsonable(obj):
    """Best-effort JSON sanitizer for stats payloads (tuples, numpy
    scalars, dict keys that are tuples)."""
    if isinstance(obj, Mapping):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if hasattr(obj, "item"):               # numpy scalar
        return obj.item()
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    return str(obj)


def send_frame(sock: socket.socket, payload: Dict) -> None:
    """Write one length-prefixed JSON frame."""
    body = json.dumps(payload, separators=(",", ":")).encode("utf-8")
    if len(body) > MAX_FRAME:
        raise ValueError(f"frame of {len(body)} bytes exceeds {MAX_FRAME}")
    sock.sendall(_LEN.pack(len(body)) + body)


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            return None
        buf += chunk
    return buf


def recv_frame(sock: socket.socket) -> Optional[Dict]:
    """Read one frame; ``None`` on a clean EOF at a frame boundary."""
    head = _recv_exact(sock, _LEN.size)
    if head is None:
        return None
    (n,) = _LEN.unpack(head)
    if n > MAX_FRAME:
        raise ValueError(f"peer announced a {n}-byte frame (> {MAX_FRAME})")
    body = _recv_exact(sock, n)
    if body is None:
        return None
    return json.loads(body.decode("utf-8"))


class ServeFrontend:
    """A threaded TCP server wrapping one :class:`DSEService` (see the
    module docstring for protocol and failure semantics).  Binds and
    starts serving on construction (``port=0`` picks a free port — read
    :attr:`address`); ``close()`` stops the listener, existing
    connections drain on their next request."""

    def __init__(self, service: DSEService, host: str = "127.0.0.1",
                 port: int = 0, max_inflight: int = 32,
                 default_timeout_s: float = 120.0):
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got {max_inflight}")
        self.service = service
        self.max_inflight = int(max_inflight)
        self.default_timeout_s = float(default_timeout_s)
        self._lock = threading.Lock()
        self._inflight = 0
        self.accepted = 0               # queries admitted past the gate
        self.shed = 0                   # queries rejected 429-style
        self.rpc_errors = 0             # error frames sent (any kind)
        frontend = self

        class _Handler(socketserver.BaseRequestHandler):
            def handle(self) -> None:  # one thread per connection
                sock = self.request
                while True:
                    try:
                        req = recv_frame(sock)
                    except (ValueError, OSError, json.JSONDecodeError):
                        break
                    if req is None:
                        break
                    try:
                        send_frame(sock, frontend._handle(req))
                    except OSError:
                        break

        class _Server(socketserver.ThreadingTCPServer):
            daemon_threads = True
            allow_reuse_address = True

        self._server = _Server((host, port), _Handler)
        self._thread = threading.Thread(target=self._server.serve_forever,
                                        daemon=True, name="serve-frontend")
        self._thread.start()

    @property
    def address(self) -> Tuple[str, int]:
        """The bound ``(host, port)``."""
        return self._server.server_address

    def close(self) -> None:
        """Stop accepting connections and join the listener thread (the
        wrapped service is NOT closed — it may outlive the front-end)."""
        self._server.shutdown()
        self._server.server_close()
        self._thread.join(timeout=10.0)

    def __enter__(self) -> "ServeFrontend":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- request handling ---------------------------------------------------

    def _handle(self, req: Dict) -> Dict:
        op = req.get("op", "query")
        if op == "health":
            return self._health()
        if op == "stats":
            return {"ok": True, "stats": _jsonable(self.service.stats())}
        if op == "query":
            return self._query(req)
        with self._lock:
            self.rpc_errors += 1
        return {"ok": False, "error": error_payload(
            InvalidQuery(f"unknown op {op!r}"))}

    def _health(self) -> Dict:
        """Readiness + the failure-semantics counters, oracle-free: what
        a load balancer polls to spot a degraded or dead replica."""
        st = self.service.stats()
        ready = not self.service.batcher._closed
        with self._lock:
            inflight, shed = self._inflight, self.shed
        return {"ok": True, "ready": ready,
                "breaker": st["breaker"]["state"],
                "tiers": _jsonable(st["tiers"]),
                "tier_us_per_query": _jsonable(st["tier_us_per_query"]),
                "fallback_rate": st["fallback_rate"],
                "retries": st["retries"], "timeouts": st["timeouts"],
                "deadline_misses": st["deadline_misses"],
                "worker_restarts": st["worker_restarts"],
                "inflight": inflight, "shed": shed,
                "max_inflight": self.max_inflight}

    def _query(self, req: Dict) -> Dict:
        with self._lock:
            if self._inflight >= self.max_inflight:
                self.shed += 1
                self.rpc_errors += 1
                return {"ok": False, "error": error_payload(Overloaded(
                    f"{self._inflight} queries in flight "
                    f"(max_inflight={self.max_inflight})",
                    max_inflight=self.max_inflight))}
            self._inflight += 1
            self.accepted += 1
        try:
            deadline_ms = req.get("deadline_ms")
            deadline_s = None if deadline_ms is None \
                else float(deadline_ms) / 1e3
            try:
                q = Query.from_payload(req)
            except (KeyError, ValueError, TypeError) as e:
                raise InvalidQuery(str(e)) from e
            try:
                ans = self.service.query(
                    q, timeout=self.default_timeout_s, deadline_s=deadline_s)
            except (KeyError, ValueError) as e:
                # service-side validation (unknown workload/arch/knob,
                # out-of-range override) — not retryable
                raise InvalidQuery(str(e)) from e
            return {"ok": True, "answer": ans.to_payload()}
        except BaseException as e:      # noqa: BLE001 — every failure framed
            if isinstance(e, (KeyboardInterrupt, SystemExit)):
                raise
            with self._lock:
                self.rpc_errors += 1
            return {"ok": False, "error": error_payload(e)}
        finally:
            with self._lock:
                self._inflight -= 1


class ServeClient:
    """Blocking client for :class:`ServeFrontend` (one socket, many
    requests).  Query failures raise the matching
    :class:`~repro.serve.errors.ServeError` subclass reconstructed from
    the error frame — ``Overloaded`` means back off and retry,
    ``InvalidQuery`` means don't."""

    def __init__(self, address: Tuple[str, int],
                 connect_timeout_s: float = 10.0,
                 io_timeout_s: float = 300.0):
        self._sock = socket.create_connection(address,
                                              timeout=connect_timeout_s)
        self._sock.settimeout(io_timeout_s)
        self._lock = threading.Lock()

    def _call(self, req: Dict) -> Dict:
        with self._lock:
            send_frame(self._sock, req)
            resp = recv_frame(self._sock)
        if resp is None:
            raise ConnectionError("server closed the connection")
        return resp

    def query(self, query: Optional[Query] = None,
              deadline_ms: Optional[float] = None, **kwargs) -> Answer:
        """Ask one question (a :class:`Query` or ``Query.make`` kwargs);
        returns the :class:`Answer` or raises the structured error."""
        q = query if query is not None else Query.make(**kwargs)
        req = {"op": "query", **q.to_payload()}
        if deadline_ms is not None:
            req["deadline_ms"] = float(deadline_ms)
        resp = self._call(req)
        if not resp.get("ok"):
            raise error_from_payload(resp.get("error") or {})
        return Answer.from_payload(resp["answer"])

    def health(self) -> Dict:
        """The readiness/health probe payload."""
        return self._call({"op": "health"})

    def stats(self) -> Dict:
        """The full (JSON-sanitized) ``DSEService.stats()`` payload."""
        return self._call({"op": "stats"})["stats"]

    def close(self) -> None:
        """Close the client's socket (idempotent)."""
        try:
            self._sock.close()
        except OSError:
            pass

    def __enter__(self) -> "ServeClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
