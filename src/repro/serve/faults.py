"""Deterministic, replayable fault injection for the serving stack.

A :class:`FaultPlan` is a schedule of failures keyed by **packed-dispatch
attempt index** — not wall-clock time — so the same chaos schedule
replays bit-identically in unit tests, the load harness
(``examples/serve_dse.py --faults``), and the CI ``chaos-smoke`` job.
Attempt ``n`` is the n-th time the service tries the packed oracle
(retries count: a dispatch retried twice consumes three attempt
indices), which makes a plan meaningful independent of how queries
happen to coalesce into windows.

Plans are written in a compact spec string::

    packed[2:5]=error; packed[6]=latency:0.05; packed[8]=poison; packed[9]=kill

``site[selector]=action`` clauses, ``;``-separated.  Selectors are
half-open attempt ranges (``N``, ``A:B``, ``A:`` = from A on, ``:B``);
later clauses override earlier ones.  Actions:

* ``error`` — the attempt raises
  :class:`~repro.serve.errors.TransientDispatchError` (a transient
  dispatch failure: the retry policy and circuit breaker see it);
* ``latency:S`` — the attempt succeeds but only after an injected
  ``S``-second spike (exercises deadlines and slow-oracle behaviour);
* ``poison`` — the attempt "succeeds" but returns an all-NaN payload;
  the service's output validation converts it into
  :class:`~repro.serve.errors.PoisonedDispatch`;
* ``kill`` — the attempt raises :class:`WorkerKill`, a ``BaseException``
  that tears down the batcher worker thread mid-flight (the batch's
  futures still fail cleanly, and the next submission respawns the
  worker — the "a worker dies" scenario).

Activate a plan via ``DSEService(fault_plan=...)`` (a plan, a spec
string, or ``None``) or the ``SERVE_FAULT_PLAN`` environment variable
(read when ``fault_plan`` is not given — the CI hook).
"""

from __future__ import annotations

import re
import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["FaultAction", "FaultPlan", "FaultInjector", "WorkerKill",
           "ENV_FAULT_PLAN"]

ENV_FAULT_PLAN = "SERVE_FAULT_PLAN"

_KINDS = ("error", "latency", "poison", "kill")


class WorkerKill(BaseException):
    """Injected worker-thread death.  Deliberately NOT an ``Exception``:
    it exercises the batcher's ``BaseException`` path — fail the batch's
    futures, then re-raise so the worker actually dies (like a real
    ``SystemExit``/``KeyboardInterrupt`` would) instead of being silently
    routed into futures."""


@dataclass(frozen=True)
class FaultAction:
    """What one attempt does: ``kind`` in ``{"ok", "error", "poison",
    "kill"}`` plus an optional injected ``latency_s`` sleep (a bare
    ``latency:S`` clause is ``kind="ok"`` with a spike)."""

    kind: str = "ok"
    latency_s: float = 0.0

    def __post_init__(self):
        if self.kind not in ("ok", "error", "poison", "kill"):
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.latency_s < 0:
            raise ValueError(f"latency_s must be >= 0, got {self.latency_s}")


_CLAUSE = re.compile(
    r"^(?P<site>[a-z_]+)\s*\[\s*(?P<lo>\d*)\s*(?P<colon>:?)\s*(?P<hi>\d*)\s*\]"
    r"\s*=\s*(?P<action>[a-z]+)(?::(?P<param>[0-9.eE+-]+))?$")


@dataclass(frozen=True)
class FaultPlan:
    """An ordered set of ``(site, [lo, hi), action)`` rules; the LAST
    matching rule wins so later clauses refine earlier ranges.  ``hi``
    ``None`` means unbounded (``A:``)."""

    rules: Tuple[Tuple[str, int, Optional[int], FaultAction], ...] = ()
    spec: str = field(default="", compare=False)

    SITES = ("packed",)

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """Parse the spec grammar in the module docstring; raises
        ``ValueError`` with the offending clause on malformed input."""
        rules: List[Tuple[str, int, Optional[int], FaultAction]] = []
        for raw in spec.split(";"):
            clause = raw.strip()
            if not clause:
                continue
            m = _CLAUSE.match(clause)
            if m is None:
                raise ValueError(f"malformed fault clause {clause!r} "
                                 f"(grammar: site[lo:hi]=action[:param])")
            site = m.group("site")
            if site not in cls.SITES:
                raise ValueError(f"unknown fault site {site!r} in "
                                 f"{clause!r}; known sites: {cls.SITES}")
            lo = int(m.group("lo") or 0)
            if m.group("colon"):
                hi = int(m.group("hi")) if m.group("hi") else None
            else:
                hi = lo + 1
            if hi is not None and hi <= lo:
                raise ValueError(f"empty attempt range in {clause!r}")
            kind, param = m.group("action"), m.group("param")
            if kind == "latency":
                action = FaultAction("ok", float(param if param is not None
                                                 else 0.01))
            elif kind in ("error", "poison", "kill"):
                if param is not None:
                    raise ValueError(f"{kind} takes no parameter "
                                     f"({clause!r})")
                action = FaultAction(kind)
            else:
                raise ValueError(f"unknown fault action {kind!r} in "
                                 f"{clause!r}; known: {_KINDS}")
            rules.append((site, lo, hi, action))
        return cls(rules=tuple(rules), spec=spec)

    def to_spec(self) -> str:
        """Canonical spec string (parses back to an equal plan)."""
        out = []
        for site, lo, hi, act in self.rules:
            sel = f"{lo}" if hi == lo + 1 else f"{lo}:{hi if hi else ''}"
            if act.kind == "ok":
                out.append(f"{site}[{sel}]=latency:{act.latency_s:g}")
            else:
                out.append(f"{site}[{sel}]={act.kind}")
        return ";".join(out)

    def action(self, site: str, n: int) -> FaultAction:
        """The action for attempt ``n`` at ``site`` (last match wins;
        default: a clean ``ok``)."""
        hit = FaultAction()
        for s, lo, hi, act in self.rules:
            if s == site and lo <= n and (hi is None or n < hi):
                hit = act
        return hit

    def max_faulty_attempt(self, site: str = "packed") -> int:
        """One past the last attempt index any non-ok rule can touch
        (``-1`` when a rule is unbounded) — lets harnesses check a plan's
        fault window actually ends so recovery is reachable."""
        worst = 0
        for s, lo, hi, act in self.rules:
            if s != site or act == FaultAction():
                continue
            if hi is None:
                return -1
            worst = max(worst, hi)
        return worst


class FaultInjector:
    """The runtime half: owns the per-site attempt counters (thread-safe)
    and hands each dispatch attempt its scheduled :class:`FaultAction`.
    One injector per service instance, so a fresh replay service walks
    the identical schedule from attempt 0."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._lock = threading.Lock()
        self._counts: Dict[str, int] = {}

    def next(self, site: str = "packed") -> Tuple[int, FaultAction]:
        """Claim the next attempt index at ``site`` and return it with
        its scheduled action."""
        with self._lock:
            n = self._counts.get(site, 0)
            self._counts[site] = n + 1
        return n, self.plan.action(site, n)

    def attempts(self, site: str = "packed") -> int:
        """Attempt indices consumed so far at ``site``."""
        with self._lock:
            return self._counts.get(site, 0)
