"""Query/answer surface of the DSE service.

A :class:`Query` is one client's "which accelerator + config for my
model?" question: a workload (an operator kind such as ``"gemm"`` or a
network name such as ``"whisper_small"``), an optional architecture
subset, optional knob overrides that pin design-space axes the client has
already committed to, and the number of ranked designs wanted back.

Queries are *canonical* — construction normalizes the archs/overrides
containers into sorted tuples — so a query's identity (:attr:`Query.key`)
is a pure function of what is being asked, never of how the dataclass was
spelled.  The service's answer cache, its dispatch dedup, and the
determinism guarantee ("same answer regardless of arrival order or
batching") all hang off that property.

An :class:`Answer` carries the Pareto-ranked :class:`Design` rows.  Both
are plain frozen dataclasses comparing by value, so tests can assert a
served answer ``==`` the answer recomputed from a direct Explorer sweep;
the bookkeeping :attr:`Answer.cached` flag is excluded from comparison
(a cache hit MUST equal the recomputed answer).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import (Dict, Iterable, Mapping, Optional, Sequence, Tuple,
                    Union)

__all__ = ["Query", "Design", "Answer"]


@dataclass(frozen=True)
class Query:
    """One client question over the served design space.

    ``workload``: operator kind (``"gemm"``) or network name
    (``"whisper_small"``); ``None`` asks over the whole served matrix.
    ``archs``: restrict to these architectures (``None`` = all).
    ``overrides``: sorted ``(knob name, θ)`` pairs pinning axes the client
    has fixed (their columns are overwritten in every candidate).
    ``top_k``: maximum number of ranked designs in the answer.

    Build via :meth:`make` (it normalizes dict/list arguments); the frozen
    tuple fields make the query hashable — :attr:`key` is the answer-cache
    and dedup identity.
    """

    workload: Optional[str] = None
    archs: Optional[Tuple[str, ...]] = None
    overrides: Tuple[Tuple[str, float], ...] = ()
    top_k: int = 5

    @staticmethod
    def make(workload: Optional[str] = None,
             archs: Optional[Sequence[str]] = None,
             overrides: Union[Mapping[str, float],
                              Iterable[Tuple[str, float]], None] = None,
             top_k: int = 5) -> "Query":
        """Canonicalizing constructor: ``archs`` (any iterable, or a bare
        string) and ``overrides`` (a mapping or ``(name, θ)`` pairs)
        become sorted tuples, so two queries asking the same thing are
        equal and cache-alias."""
        if isinstance(archs, str):
            archs = (archs,)
        if top_k < 1:
            raise ValueError(f"top_k must be >= 1, got {top_k}")
        if hasattr(overrides, "items"):
            overrides = overrides.items()
        return Query(
            workload=workload,
            archs=None if archs is None else tuple(sorted(set(archs))),
            overrides=() if not overrides else tuple(
                sorted((str(k), float(v)) for k, v in overrides)),
            top_k=int(top_k))

    @property
    def key(self) -> Tuple:
        """Hashable canonical identity (the answer-cache/dedup key)."""
        return (self.workload, self.archs, self.overrides, self.top_k)

    @property
    def override_map(self) -> Dict[str, float]:
        """The overrides as a plain dict (knob name -> pinned θ)."""
        return dict(self.overrides)

    def to_payload(self) -> Dict[str, object]:
        """JSON-safe wire form (the RPC front-end's request body)."""
        return {"workload": self.workload,
                "archs": None if self.archs is None else list(self.archs),
                "overrides": {k: v for k, v in self.overrides},
                "top_k": self.top_k}

    @staticmethod
    def from_payload(payload: Mapping) -> "Query":
        """Rebuild (and re-canonicalize) a query from its wire form."""
        return Query.make(workload=payload.get("workload"),
                          archs=payload.get("archs"),
                          overrides=payload.get("overrides"),
                          top_k=payload.get("top_k", 5))


@dataclass(frozen=True)
class Design:
    """One ranked design point in an answer: the shared knob vector θ plus
    its objectives over the query's cell subset.

    ``latency`` is the mean baseline-relative cycle count across the
    queried cells (1.0 = the reference machine); ``energy`` is the mean
    baseline-relative energy over the same cells (dynamic switching +
    static leakage, 1.0 = the reference machine); ``cost`` is the area
    proxy; ``cycles`` are the raw per-cell estimates, aligned with the
    answer's ``cells`` tuple."""

    theta: Tuple[float, ...]         # shared knob values, space order
    latency: float                   # mean baseline-relative cycles
    energy: float                    # mean baseline-relative energy
    cost: float                      # area proxy
    cycles: Tuple[float, ...]        # per queried cell, Answer.cells order

    def knobs(self, names: Sequence[str]) -> Dict[str, float]:
        """θ as a name -> value dict (``names`` from the design space)."""
        return dict(zip(names, self.theta))

    def to_payload(self) -> Dict[str, object]:
        """JSON-safe wire form."""
        return {"theta": list(self.theta), "latency": self.latency,
                "energy": self.energy, "cost": self.cost,
                "cycles": list(self.cycles)}

    @staticmethod
    def from_payload(payload: Mapping) -> "Design":
        """Rebuild a design from its wire form."""
        return Design(theta=tuple(float(v) for v in payload["theta"]),
                      latency=float(payload["latency"]),
                      energy=float(payload["energy"]),
                      cost=float(payload["cost"]),
                      cycles=tuple(float(c) for c in payload["cycles"]))


@dataclass(frozen=True)
class Answer:
    """The service's reply: the resolved cell subset and the Pareto-ranked
    designs (sorted by latency, at most ``query.top_k`` rows).

    ``best_arch`` names the architecture whose cell runs the top design at
    the lowest baseline-relative latency — the "which accelerator" half of
    the question; ``designs[0]`` is the "which config" half.  ``cached``
    records whether this reply came from the answer cache; ``tier`` names
    the oracle tier that computed it (``"packed"``, or ``"surrogate"``
    when the staged hierarchy answered from the fast tier) and
    ``err_bound`` is that tier's stated relative-error bound (0.0 for the
    exact packed tier).  The bookkeeping fields are excluded from
    equality because a cache hit must compare equal to the same answer
    recomputed from scratch."""

    query: Query
    cells: Tuple[str, ...]           # resolved cell names, matrix order
    designs: Tuple[Design, ...]      # Pareto-ranked, latency-ascending
    best_arch: str
    cached: bool = field(default=False, compare=False)
    tier: str = field(default="packed", compare=False)
    err_bound: float = field(default=0.0, compare=False)

    @property
    def best(self) -> Design:
        """The lowest-latency Pareto design (rank 0)."""
        return self.designs[0]

    def to_payload(self) -> Dict[str, object]:
        """JSON-safe wire form — the RPC front-end's answer body; the
        round trip (``from_payload(to_payload(a)) == a``) preserves value
        equality AND the bookkeeping tier/bound fields."""
        return {"query": self.query.to_payload(),
                "cells": list(self.cells),
                "designs": [d.to_payload() for d in self.designs],
                "best_arch": self.best_arch, "cached": self.cached,
                "tier": self.tier, "err_bound": self.err_bound}

    @staticmethod
    def from_payload(payload: Mapping) -> "Answer":
        """Rebuild an answer from its wire form (the client half)."""
        return Answer(query=Query.from_payload(payload["query"]),
                      cells=tuple(payload["cells"]),
                      designs=tuple(Design.from_payload(d)
                                    for d in payload["designs"]),
                      best_arch=str(payload["best_arch"]),
                      cached=bool(payload.get("cached", False)),
                      tier=str(payload.get("tier", "packed")),
                      err_bound=float(payload.get("err_bound", 0.0)))
