"""Runtime substrate: straggler detection, failure injection, metrics."""

from .monitor import FailureInjector, Metrics, StragglerMonitor

__all__ = ["StragglerMonitor", "FailureInjector", "Metrics"]
