"""Straggler detection, failure injection and step metrics.

``StragglerMonitor`` flags steps whose wall time deviates from the running
median by more than ``k`` median-absolute-deviations — at fleet scale this
is the first signal of a failing host/NIC before the job hard-fails; the
driver reacts by logging + (optionally) checkpointing early.

``FailureInjector`` deterministically raises at a chosen step — used by the
fault-tolerance tests to prove the checkpoint/restore path end-to-end.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

import numpy as np

__all__ = ["StragglerMonitor", "FailureInjector", "Metrics"]


class StragglerMonitor:
    def __init__(self, window: int = 50, k: float = 5.0, warmup: int = 5):
        self.window = window
        self.k = k
        self.warmup = warmup
        self.times: Deque[float] = deque(maxlen=window)
        self.flagged: List[int] = []
        self._t0: Optional[float] = None
        self._step = 0

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self) -> bool:
        """Record the step; True if it is a straggler."""
        assert self._t0 is not None, "stop() without start()"
        dt = time.perf_counter() - self._t0
        self._t0 = None
        self._step += 1
        is_straggler = False
        if len(self.times) >= self.warmup:
            med = float(np.median(self.times))
            mad = float(np.median(np.abs(np.asarray(self.times) - med)))
            if dt > med + self.k * max(mad, 1e-9):
                is_straggler = True
                self.flagged.append(self._step)
        self.times.append(dt)
        return is_straggler

    def observe(self, dt: float) -> bool:
        """Direct-observation variant (tests feed synthetic timings)."""
        self._t0 = time.perf_counter() - dt
        return self.stop()


class FailureInjector:
    """Raises RuntimeError at ``fail_at_step`` exactly once (test hook)."""

    def __init__(self, fail_at_step: int = -1):
        self.fail_at_step = fail_at_step
        self.fired = False

    def check(self, step: int) -> None:
        if step == self.fail_at_step and not self.fired:
            self.fired = True
            raise RuntimeError(f"injected failure at step {step}")


@dataclass
class Metrics:
    """Tiny append-only metrics log (CSV-serializable)."""

    rows: List[Dict[str, float]] = field(default_factory=list)

    def log(self, step: int, **kv: float) -> None:
        self.rows.append({"step": step, **{k: float(v) for k, v in kv.items()}})

    def to_csv(self) -> str:
        if not self.rows:
            return ""
        keys = list(self.rows[0].keys())
        lines = [",".join(keys)]
        for r in self.rows:
            lines.append(",".join(str(r.get(k, "")) for k in keys))
        return "\n".join(lines)
