"""Blocked max-plus matmul Pallas kernel.

The AIDG longest-path relaxation is a max-plus matmul (DESIGN.md §4):
``(A ⊗ B)_ij = max_k (A_ik + B_kj)``.  The kernel tiles exactly like an MXU
matmul — (8, 128)-aligned VMEM blocks, k-innermost grid accumulation — but
reduces with max/add on the VPU instead of mul/add on the MXU.

VMEM budget: the naive broadcast ``a[:, :, None] + b[None, :, :]`` would
materialize a (bm, bk, bn) cube; instead the kernel walks the k block in
``K_STEP``-deep slabs, keeping the working set at
``bm*bk + bk*bn + bm*bn + K_STEP*bm*bn`` floats.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["maxplus_matmul_kernel", "maxplus_matmul_pallas",
           "maxplus_matvec_pallas"]

NEG = -1e18
K_STEP = 8  # k-slab depth per VPU step inside a block


def maxplus_matmul_kernel(a_ref, b_ref, o_ref, *, bk: int):
    """One (bm, bn) output tile, accumulating max over the k grid axis."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, NEG)

    a = a_ref[...]            # (bm, bk)
    b = b_ref[...]            # (bk, bn)

    if bk % K_STEP == 0 and bk > K_STEP:
        def body(s, acc):
            # (bm, K_STEP, 1) + (1, K_STEP, bn) -> max over the slab axis
            a_slab = jax.lax.dynamic_slice_in_dim(a, s * K_STEP, K_STEP, axis=1)
            b_slab = jax.lax.dynamic_slice_in_dim(b, s * K_STEP, K_STEP, axis=0)
            cand = jnp.max(a_slab[:, :, None] + b_slab[None, :, :], axis=1)
            return jnp.maximum(acc, cand)

        acc = jax.lax.fori_loop(0, bk // K_STEP, body,
                                jnp.full(o_ref.shape, NEG, o_ref.dtype))
    else:  # tiny-k fallback: single broadcast slab
        acc = jnp.max(a[:, :, None] + b[None, :, :], axis=1)
    o_ref[...] = jnp.maximum(o_ref[...], acc)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def maxplus_matmul_pallas(a: jnp.ndarray, b: jnp.ndarray, *,
                          bm: int = 128, bk: int = 128, bn: int = 128,
                          interpret: bool = True) -> jnp.ndarray:
    """C = A ⊗ B for (M, K) ⊗ (K, N); shapes must divide the block sizes
    (ops.pad_maxplus handles ragged shapes)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (m, k, n, bm, bk, bn)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(maxplus_matmul_kernel, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a.astype(jnp.float32), b.astype(jnp.float32))


def maxplus_matvec_pallas(A: jnp.ndarray, v: jnp.ndarray, **kw) -> jnp.ndarray:
    """(A ⊗ v)_i = max_k (A_ik + v_k) for (M, K) ⊗ (K,) — the per-block
    propagation step of the AIDG blocked evaluator
    (``repro.core.aidg.maxplus.longest_path_blocked``) routed through the
    Pallas kernel as a single-column matmul."""
    return maxplus_matmul_pallas(A, v[:, None], **kw)[:, 0]
