"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic reference the kernels are property-tested
against (``tests/test_kernels.py`` sweeps shapes/dtypes and asserts
allclose in ``interpret=True`` mode).
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

__all__ = ["maxplus_matmul_ref", "gemm_ref", "flash_attention_ref",
           "selective_scan_ref"]

NEG = -1e18


def maxplus_matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(A ⊗ B)_ij = max_k (A_ik + B_kj) — max-plus semiring matmul."""
    return jnp.max(a[..., :, :, None] + b[..., None, :, :], axis=-2)


def gemm_ref(a: jnp.ndarray, b: jnp.ndarray, activation: int = 0,
             out_dtype=jnp.float32) -> jnp.ndarray:
    """C = act(A @ B) with f32 accumulation; activation 1 = ReLU (the Γ̈
    ``gemm`` instruction's optional activation, paper Listing 4)."""
    out = jnp.dot(a, b, preferred_element_type=jnp.float32)
    if activation == 1:
        out = jnp.maximum(out, 0.0)
    return out.astype(out_dtype)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True,
                        scale: Optional[float] = None) -> jnp.ndarray:
    """Masked multi-head attention, (B, H, S, D) layout, f32 softmax."""
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        qlen, klen = q.shape[-2], k.shape[-2]
        mask = jnp.tril(jnp.ones((qlen, klen), dtype=bool), klen - qlen)
        s = jnp.where(mask, s, NEG)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def selective_scan_ref(x, dt, b, c, a, d):
    """Naive per-step selective scan: the Mamba-1 recurrence oracle.

    x/dt: (B, S, D); b/c: (B, S, N); a: (D, N); d: (D,) -> (B, S, D)."""
    import jax

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        dA = jnp.exp(dt_t[:, :, None] * a[None])
        h = dA * h + (dt_t * x_t)[:, :, None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t) + d[None] * x_t
        return h, y

    B, S, D = x.shape
    N = b.shape[-1]
    h0 = jnp.zeros((B, D, N), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(b, 1, 0), jnp.moveaxis(c, 1, 0))
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1)
