"""Selective-scan (Mamba-1) Pallas kernel — beyond-paper addition for the
ssm/hybrid architectures (falcon-mamba, jamba).

    h_t = exp(dt_t ⊙ A) ⊙ h_{t-1} + (dt_t ⊙ x_t) ⊗ B_t
    y_t = h_t · C_t + D ⊙ x_t

The CUDA kernel the Mamba paper ships keeps h resident in shared memory
and streams (x, dt, B, C) through it; the TPU-native expression keeps the
(bd, N) state tile resident in VMEM across a sequential time loop, with
the channel dimension blocked over the grid — channels are independent, so
the grid parallelizes cleanly over cores while time stays a `fori_loop`
inside the kernel (HBM -> VMEM -> VREG, DESIGN.md §4).

Layout: x/dt (B, S, D); B/C (B, S, N); A (D, N); grid (B, D/bd).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["selective_scan_kernel", "selective_scan_pallas"]


def selective_scan_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, y_ref,
                          h_ref, *, seq_len: int):
    h_ref[...] = jnp.zeros_like(h_ref)
    A = a_ref[0]                       # (bd, N)
    D = d_ref[0]                       # (bd,)

    def step(t, _):
        x_t = x_ref[0, t]              # (bd,)
        dt_t = dt_ref[0, t]            # (bd,)
        b_t = b_ref[0, t]              # (N,)
        c_t = c_ref[0, t]              # (N,)
        dA = jnp.exp(dt_t[:, None] * A)                     # (bd, N)
        h = dA * h_ref[...] + (dt_t * x_t)[:, None] * b_t[None, :]
        h_ref[...] = h
        y_ref[0, t] = (h @ c_t) + D * x_t
        return 0

    jax.lax.fori_loop(0, seq_len, step, 0)


@functools.partial(jax.jit, static_argnames=("bd", "interpret"))
def selective_scan_pallas(x: jnp.ndarray, dt: jnp.ndarray, b: jnp.ndarray,
                          c: jnp.ndarray, a: jnp.ndarray, d: jnp.ndarray, *,
                          bd: int = 128, interpret: bool = True) -> jnp.ndarray:
    """x/dt: (B, S, D); b/c: (B, S, N); a: (D, N); d: (D,) -> y (B, S, D)."""
    B, S, Dm = x.shape
    N = b.shape[-1]
    bd = min(bd, Dm)
    assert Dm % bd == 0, (Dm, bd)
    grid = (B, Dm // bd)
    return pl.pallas_call(
        functools.partial(selective_scan_kernel, seq_len=S),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, S, bd), lambda i, j: (i, 0, j)),   # x
            pl.BlockSpec((1, S, bd), lambda i, j: (i, 0, j)),   # dt
            pl.BlockSpec((1, S, N), lambda i, j: (i, 0, 0)),    # B
            pl.BlockSpec((1, S, N), lambda i, j: (i, 0, 0)),    # C
            pl.BlockSpec((1, bd, N), lambda i, j: (0, j, 0)),   # A
            pl.BlockSpec((1, bd), lambda i, j: (0, j)),         # D
        ],
        out_specs=pl.BlockSpec((1, S, bd), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((B, S, Dm), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],      # resident h
        interpret=interpret,
    )(x, dt, b, c, a[None], d[None])
