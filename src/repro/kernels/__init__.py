"""Pallas TPU kernels for the framework's compute hot-spots.

* ``maxplus``         — blocked max-plus matmul (AIDG longest-path closure)
* ``systolic_gemm``   — MXU-tiled GeMM with fused activation (paper §4.2/§4.3
                        adapted to the TPU memory hierarchy)
* ``flash_attention`` — chunked online-softmax attention (prefill hot-spot)

Each kernel: ``<name>.py`` (pl.pallas_call + BlockSpec), validated in
``interpret=True`` mode against the pure-jnp oracles in ``ref.py``; public
entry points with padding/fallback logic live in ``ops.py``.
"""

from . import ops, ref
from .flash_attention import flash_attention_pallas
from .maxplus import maxplus_matmul_pallas
from .systolic_gemm import systolic_gemm_pallas

__all__ = ["ops", "ref", "flash_attention_pallas", "maxplus_matmul_pallas",
           "systolic_gemm_pallas"]
