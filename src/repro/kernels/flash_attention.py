"""Chunked online-softmax attention Pallas kernel (flash-attention style).

The compute hot-spot of every attention architecture in the model zoo:
prefill_32k would otherwise materialize a 32k x 32k score matrix per head.
The kernel streams KV blocks through VMEM with the classic running
(max, sum, acc) online-softmax state held in VMEM scratch across the
kv grid axis.

Causal masking is block-aware: KV blocks strictly above the diagonal are
skipped via the mask (the q >= k condition is evaluated per element only on
the diagonal blocks).  Sliding-window attention (h2o-danube) additionally
masks keys older than ``window`` positions.

Layout: (B*H, S, D) — batch and heads flattened into the leading grid axis,
S and D in the (8, 128)-aligned trailing dims.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_kernel", "flash_attention_pallas"]

NEG = -1e18


def flash_attention_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                           *, scale: float, causal: bool, window: int,
                           bq: int, bk: int, n_k: int):
    kv_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                      # (bq, d)
    k = k_ref[0]                      # (bk, d)
    v = v_ref[0]                      # (bk, d)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (bq, bk)

    q_pos = pl.program_id(1) * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bk), 0)
    k_pos = kv_idx * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), dtype=bool)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG)

    m_prev = m_ref[...]               # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)            # masked entries underflow to 0
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kv_idx == n_k - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)  # fully-masked rows -> 0 output
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "bk", "causal", "window",
                                             "scale", "interpret"))
def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                           bq: int = 128, bk: int = 128, causal: bool = True,
                           window: int = 0, scale: float | None = None,
                           interpret: bool = True) -> jnp.ndarray:
    """(BH, Sq, D) x (BH, Sk, D) x (BH, Sk, D) -> (BH, Sq, D).

    Block sizes must divide the sequence lengths (ops.flash_attention pads).
    """
    bh, sq, d = q.shape
    _, sk, _ = k.shape
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    bq = min(bq, sq)
    bk = min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, sk, bq, bk)
    grid = (bh, sq // bq, sk // bk)
    return pl.pallas_call(
        functools.partial(flash_attention_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bk=bk, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running denominator
            pltpu.VMEM((bq, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
