"""MXU-tiled GeMM Pallas kernel — the TPU-native adaptation of the paper's
systolic array (§4.2) and of Γ̈'s fused ``gemm`` instruction (§4.3).

Hardware adaptation (DESIGN.md §4): the paper's PE-grid dataflow (operands
skewed through a 2-D grid, output-stationary accumulators) *is* what the
MXU implements in silicon.  The TPU-idiomatic expression is therefore not a
PE-by-PE emulation but a blocked matmul whose BlockSpec tiling plays the
role of the load/store units: (bm, bk) × (bk, bn) VMEM tiles stream through
the MXU with a float32 accumulator tile held resident across the k grid
axis — exactly the output-stationary discipline of Fig. 4, one level up the
memory hierarchy (HBM -> VMEM -> MXU instead of DRAM -> load units -> PEs).

The optional fused ReLU on the final k step reproduces the Γ̈ ``gemm``
instruction's activation parameter (Listing 4).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["systolic_gemm_kernel", "systolic_gemm_pallas"]


def systolic_gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, activation: int,
                         n_k: int):
    """Output-stationary (bm, bn) tile: accumulate over the k grid axis in a
    float32 scratch accumulator, write (+ activation) on the last step."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _flush():
        out = acc_ref[...]
        if activation == 1:
            out = jnp.maximum(out, 0.0)
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "activation",
                                             "out_dtype", "interpret"))
def systolic_gemm_pallas(a: jnp.ndarray, b: jnp.ndarray, *,
                         bm: int = 128, bk: int = 128, bn: int = 128,
                         activation: int = 0, out_dtype=jnp.float32,
                         interpret: bool = True) -> jnp.ndarray:
    """C = act(A @ B); (M, K) @ (K, N), block sizes must divide the shapes
    (ops.systolic_gemm pads ragged inputs)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (m, k, n, bm, bk, bn)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(systolic_gemm_kernel, activation=activation,
                          n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        # float32 accumulator tile resident in VMEM across the k axis
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
