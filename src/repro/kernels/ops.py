"""Jit'd public wrappers around the Pallas kernels.

Handle ragged shapes by padding to block multiples (max-plus pads with
-inf, gemm with zeros, attention with masked keys), pick block sizes that
fit VMEM, and fall back to the pure-jnp reference for shapes where a kernel
launch cannot pay for itself (tiny operands).

``interpret=True`` is the default everywhere in this repo: the container is
CPU-only and Pallas TPU kernels execute through the interpreter for
correctness validation; on a real TPU backend pass ``interpret=False``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .flash_attention import flash_attention_pallas
from .maxplus import maxplus_matmul_pallas
from .selective_scan import selective_scan_pallas
from .systolic_gemm import systolic_gemm_pallas

__all__ = ["maxplus_matmul", "gemm", "flash_attention", "selective_scan"]

NEG = -1e18


def _pad_to(x: jnp.ndarray, mults, value) -> jnp.ndarray:
    pads = []
    needs = False
    for dim, m in zip(x.shape, mults):
        p = (-dim) % m
        pads.append((0, p))
        needs = needs or p > 0
    return jnp.pad(x, pads, constant_values=value) if needs else x


def maxplus_matmul(a: jnp.ndarray, b: jnp.ndarray, *, bm: int = 128,
                   bk: int = 128, bn: int = 128,
                   interpret: bool = True) -> jnp.ndarray:
    """(A ⊗ B) with -inf padding for ragged shapes."""
    m, k = a.shape
    _, n = b.shape
    ap = _pad_to(a, (bm, bk), NEG)
    bp = _pad_to(b, (bk, bn), NEG)
    out = maxplus_matmul_pallas(ap, bp, bm=bm, bk=bk, bn=bn,
                                interpret=interpret)
    return out[:m, :n]


def gemm(a: jnp.ndarray, b: jnp.ndarray, *, activation: int = 0,
         bm: int = 128, bk: int = 128, bn: int = 128,
         out_dtype=jnp.float32, interpret: bool = True) -> jnp.ndarray:
    """act(A @ B) with zero padding for ragged shapes."""
    m, k = a.shape
    _, n = b.shape
    ap = _pad_to(a, (bm, bk), 0)
    bp = _pad_to(b, (bk, bn), 0)
    out = systolic_gemm_pallas(ap, bp, bm=bm, bk=bk, bn=bn,
                               activation=activation, out_dtype=out_dtype,
                               interpret=interpret)
    return out[:m, :n]


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128,
                    scale: Optional[float] = None,
                    interpret: bool = True) -> jnp.ndarray:
    """Attention over (B, H, S, D) or (BH, S, D) inputs.

    Padded keys are masked through the causal/positional mask: key padding
    appends positions > every real query position, which the causal mask
    excludes; for non-causal inputs padded keys are masked explicitly by
    passing window=0 and relying on -inf score padding via key padding of
    q-side only — non-causal ragged ``sk`` therefore falls back to ref.
    """
    squeeze = q.ndim == 4
    if squeeze:
        b, h, s, d = q.shape
        q = q.reshape(b * h, s, d)
        k = k.reshape(b * h, k.shape[2], d)
        v = v.reshape(b * h, v.shape[2], d)
    bh, sq, d = q.shape
    _, sk, _ = k.shape
    pq, pk = (-sq) % bq, (-sk) % bk
    if pk and not causal:
        out = ref.flash_attention_ref(q, k, v, causal=False, scale=scale)
    else:
        qp = _pad_to(q, (1, bq, 1), 0)
        kp = _pad_to(k, (1, bk, 1), 0)
        vp = _pad_to(v, (1, bk, 1), 0)
        out = flash_attention_pallas(qp, kp, vp, bq=bq, bk=bk, causal=causal,
                                     window=window, scale=scale,
                                     interpret=interpret)[:, :sq]
    if squeeze:
        out = out.reshape(b, h, sq, d)
    return out


def selective_scan(x, dt, b, c, a, d, *, bd: int = 128,
                   interpret: bool = True):
    """Mamba-1 selective scan; pads the channel dim to the block size."""
    B, S, D = x.shape
    p = (-D) % bd if D > bd else 0
    if D < bd:
        bd = D
    if p:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, p)))
        dt = jnp.pad(dt, ((0, 0), (0, 0), (0, p)))
        a = jnp.pad(a, ((0, p), (0, 0)))
        d = jnp.pad(d, ((0, p),))
    out = selective_scan_pallas(x, dt, b, c, a, d, bd=bd,
                                interpret=interpret)
    return out[:, :, :D]
