"""Fault tolerance of the serving stack (``repro.serve``): the fault
plan grammar replays deterministically, transient dispatch failures are
retried and absorbed, the circuit breaker walks closed -> open ->
half-open -> closed exactly as specified, queries degrade onto the
surrogate tier with honestly widened bounds (and recover to exact packed
answers), deadlines and timeouts never leak enqueued work, a killed
worker thread respawns, and the RPC front-end sheds load / frames every
failure as a structured error.

The determinism contract extends under injected faults: the SAME fault
plan on a fresh service produces the SAME per-query outcomes (answer or
structured error) threaded as in sequential replay, because plans are
keyed by packed-dispatch attempt index and the breaker's cooldown is
counted in rejected dispatch opportunities — never wall-clock time.
"""

import threading
import time
from concurrent.futures import TimeoutError as FutureTimeout

import numpy as np
import pytest

from repro.core.aidg.explorer import (Explorer, default_scenarios,
                                      resolve_cells)
from repro.serve import (DEGRADED_WIDEN, Answer, CircuitBreaker,
                         DeadlineExceeded, DSEService, FaultAction,
                         FaultPlan, InvalidQuery, MicroBatcher,
                         OracleUnavailable, Overloaded, PoisonedDispatch,
                         Query, RetryPolicy, ServeClient, ServeError,
                         ServeFrontend, TransientDispatchError, WorkerKill,
                         error_from_payload, error_payload)
from repro.serve.faults import ENV_FAULT_PLAN
from repro.surrogate import SurrogateConfig, train_surrogate

# reduced budget: these tests exercise failure mechanics, not accuracy
CFG = SurrogateConfig(n_samples=48, steps=250)


@pytest.fixture(scope="module")
def ex2():
    """oma/gemm + systolic/gemm — the cheap 2-cell corner, enough for
    subset queries and surrogate coverage to be non-trivial."""
    return Explorer(scenarios=default_scenarios()[:2])


@pytest.fixture(scope="module")
def bundle(ex2):
    return train_surrogate(ex2, CFG)


def make_svc(ex2, **kw):
    kw.setdefault("pool", 8)
    kw.setdefault("seed", 1)
    kw.setdefault("max_batch", 4)
    kw.setdefault("window_s", 0.005)
    kw.setdefault("retry", RetryPolicy(max_attempts=2, base_s=0.0))
    return DSEService(ex2, **kw)


# -- fault plan grammar -------------------------------------------------------

def test_fault_plan_parse_and_lookup():
    plan = FaultPlan.parse(
        "packed[2:5]=error; packed[6]=latency:0.25; packed[8:]=poison")
    assert plan.action("packed", 1) == FaultAction()
    assert plan.action("packed", 2) == FaultAction("error")
    assert plan.action("packed", 4) == FaultAction("error")
    assert plan.action("packed", 5) == FaultAction()
    assert plan.action("packed", 6) == FaultAction("ok", 0.25)
    assert plan.action("packed", 7) == FaultAction()
    assert plan.action("packed", 8) == FaultAction("poison")
    assert plan.action("packed", 10 ** 6) == FaultAction("poison")
    # last clause wins on overlap
    refined = FaultPlan.parse("packed[0:10]=error;packed[3]=kill")
    assert refined.action("packed", 3) == FaultAction("kill")
    assert refined.action("packed", 4) == FaultAction("error")


def test_fault_plan_roundtrip_and_window():
    spec = "packed[0:2]=error;packed[4]=latency:0.01;packed[5]=kill"
    plan = FaultPlan.parse(spec)
    assert FaultPlan.parse(plan.to_spec()) == plan
    assert plan.max_faulty_attempt() == 6      # one past the last faulty
    assert FaultPlan.parse("packed[3:]=error").max_faulty_attempt() == -1
    assert FaultPlan.parse("").max_faulty_attempt() == 0


@pytest.mark.parametrize("bad", [
    "packed=error",                  # no selector
    "packed[]=error",                # empty selector... lo defaults 0? no: [] -> lo='' colon='' -> lo=0 hi=1; actually valid — see below
    "gemm[0]=error",                 # unknown site
    "packed[0]=explode",             # unknown action
    "packed[0]=error:7",             # error takes no parameter
    "packed[5:2]=error",             # empty range
    "packed[0] error",               # malformed clause
])
def test_fault_plan_rejects_malformed(bad):
    if bad == "packed[]=error":
        # `[]` is the degenerate-but-legal "attempt 0" selector
        assert FaultPlan.parse(bad).action("packed", 0) == FaultAction("error")
        return
    with pytest.raises(ValueError):
        FaultPlan.parse(bad)


def test_fault_plan_env_hook(ex2, monkeypatch):
    monkeypatch.setenv(ENV_FAULT_PLAN, "packed[0]=error")
    svc = make_svc(ex2)
    try:
        assert svc.fault_plan is not None
        assert svc.fault_plan.to_spec() == "packed[0]=error"
    finally:
        svc.close()
    monkeypatch.delenv(ENV_FAULT_PLAN)
    svc = make_svc(ex2)
    try:
        assert svc.fault_plan is None
        assert svc.stats()["fault_plan"] is None
    finally:
        svc.close()


# -- retry policy + circuit breaker (unit) -----------------------------------

def test_retry_delays_deterministic_and_bounded():
    a = list(RetryPolicy(max_attempts=4, base_s=0.01, factor=2.0,
                         jitter=0.5, seed=7).delays())
    b = list(RetryPolicy(max_attempts=4, base_s=0.01, factor=2.0,
                         jitter=0.5, seed=7).delays())
    assert a == b                       # seeded jitter: pure replay
    assert a[0] == 0.0                  # first attempt never sleeps
    for i, d in enumerate(a[1:], 1):
        lo = 0.01 * 2.0 ** (i - 1)
        assert lo <= d <= lo * 1.5


def test_retry_call_budget_and_passthrough():
    calls = []

    def flaky():
        calls.append(1)
        raise TransientDispatchError("nope")

    r = RetryPolicy(max_attempts=3, base_s=0.0)
    with pytest.raises(TransientDispatchError):
        r.call(flaky, retry_on=(TransientDispatchError,))
    assert len(calls) == 3
    # non-matching exceptions propagate immediately, unretried
    calls.clear()

    def wrong():
        calls.append(1)
        raise ValueError("not transient")

    with pytest.raises(ValueError):
        r.call(wrong, retry_on=(TransientDispatchError,))
    assert len(calls) == 1


def test_breaker_state_machine():
    br = CircuitBreaker(open_after=2, probe_after=2)
    assert br.state == "closed" and br.allow()
    br.record_failure()
    assert br.state == "closed"         # streak 1 < open_after
    br.record_success()
    br.record_failure()
    assert br.state == "closed"         # success reset the streak
    br.record_failure()
    br.record_failure()
    assert br.state == "open" and br.opens == 1
    # open: probe_after rejections, then the next allow() IS the probe
    assert not br.allow() and not br.allow()
    assert br.allow() and br.state == "half-open"
    # concurrent dispatches shed while the probe is in flight
    assert not br.allow()
    br.record_failure()                 # failed probe re-opens
    assert br.state == "open" and br.opens == 2
    assert not br.allow() and not br.allow()
    assert br.allow()
    br.record_success()                 # successful probe closes
    assert br.state == "closed"
    assert br.transitions == [("closed", "open"), ("open", "half-open"),
                              ("half-open", "open"), ("open", "half-open"),
                              ("half-open", "closed")]
    snap = br.snapshot()
    assert snap["state"] == "closed" and snap["opens"] == 2


# -- service-level fault handling --------------------------------------------

def test_transient_faults_absorbed_by_retry(ex2):
    with make_svc(ex2, retry=RetryPolicy(max_attempts=3, base_s=0.0),
                  fault_plan="packed[0:2]=error") as svc:
        a = svc.query(workload="gemm")
        assert a.tier == "packed" and a.err_bound == 0.0
        st = svc.stats()
        assert st["retries"] == 2
        assert st["breaker"]["state"] == "closed"
        assert svc.faults.attempts() == 3


def test_poisoned_dispatch_retried_never_served(ex2):
    # the "oracle returns garbage" path: attempt 0 yields all-NaN output,
    # output validation converts it to a retryable PoisonedDispatch
    with make_svc(ex2, fault_plan="packed[0]=poison") as svc:
        a = svc.query(workload="gemm")
        assert a.tier == "packed"
        assert all(np.isfinite(c) for d in a.designs for c in d.cycles)
        assert svc.stats()["retries"] == 1
    assert issubclass(PoisonedDispatch, TransientDispatchError)


def test_exhausted_retries_fail_fast_without_surrogate(ex2):
    with make_svc(ex2, retry=RetryPolicy(max_attempts=1, base_s=0.0),
                  breaker=CircuitBreaker(open_after=1, probe_after=2),
                  fault_plan="packed[0]=error") as svc:
        with pytest.raises(OracleUnavailable) as ei:
            svc.query(workload="gemm")
        assert ei.value.code == 503 and ei.value.retryable
        assert svc.breaker.state == "open"
        assert svc.stats()["tiers"]["failed"] == 1
        # failed outcomes are never cached: the same query is a fresh miss
        assert svc.cache_stats["misses"] == 1
        with pytest.raises(OracleUnavailable):
            svc.query(workload="gemm")
        assert svc.cache_stats["misses"] == 2


def test_degraded_answers_recover_and_stay_within_bounds(ex2, bundle):
    q = Query.make(workload="gemm", top_k=4)
    # surrogate_max_err=-1 forces normal routing to the packed tier, so
    # the surrogate is ONLY reachable through degradation
    svc = make_svc(ex2, surrogate=bundle, surrogate_max_err=-1.0,
                   retry=RetryPolicy(max_attempts=1, base_s=0.0),
                   breaker=CircuitBreaker(open_after=1, probe_after=1),
                   fault_plan="packed[0]=error",
                   degraded_max_err=np.inf)
    try:
        # dispatch 1 fails -> breaker opens -> this very query degrades
        a = svc.query(q)
        assert a.tier == "surrogate-degraded" and not a.cached
        cols = np.asarray(resolve_cells(ex2.compiled, workload="gemm"))
        stated = DEGRADED_WIDEN * float(bundle.err_bound[cols].max())
        assert a.err_bound == pytest.approx(stated)
        assert svc.breaker.state == "open"

        # degraded answers are never cached: repeat is a fresh miss
        # (still degraded — the breaker needs one more rejected
        # opportunity before it half-opens)
        b = svc.query(q)
        assert b.tier == "surrogate-degraded" and not b.cached
        assert svc.cache_stats["hits"] == 0

        # third dispatch opportunity is the half-open probe; the plan is
        # clean from attempt 1 on, so it succeeds and the breaker closes
        c = svc.query(q)
        assert c.tier == "packed" and c.err_bound == 0.0
        assert svc.breaker.state == "closed"
        assert svc.breaker.transitions == [
            ("closed", "open"), ("open", "half-open"),
            ("half-open", "closed")]
        # recovery restored the exact answer to the cache
        d = svc.query(q)
        assert d.cached and d.tier == "packed"

        # honesty of the stated widened bound: every degraded design's
        # relative latency matches the packed oracle recomputed offline
        # within the bound stamped on the answer
        exact_c = c  # packed answer over the identical candidate block
        by_theta = {dd.theta: dd for dd in exact_c.designs}
        cycles, _ = ex2.evaluate_full(svc.pool)
        rel = (cycles[:, cols] / ex2.baselines[None, cols]).mean(axis=1)
        pool_lat = {tuple(float(v) for v in svc.pool[i]): float(rel[i])
                    for i in range(svc.pool.shape[0])}
        for dd in a.designs:
            exact = (by_theta[dd.theta].latency
                     if dd.theta in by_theta else pool_lat[dd.theta])
            assert abs(dd.latency - exact) / exact <= a.err_bound, (
                dd.theta, dd.latency, exact, a.err_bound)
    finally:
        svc.close()


def test_degradation_ladder_covers_only_calibrated_cells(ex2, bundle):
    # degraded_max_err below every calibrated bound: nothing is covered,
    # so an open breaker means fail-fast for ALL queries
    svc = make_svc(ex2, surrogate=bundle, surrogate_max_err=-1.0,
                   retry=RetryPolicy(max_attempts=1, base_s=0.0),
                   breaker=CircuitBreaker(open_after=1, probe_after=9),
                   fault_plan="packed[0]=error", degraded_max_err=0.0)
    try:
        with pytest.raises(OracleUnavailable) as ei:
            svc.query(workload="gemm")
        assert ei.value.detail.get("breaker") in ("closed", "open")
        with pytest.raises(OracleUnavailable):
            svc.query(workload="gemm", archs=["oma"])
        assert svc.stats()["tiers"]["failed"] == 2
        assert svc.stats()["tiers"]["surrogate-degraded"] == 0
    finally:
        svc.close()


def test_threaded_equals_replay_under_faults(ex2):
    """The determinism contract under chaos: the same fault plan on a
    fresh service yields the same per-query outcome (answer or error
    kind) threaded as in sequential replay, and the breaker walks the
    same transition path."""
    stream = [Query.make(workload="gemm", top_k=k) for k in range(1, 13)]
    mk = lambda: make_svc(  # noqa: E731 — two identical fresh services
        ex2, max_batch=4,
        retry=RetryPolicy(max_attempts=2, base_s=0.0),
        breaker=CircuitBreaker(open_after=1, probe_after=1),
        fault_plan="packed[0:2]=error")

    with mk() as replay_svc:
        replayed = replay_svc.query_many(stream, return_exceptions=True)
        replay_trans = list(replay_svc.breaker.transitions)
        replay_attempts = replay_svc.faults.attempts()

    with mk() as svc:
        with svc.batcher.hold():
            futs = [svc.submit(q) for q in stream]
        svc.batcher.drain()
        threaded = []
        for f in futs:
            threaded.append(f.exception() or f.result())
        threaded_trans = list(svc.breaker.transitions)
        threaded_attempts = svc.faults.attempts()

    # window 1: retries exhausted -> breaker opens -> OracleUnavailable;
    # window 2: rejected (the open->half-open cooldown opportunity);
    # window 3: the half-open probe succeeds -> packed answers
    assert [type(o).__name__ for o in replayed] == (
        ["OracleUnavailable"] * 8 + ["Answer"] * 4)
    assert len(threaded) == len(replayed) == len(stream)
    for got, want in zip(threaded, replayed):
        if isinstance(want, Answer):
            assert got == want and got.tier == want.tier
        else:
            assert type(got) is type(want)
    assert threaded_trans == replay_trans == [
        ("closed", "open"), ("open", "half-open"), ("half-open", "closed")]
    assert threaded_attempts == replay_attempts == 3


@pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnhandledThreadExceptionWarning")
def test_worker_kill_fails_batch_and_respawns(ex2):
    with make_svc(ex2, fault_plan="packed[0]=kill") as svc:
        fut = svc.submit(workload="gemm")
        with pytest.raises(WorkerKill):
            fut.result(timeout=30)
        # the forwarded exception names its window
        assert len(fut.exception().batch_items) == 1
        # the worker died loudly (BaseException is not swallowed) …
        deadline = time.monotonic() + 5.0
        while svc.batcher._worker.is_alive():
            assert time.monotonic() < deadline, "worker survived WorkerKill"
            time.sleep(0.005)
        # … and the next submission respawns it; attempt 1 is clean
        a = svc.query(workload="gemm", timeout=30)
        assert a.tier == "packed"
        assert svc.stats()["worker_restarts"] == 1


def test_latency_spike_injection(ex2):
    with make_svc(ex2, fault_plan="packed[0]=latency:0.2") as svc:
        t0 = time.perf_counter()
        a = svc.query(workload="gemm")
        assert a.tier == "packed"
        assert time.perf_counter() - t0 >= 0.2
        assert svc.stats()["retries"] == 0      # a spike is not a failure


# -- deadlines and timeout accounting ----------------------------------------

def test_expired_deadline_fails_before_evaluation(ex2):
    with make_svc(ex2) as svc:
        with svc.batcher.hold():
            fut = svc.submit(Query.make(workload="gemm"), deadline_s=0.03)
            time.sleep(0.1)             # expire while the window is held
        with pytest.raises(DeadlineExceeded) as ei:
            fut.result(timeout=30)
        assert ei.value.code == 504
        assert isinstance(ei.value, FutureTimeout)
        st = svc.stats()
        assert st["deadline_misses"] == 1
        assert st["device_dispatches"] == 0      # never reached an oracle


def test_query_timeout_cancels_and_accounts(ex2):
    with make_svc(ex2) as svc:
        with svc.batcher.hold():
            with pytest.raises(DeadlineExceeded):
                svc.query(workload="gemm", timeout=0.05)
            # a second client timing out on the SAME held window
            with pytest.raises(DeadlineExceeded):
                svc.query(workload="gemm", deadline_s=0.05)
        svc.batcher.drain()
        st = svc.stats()
        assert st["timeouts"] == 2
        # both futures were cancelled while queued: reaped pre-dispatch,
        # so the abandoned window never touched the device
        assert st["cancelled"] == 2
        assert st["windows"] == 0 and st["device_dispatches"] == 0
        # the service still serves normally afterwards
        assert svc.query(workload="gemm").tier == "packed"


def test_deadline_closes_window_early(ex2):
    # window_s is huge; the submission's deadline must close it early
    with make_svc(ex2, window_s=30.0) as svc:
        t0 = time.monotonic()
        a = svc.query(workload="gemm", deadline_s=0.25, timeout=10.0)
        assert a.tier == "packed"
        assert time.monotonic() - t0 < 5.0


# -- micro-batcher failure contract ------------------------------------------

def test_batcher_per_item_exception_results():
    def dispatch(items):
        return [ValueError(f"bad {x}") if x % 2 else x * 10
                for x in items]

    with MicroBatcher(dispatch, max_batch=8, window_s=0.001) as b:
        with b.hold():
            futs = [b.submit(x) for x in range(4)]
        b.drain()
    assert futs[0].result() == 0 and futs[2].result() == 20
    with pytest.raises(ValueError):
        futs[1].result()
    with pytest.raises(ValueError):
        futs[3].result()
    # one window dispatched them all; per-item failure is not batch failure
    assert b.dispatch_log == [[0, 1, 2, 3]]


@pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnhandledThreadExceptionWarning")
def test_batcher_reraises_base_exception_and_respawns():
    boom = threading.Event()

    def dispatch(items):
        if not boom.is_set():
            boom.set()
            raise WorkerKill("die")
        return list(items)

    b = MicroBatcher(dispatch, max_batch=2, window_s=0.001)
    try:
        f1 = b.submit("a")
        with pytest.raises(WorkerKill) as ei:
            f1.result(timeout=10)
        assert ei.value.batch_items == ("a",)
        f2 = b.submit("b")              # respawns the dead worker
        assert f2.result(timeout=10) == "b"
        assert b.worker_restarts == 1
    finally:
        b.close()


def test_batcher_drain_timeout():
    release = threading.Event()

    def dispatch(items):
        release.wait(10)
        return list(items)

    b = MicroBatcher(dispatch, max_batch=1, window_s=0.0)
    try:
        fut = b.submit(1)
        with pytest.raises(TimeoutError):
            b.drain(timeout=0.05)
        release.set()
        assert fut.result(timeout=10) == 1
        b.drain(timeout=10)
    finally:
        b.close()


# -- structured errors on the wire -------------------------------------------

def test_error_payload_roundtrip():
    for err in (InvalidQuery("bad knob", knob="nope"),
                Overloaded("full", max_inflight=4),
                OracleUnavailable("down", breaker="open"),
                DeadlineExceeded("late", timeout_s=0.1)):
        p = error_payload(err)
        back = error_from_payload(p)
        assert type(back) is type(err)
        assert back.kind == err.kind and back.code == err.code
        assert back.retryable == err.retryable
        assert back.detail == err.detail and str(back) == str(err)
    # non-ServeError exceptions still produce a well-formed frame
    p = error_payload(RuntimeError("boom"))
    assert p["kind"] == "serve-error" and not p["retryable"]
    assert isinstance(error_from_payload(p), ServeError)
    # unknown kinds downgrade to the base class, never crash the client
    assert isinstance(error_from_payload({"kind": "from-the-future"}),
                      ServeError)


def test_wire_roundtrip_query_answer(ex2):
    q = Query.make(workload="gemm", archs=["systolic", "oma"],
                   overrides={"matrix": 2.0}, top_k=3)
    assert Query.from_payload(q.to_payload()) == q
    with make_svc(ex2) as svc:
        a = svc.query(q)
    back = Answer.from_payload(a.to_payload())
    assert back == a
    assert back.tier == a.tier and back.err_bound == a.err_bound
    assert back.cached == a.cached


# -- RPC front-end ------------------------------------------------------------

def test_frontend_roundtrip_health_stats(ex2):
    with make_svc(ex2) as svc:
        direct = svc.query(workload="gemm")
        with ServeFrontend(svc, max_inflight=4) as fe:
            with ServeClient(fe.address) as cli:
                a = cli.query(workload="gemm")
                assert a == direct and a.cached     # same cache, same answer
                h = cli.health()
                assert h["ready"] and h["breaker"] == "closed"
                assert h["fallback_rate"] == 1.0    # no surrogate armed
                assert h["shed"] == 0 and h["max_inflight"] == 4
                st = cli.stats()
                assert st["cache"]["hits"] == 1
                assert st["breaker"]["state"] == "closed"
            assert fe.accepted == 1 and fe.rpc_errors == 0


def test_frontend_rejects_invalid_queries(ex2):
    with make_svc(ex2) as svc, ServeFrontend(svc) as fe, \
            ServeClient(fe.address) as cli:
        with pytest.raises(InvalidQuery) as ei:
            cli.query(workload="gemm", overrides={"no_such_knob": 1.0})
        assert ei.value.code == 400 and not ei.value.retryable
        with pytest.raises(InvalidQuery):
            cli.query(workload="gemm", overrides={"matrix": 1e9})
        # an unknown op is an invalid request, not a dropped connection
        assert not cli._call({"op": "selfdestruct"})["ok"]
        # the connection survives all three errors
        assert cli.query(workload="gemm").tier == "packed"
        assert fe.rpc_errors == 3


def test_frontend_sheds_load_when_full(ex2):
    with make_svc(ex2) as svc, ServeFrontend(svc, max_inflight=1) as fe:
        with svc.batcher.hold():        # first query parks in the window
            got = {}

            def slow():
                with ServeClient(fe.address) as c:
                    got["a"] = c.query(workload="gemm")

            t = threading.Thread(target=slow)
            t.start()
            with ServeClient(fe.address) as cli:
                deadline = time.monotonic() + 10.0
                while cli.health()["inflight"] < 1:
                    assert time.monotonic() < deadline
                    time.sleep(0.005)
                with pytest.raises(Overloaded) as ei:
                    cli.query(workload="gemm")
                assert ei.value.code == 429 and ei.value.retryable
                assert ei.value.detail["max_inflight"] == 1
        t.join(timeout=30)
        assert got["a"].tier == "packed"    # the admitted query completed
        assert fe.shed == 1
        with ServeClient(fe.address) as cli:
            assert cli.health()["shed"] == 1


def test_frontend_propagates_deadline(ex2):
    with make_svc(ex2) as svc, ServeFrontend(svc) as fe, \
            ServeClient(fe.address) as cli:
        with svc.batcher.hold():
            with pytest.raises(DeadlineExceeded) as ei:
                cli.query(workload="gemm", deadline_ms=60)
            assert ei.value.code == 504 and ei.value.retryable
        svc.batcher.drain()
        assert svc.stats()["timeouts"] == 1
        # a generous deadline sails through
        a = cli.query(workload="gemm", deadline_ms=60_000)
        assert a.tier == "packed"


def test_frontend_surfaces_degraded_service(ex2, bundle):
    svc = make_svc(ex2, surrogate=bundle, surrogate_max_err=-1.0,
                   retry=RetryPolicy(max_attempts=1, base_s=0.0),
                   breaker=CircuitBreaker(open_after=1, probe_after=99),
                   fault_plan="packed[0:]=error", degraded_max_err=np.inf)
    try:
        with ServeFrontend(svc) as fe, ServeClient(fe.address) as cli:
            a = cli.query(workload="gemm")
            assert a.tier == "surrogate-degraded"
            assert a.err_bound > 0.0
            h = cli.health()
            assert h["breaker"] == "open" and h["ready"]
    finally:
        svc.close()
