"""Batched multi-architecture DSE engine (repro.core.aidg.explorer):

(a) the batched sweep at θ = 1 reproduces the cycle-accurate event
    simulator per (arch, workload) — exactly on the exact cells,
(b) the Pareto frontier is non-dominated and deterministic,
(c) the AIDG cache returns results identical to cold builds,
plus candidate generators, projection, chunking, and refinement.
"""

import numpy as np
import pytest

from repro.core.aidg import fixed_point_batch, fixed_point_jax, sweep
from repro.core.aidg.explorer import (DEFAULT_SPACE, Explorer,
                                      clear_scenario_cache, compile_scenario,
                                      default_scenarios, grid_candidates,
                                      pareto_front, random_candidates)

SCENARIOS = default_scenarios()
IDS = [s.name for s in SCENARIOS]

# Golden θ = 1 wavefront cycles per default cell, pinned as literals so an
# evaluator refactor cannot silently drift the baseline while staying inside
# each cell's sim_tol band.  Update ONLY when a change is *supposed* to move
# the estimate — and re-justify it against the event simulator (the second
# member of test_theta_one_golden_regression re-checks golden vs oracle).
GOLDEN_THETA1_CYCLES = {
    "oma/gemm": 3832.0,
    "systolic/gemm": 1187.0,
    "gamma/gemm": 2954.0,
    "gamma/attention": 980.0,
    "gamma/scan": 2753.0,
    "eyeriss/conv": 91.0,
    "plasticine/reduce": 91.0,
    "tpu_v5e/gemm": 3881.0,
    "tpu_v5e/attention": 225.0,
    "tpu_v5e/scan": 613.0,
}


@pytest.fixture(scope="module")
def explorer():
    return Explorer()


# ---------------------------------------------------------------------------
# (a) θ = 1 vs the event simulator, cell by cell
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scenario", SCENARIOS, ids=IDS)
def test_sweep_theta_one_matches_event_sim(scenario, explorer):
    cs = next(c for c in explorer.compiled if c.scenario.key == scenario.key)
    # Explorer.baselines IS the compiled sweep evaluated at θ = 1
    est = float(explorer.baselines[explorer.compiled.index(cs)])
    sim = cs.simulate()
    if scenario.sim_tol == 0.0:
        assert round(est) == sim, (cs.name, est, sim)
    else:
        assert abs(est - sim) / sim <= scenario.sim_tol, (cs.name, est, sim)


@pytest.mark.parametrize("scenario", SCENARIOS, ids=IDS)
def test_theta_one_golden_regression(scenario, explorer):
    """Golden regression: the wavefront θ = 1 estimate is pinned to a
    literal per cell.  The sim-agreement test above has a tolerance band on
    inexact cells, so an evaluator refactor could drift inside it unnoticed
    — this pin turns any drift into a loud, reviewed diff.  The golden
    value itself must stay within the cell's sim_tol of the oracle, so the
    pin can't ossify a wrong number either."""
    assert scenario.name in GOLDEN_THETA1_CYCLES, (
        f"new scenario {scenario.name}: add its θ=1 wavefront cycles to "
        f"GOLDEN_THETA1_CYCLES")
    golden = GOLDEN_THETA1_CYCLES[scenario.name]
    cs = next(c for c in explorer.compiled if c.scenario.key == scenario.key)
    est = float(explorer.baselines[explorer.compiled.index(cs)])
    assert est == pytest.approx(golden, abs=0.5), (cs.name, est, golden)
    sim = cs.simulate()
    tol = max(scenario.sim_tol, 1e-9)
    assert abs(golden - sim) / sim <= tol, (cs.name, golden, sim)


def test_matrix_has_exact_cell_and_required_extent():
    """The acceptance floor: >= 4 architectures, >= 3 workload kinds, and
    at least one (arch, workload) cell whose AIDG is cycle-exact."""
    archs = {s.arch for s in SCENARIOS}
    workloads = {s.workload for s in SCENARIOS}
    assert len(archs) >= 4 and len(workloads) >= 3
    assert any(s.sim_tol == 0.0 for s in SCENARIOS)


# ---------------------------------------------------------------------------
# (b) Pareto frontier: non-dominated, deterministic
# ---------------------------------------------------------------------------


def _dominates(a, b):
    return np.all(a <= b) and np.any(a < b)


def test_pareto_front_is_nondominated(explorer):
    cand = random_candidates(explorer.space, 64, seed=3)
    res = explorer.explore(cand)
    objs = np.stack([res.latency, res.energy, res.cost], axis=1)
    front = set(int(i) for i in res.pareto)
    assert front, "empty frontier"
    for i in front:
        for j in range(len(objs)):
            if j != i:
                assert not _dominates(objs[j], objs[i]), (j, i)
    # everything off the frontier is dominated by something on it
    for j in range(len(objs)):
        if j not in front:
            assert any(_dominates(objs[i], objs[j]) or
                       np.array_equal(objs[i], objs[j]) for i in front), j


def test_pareto_front_deterministic_and_order_invariant():
    rng = np.random.default_rng(0)
    objs = rng.uniform(0, 1, (200, 2))
    objs[17] = objs[3]  # exact duplicate: first occurrence wins
    f1 = pareto_front(objs)
    f2 = pareto_front(objs)
    assert np.array_equal(f1, f2)
    # sorted by first objective
    assert np.all(np.diff(objs[f1, 0]) >= 0)
    # permuting the rows keeps the same set of non-dominated POINTS
    perm = rng.permutation(len(objs))
    fp = pareto_front(objs[perm])
    pts = lambda idx, o: sorted(map(tuple, np.round(o[idx], 12)))
    assert pts(f1, objs) == pts(fp, objs[perm])
    assert 17 not in set(f1.tolist())


def test_pareto_front_ignores_nonfinite_rows():
    """Regression: a candidate whose sweep diverges (NaN/inf objectives)
    used to corrupt the lexsort-based frontier silently — an inf-latency
    row could enter the frontier purely by having the smallest cost, and a
    NaN row breaks the sort's ordering contract.  Non-finite rows must be
    dropped with a warning and never appear in (or displace) the result."""
    clean = np.asarray([[1.0, 3.0], [2.0, 2.0], [3.0, 1.0], [2.5, 2.5]])
    base = pareto_front(clean)
    dirty = np.concatenate([clean, [[np.nan, 0.1], [np.inf, 0.05],
                                    [0.01, np.nan], [-np.inf, -np.inf]]])
    with pytest.warns(RuntimeWarning, match="non-finite"):
        front = pareto_front(dirty)
    # identical frontier, by original-row index
    assert np.array_equal(front, base)
    assert not (set(front.tolist()) & {4, 5, 6, 7})
    # all-non-finite input yields an empty frontier, not a crash
    with pytest.warns(RuntimeWarning, match="non-finite"):
        assert pareto_front(dirty[4:]).size == 0
    # finite input stays warning-free
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error")
        assert np.array_equal(pareto_front(clean), base)


def test_baseline_candidate_has_unit_latency(explorer):
    """Normalization is self-consistent: the θ = 1 candidate scores exactly
    latency 1.0 because Explorer.baselines comes from the same compiled
    sweep evaluator."""
    res = explorer.explore(np.ones((1, explorer.space.n), np.float32))
    assert res.latency[0] == pytest.approx(1.0, abs=1e-5)
    # same self-consistency for the energy objective: the baselines come
    # from the same evaluate_full the sweep uses
    assert res.energy[0] == pytest.approx(1.0, abs=1e-5)


def test_explore_is_deterministic(explorer):
    cand = random_candidates(explorer.space, 32, seed=7)
    r1 = explorer.explore(cand)
    r2 = explorer.explore(cand)
    assert np.array_equal(r1.cycles, r2.cycles)
    assert np.array_equal(r1.pareto, r2.pareto)


# ---------------------------------------------------------------------------
# (c) AIDG cache ≡ cold build
# ---------------------------------------------------------------------------


def test_scenario_cache_identical_to_cold_build():
    sc = next(s for s in SCENARIOS if s.name == "gamma/attention")
    cached1 = compile_scenario(sc, use_cache=True)
    cached2 = compile_scenario(sc, use_cache=True)
    assert cached1 is cached2  # the cache actually caches
    cold = compile_scenario(sc, use_cache=False)
    assert cold is not cached1
    for attr in ("work", "base", "preds", "pred_extra", "fu_lat", "mem_lat"):
        assert np.array_equal(getattr(cold.aidg, attr),
                              getattr(cached1.aidg, attr)), attr
    assert cold.baseline == cached1.baseline
    to = np.full((4, cold.problem.n_op), 0.5, np.float32)
    ts = np.full((4, cold.problem.n_st), 2.0, np.float32)
    assert np.array_equal(sweep(cold.problem, to, ts),
                          sweep(cached1.problem, to, ts))


def test_cache_key_distinguishes_builders():
    """Two scenarios sharing (arch, workload, params) but built by
    different callables must not alias in the cache."""
    from repro.core.aidg.explorer import Scenario
    sc = SCENARIOS[0]
    a = Scenario(sc.arch, sc.workload, lambda: sc.build(), sc.params)

    def other_build():
        return sc.build()

    b = Scenario(sc.arch, sc.workload, other_build, sc.params)
    assert a.key != b.key


def test_default_scenario_params_carry_builder_identity():
    """The S() helper wraps every builder in a lambda (one shared
    __qualname__), so params must embed the wrapped function's identity —
    otherwise same-(arch, workload, sizes) cells with different builders
    would alias in the AIDG cache."""
    for s in SCENARIOS:
        assert s.params[0][0] == "__builder__", s.name
    keys = [s.key for s in SCENARIOS]
    assert len(keys) == len(set(keys))


def test_fixed_point_batch_rejects_unknown_storage(explorer):
    aidg = explorer.compiled[0].aidg
    with pytest.raises(KeyError, match="unknown storage"):
        fixed_point_batch(aidg, storage_lats={
            "no_such_storage": np.ones((2, 4), np.float32)})


def test_clear_scenario_cache():
    sc = SCENARIOS[0]
    a = compile_scenario(sc)
    clear_scenario_cache()
    b = compile_scenario(sc)
    assert a is not b and a.baseline == b.baseline


# ---------------------------------------------------------------------------
# candidate generators, projection, chunking, refinement
# ---------------------------------------------------------------------------


def test_candidate_generators_shapes_and_bounds():
    space = DEFAULT_SPACE
    g = grid_candidates(space, points=3)
    assert g.shape == (3 ** space.n, space.n)
    r = random_candidates(space, 100, seed=1)
    assert r.shape == (100, space.n)
    assert np.all(r[0] == 1.0)  # baseline row
    lo = np.asarray([k.lo for k in space.knobs])
    hi = np.asarray([k.hi for k in space.knobs])
    for c in (g, r):
        assert np.all(c >= lo - 1e-6) and np.all(c <= hi + 1e-6)
    # grids are deterministic
    assert np.array_equal(g, grid_candidates(space, points=3))


def test_projection_identity_for_unmatched_classes(explorer):
    """Knob vectors at 1.0 must project to all-ones θ; unmatched classes
    stay at 1.0 for any knob values."""
    for cs in explorer.compiled:
        to, ts = explorer.space.theta_for(
            cs.problem, np.ones((1, explorer.space.n), np.float32))
        assert np.all(to == 1.0) and np.all(ts == 1.0)


def test_theta_for_rejects_wrong_candidate_width(explorer):
    """Candidates minted for a different DesignSpace must error, not
    silently misproject onto the identity column."""
    bad = np.ones((2, explorer.space.n + 1), np.float32)
    with pytest.raises(ValueError, match="knobs"):
        explorer.space.theta_for(explorer.compiled[0].problem, bad)


def test_chunked_sweep_matches_unchunked(explorer):
    cs = explorer.compiled[2]  # gamma/gemm
    rng = np.random.default_rng(5)
    B = 37  # deliberately not a multiple of the chunk
    to = rng.uniform(0.5, 2, (B, cs.problem.n_op)).astype(np.float32)
    ts = rng.uniform(0.5, 2, (B, cs.problem.n_st)).astype(np.float32)
    full = sweep(cs.problem, to, ts)
    chunked = sweep(cs.problem, to, ts, chunk=16)
    assert np.allclose(full, chunked, atol=1e-3)


def test_fixed_point_batch_matches_single(explorer):
    cs = explorer.compiled[3]  # gamma/attention
    aidg = cs.aidg
    rng = np.random.default_rng(9)
    works = np.maximum(1.0, aidg.work[None, :] *
                       rng.uniform(0.5, 2, (3, aidg.n))).astype(np.float32)
    batch = np.asarray(fixed_point_batch(aidg, works=works))
    for i in range(3):
        single = np.asarray(fixed_point_jax(aidg, work=works[i]))
        assert np.allclose(batch[i], single, atol=1e-3), i
    # batched storage latencies (works broadcast from the baseline)
    st = next(iter(aidg.storage_lat))
    lats = np.stack([aidg.storage_lat[st] * f for f in (0.5, 1.0, 2.0)])
    batch = np.asarray(fixed_point_batch(
        aidg, storage_lats={st: lats.astype(np.float32)}))
    for i, f in enumerate((0.5, 1.0, 2.0)):
        single = np.asarray(fixed_point_jax(
            aidg, storage_lat={st: aidg.storage_lat[st] * f}))
        assert np.allclose(batch[i], single, atol=1e-3), i


def test_refine_stays_in_bounds_and_does_not_regress(explorer):
    best = explorer.refine(rounds=1, points=5, objective="latency")
    lo = np.asarray([k.lo for k in explorer.space.knobs])
    hi = np.asarray([k.hi for k in explorer.space.knobs])
    assert np.all(best >= lo - 1e-6) and np.all(best <= hi + 1e-6)
    base = explorer.explore(np.ones((1, explorer.space.n), np.float32))
    ref = explorer.explore(best[None, :])
    assert ref.latency[0] <= base.latency[0] + 1e-6


def test_refine_never_regresses_from_offgrid_start(explorer):
    """Coordinate steps always include the incumbent level, so refining
    from a start that is not on the geomspace grid cannot end up worse."""
    start = np.asarray([0.7, 1.3, 0.9, 1.1, 0.8], np.float32)

    def score(theta):
        r = explorer.explore(theta[None, :])
        return float(r.latency[0] * r.cost[0])

    best = explorer.refine(start=start, rounds=1, points=2)
    assert score(best) <= score(start) + 1e-6


def test_cost_proxy_monotone(explorer):
    """Uniformly faster hardware must cost more."""
    fast = np.full((1, explorer.space.n), 0.5, np.float32)
    slow = np.full((1, explorer.space.n), 2.0, np.float32)
    assert explorer.cost_proxy(fast)[0] > explorer.cost_proxy(slow)[0]
