"""AIDG fast estimation vs the cycle-accurate event simulator (paper §6,
[16]), plus the max-plus JAX paths and the DSE sweep."""

import numpy as np
import pytest

from repro.core.acadl import simulate
from repro.core.acadl.sim import build_trace
from repro.core.aidg import (build_aidg, estimate_cycles, fixed_point_jax,
                             longest_path, longest_path_blocked,
                             longest_path_fixed_point, longest_path_scan,
                             longest_path_wavefront, make_problem, sweep)
from repro.core.archs import make_gamma_ag, make_oma_ag, make_systolic_ag
from repro.core.mapping.gemm import (gamma_gemm, init_gemm_memory,
                                     oma_gemm_looped, oma_gemm_unrolled)
from repro.core.mapping.systolic import (init_systolic_memory,
                                         systolic_gemm_program)


def _gamma_case(nu=2, n=32):
    A = np.ones((n, n), np.float32)
    ag, _ = make_gamma_ag(n_units=nu)
    init_gemm_memory(ag, A, A, memory="dram0", tile=8)
    units = tuple((f"lsu{k}", f"matMulFu{k}", f"vrf{k}") for k in range(nu))
    return ag, gamma_gemm(n, n, n, tile=8, units=units)


CASES = []


def _oma_case(looped):
    A = np.ones((6, 6))
    ag, _ = make_oma_ag()
    init_gemm_memory(ag, A, A)
    prog = oma_gemm_looped(6, 6, 6) if looped else oma_gemm_unrolled(6, 6, 6)
    return ag, prog


def _systolic_case():
    A = np.ones((8, 12)); B = np.ones((12, 8))
    ag, _ = make_systolic_ag(4, 4)
    init_systolic_memory(ag, A, B)
    return ag, systolic_gemm_program(8, 12, 8, 4, 4)


@pytest.mark.parametrize("case,tol", [
    ("oma_looped", 0.0),      # branchy scalar code: exact
    ("oma_unrolled", 0.0),    # straightline: exact
    ("gamma1", 0.0),          # single-unit fused tensor: exact
    ("gamma2", 0.02),         # multi-unit OoO + storage queueing: <=2%
    ("systolic", 0.04),       # 16-PE wavefront + DRAM queueing: <=4%
])
def test_aidg_matches_event_sim(case, tol):
    ag, prog = {
        "oma_looped": lambda: _oma_case(True),
        "oma_unrolled": lambda: _oma_case(False),
        "gamma1": lambda: _gamma_case(1),
        "gamma2": lambda: _gamma_case(2),
        "systolic": _systolic_case,
    }[case]()
    sim_cycles = simulate(ag, prog).cycles
    est, _ = estimate_cycles(ag, prog)
    err = abs(est - sim_cycles) / sim_cycles
    assert err <= tol + 1e-9, (est, sim_cycles)


def test_jnp_paths_agree_with_numpy():
    ag, prog = _gamma_case(2)
    trace = build_trace(ag, prog)
    aidg = build_aidg(ag, trace)
    t_np = longest_path(aidg)
    t_wave = np.asarray(longest_path_wavefront(aidg))
    t_scan = np.asarray(longest_path_scan(aidg))
    t_blk = longest_path_blocked(aidg, block=64)
    assert np.allclose(t_np, t_wave, atol=0.5)
    assert np.allclose(t_np, t_scan, atol=0.5)
    assert np.allclose(t_np, t_blk, atol=0.5)
    fp_np = longest_path_fixed_point(aidg)
    for engine in ("wavefront", "scan", "blocked"):
        fp_jx = np.asarray(fixed_point_jax(aidg, engine=engine))
        assert abs(fp_np.max() - fp_jx.max()) < 1.0, engine


def test_dse_theta_one_reproduces_baseline():
    ag, prog = _gamma_case(2)
    trace = build_trace(ag, prog)
    aidg = build_aidg(ag, trace)
    base = longest_path_fixed_point(aidg).max()
    prob = make_problem(aidg)
    ones_op = np.ones((1, prob.n_op), np.float32)
    ones_st = np.ones((1, prob.n_st), np.float32)
    out = sweep(prob, ones_op, ones_st)
    assert abs(float(out[0]) - base) < 1.0


def test_dse_monotone_in_memory_latency():
    """Slower DRAM can never make the workload faster."""
    ag, prog = _gamma_case(2)
    trace = build_trace(ag, prog)
    aidg = build_aidg(ag, trace)
    prob = make_problem(aidg)
    thetas_st = np.asarray([[0.5], [1.0], [2.0], [4.0]], np.float32)
    thetas_op = np.ones((4, prob.n_op), np.float32)
    out = sweep(prob, thetas_op, thetas_st)
    assert np.all(np.diff(out) >= -0.5)


def test_dse_batched_sweep_shape():
    ag, prog = _gamma_case(1, n=16)
    trace = build_trace(ag, prog)
    prob = make_problem(build_aidg(ag, trace))
    B = 16
    rng = np.random.default_rng(0)
    out = sweep(prob, rng.uniform(0.5, 2, (B, prob.n_op)).astype(np.float32),
                rng.uniform(0.5, 2, (B, prob.n_st)).astype(np.float32))
    assert out.shape == (B,) and np.all(out > 0)
