"""The condensed + matrix-packed evaluation engine:

(a) ``builder.condense_aidg`` — θ-parametric chain condensation (absorbed
    super-edges + affine-chain coupling) is EXACT on the hard max-plus
    path for every θ, per default cell, and actually shrinks the
    sequential scan on chain-dominated graphs (≥ 3x),
(b) ``maxplus.fixed_point_jax(engine="condensed")`` / the soft family —
    agreement with the wavefront engine, soft bounds,
(c) ``dse.PackedMatrix`` — the whole matrix in one dispatch: golden θ = 1
    pins hold exactly, network cells, pipelined composition, chunking,
    and the packed gradient path (packed-vs-wavefront and packed-vs-
    per-cell agreement live in tests/test_oracle_chain.py),
(d) storage static-order proofs and the prologue condensation boundary,
(e) the scenario-cache-stats autouse fixture isolates tests (regression).
"""

import numpy as np
import pytest

from repro.core.aidg.builder import condense_aidg
from repro.core.aidg.dse import PackSpec, PackedMatrix, sweep
from repro.core.aidg.explorer import (DEFAULT_SPACE, Explorer,
                                      compile_scenario, default_scenarios,
                                      random_candidates,
                                      scenario_cache_stats)
from repro.core.aidg.maxplus import fixed_point_jax, fixed_point_soft

from test_dse_explorer import GOLDEN_THETA1_CYCLES

SCENARIOS = default_scenarios()
IDS = [s.name for s in SCENARIOS]


@pytest.fixture(scope="module")
def ex_packed():
    return Explorer()                      # engine="packed" is the default


@pytest.fixture(scope="module")
def ex_wave():
    return Explorer(engine="wavefront")


# ---------------------------------------------------------------------------
# (a) condensation exactness + level reduction
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scenario", SCENARIOS, ids=IDS)
def test_condensed_fixed_point_exact_at_theta_one(scenario):
    aidg = compile_scenario(scenario).aidg
    t_wf = np.asarray(fixed_point_jax(aidg, engine="wavefront"))
    t_cd = np.asarray(fixed_point_jax(aidg, engine="condensed"))
    assert np.array_equal(t_wf, t_cd), scenario.name


@pytest.mark.parametrize("scenario", SCENARIOS, ids=IDS)
def test_condensed_sweep_matches_wavefront_at_random_theta(scenario):
    prob = compile_scenario(scenario).problem
    rng = np.random.default_rng(hash(scenario.name) % 2 ** 31)
    B = 6
    to = rng.uniform(0.25, 4.0, (B, prob.n_op)).astype(np.float32)
    ts = rng.uniform(0.25, 4.0, (B, prob.n_st)).astype(np.float32)
    out_wf = sweep(prob, to, ts, engine="wavefront")
    out_cd = sweep(prob, to, ts, engine="condensed")
    assert np.allclose(out_wf, out_cd, rtol=1e-4, atol=0.5), scenario.name


@pytest.mark.parametrize("tau", [0.05, 0.01])
@pytest.mark.parametrize("scenario", SCENARIOS, ids=IDS)
def test_condensed_soft_bounded_by_hard_and_uncondensed_soft(scenario, tau):
    """The condensed soft family keeps absorbed/coupled steps as exact
    sums, so its makespan sits between the hard result and the (looser)
    uncondensed soft upper bound."""
    aidg = compile_scenario(scenario).aidg
    hard = float(np.asarray(fixed_point_jax(aidg)).max())
    s_wf = float(np.asarray(fixed_point_soft(aidg, tau=tau)).max())
    s_cd = float(np.asarray(
        fixed_point_soft(aidg, tau=tau, engine="condensed")).max())
    assert s_cd >= hard * (1 - 1e-3) - 1e-2, (scenario.name, s_cd, hard)
    assert s_cd <= s_wf * (1 + 1e-3) + 1e-2, (scenario.name, s_cd, s_wf)


def test_condensation_reduces_levels_on_chain_dominated_cells():
    """The tentpole's structural claim: ≥ 3x fewer sequential levels on
    the chain-dominated cell (scalar in-order OMA) and in total across
    the default matrix."""
    by_name = {s.name: s for s in SCENARIOS}
    oma = condense_aidg(compile_scenario(by_name["oma/gemm"]).aidg).stats
    assert oma["level_reduction"] >= 3.0, oma
    tot0 = tot1 = 0
    for s in SCENARIOS:
        st = condense_aidg(compile_scenario(s).aidg).stats
        assert st["levels_condensed"] <= st["levels"], s.name
        tot0 += st["levels"]
        tot1 += st["levels_condensed"]
    assert tot0 / tot1 >= 3.0, (tot0, tot1)


def test_fixed_point_soft_rejects_unknown_engine():
    aidg = compile_scenario(SCENARIOS[2]).aidg
    with pytest.raises(ValueError, match="engine"):
        fixed_point_soft(aidg, engine="blocked")


def test_condense_is_memoized_per_boundary():
    aidg = compile_scenario(SCENARIOS[2]).aidg   # gamma/gemm
    assert condense_aidg(aidg) is condense_aidg(aidg)
    b = condense_aidg(aidg, boundary=10)
    assert b is condense_aidg(aidg, boundary=10)
    assert b is not condense_aidg(aidg)


def test_condense_boundary_preserves_prefix_max():
    """With a prologue boundary, the max over KEPT nodes with original id
    < k equals the max over ALL nodes with id < k (the packed network
    prologue relies on this)."""
    sc = next(s for s in SCENARIOS if s.name == "oma/gemm")
    aidg = compile_scenario(sc).aidg
    t = np.asarray(fixed_point_jax(aidg, engine="condensed"))
    for k in (7, 63, 500):
        cond = condense_aidg(aidg, boundary=k)
        kept_below = cond.kept[cond.kept < k]
        assert kept_below.size, k
        assert t[kept_below].max() == pytest.approx(t[:k].max(), abs=1e-3), k


def test_storage_static_order_proofs():
    """The in-order OMA chain serves its D-cache in access order for every
    θ (provable: each access is an ancestor of the next); the systolic
    array's DRAM is genuinely dynamic (parallel lanes race)."""
    by_name = {s.name: s for s in SCENARIOS}
    oma = condense_aidg(compile_scenario(by_name["oma/gemm"]).aidg)
    assert oma.storage_static_order("dcache0")
    sy = condense_aidg(compile_scenario(by_name["systolic/gemm"]).aidg)
    assert not sy.storage_static_order("dram0")


def test_op_class_counts_cover_absorbed_nodes():
    cond = condense_aidg(compile_scenario(SCENARIOS[0]).aidg)  # oma/gemm
    counts = cond.op_class_counts()
    assert counts.sum() == cond.n_absorbed
    assert counts.shape[1] == len(cond.aidg.classes)


def test_longest_path_condensed_matches_wavefront():
    """The storage-free relaxation entry point (no queueing fold) agrees
    with the uncondensed wavefront node-for-node."""
    from repro.core.aidg.maxplus import (longest_path_condensed,
                                         longest_path_wavefront)
    aidg = compile_scenario(SCENARIOS[0]).aidg      # oma/gemm, one chain
    t_wf = np.asarray(longest_path_wavefront(aidg))
    t_cd = np.asarray(longest_path_condensed(aidg))
    assert np.array_equal(t_wf, t_cd)


# ---------------------------------------------------------------------------
# (c) the packed matrix: one dispatch, same numbers
# ---------------------------------------------------------------------------


def test_packed_theta_one_matches_golden_pins(ex_packed):
    """Acceptance: every cell's packed+condensed θ = 1 result matches the
    existing golden pins exactly."""
    for name, baseline in zip(ex_packed.scenario_names, ex_packed.baselines):
        assert float(baseline) == pytest.approx(
            GOLDEN_THETA1_CYCLES[name], abs=0.5), name


def test_packed_chunked_evaluate_matches(ex_packed):
    cand = random_candidates(ex_packed.space, 23, seed=9)
    full = ex_packed.evaluate(cand)
    chunked = ex_packed.evaluate(cand, chunk=8)
    assert np.allclose(full, chunked, rtol=1e-6)


def test_packed_explore_deterministic(ex_packed):
    cand = random_candidates(ex_packed.space, 16, seed=11)
    r1 = ex_packed.explore(cand)
    r2 = ex_packed.explore(cand)
    assert np.array_equal(r1.cycles, r2.cycles)
    assert np.array_equal(r1.pareto, r2.pareto)


def test_packed_stats_shape(ex_packed):
    st = ex_packed.packed_matrix().stats()
    assert st["rows"] == st["cells"] == len(SCENARIOS)
    assert st["levels_condensed"] <= st["levels"]
    assert st["buckets"] >= 1
    assert st["level_reduction"] >= 3.0


def test_pack_spec_operator_shape():
    cs = compile_scenario(SCENARIOS[2])
    spec = cs.pack_spec(DEFAULT_SPACE.projection(cs.problem))
    assert isinstance(spec, PackSpec)
    assert len(spec.problems) == 1 and spec.run_reps.tolist() == [1.0]
    assert spec.fits_within.tolist() == [0.0]   # no overlap gates


def test_packed_matrix_dedups_shared_problems():
    cs = compile_scenario(SCENARIOS[2])
    proj = DEFAULT_SPACE.projection(cs.problem)
    spec = cs.pack_spec(proj)
    pm = PackedMatrix.build([spec, spec], DEFAULT_SPACE.n)
    assert pm.n_cells == 2 and pm.n_rows == 1
    out = pm.evaluate(np.ones((1, DEFAULT_SPACE.n), np.float32))
    assert out.shape == (1, 2)
    assert out[0, 0] == out[0, 1]


def test_explorer_refine_rides_packed(ex_packed):
    """Coordinate descent on the default explorer goes through the packed
    evaluator and must still not regress from θ = 1."""
    best = ex_packed.refine(rounds=1, points=3)
    base = ex_packed.explore(np.ones((1, ex_packed.space.n), np.float32))
    ref = ex_packed.explore(best[None, :])
    assert (ref.latency[0] * ref.cost[0]
            <= base.latency[0] * base.cost[0] + 1e-6)


# ---------------------------------------------------------------------------
# network cells through the packed engine
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def net_packed():
    from repro.core.network import default_network_scenarios
    return Explorer(scenarios=default_network_scenarios(
        networks=["olmo_1b"], archs=["tpu_v5e", "gamma"]))


def test_packed_network_baseline_normalizes(net_packed):
    # per-cell agreement at random θ moved to tests/test_oracle_chain.py
    base = net_packed.explore(np.ones((1, 5), np.float32))
    assert base.latency[0] == pytest.approx(1.0, abs=1e-5)


def test_packed_pipelined_network_matches_stack():
    from repro.core.network.model import NetworkScenario
    pip = NetworkScenario("eyeriss", "whisper_small", mode="pipelined")
    ex = Explorer(scenarios=[pip])
    kt = np.asarray([[1.0] * 5, [0.5, 1.5, 0.8, 1.2, 0.9]], np.float32)
    packed = ex.evaluate(kt)[:, 0]
    stack = pip.compile().evaluate(DEFAULT_SPACE, kt)
    assert np.allclose(packed, stack, rtol=5e-3)


def test_packed_gradient_matches_finite_differences(net_packed):
    from repro.core.aidg.gradient import GradientExplorer
    ge = GradientExplorer(net_packed)
    assert ge._packed_fn is not None      # the packed grad path is active
    k0 = np.asarray([[0.8, 1.2, 0.9, 1.1, 1.0]], np.float32)
    # τ = 0.2 / eps = 1e-2 as in tests/test_gradient_dse.py: smaller τ
    # puts central differences across softmax (and queue-order) kinks
    tau = 0.2
    _, g = ge.value_and_grad(k0, tau)
    eps = 1e-2
    for i in range(5):
        kp, km = k0.copy(), k0.copy()
        kp[0, i] += eps
        km[0, i] -= eps
        vp, _ = ge.value_and_grad(kp, tau)
        vm, _ = ge.value_and_grad(km, tau)
        fd = (vp[0] - vm[0]) / (2 * eps)
        # value_and_grad returns the log-objective; compare directly
        assert abs(fd - g[0, i]) <= 5e-2 * max(1.0, abs(fd)), (i, fd, g[0, i])


def test_packed_gradient_refine_not_worse_than_start(net_packed):
    from repro.core.aidg.gradient import GradientExplorer
    ge = GradientExplorer(net_packed)
    res = ge.refine(starts=2, steps=5, seed=0)
    base = float(ge.hard_score(np.ones((1, 5), np.float32))[0])
    assert res.score <= base + 1e-6


def test_percell_gradient_path_matches_packed(ex_packed, ex_wave):
    """GradientExplorer keeps a per-cell fallback for non-packed
    explorers; both paths descend the same objective (soft surfaces are
    close, not identical — condensed chains keep exact sums)."""
    from repro.core.aidg.gradient import GradientExplorer
    gp = GradientExplorer(ex_packed)
    gc = GradientExplorer(ex_wave)
    assert gp._packed_fn is not None and gc._packed_fn is None
    k0 = np.asarray([[0.9, 1.1, 1.0, 1.2, 0.8]], np.float32)
    vp, dp = gp.value_and_grad(k0, 0.05)
    vc, dc = gc.value_and_grad(k0, 0.05)
    assert vp[0] == pytest.approx(vc[0], rel=2e-2)
    assert np.allclose(dp, dc, rtol=0.2, atol=5e-2)


# ---------------------------------------------------------------------------
# (e) cache-stats isolation (regression for the autouse fixture)
# ---------------------------------------------------------------------------


def test_cache_stats_isolated_part_one():
    """Generate cache traffic; the paired test below must not see it."""
    compile_scenario(SCENARIOS[0])
    compile_scenario(SCENARIOS[0])
    stats = scenario_cache_stats()
    assert stats["hits"] + stats["misses"] >= 2


def test_cache_stats_isolated_part_two():
    """Runs after part_one in file order: the autouse fixture must have
    zeroed the counters, so the traffic above is invisible here."""
    assert scenario_cache_stats() == {"hits": 0, "misses": 0}
    compile_scenario(SCENARIOS[0])
    stats = scenario_cache_stats()
    assert stats["hits"] + stats["misses"] == 1
