"""Dry-run machinery end-to-end on a small mesh (subprocess: 8 host
devices, smoke-size config) — exercises param/input/cache sharding rules,
lowering, compile, memory/cost/collective analyses without the 512-device
cost of the real dry-run."""

import subprocess
import sys
from pathlib import Path

CODE = """
import os, sys
sys.path.insert(0, {src!r})
import repro.launch.dryrun as dr      # sets XLA_FLAGS; override below
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
from dataclasses import replace
import jax
from repro.configs import get_smoke_config
from repro.models.config import ShapeConfig

mesh = jax.make_mesh((4, 2), ("data", "model"))
for arch, mode in (("olmo_1b", "train"), ("olmoe_1b_7b", "train"),
                   ("falcon_mamba_7b", "decode"), ("minicpm3_4b", "decode"),
                   ("whisper_small", "prefill")):
    cfg = get_smoke_config(arch)
    shape = ShapeConfig("lite", 64, 8, mode)
    rec, compiled, lowered = dr.lower_cell(cfg, shape, mesh)
    assert rec["memory"]["temp_bytes"] > 0
    assert rec["dot_flops_per_device"] > 0
    print("OK", arch, mode, rec["collective_counts"])
print("ALL_OK")
"""


def test_dryrun_lite_all_families(tmp_path):
    src = str(Path(__file__).resolve().parents[1] / "src")
    out = subprocess.run([sys.executable, "-c", CODE.format(src=src)],
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "ALL_OK" in out.stdout
