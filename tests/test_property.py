"""Hypothesis property tests on system invariants.

Skipped cleanly when ``hypothesis`` is not installed (it is a dev-only
dependency — see pyproject.toml ``[project.optional-dependencies] dev``).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.acadl.storage import SetAssociativeCache
from repro.core.aidg import build_aidg, longest_path
from repro.core.aidg.dse import evaluate_theta, evaluate_theta_soft, sweep
from repro.core.aidg.explorer import (compile_scenario, default_scenarios,
                                      pareto_front)
from repro.core.acadl.sim import build_trace
from repro.core.archs import make_gamma_ag
from repro.core.mapping.gemm import gamma_gemm, init_gemm_memory
from repro.kernels import ops, ref
from repro.models.layers import apply_rope, chunked_attention, dense_attention

SETTINGS = dict(max_examples=25, deadline=None)


@given(st.integers(2, 24), st.integers(2, 24), st.integers(2, 24),
       st.integers(0, 5))
@settings(**SETTINGS)
def test_maxplus_matches_ref_any_shape(m, k, n, seed):
    rng = np.random.default_rng(seed)
    A = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    out = ops.maxplus_matmul(A, B, bm=8, bk=8, bn=8)
    np.testing.assert_allclose(out, ref.maxplus_matmul_ref(A, B), atol=1e-5)


@given(st.integers(1, 6), st.integers(1, 6), st.integers(1, 6),
       st.integers(0, 3))
@settings(**SETTINGS)
def test_gemm_kernel_matches_ref_any_shape(mq, kq, nq, seed):
    m, k, n = 8 * mq, 8 * kq, 8 * nq
    rng = np.random.default_rng(seed)
    A = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    out = ops.gemm(A, B, bm=16, bk=16, bn=16)
    np.testing.assert_allclose(out, ref.gemm_ref(A, B), atol=1e-4, rtol=1e-4)


@given(st.integers(0, 1000), st.integers(8, 64))
@settings(**SETTINGS)
def test_rope_preserves_norm(pos, dim):
    dim = (dim // 2) * 2
    x = jnp.asarray(np.random.default_rng(pos).normal(size=(1, 1, 1, dim)),
                    jnp.float32)
    y = apply_rope(x, jnp.asarray([[pos]]), 10_000.0)
    np.testing.assert_allclose(float(jnp.linalg.norm(x)),
                               float(jnp.linalg.norm(y)), rtol=1e-5)


@given(st.integers(0, 4))
@settings(max_examples=8, deadline=None)
def test_attention_causality(seed):
    """Perturbing future tokens never changes past outputs."""
    rng = np.random.default_rng(seed)
    s, h, d = 12, 2, 8
    q = jnp.asarray(rng.normal(size=(1, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, s, h, d)), jnp.float32)
    out = dense_attention(q, k, v, causal=True)
    k2 = k.at[:, -1].add(100.0)
    v2 = v.at[:, -1].add(-50.0)
    out2 = dense_attention(q, k2, v2, causal=True)
    np.testing.assert_allclose(np.asarray(out[:, :-1]),
                               np.asarray(out2[:, :-1]), atol=1e-5)


@given(st.integers(0, 4))
@settings(max_examples=6, deadline=None)
def test_chunked_equals_dense_attention(seed):
    rng = np.random.default_rng(seed)
    s, h, d = 16, 2, 8
    q, k, v = (jnp.asarray(rng.normal(size=(1, s, h, d)), jnp.float32)
               for _ in range(3))
    a = dense_attention(q, k, v, causal=True)
    b = chunked_attention(q, k, v, causal=True, chunk=4)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


@given(st.floats(1.0, 3.0), st.floats(1.0, 3.0))
@settings(max_examples=10, deadline=None)
def test_aidg_monotone_in_work(s1, s2):
    """Scaling any latency up never reduces the estimated makespan."""
    ag, _ = make_gamma_ag(n_units=2)
    A = np.ones((16, 16), np.float32)
    init_gemm_memory(ag, A, A, memory="dram0", tile=8)
    units = (("lsu0", "matMulFu0", "vrf0"), ("lsu1", "matMulFu1", "vrf1"))
    prog = gamma_gemm(16, 16, 16, tile=8, units=units)
    trace = build_trace(ag, prog)
    aidg = build_aidg(ag, trace)
    t1 = longest_path(aidg, work=aidg.work * np.float32(s1)).max()
    t2 = longest_path(aidg, work=aidg.work * np.float32(max(s1, s2))).max()
    assert t2 >= t1 - 1e-6


# ---------------------------------------------------------------------------
# chain condensation ≡ uncondensed wavefront (repro.core.aidg.builder)
# ---------------------------------------------------------------------------

_DEFAULT_SCENARIOS = default_scenarios()
_SCN_IDS = [s.name for s in _DEFAULT_SCENARIOS]


def _theta_draw(prob, seed):
    rng = np.random.default_rng(seed)
    to = np.exp(rng.uniform(np.log(0.25), np.log(4.0),
                            prob.n_op)).astype(np.float32)
    ts = np.exp(rng.uniform(np.log(0.25), np.log(4.0),
                            prob.n_st)).astype(np.float32)
    return to, ts


@pytest.mark.parametrize("scenario", _DEFAULT_SCENARIOS, ids=_SCN_IDS)
@given(seed=st.integers(0, 2 ** 31 - 1))
@settings(max_examples=5, deadline=None)
def test_condensed_equals_wavefront_for_random_theta(scenario, seed):
    """``condense_aidg`` is exact for EVERY θ on the hard path: the
    condensed engine's cycles match the uncondensed wavefront across
    log-uniform θ draws, on every default cell (compiled kernels are
    cached, so each draw is one cheap evaluation)."""
    prob = compile_scenario(scenario).problem
    to, ts = _theta_draw(prob, seed)
    wf = sweep(prob, to[None], ts[None], engine="wavefront")[0]
    cd = sweep(prob, to[None], ts[None], engine="condensed")[0]
    assert abs(wf - cd) <= 0.5 + 1e-4 * abs(wf), (scenario.name, wf, cd)


@pytest.mark.parametrize("tau", [0.05, 0.01])
@pytest.mark.parametrize(
    "scenario",
    [s for s in _DEFAULT_SCENARIOS
     if s.name in ("oma/gemm", "gamma/gemm", "tpu_v5e/gemm")],
    ids=lambda s: s.name if hasattr(s, "name") else s)
@given(seed=st.integers(0, 2 ** 31 - 1))
@settings(max_examples=4, deadline=None)
def test_condensed_soft_bounds_for_random_theta(scenario, tau, seed):
    """On the τ-soft path the condensed evaluator (exact chain sums) stays
    between the hard result and the uncondensed soft upper bound, for
    random θ — the gradient engine descends a consistent surface."""
    prob = compile_scenario(scenario).problem
    to, ts = _theta_draw(prob, seed)
    to_j, ts_j = jnp.asarray(to), jnp.asarray(ts)
    hard = float(evaluate_theta(prob, to_j, ts_j))
    s_wf = float(evaluate_theta_soft(prob, to_j, ts_j, tau))
    s_cd = float(evaluate_theta_soft(prob, to_j, ts_j, tau,
                                     engine="condensed"))
    assert s_cd >= hard * (1 - 1e-3) - 1e-2, (scenario.name, s_cd, hard)
    assert s_cd <= s_wf * (1 + 1e-3) + 1e-2, (scenario.name, s_cd, s_wf)


# ---------------------------------------------------------------------------
# pareto_front invariants (repro.core.aidg.explorer)
# ---------------------------------------------------------------------------

# a coarse grid of finite objective values: duplicates and exact ties are
# *likely*, which is exactly the regime where frontier bugs hide
_objective = st.integers(0, 8).map(lambda v: v / 4.0)
_obj_rows = st.lists(st.tuples(_objective, _objective), min_size=1,
                     max_size=40).map(lambda r: np.asarray(r, np.float64))


def _dominates(a, b):
    return bool(np.all(a <= b) and np.any(a < b))


@given(_obj_rows)
@settings(**SETTINGS)
def test_pareto_front_mutually_nondominated(objs):
    front = pareto_front(objs)
    assert front.size > 0
    for i in front:
        for j in front:
            if i != j:
                assert not _dominates(objs[j], objs[i]), (i, j)


@given(_obj_rows)
@settings(**SETTINGS)
def test_pareto_front_dominates_every_excluded_row(objs):
    front = pareto_front(objs)
    kept = set(front.tolist())
    for j in range(len(objs)):
        if j not in kept:
            assert any(_dominates(objs[i], objs[j]) or
                       np.array_equal(objs[i], objs[j]) for i in front), j


@given(_obj_rows, st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_pareto_front_deterministic_under_permutation(objs, seed):
    f1 = pareto_front(objs)
    assert np.array_equal(f1, pareto_front(objs))        # same input, twice
    perm = np.random.default_rng(seed).permutation(len(objs))
    f2 = pareto_front(objs[perm])
    pts = lambda o, idx: sorted(map(tuple, o[idx]))
    assert pts(objs, f1) == pts(objs[perm], f2)          # same point set
    assert np.all(np.diff(objs[f1, 0]) >= 0)             # sorted by obj 0


@given(_obj_rows)
@settings(**SETTINGS)
def test_pareto_front_keeps_exactly_one_of_duplicates(objs):
    # force at least one exact duplicate pair
    objs = np.concatenate([objs, objs[:1]])
    front = pareto_front(objs)
    pts = [tuple(objs[i]) for i in front]
    assert len(pts) == len(set(pts))                     # no duplicate points
    for i in front:                                      # first occurrence wins
        first = int(np.nonzero((objs == objs[i]).all(axis=1))[0][0])
        assert i == first, (i, first)


# the same invariants in 3 objectives — (latency, energy, cost), the
# frontier Explorer.explore and serve._rank actually rank
_obj_rows3 = st.lists(st.tuples(_objective, _objective, _objective),
                      min_size=1, max_size=40).map(
                          lambda r: np.asarray(r, np.float64))


@given(_obj_rows3)
@settings(**SETTINGS)
def test_pareto_front_3d_dominance_consistent(objs):
    front = pareto_front(objs)
    assert front.size > 0
    kept = set(front.tolist())
    for i in front:                      # mutually non-dominated
        for j in front:
            if i != j:
                assert not _dominates(objs[j], objs[i]), (i, j)
    for j in range(len(objs)):           # excluded => dominated or duplicate
        if j not in kept:
            assert any(_dominates(objs[i], objs[j]) or
                       np.array_equal(objs[i], objs[j]) for i in front), j


@given(_obj_rows3, st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_pareto_front_3d_deterministic_under_permutation(objs, seed):
    f1 = pareto_front(objs)
    assert np.array_equal(f1, pareto_front(objs))
    perm = np.random.default_rng(seed).permutation(len(objs))
    f2 = pareto_front(objs[perm])
    pts = lambda o, idx: sorted(map(tuple, o[idx]))
    assert pts(objs, f1) == pts(objs[perm], f2)
    assert np.all(np.diff(objs[f1, 0]) >= 0)             # sorted by obj 0


@given(_obj_rows3, st.integers(0, 2 ** 31 - 1),
       st.sampled_from([np.nan, np.inf, -np.inf]))
@settings(**SETTINGS)
def test_pareto_front_3d_nonfinite_rows_never_enter(objs, seed, bad):
    """A diverged candidate (NaN/inf in any objective) is dropped with a
    warning and can neither enter the 3-D frontier nor displace a finite
    row that the clean input would have kept."""
    rng = np.random.default_rng(seed)
    dirty = objs.copy()
    k = int(rng.integers(0, len(objs)))
    dirty[k, int(rng.integers(0, 3))] = bad
    with pytest.warns(RuntimeWarning, match="non-finite"):
        front = pareto_front(dirty)
    assert k not in front.tolist()
    for i in front:
        assert np.all(np.isfinite(dirty[i]))
    # brute-force oracle over the finite rows: non-dominated, first
    # occurrence of each duplicate point
    rows = [i for i in range(len(dirty))
            if np.all(np.isfinite(dirty[i]))]
    want = [i for i in rows
            if not any(_dominates(dirty[j], dirty[i]) or
                       (j < i and np.array_equal(dirty[j], dirty[i]))
                       for j in rows if j != i)]
    assert sorted(front.tolist()) == sorted(want)


# ---------------------------------------------------------------------------
# micro-batcher contract (repro.serve.batcher)
# ---------------------------------------------------------------------------

from repro.serve.batcher import MicroBatcher, plan_batches  # noqa: E402


@given(st.integers(0, 200), st.integers(1, 17))
@settings(**SETTINGS)
def test_plan_batches_is_a_greedy_fifo_partition(n, k):
    plan = plan_batches(n, k)
    flat = [i for s, e in plan for i in range(s, e)]
    assert flat == list(range(n))                 # tiles [0, n) exactly
    assert all(1 <= e - s <= k for s, e in plan)  # bounded windows
    assert all(e - s == k for s, e in plan[:-1])  # only the tail is short


@given(st.lists(st.integers(0, 9), min_size=0, max_size=40),
       st.integers(1, 7))
@settings(max_examples=20, deadline=None)
def test_microbatcher_dispatches_partition_the_query_set(items, k):
    """Every submitted item lands in exactly one dispatch — no drop, no
    dup — batches are contiguous in arrival order and bounded, and each
    result lands on ITS submitter's future."""
    def dispatch(batch):
        return [x * 10 + 1 for x in batch]

    mb = MicroBatcher(dispatch, max_batch=k, window_s=0.0)
    try:
        futs = [mb.submit(x) for x in items]
        results = [f.result(timeout=30.0) for f in futs]
        mb.drain()
        assert results == [x * 10 + 1 for x in items]
        seqs = [s for b in mb.dispatch_log for s in b]
        assert seqs == list(range(len(items)))    # partition, FIFO-contiguous
        assert all(1 <= len(b) <= k for b in mb.dispatch_log)
    finally:
        mb.close()


@given(st.lists(st.integers(0, 5), min_size=1, max_size=24),
       st.integers(1, 6), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=15, deadline=None)
def test_microbatcher_answers_invariant_to_interleaving(items, k, seed):
    """Stage random burst patterns with ``hold()`` so batch composition
    varies per draw: each item's answer is a pure function of the item,
    never of its batchmates or window shape, and the dispatch log stays
    a partition under EVERY interleaving."""
    def dispatch(batch):
        return [x * x + 1 for x in batch]

    rng = np.random.default_rng(seed)
    mb = MicroBatcher(dispatch, max_batch=k, window_s=0.0)
    try:
        futs = []
        i = 0
        while i < len(items):
            burst = int(rng.integers(1, k + 2))
            with mb.hold():                        # one staged window
                for x in items[i: i + burst]:
                    futs.append(mb.submit(x))
            i += burst
        results = [f.result(timeout=30.0) for f in futs]
        mb.drain()
        assert results == [x * x + 1 for x in items]
        assert sorted(s for b in mb.dispatch_log
                      for s in b) == list(range(len(items)))
    finally:
        mb.close()


@given(st.lists(st.booleans(), min_size=1, max_size=12), st.integers(1, 4))
@settings(max_examples=15, deadline=None)
def test_microbatcher_exception_fails_exactly_its_batch(flags, k):
    """A dispatch that raises fails every future in THAT batch and no
    other; the log still partitions the submissions."""
    def dispatch(batch):
        if any(batch):
            raise RuntimeError("poisoned batch")
        return [0 for _ in batch]

    mb = MicroBatcher(dispatch, max_batch=k, window_s=0.0)
    try:
        futs = [mb.submit(b) for b in flags]
        mb.drain()
        assert sorted(s for b in mb.dispatch_log
                      for s in b) == list(range(len(flags)))
        for batch in mb.dispatch_log:
            poisoned = any(flags[s] for s in batch)
            for s in batch:
                if poisoned:
                    with pytest.raises(RuntimeError):
                        futs[s].result(timeout=30.0)
                else:
                    assert futs[s].result(timeout=30.0) == 0
    finally:
        mb.close()


# ---------------------------------------------------------------------------
# staged-oracle routing + surrogate monotonicity (repro.serve + surrogate)
# ---------------------------------------------------------------------------

import functools  # noqa: E402


@functools.lru_cache(maxsize=1)
def _tiny_ex():
    """A 3-cell operator explorer, built once per process (compiled
    scenarios are cached, so this is cheap after the first call)."""
    from repro.core.aidg.explorer import Explorer
    return Explorer(scenarios=_DEFAULT_SCENARIOS[:3])


@functools.lru_cache(maxsize=1)
def _tiny_bundle():
    """A small fixed-seed surrogate over the tiny explorer (reduced
    sample/step budget — these properties test routing and structure,
    not accuracy)."""
    from repro.surrogate import SurrogateConfig, train_surrogate
    return train_surrogate(_tiny_ex(),
                           SurrogateConfig(n_samples=48, steps=200))


@given(st.lists(st.integers(0, 2), min_size=1, max_size=12),
       st.floats(0.0, 1.0), st.integers(1, 4))
@settings(max_examples=15, deadline=None)
def test_staged_routing_never_drops_dups_or_reorders(picks, max_err, k):
    """Whatever the confidence threshold — 0 routes everything to the
    packed tier, 1 routes (nearly) everything to the surrogate — every
    query gets exactly one answer, in submission order, for its own
    question; and the tier counters account for every fresh query."""
    from repro.serve import DSEService, Query
    ex = _tiny_ex()
    svc = DSEService(ex, pool=8, surrogate=_tiny_bundle(),
                     surrogate_max_err=max_err, max_batch=k)
    try:
        queries = [Query.make(workload=ex.compiled[i].workload,
                              archs=ex.compiled[i].arch) for i in picks]
        answers = svc.query_many(queries)
        assert len(answers) == len(queries)
        for q, a in zip(queries, answers):
            assert a.query == q                      # no reorder, no swap
            assert a.tier in ("surrogate", "packed")
            if a.tier == "surrogate":
                assert 0.0 < a.err_bound <= max_err
        st_ = svc.stats()
        fresh = st_["tiers"]["surrogate"] + st_["tiers"]["packed"]
        accounted = (fresh + st_["cache"]["hits"] + st_["cache"]["coalesced"])
        assert accounted == len(queries)
        # re-asking is answered from the cache, preserving the tier label
        again = svc.query_many(queries)
        for a, b in zip(answers, again):
            assert b.cached and b == a and b.tier == a.tier
    finally:
        svc.close()


@pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnhandledThreadExceptionWarning")
@given(st.lists(st.sampled_from(["ok", "error", "poison", "kill"]),
                min_size=0, max_size=10),
       st.integers(1, 3), st.integers(1, 4))
@settings(max_examples=15, deadline=None)
def test_no_fault_schedule_drops_dups_or_reorders(actions, attempts, k):
    """The robustness half of the serving contract: under ANY injected
    fault schedule — transient dispatch errors, poisoned payloads, even
    worker-thread kills — every submitted query resolves to exactly one
    outcome, in submission order, that is either an Answer to ITS OWN
    question or a structured failure.  Nothing hangs, drops, duplicates,
    or gets a batchmate's answer."""
    from repro.serve import (Answer, CircuitBreaker, DSEService, Query,
                             RetryPolicy, WorkerKill)
    from repro.serve.errors import ServeError

    spec = ";".join(f"packed[{i}]={a}"
                    for i, a in enumerate(actions) if a != "ok")
    ex = _tiny_ex()
    svc = DSEService(ex, pool=8, max_batch=k,
                     retry=RetryPolicy(max_attempts=attempts, base_s=0.0),
                     breaker=CircuitBreaker(open_after=2, probe_after=1),
                     fault_plan=spec or None)
    try:
        queries = [Query.make(workload="gemm", top_k=t)
                   for t in range(1, 9)]
        with svc.batcher.hold():                 # pin window composition
            futs = [svc.submit(q) for q in queries]
        outcomes = [f.exception(timeout=60.0) or f.result()
                    for f in futs]
        assert len(outcomes) == len(queries)     # no drop, no dup
        for q, o in zip(queries, outcomes):
            if isinstance(o, Answer):
                assert o.query == q              # no reorder, no swap
            else:
                assert isinstance(o, (ServeError, WorkerKill)), o
        # the schedule is finite: once it runs dry, walking the breaker
        # (shed -> probe) with an UNCACHED query must reach a clean
        # dispatch — each failed probe burns schedule, so the walk is
        # bounded by the schedule length
        probe = Query.make(workload="gemm", top_k=9)
        for _ in range(2 * len(actions) + 4):
            try:
                svc.query_many([probe])
                break
            except (ServeError, WorkerKill):
                continue
        else:
            pytest.fail("service never recovered after the schedule ran dry")
        final = svc.query_many(queries)
        for q, a in zip(queries, final):
            assert isinstance(a, Answer) and a.query == q
            assert a.tier == "packed"
    finally:
        svc.close()


@given(st.integers(0, 2 ** 31 - 1), st.integers(0, 4),
       st.floats(0.05, 2.0))
@settings(max_examples=20, deadline=None)
def test_surrogate_latency_monotone_in_each_knob(seed, knob, delta):
    """The exact engine's latency is provably nondecreasing in every θ
    knob (max/sum compositions of affine maps with nonnegative
    coefficients); the surrogate's closed form is monotone BY
    CONSTRUCTION (softplus-nonnegative path weights), so the property
    must hold exactly, for every cell, at any point and step size."""
    bundle = _tiny_bundle()
    rng = np.random.default_rng(seed)
    lo = np.exp(rng.uniform(np.log(0.25), np.log(4.0),
                            bundle.n_knobs)).astype(np.float32)
    knob = knob % bundle.n_knobs
    hi = lo.copy()
    hi[knob] = np.float32(min(4.0, hi[knob] + delta))
    lat, _ = bundle.predict_rel(np.stack([lo, hi]))
    assert np.all(lat[1] >= lat[0] - 1e-6 * np.abs(lat[0])), \
        (knob, lo[knob], hi[knob], lat)


@given(st.lists(st.integers(0, 255), min_size=1, max_size=60),
       st.integers(1, 4), st.integers(1, 4))
@settings(**SETTINGS)
def test_cache_hit_implies_faster(addrs, sets_pow, ways):
    """Invariant: a probe() hit always returns hit_latency."""
    c = SetAssociativeCache(name="c", sets=2 ** sets_pow, ways=ways,
                            hit_latency=1, miss_latency=9, cache_line_size=4)
    for a in addrs:
        hit_predicted = c.probe(a)
        lat = c.access_latency("read", a)
        assert lat == (1 if hit_predicted else 9)
