"""Fast unit tests for the HLO-text roofline parsers.

``parse_dot_flops`` / ``parse_collective_bytes`` walk post-optimization
HLO *text*, which has drifted across XLA releases: older dumps print bare
operands (``dot(%a, %b)``) while current ones inline operand types
(``dot(f32[2,32,64]{2,1,0} %a, ...)``).  These snippets pin both formats
so the next drift fails here in milliseconds instead of inside the
7-minute ``test_dryrun_lite`` subprocess.
"""

import math

from repro.launch.roofline import parse_collective_bytes, parse_dot_flops

# -- checked-in snippets -----------------------------------------------------

# Legacy text: bare % operands, no inline operand types.
HLO_BARE = """\
HloModule legacy

ENTRY %main.1 (a: f32[8,16], b: f32[16,4]) -> f32[8,4] {
  %a = f32[8,16]{1,0} parameter(0)
  %b = f32[16,4]{1,0} parameter(1)
  ROOT %dot.1 = f32[8,4]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""

# Current text (jax 0.4.x / XLA:CPU): inlined operand types with layouts.
HLO_TYPED = """\
HloModule jit_f, is_scheduled=true, entry_computation_layout={(f32[8,16]{1,0}, f32[16,4]{1,0})->f32[8,4]{1,0}}

ENTRY %main.2_spmd (param: f32[8,16], param.1: f32[16,4]) -> f32[8,4] {
  %param = f32[8,16]{1,0} parameter(0)
  %param.1 = f32[16,4]{1,0} parameter(1)
  ROOT %dot.0 = f32[8,4]{1,0} dot(f32[8,16]{1,0} %param, f32[16,4]{1,0} %param.1), lhs_contracting_dims={1}, rhs_contracting_dims={0}, metadata={op_name="jit(f)/dot_general"}
}
"""

# Typed operands with TPU-style tiled layout annotations.
HLO_TILED = """\
HloModule tiled

ENTRY %main.3 (p0: bf16[128,256], p1: bf16[256,512]) -> bf16[128,512] {
  %p0 = bf16[128,256]{1,0:T(8,128)(2,1)} parameter(0)
  %p1 = bf16[256,512]{1,0:T(8,128)(2,1)} parameter(1)
  ROOT %dot.2 = bf16[128,512]{1,0:T(8,128)(2,1)} dot(bf16[128,256]{1,0:T(8,128)(2,1)} %p0, bf16[256,512]{1,0:T(8,128)(2,1)} %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""

# Scanned layer stack: dot inside a while body whose trip count XLA knows.
# Modeled on a real jax.lax.scan lowering (typed operands throughout).
HLO_WHILE = """\
HloModule jit_scan, is_scheduled=true

%region_0.9 (arg_tuple.10: (s32[], f32[2,32,64], f32[12,64,64])) -> (s32[], f32[2,32,64], f32[12,64,64]) {
  %arg_tuple.10 = (s32[], f32[2,32,64]{2,1,0}, f32[12,64,64]{2,1,0}) parameter(0)
  %get-tuple-element.4 = f32[2,32,64]{2,1,0} get-tuple-element((s32[], f32[2,32,64]{2,1,0}, f32[12,64,64]{2,1,0}) %arg_tuple.10), index=1
  %get-tuple-element.8 = f32[64,64]{1,0} bitcast(f32[12,64,64]{2,1,0} %arg_tuple.10)
  %dot.0 = f32[2,32,64]{2,1,0} dot(f32[2,32,64]{2,1,0} %get-tuple-element.4, f32[64,64]{1,0} %get-tuple-element.8), lhs_contracting_dims={2}, rhs_contracting_dims={0}
  ROOT %tuple.2 = (s32[], f32[2,32,64]{2,1,0}, f32[12,64,64]{2,1,0}) tuple(%dot.0)
}

%region_1.18 (arg_tuple.19: (s32[], f32[2,32,64], f32[12,64,64])) -> pred[] {
  %arg_tuple.19 = (s32[], f32[2,32,64]{2,1,0}, f32[12,64,64]{2,1,0}) parameter(0)
  ROOT %compare.1 = pred[] compare(%arg_tuple.19, %arg_tuple.19), direction=LT
}

ENTRY %main.25_spmd (param: f32[2,32,64], param.1: f32[12,64,64]) -> f32[2,32,64] {
  %param = f32[2,32,64]{2,1,0} parameter(0)
  %param.1 = f32[12,64,64]{2,1,0} parameter(1)
  %tuple = (s32[], f32[2,32,64]{2,1,0}, f32[12,64,64]{2,1,0}) tuple(%param, %param.1)
  %while.25 = (s32[], f32[2,32,64]{2,1,0}, f32[12,64,64]{2,1,0}) while((s32[], f32[2,32,64]{2,1,0}, f32[12,64,64]{2,1,0}) %tuple), condition=%region_1.18, body=%region_0.9, backend_config={"known_trip_count":{"n":"12"}}
  ROOT %get-tuple-element.30 = f32[2,32,64]{2,1,0} get-tuple-element((s32[], f32[2,32,64]{2,1,0}, f32[12,64,64]{2,1,0}) %while.25), index=1
}
"""

# Collectives with typed operands, one inside a known-trip while body.
HLO_COLL = """\
HloModule jit_coll, is_scheduled=true, num_partitions=8

%add.clone (x.1: f32[], y.1: f32[]) -> f32[] {
  %x.1 = f32[] parameter(0)
  %y.1 = f32[] parameter(1)
  ROOT %add.2 = f32[] add(f32[] %x.1, f32[] %y.1)
}

%region_0.9 (arg_tuple.10: (s32[], f32[32,128])) -> (s32[], f32[32,128]) {
  %arg_tuple.10 = (s32[], f32[32,128]{1,0}) parameter(0)
  %get-tuple-element.4 = f32[32,128]{1,0} get-tuple-element((s32[], f32[32,128]{1,0}) %arg_tuple.10), index=1
  %all-reduce.1 = f32[32,128]{1,0} all-reduce(f32[32,128]{1,0} %get-tuple-element.4), channel_id=2, replica_groups=[1,8]<=[8], use_global_device_ids=true, to_apply=%add.clone
  ROOT %tuple.2 = (s32[], f32[32,128]{1,0}) tuple(%all-reduce.1)
}

%region_1.18 (arg_tuple.19: (s32[], f32[32,128])) -> pred[] {
  %arg_tuple.19 = (s32[], f32[32,128]{1,0}) parameter(0)
  ROOT %compare.1 = pred[] compare(%arg_tuple.19, %arg_tuple.19), direction=LT
}

ENTRY %main.18_spmd (param: f32[32,16]) -> f32[32,128] {
  %param = f32[32,16]{1,0} parameter(0)
  %all-gather = f32[32,128]{1,0} all-gather(f32[32,16]{1,0} %param), channel_id=1, replica_groups=[1,8]<=[8], dimensions={1}, use_global_device_ids=true
  %tuple = (s32[], f32[32,128]{1,0}) tuple(%all-gather)
  %while.25 = (s32[], f32[32,128]{1,0}) while((s32[], f32[32,128]{1,0}) %tuple), condition=%region_1.18, body=%region_0.9, backend_config={"known_trip_count":{"n":"4"}}
  ROOT %get-tuple-element.30 = f32[32,128]{1,0} get-tuple-element((s32[], f32[32,128]{1,0}) %while.25), index=1
}
"""


# -- parse_dot_flops ---------------------------------------------------------

def test_dot_flops_bare_operands():
    assert parse_dot_flops(HLO_BARE) == 2.0 * 8 * 4 * 16


def test_dot_flops_typed_operands():
    assert parse_dot_flops(HLO_TYPED) == 2.0 * 8 * 4 * 16


def test_dot_flops_tiled_layouts():
    assert parse_dot_flops(HLO_TILED) == 2.0 * 128 * 512 * 256


def test_dot_flops_while_trip_multiplication():
    # one dot of 2*(2*32*64)*64 FLOPs, executed known_trip_count=12 times
    per_trip = 2.0 * (2 * 32 * 64) * 64
    assert parse_dot_flops(HLO_WHILE) == 12 * per_trip


def test_dot_flops_both_formats_agree():
    assert parse_dot_flops(HLO_BARE) == parse_dot_flops(HLO_TYPED)


# -- parse_collective_bytes --------------------------------------------------

def test_collective_bytes_typed_operands_and_trips():
    out = parse_collective_bytes(HLO_COLL)
    payload = 32 * 128 * 4  # f32[32,128]
    # all-gather: once in entry, ring factor 1.0
    assert out["all-gather"]["count"] == 1
    assert out["all-gather"]["bytes"] == payload * 1.0
    # all-reduce: inside while body, trips=4, ring factor 2.0
    assert out["all-reduce"]["count"] == 4
    assert out["all-reduce"]["bytes"] == 4 * payload * 2.0
    # kinds not present report zero
    assert out["reduce-scatter"]["bytes"] == 0.0


def test_collective_bytes_ignores_done_ops():
    hlo = HLO_BARE.replace(
        "ROOT %dot.1 = f32[8,4]{1,0} dot(%a, %b), "
        "lhs_contracting_dims={1}, rhs_contracting_dims={0}",
        "ROOT %ard = f32[8,16]{1,0} all-reduce-done(%a)")
    out = parse_collective_bytes(hlo)
    assert out["all-reduce"]["count"] == 0


def test_trip_corr_clamped_and_warns():
    """roofline_report.analyze never deflates bytes; undercount warns."""
    import warnings as w
    from repro.launch.roofline_report import analyze

    base = {"arch": "olmo_1b", "shape": "train_4k", "mesh": "single",
            "n_active_params": 1e9, "bytes_per_device": 1e9,
            "collective_bytes_total": 0.0, "memory": {}}
    # scanned model: HLO walk 12x cost_analysis -> bytes scaled by 12
    rec = dict(base, flops_per_device=1e12, dot_flops_per_device=12e12)
    cell = analyze(rec)
    assert cell.memory_s * 819e9 / 1e9 == 12.0  # trip_corr applied
    # parser-drift shape: walk < cost_analysis -> clamped to 1, warns
    rec = dict(base, flops_per_device=1e12, dot_flops_per_device=0.5e12)
    with w.catch_warnings(record=True) as caught:
        w.simplefilter("always")
        cell = analyze(rec)
    assert math.isclose(cell.memory_s * 819e9, 1e9)
    assert any("parser drift" in str(c.message) for c in caught)
