"""Per-arch smoke tests (reduced configs, CPU): one forward + one train
step, shape/finiteness asserts; prefill+decode serving-path consistency."""

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_ids, get_config, get_smoke_config
from repro.models import SHAPES, cell_is_runnable, get_model, input_specs

ARCHS = all_arch_ids()


def make_batch(cfg, B, S, key=1, dtype=jnp.float32):
    batch = {"tokens": jax.random.randint(jax.random.key(key), (B, S), 0,
                                          cfg.vocab_size)}
    if cfg.n_patches:
        batch["patches"] = jax.random.normal(
            jax.random.key(2), (B, cfg.n_patches, cfg.d_model), dtype)
    if cfg.enc_dec is not None:
        batch["frames"] = jax.random.normal(
            jax.random.key(3), (B, cfg.enc_dec.encoder_len, cfg.d_model), dtype)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init_params(jax.random.key(0))
    B, S = 2, 16
    batch = make_batch(cfg, B, S)
    logits = model.logits(params, batch)
    exp_s = S + (cfg.n_patches or 0)
    assert logits.shape == (B, exp_s, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    def loss(p):
        lg, aux = model.logits_and_aux(p, batch)
        lp = jax.nn.log_softmax(lg.astype(jnp.float32), axis=-1)
        return -lp.mean() + aux

    g = jax.grad(loss)(params)
    gsq = jax.tree_util.tree_reduce(
        lambda a, b: a + jnp.sum(jnp.square(b.astype(jnp.float32))), g, 0.0)
    assert bool(jnp.isfinite(gsq)) and float(gsq) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_serve_consistency(arch):
    """prefill(x[:-1]) + decode(x[-1]) logits == full forward at -1."""
    cfg = replace(get_smoke_config(arch), compute_dtype="float32")
    if cfg.moe is not None:
        cfg = replace(cfg, moe=replace(cfg.moe, capacity_factor=8.0))
    model = get_model(cfg)
    params = model.init_params(jax.random.key(0))
    B, S = 2, 12
    batch = make_batch(cfg, B, S)
    full = model.logits(params, batch)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :S - 1]
    cache = model.init_cache(B, 32)
    lg_pre, cache = model.prefill(params, pre, cache)
    lg_dec, cache = model.decode_step(params, batch["tokens"][:, S - 1:S],
                                      cache)
    np.testing.assert_allclose(np.asarray(full[:, -1], np.float32),
                               np.asarray(lg_dec[:, 0], np.float32),
                               atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(full[:, -2], np.float32),
                               np.asarray(lg_pre[:, 0], np.float32),
                               atol=2e-4, rtol=1e-3)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The full-size configs carry the exact assigned hyperparameters."""
    assigned = {
        "minicpm3_4b": dict(n_layers=62, d_model=2560, d_ff=6400,
                            vocab_size=73448, n_heads=40),
        "h2o_danube3_4b": dict(n_layers=24, d_model=3840, d_ff=10240,
                               vocab_size=32000, n_heads=32, n_kv=8),
        "mistral_large_123b": dict(n_layers=88, d_model=12288, d_ff=28672,
                                   vocab_size=32768, n_heads=96, n_kv=8),
        "olmo_1b": dict(n_layers=16, d_model=2048, d_ff=8192,
                        vocab_size=50304, n_heads=16),
        "phi3_vision_4b": dict(n_layers=32, d_model=3072, d_ff=8192,
                               vocab_size=32064, n_heads=32),
        "deepseek_moe_16b": dict(n_layers=28, d_model=2048, d_ff=1408,
                                 vocab_size=102400, n_experts=64, top_k=6),
        "olmoe_1b_7b": dict(n_layers=16, d_model=2048, d_ff=1024,
                            vocab_size=50304, n_experts=64, top_k=8),
        "jamba_v01_52b": dict(n_layers=32, d_model=4096, d_ff=14336,
                              vocab_size=65536, n_experts=16, top_k=2),
        "falcon_mamba_7b": dict(n_layers=64, d_model=4096, vocab_size=65024,
                                d_state=16),
        "whisper_small": dict(n_layers=12, d_model=768, d_ff=3072,
                              vocab_size=51865, n_heads=12),
    }[arch]
    cfg = get_config(arch)
    assert cfg.n_layers == assigned["n_layers"]
    assert cfg.d_model == assigned["d_model"]
    assert cfg.vocab_size == assigned["vocab_size"]
    if "d_ff" in assigned:
        assert cfg.d_ff == assigned["d_ff"]
    if "n_heads" in assigned:
        assert cfg.attention.n_heads == assigned["n_heads"]
    if "n_kv" in assigned:
        assert cfg.attention.n_kv_heads == assigned["n_kv"]
    if "n_experts" in assigned:
        assert cfg.moe.n_experts == assigned["n_experts"]
        assert cfg.moe.top_k == assigned["top_k"]
    if "d_state" in assigned:
        assert cfg.ssm.d_state == assigned["d_state"]


def test_param_count_sanity():
    """Analytic n_params lands near each arch's nameplate size."""
    expect = {"olmo_1b": 1.2e9, "falcon_mamba_7b": 7.3e9,
              "mistral_large_123b": 123e9, "deepseek_moe_16b": 16.4e9,
              "olmoe_1b_7b": 6.9e9, "jamba_v01_52b": 52e9}
    for arch, n in expect.items():
        got = get_config(arch).n_params()
        assert 0.7 * n < got < 1.35 * n, (arch, got, n)


def test_long_500k_skip_rule():
    runnable = {a: cell_is_runnable(get_config(a), SHAPES["long_500k"])[0]
                for a in ARCHS}
    assert runnable["falcon_mamba_7b"] and runnable["jamba_v01_52b"] \
        and runnable["h2o_danube3_4b"]
    for a in ("minicpm3_4b", "mistral_large_123b", "olmo_1b",
              "phi3_vision_4b", "deepseek_moe_16b", "olmoe_1b_7b",
              "whisper_small"):
        assert not runnable[a], a


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("shape", ["train_4k", "decode_32k"])
def test_input_specs_shapes(arch, shape):
    cfg = get_config(arch)
    sh = SHAPES[shape]
    specs = input_specs(cfg, sh)
    if sh.mode == "train":
        n_text = sh.seq_len - (cfg.n_patches or 0)
        assert specs["tokens"].shape == (sh.global_batch, n_text)
        assert specs["labels"].shape == (sh.global_batch, n_text)
    else:
        assert specs["token"].shape == (sh.global_batch, 1)


def test_mla_cache_is_compressed():
    """MLA's decode cache stores (kv_lora + rope) per token, independent of
    head count — the technique's stated memory advantage."""
    cfg = get_config("minicpm3_4b")
    model = get_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(1, 1024))
    leaf_bytes = sum(np.prod(l.shape) * l.dtype.itemsize
                     for l in jax.tree_util.tree_leaves(cache))
    a = cfg.attention
    per_token = (a.kv_lora_rank + a.qk_rope_head_dim) * 2  # bf16
    expect = cfg.n_layers * 1024 * per_token
    assert leaf_bytes < expect * 1.1
    # GQA equivalent would be n_heads * head_dim * 2 (k+v) per token
    gqa_equiv = cfg.n_layers * 1024 * a.n_heads * a.head_dim * 2 * 2
    assert leaf_bytes < gqa_equiv / 15  # >15x smaller
