"""Elastic checkpoint restore: save on one mesh shape, restore onto
another (different device count), values identical.

Device counts are process-global in JAX, so each phase runs in a
subprocess with its own ``--xla_force_host_platform_device_count``.
"""

import json
import subprocess
import sys
from pathlib import Path

SAVE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys; sys.path.insert(0, {src!r})
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.ckpt import save_pytree

mesh = jax.make_mesh((4,), ("data",))
w = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
w = jax.device_put(w, NamedSharding(mesh, P("data", None)))
tree = {{"w": w, "b": jnp.ones((3,))}}
save_pytree(tree, {ckpt!r}, 7, extra={{"mesh": "4"}})
print("SAVED", float(w.sum()))
"""

RESTORE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys; sys.path.insert(0, {src!r})
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.ckpt import latest_checkpoint, load_pytree

mesh = jax.make_mesh((2,), ("data",))
like = {{"w": jax.ShapeDtypeStruct((8, 8), jnp.float32),
        "b": jax.ShapeDtypeStruct((3,), jnp.float32)}}
sh = {{"w": NamedSharding(mesh, P("data", None)),
      "b": NamedSharding(mesh, P())}}
tree = load_pytree(latest_checkpoint({ckpt!r}), like, shardings=sh)
assert tree["w"].sharding.num_devices == 2
np.testing.assert_array_equal(np.asarray(tree["w"]).ravel(),
                              np.arange(64, dtype=np.float32))
print("RESTORED", float(tree["w"].sum()))
"""


def _run(code: str) -> str:
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    return out.stdout


def test_elastic_reshard_4_to_2_devices(tmp_path):
    src = str(Path(__file__).resolve().parents[1] / "src")
    ckpt = str(tmp_path / "ck")
    s1 = _run(SAVE.format(src=src, ckpt=ckpt))
    assert "SAVED" in s1
    s2 = _run(RESTORE.format(src=src, ckpt=ckpt))
    assert "RESTORED" in s2
    # same logical value on both mesh shapes
    assert s1.split()[-1] == s2.split()[-1]
