"""ACADL object system, edges, AG validity, and the event simulator
(paper §3, §4, §6)."""

import numpy as np
import pytest

from repro.core.acadl import (ACADLEdge, AGValidityError, CONTAINS, Data,
                              DanglingEdge, EdgeValidityError, ExecuteStage,
                              FORWARD, FunctionalUnit, READ_DATA,
                              RegisterFile, SRAM, WRITE_DATA,
                              connect_dangling_edge, create_ag, generate,
                              latency_t, simulate)
from repro.core.acadl.storage import DRAM, SetAssociativeCache
from repro.core.archs import make_gamma_ag, make_oma_ag, make_systolic_ag
from repro.core.mapping.gemm import (gamma_gemm, init_gemm_memory,
                                     oma_gemm_looped, oma_gemm_unrolled,
                                     read_gemm_result)
from repro.core.mapping.systolic import (init_systolic_memory,
                                         read_systolic_result,
                                         systolic_gemm_program)


# ---------------------------------------------------------------------------
# class system / edges
# ---------------------------------------------------------------------------


def test_edge_validity_rejects_bad_edges():
    @generate
    def arch():
        ex = ExecuteStage(name="ex", latency=latency_t(1))
        rf = RegisterFile(name="rf", registers={"r0": Data(32, 0)})
        with pytest.raises(EdgeValidityError):
            ACADLEdge(rf, ex, FORWARD)        # RF cannot forward
        with pytest.raises(EdgeValidityError):
            ACADLEdge(ex, rf, CONTAINS)       # stages contain FUs, not RFs

    arch()


def test_dangling_edges_connect_and_validate():
    @generate
    def arch():
        ex = ExecuteStage(name="ex", latency=latency_t(1))
        fu = FunctionalUnit(name="fu", to_process={"x"})
        ACADLEdge(ex, fu, CONTAINS)
        rf = RegisterFile(name="rf", registers={"r0": Data(32, 0)})
        d1 = DanglingEdge(edge_type=READ_DATA, source=rf)
        edge = connect_dangling_edge(d1, fu)
        assert edge.source is rf and edge.target is fu
        # unconnected dangling edge never materializes
        DanglingEdge(edge_type=WRITE_DATA, source=fu)

    arch()


def test_duplicate_names_rejected():
    @generate
    def arch():
        ExecuteStage(name="dup", latency=latency_t(1))
        with pytest.raises(ValueError):
            ExecuteStage(name="dup", latency=latency_t(1))

    arch()


def test_latency_t_forms():
    assert latency_t(3).resolve() == 3
    assert latency_t(lambda words=1, **_: 2 * words).resolve(words=4) == 8
    assert latency_t("words + 1").resolve(words=4) == 5
    with pytest.raises(ValueError):
        latency_t(-1)


def test_ag_port_bound_validation():
    @generate
    def arch():
        # storage with 1 port but 2 connected MAUs -> invalid
        from repro.core.acadl import (InstructionFetchStage,
                                      InstructionMemoryAccessUnit,
                                      MemoryAccessUnit)
        imem = SRAM(name="imem", address_ranges=((0, 100),))
        pcrf = RegisterFile(name="pcrf", registers={"pc": Data(32, 0)})
        ifs = InstructionFetchStage(name="ifs", latency=latency_t(1),
                                    issue_buffer_size=4)
        imau = InstructionMemoryAccessUnit(name="imau", latency=latency_t(0))
        ACADLEdge(imem, imau, READ_DATA)
        ACADLEdge(pcrf, imau, READ_DATA)
        ACADLEdge(ifs, imau, CONTAINS)
        st = SRAM(name="st", address_ranges=((0, 100),), read_write_ports=1)
        for i in range(2):
            ex = ExecuteStage(name=f"ex{i}", latency=latency_t(1))
            mau = MemoryAccessUnit(name=f"mau{i}")
            ACADLEdge(ex, mau, CONTAINS)
            ACADLEdge(st, mau, READ_DATA)
            ACADLEdge(ifs, ex, FORWARD)

    arch()
    with pytest.raises(AGValidityError):
        create_ag()


# ---------------------------------------------------------------------------
# OMA (paper §4.1 / §5 Listing 5)
# ---------------------------------------------------------------------------


def gemm_case(m, n, l, seed=0):
    rng = np.random.default_rng(seed)
    A = rng.integers(-4, 5, (m, n)).astype(float)
    B = rng.integers(-4, 5, (n, l)).astype(float)
    return A, B


@pytest.mark.parametrize("m,n,l", [(2, 3, 4), (4, 4, 4), (5, 7, 3)])
def test_oma_gemm_looped_functional(m, n, l):
    A, B = gemm_case(m, n, l)
    ag, _ = make_oma_ag()
    init_gemm_memory(ag, A, B)
    res = simulate(ag, oma_gemm_looped(m, n, l))
    assert np.array_equal(read_gemm_result(ag, m, l), A @ B)
    assert res.cycles > 0


def test_oma_unrolled_matches_and_is_faster():
    A, B = gemm_case(6, 6, 6)
    ag1, _ = make_oma_ag()
    init_gemm_memory(ag1, A, B)
    r_loop = simulate(ag1, oma_gemm_looped(6, 6, 6))
    ag2, _ = make_oma_ag()
    init_gemm_memory(ag2, A, B)
    r_unroll = simulate(ag2, oma_gemm_unrolled(6, 6, 6))
    assert np.array_equal(read_gemm_result(ag2, 6, 6), A @ B)
    # unrolled has no branch bubbles or loop bookkeeping
    assert r_unroll.cycles < r_loop.cycles


def test_oma_tiling_changes_cache_behavior():
    """Execution order has a significant impact on execution time via the
    cache (paper §5): tiled and untiled visits of the same (i,j,k) space
    give different cycle counts, same functional result."""
    m = n = l = 8
    A, B = gemm_case(m, n, l)
    cycles = {}
    for tile in (0, 2):
        ag, _ = make_oma_ag(cache_sets=8, cache_ways=2, cache_line_size=4,
                            cache_miss_latency=30)
        init_gemm_memory(ag, A, B)
        prog = oma_gemm_unrolled(m, n, l, tile, tile, tile)
        res = simulate(ag, prog)
        assert np.array_equal(read_gemm_result(ag, m, l), A @ B)
        cycles[tile] = res.cycles
    assert cycles[2] != cycles[0]  # order visibly changes the timing


def test_oma_cache_size_changes_timing():
    """Bigger cache -> fewer misses -> fewer cycles for the same program."""
    m = n = l = 8
    A, B = gemm_case(m, n, l)
    cycles = {}
    for sets in (2, 64):
        ag, _ = make_oma_ag(cache_sets=sets, cache_ways=2, cache_line_size=4,
                            cache_miss_latency=30)
        init_gemm_memory(ag, A, B)
        cycles[sets] = simulate(ag, oma_gemm_unrolled(m, n, l)).cycles
    assert cycles[64] < cycles[2]


# ---------------------------------------------------------------------------
# storage timing models
# ---------------------------------------------------------------------------


def test_dram_row_buffer_model():
    d = DRAM(name="d", read_latency=4, t_RCD=8, t_RP=8, row_size=16,
             address_ranges=((0, 1 << 20),))
    first = d.access_latency("read", 0)         # bank idle: t_RCD + base
    hit = d.access_latency("read", 1)           # same row: base
    miss = d.access_latency("read", 1000)       # row switch: t_RP+t_RCD+base
    assert first == 12 and hit == 4 and miss == 20


def test_cache_lru():
    c = SetAssociativeCache(name="c", sets=2, ways=2, hit_latency=1,
                            miss_latency=10, cache_line_size=4)
    assert c.access_latency("read", 0) == 10     # cold miss
    assert c.access_latency("read", 1) == 1      # same line
    assert c.access_latency("read", 8) == 10     # same set, second way
    assert c.access_latency("read", 0) == 1      # still resident
    assert c.access_latency("read", 16) == 10    # evicts LRU (line 8)
    assert c.access_latency("read", 0) == 1
    assert c.access_latency("read", 8) == 10     # line 8 was evicted


def test_burst_cycles():
    s = SRAM(name="s", read_latency=2, port_width=8, address_ranges=((0, 10),))
    assert s.access_latency("read", 0, words=8) == 2
    assert s.access_latency("read", 0, words=64) == 2 + 7


# ---------------------------------------------------------------------------
# systolic array (paper §4.2) and Γ̈ (paper §4.3)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,l,rows,cols", [(2, 3, 2, 2, 2), (6, 7, 5, 4, 4)])
def test_systolic_gemm(m, k, l, rows, cols):
    rng = np.random.default_rng(1)
    A = rng.integers(-3, 4, (m, k)).astype(float)
    B = rng.integers(-3, 4, (k, l)).astype(float)
    ag, _ = make_systolic_ag(rows, cols)
    init_systolic_memory(ag, A, B)
    res = simulate(ag, systolic_gemm_program(m, k, l, rows, cols))
    assert np.array_equal(read_systolic_result(ag, m, l), A @ B)
    assert res.cycles > 0


def test_systolic_bigger_array_is_faster():
    A = np.ones((8, 8)); B = np.ones((8, 8))
    cycles = {}
    for r in (2, 4):
        ag, _ = make_systolic_ag(r, r)
        init_systolic_memory(ag, A, B)
        cycles[r] = simulate(ag, systolic_gemm_program(8, 8, 8, r, r)).cycles
    assert cycles[4] < cycles[2]


@pytest.mark.parametrize("nu", [1, 2, 4])
def test_gamma_gemm_units_scale(nu):
    A = np.ones((32, 32), np.float32); B = np.ones((32, 32), np.float32)
    ag, _ = make_gamma_ag(n_units=nu)
    init_gemm_memory(ag, A, B, memory="dram0", tile=8)
    units = tuple((f"lsu{k}", f"matMulFu{k}", f"vrf{k}") for k in range(nu))
    res = simulate(ag, gamma_gemm(32, 32, 32, tile=8, units=units))
    C = read_gemm_result(ag, 32, 32, c_base=0x100000, memory="dram0", tile=8)
    assert np.array_equal(C, A @ B)


def test_gamma_relu_activation():
    rng = np.random.default_rng(2)
    A = rng.normal(size=(8, 8)).astype(np.float32)
    B = rng.normal(size=(8, 8)).astype(np.float32)
    ag, _ = make_gamma_ag(n_units=1)
    init_gemm_memory(ag, A, B, memory="dram0", tile=8)
    simulate(ag, gamma_gemm(8, 8, 8, tile=8, activation=1))
    C = read_gemm_result(ag, 8, 8, c_base=0x100000, memory="dram0", tile=8)
    assert np.allclose(C, np.maximum(A @ B, 0), atol=1e-5)


def test_gamma_scratchpad_store_listing4():
    """Paper Listing 4: gemm result stored to the scratchpad."""
    A = np.ones((8, 8), np.float32); B = np.ones((8, 8), np.float32)
    ag, _ = make_gamma_ag(n_units=1)
    init_gemm_memory(ag, A, B, memory="dram0", tile=8)
    simulate(ag, gamma_gemm(8, 8, 8, tile=8, c_base=0x3000))
    spm = ag.by_name["spm0"]
    assert np.array_equal(spm.read(0x3000), A @ B)


# ---------------------------------------------------------------------------
# Eyeriss-derived (row-stationary conv) and Plasticine-derived (patterns)
# ---------------------------------------------------------------------------


def test_eyeriss_row_stationary_conv():
    from repro.core.archs import make_eyeriss_ag
    from repro.core.mapping.conv import (eyeriss_conv2d, init_conv_memory,
                                         read_conv_result)
    rng = np.random.default_rng(0)
    ifm = rng.integers(-3, 4, (10, 12)).astype(float)
    flt = rng.integers(-2, 3, (3, 3)).astype(float)
    ag, _ = make_eyeriss_ag(rows=4, columns=4)
    init_conv_memory(ag, ifm, flt)
    res = simulate(ag, eyeriss_conv2d(10, 12, 3, 3, 4, 4))
    out = read_conv_result(ag, 8)
    ref = np.zeros((8, 10))
    for i in range(8):
        for j in range(10):
            ref[i, j] = np.sum(ifm[i:i + 3, j:j + 3] * flt)
    assert np.allclose(out, ref)
    assert res.cycles > 0


def test_plasticine_map_reduce_scales():
    from repro.core.archs import make_plasticine_ag
    from repro.core.mapping.patterns import (init_vector_memory,
                                             plasticine_map_reduce,
                                             read_scalar)
    x = np.random.default_rng(1).normal(size=(1024,))
    cycles = {}
    for n in (2, 4):
        ag, _ = make_plasticine_ag(n_pcu=n, n_pmu=n)
        init_vector_memory(ag, x, n)
        res = simulate(ag, plasticine_map_reduce(1024, n, n))
        assert np.isclose(read_scalar(ag, n), (x * x).sum())
        cycles[n] = res.cycles
    assert cycles[4] < cycles[2]     # more PCUs -> faster
