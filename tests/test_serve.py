"""DSE-as-a-service (``repro.serve``): served answers are byte-equal to
direct Explorer sweeps, deterministic under concurrency and arbitrary
micro-batch composition, cache counters transition correctly, and the
device-sharded evaluator is bitwise-exact — in-process and under a
forced 8-host-device subprocess."""

import os
import pathlib
import subprocess
import sys
import threading
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

from repro.core.aidg.explorer import (Explorer, default_scenarios,
                                      pareto_front, random_candidates,
                                      resolve_cells)
from repro.serve import Answer, Design, DSEService, Query

ROOT = pathlib.Path(__file__).resolve().parent.parent

# a 4-cell corner of the default matrix: two archs sharing a workload
# (subset queries bite), one non-gemm workload, one multi-workload arch
SUBSET = {("oma", "gemm"), ("systolic", "gemm"), ("gamma", "attention"),
          ("tpu_v5e", "gemm")}


@pytest.fixture(scope="module")
def ex():
    scs = [s for s in default_scenarios()
           if (s.arch, s.workload) in SUBSET]
    assert len(scs) == len(SUBSET)
    return Explorer(scenarios=scs)


@pytest.fixture()
def svc(ex):
    s = DSEService(ex, pool=8, seed=1, max_batch=4, window_s=0.01)
    yield s
    s.close()


def mixed_stream(n=12):
    """A deterministic mixed client stream over the SUBSET matrix."""
    base = [Query.make(workload="gemm"),
            Query.make(workload="gemm", top_k=2),
            Query.make(workload="attention"),
            Query.make(workload="gemm", archs=["oma", "systolic"]),
            Query.make(archs=["gamma"]),
            Query.make(workload="gemm", overrides={"matrix": 2.0})]
    return [base[i % len(base)] for i in range(n)]


# -- byte-equality vs a direct Explorer sweep -------------------------------

def direct_answer(service, q):
    """The oracle: re-derive the answer from a DIRECT Explorer sweep of
    the same candidate block — no service, no batching, no cache —
    mirroring the documented ranking pipeline independently."""
    ex = service.explorer
    cand = service.pool.copy()
    for name, val in q.overrides:
        cand[:, ex.space.names.index(name)] = val
    cycles, energy_pj = ex.evaluate_full(cand)
    cols = np.asarray(resolve_cells(ex.compiled, workload=q.workload,
                                    archs=q.archs))
    names = tuple(ex.compiled[i].name for i in cols)
    rel = cycles[:, cols] / ex.baselines[None, cols]
    latency = rel.mean(axis=1)
    energy = (energy_pj[:, cols]
              / ex.energy_baselines[None, cols]).mean(axis=1)
    cost = ex.cost_proxy(cand)
    top = pareto_front(np.stack([latency, energy, cost],
                                axis=1))[: q.top_k]
    designs = tuple(
        Design(theta=tuple(float(v) for v in cand[i]),
               latency=float(latency[i]), energy=float(energy[i]),
               cost=float(cost[i]),
               cycles=tuple(float(c) for c in cycles[i, cols]))
        for i in top)
    lead = int(top[0]) if len(top) else int(np.argmin(latency))
    best_arch = ex.compiled[int(cols[int(np.argmin(rel[lead]))])].arch
    return Answer(query=q, cells=names, designs=designs,
                  best_arch=best_arch)


def test_served_equals_direct_sweep(svc):
    for q in {q.key: q for q in mixed_stream()}.values():
        assert svc.query(q) == direct_answer(svc, q)


def test_answer_shape(svc):
    a = svc.query(workload="gemm", archs=["oma"], top_k=2)
    assert a.cells == ("oma/gemm",)
    assert a.best_arch == "oma"
    assert 1 <= len(a.designs) <= 2
    assert a.best is a.designs[0]
    d = a.best
    assert len(d.theta) == svc.space.n and len(d.cycles) == len(a.cells)
    assert d.knobs(svc.space.names)["matrix"] == d.theta[
        svc.space.names.index("matrix")]


def test_energy_surfaced_in_answers_and_stats(svc):
    a = svc.query(workload="gemm")
    assert all(d.energy > 0.0 for d in a.designs)
    # row 0 of the pool is θ = 1, the reference machine: its energy is
    # exactly the baseline, so SOME ranked design sits at/above 1.0 only
    # if θ = 1 survived the front — but every design's energy is finite
    assert all(np.isfinite(d.energy) for d in a.designs)
    st = svc.stats()
    assert st["objectives"] == ("latency", "energy", "cost")
    base = st["energy_baseline_pj"]
    assert set(base) == {cs.name for cs in svc.explorer.compiled}
    assert all(v > 0.0 for v in base.values())


# -- determinism under concurrency ------------------------------------------

def test_threaded_equals_sequential_replay(ex):
    stream = mixed_stream(18)
    svc = DSEService(ex, pool=8, seed=1, max_batch=3, window_s=0.002)
    try:
        with ThreadPoolExecutor(max_workers=6) as tp:
            threaded = list(tp.map(svc.query, stream))
    finally:
        svc.close()
    ref = DSEService(ex, pool=8, seed=1, max_batch=3)
    try:
        replay = ref.query_many(stream)
    finally:
        ref.close()
    assert threaded == replay


def test_answers_invariant_to_batch_composition(ex):
    """The same query answered through windows of 1, through a full
    window, and coalesced with strangers — all byte-equal."""
    q = Query.make(workload="gemm", top_k=3)
    got = []
    for max_batch, stream in [(1, [q]),
                              (4, [q] * 4),
                              (4, mixed_stream(7) + [q])]:
        s = DSEService(ex, pool=8, seed=1, max_batch=max_batch)
        try:
            got.append(s.query_many(stream)[-1])
        finally:
            s.close()
    assert got[0] == got[1] == got[2]


# -- micro-batch window boundaries ------------------------------------------

@pytest.mark.parametrize("m,expected", [(1, [1]), (4, [4]), (6, [4, 2])])
def test_window_boundaries(ex, m, expected):
    """Staged windows split exactly like ``plan_batches``: 1 query, a
    full window (k = max_batch), and an overflowing one (> k)."""
    svc = DSEService(ex, pool=8, seed=1, max_batch=4, window_s=0.005)
    try:
        with svc.batcher.hold():
            futs = [svc.submit(workload="gemm", top_k=i + 1)
                    for i in range(m)]
        answers = [f.result(timeout=60.0) for f in futs]
        assert [len(w) for w in svc.window_log] == expected
        assert [len(b) for b in svc.batcher.dispatch_log] == expected
        # arrival order survives batching: answer i is for top_k = i+1
        assert [len(a.designs) <= i + 1 for i, a in enumerate(answers)]
        assert [a.query.top_k for a in answers] == list(range(1, m + 1))
    finally:
        svc.close()


# -- cache counters ----------------------------------------------------------

def test_cache_counter_transitions(svc):
    q = Query.make(workload="attention")
    a1 = svc.query(q)
    assert svc.cache_stats == {"hits": 0, "misses": 1, "coalesced": 0}
    assert a1.cached is False

    a2 = svc.query(q)
    assert svc.cache_stats == {"hits": 1, "misses": 1, "coalesced": 0}
    assert a2.cached is True
    assert a1 == a2                    # cached flag excluded from equality

    # two identical queries in ONE held window: 1 miss + 1 coalesced
    with svc.batcher.hold():
        f1 = svc.submit(workload="gemm")
        f2 = svc.submit(workload="gemm")
    r1, r2 = f1.result(60.0), f2.result(60.0)
    assert svc.cache_stats == {"hits": 1, "misses": 2, "coalesced": 1}
    assert r1 == r2
    # the window evaluated the key once
    assert svc.evaluated_log[-1] == [Query.make(workload="gemm").key]

    st = svc.stats()
    assert st["hit_ratio"] == pytest.approx(2 / 4)
    assert st["device_dispatches"] == 2 and st["windows"] == 3


def test_cached_answers_skip_the_device(svc):
    q = Query.make(workload="gemm", top_k=2)
    svc.query(q)
    before = svc.dispatched_candidates
    assert before == svc.pool.shape[0]
    for _ in range(3):
        assert svc.query(q).cached is True
    assert svc.dispatched_candidates == before


# -- validation fails fast, in the caller -----------------------------------

def test_bad_queries_fail_fast(svc):
    with pytest.raises(KeyError, match="workload"):
        svc.query(workload="nope")
    with pytest.raises(KeyError, match="arch"):
        svc.query(archs=["nope"])
    with pytest.raises(KeyError, match="knob"):
        svc.query(workload="gemm", overrides={"bogus": 1.0})
    with pytest.raises(ValueError, match="outside"):
        svc.query(workload="gemm", overrides={"matrix": 1e9})
    with pytest.raises(ValueError, match="top_k"):
        Query.make(top_k=0)
    # a poisoned window would have broken the NEXT query — it doesn't
    assert svc.query(workload="gemm").best_arch in {"oma", "systolic",
                                                    "gamma", "tpu_v5e"}


def test_query_canonicalization():
    a = Query.make(workload="gemm", archs=["b", "a"],
                   overrides={"y": 2.0, "x": 1.0})
    b = Query.make(workload="gemm", archs=("a", "b"),
                   overrides=[("x", 1.0), ("y", 2.0)])
    assert a == b and a.key == b.key and hash(a) == hash(b)
    assert Query.make(archs="oma").archs == ("oma",)
    assert a.override_map == {"x": 1.0, "y": 2.0}


# -- shutdown: no leaked worker threads ---------------------------------------

def test_close_is_idempotent(ex):
    svc = DSEService(ex, pool=8, seed=1)
    svc.query(workload="gemm")
    svc.close()
    svc.close()                 # second close is a no-op, not a hang/raise
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit(workload="gemm")


def test_close_during_hold_flushes_pending(ex):
    """Regression: closing while a ``hold()`` was open used to deadlock
    the worker (it waited for the hold to lift with items still pending).
    A close now overrides the hold: pending futures resolve and the
    worker joins."""
    svc = DSEService(ex, pool=8, seed=1)
    with svc.batcher.hold():
        fut = svc.submit(workload="gemm")
        svc.batcher.close(timeout=60.0)
    assert fut.result(timeout=60.0).best_arch
    assert not svc.batcher._worker.is_alive()


def test_unclosed_service_leaves_no_joinable_threads(ex):
    """Regression: a DSEService used WITHOUT close()/``with`` must not
    leak anything interpreter shutdown can trip over — the worker is a
    daemon (never blocks exit) AND registered in the atexit close set,
    so shutdown flushes and joins it instead of racing its exceptions."""
    from repro.serve import batcher as batcher_mod

    svc = DSEService(ex, pool=8, seed=1)
    svc.query(workload="gemm")
    # no non-daemon "microbatcher" thread exists anywhere in the process
    assert not any(t.name == "microbatcher" and not t.daemon
                   for t in threading.enumerate())
    assert svc.batcher._worker.daemon
    # the atexit hook knows this batcher and closing it joins the worker
    assert svc.batcher in batcher_mod._LIVE
    batcher_mod._close_all()
    svc.batcher._worker.join(timeout=30.0)
    assert not svc.batcher._worker.is_alive()
    assert svc.batcher not in batcher_mod._LIVE


# -- sharded evaluation -------------------------------------------------------

def test_sharded_exact_in_process(ex):
    """θ = 1 and random batches: the sharded path is bitwise-equal to
    single-device under whatever device count this process has
    (typically 1 — the 8-device case runs in the subprocess test)."""
    pm = ex.packed_matrix()
    theta1 = np.ones((1, ex.space.n), np.float32)
    assert np.array_equal(ex.evaluate(theta1, sharded=True),
                          ex.evaluate(theta1))
    cand = random_candidates(ex.space, 8, seed=3)
    assert np.array_equal(pm.evaluate(cand, sharded=True),
                          pm.evaluate(cand))
    assert np.array_equal(ex.evaluate(cand, sharded=True, chunk=3),
                          ex.evaluate(cand))


def test_sharded_service_matches_unsharded(ex):
    plain = DSEService(ex, pool=8, seed=1)
    shard = DSEService(ex, pool=8, seed=1, sharded=True)
    try:
        q = Query.make(workload="gemm")
        assert plain.query(q) == shard.query(q)
    finally:
        plain.close()
        shard.close()


def test_sharded_device_count_validation(ex):
    import jax

    pm = ex.packed_matrix()
    avail = jax.local_device_count()
    assert pm.n_shards(None) == avail
    with pytest.raises(ValueError, match="n_devices"):
        pm.n_shards(0)
    with pytest.raises(ValueError, match="n_devices"):
        pm.n_shards(avail + 1)


SHARD_SCRIPT = r"""
import numpy as np, jax
assert jax.local_device_count() == 8, jax.local_device_count()
from repro.core.aidg.explorer import (Explorer, default_scenarios,
                                      random_candidates)
scs = [s for s in default_scenarios()
       if (s.arch, s.workload) in {("oma", "gemm"), ("gamma", "attention")}]
ex = Explorer(scenarios=scs)
pm = ex.packed_matrix()
assert pm.n_shards(None) == 8
for B in (16, 13):      # a device multiple AND a padded remainder
    cand = random_candidates(ex.space, B, seed=0)
    a, b = pm.evaluate(cand), pm.evaluate(cand, sharded=True)
    assert a.shape == b.shape == (B, pm.n_cells), (a.shape, b.shape)
    assert np.array_equal(a, b), np.abs(a - b).max()
print("SHARDED-EXACT")
"""


def test_sharded_exact_on_eight_forced_devices():
    """θ-batches on a forced 8-host-device mesh agree bitwise with the
    single-device path (the flag only applies at jax init, hence the
    subprocess)."""
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    env["XLA_FLAGS"] = ((flags + " ") if flags else "") + \
        "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(ROOT / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run([sys.executable, "-c", SHARD_SCRIPT],
                          env=env, capture_output=True, text=True,
                          timeout=900)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "SHARDED-EXACT" in proc.stdout
