"""MoE dispatch and Mamba scan semantics."""

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import MoEConfig, SSMConfig
from repro.models.mamba import (init_mamba, init_mamba_cache, mamba_block)
from repro.models.moe import init_moe, moe_block


def _moe_cfg(**kw):
    base = dict(n_experts=8, top_k=2, n_shared_experts=0, d_expert=32,
                capacity_factor=8.0, every=1)
    base.update(kw)
    return MoEConfig(**base)


def test_moe_matches_dense_reference():
    """With ample capacity, the gather/scatter dispatch equals the
    brute-force 'run every expert on every token' reference."""
    cfg = _moe_cfg()
    d = 16
    params = init_moe(jax.random.key(0), cfg, d, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 8, d))
    out, aux = moe_block(params, x, cfg, group=16)

    # reference: explicit top-k mixture
    logits = x.reshape(-1, d) @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    gates, ids = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    flat = x.reshape(-1, d)
    expert_out = []
    for e in range(cfg.n_experts):
        h = jax.nn.silu(flat @ params["w_gate"][e]) * (flat @ params["w_up"][e])
        expert_out.append(h @ params["w_down"][e])
    expert_out = jnp.stack(expert_out, 1)            # (T, E, d)
    want = jnp.zeros_like(flat)
    for s in range(cfg.top_k):
        want = want + gates[:, s:s+1] * jnp.take_along_axis(
            expert_out, ids[:, s][:, None, None], 1)[:, 0]
    np.testing.assert_allclose(np.asarray(out.reshape(-1, d)),
                               np.asarray(want), atol=1e-5)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    """With capacity factor << 1 some tokens must be dropped (output norm
    strictly smaller than with ample capacity)."""
    d = 16
    x = jax.random.normal(jax.random.key(1), (2, 32, d))
    big = _moe_cfg(capacity_factor=8.0)
    small = _moe_cfg(capacity_factor=0.25)
    params = init_moe(jax.random.key(0), big, d, jnp.float32)
    out_big, _ = moe_block(params, x, big, group=64)
    out_small, _ = moe_block(params, x, small, group=64)
    assert float(jnp.linalg.norm(out_small)) < float(jnp.linalg.norm(out_big))


def test_moe_shared_experts():
    cfg = _moe_cfg(n_shared_experts=2)
    d = 16
    params = init_moe(jax.random.key(0), cfg, d, jnp.float32)
    assert "shared" in params
    x = jax.random.normal(jax.random.key(1), (1, 8, d))
    out, _ = moe_block(params, x, cfg, group=8)
    assert out.shape == x.shape


# ---------------------------------------------------------------------------
# mamba
# ---------------------------------------------------------------------------


def test_mamba_chunked_equals_unchunked():
    """Chunked two-level scan == single-chunk scan (same math)."""
    cfg_small = SSMConfig(d_state=4, d_conv=4, expand=2, chunk=4)
    cfg_big = SSMConfig(d_state=4, d_conv=4, expand=2, chunk=64)
    d = 8
    params = init_mamba(jax.random.key(0), cfg_small, d, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 16, d)) * 0.5
    out_small, _ = mamba_block(params, x, cfg_small)
    out_big, _ = mamba_block(params, x, cfg_big)
    np.testing.assert_allclose(np.asarray(out_small), np.asarray(out_big),
                               atol=1e-5)


def test_mamba_decode_matches_prefill():
    """Step-by-step cached decode == full-sequence scan."""
    cfg = SSMConfig(d_state=4, d_conv=4, expand=2, chunk=8)
    d = 8
    params = init_mamba(jax.random.key(0), cfg, d, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 8, d)) * 0.5
    full, _ = mamba_block(params, x, cfg)

    cache = init_mamba_cache(cfg, d, 2, jnp.float32)
    outs = []
    for t in range(8):
        y, cache = mamba_block(params, x[:, t:t + 1], cfg, cache=cache)
        outs.append(y)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step), atol=1e-4)


def test_mamba_prefill_state_continues_decode():
    """prefill(x[:6]) then decode steps 6,7 == full scan."""
    cfg = SSMConfig(d_state=4, d_conv=4, expand=2, chunk=4)
    d = 8
    params = init_mamba(jax.random.key(0), cfg, d, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 8, d)) * 0.5
    full, _ = mamba_block(params, x, cfg)
    cache = init_mamba_cache(cfg, d, 1, jnp.float32)
    _, cache = mamba_block(params, x[:, :6], cfg, cache=cache)
    y6, cache = mamba_block(params, x[:, 6:7], cfg, cache=cache)
    y7, cache = mamba_block(params, x[:, 7:8], cfg, cache=cache)
    np.testing.assert_allclose(np.asarray(full[:, 6]), np.asarray(y6[:, 0]),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(full[:, 7]), np.asarray(y7[:, 0]),
                               atol=1e-4)


def test_mamba_ragged_padding_state_correct():
    """Padded tail (s % chunk != 0) must not perturb the carried state."""
    cfg = SSMConfig(d_state=4, d_conv=4, expand=2, chunk=8)
    d = 8
    params = init_mamba(jax.random.key(0), cfg, d, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 11, d)) * 0.5  # 11 % 8 != 0
    cache = init_mamba_cache(cfg, d, 1, jnp.float32)
    _, cache_ragged = mamba_block(params, x, cfg, cache=cache)
    # reference: step-by-step
    cache2 = init_mamba_cache(cfg, d, 1, jnp.float32)
    for t in range(11):
        _, cache2 = mamba_block(params, x[:, t:t + 1], cfg, cache=cache2)
    np.testing.assert_allclose(np.asarray(cache_ragged["h"]),
                               np.asarray(cache2["h"]), atol=1e-4)
