"""Substrate: data pipeline, optimizer, compression, checkpoints, runtime
monitors — the fault-tolerance story end-to-end."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager, latest_checkpoint, load_pytree, \
    save_pytree
from repro.data import DataConfig, TokenPipeline, memmap_source, \
    synthetic_source
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         compress_bf16, compress_int8, decompress_int8,
                         error_feedback_update, linear_warmup_cosine)
from repro.runtime import FailureInjector, Metrics, StragglerMonitor


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_data_deterministic_and_resumable():
    cfg = DataConfig(seq_len=16, global_batch=4, vocab_size=100, seed=7)
    src = synthetic_source(cfg)
    a, b = src(3), src(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(src(3)["tokens"], src(4)["tokens"])
    # labels are next-token shifted
    full = src(0)
    pipe = TokenPipeline(cfg, src, start_step=5)
    first = next(pipe)
    np.testing.assert_array_equal(first["tokens"], src(5)["tokens"])
    assert pipe.state()["step"] == 6
    pipe.close()


def test_data_host_sharding_differs():
    a = synthetic_source(DataConfig(16, 8, 100, host_id=0, n_hosts=2))(0)
    b = synthetic_source(DataConfig(16, 8, 100, host_id=1, n_hosts=2))(0)
    assert a["tokens"].shape == (4, 16)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_memmap_source(tmp_path):
    toks = np.arange(1000, dtype=np.uint16)
    path = tmp_path / "tokens.bin"
    toks.tofile(path)
    cfg = DataConfig(seq_len=9, global_batch=2, vocab_size=50000)
    src = memmap_source(cfg, path)
    b0 = src(0)
    assert b0["tokens"].shape == (2, 9)
    np.testing.assert_array_equal(b0["labels"][:, :-1], b0["tokens"][:, 1:])


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_optimizes_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, clip_norm=0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, m = adamw_update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 1e-2
    assert int(state["step"]) == 200


def test_adamw_clipping_bounds_update():
    cfg = AdamWConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(3)}
    state = adamw_init(params)
    _, _, m = adamw_update(cfg, params, {"w": jnp.full(3, 1e6)}, state)
    assert float(m["grad_norm"]) > 1e5  # reported pre-clip


def test_schedule_warmup_then_decay():
    f = linear_warmup_cosine(10, 100)
    assert float(f(jnp.asarray(0))) < 0.11
    assert abs(float(f(jnp.asarray(10))) - 1.0) < 0.01
    assert float(f(jnp.asarray(95))) < 0.5


def test_int8_error_feedback_converges():
    """EF residual keeps the long-run quantization bias near zero."""
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(256,)), jnp.float32) * 1e-3
    resid = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    for _ in range(64):
        q, s, resid = error_feedback_update(g, resid)
        acc = acc + decompress_int8(q, s)
    np.testing.assert_allclose(np.asarray(acc / 64), np.asarray(g),
                               atol=float(jnp.abs(g).max()) * 0.05)


def test_bf16_stochastic_rounding_unbiased():
    x = {"g": jnp.full((20000,), 1.0 + 2 ** -10, jnp.float32)}  # between bf16 grid points
    total = np.zeros((20000,), np.float64)
    for i in range(8):
        q = compress_bf16(x, jax.random.key(i))
        total += np.asarray(q["g"], np.float64)
    mean = total.mean() / 8
    assert abs(mean - (1.0 + 2 ** -10)) < 2e-4  # unbiased to ~1e-4


# ---------------------------------------------------------------------------
# checkpoints
# ---------------------------------------------------------------------------


def _tree(seed=0):
    k = jax.random.key(seed)
    return {"a": jax.random.normal(k, (4, 5)),
            "b": {"c": jnp.arange(7, dtype=jnp.int32)}}


def test_ckpt_roundtrip(tmp_path):
    t = _tree()
    save_pytree(t, tmp_path, 10, extra={"data_step": 10})
    path = latest_checkpoint(tmp_path)
    assert path is not None and path.name == "step_000000010"
    back = load_pytree(path, jax.eval_shape(lambda: t))
    np.testing.assert_allclose(np.asarray(t["a"]), np.asarray(back["a"]))
    np.testing.assert_array_equal(np.asarray(t["b"]["c"]),
                                  np.asarray(back["b"]["c"]))


def test_ckpt_uncommitted_ignored(tmp_path):
    save_pytree(_tree(), tmp_path, 5)
    # fake a torn checkpoint at a later step (no COMMIT)
    bad = tmp_path / "step_000000009"
    bad.mkdir()
    (bad / "manifest.json").write_text("{}")
    assert latest_checkpoint(tmp_path).name == "step_000000005"


def test_ckpt_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, every_steps=1, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(_tree(), s)
    names = sorted(p.name for p in tmp_path.iterdir())
    assert names == ["step_000000003", "step_000000004"]


def test_ckpt_elastic_dtype_cast(tmp_path):
    t = {"w": jnp.ones((8,), jnp.float32)}
    save_pytree(t, tmp_path, 1)
    like = {"w": jax.ShapeDtypeStruct((8,), jnp.bfloat16)}
    back = load_pytree(latest_checkpoint(tmp_path), like)
    assert back["w"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# runtime
# ---------------------------------------------------------------------------


def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor(window=20, k=5.0, warmup=5)
    for _ in range(10):
        assert not mon.observe(0.10 + np.random.default_rng(0).uniform(0, 1e-3))
    assert mon.observe(1.0)       # 10x median -> flagged
    assert not mon.observe(0.10)


def test_failure_injector():
    inj = FailureInjector(fail_at_step=3)
    inj.check(2)
    with pytest.raises(RuntimeError):
        inj.check(3)
    inj.check(3)  # fires once


def test_metrics_csv():
    m = Metrics()
    m.log(0, loss=1.5)
    m.log(1, loss=1.25)
    csv = m.to_csv()
    assert csv.splitlines()[0] == "step,loss"
    assert "1.25" in csv
