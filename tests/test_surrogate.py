"""The surrogate tier (``repro.surrogate``) unit tests, on a cheap 2-cell
explorer: the sweep-table export, fixed-seed training determinism,
save/load round-trips, the θ = 1 anchor and confidence API, and the
service integration (routing, per-tier stats, threaded == replay, and the
mismatched-bundle fail-fast).  Accuracy against the full matrix is the
oracle-chain tier's job (tests/test_oracle_chain.py)."""

from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

from repro.core.aidg.explorer import (Explorer, default_scenarios,
                                      random_candidates)
from repro.serve import DSEService, Query
from repro.surrogate import (SurrogateBundle, SurrogateConfig,
                             evaluate_surrogate, train_surrogate)

# reduced budget: these tests exercise mechanics, not accuracy bars
CFG = SurrogateConfig(n_samples=64, steps=400)


@pytest.fixture(scope="module")
def ex2():
    """oma/gemm + systolic/gemm — two cells sharing a workload, so both
    full-matrix and arch-subset queries resolve non-trivially."""
    return Explorer(scenarios=default_scenarios()[:2])


@pytest.fixture(scope="module")
def bundle(ex2):
    return train_surrogate(ex2, CFG)


# -- the sweep-table export ---------------------------------------------------

def test_export_training_table_shapes_and_baselines(ex2):
    pm = ex2.packed_matrix()
    kt = random_candidates(ex2.space, 5, seed=3, include_baseline=False)
    table = pm.export_training_table(kt)
    S = len(ex2.compiled)
    assert table["theta"].shape == (5, ex2.space.n)
    assert table["cycles"].shape == table["energy"].shape == (5, S)
    assert np.all(table["cycles"] > 0) and np.all(table["energy"] > 0)
    # the prepended θ = 1 row IS the baseline, from the same dispatch
    c1, e1 = ex2.evaluate_full(np.ones((1, ex2.space.n), np.float32))
    assert np.array_equal(table["cycles_base"], c1[0].astype(np.float64))
    assert np.array_equal(table["energy_base"], e1[0].astype(np.float64))


def test_export_chunked_matches_unchunked(ex2):
    pm = ex2.packed_matrix()
    kt = random_candidates(ex2.space, 7, seed=4, include_baseline=False)
    a = pm.export_training_table(kt)
    b = pm.export_training_table(kt, chunk=3)
    assert np.allclose(a["cycles"], b["cycles"], rtol=1e-6)
    assert np.allclose(a["energy"], b["energy"], rtol=1e-6)


# -- training, determinism, persistence ---------------------------------------

def test_training_is_deterministic(ex2, bundle):
    again = train_surrogate(ex2, CFG)
    for k in bundle.params:
        assert np.array_equal(np.asarray(bundle.params[k]),
                              np.asarray(again.params[k])), k
    assert np.array_equal(bundle.err_bound, again.err_bound)
    assert bundle.meta == again.meta


def test_bundle_metadata(ex2, bundle):
    assert bundle.cell_names == tuple(cs.name for cs in ex2.compiled)
    assert bundle.knob_names == tuple(ex2.space.names)
    assert bundle.n_cells == 2 and bundle.n_knobs == ex2.space.n
    assert bundle.meta["config"]["n_samples"] == CFG.n_samples
    assert bundle.meta["n_train"] + bundle.meta["n_holdout"] \
        == CFG.n_samples
    assert np.all(bundle.err_bound > 0.0)


def test_save_load_roundtrip(tmp_path, bundle):
    path = tmp_path / "bundle.npz"
    bundle.save(path)
    loaded = SurrogateBundle.load(path)
    assert loaded.cell_names == bundle.cell_names
    assert loaded.knob_names == bundle.knob_names
    assert loaded.meta == bundle.meta
    assert np.array_equal(loaded.err_bound, bundle.err_bound)
    kt = np.exp(np.random.default_rng(7).uniform(
        -1.0, 1.0, (6, bundle.n_knobs))).astype(np.float32)
    c0, e0 = bundle.predict_full(kt)
    c1, e1 = loaded.predict_full(kt)
    assert np.array_equal(c0, c1) and np.array_equal(e0, e1)


def test_predict_anchored_at_theta_one(ex2, bundle):
    """The θ = 1 row always trains, so the ratio prediction at θ = 1 sits
    within the cell's own stated bound of exactly 1.0."""
    lat, en = bundle.predict_rel(np.ones((1, bundle.n_knobs), np.float32))
    assert lat.shape == en.shape == (1, 2)
    assert np.all(np.abs(lat[0] - 1.0) <= bundle.err_bound)
    assert np.all(np.abs(en[0] - 1.0) <= bundle.err_bound)


def test_confident_api(bundle):
    assert bundle.confident(max_err=10.0)
    assert not bundle.confident(max_err=0.0)
    assert bundle.confident(cols=[0], max_err=float(bundle.err_bound[0]))
    assert not bundle.confident(cols=[], max_err=10.0)   # empty = never


def test_latency_monotone_on_grid(bundle):
    """Deterministic spot-check of the by-construction monotonicity (the
    hypothesis sweep lives in test_property.py): raising any single knob
    never lowers any cell's predicted latency ratio."""
    base = np.full((1, bundle.n_knobs), 0.7, np.float32)
    lat0, _ = bundle.predict_rel(base)
    for k in range(bundle.n_knobs):
        up = base.copy()
        up[0, k] = 2.5
        lat1, _ = bundle.predict_rel(up)
        assert np.all(lat1 >= lat0 - 1e-6), k


def test_evaluate_surrogate_report(ex2, bundle):
    rep = evaluate_surrogate(bundle, ex2, n=16, seed=5)
    assert rep["err_latency"].shape == rep["err_energy"].shape == (16, 2)
    assert rep["cells"] == list(bundle.cell_names)
    assert 0.0 <= rep["median_latency_err"] < 1.0
    assert rep["bound_coverage"].shape == (2,)


# -- service integration: the staged router -----------------------------------

def test_service_routes_to_surrogate_tier(ex2, bundle):
    with DSEService(ex2, pool=8, seed=1, surrogate=bundle,
                    surrogate_max_err=10.0) as svc:
        a = svc.query(workload="gemm")
        assert a.tier == "surrogate"
        assert 0.0 < a.err_bound <= 10.0
        assert a.cells == ("oma/gemm", "systolic/gemm")
        # the fast tier never touches the device-dispatch counters
        assert svc.dispatched_candidates == 0
        assert svc.evaluated_log == []
        st = svc.stats()
        assert st["surrogate_armed"] is True
        assert st["tiers"] == {"cache": 0, "surrogate": 1, "packed": 0,
                               "surrogate-degraded": 0, "failed": 0}
        assert st["fallback_rate"] == 0.0
        assert st["tier_time_s"]["surrogate"] > 0.0
        assert st["tier_us_per_query"]["surrogate"] > 0.0
        # a repeat is a cache hit that PRESERVES the tier label
        b = svc.query(workload="gemm")
        assert b.cached and b.tier == "surrogate" and b == a
        assert svc.stats()["tiers"]["cache"] == 1


def test_service_falls_back_when_bound_exceeded(ex2, bundle):
    with DSEService(ex2, pool=8, seed=1, surrogate=bundle,
                    surrogate_max_err=0.0) as svc:
        a = svc.query(workload="gemm")
        assert a.tier == "packed" and a.err_bound == 0.0
        assert svc.dispatched_candidates == 8
        st = svc.stats()
        assert st["tiers"] == {"cache": 0, "surrogate": 0, "packed": 1,
                               "surrogate-degraded": 0, "failed": 0}
        assert st["fallback_rate"] == 1.0


def test_service_without_surrogate_is_packed_only(ex2):
    with DSEService(ex2, pool=8, seed=1) as svc:
        a = svc.query(workload="gemm")
        assert a.tier == "packed"
        st = svc.stats()
        assert st["surrogate_armed"] is False
        assert st["fallback_rate"] == 1.0


def test_surrogate_answers_match_packed_structure(ex2, bundle):
    """Same query through both tiers: identical resolved cells, the same
    candidate pool behind every design, and latencies within a few
    stated bounds of each other (the chain tier owns the tight bars)."""
    q = Query.make(workload="gemm", top_k=3)
    with DSEService(ex2, pool=8, seed=1, surrogate=bundle,
                    surrogate_max_err=10.0) as fast:
        a_sur = fast.query(q)
    with DSEService(ex2, pool=8, seed=1) as slow:
        a_pkd = slow.query(q)
    assert a_sur.cells == a_pkd.cells
    pool_thetas = {tuple(np.float32(v) for v in row)
                   for row in random_candidates(ex2.space, 8, seed=1)}
    for d in a_sur.designs:
        assert tuple(np.float32(v) for v in d.theta) in pool_thetas
    tol = 5.0 * float(bundle.err_bound.max())
    assert a_sur.best.latency == pytest.approx(a_pkd.best.latency,
                                               rel=max(tol, 0.05))


def test_threaded_equals_replay_with_surrogate(ex2, bundle):
    stream = [Query.make(workload="gemm"),
              Query.make(workload="gemm", archs=["oma"]),
              Query.make(workload="gemm", top_k=2),
              Query.make(workload="gemm", overrides={"matrix": 2.0})] * 3
    svc = DSEService(ex2, pool=8, seed=1, surrogate=bundle,
                     surrogate_max_err=10.0, max_batch=3, window_s=0.002)
    try:
        with ThreadPoolExecutor(max_workers=4) as tp:
            threaded = list(tp.map(svc.query, stream))
    finally:
        svc.close()
    ref = DSEService(ex2, pool=8, seed=1, surrogate=bundle,
                     surrogate_max_err=10.0, max_batch=3)
    try:
        replay = ref.query_many(stream)
    finally:
        ref.close()
    assert threaded == replay
    assert all(a.tier == "surrogate" for a in replay if not a.cached)


def test_mismatched_bundle_fails_fast(bundle):
    ex3 = Explorer(scenarios=default_scenarios()[:3])
    with pytest.raises(ValueError, match="cells"):
        DSEService(ex3, pool=8, surrogate=bundle)
