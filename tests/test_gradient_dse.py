"""Gradient-based DSE over the smooth max-plus relaxation
(repro.core.aidg.{maxplus,dse,gradient}):

(a) soft -> hard agreement: the τ-tempered evaluator upper-bounds the hard
    wavefront result and converges to it as τ anneals, on every default
    scenario,
(b) the compiled knob-space gradient (`grad_sweep`) matches central finite
    differences per cell,
(c) end-to-end: `refine(method="grad")` from θ = 1 matches or beats the
    default coordinate-descent incumbent (latency·cost) on the full matrix
    while evaluating at most half as many candidates.
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core.aidg.dse import (evaluate_theta, evaluate_theta_soft,
                                 grad_sweep)
from repro.core.aidg.explorer import Explorer, default_scenarios
from repro.core.aidg.gradient import GradientExplorer
from repro.core.aidg.maxplus import (fixed_point_jax, fixed_point_soft,
                                     longest_path_soft,
                                     longest_path_wavefront, slot_queue_scan,
                                     slot_queue_soft, softmax_reduce,
                                     softmaximum)

SCENARIOS = default_scenarios()
IDS = [s.name for s in SCENARIOS]


@pytest.fixture(scope="module")
def explorer():
    return Explorer()


def _compiled(explorer, scenario):
    return next(c for c in explorer.compiled
                if c.scenario.key == scenario.key)


# ---------------------------------------------------------------------------
# (a) soft -> hard agreement under τ annealing
# ---------------------------------------------------------------------------


def test_softmaximum_and_reduce_limit():
    a, b = jnp.float32(3.0), jnp.float32(5.0)
    for tau in (1.0, 0.1, 0.01):
        s = float(softmaximum(a, b, tau))
        assert 5.0 <= s <= 5.0 + tau * np.log(2) + 1e-5, tau
    x = jnp.asarray([1.0, 4.0, 2.0, -1e18], jnp.float32)  # NEG-style pad
    for tau in (1.0, 0.1, 0.01):
        s = float(softmax_reduce(x, tau))
        assert 4.0 <= s <= 4.0 + tau * np.log(3) + 1e-5, tau


@pytest.mark.parametrize("slots", [1, 3])
def test_slot_queue_soft_matches_hard(slots):
    rng = np.random.default_rng(0)
    arrival = jnp.asarray(np.sort(rng.uniform(0, 50, 24)), jnp.float32)
    lat = jnp.asarray(rng.uniform(1, 9, 24), jnp.float32)
    hard = np.asarray(slot_queue_scan(arrival, lat, slots))
    prev_err = np.inf
    for tau in (1.0, 0.1, 0.01):
        soft = np.asarray(slot_queue_soft(arrival, lat, slots, tau))
        assert np.all(soft >= hard - 1e-3), (slots, tau)  # upper bound
        err = np.abs(soft - hard).max()
        assert err <= prev_err + 1e-4, (slots, tau)       # anneal improves
        prev_err = err
    assert prev_err < 0.25  # τ = 0.01: agree to a fraction of a cycle


@pytest.mark.parametrize("scenario", SCENARIOS, ids=IDS)
def test_soft_longest_path_anneals_to_wavefront(scenario, explorer):
    ca = _compiled(explorer, scenario).compiled_aidg
    hard = np.asarray(longest_path_wavefront(ca))
    prev_rel = np.inf
    for tau in (0.5, 0.1, 0.01):
        soft = np.asarray(longest_path_soft(ca, tau=tau))
        assert soft.max() >= hard.max() - 1e-2, tau       # upper bound
        rel = abs(soft.max() - hard.max()) / max(1.0, hard.max())
        assert rel <= prev_rel + 1e-6, tau                # anneal improves
        prev_rel = rel
    assert prev_rel < 2e-3, scenario.name


@pytest.mark.parametrize("scenario", SCENARIOS, ids=IDS)
def test_soft_fixed_point_anneals_to_hard(scenario, explorer):
    """The full τ-tempered evaluator (soft occupancy floor + soft wavefront
    + soft queueing + soft makespan) converges to the hard wavefront cycles
    on every default cell."""
    cs = _compiled(explorer, scenario)
    ones_op = jnp.ones((cs.problem.n_op,), jnp.float32)
    ones_st = jnp.ones((cs.problem.n_st,), jnp.float32)
    hard = float(evaluate_theta(cs.problem, ones_op, ones_st))
    soft = float(evaluate_theta_soft(cs.problem, ones_op, ones_st, tau=0.01))
    assert abs(soft - hard) / max(1.0, hard) < 5e-3, (soft, hard)


def test_fixed_point_soft_upper_bounds_hard(explorer):
    cs = explorer.compiled[2]  # gamma/gemm: multi-unit + storage queueing
    hard = np.asarray(fixed_point_jax(cs.compiled_aidg, n_iters=2))
    soft = np.asarray(fixed_point_soft(cs.compiled_aidg, tau=0.1, n_iters=2))
    assert np.all(soft >= hard - 1e-2)


# ---------------------------------------------------------------------------
# (b) jax.grad vs central finite differences, per cell
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scenario", SCENARIOS, ids=IDS)
def test_grad_matches_finite_differences(scenario, explorer):
    cs = _compiled(explorer, scenario)
    op_idx, st_idx = explorer.space.projection(cs.problem)
    fn = grad_sweep(cs.problem, op_idx, st_idx, n_iters=explorer.n_iters)
    K = explorer.space.n
    rng = np.random.default_rng(hash(scenario.name) % 2 ** 31)
    knobs = np.exp(rng.uniform(-0.5, 0.5, K)).astype(np.float32)
    # τ sets the curvature scale: too small and central differences are
    # biased across the softmax transitions; 0.2 keeps FD truncation well
    # under the 5% gate while the gradient itself is exact for the traced
    # float32 function
    tau = jnp.float32(0.2)
    _, g = fn(jnp.asarray(knobs)[None], tau)
    g = np.asarray(g[0], np.float64)
    eps = 1e-2
    for k in range(K):
        kp, km = knobs.copy(), knobs.copy()
        kp[k] += eps
        km[k] -= eps
        vp, _ = fn(jnp.asarray(kp)[None], tau)
        vm, _ = fn(jnp.asarray(km)[None], tau)
        fd = (float(vp[0]) - float(vm[0])) / (2 * eps)
        assert abs(fd - g[k]) <= 5e-2 * max(1.0, abs(fd)), \
            (scenario.name, explorer.space.names[k], fd, g[k])


def test_grad_sweep_is_cached(explorer):
    cs = explorer.compiled[0]
    proj = explorer.space.projection(cs.problem)
    assert grad_sweep(cs.problem, *proj) is grad_sweep(cs.problem, *proj)


def test_grad_zero_for_unmatched_knob(explorer):
    """A knob that matches nothing in a scenario (e.g. `matrix` on a cell
    with no matrix unit ops) must get exactly zero gradient there."""
    cs = _compiled(explorer, next(s for s in SCENARIOS
                                  if s.name == "plasticine/reduce"))
    op_idx, st_idx = explorer.space.projection(cs.problem)
    fn = grad_sweep(cs.problem, op_idx, st_idx, n_iters=explorer.n_iters)
    K = explorer.space.n
    _, g = fn(jnp.ones((1, K), jnp.float32), jnp.float32(0.1))
    g = np.asarray(g[0])
    matched = set(op_idx[op_idx < K]) | set(st_idx[st_idx < K])
    for k in range(K):
        if k not in matched:
            assert g[k] == 0.0, explorer.space.names[k]
    assert matched, "scenario matches no knobs — test is vacuous"


# ---------------------------------------------------------------------------
# (c) end-to-end: gradient refine vs the coordinate-descent incumbent
# ---------------------------------------------------------------------------


def test_gradient_refine_beats_coordinate_descent(explorer):
    """The acceptance gate: from θ = 1, batched multi-start projected Adam
    over the smooth relaxation reaches a latency·cost at least as good as
    the default coordinate-descent incumbent on the full default matrix,
    with at most half the candidate evaluations (46 vs 100)."""
    cd_theta = explorer.refine()          # default: rounds=2, points=9
    cd_evals = (9 + 1) * explorer.space.n * 2
    res = explorer.explore(cd_theta[None, :])
    cd_score = float(res.latency[0] * res.cost[0])

    ge = GradientExplorer(explorer)
    out = ge.refine()                     # default: starts=2, steps=22
    assert out.evaluations * 2 <= cd_evals, (out.evaluations, cd_evals)
    # "matches or beats": allow 0.1% for cross-platform float drift
    assert out.score <= cd_score * 1.001, (out.score, cd_score)
    # the incumbent respects the knob box
    lo = np.asarray([k.lo for k in explorer.space.knobs])
    hi = np.asarray([k.hi for k in explorer.space.knobs])
    assert np.all(out.theta >= lo - 1e-6) and np.all(out.theta <= hi + 1e-6)
    # the reported score is the hard evaluator's verdict, reproducible
    re = explorer.explore(out.theta[None, :])
    assert float(re.latency[0] * re.cost[0]) == pytest.approx(out.score,
                                                              rel=1e-6)


def test_refine_method_grad_api(explorer):
    """Explorer.refine(method='grad') returns an in-bounds knob vector and
    improves on θ = 1; unknown methods and stray kwargs are rejected."""
    theta = explorer.refine(method="grad", starts=1, steps=4, tau0=0.2)
    assert theta.shape == (explorer.space.n,)
    base = explorer.explore(np.ones((1, explorer.space.n), np.float32))
    ref = explorer.explore(theta[None, :])
    assert (ref.latency[0] * ref.cost[0]
            <= base.latency[0] * base.cost[0] + 1e-6)
    with pytest.raises(ValueError, match="method"):
        explorer.refine(method="newton")
    with pytest.raises(TypeError, match="coord"):
        explorer.refine(method="coord", steps=3)
    with pytest.raises(TypeError, match="starts/steps"):
        explorer.refine(method="grad", rounds=5)  # coord knob, not silently
    with pytest.raises(TypeError, match="starts/steps"):  # ignored
        explorer.refine(method="grad", points=20)
    with pytest.raises(ValueError, match="objective"):
        GradientExplorer(explorer, objective="area")


def test_gradient_refine_is_deterministic(explorer):
    ge = GradientExplorer(explorer)
    a = ge.refine(starts=2, steps=3, seed=5)
    b = ge.refine(starts=2, steps=3, seed=5)
    assert np.array_equal(a.theta, b.theta)
    assert a.score == b.score
    assert a.evaluations == b.evaluations == 2 * 3 + 2


def test_gradient_objective_latency_pushes_faster_hardware(explorer):
    """Pure-latency descent has no cost counterweight: every matched knob
    should move below 1 (faster hardware is always at least as fast)."""
    ge = GradientExplorer(explorer, objective="latency")
    out = ge.refine(starts=1, steps=6, lr=0.4, tau0=0.2, tau_min=0.05)
    base = explorer.explore(np.ones((1, explorer.space.n), np.float32))
    ref = explorer.explore(out.theta[None, :])
    assert ref.latency[0] <= base.latency[0]
    assert np.all(out.theta <= 1.0 + 1e-6)
