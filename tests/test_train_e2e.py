"""End-to-end training: loss goes down; crash + auto-resume reproduces the
uninterrupted run exactly (determinism contract of the data pipeline +
checkpoint manager)."""

import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.launch.train import train_loop


def quiet(*a, **k):
    pass


def test_train_loss_decreases(tmp_path):
    cfg = get_smoke_config("olmo_1b")
    _, metrics = train_loop(cfg, steps=30, batch=8, seq=64,
                            ckpt_dir=None, print_fn=quiet)
    losses = [r["loss"] for r in metrics.rows]
    assert losses[-1] < losses[0] - 0.3


def test_crash_resume_is_exact(tmp_path):
    """Run A: 16 steps uninterrupted.  Run B: crash at step 12 (after the
    step-8 checkpoint), restart, finish.  Final metrics must match."""
    cfg = get_smoke_config("olmo_1b")
    kw = dict(steps=16, batch=4, seq=32, ckpt_every=8, print_fn=quiet)

    _, m_a = train_loop(cfg, ckpt_dir=str(tmp_path / "a"), **kw)

    with pytest.raises(RuntimeError, match="injected failure"):
        train_loop(cfg, ckpt_dir=str(tmp_path / "b"), fail_at_step=12, **kw)
    _, m_b = train_loop(cfg, ckpt_dir=str(tmp_path / "b"), **kw)

    last_a = [r for r in m_a.rows if r["step"] == 15][0]
    last_b = [r for r in m_b.rows if r["step"] == 15][0]
    np.testing.assert_allclose(last_a["loss"], last_b["loss"], rtol=1e-5)


def test_moe_arch_trains(tmp_path):
    cfg = get_smoke_config("olmoe_1b_7b")
    _, metrics = train_loop(cfg, steps=16, batch=4, seq=32, ckpt_dir=None,
                            print_fn=quiet)
    losses = [r["loss"] for r in metrics.rows]
    assert losses[-1] < losses[0]


def test_ssm_arch_trains(tmp_path):
    cfg = get_smoke_config("falcon_mamba_7b")
    _, metrics = train_loop(cfg, steps=40, batch=4, seq=32, ckpt_dir=None,
                            lr=1e-3, print_fn=quiet)  # SSM needs warmup
    losses = [r["loss"] for r in metrics.rows]
    assert losses[-1] < losses[0] - 0.5
