"""The compiled AIDG engine (trace → AIDG → LevelSchedule → CompiledAIDG):

(a) evaluator equivalence on every ARCH_REGISTRY scenario cell —
    ``longest_path_wavefront == longest_path_scan == numpy longest_path``
    (exact) and ``fixed_point_jax(engine="wavefront")`` matches
    ``builder.longest_path_fixed_point``, including the θ-reweighted DSE
    path,
(b) the level schedule's invariants (predecessors strictly shallower,
    levels partition the nodes, level-major renumbering consistent),
(c) no silent accuracy loss on high-in-degree nodes: ``build_aidg`` widens
    the padded predecessor slots instead of dropping edges,
(d) the AIDG dataclass ships proper array defaults (no ``None`` sentinels),
(e) the blocked engine is device-resident and runs the Pallas max-plus
    kernel on the AIDG path.
"""

import warnings

import numpy as np
import pytest

from repro.core.aidg import builder as builder_mod
from repro.core.aidg.builder import (AIDG, compile_aidg,
                                     compute_level_schedule, longest_path,
                                     longest_path_fixed_point)
from repro.core.aidg.dse import compiled_sweep, make_problem, sweep
from repro.core.aidg.explorer import (Explorer, compile_scenario,
                                      default_scenarios)
from repro.core.aidg.maxplus import (ENGINES, fixed_point_jax,
                                     longest_path_blocked, longest_path_scan,
                                     longest_path_wavefront, slot_queue_scan)

SCENARIOS = default_scenarios()
IDS = [s.name for s in SCENARIOS]


# ---------------------------------------------------------------------------
# (a) evaluator equivalence, cell by cell
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scenario", SCENARIOS, ids=IDS)
def test_wavefront_and_scan_match_numpy_exactly(scenario):
    aidg = compile_scenario(scenario).aidg
    t_np = longest_path(aidg)
    t_wf = np.asarray(longest_path_wavefront(aidg), np.float64)
    t_sc = np.asarray(longest_path_scan(aidg), np.float64)
    assert np.array_equal(t_np, t_wf), scenario.name
    assert np.array_equal(t_np, t_sc), scenario.name


@pytest.mark.parametrize("scenario", SCENARIOS, ids=IDS)
def test_fixed_point_wavefront_matches_numpy_fixed_point(scenario):
    aidg = compile_scenario(scenario).aidg
    fp_np = longest_path_fixed_point(aidg)
    fp_wf = np.asarray(fixed_point_jax(aidg, engine="wavefront"))
    # same tolerance as the seed's scan-vs-numpy fixed-point check
    assert abs(fp_np.max() - fp_wf.max()) < 1.0, scenario.name


@pytest.mark.parametrize("scenario", SCENARIOS, ids=IDS)
def test_theta_reweighted_engines_agree(scenario):
    """The θ-reweighted DSE path gives the same cycles per engine."""
    prob = compile_scenario(scenario).problem
    rng = np.random.default_rng(11)
    B = 4
    to = rng.uniform(0.5, 2.0, (B, prob.n_op)).astype(np.float32)
    ts = rng.uniform(0.5, 2.0, (B, prob.n_st)).astype(np.float32)
    out_wf = sweep(prob, to, ts, engine="wavefront")
    out_sc = sweep(prob, to, ts, engine="scan")
    assert np.allclose(out_wf, out_sc, atol=0.5), scenario.name


def test_wavefront_is_default_engine():
    """``fixed_point_jax``/``compiled_sweep`` default to the wavefront."""
    from repro.core.aidg.maxplus import DEFAULT_ENGINE
    assert DEFAULT_ENGINE == "wavefront"
    prob = compile_scenario(SCENARIOS[2]).problem   # gamma/gemm
    assert compiled_sweep(prob, 2) is compiled_sweep(prob, 2, "wavefront")
    assert compiled_sweep(prob, 2) is not compiled_sweep(prob, 2, "scan")


def test_explorer_engine_knob():
    ex_wf = Explorer(engine="wavefront")
    ex_sc = Explorer(engine="scan")
    cand = np.asarray([[1.0] * ex_wf.space.n,
                       [0.5, 2.0, 1.0, 0.7, 1.5]], np.float32)
    assert np.allclose(ex_wf.evaluate(cand), ex_sc.evaluate(cand), atol=0.5)
    with pytest.raises(ValueError, match="engine"):
        Explorer(engine="nonsense")


def test_unknown_engine_raises():
    aidg = compile_scenario(SCENARIOS[2]).aidg
    with pytest.raises(ValueError, match="engine"):
        fixed_point_jax(aidg, engine="nope")
    assert set(ENGINES) == {"wavefront", "scan", "blocked", "condensed"}
    # the Explorer additionally accepts the matrix-packed evaluator (its
    # default), which is not a per-cell fixed-point engine
    from repro.core.aidg.explorer import (DEFAULT_EXPLORER_ENGINE,
                                          EXPLORER_ENGINES)
    assert set(EXPLORER_ENGINES) == set(ENGINES) | {"packed"}
    assert DEFAULT_EXPLORER_ENGINE == "packed"


# ---------------------------------------------------------------------------
# (b) level schedule invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scenario", SCENARIOS, ids=IDS)
def test_level_schedule_invariants(scenario):
    ca = compile_aidg(compile_scenario(scenario).aidg)
    a, s = ca.aidg, ca.schedule
    # every predecessor is strictly shallower
    for i in range(a.n):
        js = a.preds[i][a.preds[i] >= 0]
        assert (s.depth[js] < s.depth[i]).all(), (scenario.name, i)
    # the levels partition the nodes
    real = s.level_nodes[s.level_nodes < a.n]
    assert np.array_equal(np.sort(real), np.arange(a.n))
    # level-major renumbering is a consistent permutation
    assert np.array_equal(s.order[s.rank], np.arange(a.n))
    assert (np.diff(s.depth[s.order]) >= 0).all()
    # the schedule never deepens past the node count
    assert s.n_levels <= max(1, a.n)
    assert a.stats["n_levels"] == s.n_levels


def test_level_schedule_of_empty_graph():
    s = compute_level_schedule(np.zeros((0, 4), np.int32), 0)
    assert s.n_levels == 0 and s.width == 0 and s.parallelism == 0.0


# ---------------------------------------------------------------------------
# (c) high-in-degree nodes: edges are widened, never dropped
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scenario", SCENARIOS, ids=IDS)
def test_default_scenarios_have_no_pred_overflow(scenario):
    aidg = compile_scenario(scenario).aidg
    assert aidg.stats["pred_overflow"] == 0, scenario.name
    assert aidg.preds.shape[1] == builder_mod.MAX_PREDS


def test_pred_width_expands_instead_of_dropping_edges(monkeypatch):
    """Rebuilding with a tiny MAX_PREDS must widen the padding (warning
    emitted) and keep the longest path bit-identical — no silent accuracy
    loss from dropped edges."""
    sc = SCENARIOS[2]                        # gamma/gemm, in-degree up to 4
    from repro.core.acadl.sim import build_trace
    from repro.core.aidg.builder import build_aidg
    ag, prog = sc.build()
    trace = build_trace(ag, prog)
    ref = longest_path(build_aidg(ag, trace))

    monkeypatch.setattr(builder_mod, "MAX_PREDS", 2)
    ag2, prog2 = sc.build()
    trace2 = build_trace(ag2, prog2)
    with pytest.warns(RuntimeWarning, match="widening"):
        tight = build_aidg(ag2, trace2)
    assert tight.stats["pred_overflow"] > 0
    assert tight.preds.shape[1] == tight.stats["pred_width"] > 2
    assert np.array_equal(longest_path(tight), ref)
    # the compiled wavefront evaluator folds the widened slots too
    assert np.array_equal(np.asarray(longest_path_wavefront(tight),
                                     np.float64), ref)


def test_evaluators_handle_wide_preds_directly():
    """A hand-built AIDG with more predecessors than MAX_PREDS evaluates
    identically through numpy, scan, and wavefront."""
    rng = np.random.default_rng(0)
    n, width = 40, 20
    preds = np.full((n, width), -1, np.int32)
    extra = np.zeros((n, width), np.float32)
    for i in range(1, n):
        k = int(rng.integers(1, min(i, width) + 1))
        js = rng.choice(i, size=k, replace=False)
        preds[i, :k] = np.sort(js)[::-1]
        extra[i, :k] = rng.integers(0, 4, k)
    aidg = AIDG(n=n, work=rng.integers(1, 5, n).astype(np.float32),
                fu_lat=np.zeros(n, np.float32),
                mem_lat=np.zeros(n, np.float32),
                base=rng.integers(0, 9, n).astype(np.float32),
                preds=preds, pred_extra=extra)
    t_np = longest_path(aidg)
    assert np.array_equal(t_np, np.asarray(longest_path_scan(aidg),
                                           np.float64))
    assert np.array_equal(t_np, np.asarray(longest_path_wavefront(aidg),
                                           np.float64))
    assert np.allclose(t_np, longest_path_blocked(aidg, block=16), atol=0.5)


# ---------------------------------------------------------------------------
# (d) AIDG dataclass defaults
# ---------------------------------------------------------------------------


def test_aidg_metadata_defaults_are_arrays():
    aidg = AIDG(n=0, work=np.zeros(0, np.float32),
                fu_lat=np.zeros(0, np.float32),
                mem_lat=np.zeros(0, np.float32),
                base=np.zeros(0, np.float32),
                preds=np.zeros((0, 1), np.int32),
                pred_extra=np.zeros((0, 1), np.float32))
    for attr in ("op_class", "op_scale", "mem_words"):
        val = getattr(aidg, attr)
        assert isinstance(val, np.ndarray), attr
        assert val.shape == (0,), attr
    # distinct instances don't share the default arrays
    other = AIDG(n=0, work=np.zeros(0, np.float32),
                 fu_lat=np.zeros(0, np.float32),
                 mem_lat=np.zeros(0, np.float32),
                 base=np.zeros(0, np.float32),
                 preds=np.zeros((0, 1), np.int32),
                 pred_extra=np.zeros((0, 1), np.float32))
    assert aidg.op_class is not other.op_class
    # make_problem consumes the defaults without special-casing None
    prob = make_problem(aidg)
    assert prob.n_op == 0 and prob.n_st == 0


# ---------------------------------------------------------------------------
# (e) blocked engine: device-resident scan + Pallas kernel on the AIDG path
# ---------------------------------------------------------------------------


def test_blocked_matches_numpy_and_accepts_pallas():
    from repro.kernels.maxplus import maxplus_matmul_pallas
    aidg = compile_scenario(SCENARIOS[2]).aidg     # gamma/gemm
    t_np = longest_path(aidg)
    t_jnp = longest_path_blocked(aidg, block=64)
    t_pl = longest_path_blocked(aidg, block=64,
                                matmul=maxplus_matmul_pallas)
    assert np.allclose(t_np, t_jnp, atol=0.5)
    assert np.allclose(t_np, t_pl, atol=0.5)


def test_blocked_engine_in_fixed_point():
    aidg = compile_scenario(SCENARIOS[2]).aidg
    fp_np = longest_path_fixed_point(aidg)
    fp_bl = np.asarray(fixed_point_jax(aidg, engine="blocked"))
    assert abs(fp_np.max() - fp_bl.max()) < 1.0


def test_slot_queue_single_slot_closed_form():
    """The slots == 1 cummax fast path equals the sequential reference."""
    rng = np.random.default_rng(3)
    arrival = np.sort(rng.integers(0, 50, 64)).astype(np.float32)
    lat = rng.integers(1, 9, 64).astype(np.float32)
    fast = np.asarray(slot_queue_scan(arrival, lat, 1))
    done, free = [], 0.0
    for a, l in zip(arrival, lat):
        free = max(float(a), free) + float(l)
        done.append(free)
    assert np.allclose(fast, np.asarray(done))
