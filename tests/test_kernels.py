"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps in
interpret=True mode (the CPU validation contract)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("m,k,n", [(32, 32, 32), (128, 128, 128),
                                   (100, 77, 130), (256, 64, 192), (8, 8, 8)])
def test_maxplus_matmul(m, k, n, rng):
    A = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    out = ops.maxplus_matmul(A, B, bm=32, bk=32, bn=32)
    np.testing.assert_allclose(out, ref.maxplus_matmul_ref(A, B), atol=1e-5)


def test_maxplus_associativity(rng):
    A, B, C = (jnp.asarray(rng.normal(size=(16, 16)), jnp.float32)
               for _ in range(3))
    left = ops.maxplus_matmul(ops.maxplus_matmul(A, B), C)
    right = ops.maxplus_matmul(A, ops.maxplus_matmul(B, C))
    np.testing.assert_allclose(left, right, atol=1e-4)


def test_maxplus_matvec(rng):
    """The single-column wrapper the blocked AIDG evaluator uses."""
    from repro.kernels.maxplus import maxplus_matvec_pallas
    A = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    out = maxplus_matvec_pallas(A, v)
    want = jnp.max(A + v[None, :], axis=1)
    np.testing.assert_allclose(out, want, atol=1e-5)


@pytest.mark.parametrize("m,k,n,dt", [
    (128, 128, 128, jnp.float32),
    (64, 200, 96, jnp.bfloat16),
    (37, 53, 29, jnp.float32),
    (256, 128, 64, jnp.bfloat16),
])
@pytest.mark.parametrize("act", [0, 1])
def test_systolic_gemm(m, k, n, dt, act, rng):
    A = jnp.asarray(rng.normal(size=(m, k)), dt)
    B = jnp.asarray(rng.normal(size=(k, n)), dt)
    out = ops.gemm(A, B, activation=act, bm=32, bk=64, bn=32)
    want = ref.gemm_ref(A, B, activation=act)
    atol = 2e-2 if dt == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(out, want, atol=atol, rtol=1e-2)


@pytest.mark.parametrize("b,h,s,d,causal,window", [
    (1, 2, 128, 64, True, 0),
    (2, 2, 256, 64, True, 0),
    (1, 1, 160, 64, True, 0),       # ragged -> padded
    (1, 2, 128, 64, False, 0),
    (1, 2, 256, 64, True, 64),      # sliding window
    (1, 2, 256, 128, True, 0),
])
def test_flash_attention(b, h, s, d, causal, window, rng):
    q, k, v = (jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
               for _ in range(3))
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              bq=64, bk=64)
    # windowed reference
    s_ = jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(d)
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= qp >= kp
    if window > 0:
        mask &= kp > qp - window
    s_ = jnp.where(mask, s_, -1e18)
    want = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(s_, axis=-1), v)
    np.testing.assert_allclose(out, want, atol=2e-4, rtol=1e-3)


def test_flash_attention_bf16(rng):
    q, k, v = (jnp.asarray(rng.normal(size=(2, 128, 64)), jnp.bfloat16)
               for _ in range(3))
    out = ops.flash_attention(q, k, v, causal=True, bq=64, bk=64)
    want = ref.flash_attention_ref(q[:, None].transpose(0, 1, 2, 3).reshape(2, 1, 128, 64),
                                   k.reshape(2, 1, 128, 64),
                                   v.reshape(2, 1, 128, 64), causal=True)
    np.testing.assert_allclose(out.astype(jnp.float32).reshape(2, 1, 128, 64),
                               want.astype(jnp.float32), atol=3e-2, rtol=3e-2)


@pytest.mark.parametrize("B,S,D,N,bd", [(2, 16, 32, 4, 16),
                                        (1, 64, 128, 16, 64),
                                        (2, 33, 48, 8, 16),
                                        (1, 20, 100, 8, 64)])
def test_selective_scan(B, S, D, N, bd, rng):
    x = jnp.asarray(rng.normal(size=(B, S, D)), jnp.float32) * 0.5
    dt = jnp.asarray(np.abs(rng.normal(size=(B, S, D))) * 0.1, jnp.float32)
    b = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    a = -jnp.asarray(np.abs(rng.normal(size=(D, N))) + 0.1, jnp.float32)
    d = jnp.asarray(rng.normal(size=(D,)), jnp.float32)
    got = ops.selective_scan(x, dt, b, c, a, d, bd=bd)
    want = ref.selective_scan_ref(x, dt, b, c, a, d)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)
