"""The recorded-baseline regression guard (``benchmarks/baseline.py``),
verified — not just wired.

Covers the comparator on synthetic snapshots (missing row, within
tolerance, breach), tolerance resolution (argument / env / cross-budget
scaling), snapshot loading preference, the injected-2x-slowdown
acceptance check against the REAL checked-in ``BENCH_dse*.json``, and
the wiring inside ``benchmarks.bench_dse.run`` itself (a slowed packed
row must abort the bench)."""

import json
import pathlib
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT))

from benchmarks import baseline  # noqa: E402


def snap(packed=1000.0, network=2000.0, energy=1000.0, budget="small"):
    """A synthetic recorded snapshot in the run.py --json shape."""
    return {"section": "dse", "budget": budget, "rows": [
        {"name": "dse/packed", "us_per_call": 1.0,
         "derived": f"configs_per_s={packed}",
         "metrics": {"configs_per_s": packed}},
        {"name": "dse/energy", "us_per_call": 1.0,
         "derived": f"configs_per_s={energy}",
         "metrics": {"configs_per_s": energy}},
        {"name": "network/matrix", "us_per_call": 1.0,
         "derived": f"configs_per_s={network}",
         "metrics": {"configs_per_s": network}},
    ]}


def live(packed=1000.0, network=2000.0, energy=1000.0, extra=()):
    """Synthetic LIVE bench rows (raw ``derived`` strings, as handed to
    the guard by ``bench_dse.run``)."""
    rows = [
        {"name": "dse/packed", "us_per_call": 1.0,
         "derived": f"engine=packed;configs_per_s={packed:.0f}"},
        {"name": "dse/energy", "us_per_call": 1.0,
         "derived": f"objectives=cycles+energy;configs_per_s={energy:.0f}"},
        {"name": "network/matrix", "us_per_call": 1.0,
         "derived": f"engine=packed;configs_per_s={network:.0f}"},
    ]
    rows.extend(extra)
    return rows


# -- check_rows: the comparator ---------------------------------------------

def test_within_tolerance_passes():
    assert baseline.check_rows(live(900.0, 1900.0), snap()) == []


def test_faster_than_recorded_passes():
    assert baseline.check_rows(live(5000.0, 9000.0), snap()) == []


def test_breach_reports_the_slowed_row():
    problems = baseline.check_rows(live(packed=400.0), snap())
    assert len(problems) == 1
    assert "dse/packed" in problems[0] and "regressed" in problems[0]


def test_both_rows_can_breach():
    problems = baseline.check_rows(live(400.0, 100.0), snap())
    assert len(problems) == 2


def test_missing_live_row_is_a_problem():
    rows = [r for r in live() if r["name"] != "network/matrix"]
    problems = baseline.check_rows(rows, snap())
    assert problems == ["network/matrix: missing from the live run"]


def test_missing_snapshot_row_is_a_problem():
    s = snap()
    s["rows"] = [r for r in s["rows"] if r["name"] != "dse/packed"]
    problems = baseline.check_rows(live(), s)
    assert problems == ["dse/packed: missing from the recorded snapshot"]


def test_non_numeric_metric_is_a_problem():
    rows = live()
    rows[0]["derived"] = "engine=packed"        # no configs_per_s at all
    problems = baseline.check_rows(rows, snap())
    assert "no numeric" in problems[0]


def test_tolerance_is_configurable():
    # 0.9x the recorded rate: fine at the default 0.5, breach at 0.95
    assert baseline.check_rows(live(900.0, 1800.0), snap()) == []
    tight = baseline.check_rows(live(900.0, 1800.0), snap(), tolerance=0.95)
    assert len(tight) == 2


# -- snapshot naming + loading ----------------------------------------------

def test_snapshot_path_budget_suffix(tmp_path):
    assert baseline.snapshot_path("dse", "full", tmp_path).name \
        == "BENCH_dse.json"
    assert baseline.snapshot_path("dse", "small", tmp_path).name \
        == "BENCH_dse_small.json"


def test_load_baseline_prefers_budget_match(tmp_path):
    (tmp_path / "BENCH_dse.json").write_text(json.dumps(snap(budget="full")))
    (tmp_path / "BENCH_dse_small.json").write_text(
        json.dumps(snap(packed=123.0, budget="small")))
    got = baseline.load_baseline("dse", "small", tmp_path)
    assert got["budget"] == "small"
    assert got["rows"][0]["metrics"]["configs_per_s"] == 123.0


def test_load_baseline_falls_back_to_full(tmp_path):
    (tmp_path / "BENCH_dse.json").write_text(json.dumps(snap(budget="full")))
    got = baseline.load_baseline("dse", "small", tmp_path)
    assert got["budget"] == "full"
    assert baseline.load_baseline("dse", "small", tmp_path / "nope") is None


# -- assert_baseline: the CI wiring -----------------------------------------

def test_assert_baseline_passes_and_breaches(tmp_path):
    (tmp_path / "BENCH_dse_small.json").write_text(json.dumps(snap()))
    baseline.assert_baseline(live(900.0, 1900.0), budget="small",
                             out_dir=tmp_path)
    with pytest.raises(AssertionError, match="dse/packed"):
        baseline.assert_baseline(live(packed=400.0), budget="small",
                                 out_dir=tmp_path)


def test_assert_baseline_missing_snapshot_is_an_error(tmp_path):
    with pytest.raises(AssertionError, match="no recorded baseline"):
        baseline.assert_baseline(live(), budget="small", out_dir=tmp_path)


def test_assert_baseline_env_tolerance(tmp_path, monkeypatch):
    (tmp_path / "BENCH_dse_small.json").write_text(json.dumps(snap()))
    # 0.6x the recorded rate passes the 0.5 default...
    baseline.assert_baseline(live(600.0, 1200.0), budget="small",
                             out_dir=tmp_path)
    # ...but breaches once the env tightens the floor to 0.8
    monkeypatch.setenv("BENCH_BASELINE_TOL", "0.8")
    with pytest.raises(AssertionError):
        baseline.assert_baseline(live(600.0, 1200.0), budget="small",
                                 out_dir=tmp_path)


def test_assert_baseline_cross_budget_scales_tolerance(tmp_path):
    # only the FULL snapshot exists: a small-budget run gets the
    # CROSS_BUDGET_FACTOR headroom (0.5 * 0.5 = 0.25 floor)...
    (tmp_path / "BENCH_dse.json").write_text(json.dumps(snap(budget="full")))
    baseline.assert_baseline(live(300.0, 700.0), budget="small",
                             out_dir=tmp_path)
    # ...which still catches a deep regression
    with pytest.raises(AssertionError):
        baseline.assert_baseline(live(100.0, 200.0), budget="small",
                                 out_dir=tmp_path)


def test_guard_enabled_env_and_budget(monkeypatch):
    monkeypatch.delenv("BENCH_BASELINE_GUARD", raising=False)
    assert baseline.guard_enabled("small") is True
    assert baseline.guard_enabled("full") is False
    monkeypatch.setenv("BENCH_BASELINE_GUARD", "1")
    assert baseline.guard_enabled("full") is True
    monkeypatch.setenv("BENCH_BASELINE_GUARD", "0")
    assert baseline.guard_enabled("small") is False


# -- the acceptance check: injected 2x slowdown vs the REAL snapshot --------

def test_injected_2x_slowdown_fails_against_checked_in_snapshot():
    """The acceptance criterion, against the actual recorded trajectory:
    synthesize a live run at 0.49x the checked-in throughput (a 2x
    slowdown as any real regression plus jitter would measure) and
    assert the guard breaches; at 1.0x it must pass."""
    recorded = baseline.load_baseline("dse", "small")
    assert recorded is not None, "BENCH_dse*.json must be checked in"
    by_name = {r["name"]: r["metrics"]["configs_per_s"]
               for r in recorded["rows"]
               if r["name"] in baseline.GUARDED_ROWS}
    assert set(by_name) == set(baseline.GUARDED_ROWS)
    ok = live(by_name["dse/packed"], by_name["network/matrix"],
              energy=by_name["dse/energy"])
    slow = live(by_name["dse/packed"] * 0.49,
                by_name["network/matrix"] * 0.49,
                energy=by_name["dse/energy"] * 0.49)
    assert baseline.check_rows(ok, recorded) == []
    problems = baseline.check_rows(slow, recorded)
    assert any("dse/packed" in p for p in problems)


def test_bench_dse_run_is_wired_to_the_guard(monkeypatch, tmp_path):
    """End-to-end wiring: ``bench_dse.run`` with stubbed measurement
    stages must call the guard and abort when the packed row comes in
    2x slow against the snapshot."""
    from benchmarks import bench_dse

    (tmp_path / "BENCH_dse_small.json").write_text(json.dumps(snap()))
    monkeypatch.setenv("BENCH_BUDGET", "small")
    monkeypatch.setenv("BENCH_BASELINE_GUARD", "1")
    monkeypatch.setattr(baseline, "REPO_ROOT", tmp_path)

    def stub(rows_out):
        def _run(rows):
            rows.extend(rows_out)
        return _run

    for stage in ("_bench_single", "_bench_matrix", "_bench_depth",
                  "_bench_gradient"):
        monkeypatch.setattr(bench_dse, stage, stub([]))
    monkeypatch.setattr(bench_dse, "_bench_network", stub(live(packed=400.0)))
    with pytest.raises(AssertionError, match="dse/packed"):
        bench_dse.run([])
    monkeypatch.setattr(bench_dse, "_bench_network", stub(live()))
    bench_dse.run([])                  # healthy rows pass
