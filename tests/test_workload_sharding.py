"""Workload extraction (paper §5) and sharding-rule invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import all_arch_ids, get_config, get_smoke_config
from repro.core.aidg import estimate_cycles
from repro.core.archs import TPU_V5E, make_tpu_v5e_ag
from repro.core.mapping.workload import extract_operators, map_to_tpu
from repro.launch.roofline import parse_collective_bytes, roofline_terms
from repro.launch.sharding import guard_spec
from repro.models import SHAPES, get_model


@pytest.mark.parametrize("arch", all_arch_ids())
def test_operator_macs_match_analytic_flops(arch):
    """2 * extracted MACs ≈ 6·N_active·D for training (±25%)."""
    cfg = get_config(arch)
    shape = SHAPES["train_4k"]
    ops = extract_operators(cfg, shape)
    macs = sum(o.macs for o in ops)
    tokens = shape.global_batch * shape.seq_len
    model_flops = 6 * cfg.n_active_params() * tokens
    ratio = 2 * macs / model_flops
    assert 0.7 < ratio < 1.4, (arch, ratio)


def test_tpu_mapping_reproduces_compute_roofline():
    """AIDG cycles on the TPU-v5e ACADL model ≈ analytic compute bound for
    a compute-bound workload (mistral train) — the model/roofline
    cross-validation experiment."""
    cfg = get_config("mistral-large-123b")
    shape = SHAPES["train_4k"]
    ag, _ = make_tpu_v5e_ag()
    prog = map_to_tpu(cfg, shape, per_device=256)
    cycles, _ = estimate_cycles(ag, prog)
    secs = cycles / (TPU_V5E["clock_ghz"] * 1e9)
    tokens = shape.global_batch * shape.seq_len
    analytic = 6 * cfg.n_params() * tokens / 256 / TPU_V5E["peak_bf16_flops"]
    assert 0.8 < secs / analytic < 1.5, (secs, analytic)


def test_guard_spec_drops_nondividing_axes():
    import jax
    mesh = jax.make_mesh((1,), ("model",))  # single device: size-1 axes
    spec = guard_spec(mesh, P("model", None), (7, 3))
    assert spec == P("model", None)  # 7 % 1 == 0 -> kept


def test_collective_parser_counts_while_trips():
    hlo = """
%body.1 (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %ar = f32[8]{0} all-reduce(%x), replica_groups={}
  ROOT %t = (s32[], f32[8]) tuple(%i, %ar)
}
%cond.1 (p: (s32[], f32[8])) -> pred[] {
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}
ENTRY %main (a: f32[8]) -> f32[8] {
  %ag = f32[16]{0} all-gather(%a), replica_groups={}
  %w = (s32[], f32[8]) while(%init), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[8] get-tuple-element(%w), index=1
}
"""
    out = parse_collective_bytes(hlo)
    assert out["all-gather"]["count"] == 1
    assert out["all-gather"]["bytes"] == 16 * 4
    assert out["all-reduce"]["count"] == 5            # 5 while trips
    assert out["all-reduce"]["bytes"] == 5 * 8 * 4 * 2  # ring factor 2


def test_roofline_terms():
    t = roofline_terms(flops=197e12, hbm_bytes=819e9, coll_bytes=50e9)
    assert abs(t["compute_s"] - 1.0) < 1e-9
    assert abs(t["memory_s"] - 1.0) < 1e-9
    assert abs(t["collective_s"] - 1.0) < 1e-9
