"""Energy/power as first-class DSE objectives
(repro.core.archs.energy + repro.core.aidg.energy + the packed
3-objective dispatch):

(a) exactness: the packed engine's in-trace energy equals the per-cell
    analytic recompute from raw op-class counts on EVERY matrix cell at
    θ = 1 (and within float tolerance at random θ), and folding through
    the condensed chains (``CondensedAIDG.op_class_counts``) counts
    exactly the same instructions as a raw bincount,
(b) gradients: the energy and energy-delay objectives' analytic/AD
    gradients match central finite differences,
(c) the per-memory-level bottleneck report: storage-node traffic x
    per-level access energy, grouped by storage class, shares summing
    to one,
(d) the per-tech-node coefficient tables and classifier regexes.
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core.aidg.builder import condense_aidg
from repro.core.aidg.energy import energy_bottleneck_report, fold_dyn_energy
from repro.core.aidg.explorer import Explorer
from repro.core.aidg.gradient import GradientExplorer
from repro.core.archs.energy import (ARCH_TECH_NM, ENERGY_REGISTRY,
                                     EnergyModel, TECH_TABLES, energy_model)


@pytest.fixture(scope="module")
def ex(matrix_ex):
    """The full scenario/network matrix on the packed engine (the shared
    session instance — see conftest)."""
    return matrix_ex


@pytest.fixture(scope="module")
def ex_op():
    """Operator cells only (cheap) — for the gradient tests."""
    return Explorer()


# ---------------------------------------------------------------------------
# (a) exactness: packed == analytic at random θ, condensed fold == raw fold
# (the θ = 1 per-cell recompute assert lives in tests/test_oracle_chain.py,
# the one differential harness for all cross-engine agreement claims)
# ---------------------------------------------------------------------------


def test_packed_energy_matches_analytic_at_random_theta(ex):
    """Away from θ = 1 the closed form still holds (counts are
    θ-independent):  E(θ) = edyn · (1/θ, 1) + P_static · T(θ)."""
    rng = np.random.default_rng(11)
    kt = np.exp(rng.uniform(-0.6, 0.6, (4, ex.space.n))).astype(np.float32)
    cycles, energy = ex.evaluate_full(kt)
    edyn, pstat = ex._energy_arrays()
    inv = 1.0 / np.concatenate(
        [kt.astype(np.float64), np.ones((kt.shape[0], 1))], axis=1)
    e_ref = inv @ edyn.T + pstat[None, :] * cycles.astype(np.float64)
    np.testing.assert_allclose(energy, e_ref, rtol=2e-4)


def test_condensed_fold_counts_exactly_match_raw_bincount(ex_op):
    """Absorbed ∪ kept = all nodes: folding the dynamic energy through
    ``CondensedAIDG.op_class_counts`` + the kept-node bincount gives the
    SAME integer counts as the raw AIDG bincount, so the two folds are
    bit-equal (integer arithmetic, identical pJ multipliers)."""
    for cs, proj in zip(ex_op.compiled, ex_op._projections):
        model = energy_model(cs.arch)
        raw = fold_dyn_energy(cs.problem, proj, ex_op.space.n, model)
        cond = condense_aidg(cs.problem.aidg)
        via_cond = fold_dyn_energy(cs.problem, proj, ex_op.space.n, model,
                                   cond=cond)
        assert np.array_equal(raw, via_cond), cs.name
        assert raw.sum() > 0.0, cs.name          # every cell burns energy


def test_explore_energy_rides_the_same_dispatch(ex_op):
    """explore() returns the normalized energy objective alongside
    latency/cost, and faster-than-baseline θ burns MORE dynamic energy
    (the DVFS-style counter-objective that makes the trade-off real)."""
    kt = np.stack([np.ones(ex_op.space.n, np.float32),
                   np.full(ex_op.space.n, 0.5, np.float32)])
    res = ex_op.explore(kt)
    assert res.energy.shape == res.latency.shape
    assert res.energy[0] == pytest.approx(1.0, abs=1e-5)
    assert res.latency[1] < res.latency[0]       # θ = 0.5: faster...
    assert res.energy[1] > res.energy[0]         # ...but more joules
    row = res.frontier()[0]
    assert "energy" in row


# ---------------------------------------------------------------------------
# (b) energy-objective gradients vs central finite differences
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("objective", ["energy", "edp"])
def test_energy_gradient_matches_finite_differences(ex_op, objective):
    ge = GradientExplorer(ex_op, objective=objective)
    K = ex_op.space.n
    rng = np.random.default_rng(29)
    knobs = np.exp(rng.uniform(-0.5, 0.5, K)).astype(np.float32)
    tau = 0.2                    # same curvature scale as the latency FD test
    _, g = ge.value_and_grad(knobs[None], tau)
    g = np.asarray(g[0], np.float64)
    eps = 1e-2
    for k in range(K):
        kp, km = knobs.copy(), knobs.copy()
        kp[k] += eps
        km[k] -= eps
        vp, _ = ge.value_and_grad(kp[None], tau)
        vm, _ = ge.value_and_grad(km[None], tau)
        fd = (float(vp[0]) - float(vm[0])) / (2 * eps)
        assert abs(fd - g[k]) <= 5e-2 * max(1.0, abs(fd)), \
            (objective, ex_op.space.names[k], fd, g[k])


def test_energy_objective_needs_the_packed_engine():
    from repro.core.aidg.explorer import default_scenarios
    exw = Explorer(scenarios=default_scenarios()[:1], engine="wavefront")
    with pytest.raises(ValueError, match="objective"):
        GradientExplorer(exw, objective="energy")
    with pytest.raises(ValueError, match="packed"):
        GradientExplorer(exw, objective="edp")


def test_energy_refine_hard_score_is_reproducible(ex_op):
    ge = GradientExplorer(ex_op, objective="edp")
    out = ge.refine(starts=2, steps=4)
    re = ex_op.explore(out.theta[None, :])
    assert float(re.latency[0] * re.energy[0]) == pytest.approx(
        out.score, rel=1e-6)


# ---------------------------------------------------------------------------
# (c) the per-memory-level energy-bottleneck report
# ---------------------------------------------------------------------------


def test_bottleneck_report_scenario_cell(ex_op):
    cs = next(c for c in ex_op.compiled if c.name == "tpu_v5e/gemm")
    rows = energy_bottleneck_report(cs)
    assert rows, "tpu_v5e/gemm moves data — report must not be empty"
    classes = {r["storage_class"] for r in rows}
    assert "dram" in classes                     # hbm0
    assert "onchip" in classes                   # vmem0
    shares = [r["share"] for r in rows]
    assert sum(shares) == pytest.approx(1.0)
    assert shares == sorted(shares, reverse=True)    # sorted descending
    for r in rows:
        assert r["energy_pj"] == pytest.approx(
            r["words"] * r["pj_per_word"])
    # DRAM access energy dominates on-chip per word — with real traffic
    # on both levels the report makes the hierarchy visible
    by_cls = {r["storage_class"]: r for r in rows}
    assert by_cls["dram"]["pj_per_word"] > by_cls["onchip"]["pj_per_word"]


def test_bottleneck_report_network_cell(ex):
    net = next(c for c in ex.compiled if hasattr(c, "stack"))
    rows = energy_bottleneck_report(net)
    assert rows
    assert sum(r["share"] for r in rows) == pytest.approx(1.0)
    total = sum(r["energy_pj"] for r in rows)
    assert total > 0.0
    # composed traffic: a whole DNN moves orders of magnitude more words
    # than any single-operator cell
    op = energy_bottleneck_report(ex.compiled[0])
    assert total > sum(r["energy_pj"] for r in op)


# ---------------------------------------------------------------------------
# (d) the coefficient tables and classifiers
# ---------------------------------------------------------------------------


def test_energy_model_registry_and_tables():
    assert set(ENERGY_REGISTRY) == set(ARCH_TECH_NM)
    for arch, nm in ARCH_TECH_NM.items():
        m = energy_model(arch)
        assert m.tech_nm == nm
        assert m.static_pj > 0.0
    # unknown architectures fall back to the default node, not a KeyError
    assert isinstance(energy_model("not_an_arch"), EnergyModel)
    # scaling: every coefficient shrinks monotonically with the tech node
    for cls in ("mac", "vector", "mem", "ctrl"):
        vals = [TECH_TABLES[nm]["op"][cls] for nm in sorted(TECH_TABLES)]
        assert vals == sorted(vals), cls         # 7 nm cheapest
    for cls in ("reg", "onchip", "dram"):
        vals = [TECH_TABLES[nm]["word"][cls] for nm in sorted(TECH_TABLES)]
        assert vals == sorted(vals), cls


def test_op_and_storage_classifiers():
    assert EnergyModel.op_category("gemm@matMulFu0") == "mac"
    assert EnergyModel.op_category("row_conv@pe00") == "mac"
    assert EnergyModel.op_category("attn@vpu0") == "vector"
    assert EnergyModel.op_category("reduce@cu3") == "vector"
    assert EnergyModel.op_category("t_load@lsu0") == "mem"
    assert EnergyModel.op_category("drain@store0") == "mem"
    assert EnergyModel.op_category("branch@ctrl0") == "ctrl"
    assert EnergyModel.storage_class("dram0") == "dram"
    assert EnergyModel.storage_class("hbm0") == "dram"
    assert EnergyModel.storage_class("vmem0") == "onchip"
    assert EnergyModel.storage_class("glb0") == "onchip"
    assert EnergyModel.storage_class("pmu2") == "onchip"
    assert EnergyModel.storage_class("rf7") == "reg"
    m = energy_model("gamma")
    assert m.word_pj("dram0") == m.word_table["dram"]
    assert m.op_pj("gemm@matMulFu0") == m.op_table["mac"]
