"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on the single CPU
device; only the dry-run (repro.launch.dryrun) forces 512 host devices."""

import numpy as np
import pytest

try:
    # the autouse cache-stats reset below is function-scoped; hypothesis's
    # health check would otherwise flag it on every @given test.  Resetting
    # once per test (not per example) is exactly the intended semantics —
    # the counters are only read by tests that generate their own traffic.
    from hypothesis import HealthCheck, settings as _hsettings
    _hsettings.register_profile(
        "repro", suppress_health_check=[HealthCheck.function_scoped_fixture])
    _hsettings.load_profile("repro")
except ImportError:          # hypothesis is a dev-only dependency
    pass


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def matrix_ex():
    """ONE full scenario/network matrix Explorer (packed engine) shared
    across the whole suite — building it compiles all 31 cells, so every
    module that sweeps the full matrix (energy, oracle chain) must reuse
    this instance instead of constructing its own."""
    from repro.core.aidg.explorer import Explorer
    return Explorer(networks=True)


@pytest.fixture(scope="session")
def matrix_surrogate(matrix_ex):
    """The surrogate tier trained on ``matrix_ex`` from the fixed default
    seed — the artifact the oracle-chain tier checks against its stated
    calibration, shared because training is the expensive step."""
    from repro.surrogate import train_surrogate
    return train_surrogate(matrix_ex)


@pytest.fixture(autouse=True)
def _isolate_scenario_cache_stats():
    """Zero the process-wide AIDG-cache hit/miss counters before every
    test (the cache CONTENTS are kept — clearing compiled scenarios would
    slow the suite enormously and tests that need a cold cache call
    ``clear_scenario_cache`` themselves).  Without this, any test reading
    ``scenario_cache_stats`` sees counts leaked from whichever tests
    happened to run earlier — order-dependent flakiness."""
    from repro.core.aidg import explorer
    explorer._CACHE_STATS["hits"] = 0
    explorer._CACHE_STATS["misses"] = 0
    yield
