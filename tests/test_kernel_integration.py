"""Pallas kernel paths wired into the model stack: the `attention_impl` /
`ssm_impl` config knobs must be numerically equivalent to the pure-jnp
paths (interpret=True on CPU; on TPU the same knobs select the compiled
kernels)."""

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.lm as lm
from repro.configs import get_smoke_config
from repro.models import get_model
from repro.models.config import SSMConfig
from repro.models.mamba import init_mamba, mamba_block


@pytest.mark.parametrize("arch", ["olmo_1b", "mistral_large_123b"])
def test_flash_pallas_impl_matches_chunked(arch):
    cfg = replace(get_smoke_config(arch), compute_dtype="float32")
    model = get_model(cfg)
    params = model.init_params(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 64), 0, cfg.vocab_size)
    a = lm.forward(params, cfg, toks, impl="chunked", chunk=16)
    b = lm.forward(params, cfg, toks, impl="flash_pallas_interpret")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-4,
                               rtol=1e-3)


def test_pallas_ssm_impl_matches_chunked_scan():
    cfg = SSMConfig(d_state=4, d_conv=4, expand=2, chunk=8)
    params = init_mamba(jax.random.key(0), cfg, 8, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 16, 8)) * 0.5
    y1, _ = mamba_block(params, x, cfg)
    y2, _ = mamba_block(params, x, cfg, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


def test_ssm_impl_through_config():
    cfg = replace(get_smoke_config("falcon_mamba_7b"),
                  compute_dtype="float32")
    cfg_pl = replace(cfg, ssm_impl="pallas_interpret")
    m1, m2 = get_model(cfg), get_model(cfg_pl)
    params = m1.init_params(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
    a = m1.logits(params, {"tokens": toks})
    b = m2.logits(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-4,
                               rtol=1e-3)
